//! Ad-hoc XLA-compile-time probe: `compile_probe <hlo-file>...` times the
//! PJRT compile of each given HLO-text artifact (used for the §Perf
//! compile-latency investigation in EXPERIMENTS.md).
use quantum_peft::runtime::Runtime;
fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    for path in std::env::args().skip(1) {
        let t0 = std::time::Instant::now();
        rt.load(std::path::Path::new(&path))?;
        println!("{path}: {:.1}s ({} KB)", t0.elapsed().as_secs_f64(),
                 std::fs::metadata(&path)?.len() / 1024);
    }
    Ok(())
}
