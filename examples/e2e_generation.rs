//! E2E-NLG example (Table 3's workload): pretrain a small decoder LM on
//! domain text, PEFT-fine-tune it to verbalize slot/value meaning
//! representations, then *generate* with greedy decoding and score with
//! the full n-gram metric suite — printing actual generated text.
//!
//!   cargo run --release --example e2e_generation

use quantum_peft::config;
use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::trainer::{greedy_generate, pretrain_decoder,
                                         run_e2e, E2eRunSpec};
use quantum_peft::data::e2e::E2eData;
use quantum_peft::report::tables;
use quantum_peft::runtime::{Manifest, Runtime, TrainSession};
use quantum_peft::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("REPRO_PRESET").unwrap_or_else(|_| "quick".into());
    let cfg = config::preset(&preset)?;
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let log = EventLog::null();

    let backbone = tables::runs_dir().join("backbones/example_dec.qpck");
    let steps = cfg.f64_or("pretrain", "steps", 150.0) as usize;
    println!("[1/3] pretraining decoder LM ({steps} steps)");
    let losses = pretrain_decoder(&rt, &manifest, "dec_pretrain", steps,
                                  0.003, 0, &backbone, &log)?;
    println!("  lm loss {:.3} -> {:.3}", losses[0],
             losses.last().unwrap());

    println!("[2/3] fine-tuning Quantum-PEFT (Q_T, P=3, K=2) on slot-to-text");
    let spec = E2eRunSpec {
        tag: "dec_qpeft_taylor",
        cfg: config::train_config(&cfg),
        backbone: Some(&backbone),
        gen_cases: 48,
    };
    let r = run_e2e(&rt, &manifest, &spec, &log)?;
    println!("  metrics:");
    for (k, v) in &r.extra_metrics {
        println!("    {k:<8} {v:.4}");
    }

    println!("[3/3] sample generations");
    let entry = manifest.get("dec_qpeft_taylor")?;
    let mut session = TrainSession::new(&rt, entry, 0)?;
    session.load_named(&quantum_peft::coordinator::checkpoint::load(&backbone)?)?;
    // quick adaptation so samples aren't from the raw backbone
    let data = E2eData::new();
    let mut rng = Rng::new(1);
    let seq_len = entry.batch[0].shape[1];
    let bsz = entry.batch_size();
    for step in 0..60 {
        let mut toks = Vec::new();
        let mut masks = Vec::new();
        for _ in 0..bsz {
            let (t, m, _) = data.training_example(&mut rng, seq_len);
            toks.push(t);
            masks.push(m);
        }
        let batch = [
            quantum_peft::runtime::tensors::stack_tokens(&toks),
            quantum_peft::runtime::tensors::stack_f32(&masks, &[seq_len]),
        ];
        session.step(&batch, 0.01, 0.01,
                     &quantum_peft::coordinator::trainer::default_extras(
                         &session.entry, 0.0, &Default::default()))?;
        let _ = step;
    }
    let mrs: Vec<_> = (0..4).map(|_| data.sample_mr(&mut rng)).collect();
    let extras = quantum_peft::coordinator::trainer::default_extras(
        &session.entry, 0.0, &Default::default());
    let hyps = greedy_generate(&session, &data, &mrs, seq_len, &extras)?;
    for (mr, hyp) in mrs.iter().zip(&hyps) {
        println!("  MR:  {}", data.vocab.decode(&data.mr_tokens(mr)));
        println!("  GEN: {}", data.vocab.decode(hyp));
        println!("  REF: {}", data.vocab.decode(&data.references(mr)[0]));
        println!();
    }
    Ok(())
}
