//! END-TO-END DRIVER (DESIGN.md §4, EXPERIMENTS.md §E2E): exercises every
//! layer of the system on a real workload —
//!
//!   1. *pretrain* the transformer encoder on the synthetic corpus with
//!      the denoising objective, logging the loss curve (L2 train-step
//!      graphs with Pallas kernels, executed by the L3 coordinator over
//!      PJRT);
//!   2. freeze the backbone and *fine-tune* a panel of PEFT methods
//!      (LoRA, AdaLoRA, and both Quantum-PEFT parameterizations) on two
//!      GLUE-substitute tasks;
//!   3. print the Table-2-shaped comparison: accuracy vs adapter params.
//!
//!   REPRO_PRESET=quick cargo run --release --example glue_sweep

use std::collections::BTreeMap;

use quantum_peft::config;
use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::sweep::{aggregate, run_glue_sweep, SweepPlan};
use quantum_peft::coordinator::trainer::pretrain_encoder;
use quantum_peft::data::glue::Task;
use quantum_peft::report::{self, tables};
use quantum_peft::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("REPRO_PRESET").unwrap_or_else(|_| "quick".into());
    let cfg = config::preset(&preset)?;
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let log = EventLog::new(Some(tables::runs_dir().join("glue_sweep.jsonl")),
                            false)?;

    // ---- 1. pretraining (the loss curve is the e2e health signal) ----
    let backbone = tables::runs_dir().join("backbones/example_enc.qpck");
    let steps = cfg.f64_or("pretrain", "steps", 200.0) as usize;
    println!("[1/3] pretraining encoder backbone: {steps} steps");
    let losses = pretrain_encoder(&rt, &manifest, "enc_pretrain", steps,
                                  0.003, 0, &backbone, &log)?;
    for (i, chunk) in losses.chunks(steps.div_ceil(10)).enumerate() {
        let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  pretrain loss [{:>3}%] {:.4}", (i + 1) * 10, mean);
    }

    // ---- 2. PEFT sweep over the frozen backbone ----
    println!("[2/3] fine-tuning PEFT panel");
    let plan = SweepPlan {
        tags: ["enc_lora", "enc_adalora", "enc_qpeft_taylor",
               "enc_qpeft_pauli"].iter().map(|s| s.to_string()).collect(),
        tasks: vec![Task::Sst2, Task::Mrpc],
        seeds: vec![0],
        cfg: config::train_config(&cfg),
        backbone: Some(backbone),
        task_lr: BTreeMap::new(),
    };
    let results = run_glue_sweep(&rt, &manifest, &plan, &log)?;

    // ---- 3. Table-2-shaped report ----
    println!("[3/3] results");
    let aggs = aggregate(&results);
    let rows: Vec<Vec<String>> = aggs.iter()
        .map(|a| vec![
            a.tag.clone(),
            a.task.clone(),
            report::fmt_params(a.adapter_params),
            format!("{:.2}", 100.0 * a.mean_metric),
            format!("{:.1}", a.mean_step_ms),
        ])
        .collect();
    print!("{}", report::render_table(
        &["method", "task", "adapter params", "metric %", "ms/step"], &rows));
    println!("\nXLA compile: {:.1}s total (cached per artifact)",
             rt.total_compile_seconds());
    Ok(())
}
