//! Quickstart: load an AOT artifact, fine-tune Quantum-PEFT (Pauli) on a
//! synthetic task for a handful of steps, and inspect the result.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::collections::BTreeMap;

use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::trainer::{run_glue, GlueRunSpec, TrainConfig};
use quantum_peft::data::glue::Task;
use quantum_peft::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = Manifest::load(&Manifest::default_dir())?;

    // The paper's method at its most extreme: Q_P adapters with
    // (2L+1)log2(d) - 2L angles per side — 140 adapter params total on
    // this encoder, vs 2048 for LoRA(K=4).
    let entry = manifest.get("enc_qpeft_pauli")?;
    println!("artifact {}: {} adapter params, {} trainable (incl. head)",
             entry.tag, entry.adapter_param_count, entry.trainable_param_count);

    let spec = GlueRunSpec {
        tag: "enc_qpeft_pauli",
        task: Task::Sst2,
        cfg: TrainConfig {
            steps: 40,
            lr: 0.02,
            train_examples: 256,
            test_examples: 128,
            eval_every: 20,
            ..TrainConfig::default()
        },
        backbone: None, // quickstart trains from scratch; see glue_sweep
        extras_override: BTreeMap::new(),
    };
    let r = run_glue(&rt, &manifest, &spec, &EventLog::null())?;
    println!("loss: {:.4} -> {:.4}", r.losses.first().unwrap(),
             r.losses.last().unwrap());
    println!("sst2 accuracy: {:.2}% with {} adapter parameters",
             100.0 * r.best_metric, r.adapter_params);
    println!("step time: {:.1} ms/batch (XLA compile {:.1}s, once per artifact)",
             r.step_ms, rt.total_compile_seconds());
    Ok(())
}
