//! ViT transfer example (Table 6's workload): pretrain a small ViT on the
//! 20-class synthetic pretask, quantize the frozen backbone to 3 bits
//! host-side, then fine-tune adapters on the held-out 10-class task —
//! LoRA ranks vs Quantum-PEFT Pauli, reporting accuracy vs adapter params.
//!
//!   cargo run --release --example vit_transfer

use std::collections::BTreeMap;

use quantum_peft::config;
use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::trainer::{pretrain_vit, run_vit, VitRunSpec};
use quantum_peft::report::{self, tables};
use quantum_peft::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("REPRO_PRESET").unwrap_or_else(|_| "quick".into());
    let cfg = config::preset(&preset)?;
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let log = EventLog::null();

    let backbone = tables::runs_dir().join("backbones/example_vit.qpck");
    let steps = cfg.f64_or("pretrain", "steps", 200.0) as usize;
    println!("[1/2] pretraining ViT on 20-class pretask ({steps} steps)");
    let losses = pretrain_vit(&rt, &manifest, "vit_pretrain", steps, 0.003, 0,
                              &backbone, &log)?;
    println!("  loss {:.3} -> {:.3}", losses[0], losses.last().unwrap());

    println!("[2/2] transfer to 10 held-out classes, 3-bit frozen backbone");
    let tcfg = config::train_config(&cfg);
    let mut rows = Vec::new();
    for tag in ["vit_lora_k1", "vit_lora_k4", "vit_qpt_pauli"] {
        let spec = VitRunSpec {
            tag,
            cfg: tcfg.clone(),
            backbone: Some(&backbone),
            base_bits: Some(3),
            extras_override: BTreeMap::new(),
        };
        let r = run_vit(&rt, &manifest, &spec, &log)?;
        println!("  {tag}: {:.2}% ({} adapter params)",
                 100.0 * r.best_metric, r.adapter_params);
        rows.push(vec![
            tag.to_string(),
            report::fmt_params(r.adapter_params),
            format!("{:.2}", 100.0 * r.best_metric),
        ]);
    }
    print!("{}", report::render_table(
        &["method", "adapter params", "accuracy %"], &rows));
    Ok(())
}
