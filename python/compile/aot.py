"""AOT lowering: every (model, method) config -> HLO-text artifacts + manifest.

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits protos
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
`xla` 0.1.6 crate binds) rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Per config tag three computations are lowered:

  {tag}.init.hlo.txt    (seed:i32) -> (frozen..., train...)
  {tag}.train.hlo.txt   (frozen..., train..., m..., v..., step, lr, wd,
                         extras..., batch...) -> (loss, train', m', v')
  {tag}.eval.hlo.txt    (frozen..., train..., extras..., batch_x)
                         -> (logits,)

plus `artifacts/manifest.json` describing every tensor (name/shape/dtype)
so the Rust runtime (rust/src/runtime/manifest.rs) is fully self-
sufficient. Python never runs again after this step.

Usage:  python -m compile.aot [--out-dir ../artifacts] [--filter enc_]
        python -m compile.aot --list
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from . import train as T
from .models import decoder as dec
from .models import transformer as enc
from .models import vit as vit_mod
from .peft import make_method
from .peft.base import PeftMethod


# --------------------------------------------------------------------------
# config registry
# --------------------------------------------------------------------------

ENC_CFG = enc.EncoderConfig(vocab=256, d=64, n_heads=4, n_layers=2, ff=128,
                            seq_len=24, n_out=2)
DEC_CFG = dec.DecoderConfig(vocab=256, d=64, n_heads=4, n_layers=2, ff=128,
                            seq_len=48)
VIT_CFG = vit_mod.ViTConfig(image=16, patch=4, d=64, n_heads=4, n_layers=2,
                            ff=128, n_out=10)
VIT_PRE_CFG = vit_mod.ViTConfig(image=16, patch=4, d=64, n_heads=4,
                                n_layers=2, ff=128, n_out=20)

ENC_BATCH = 16
DEC_BATCH = 16
VIT_BATCH = 16


def _cfgdict(c):
    import dataclasses

    return dataclasses.asdict(c)


def configs():
    """(tag -> spec) for every artifact family. spec keys: model, cfg,
    method (registry name), method_kw, task ('cls'|'dae'|'lm'|'img'),
    extras (model-level runtime scalars)."""
    out = {}

    def add(tag, **spec):
        assert tag not in out
        out[tag] = spec

    # ---- encoder: synthetic-GLUE family (Tables 2 & 5) ----
    add("enc_pretrain", model="encoder", cfg=ENC_CFG, method="ft",
        method_kw={}, task="dae", extras=())
    enc_methods = [
        ("ft", {}),
        ("lora", dict(k=4)),
        ("adalora", dict(k=4)),
        ("loha", dict(k=4)),
        ("lokr", dict(k=4, f=8)),
        ("bitfit", {}),
        ("hadapter", dict(bottleneck=8)),
        ("padapter", dict(bottleneck=8)),
        ("mora", dict(k=4)),
        ("quanta", {}),
        ("qpeft_pauli", dict(k=3, n_layers=1)),
        ("qpeft_taylor", dict(k=4, order=8)),
    ]
    for name, kw in enc_methods:
        add(f"enc_{name}", model="encoder", cfg=ENC_CFG, method=name,
            method_kw=kw, task="cls", extras=("task_kind",))

    # wide encoder = the Mistral-7B stand-in for Table 5 (2x width)
    wide = enc.EncoderConfig(vocab=256, d=128, n_heads=4, n_layers=2, ff=256,
                             seq_len=24, n_out=2)
    add("encw_pretrain", model="encoder", cfg=wide, method="ft",
        method_kw={}, task="dae", extras=())
    for name, kw in [("lora", dict(k=4)), ("adalora", dict(k=4)),
                     ("qpeft_taylor", dict(k=4, order=8))]:
        add(f"encw_{name}", model="encoder", cfg=wide, method=name,
            method_kw=kw, task="cls", extras=("task_kind",))

    # ---- decoder: E2E-NLG family (Tables 3 & 4) ----
    add("dec_pretrain", model="decoder", cfg=DEC_CFG, method="ft",
        method_kw={}, task="lm", extras=())
    for name, kw in [
        ("ft", {}),
        ("lora", dict(k=4)),
        ("adalora", dict(k=4)),
        ("loha", dict(k=4)),
        ("lokr", dict(k=4, f=8)),
        ("qpeft_taylor", dict(k=2, order=3)),   # paper: Q_T, P=3, K=2 (K'=1)
    ]:
        add(f"dec_{name}", model="decoder", cfg=DEC_CFG, method=name,
            method_kw=kw, task="lm", extras=())

    # ---- ViT: CIFAR transfer family (Tables 6-10) ----
    add("vit_pretrain", model="vit", cfg=VIT_PRE_CFG, method="ft",
        method_kw={}, task="img", extras=())
    vit_methods = [
        ("ft", {}, "ft"),
        ("lora", dict(k=1), "lora_k1"),
        ("lora", dict(k=2), "lora_k2"),
        ("lora", dict(k=4), "lora_k4"),
        ("qpeft_pauli", dict(k=1, n_layers=1), "qpt_pauli"),
        ("qpeft_pauli", dict(k=1, n_layers=2), "qpt_pauli_l2"),   # Table 9
        ("qpeft_pauli", dict(k=1, n_layers=3), "qpt_pauli_l3"),
        ("qpeft_pauli", dict(k=1, n_layers=4), "qpt_pauli_l4"),
        # one artifact serves Tables 7 + 8: K' and quantization are runtime
        ("qpeft_taylor", dict(k=8, order=8, group=32), "qpt_taylor"),
        ("qpeft_tn", dict(network="cp", k=4), "tn_cp"),           # Table 10
        ("qpeft_tn", dict(network="td", k=4), "tn_td"),
        ("qpeft_tn", dict(network="ttd", k=4), "tn_ttd"),
        ("qpeft_tn", dict(network="trd", k=4), "tn_trd"),
        ("qpeft_tn", dict(network="htd", k=4), "tn_htd"),
    ]
    for name, kw, tag in vit_methods:
        add(f"vit_{tag}", model="vit", cfg=VIT_CFG, method=name,
            method_kw=kw, task="img", extras=())
    return out


# --------------------------------------------------------------------------
# per-config assembly
# --------------------------------------------------------------------------

def build_tree(spec, key, method: PeftMethod):
    model, cfg, task = spec["model"], spec["cfg"], spec["task"]
    kb, ka, kh = jax.random.split(key, 3)
    if model == "encoder":
        base = enc.init_base(kb, cfg)
        heads = enc.init_heads(kh, cfg)
        head = heads["dae"] if task == "dae" else heads["cls"]
        adapters = enc.init_adapters(ka, cfg, method)
    elif model == "decoder":
        base = dec.init_base(kb, cfg)
        head = dec.init_heads(kh, cfg)["lm"]
        adapters = dec.init_adapters(ka, cfg, method)
    else:
        base = vit_mod.init_base(kb, cfg)
        head = vit_mod.init_heads(kh, cfg)["cls"]
        adapters = vit_mod.init_adapters(ka, cfg, method)
    tree = {"base": base, "head": head}
    if adapters:
        tree["adapters"] = adapters
    return tree


def batch_spec(spec):
    cfg = spec["cfg"]
    if spec["model"] == "encoder":
        if spec["task"] == "dae":
            return [("corrupted", (ENC_BATCH, cfg.seq_len), jnp.int32),
                    ("clean", (ENC_BATCH, cfg.seq_len), jnp.int32)]
        return [("tokens", (ENC_BATCH, cfg.seq_len), jnp.int32),
                ("labels", (ENC_BATCH,), jnp.float32)]
    if spec["model"] == "decoder":
        return [("tokens", (DEC_BATCH, cfg.seq_len), jnp.int32),
                ("loss_mask", (DEC_BATCH, cfg.seq_len), jnp.float32)]
    return [("images", (VIT_BATCH, cfg.image, cfg.image, cfg.channels),
             jnp.float32),
            ("labels", (VIT_BATCH,), jnp.int32)]


def make_loss_and_logits(spec, method: PeftMethod):
    cfg, task = spec["cfg"], spec["task"]
    n_model_extras = len(spec["extras"])
    method_extras = tuple(method.extra_inputs)

    def set_method_extras(extras):
        me = extras[n_model_extras:]
        if method_extras:
            method.set_extras(**dict(zip(method_extras, me)))

    if spec["model"] == "encoder":
        if task == "dae":
            def loss_fn(tree, extras, corrupted, clean):
                set_method_extras(extras)
                return enc.dae_loss(tree["base"], tree.get("adapters", {}),
                                    {"dae": tree["head"]}, corrupted, clean,
                                    cfg, method)

            def logits_fn(tree, extras, corrupted):
                set_method_extras(extras)
                return enc.dae_logits(tree["base"], tree.get("adapters", {}),
                                      {"dae": tree["head"]}, corrupted, cfg,
                                      method)
            return loss_fn, logits_fn

        def loss_fn(tree, extras, tokens, labels):
            set_method_extras(extras)
            base_loss = enc.cls_loss(tree["base"], tree.get("adapters", {}),
                                     {"cls": tree["head"]}, tokens, labels,
                                     extras[0], cfg, method)
            return base_loss + method.extra_loss(tree.get("adapters", {}))

        def logits_fn(tree, extras, tokens):
            set_method_extras(extras)
            return enc.cls_logits(tree["base"], tree.get("adapters", {}),
                                  {"cls": tree["head"]}, tokens, cfg, method)
        return loss_fn, logits_fn

    if spec["model"] == "decoder":
        def loss_fn(tree, extras, tokens, loss_mask):
            set_method_extras(extras)
            base_loss = dec.lm_loss(tree["base"], tree.get("adapters", {}),
                                    {"lm": tree["head"]}, tokens, loss_mask,
                                    cfg, method)
            return base_loss + method.extra_loss(tree.get("adapters", {}))

        def logits_fn(tree, extras, tokens):
            set_method_extras(extras)
            return dec.lm_logits(tree["base"], tree.get("adapters", {}),
                                 {"lm": tree["head"]}, tokens, cfg, method)
        return loss_fn, logits_fn

    def loss_fn(tree, extras, images, labels):
        set_method_extras(extras)
        base_loss = vit_mod.cls_loss(tree["base"], tree.get("adapters", {}),
                                     {"cls": tree["head"]}, images, labels,
                                     cfg, method)
        return base_loss + method.extra_loss(tree.get("adapters", {}))

    def logits_fn(tree, extras, images):
        set_method_extras(extras)
        return vit_mod.logits(tree["base"], tree.get("adapters", {}),
                              {"cls": tree["head"]}, images, cfg, method)
    return loss_fn, logits_fn


def adapter_param_count(tree, part: T.Partition) -> int:
    """Trainable params excluding the task head (the paper's '# trainable
    parameters' column counts adapters; the manifest reports both)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for name, leaf, trainable in zip(part.names, leaves, part.mask):
        if trainable and not name.startswith("head"):
            total += leaf.size
    return total


# --------------------------------------------------------------------------
# lowering
# --------------------------------------------------------------------------

def to_hlo_text(fn, example_args) -> str:
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _tensor_meta(names, leaves):
    return [{"name": n, "shape": list(l.shape), "dtype": str(l.dtype)}
            for n, l in zip(names, leaves)]


def lower_config(tag: str, spec, out_dir: str) -> dict:
    t0 = time.time()
    method = make_method(spec["method"], **spec["method_kw"])
    tree = build_tree(spec, jax.random.PRNGKey(0), method)
    part = T.make_partition(tree, method)
    frozen, trainable = part.split(tree)
    extras = tuple(spec["extras"]) + tuple(method.extra_inputs)
    bspec = batch_spec(spec)

    loss_fn, logits_fn = make_loss_and_logits(spec, method)
    step_fn = T.make_train_step(loss_fn, part, len(extras))
    eval_fn = T.make_eval_step(logits_fn, part, len(extras))

    # ---- init ----
    def init_fn(seed):
        key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
        t = build_tree(spec, key, method)
        fz, tr = part.split(t)
        return tuple(fz) + tuple(tr)

    files = {}
    init_txt = to_hlo_text(init_fn, [_sds((), jnp.int32)])
    files["init"] = f"{tag}.init.hlo.txt"
    with open(os.path.join(out_dir, files["init"]), "w") as f:
        f.write(init_txt)

    # ---- train ----
    p_args = [_sds(l.shape, l.dtype) for l in frozen]
    t_args = [_sds(l.shape, l.dtype) for l in trainable]
    scalars = [_sds((), jnp.float32)] * 3
    extra_args = [_sds((), jnp.float32)] * len(extras)
    batch_args = [_sds(s, d) for _, s, d in bspec]
    train_txt = to_hlo_text(
        step_fn, p_args + t_args + t_args + t_args + scalars + extra_args
        + batch_args)
    files["train"] = f"{tag}.train.hlo.txt"
    with open(os.path.join(out_dir, files["train"]), "w") as f:
        f.write(train_txt)

    # ---- eval ----
    eval_txt = to_hlo_text(eval_fn, p_args + t_args + extra_args
                           + batch_args[:1])
    files["eval"] = f"{tag}.eval.hlo.txt"
    with open(os.path.join(out_dir, files["eval"]), "w") as f:
        f.write(eval_txt)

    leaves = jax.tree_util.tree_leaves(tree)
    froz_meta = [m for m, t in zip(_tensor_meta(part.names, leaves), part.mask)
                 if not t]
    train_meta = [m for m, t in zip(_tensor_meta(part.names, leaves), part.mask)
                  if t]
    entry = {
        "tag": tag,
        "model": spec["model"],
        "method": spec["method"],
        "method_kw": dict(spec["method_kw"]),
        "task": spec["task"],
        "cfg": _cfgdict(spec["cfg"]),
        "files": files,
        "frozen": froz_meta,
        "trainable": train_meta,
        "extras": list(extras),
        "batch": [{"name": n, "shape": list(s), "dtype": str(jnp.dtype(d))}
                  for n, s, d in bspec],
        "trainable_param_count": int(sum(l.size for l, t in
                                         zip(leaves, part.mask) if t)),
        "adapter_param_count": int(adapter_param_count(tree, part)),
        "total_param_count": int(sum(l.size for l in leaves)),
    }
    print(f"[aot] {tag}: {time.time() - t0:.1f}s "
          f"(adapter={entry['adapter_param_count']}, "
          f"trainable={entry['trainable_param_count']})", flush=True)
    return entry


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--filter", default="",
                    help="only lower tags containing this substring")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    cfgs = configs()
    if args.list:
        for tag in cfgs:
            print(tag)
        return 0

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"artifacts": {}, "version": 1}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    selected = {t: s for t, s in cfgs.items() if args.filter in t}
    t0 = time.time()
    for tag, spec in selected.items():
        entry = lower_config(tag, spec, out_dir)
        manifest["artifacts"][tag] = entry
        # write incrementally so an interrupted run keeps its progress
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    print(f"[aot] lowered {len(selected)} configs in {time.time() - t0:.0f}s "
          f"-> {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
