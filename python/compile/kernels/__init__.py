"""L1 Pallas kernels (interpret=True on this image) + pure-jnp oracles.

  pauli_kernel    fused Pauli-circuit apply  y = x @ Q_P      (eq. 2)
  taylor_kernel   Horner Taylor orthogonal apply y = x @ Q_T  (§4.1)
  adapter_kernel  fused xW + alpha ((xU) lam) V^T             (hot path)
  ref             the oracles every kernel is tested against
"""
from . import adapter_kernel, pauli_kernel, ref, taylor_kernel  # noqa: F401
