"""L1 Pallas kernel: fused frozen-weight + SVD-form adapter matmul.

    y = x @ W + scale * ((x @ U) * lam) @ V^T

One kernel invocation covers the whole PEFT family's hot path: LoRA
(lam = 1), AdaLoRA / Quantum-PEFT (U, V Stiefel frames, lam the diagonal
node). Fusing the adapter branch into the base matmul means the [B_t, N]
activation tile is read from HBM once and both products accumulate in
VMEM — on TPU this is a single MXU pipeline with the K-skinny adapter
matmuls hidden under the W matmul's latency.

interpret=True on this image (see pauli_kernel.py header).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_BLOCK_B = 128


def _kernel(x_ref, w_ref, u_ref, lam_ref, v_ref, scale_ref, o_ref):
    x = x_ref[...]
    base = x @ w_ref[...]
    z = (x @ u_ref[...]) * lam_ref[...]
    o_ref[...] = base + scale_ref[0] * (z @ v_ref[...].T)


def _adapter_apply_pallas(x, w, u, lam, v, scale, block_b: int = _BLOCK_B):
    b, din = x.shape
    dout = w.shape[1]
    k = u.shape[1]
    bb = min(block_b, max(b, 1))
    pad = (-b) % bb
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    scale_arr = jnp.reshape(scale, (1,)).astype(x.dtype)
    out = pl.pallas_call(
        _kernel,
        grid=(xp.shape[0] // bb,),
        in_specs=[
            pl.BlockSpec((bb, din), lambda i: (i, 0)),
            pl.BlockSpec((din, dout), lambda i: (0, 0)),
            pl.BlockSpec((din, k), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((dout, k), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], dout), x.dtype),
        interpret=True,
    )(xp, w, u, lam, v, scale_arr)
    return out[:b] if pad else out


def make_adapter_apply(use_pallas: bool = True):
    """Returns f(x, w, u, lam, v, scale) with kernel fwd + ref bwd."""

    @jax.custom_vjp
    def f(x, w, u, lam, v, scale):
        if use_pallas:
            return _adapter_apply_pallas(x, w, u, lam, v, scale)
        return ref.adapter_apply(x, w, u, lam, v, scale)

    def f_fwd(x, w, u, lam, v, scale):
        return f(x, w, u, lam, v, scale), (x, w, u, lam, v, scale)

    def f_bwd(resid, g):
        _, vjp = jax.vjp(ref.adapter_apply, *resid)
        return vjp(g)

    f.defvjp(f_fwd, f_bwd)
    return f
