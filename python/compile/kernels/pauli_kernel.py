"""L1 Pallas kernel: fused Pauli-circuit apply  y = x @ Q_P  (eq. 2).

TPU mapping (DESIGN.md §Hardware-Adaptation): the batch tile [B_t, N]
stays resident in VMEM while *all* L·q rotation sweeps and CZ sign layers
run over it — one HBM round-trip per circuit instead of one per layer.
Rotations are VPU work (strided pairwise rotate); CZ layers are a
broadcast multiply with a precomputed {+-1}^N sign vector baked in as a
kernel constant table.

interpret=True is mandatory on this image (CPU PJRT cannot execute Mosaic
custom-calls); the kernel still exercises the exact BlockSpec/VMEM
structure a real TPU build would use. Numerics: f32 throughout (real-TPU
target: bf16 tile with f32 rotation accumulation).

The public entry `pauli_apply` carries a custom_vjp whose backward runs
through the jnp reference (kernels/ref.py), keeping every AOT graph plain
HLO and exactly consistent with the tested forward.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quantum import pauli as pauli_mod
from . import ref

# Default batch tile: 128 rows x N f32. For N = 4096 that is a 2 MiB tile,
# comfortably inside a 16 MiB VMEM budget with double buffering.
_BLOCK_B = 128


def _build_sign_table(circuit: pauli_mod.PauliCircuit) -> np.ndarray:
    """[n_layers, N] table of CZ sign vectors (+1 rows for sign-free layers)."""
    n = circuit.dim
    rows = []
    for layer in circuit.layers:
        rows.append(layer.sign if layer.sign is not None else np.ones(n, np.float32))
    return np.stack(rows).astype(np.float32)


def _kernel(theta_ref, sign_ref, x_ref, o_ref, *, circuit: pauli_mod.PauliCircuit):
    """One batch tile through the whole circuit, VMEM-resident."""
    x = x_ref[...]
    n = circuit.dim
    for li, layer in enumerate(circuit.layers):
        th = theta_ref[layer.theta_ofs: layer.theta_ofs + len(layer.qubits)]
        cos_t = jnp.cos(th / 2.0)
        sin_t = jnp.sin(th / 2.0)
        for i, k in enumerate(layer.qubits):
            stride = 1 << k
            xr = x.reshape(x.shape[0], n // (2 * stride), 2, stride)
            x0, x1 = xr[:, :, 0, :], xr[:, :, 1, :]
            y0 = cos_t[i] * x0 - sin_t[i] * x1
            y1 = sin_t[i] * x0 + cos_t[i] * x1
            x = jnp.stack([y0, y1], axis=2).reshape(x.shape[0], n)
        if layer.sign is not None:
            x = x * sign_ref[li, :]
    o_ref[...] = x


def _pauli_apply_pallas(x, thetas, circuit: pauli_mod.PauliCircuit,
                        block_b: int = _BLOCK_B):
    """Tile the batch dimension and run the fused kernel."""
    b, n = x.shape
    assert n == circuit.dim
    bb = min(block_b, max(b, 1))
    pad = (-b) % bb
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    signs = jnp.asarray(_build_sign_table(circuit))
    out = pl.pallas_call(
        functools.partial(_kernel, circuit=circuit),
        grid=(xp.shape[0] // bb,),
        in_specs=[
            pl.BlockSpec((circuit.num_params,), lambda i: (0,)),
            pl.BlockSpec(signs.shape, lambda i: (0, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], n), x.dtype),
        interpret=True,
    )(thetas, signs, xp)
    return out[:b] if pad else out


def make_pauli_apply(circuit: pauli_mod.PauliCircuit, use_pallas: bool = True):
    """Returns f(x, thetas) = x @ Q_P with kernel forward + ref backward."""

    @jax.custom_vjp
    def f(x, thetas):
        if use_pallas:
            return _pauli_apply_pallas(x, thetas, circuit)
        return ref.pauli_apply(x, thetas, circuit)

    def f_fwd(x, thetas):
        return f(x, thetas), (x, thetas)

    def f_bwd(resid, g):
        x, thetas = resid
        _, vjp = jax.vjp(lambda xx, tt: ref.pauli_apply(xx, tt, circuit), x, thetas)
        return vjp(g)

    f.defvjp(f_fwd, f_bwd)
    return f
