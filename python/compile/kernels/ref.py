"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here is the mathematically-transparent implementation the
kernels are tested against (python/tests/test_kernels.py, hypothesis
sweeps) and the backward-pass implementation used by the kernels'
custom_vjp rules — so kernel forward == ref forward guarantees gradient
correctness of the AOT training graphs.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..quantum import pauli as pauli_mod


def pauli_apply(x, thetas, circuit: pauli_mod.PauliCircuit):
    """x @ Q_P — direct layer-by-layer jnp apply (gates.apply_kron_ry)."""
    return circuit.apply(x, thetas)


def taylor_apply(x, bk, order: int):
    """x @ Q_T with Q_T = sum_{p<=P} A^p / p!, A = L - L^T,
    L = tril(B_K, -1) zero-padded to N x N. Dense materialization —
    O(N^2) but unambiguous."""
    n = x.shape[-1]
    k = bk.shape[1]
    lmat = jnp.zeros((n, n), dtype=x.dtype).at[:, :k].set(jnp.tril(bk, k=-1))
    a = lmat - lmat.T
    acc = x
    for p in range(order, 0, -1):
        acc = x + (acc @ a) / p
    return acc


def adapter_apply(x, w, u, lam, v, scale):
    """Fused frozen-weight + SVD-form adapter forward:
        y = x @ W + scale * ((x @ U) * lam) @ V^T
    covering LoRA (lam = 1, U = B, V^T = A) and Quantum-PEFT/AdaLoRA
    (U, V Stiefel frames, lam the diagonal node)."""
    return x @ w + scale * (((x @ u) * lam) @ v.T)
