"""L1 Pallas kernel: Taylor orthogonal apply  y = x @ Q_T  (§4.1, A.1).

Q_T = sum_{p<=P} A^p / p! with A = L - L^T and L = tril(B_K, -1) zero-
padded to N x N (only the first K' columns are nonzero). The kernel never
materializes A: per Horner step

    acc <- x + ( pad(acc @ L_f)  -  acc[:, :K'] @ L_f^T ) / p

i.e. two skinny matmuls against the [N, K'] Lie factor — exactly the
tensor-contraction-ordering trick of §4.1 that removes the memory
redundancy of a naive mapping.

TPU mapping: the [N, K'] factor is tiny (<= 64 KiB for N = 4096, K' = 4)
and stays VMEM-resident across all P steps; activation tiles [B_t, N]
stream through with double buffering; the matmuls are MXU work with f32
accumulation. interpret=True on this image (see pauli_kernel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_BLOCK_B = 128


def _kernel(bk_ref, x_ref, o_ref, *, order: int, n: int, k: int):
    x = x_ref[...]
    lf = jnp.tril(bk_ref[...], k=-1)          # [N, K'] strictly-lower factor
    acc = x
    for p in range(order, 0, -1):
        t1 = acc @ lf                          # [B_t, K']   (acc @ L)
        t2 = acc[:, :k] @ lf.T                 # [B_t, N]    (acc @ L^T)
        if k >= n:
            av = t1 - t2                       # K' == N: no padding needed
        else:
            av = jnp.concatenate(
                [t1, jnp.zeros((acc.shape[0], n - k), acc.dtype)], axis=1) - t2
        acc = x + av / p
    o_ref[...] = acc


def _taylor_apply_pallas(x, bk, order: int, block_b: int = _BLOCK_B):
    b, n = x.shape
    k = bk.shape[1]
    bb = min(block_b, max(b, 1))
    pad = (-b) % bb
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    out = pl.pallas_call(
        functools.partial(_kernel, order=order, n=n, k=k),
        grid=(xp.shape[0] // bb,),
        in_specs=[
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], n), x.dtype),
        interpret=True,
    )(bk, xp)
    return out[:b] if pad else out


def make_taylor_apply(order: int, use_pallas: bool = True):
    """Returns f(x, bk) = x @ Q_T(B_K) with kernel fwd + ref bwd."""

    @jax.custom_vjp
    def f(x, bk):
        if use_pallas:
            return _taylor_apply_pallas(x, bk, order)
        return ref.taylor_apply(x, bk, order)

    def f_fwd(x, bk):
        return f(x, bk), (x, bk)

    def f_bwd(resid, g):
        x, bk = resid
        _, vjp = jax.vjp(lambda xx, bb: ref.taylor_apply(xx, bb, order), x, bk)
        return vjp(g)

    f.defvjp(f_fwd, f_bwd)
    return f
