"""Model zoo: encoder (GLUE substitute), decoder LM (E2E substitute),
ViT (CIFAR-10 transfer substitute). All pure functions over dict pytrees;
PEFT adapters thread through models.layers."""
from . import decoder, layers, transformer, vit  # noqa: F401
