"""Decoder LM for the E2E-NLG substitute (Table 3/4).

Causal transformer over [MR ; SEP ; text] sequences: the Rust data layer
renders slot/value meaning representations and reference texts into one
token stream; the LM trains with next-token CE where loss is only charged
on the text segment (loss_mask input). Generation is greedy: the Rust
coordinator calls the eval artifact repeatedly, appending the argmax of
the last valid position.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..peft.base import PeftMethod
from . import layers


@dataclasses.dataclass(frozen=True)
class DecoderConfig:
    vocab: int = 256
    d: int = 64
    n_heads: int = 4
    n_layers: int = 2
    ff: int = 128
    seq_len: int = 48


def init_base(key, cfg: DecoderConfig) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 3)
    return {
        "tok": jax.random.normal(ks[0], (cfg.vocab, cfg.d), dtype=jnp.float32) * 0.02,
        "pos": jax.random.normal(ks[1], (cfg.seq_len, cfg.d), dtype=jnp.float32) * 0.02,
        "blocks": [layers.init_block(ks[2 + i], cfg.d, cfg.ff)
                   for i in range(cfg.n_layers)],
        "ln_f": layers.init_layer_norm(cfg.d),
    }


def init_heads(key, cfg: DecoderConfig) -> dict:
    return {"lm": layers.init_dense(key, cfg.d, cfg.vocab)}


def init_adapters(key, cfg: DecoderConfig, method: PeftMethod) -> dict:
    ks = jax.random.split(key, cfg.n_layers)
    blocks = [layers.init_block_adapters(ks[i], method, cfg.d)
              for i in range(cfg.n_layers)]
    if all(not b for b in blocks):
        return {}
    return {"blocks": blocks}


def lm_logits(base, adapters, heads, tokens, cfg: DecoderConfig,
              method: PeftMethod):
    """tokens [B, T] -> next-token logits [B, T, vocab] (causal)."""
    b, t = tokens.shape
    pad_bias, _ = layers.padding_mask(tokens)
    mask = layers.causal_mask(t) + pad_bias
    x = base["tok"][tokens] + base["pos"][:t]
    ablocks = adapters.get("blocks", [None] * cfg.n_layers) if adapters else \
        [None] * cfg.n_layers
    for p, a in zip(base["blocks"], ablocks):
        x = layers.block(p, a, x, mask, cfg.n_heads, method)
    return layers.dense(heads["lm"], layers.layer_norm(base["ln_f"], x))


def lm_loss(base, adapters, heads, tokens, loss_mask, cfg, method,
            label_smooth: float = 0.1):
    """Next-token CE with label smoothing 0.1 (paper Table 14) charged only
    where loss_mask[b, t+1] == 1 (the text segment, not the MR prompt)."""
    logits = lm_logits(base, adapters, heads, tokens, cfg, method)
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None].astype(jnp.int32), axis=-1)[..., 0]
    smooth = -jnp.mean(lp, axis=-1)
    per_tok = (1.0 - label_smooth) * nll + label_smooth * smooth
    m = loss_mask[:, 1:].astype(jnp.float32)
    return jnp.sum(per_tok * m) / jnp.maximum(jnp.sum(m), 1.0)
