"""Shared transformer building blocks with PEFT-wrapped projections.

Conventions:
  * params are plain nested dicts of jnp arrays (lists for block stacks);
  * every dense weight is [d_in, d_out], bias [d_out];
  * PEFT adapters attach to the attention q and v projections (the
    paper's default sites, §5.1/§5.4); bottleneck adapters (Houlsby /
    Pfeiffer) attach at the sublayer outputs;
  * dtype f32 end-to-end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..peft.base import PeftMethod


def init_dense(key, n: int, m: int) -> dict:
    return {
        "w": jax.random.normal(key, (n, m), dtype=jnp.float32) / jnp.sqrt(n),
        "b": jnp.zeros((m,), dtype=jnp.float32),
    }


def dense(p: dict, x):
    return x @ p["w"] + p["b"]


def dense_peft(p: dict, adapter: dict | None, x, method: PeftMethod):
    """PEFT-adapted dense: W frozen, Delta-W from the method's adapter."""
    if adapter is None or not adapter:
        return x @ p["w"] + p["b"]
    lead = x.shape[:-1]
    y = method.apply(adapter, x.reshape(-1, x.shape[-1]), p["w"])
    return y.reshape(lead + (p["w"].shape[1],)) + p["b"]


def init_layer_norm(d: int) -> dict:
    return {"g": jnp.ones((d,), dtype=jnp.float32),
            "b": jnp.zeros((d,), dtype=jnp.float32)}


def layer_norm(p: dict, x, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def init_attention(key, d: int) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d, d),
        "wk": init_dense(ks[1], d, d),
        "wv": init_dense(ks[2], d, d),
        "wo": init_dense(ks[3], d, d),
    }


def attention(p: dict, adapters: dict | None, x, mask, n_heads: int,
              method: PeftMethod):
    """Multi-head attention; PEFT on q and v projections.

    mask: [B, T] validity (1 = real token) or [T, T] causal, or both
    combined upstream into an additive [B, 1, T, T] bias.
    """
    b, t, d = x.shape
    dh = d // n_heads
    a = adapters or {}
    q = dense_peft(p["wq"], a.get("q"), x, method)
    k = dense(p["wk"], x)
    v = dense_peft(p["wv"], a.get("v"), x, method)

    def heads(z):
        return z.reshape(b, t, n_heads, dh).transpose(0, 2, 1, 3)

    qh, kh, vh = heads(q), heads(k), heads(v)
    logits = qh @ kh.transpose(0, 1, 3, 2) / jnp.sqrt(jnp.float32(dh))
    logits = logits + mask
    att = jax.nn.softmax(logits, axis=-1)
    out = (att @ vh).transpose(0, 2, 1, 3).reshape(b, t, d)
    return dense(p["wo"], out)


def init_mlp(key, d: int, ff: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {"w1": init_dense(k1, d, ff), "w2": init_dense(k2, ff, d)}


def mlp(p: dict, x):
    return dense(p["w2"], jax.nn.gelu(dense(p["w1"], x)))


def init_block(key, d: int, ff: int) -> dict:
    ka, km = jax.random.split(key)
    return {
        "ln1": init_layer_norm(d),
        "attn": init_attention(ka, d),
        "ln2": init_layer_norm(d),
        "mlp": init_mlp(km, d, ff),
    }


def block(p: dict, adapters: dict | None, x, mask, n_heads: int,
          method: PeftMethod):
    """Pre-LN transformer block with optional bottleneck adapters."""
    a = adapters or {}
    h = attention(p["attn"], a, layer_norm(p["ln1"], x), mask, n_heads, method)
    if "bn_attn" in a:
        h = method.bottleneck_apply(a["bn_attn"], h)
    x = x + h
    h = mlp(p["mlp"], layer_norm(p["ln2"], x))
    if "bn_mlp" in a:
        h = method.bottleneck_apply(a["bn_mlp"], h)
    return x + h


def init_block_adapters(key, method: PeftMethod, d: int) -> dict:
    """Adapter params for one block, per the method's attachment sites."""
    out = {}
    style = getattr(method, "block_adapter", None)
    ks = jax.random.split(key, 4)
    if style == "houlsby":
        out["bn_attn"] = method.init_bottleneck(ks[0], d)
        out["bn_mlp"] = method.init_bottleneck(ks[1], d)
        return out
    if style == "pfeiffer":
        out["bn_attn"] = method.init_bottleneck(ks[0], d)
        return out
    q = method.init(ks[2], d, d)
    v = method.init(ks[3], d, d)
    if q:
        out["q"] = q
    if v:
        out["v"] = v
    return out


def padding_mask(tokens, pad_id: int = 0):
    """[B, T] int tokens -> additive [B, 1, 1, T] attention bias."""
    valid = (tokens != pad_id).astype(jnp.float32)
    return (valid[:, None, None, :] - 1.0) * 1e9, valid


def causal_mask(t: int):
    """Additive [1, 1, T, T] causal bias."""
    m = jnp.tril(jnp.ones((t, t), dtype=jnp.float32))
    return (m[None, None, :, :] - 1.0) * 1e9
