"""Encoder transformer: synthetic-GLUE classifier/regressor + DAE pretrain.

The GLUE substitute (DESIGN.md §2): the Rust coordinator first pretrains
this encoder with a denoising objective on a synthetic corpus (`dae_loss`,
full fine-tuning artifact), then freezes the backbone and fine-tunes PEFT
adapters + head per task (`cls_loss`, task_kind scalar selects CE vs MSE
so one artifact family serves SST-2/CoLA/RTE/MRPC *and* STS-B shapes).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..peft.base import PeftMethod
from . import layers


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    vocab: int = 256
    d: int = 64
    n_heads: int = 4
    n_layers: int = 2
    ff: int = 128
    seq_len: int = 32
    n_out: int = 2          # classifier logits (regression uses logit 0)


def init_base(key, cfg: EncoderConfig) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 3)
    return {
        "tok": jax.random.normal(ks[0], (cfg.vocab, cfg.d), dtype=jnp.float32) * 0.02,
        "pos": jax.random.normal(ks[1], (cfg.seq_len, cfg.d), dtype=jnp.float32) * 0.02,
        "blocks": [layers.init_block(ks[2 + i], cfg.d, cfg.ff)
                   for i in range(cfg.n_layers)],
        "ln_f": layers.init_layer_norm(cfg.d),
    }


def init_heads(key, cfg: EncoderConfig) -> dict:
    kc, kd = jax.random.split(key)
    return {
        "cls": layers.init_dense(kc, cfg.d, cfg.n_out),
        "dae": layers.init_dense(kd, cfg.d, cfg.vocab),
    }


def init_adapters(key, cfg: EncoderConfig, method: PeftMethod) -> dict:
    ks = jax.random.split(key, cfg.n_layers)
    blocks = [layers.init_block_adapters(ks[i], method, cfg.d)
              for i in range(cfg.n_layers)]
    if all(not b for b in blocks):
        return {}
    return {"blocks": blocks}


def encode(base: dict, adapters: dict, tokens, cfg: EncoderConfig,
           method: PeftMethod):
    """tokens [B, T] -> hidden [B, T, d], valid [B, T]."""
    b, t = tokens.shape
    mask, valid = layers.padding_mask(tokens)
    x = base["tok"][tokens] + base["pos"][:t]
    ablocks = adapters.get("blocks", [None] * cfg.n_layers) if adapters else \
        [None] * cfg.n_layers
    for p, a in zip(base["blocks"], ablocks):
        x = layers.block(p, a, x, mask, cfg.n_heads, method)
    return layers.layer_norm(base["ln_f"], x), valid


def cls_logits(base, adapters, heads, tokens, cfg, method):
    """Mean-pooled classification/regression head output [B, n_out]."""
    h, valid = encode(base, adapters, tokens, cfg, method)
    denom = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1.0)
    pooled = jnp.sum(h * valid[:, :, None], axis=1) / denom
    return layers.dense(heads["cls"], pooled)


def cls_loss(base, adapters, heads, tokens, labels, task_kind, cfg, method):
    """task_kind = 0: softmax CE on integer labels; 1: MSE of logit 0 on
    float labels (STS-B-style regression)."""
    logits = cls_logits(base, adapters, heads, tokens, cfg, method)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(
        logp, jnp.clip(labels.astype(jnp.int32), 0, cfg.n_out - 1)[:, None],
        axis=1))
    mse = jnp.mean((logits[:, 0] - labels.astype(jnp.float32)) ** 2)
    return (1.0 - task_kind) * ce + task_kind * mse


def dae_logits(base, adapters, heads, tokens, cfg, method):
    """Per-position vocabulary logits for denoising pretraining."""
    h, _ = encode(base, adapters, tokens, cfg, method)
    return layers.dense(heads["dae"], h)


def dae_loss(base, adapters, heads, corrupted, clean, cfg, method):
    """Reconstruct clean tokens from corrupted input (pad positions skipped)."""
    logits = dae_logits(base, adapters, heads, corrupted, cfg, method)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, clean[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    valid = (clean != 0).astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
