"""Small ViT for the CIFAR-10 transfer substitute (Tables 6-10).

16x16x3 synthetic shape/texture images, patch size 4 -> 16 patch tokens
plus a learned CLS token. The Rust coordinator pretrains on a 20-class
synthetic pretask, quantizes the frozen backbone to n bits host-side
(Table 6's 3-bit base), then fine-tunes adapters + a fresh 10-class head.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..peft.base import PeftMethod
from . import layers


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    image: int = 16
    patch: int = 4
    channels: int = 3
    d: int = 64
    n_heads: int = 4
    n_layers: int = 2
    ff: int = 128
    n_out: int = 10

    @property
    def n_patches(self) -> int:
        return (self.image // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels


def init_base(key, cfg: ViTConfig) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 4)
    return {
        "embed": layers.init_dense(ks[0], cfg.patch_dim, cfg.d),
        "cls": jax.random.normal(ks[1], (1, 1, cfg.d), dtype=jnp.float32) * 0.02,
        "pos": jax.random.normal(ks[2], (cfg.n_patches + 1, cfg.d),
                                 dtype=jnp.float32) * 0.02,
        "blocks": [layers.init_block(ks[3 + i], cfg.d, cfg.ff)
                   for i in range(cfg.n_layers)],
        "ln_f": layers.init_layer_norm(cfg.d),
    }


def init_heads(key, cfg: ViTConfig) -> dict:
    return {"cls": layers.init_dense(key, cfg.d, cfg.n_out)}


def init_adapters(key, cfg: ViTConfig, method: PeftMethod) -> dict:
    ks = jax.random.split(key, cfg.n_layers)
    blocks = [layers.init_block_adapters(ks[i], method, cfg.d)
              for i in range(cfg.n_layers)]
    if all(not b for b in blocks):
        return {}
    return {"blocks": blocks}


def patchify(images, cfg: ViTConfig):
    """[B, H, W, C] -> [B, n_patches, patch_dim]."""
    b = images.shape[0]
    p, g = cfg.patch, cfg.image // cfg.patch
    x = images.reshape(b, g, p, g, p, cfg.channels)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, g * g, cfg.patch_dim)


def logits(base, adapters, heads, images, cfg: ViTConfig, method: PeftMethod):
    b = images.shape[0]
    x = layers.dense(base["embed"], patchify(images, cfg))
    cls = jnp.broadcast_to(base["cls"], (b, 1, cfg.d))
    x = jnp.concatenate([cls, x], axis=1) + base["pos"]
    mask = jnp.zeros((1, 1, 1, cfg.n_patches + 1), dtype=jnp.float32)
    ablocks = adapters.get("blocks", [None] * cfg.n_layers) if adapters else \
        [None] * cfg.n_layers
    for p, a in zip(base["blocks"], ablocks):
        x = layers.block(p, a, x, mask, cfg.n_heads, method)
    h = layers.layer_norm(base["ln_f"], x)[:, 0]
    return layers.dense(heads["cls"], h)


def cls_loss(base, adapters, heads, images, labels, cfg, method):
    lg = logits(base, adapters, heads, images, cfg, method)
    lp = jax.nn.log_softmax(lg, axis=-1)
    return -jnp.mean(jnp.take_along_axis(
        lp, labels.astype(jnp.int32)[:, None], axis=1))
