"""PEFT method registry: the paper's Quantum-PEFT + every baseline it
compares against (Tables 2, 3, 5, 6, 10)."""
from __future__ import annotations

from .base import BottleneckAdapter, FullFT, PeftMethod  # noqa: F401
from .lora_family import AdaLoRA, BitFit, LoHa, LoKr, LoRA  # noqa: F401
from .highrank import MoRA, QuanTA  # noqa: F401
from .quantum_peft import (  # noqa: F401
    QuantumPeftPauli,
    QuantumPeftTaylor,
    QuantumPeftTensorNetwork,
)


def make_method(name: str, **kw) -> PeftMethod:
    """Factory used by aot.py config tags; kw override per-method defaults."""
    table = {
        "ft": FullFT,
        "lora": LoRA,
        "adalora": AdaLoRA,
        "loha": LoHa,
        "lokr": LoKr,
        "bitfit": BitFit,
        "hadapter": lambda **k: BottleneckAdapter(style="houlsby", **k),
        "padapter": lambda **k: BottleneckAdapter(style="pfeiffer", **k),
        "mora": MoRA,
        "quanta": QuanTA,
        "qpeft_pauli": QuantumPeftPauli,
        "qpeft_taylor": QuantumPeftTaylor,
        "qpeft_tn": QuantumPeftTensorNetwork,
    }
    if name not in table:
        raise KeyError(f"unknown PEFT method {name!r}; have {sorted(table)}")
    return table[name](**kw)


ALL_METHODS = ("ft", "lora", "adalora", "loha", "lokr", "bitfit", "hadapter",
               "padapter", "mora", "quanta", "qpeft_pauli", "qpeft_taylor",
               "qpeft_tn")
