"""PEFT method interface.

A method adapts a single frozen weight W in R^{n x m}; the model layer
calls `apply(adapter_params, x, w)` on its hot path. Methods are
stateless config objects — all trainable state lives in the params
pytree, all structure is baked at AOT-lowering time.

`extras` threading: some methods consume *runtime* scalars (intrinsic
rank K', quantization levels) so one AOT artifact serves a whole paper
sweep; these arrive via `set_extras` before tracing and are traced
scalars inside the lowered graph.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp


class PeftMethod:
    """Base class: identity adaptation (used by full fine-tuning)."""

    name = "ft"
    #: names of runtime scalar inputs this method consumes (AOT inputs)
    extra_inputs: tuple = ()

    def __init__(self):
        self._extras: Dict[str, jnp.ndarray] = {}

    # -- structure ---------------------------------------------------------
    def init(self, key, n: int, m: int) -> dict:
        """Adapter parameter pytree for one n x m weight ({} = none)."""
        return {}

    def num_params(self, n: int, m: int) -> int:
        return 0

    # -- runtime scalars ----------------------------------------------------
    def set_extras(self, **kw):
        self._extras = dict(kw)

    def extra(self, name: str, default=None):
        if name in self._extras:
            return self._extras[name]
        if default is None:
            raise KeyError(f"{self.name}: missing runtime extra {name!r}")
        return default

    # -- forward ------------------------------------------------------------
    def apply(self, params: dict, x, w):
        """y = x @ (W + Delta-W); base class: no delta."""
        return x @ w

    def delta_w(self, params: dict, n: int, m: int):
        """Materialized Delta-W (tests, analysis); not on the hot path."""
        return jnp.zeros((n, m), dtype=jnp.float32)

    def extra_loss(self, all_adapter_params) -> jnp.ndarray:
        """Method-level regularizer added to the task loss (AdaLoRA)."""
        return jnp.float32(0.0)

    # -- trainability -------------------------------------------------------
    #: whether the *base* weights train ("ft") / biases train ("bitfit")
    base_trainable = False
    bias_trainable = False


class FullFT(PeftMethod):
    """Full fine-tuning: no adapters, the whole base model trains."""

    name = "ft"
    base_trainable = True
    bias_trainable = True


class BottleneckAdapter(PeftMethod):
    """Houlsby / Pfeiffer serial adapters (Table 2 baselines).

    Not a per-weight delta: a bottleneck MLP  h + W_up gelu(W_down h)
    inserted after the attention sublayer (Pfeiffer) or after both the
    attention and FFN sublayers (Houlsby). The model (models/layers.py)
    checks `block_adapter` and routes through `bottleneck()`.
    """

    name = "hadapter"
    block_adapter = "houlsby"

    def __init__(self, bottleneck: int = 8, style: str = "houlsby"):
        super().__init__()
        self.bottleneck = bottleneck
        self.block_adapter = style
        self.name = "hadapter" if style == "houlsby" else "padapter"

    def init_bottleneck(self, key, d: int) -> dict:
        import jax

        kd, _ = jax.random.split(key)
        return {
            "down": jax.random.normal(kd, (d, self.bottleneck),
                                      dtype=jnp.float32) / jnp.sqrt(d),
            "up": jnp.zeros((self.bottleneck, d), dtype=jnp.float32),
        }

    def bottleneck_apply(self, params, h):
        import jax

        return h + jax.nn.gelu(h @ params["down"]) @ params["up"]

    def bottleneck_params(self, d: int) -> int:
        return 2 * d * self.bottleneck
