"""High-rank / tensor-folding baselines of Table 2: MoRA and QuanTA.

MoRA (Jiang et al., 2024b): one square trainable matrix M in
R^{Khat x Khat}, Khat = floor(sqrt((n+m)K)), with *non-trainable*
compress/decompress maps so dims match — high-rank but unable to scale
below LoRA (paper A.6).

QuanTA (Chen et al., 2024b): tensor folding — the update factorizes over
folded axes; we implement the 2-axis folding Delta-W = A1 (x) A2 with
dense square factors per folded dimension pair, matching QuanTA's
parameter scaling (sum of squared fold dims) without its unitary-free
redundancy; the paper contrasts exactly this redundancy (A.6).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .base import PeftMethod


def _fold(d: int):
    """Near-square factorization d = d1 * d2, d1 <= d2."""
    f, best = 1, (1, d)
    while f * f <= d:
        if d % f == 0:
            best = (f, d // f)
        f += 1
    return best


class MoRA(PeftMethod):
    name = "mora"

    def __init__(self, k: int = 4, alpha: float = 1.0):
        super().__init__()
        self.k, self.alpha = k, alpha

    def khat(self, n: int, m: int) -> int:
        return max(1, int(math.isqrt((n + m) * self.k)))

    def init(self, key, n: int, m: int) -> dict:
        kh = self.khat(n, m)
        return {"m": jnp.zeros((kh, kh), dtype=jnp.float32)}

    def num_params(self, n: int, m: int) -> int:
        kh = self.khat(n, m)
        return kh * kh

    def _maps(self, n: int, m: int, kh: int):
        """Fixed grouped-average compress [n, kh] / repeat decompress [kh, m]."""
        gi = (jnp.arange(n) * kh) // n
        p_in = jax.nn.one_hot(gi, kh, dtype=jnp.float32)
        p_in = p_in / jnp.maximum(jnp.sum(p_in, axis=0, keepdims=True), 1.0)
        go = (jnp.arange(m) * kh) // m
        p_out = jax.nn.one_hot(go, kh, dtype=jnp.float32).T
        return p_in, p_out

    def delta_w(self, params, n, m):
        kh = params["m"].shape[0]
        p_in, p_out = self._maps(n, m, kh)
        return self.alpha * p_in @ params["m"] @ p_out

    def apply(self, params, x, w):
        kh = params["m"].shape[0]
        n, m = w.shape
        p_in, p_out = self._maps(n, m, kh)
        return x @ w + self.alpha * (((x @ p_in) @ params["m"]) @ p_out)


class QuanTA(PeftMethod):
    name = "quanta"

    def __init__(self, alpha: float = 1.0):
        super().__init__()
        self.alpha = alpha

    def init(self, key, n: int, m: int) -> dict:
        n1, n2 = _fold(n)
        m1, m2 = _fold(m)
        k1, _ = jax.random.split(key)
        return {
            # zero-init second factor => Delta-W = 0 at start
            "a1": jax.random.normal(k1, (n1, m1), dtype=jnp.float32) / jnp.sqrt(n1),
            "a2": jnp.zeros((n2, m2), dtype=jnp.float32),
        }

    def num_params(self, n: int, m: int) -> int:
        n1, n2 = _fold(n)
        m1, m2 = _fold(m)
        return n1 * m1 + n2 * m2

    def delta_w(self, params, n, m):
        a1, a2 = params["a1"], params["a2"]
        n1, m1 = a1.shape
        n2, m2 = a2.shape
        # (x)_fold: W[(i1 i2), (j1 j2)] = A1[i1, j1] A2[i2, j2]
        return self.alpha * jnp.einsum("ac,bd->abcd", a1, a2).reshape(n, m)

    def apply(self, params, x, w):
        n, m = w.shape
        return x @ (w + self.delta_w(params, n, m))
