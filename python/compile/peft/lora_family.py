"""LoRA-family baselines from Tables 2/3/5/6: LoRA, AdaLoRA, LoHa, LoKr.

All use the fused adapter kernel (kernels/adapter_kernel.py) where the
update is expressible as U diag(lam) V^T; LoHa/LoKr materialize Delta-W
(their Hadamard/Kronecker structure does not factor through the fused
form) — at fine-tuning dimensions this is how the reference
implementations (peft / LyCORIS) behave too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.adapter_kernel import make_adapter_apply
from .base import PeftMethod

_adapter_apply = make_adapter_apply(use_pallas=True)


class LoRA(PeftMethod):
    """Hu et al. 2021: Delta-W = (alpha/K) B A, B zero-init, A gaussian."""

    name = "lora"

    def __init__(self, k: int = 4, alpha: float = 32.0, use_pallas: bool = True):
        super().__init__()
        self.k, self.alpha = k, alpha
        self._apply = make_adapter_apply(use_pallas)

    def init(self, key, n: int, m: int) -> dict:
        ka, _ = jax.random.split(key)
        return {
            "a": jax.random.normal(ka, (m, self.k), dtype=jnp.float32) / jnp.sqrt(m),
            "b": jnp.zeros((n, self.k), dtype=jnp.float32),
        }

    def num_params(self, n: int, m: int) -> int:
        return (n + m) * self.k

    def apply(self, params, x, w):
        ones = jnp.ones((self.k,), dtype=x.dtype)
        return self._apply(x, w, params["b"], ones, params["a"],
                           jnp.float32(self.alpha / self.k))

    def delta_w(self, params, n, m):
        return (self.alpha / self.k) * params["b"] @ params["a"].T


class AdaLoRA(PeftMethod):
    """Zhang et al. 2023: SVD-form U Lambda V^T with an *inexact*
    orthogonality regularizer ||U^T U - I||^2 + ||V^T V - I||^2 — the
    paper's Figure 1 contrast case (Quantum-PEFT gets orthogonality by
    construction, AdaLoRA pays K(K+1) redundant params + a regularizer)."""

    name = "adalora"
    reg_weight = 0.1

    def __init__(self, k: int = 4, alpha: float = 32.0, use_pallas: bool = True):
        super().__init__()
        self.k, self.alpha = k, alpha
        self._apply = make_adapter_apply(use_pallas)

    def init(self, key, n: int, m: int) -> dict:
        ku, kv = jax.random.split(key)
        return {
            "u": jax.random.normal(ku, (n, self.k), dtype=jnp.float32) / jnp.sqrt(n),
            "v": jax.random.normal(kv, (m, self.k), dtype=jnp.float32) / jnp.sqrt(m),
            "lam": jnp.zeros((self.k,), dtype=jnp.float32),
        }

    def num_params(self, n: int, m: int) -> int:
        return (n + m) * self.k + self.k

    def apply(self, params, x, w):
        return self._apply(x, w, params["u"], params["lam"], params["v"],
                           jnp.float32(self.alpha / self.k))

    def delta_w(self, params, n, m):
        return (self.alpha / self.k) * (params["u"] * params["lam"]) @ params["v"].T

    def extra_loss(self, all_adapter_params):
        """Sum of orthogonality penalties over every adapter site."""
        def site_loss(p):
            u, v = p["u"], p["v"]
            iu = jnp.eye(u.shape[1], dtype=u.dtype)
            return (jnp.sum((u.T @ u - iu) ** 2) + jnp.sum((v.T @ v - iu) ** 2))

        leaves = [site_loss(p) for p in _iter_sites(all_adapter_params)]
        return self.reg_weight * sum(leaves, jnp.float32(0.0))


class LoHa(PeftMethod):
    """Hyeon-Woo et al. 2022 (FedPara/LoHa): Delta-W = (B1 A1) .* (B2 A2)."""

    name = "loha"

    def __init__(self, k: int = 4, alpha: float = 32.0):
        super().__init__()
        self.k, self.alpha = k, alpha

    def init(self, key, n: int, m: int) -> dict:
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "a1": jax.random.normal(k1, (m, self.k), dtype=jnp.float32) / jnp.sqrt(m),
            "b1": jnp.zeros((n, self.k), dtype=jnp.float32),
            "a2": jax.random.normal(k2, (m, self.k), dtype=jnp.float32) / jnp.sqrt(m),
            "b2": jax.random.normal(k3, (n, self.k), dtype=jnp.float32) / jnp.sqrt(n),
        }

    def num_params(self, n: int, m: int) -> int:
        return 2 * (n + m) * self.k

    def delta_w(self, params, n, m):
        return ((self.alpha / self.k)
                * (params["b1"] @ params["a1"].T)
                * (params["b2"] @ params["a2"].T))

    def apply(self, params, x, w):
        n, m = w.shape
        return x @ (w + self.delta_w(params, n, m))


class LoKr(PeftMethod):
    """Yeh et al. 2024 (LyCORIS LoKr): Delta-W = C (x) (B A) with a small
    dense Kronecker factor C in R^{f x f} and a low-rank pair on the
    (n/f) x (m/f) block."""

    name = "lokr"

    def __init__(self, k: int = 4, f: int = 8, alpha: float = 32.0):
        super().__init__()
        self.k, self.f, self.alpha = k, f, alpha

    def _block_dims(self, n: int, m: int):
        f = self.f
        while n % f or m % f:
            f //= 2
        return f, n // f, m // f

    def init(self, key, n: int, m: int) -> dict:
        f, nb, mb = self._block_dims(n, m)
        kc, ka = jax.random.split(key)
        return {
            "c": jax.random.normal(kc, (f, f), dtype=jnp.float32) / f,
            "a": jax.random.normal(ka, (mb, self.k), dtype=jnp.float32) / jnp.sqrt(mb),
            "b": jnp.zeros((nb, self.k), dtype=jnp.float32),
        }

    def num_params(self, n: int, m: int) -> int:
        f, nb, mb = self._block_dims(n, m)
        return f * f + (nb + mb) * self.k

    def delta_w(self, params, n, m):
        block = params["b"] @ params["a"].T            # [n/f, m/f]
        return (self.alpha / self.k) * jnp.kron(params["c"], block)

    def apply(self, params, x, w):
        n, m = w.shape
        return x @ (w + self.delta_w(params, n, m))


class BitFit(PeftMethod):
    """Zaken et al. 2022: train only bias vectors (handled by the model's
    trainability mask; no per-weight adapter params)."""

    name = "bitfit"
    bias_trainable = True


def _iter_sites(tree):
    """Yield every adapter-site dict (a dict of arrays) in a nested tree."""
    if isinstance(tree, dict):
        if tree and all(not isinstance(v, (dict, list, tuple))
                        for v in tree.values()):
            yield tree
        else:
            for v in tree.values():
                yield from _iter_sites(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from _iter_sites(v)
