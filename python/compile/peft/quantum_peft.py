"""Quantum-PEFT — the paper's method (§4).

Delta-W = U diag(lam) V^T where U in V_K(n), V in V_K(m) are *not*
trainable matrices but computed through quantum mappings of
orders-of-magnitude smaller intrinsic parameters:

  * `QuantumPeftPauli`  — U, V from the eq.-(2) Pauli circuit Q_P
    ((2L+1)log2(N) - 2L angles per side; QSD (eq. 4) when a dimension is
    not a power of two). Hot path: the fused Pallas Pauli kernel.
  * `QuantumPeftTaylor` — U, V from the Taylor mapping Q_T of a masked
    Lie factor B_K (intrinsic rank K' as a *runtime* scalar -> one AOT
    artifact serves the whole Table-8 sweep). Hot path: the Pallas Horner
    kernel. Optional QAT fake-quant of the Lie parameters with runtime
    `quant_levels` / `quant_mode` scalars (Table 7).

lam is zero-initialized so Delta-W = 0 at the start of fine-tuning (the
LoRA-B=0 convention); U, V start as random points on the Stiefel
manifold.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.adapter_kernel import make_adapter_apply
from ..kernels.pauli_kernel import make_pauli_apply
from ..kernels.taylor_kernel import make_taylor_apply
from ..quantum import mappings, pauli, qsd, quantize
from .base import PeftMethod


def _is_pow2(n: int) -> bool:
    return n >= 2 and (n & (n - 1)) == 0


class QuantumPeftPauli(PeftMethod):
    """Q_P-parameterized Quantum-PEFT (the extreme-compression regime).

    Two execution modes (same math, pinned equal by tests):
      * "materialize" (default for power-of-two dims <= 1024): build the
        dense Q_P via the compact Kronecker-chain product and run the
        *fused adapter Pallas kernel* xW + alpha((xU)lam)V^T — tiny HLO,
        fast to compile on xla_extension 0.5.1 (§Perf L2);
      * "apply": stream activations through the O(N log N) fused Pauli
        Pallas kernel — the large-N path the paper's complexity claims
        describe.
    """

    name = "qpeft_pauli"

    def __init__(self, k: int = 3, n_layers: int = 1, alpha: float = 32.0,
                 use_pallas: bool = True, mode: str = "auto"):
        super().__init__()
        self.k, self.n_layers, self.alpha = k, n_layers, alpha
        self.use_pallas = use_pallas
        self.mode = mode
        self._adapter_kernel = make_adapter_apply(use_pallas)
        self._circuits = {}
        self._kernels = {}

    def _mode_for(self, n: int, m: int) -> str:
        if self.mode != "auto":
            return self.mode
        if _is_pow2(n) and _is_pow2(m) and max(n, m) <= 1024:
            return "materialize"
        return "apply"

    def _circuit(self, n: int):
        if n not in self._circuits:
            if _is_pow2(n):
                self._circuits[n] = pauli.build(n.bit_length() - 1, self.n_layers)
                if self.use_pallas:
                    self._kernels[n] = make_pauli_apply(self._circuits[n])
            else:
                self._circuits[n] = qsd.build(n, self.n_layers)
        return self._circuits[n]

    def init(self, key, n: int, m: int) -> dict:
        cu, cv = self._circuit(n), self._circuit(m)
        ku, kv = jax.random.split(key)
        return {
            "th_u": 0.2 * jax.random.normal(ku, (cu.num_params,), dtype=jnp.float32),
            "th_v": 0.2 * jax.random.normal(kv, (cv.num_params,), dtype=jnp.float32),
            "lam": jnp.zeros((self.k,), dtype=jnp.float32),
        }

    def num_params(self, n: int, m: int) -> int:
        return self._circuit(n).num_params + self._circuit(m).num_params + self.k

    def _apply_circuit(self, n: int, x, th):
        circ = self._circuit(n)
        if _is_pow2(n) and self.use_pallas:
            return self._kernels[n](x, th)
        return circ.apply(x, th)

    def apply(self, params, x, w):
        """y = x W + (alpha/K) ((x U) * lam) V^T.

        materialize mode: U, V from the Kronecker-chain product, fused
        adapter Pallas kernel for the whole expression.
        apply mode: x U = (x @ Q_P^{(n)})[:, :K] via the fused Pauli
        Pallas kernel; z V^T = pad_m(z) @ Q_P^{(m)T} (transpose circuit).
        """
        n, m = w.shape
        lead = x.shape[:-1]
        x2 = x.reshape(-1, n)
        if self._mode_for(n, m) == "materialize":
            u = self._circuit(n).materialize_kron(params["th_u"])[:, : self.k]
            v = self._circuit(m).materialize_kron(params["th_v"])[:, : self.k]
            y = self._adapter_kernel(x2, w, u, params["lam"], v,
                                     jnp.float32(self.alpha / self.k))
            return y.reshape(lead + (m,))
        xu = self._apply_circuit(n, x2, params["th_u"])[:, : self.k]
        z = xu * params["lam"]
        zp = jnp.zeros((x2.shape[0], m), dtype=x.dtype).at[:, : self.k].set(z)
        circ_v = self._circuit(m)
        zv = circ_v.apply_t(zp, params["th_v"]) if hasattr(circ_v, "apply_t") \
            else zp @ circ_v.materialize(params["th_v"]).T
        y = x2 @ w + (self.alpha / self.k) * zv
        return y.reshape(lead + (m,))

    def delta_w(self, params, n, m):
        u = self._circuit(n).materialize(params["th_u"])[:, : self.k] \
            if _is_pow2(n) else self._circuit(n).columns(params["th_u"], self.k)
        v = self._circuit(m).materialize(params["th_v"])[:, : self.k] \
            if _is_pow2(m) else self._circuit(m).columns(params["th_v"], self.k)
        return (self.alpha / self.k) * (u * params["lam"]) @ v.T


class QuantumPeftTaylor(PeftMethod):
    """Q_T-parameterized Quantum-PEFT (the speed-oriented regime, §4.2).

    Runtime extras (all optional, traced scalars):
      k_prime       intrinsic rank mask over Lie columns  (Table 8)
      quant_levels  2^n - 1 fake-quant levels, <= 0 disables (Table 7)
      quant_mode    0 = uniform, 1 = adaptive bit loading  (Table 7)
    """

    name = "qpeft_taylor"
    extra_inputs = ("k_prime", "quant_levels", "quant_mode")

    def __init__(self, k: int = 4, order: int = 8, alpha: float = 32.0,
                 group: int = 64, use_pallas: bool = True):
        super().__init__()
        self.k, self.order, self.alpha, self.group = k, order, alpha, group
        self._kernel = make_taylor_apply(order, use_pallas)

    def init(self, key, n: int, m: int) -> dict:
        ku, kv = jax.random.split(key)
        nu = mappings.lower_params_count(n, self.k)
        nv = mappings.lower_params_count(m, self.k)
        return {
            "th_u": 0.2 * jax.random.normal(ku, (nu,), dtype=jnp.float32),
            "th_v": 0.2 * jax.random.normal(kv, (nv,), dtype=jnp.float32),
            "lam": jnp.zeros((self.k,), dtype=jnp.float32),
        }

    def num_params(self, n: int, m: int, k_prime: int = None) -> int:
        kp = self.k if k_prime is None else k_prime
        return (mappings.lower_params_count(n, kp)
                + mappings.lower_params_count(m, kp) + self.k)

    def _lie_factor(self, th, n: int):
        """theta -> (quantized) masked B_K factor."""
        levels = self.extra("quant_levels", jnp.float32(0.0))
        mode = self.extra("quant_mode", jnp.float32(0.0))
        uni = quantize.fake_quant_st(th, jnp.maximum(levels, 1.0), self.group)
        # adaptive path: levels carries 2^bits - 1; recover base bits
        bits = jnp.log2(jnp.maximum(levels, 1.0) + 1.0)
        ada = quantize.adaptive_bit_loading(th, bits, self.group)
        th_q = jnp.where(levels > 0.0, jnp.where(mode > 0.5, ada, uni), th)
        bk = mappings.params_to_lower(th_q, n, self.k)
        kp = self.extra("k_prime", jnp.float32(self.k))
        return bk * mappings.intrinsic_mask(n, self.k, kp)

    def apply(self, params, x, w):
        n, m = w.shape
        lead = x.shape[:-1]
        x2 = x.reshape(-1, n)
        bu = self._lie_factor(params["th_u"], n)
        bv = self._lie_factor(params["th_v"], m)
        xu = self._kernel(x2, bu)[:, : self.k]          # x @ U
        z = xu * params["lam"]
        zp = jnp.zeros((x2.shape[0], m), dtype=x.dtype).at[:, : self.k].set(z)
        # z @ V^T = pad(z) @ Q_T(A_v)^T = pad(z) @ Q_T(-A_v): negate the factor
        zv = self._kernel(zp, -bv)
        y = x2 @ w + (self.alpha / self.k) * zv
        return y.reshape(lead + (m,))

    def delta_w(self, params, n, m):
        bu = self._lie_factor(params["th_u"], n)
        bv = self._lie_factor(params["th_v"], m)
        u = mappings.q_taylor(mappings.skew_from_factor(bu, n), self.order)[:, : self.k]
        v = mappings.q_taylor(mappings.skew_from_factor(bv, m), self.order)[:, : self.k]
        return (self.alpha / self.k) * (u * params["lam"]) @ v.T


class QuantumPeftTensorNetwork(PeftMethod):
    """Table-10 variants: Delta-W from a CP/TD/TTD/TRD/HTD network with
    orthogonal (Taylor-mapped) nodes — see quantum/tensor_networks.py."""

    name = "qpeft_tn"

    def __init__(self, network: str = "ttd", k: int = 4, order: int = 8,
                 alpha: float = 32.0):
        super().__init__()
        from ..quantum import tensor_networks as tn

        assert network in tn.NETWORKS
        self.network, self.k, self.order, self.alpha = network, k, order, alpha
        self._tn = tn

    def init(self, key, n: int, m: int) -> dict:
        return self._tn.init_params(key, self.network, n, m, self.k)

    def num_params(self, n: int, m: int) -> int:
        return self._tn.num_params(self.network, n, m, self.k)

    def delta_w(self, params, n, m):
        return (self.alpha / self.k) * self._tn.delta_w(
            self.network, params, n, m, self.k, self.order)

    def apply(self, params, x, w):
        n, m = w.shape
        return x @ (w + self.delta_w(params, n, m))
