"""Quantum-inspired building blocks of Quantum-PEFT (paper §4).

Submodules:
  gates            RY / CZ primitives and Kronecker-structured applies
  pauli            eq. (2) Pauli parameterization Q_P (log-params circuits)
  mappings         Lie-algebra -> orthogonal mappings (Q_E/C/T/N/H/G)
  qsd              quantum Shannon decomposition for arbitrary dims (eq. 4)
  diagonal         generalized CZ / diagonal nodes (real, Rademacher-ReinMax)
  quantize         groupwise Lie-parameter quantization + QAT (+A.5)
  tensor_networks  CP/TD/TTD/TRD/HTD adapter constructions (Table 10)
  accounting       closed-form parameter/byte counts (Table 1)
"""
from . import (  # noqa: F401
    accounting,
    diagonal,
    gates,
    mappings,
    pauli,
    qsd,
    quantize,
    tensor_networks,
)
