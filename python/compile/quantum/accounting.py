"""Closed-form trainable-parameter and storage accounting (Table 1, §4.2).

Counts are *analytic* — they depend only on layer dimensions, rank and
circuit depth, never on data — so Table 1 is reproduced exactly (same
model dimensions as the paper).  The Rust mirror (rust/src/peft/
accounting.rs) must agree; python/tests/test_accounting.py cross-checks
these formulas against actual pytree leaf counts of the PEFT methods.

Conventions (paper §4.2):
  LoRA        2 N K          per adapted N x M weight (K-rank pair, N==M there)
  AdaLoRA     (N + M) K + K  (SVD form, CP-redundant)
  Quantum-PEFT (Pauli)  2 ((2L+1) log2(N) - 2L) + K   per weight
  Quantum-PEFT (Taylor) 2 N K - K^2                    at N'=N, K'=K
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

from . import pauli, qsd


def lora_params(n: int, m: int, k: int) -> int:
    return (n + m) * k


def adalora_params(n: int, m: int, k: int) -> int:
    return (n + m) * k + k


def loha_params(n: int, m: int, k: int) -> int:
    return 2 * (n + m) * k


def lokr_params(n: int, m: int, k: int, f: int = 8) -> int:
    """Kronecker C (x) (B A): C is [f, f], low-rank pair on [n/f, m/f]."""
    return f * f + (n // f + m // f) * k


def mora_params(n: int, m: int, k: int) -> int:
    khat = int(math.isqrt((n + m) * k))
    return khat * khat


def quanta_params(n: int, m: int, k: int) -> int:
    """Tensor-folding with two-axis folding per side (simplified QuanTA)."""
    def fold(d: int) -> Tuple[int, int]:
        f = 1
        best = (1, d)
        while f * f <= d:
            if d % f == 0:
                best = (f, d // f)
            f += 1
        return best

    n1, n2 = fold(n)
    m1, m2 = fold(m)
    return n1 * n1 + n2 * n2 + m1 * m1 + m2 * m2


def qpeft_pauli_params(n: int, m: int, k: int, l: int = 1) -> int:
    """Pauli Q_P on both sides + diagonal: 2((2L+1)log2(N)-2L) + K.
    Non-power-of-two dims go through QSD (qsd.num_params)."""
    def side(d: int) -> int:
        if d >= 2 and (d & (d - 1)) == 0:
            return pauli.num_params(d, l)
        return qsd.num_params(d, l)

    return side(n) + side(m) + k


def qpeft_taylor_params(n: int, m: int, k: int, k_prime: int = None) -> int:
    """Taylor mapping on both sides + diagonal; with full K' = K this is
    the paper's 2NK - K^2 (the strictly-lower-triangular count)."""
    kp = k if k_prime is None else k_prime
    from . import mappings

    return (mappings.lower_params_count(n, kp)
            + mappings.lower_params_count(m, kp) + k)


METHOD_COUNTS = {
    "lora": lora_params,
    "adalora": adalora_params,
    "loha": loha_params,
    "lokr": lokr_params,
    "mora": mora_params,
    "quanta": quanta_params,
    "qpeft_pauli": qpeft_pauli_params,
    "qpeft_taylor": qpeft_taylor_params,
}


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Adapted-weight inventory of a model: list of (N, M, count)."""

    name: str
    weights: Tuple[Tuple[int, int, int], ...]   # (n, m, multiplicity)


# Table 1 model geometries: PEFT on query/value projections.
DEBERTA_V3_BASE = ModelSpec("deberta-v3-base", ((768, 768, 24),))       # 12 layers x {q, v}
LLAMA31_405B = ModelSpec("llama-3.1-405b", ((16384, 16384, 252),))     # 126 layers x {q, v}
GPT4_1T = ModelSpec("gpt-4", ((24576, 24576, 240),))                   # 120 layers x {q, v}


def table1_row(spec: ModelSpec, k: int, l: int = 1) -> dict:
    lora = sum(mult * lora_params(n, m, k) for n, m, mult in spec.weights)
    qp = sum(mult * qpeft_pauli_params(n, m, k, l) for n, m, mult in spec.weights)
    return {
        "model": spec.name,
        "rank": k,
        "lora_params": lora,
        "lora_bytes": lora * 4,
        "qpeft_params": qp,
        "qpeft_bytes": qp * 4,
    }


def table1(ks=(1, 16, 256)) -> List[dict]:
    rows = []
    for spec in (DEBERTA_V3_BASE, LLAMA31_405B, GPT4_1T):
        for k in ks:
            rows.append(table1_row(spec, k))
    return rows
