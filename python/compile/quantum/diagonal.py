"""Diagonal nodes — generalized CZ modules (paper §4.1, Figure 3b).

Three flavours:
  * real:        Lambda in R^K (acts as trainable singular values; the
                 SVD-form Delta-W = U Lambda V^T uses this, zero-init so
                 Delta-W = 0 at the start of fine-tuning, like LoRA's B=0);
  * rademacher:  Lambda in {+-1}^K via the ReinMax straight-through trick
                 (Liu et al., 2024) — a perfect reflection-group O(1)^K
                 element;
  * gumbel:      Gumbel-softmax relaxation of the same binary choice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def real_diag(lam):
    """Identity map: Lambda used directly as singular values."""
    return lam


def _straight_through(hard, soft):
    """Forward `hard`, backprop through `soft`."""
    return hard + soft - jax.lax.stop_gradient(soft)


def rademacher_reinmax(lam, tau: float = 1.0):
    """ReinMax-estimated sign vector: forward sign(lam) in {+-1}^K,
    backward through the second-order-accurate ReinMax surrogate
    2*pi1 - 0.5*p with pi1 = (D + p)/2 (Liu et al., 2024, eq. 12).

    Two-class specialization: classes (+1, -1) with logits (lam, -lam)/tau.
    """
    logits = jnp.stack([lam, -lam], axis=-1) / tau
    p = jax.nn.softmax(logits, axis=-1)
    hard = jnp.where(lam >= 0, 1.0, -1.0)
    d = jnp.stack([(hard + 1) / 2, (1 - hard) / 2], axis=-1)  # one-hot
    pi1 = 0.5 * (d + p)
    surrogate = 2.0 * pi1 - 0.5 * p
    # expectation of the sign under the surrogate distribution
    soft_sign = surrogate[..., 0] - surrogate[..., 1]
    return _straight_through(hard, soft_sign)


def rademacher_gumbel(lam, key, tau: float = 1.0):
    """Gumbel-softmax sampled sign with straight-through forward."""
    logits = jnp.stack([lam, -lam], axis=-1) / tau
    g = -jnp.log(-jnp.log(jax.random.uniform(key, logits.shape) + 1e-10) + 1e-10)
    p = jax.nn.softmax((logits + g) / tau, axis=-1)
    hard_idx = jnp.argmax(p, axis=-1)
    hard = jnp.where(hard_idx == 0, 1.0, -1.0)
    soft_sign = p[..., 0] - p[..., 1]
    return _straight_through(hard, soft_sign)


def diag_node(lam, kind: str = "real", tau: float = 1.0, key=None):
    if kind == "real":
        return real_diag(lam)
    if kind == "rademacher":
        return rademacher_reinmax(lam, tau)
    if kind == "gumbel":
        assert key is not None, "gumbel diagonal needs a PRNG key"
        return rademacher_gumbel(lam, key, tau)
    raise ValueError(f"unknown diagonal node kind {kind!r}")
