"""Quantum gate primitives used by the Pauli parameterization (paper §3).

Everything here is *classical* linear algebra: an RY gate is the 2x2
rotation of eq. (1); a CZ gate is the diagonal reflection diag(1,1,1,-1).
A "circuit" is a product of Kronecker-structured layers of these gates.

These helpers are shared by the pure-jnp reference path (kernels/ref.py),
the Pallas kernel (kernels/pauli_kernel.py) and the AOT model graphs; the
Rust mirror lives in rust/src/quantum/gates.rs and must match bit-for-bit
conventions (qubit 0 = fastest-varying axis; layers applied right-to-left
as written in eq. (2)).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def ry_matrix(theta):
    """RY(theta) of eq. (1): the SO(2) rotation by theta/2."""
    c = jnp.cos(theta / 2.0)
    s = jnp.sin(theta / 2.0)
    return jnp.stack(
        [jnp.stack([c, -s], axis=-1), jnp.stack([s, c], axis=-1)], axis=-2
    )


def cz_sign_vector(q: int, pairs) -> np.ndarray:
    """Sign vector in {+-1}^(2^q) of applying CZ on each (a, b) qubit pair.

    CZ = diag(1, 1, 1, -1) flips the sign of basis states where both
    qubits are |1>. Composing CZs on disjoint pairs is an elementwise
    product of sign vectors, so a whole CZ layer is one multiply.

    Qubit convention: qubit k corresponds to bit k of the basis-state
    index (little-endian), i.e. axis k of x.reshape([2]*q) with axis 0
    fastest-varying.
    """
    n = 1 << q
    idx = np.arange(n)
    sign = np.ones(n, dtype=np.float32)
    for a, b in pairs:
        both = ((idx >> a) & 1) & ((idx >> b) & 1)
        sign = sign * np.where(both == 1, -1.0, 1.0).astype(np.float32)
    return sign


def adjacent_pairs(qubits) -> list:
    """Pair up adjacent qubits of a list: [q0,q1,q2,q3,q4] -> [(q0,q1),(q2,q3)].

    The leftover qubit (odd count) is untouched — this generalizes the
    paper's CZ^{(q-1)/2} (eq. 2, stated for odd q) to any qubit count.
    """
    return [(qubits[i], qubits[i + 1]) for i in range(0, len(qubits) - 1, 2)]


def apply_ry_axis(x, cos_t, sin_t, k: int, q: int):
    """Apply RY(theta) on qubit k of batched states x of shape [..., 2^q].

    Equivalent to (I_{2^{q-k-1}} (x) RY (x) I_{2^k}) acting on the last
    axis; implemented as a strided pairwise rotation, O(N) work.
    """
    n = 1 << q
    lead = x.shape[:-1]
    stride = 1 << k
    xr = x.reshape(lead + (n // (2 * stride), 2, stride))
    x0 = xr[..., 0, :]
    x1 = xr[..., 1, :]
    y0 = cos_t * x0 - sin_t * x1
    y1 = sin_t * x0 + cos_t * x1
    return jnp.stack([y0, y1], axis=-2).reshape(lead + (n,))


def apply_kron_ry(x, thetas, qubits, q: int):
    """Apply (x)_{k in qubits} RY(theta_k) to x in [..., 2^q].

    `thetas` is a 1-D array aligned with `qubits`. Sequential per-qubit
    rotations: q axis sweeps of O(N) each — the "Kronecker shuffle"
    (Plateau 1985) giving the O(N log N) circuit apply of §4.2.
    """
    cos_t = jnp.cos(thetas / 2.0)
    sin_t = jnp.sin(thetas / 2.0)
    for i, k in enumerate(qubits):
        x = apply_ry_axis(x, cos_t[i], sin_t[i], k, q)
    return x
