"""Lie-algebra -> orthogonal-matrix mappings (paper §4.1 and Appendix A.1).

Given a strictly-lower-triangular parameter matrix B (only its first K'
columns trainable — the paper's *intrinsic rank* masking), the
skew-symmetric A = B - B^T generates an orthogonal matrix via one of:

  Q_E  exponential map            expm(A)                          (exact)
  Q_C  Cayley transform           (I+A)(I-A)^{-1}                  (exact)
  Q_T  Taylor series              sum_{p<=P} A^p / p!              (approx of Q_E)
  Q_N  Neumann series             (I+A) sum_{p<=P} A^p             (approx of Q_C)
  Q_H  Householder reflections    prod_k (I - 2 n_k n_k^T)         (exact)
  Q_G  Givens rotations           prod G_{n-k}(B_{n,k})            (exact)

Truncating columns of the resulting square orthogonal matrix yields a
Stiefel V_K(N') frame (Figure 3a). The paper selects Q_T as the best
accuracy/speed/parameter trade-off and Q_P (pauli.py) for the extreme
parameter regime; Figure 6 benchmarks all of them (mirrored in
rust/src/quantum/mappings.rs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MAPPINGS = ("exp", "cayley", "taylor", "neumann", "householder", "givens")


def lower_params_count(n: int, k: int) -> int:
    """Number of strictly-lower-triangular entries in the first k columns
    of an n x n matrix: sum_{j<k} (n-1-j) = nk - k(k+1)/2 ... clipped."""
    k = min(k, n - 1) if n > 1 else 0
    return sum(n - 1 - j for j in range(k))


def params_to_lower(theta, n: int, k: int):
    """Scatter a flat parameter vector into the strictly-lower N' x K'
    factor B_K (Figure 3a). Column-major fill; frozen/absent entries are 0."""
    bk = jnp.zeros((n, k), dtype=theta.dtype)
    ofs = 0
    for j in range(min(k, n - 1)):
        m = n - 1 - j
        bk = bk.at[j + 1:, j].set(theta[ofs: ofs + m])
        ofs += m
    return bk


def intrinsic_mask(n: int, k: int, k_prime) -> jnp.ndarray:
    """[n, k] mask keeping only the top-K' columns trainable (paper §4.1,
    Table 8). `k_prime` may be a traced scalar so one AOT artifact serves
    the whole K' sweep."""
    col = jnp.arange(k)[None, :]
    return (col < k_prime).astype(jnp.float32) * jnp.ones((n, 1), dtype=jnp.float32)


def skew_from_factor(bk, n: int):
    """A = B - B^T from the N' x K' strictly-lower factor (zero-padded)."""
    k = bk.shape[1]
    b = jnp.zeros((n, n), dtype=bk.dtype).at[:, :k].set(jnp.tril(bk, k=-1))
    return b - b.T


def q_exp(a):
    """Q_E = expm(A): exact orthogonal map (uses Pade under the hood)."""
    return jax.scipy.linalg.expm(a)


def q_cayley(a):
    """Q_C = (I + A)(I - A)^{-1}: exact for any skew-symmetric A."""
    n = a.shape[-1]
    eye = jnp.eye(n, dtype=a.dtype)
    return jnp.linalg.solve((eye - a).T, (eye + a).T).T


def q_taylor(a, order: int = 8):
    """Q_T = sum_{p=0}^P A^p / p! via Horner: never forms A^p explicitly."""
    n = a.shape[-1]
    acc = jnp.eye(n, dtype=a.dtype)
    for p in range(order, 0, -1):
        acc = jnp.eye(n, dtype=a.dtype) + (a @ acc) / p
    return acc


def q_taylor_apply(a, x, order: int = 8):
    """x @ Q_T^T == Q_T x for column semantics; here: apply Q_T to rows of
    x from the right via the same Horner recursion on row-vectors,
    avoiding materializing Q_T (the tensor-contraction-ordering trick of
    §4.1).  x: [..., N], returns x @ Q_T."""
    # x @ Q_T = x @ sum A^p/p! ; Horner on the right: acc = x + (acc @ A)/p
    acc = x
    for p in range(order, 0, -1):
        acc = x + (acc @ a) / p
    return acc


def q_neumann(a, order: int = 8):
    """Q_N = (I + A) sum_{p=0}^P A^p — Neumann-series approx of Cayley."""
    n = a.shape[-1]
    acc = jnp.eye(n, dtype=a.dtype)
    for _ in range(order):
        acc = jnp.eye(n, dtype=a.dtype) + a @ acc
    return (jnp.eye(n, dtype=a.dtype) + a) @ acc


def q_householder(bk, n: int):
    """Q_H = prod_k (I - 2 n_k n_k^T), n_k = normalized k-th column of B
    (canonical coset decomposition, Cabrera et al. 2010)."""
    k = bk.shape[1]
    q = jnp.eye(n, dtype=bk.dtype)
    for j in range(k):
        v = bk[:, j]
        nrm2 = jnp.maximum(v @ v, 1e-12)
        h = jnp.eye(n, dtype=bk.dtype) - 2.0 * jnp.outer(v, v) / nrm2
        q = q @ h
    return q


def q_givens(bk, n: int):
    """Q_G = prod_{k} prod_{m>k} G_{m-1}(B_{m,k}): a ladder of adjacent-plane
    rotations per column. Sequential by nature (Figure 6's slow tail)."""
    k = bk.shape[1]
    q = jnp.eye(n, dtype=bk.dtype)
    for j in range(min(k, n - 1)):
        for m in range(j + 1, n):
            th = bk[m, j]
            c, s = jnp.cos(th), jnp.sin(th)
            # rotate rows m-1, m of the accumulator
            r0 = q[m - 1], q[m]
            q = q.at[m - 1].set(c * r0[0] - s * r0[1])
            q = q.at[m].set(s * r0[0] + c * r0[1])
    return q


def orthogonal(theta, n: int, k: int, method: str = "taylor", order: int = 8,
               k_prime=None):
    """Full pipeline of Figure 3(a): flat Lie params -> B_K (masked to the
    intrinsic rank K' if given) -> skew A -> orthogonal Q -> Stiefel
    truncation Q[:, :k].

    Returns the N x K frame (left-orthogonal for the exact mappings,
    near-orthogonal for the series approximations)."""
    bk = params_to_lower(theta, n, k)
    if k_prime is not None:
        bk = bk * intrinsic_mask(n, k, k_prime)
    if method == "householder":
        return q_householder(bk, n)[:, :k]
    if method == "givens":
        return q_givens(bk, n)[:, :k]
    a = skew_from_factor(bk, n)
    if method == "exp":
        q = q_exp(a)
    elif method == "cayley":
        q = q_cayley(a)
    elif method == "taylor":
        q = q_taylor(a, order)
    elif method == "neumann":
        q = q_neumann(a, order)
    else:
        raise ValueError(f"unknown mapping {method!r}")
    return q[:, :k]


def unitarity_error(q) -> jnp.ndarray:
    """||Q Q^T - I||_inf — Figure 6's error metric."""
    n = q.shape[0]
    return jnp.max(jnp.abs(q @ q.T - jnp.eye(n, dtype=q.dtype)))
