"""Pauli parameterization Q_P of eq. (2) — the paper's core contribution.

A unitary on SO(2^q) built from the simplified two-design ansatz
(Cerezo et al., 2021): an initial full Kronecker layer of RY rotations,
followed by L alternating "entanglement blocks". Each block has

  sub-layer A:  (CZ-pairs o  (x)_{k=1..q-1} RY(theta))  (x)  I   — qubits 0..q-2
  sub-layer B:   I  (x)  (CZ-pairs o  (x)_{k=2..q} RY(theta))    — qubits 1..q-1

Trainable parameter count:  q + 2 L (q-1)  ==  (2L+1) log2(N) - 2L,
i.e. *logarithmic* in the ambient dimension N — the headline scaling of
the paper (vs 2NK for LoRA).

The circuit is exposed in two forms:
  * `apply`        — x @ Q_P for batched row-vectors (O(N log N · L));
  * `materialize`  — the dense N x N orthogonal matrix (tests / small N).

`PauliCircuit` is a static *structure* object (shapes, qubit lists, sign
vectors are all Python/NumPy constants baked into the lowered HLO); the
trainable angles are a flat jnp array so they can live in a params pytree.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np
import jax.numpy as jnp

from . import gates


@dataclasses.dataclass(frozen=True)
class _Layer:
    """One RY-Kronecker sweep (+ optional CZ sign layer) of the circuit."""

    qubits: Tuple[int, ...]     # qubits rotated by this layer
    theta_ofs: int              # offset of this layer's angles in the flat vector
    sign: np.ndarray | None     # CZ sign vector applied after the rotations


@dataclasses.dataclass(frozen=True)
class PauliCircuit:
    """Static structure of Q_P for N = 2^q with L entanglement blocks."""

    q: int
    n_layers: int               # L in the paper
    layers: Tuple[_Layer, ...]
    num_params: int

    @property
    def dim(self) -> int:
        return 1 << self.q

    def apply(self, x, thetas):
        """Compute x @ Q_P, x of shape [..., 2^q], thetas flat [num_params].

        Note: with our convention each layer acts on row-vectors from the
        right, so layers are applied in construction order.
        """
        assert thetas.shape[-1] == self.num_params, (
            f"expected {self.num_params} angles, got {thetas.shape}"
        )
        for layer in self.layers:
            th = jnp.asarray(thetas)[layer.theta_ofs: layer.theta_ofs + len(layer.qubits)]
            x = gates.apply_kron_ry(x, th, list(layer.qubits), self.q)
            if layer.sign is not None:
                x = x * jnp.asarray(layer.sign)
        return x

    def apply_t(self, x, thetas):
        """Compute x @ Q_P^T (transpose circuit: reversed layers, -theta).

        Used to apply V^T when V = Q_P[:, :K]: pad the K-vector with zeros
        and run the transposed circuit.
        """
        for layer in reversed(self.layers):
            if layer.sign is not None:
                x = x * jnp.asarray(layer.sign)
            th = jnp.asarray(thetas)[layer.theta_ofs: layer.theta_ofs + len(layer.qubits)]
            x = gates.apply_kron_ry(x, -th[::-1], list(layer.qubits)[::-1], self.q)
        return x

    def materialize(self, thetas):
        """Dense Q_P in R^{N x N}; row i = e_i @ Q_P (so x @ Q_P = x @ mat)."""
        eye = jnp.eye(self.dim, dtype=jnp.float32)
        return self.apply(eye, thetas)

    def materialize_kron(self, thetas):
        """Dense Q_P built as a product of Kronecker-chain layer matrices.

        Mathematically identical to `materialize` (pinned by tests) but
        lowers to ~25 small ops per circuit instead of ~N_rot·7 strided
        reshape/stack chains — the §Perf L2 fix: xla_extension 0.5.1's
        CPU pipeline compiles the op-chain form catastrophically slowly
        (209s -> ~2s for the d=64 encoder train step), so the AOT model
        graphs use this form while the Pallas kernel keeps the O(N log N)
        apply path for the large-N regime.

        Convention: qubit k = bit k of the basis index (fastest axis 0),
        so the per-qubit factor sits *innermost-last* in the kron chain,
        and the row-vector action x @ Q uses the transposed rotation
        R^T = [[c, s], [-s, c]].
        """
        n = self.dim
        q_total = None
        for layer in self.layers:
            th = jnp.asarray(thetas)[layer.theta_ofs:
                                     layer.theta_ofs + len(layer.qubits)]
            c = jnp.cos(th / 2.0)
            s = jnp.sin(th / 2.0)
            active = dict(zip(layer.qubits, range(len(layer.qubits))))
            # build kron chain from the highest qubit down so qubit 0 is
            # the innermost (fastest-varying) factor
            mat = jnp.ones((1, 1), dtype=jnp.float32)
            for k in range(self.q - 1, -1, -1):
                if k in active:
                    i = active[k]
                    rt = jnp.stack([
                        jnp.stack([c[i], s[i]]),
                        jnp.stack([-s[i], c[i]]),
                    ])  # R^T for row-vector action
                else:
                    rt = jnp.eye(2, dtype=jnp.float32)
                mat = jnp.kron(mat, rt)
            if layer.sign is not None:
                mat = mat * jnp.asarray(layer.sign)[None, :]
            q_total = mat if q_total is None else q_total @ mat
        if q_total is None:
            q_total = jnp.eye(n, dtype=jnp.float32)
        return q_total

    def columns(self, thetas, k: int):
        """First k columns of Q_P — a Stiefel V_k(N) frame by construction."""
        return self.materialize(thetas)[:, :k]


def build(q: int, n_layers: int) -> PauliCircuit:
    """Build the eq. (2) circuit structure for q qubits, L = n_layers."""
    assert q >= 1
    layers: List[_Layer] = []
    ofs = 0

    # initial full Kronecker RY layer: q angles, no entanglement
    layers.append(_Layer(qubits=tuple(range(q)), theta_ofs=ofs, sign=None))
    ofs += q

    for _ in range(n_layers):
        if q >= 2:
            # sub-layer A on qubits 0..q-2 (".. (x) I" in eq. 2)
            qa = list(range(0, q - 1))
            layers.append(
                _Layer(
                    qubits=tuple(qa),
                    theta_ofs=ofs,
                    sign=gates.cz_sign_vector(q, gates.adjacent_pairs(qa)),
                )
            )
            ofs += len(qa)
            # sub-layer B on qubits 1..q-1 ("I (x) .." in eq. 2)
            qb = list(range(1, q))
            layers.append(
                _Layer(
                    qubits=tuple(qb),
                    theta_ofs=ofs,
                    sign=gates.cz_sign_vector(q, gates.adjacent_pairs(qb)),
                )
            )
            ofs += len(qb)
    return PauliCircuit(q=q, n_layers=n_layers, layers=tuple(layers), num_params=ofs)


def num_params(n: int, n_layers: int) -> int:
    """(2L+1) log2(N) - 2L for power-of-two N (paper §4.1)."""
    q = int(np.log2(n))
    assert (1 << q) == n, "num_params: N must be a power of two"
    if q == 1:
        return 1
    return q + 2 * n_layers * (q - 1)


def init_angles(key, circuit: PauliCircuit, scale: float = 0.2):
    """Small random angles — near-identity init keeps Delta-W ~ 0 at start
    only when combined with a zero-initialized diagonal node (as in LoRA's
    zero-init of B)."""
    import jax

    return scale * jax.random.normal(key, (circuit.num_params,), dtype=jnp.float32)
