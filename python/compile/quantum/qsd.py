"""Quantum Shannon decomposition (QSD) — eq. (4) and Example 4.1.

Solves the power-of-two limitation of the Pauli parameterization: any
orthogonal matrix on SO(N), N = N1 + N2 (N1 = largest power of two <= N,
N1 >= N2 >= 1), is built as

    Q = blkdiag(U1, U2) . G(phi) . blkdiag(V1, V2)

with U1, V1 on SO(N1), U2, V2 on SO(N2), and G(phi) the cosine-sine
orthogonal coupling acting on the last N2 coordinates of the first block
and the N2 coordinates of the second block:

    [ya]   [ cos(phi)  -sin(phi)] [xa]      xa = x[N1-N2 : N1]
    [yb] = [ sin(phi)   cos(phi)] [xb],     xb = x[N1 : N],   phi in R^{N2}.

(A row/column permutation of the paper's eq. (4) block layout — the same
group element with friendlier indexing.)  Power-of-two blocks are Pauli
circuits (pauli.py); non-power-of-two sub-blocks recurse, reproducing
Example 4.1 (N=28 -> 16 + (8 + 4), two CS couplings, three Pauli blocks
per side).

Parameter layout (flat, in order): [U1 | U2 | phi | V1 | V2], recursing
inside U2/V2 as needed. Dim-1 blocks are parameterless identities.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from . import pauli


@dataclasses.dataclass(frozen=True)
class QsdNode:
    """Recursive QSD structure over dimension n (any n >= 1)."""

    n: int
    num_params: int
    # leaf: a Pauli circuit (power-of-two n) or identity (n == 1)
    leaf: Optional[pauli.PauliCircuit]
    # internal: split n = n1 + n2 with four children + n2 CS angles
    n1: int = 0
    n2: int = 0
    u1: Optional["QsdNode"] = None
    u2: Optional["QsdNode"] = None
    v1: Optional["QsdNode"] = None
    v2: Optional["QsdNode"] = None

    def apply(self, x, thetas):
        """x @ Q for x of shape [..., n]; thetas flat [num_params]."""
        if self.n == 1:
            return x
        if self.leaf is not None:
            return self.leaf.apply(x, thetas)
        o = 0
        th_u1 = thetas[o: o + self.u1.num_params]; o += self.u1.num_params
        th_u2 = thetas[o: o + self.u2.num_params]; o += self.u2.num_params
        phi = thetas[o: o + self.n2]; o += self.n2
        th_v1 = thetas[o: o + self.v1.num_params]; o += self.v1.num_params
        th_v2 = thetas[o: o + self.v2.num_params]; o += self.v2.num_params

        xa = self.u1.apply(x[..., : self.n1], th_u1)
        xb = self.u2.apply(x[..., self.n1:], th_u2)
        # CS coupling on the trailing n2 of the first block vs second block
        c, s = jnp.cos(phi), jnp.sin(phi)
        ha, ta = xa[..., : self.n1 - self.n2], xa[..., self.n1 - self.n2:]
        ya = c * ta - s * xb
        yb = s * ta + c * xb
        za = jnp.concatenate([ha, ya], axis=-1)
        return jnp.concatenate(
            [self.v1.apply(za, th_v1), self.v2.apply(yb, th_v2)], axis=-1
        )

    def materialize(self, thetas):
        return self.apply(jnp.eye(self.n, dtype=jnp.float32), thetas)

    def columns(self, thetas, k: int):
        """First k columns — a Stiefel frame (exact orthogonality)."""
        return self.materialize(thetas)[:, :k]


def split(n: int) -> Tuple[int, int]:
    """(N1, N2): N1 = largest power of two strictly below n (for
    non-power-of-two n, the largest power of two <= n)."""
    assert n >= 2
    n1 = 1 << (n.bit_length() - 1)
    if n1 == n:
        n1 = n >> 1
    return n1, n - n1


def build(n: int, n_layers: int) -> QsdNode:
    """QSD circuit for arbitrary n >= 1, Pauli blocks of depth L."""
    assert n >= 1
    if n == 1:
        return QsdNode(n=1, num_params=0, leaf=None)
    if (n & (n - 1)) == 0:  # power of two -> plain Pauli leaf
        circ = pauli.build(n.bit_length() - 1, n_layers)
        return QsdNode(n=n, num_params=circ.num_params, leaf=circ)
    n1, n2 = split(n)
    u1 = build(n1, n_layers)
    u2 = build(n2, n_layers)
    v1 = build(n1, n_layers)
    v2 = build(n2, n_layers)
    num = u1.num_params + u2.num_params + n2 + v1.num_params + v2.num_params
    return QsdNode(n=n, num_params=num, leaf=None, n1=n1, n2=n2,
                   u1=u1, u2=u2, v1=v1, v2=v2)


def num_params(n: int, n_layers: int) -> int:
    return build(n, n_layers).num_params


def power_of_two_blocks(n: int) -> list:
    """Greedy binary partition of N, e.g. 28 -> [16, 8, 4]; 257 -> [256, 1].
    (Used by the Rust accounting mirror and Example 4.1 tests.)"""
    blocks = []
    while n > 0:
        b = 1 << (n.bit_length() - 1)
        blocks.append(b)
        n -= b
    return blocks
