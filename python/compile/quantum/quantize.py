"""Groupwise integer quantization of Lie parameters (paper §4.2 "Quantization",
Tables 7 and experiments §5.4) + adaptive bit loading (Appendix A.5).

    theta_q = round((theta - mu) / beta) * beta + mu
    beta    = (max - min) / (2^n - 1),   mu = min      (per group of g)

QAT uses the straight-through trick: theta := theta_q + theta - sg(theta),
i.e. forward quantized, identity backward.  The bit-width `n` enters only
through `levels = 2^n - 1`, so a *traced scalar* number of levels lets a
single AOT artifact serve the whole Table-7 bit sweep at run time.

Storage cost per parameter (paper): n + 32/g bits (fp16 beta and mu per
group) — mirrored in rust/src/peft/accounting.rs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _group_reshape(theta, g: int):
    """Pad flat theta to a multiple of g and reshape to [n_groups, g]."""
    n = theta.shape[0]
    n_groups = -(-n // g)
    pad = n_groups * g - n
    padded = jnp.pad(theta, (0, pad))
    return padded.reshape(n_groups, g), n


def quantize_groups(theta, levels, g: int = 128):
    """Quantize flat theta with `levels` = 2^n - 1 quantization steps per
    group of g. `levels` may be a traced scalar (float)."""
    grp, n = _group_reshape(theta, g)
    lo = jnp.min(grp, axis=1, keepdims=True)
    hi = jnp.max(grp, axis=1, keepdims=True)
    beta = (hi - lo) / jnp.maximum(levels, 1.0)
    beta = jnp.where(beta <= 0, 1.0, beta)  # constant group -> passthrough
    q = jnp.round((grp - lo) / beta) * beta + lo
    return q.reshape(-1)[:n]


def fake_quant_st(theta, levels, g: int = 128):
    """QAT straight-through fake-quant: forward quantized, gradient = 1."""
    q = quantize_groups(theta, levels, g)
    return theta + jax.lax.stop_gradient(q - theta)


def adaptive_bit_loading(theta, base_bits: float, g: int = 128,
                         kappa: float = 1.0):
    """Appendix A.5 adaptive (mixed-precision) bit loading.

    Per-group bits  q_i = round(base + log2(Delta_i^kappa / mean Delta)),
    Delta_i = max_i - min_i (group dynamic range). Groups with q_i <= 0 are
    structurally pruned to their zero point (mu). Returns the fake-quant
    (straight-through) tensor — a traced `base_bits` serves the Table-7
    adaptive rows with one artifact."""
    grp, n = _group_reshape(theta, g)
    lo = jnp.min(grp, axis=1, keepdims=True)
    hi = jnp.max(grp, axis=1, keepdims=True)
    delta = (hi - lo)[:, 0]
    mean_delta = jnp.maximum(jnp.mean(delta ** kappa), 1e-12)
    bits = jnp.round(base_bits + jnp.log2(jnp.maximum(delta ** kappa, 1e-12)
                                          / mean_delta))
    bits = jnp.clip(bits, 0.0, 16.0)[:, None]
    levels = jnp.maximum(2.0 ** bits - 1.0, 1.0)
    beta = (hi - lo) / levels
    beta = jnp.where(beta <= 0, 1.0, beta)
    q = jnp.round((grp - lo) / beta) * beta + lo
    q = jnp.where(bits <= 0.0, lo, q)  # 0-bit group -> structural prune
    flat = q.reshape(-1)[:n]
    return theta + jax.lax.stop_gradient(flat - theta)


def storage_bits_per_param(n_bits: float, g: int = 128) -> float:
    """n + 32/g bits per Lie parameter (fp16 beta + fp16 mu per group)."""
    return n_bits + 32.0 / g


def quantize_base_weights(w, n_bits: int, g: int = 128):
    """Post-training quantization of a *frozen* base weight tensor (used
    for the 3-bit ViT backbone of Table 6; the Rust coordinator applies
    the identical transform host-side before feeding frozen params)."""
    flat = w.reshape(-1)
    q = quantize_groups(flat, float(2 ** n_bits - 1), g)
    return q.reshape(w.shape)
