"""Tensor-network adapter constructions (Appendix A.3, Table 10, Fig. 5/7).

Delta-W for W in R^{n x m} built from small *orthogonal* nodes (Taylor
mapping, mappings.py) plus one diagonal node — the canonical-form insight
of the paper: any TTD/TD network can be renormalized so all nodes but one
diagonal are unitary, removing LoRA-style parameter redundancy.

Networks (matching Table 10's columns):
  CP         sum_r  lam_r  u_r (x) v_r            (K orthogonal frames + diag)
  TD         U G V^T  (Tucker-2, dense K x K core)
  TTD (MPS)  reshape to (n1, n2) x (m1, m2), 4-core tensor train
  TRD        3-node ring with one diagonal node
  HTD (TTN)  binary tree: two leaf frames + root coupling

Every node's orthogonal factor comes from `mappings.orthogonal` so the
trainable parameters live in Lie algebras; parameter counts are exposed
for the accounting module and verified against actual pytree sizes in
python/tests/test_tensor_networks.py.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import mappings

NETWORKS = ("cp", "td", "ttd", "trd", "htd")


def _factor2(d: int) -> Tuple[int, int]:
    """Near-square factorization d = d1 * d2 (d1 <= d2)."""
    best = (1, d)
    f = 1
    while f * f <= d:
        if d % f == 0:
            best = (f, d // f)
        f += 1
    return best


def param_shapes(net: str, n: int, m: int, k: int, order: int = 8) -> Dict[str, tuple]:
    """Shapes of the trainable Lie/diag parameters for each network."""
    if net == "cp":
        return {
            "lie_u": (mappings.lower_params_count(n, k),),
            "lie_v": (mappings.lower_params_count(m, k),),
            "diag": (k,),
        }
    if net == "td":
        return {
            "lie_u": (mappings.lower_params_count(n, k),),
            "lie_v": (mappings.lower_params_count(m, k),),
            "core": (k, k),
        }
    if net == "ttd":
        n1, n2 = _factor2(n)
        m1, m2 = _factor2(m)
        return {
            "lie_g1": (mappings.lower_params_count(n1, min(k, n1)),),
            "core2": (min(k, n1), n2, k),
            "core3": (k, m1, min(k, m2)),
            "lie_g4": (mappings.lower_params_count(m2, min(k, m2)),),
            "diag": (k,),
        }
    if net == "trd":
        n1, n2 = _factor2(n)
        return {
            "lie_a": (mappings.lower_params_count(n1, min(k, n1)),),
            "lie_b": (mappings.lower_params_count(n2, min(k, n2)),),
            "lie_c": (mappings.lower_params_count(m, k),),
            "core": (min(k, n1), min(k, n2), k),
            "diag": (k,),
        }
    if net == "htd":
        n1, n2 = _factor2(n)
        m1, m2 = _factor2(m)
        return {
            "lie_n1": (mappings.lower_params_count(n1, min(k, n1)),),
            "lie_n2": (mappings.lower_params_count(n2, min(k, n2)),),
            "lie_m1": (mappings.lower_params_count(m1, min(k, m1)),),
            "lie_m2": (mappings.lower_params_count(m2, min(k, m2)),),
            "root": (min(k, n1) * min(k, n2), min(k, m1) * min(k, m2)),
        }
    raise ValueError(f"unknown tensor network {net!r}")


def num_params(net: str, n: int, m: int, k: int) -> int:
    import numpy as np

    return int(sum(np.prod(s) for s in param_shapes(net, n, m, k).values()))


def init_params(key, net: str, n: int, m: int, k: int, scale: float = 0.2):
    shapes = param_shapes(net, n, m, k)
    keys = jax.random.split(key, len(shapes))
    out = {}
    for kk, (name, shp) in zip(keys, sorted(shapes.items())):
        if name in ("diag",):
            out[name] = jnp.zeros(shp, dtype=jnp.float32)  # Delta-W = 0 at init
        elif name in ("core", "core2", "core3", "root"):
            out[name] = jnp.zeros(shp, dtype=jnp.float32)
        else:
            out[name] = scale * jax.random.normal(kk, shp, dtype=jnp.float32)
    return out


def delta_w(net: str, params, n: int, m: int, k: int, order: int = 8):
    """Materialize Delta-W in R^{n x m} from the network parameters."""
    orth = lambda th, d, kk: mappings.orthogonal(th, d, kk, "taylor", order)
    if net == "cp":
        u = orth(params["lie_u"], n, k)          # [n, k]
        v = orth(params["lie_v"], m, k)          # [m, k]
        return (u * params["diag"][None, :]) @ v.T
    if net == "td":
        u = orth(params["lie_u"], n, k)
        v = orth(params["lie_v"], m, k)
        return u @ params["core"] @ v.T
    if net == "ttd":
        n1, n2 = _factor2(n)
        m1, m2 = _factor2(m)
        k1, k4 = min(k, n1), min(k, m2)
        g1 = orth(params["lie_g1"], n1, k1)      # [n1, k1]
        g4 = orth(params["lie_g4"], m2, k4)      # [m2, k4]
        g2 = params["core2"]                     # [k1, n2, k]
        g3 = params["core3"] * params["diag"][:, None, None]  # [k, m1, k4]
        # contract: W[n1 n2, m1 m2] = g1 g2 g3 g4
        t = jnp.einsum("ab,bcd->acd", g1, g2)        # [n1, n2, k]
        t = jnp.einsum("acd,def->acef", t, g3)       # [n1, n2, m1, k4]
        t = jnp.einsum("acef,gf->aceg", t, g4)       # [n1, n2, m1, m2]
        return t.reshape(n, m)
    if net == "trd":
        n1, n2 = _factor2(n)
        ka, kb = min(k, n1), min(k, n2)
        a = orth(params["lie_a"], n1, ka)
        b = orth(params["lie_b"], n2, kb)
        c = orth(params["lie_c"], m, k)
        core = params["core"] * params["diag"][None, None, :]  # [ka, kb, k]
        t = jnp.einsum("ia,jb,abk->ijk", a, b, core)  # [n1, n2, k]
        return t.reshape(n, k) @ c.T
    if net == "htd":
        n1, n2 = _factor2(n)
        m1, m2 = _factor2(m)
        k1, k2 = min(k, n1), min(k, n2)
        k3, k4 = min(k, m1), min(k, m2)
        a = orth(params["lie_n1"], n1, k1)
        b = orth(params["lie_n2"], n2, k2)
        c = orth(params["lie_m1"], m1, k3)
        d = orth(params["lie_m2"], m2, k4)
        left = jnp.einsum("ia,jb->ijab", a, b).reshape(n, k1 * k2)
        right = jnp.einsum("ic,jd->ijcd", c, d).reshape(m, k3 * k4)
        return left @ params["root"] @ right.T
    raise ValueError(f"unknown tensor network {net!r}")
