"""Fused training step construction + trainable-leaf partitioning.

A train-step artifact is one XLA computation:

  (frozen..., train..., m..., v..., step, lr, wd, extras..., batch...)
      -> (loss, new_train..., new_m..., new_v...)

Frozen leaves are inputs only (the Rust coordinator re-feeds them every
step — on CPU PJRT this is a host memcpy); optimizer state (AdamW m/v)
exists *only* for trainable leaves, which is most of the PEFT memory
story (Table 4's memory ratios fall out of exactly this split).

Learning rate, weight decay and step index are runtime scalars: the Rust
coordinator owns the schedule (linear warmup+decay etc.) and the graph
stays schedule-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def path_str(path) -> str:
    """KeyPath -> 'base.blocks[0].attn.wq.w' style name."""
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out[-1] = out[-1] + f"[{p.idx}]" if out else f"[{p.idx}]"
        else:
            out.append(str(p))
    return ".".join(out)


def flatten_with_names(tree) -> Tuple[List[str], List[jnp.ndarray], object]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [path_str(p) for p, _ in flat]
    leaves = [l for _, l in flat]
    return names, leaves, treedef


def trainable_predicate(method) -> Callable[[str], bool]:
    """Which leaves train, per method (DESIGN.md §3):
    adapters + task head always; base weights iff `base_trainable`;
    base biases additionally iff `bias_trainable` (BitFit)."""

    def pred(name: str) -> bool:
        root = name.split(".", 1)[0]
        if root in ("adapters", "head"):
            return True
        if method.base_trainable:
            return True
        if method.bias_trainable and name.rsplit(".", 1)[-1] == "b":
            return True
        return False

    return pred


@dataclasses.dataclass
class Partition:
    """Stable split of a params pytree into frozen and trainable leaves."""

    treedef: object
    names: List[str]
    mask: List[bool]                 # True = trainable, aligned with names

    @property
    def frozen_names(self) -> List[str]:
        return [n for n, t in zip(self.names, self.mask) if not t]

    @property
    def trainable_names(self) -> List[str]:
        return [n for n, t in zip(self.names, self.mask) if t]

    def split(self, tree) -> Tuple[List, List]:
        leaves = self.treedef.flatten_up_to(tree)
        leaves = jax.tree_util.tree_leaves(tree)
        frozen = [l for l, t in zip(leaves, self.mask) if not t]
        train = [l for l, t in zip(leaves, self.mask) if t]
        return frozen, train

    def merge(self, frozen: Sequence, train: Sequence):
        fi = iter(frozen)
        ti = iter(train)
        leaves = [next(ti) if t else next(fi) for t in self.mask]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


def make_partition(example_tree, method) -> Partition:
    names, _, treedef = flatten_with_names(example_tree)
    pred = trainable_predicate(method)
    return Partition(treedef=treedef, names=names,
                     mask=[pred(n) for n in names])


def adamw_update(p, g, m, v, step, lr, wd):
    """Decoupled AdamW on one leaf; step is the 1-based update index."""
    m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m2 / (1.0 - ADAM_B1 ** step)
    vhat = v2 / (1.0 - ADAM_B2 ** step)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * p)
    return p2, m2, v2


def make_train_step(loss_fn, part: Partition, n_extras: int):
    """loss_fn(params_tree, extras_tuple, *batch) -> scalar.

    Returns step(frozen..., train..., m..., v..., step, lr, wd,
                 extras..., batch...) as a flat-arguments function ready
    for jax.jit().lower() — see aot.py for the argument layout contract
    shared with rust/src/runtime/session.rs."""
    n_froz = len(part.frozen_names)
    n_train = len(part.trainable_names)

    def step_fn(*args):
        i = 0
        frozen = list(args[i: i + n_froz]); i += n_froz
        train = list(args[i: i + n_train]); i += n_train
        m = list(args[i: i + n_train]); i += n_train
        v = list(args[i: i + n_train]); i += n_train
        step, lr, wd = args[i], args[i + 1], args[i + 2]; i += 3
        extras = tuple(args[i: i + n_extras]); i += n_extras
        batch = args[i:]

        def loss_of(train_leaves):
            tree = part.merge(frozen, train_leaves)
            return loss_fn(tree, extras, *batch)

        loss, grads = jax.value_and_grad(loss_of)(train)
        new_t, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(train, grads, m, v):
            p2, m2, v2 = adamw_update(p, g, mi, vi, step, lr, wd)
            new_t.append(p2)
            new_m.append(m2)
            new_v.append(v2)
        return tuple([loss] + new_t + new_m + new_v)

    return step_fn


def make_eval_step(logits_fn, part: Partition, n_extras: int):
    """(frozen..., train..., extras..., batch...) -> (logits,)."""
    n_froz = len(part.frozen_names)
    n_train = len(part.trainable_names)

    def eval_fn(*args):
        i = 0
        frozen = list(args[i: i + n_froz]); i += n_froz
        train = list(args[i: i + n_train]); i += n_train
        extras = tuple(args[i: i + n_extras]); i += n_extras
        batch = args[i:]
        tree = part.merge(frozen, train)
        logits = logits_fn(tree, extras, *batch)
        # keep every extra alive in the lowered signature even when the
        # logits path ignores it (e.g. task_kind only affects the loss):
        # jax prunes unused arguments at lowering, which would break the
        # fixed argument-count contract with rust/src/runtime/session.rs.
        if extras:
            keep = sum(jnp.asarray(e, jnp.float32) * 0.0 for e in extras)
            logits = logits + keep
        return (logits,)

    return eval_fn
