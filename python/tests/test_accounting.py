"""Accounting formulas vs actual pytrees + Table 1 regeneration."""
import jax
import numpy as np
import pytest

from compile.peft import make_method
from compile.quantum import accounting, tensor_networks


@pytest.mark.parametrize("n,m,k", [(16, 16, 2), (64, 32, 4), (128, 128, 1)])
def test_lora_count_matches_method(n, m, k):
    meth = make_method("lora", k=k)
    p = meth.init(jax.random.PRNGKey(0), n, m)
    actual = sum(a.size for a in jax.tree_util.tree_leaves(p))
    assert accounting.lora_params(n, m, k) == actual


@pytest.mark.parametrize("n,m,k", [(16, 16, 2), (64, 64, 4)])
def test_adalora_count(n, m, k):
    meth = make_method("adalora", k=k)
    p = meth.init(jax.random.PRNGKey(0), n, m)
    actual = sum(a.size for a in jax.tree_util.tree_leaves(p))
    assert accounting.adalora_params(n, m, k) == actual


@pytest.mark.parametrize("n,m,k,l", [(16, 16, 2, 1), (64, 64, 3, 1),
                                     (64, 64, 3, 2), (12, 20, 2, 1)])
def test_qpeft_pauli_count(n, m, k, l):
    meth = make_method("qpeft_pauli", k=k, n_layers=l)
    p = meth.init(jax.random.PRNGKey(0), n, m)
    actual = sum(a.size for a in jax.tree_util.tree_leaves(p))
    assert accounting.qpeft_pauli_params(n, m, k, l) == actual


@pytest.mark.parametrize("n,m,k", [(16, 16, 2), (64, 32, 4)])
def test_qpeft_taylor_count(n, m, k):
    meth = make_method("qpeft_taylor", k=k)
    p = meth.init(jax.random.PRNGKey(0), n, m)
    actual = sum(a.size for a in jax.tree_util.tree_leaves(p))
    assert accounting.qpeft_taylor_params(n, m, k) == actual


@pytest.mark.parametrize("net", tensor_networks.NETWORKS)
def test_tensor_network_counts(net):
    n, m, k = 24, 16, 4
    p = tensor_networks.init_params(jax.random.PRNGKey(0), net, n, m, k)
    actual = sum(int(np.prod(a.shape)) for a in p.values())
    assert tensor_networks.num_params(net, n, m, k) == actual


def test_table1_lora_matches_paper_exactly():
    """Paper Table 1 LoRA column (DeBERTa 36.9K/589.8K/9437.2K at
    K=1/16/256; Llama 8.26M at K=1) — analytic, must match."""
    rows = {(r["model"], r["rank"]): r for r in accounting.table1()}
    assert rows[("deberta-v3-base", 1)]["lora_params"] == 36_864
    assert rows[("deberta-v3-base", 16)]["lora_params"] == 589_824
    assert rows[("deberta-v3-base", 256)]["lora_params"] == 9_437_184
    assert abs(rows[("llama-3.1-405b", 1)]["lora_params"] - 8.26e6) < 1e4


def test_table1_qpeft_orders_of_magnitude_smaller():
    for r in accounting.table1():
        if r["rank"] >= 16:
            assert r["qpeft_params"] * 10 < r["lora_params"], r


def test_qpeft_scaling_is_sublinear_lora_is_linear():
    l1 = accounting.lora_params(1024, 1024, 8)
    l2 = accounting.lora_params(4096, 4096, 8)
    q1 = accounting.qpeft_pauli_params(1024, 1024, 8)
    q2 = accounting.qpeft_pauli_params(4096, 4096, 8)
    assert l2 / l1 == 4.0              # linear in N
    assert q2 / q1 < 1.5               # logarithmic in N


def test_memory_ratio_structure_table4():
    """Optimizer-state memory ~ 3x trainable params (AdamW m, v + grads);
    LoRA vs Quantum-PEFT ratio at GPT2-Medium-like dims (d=1024, 24x2
    sites, K=4) should exceed the paper's observed 4x."""
    lora = 48 * accounting.lora_params(1024, 1024, 4)
    qp = 48 * accounting.qpeft_taylor_params(1024, 1024, 2, k_prime=1)
    assert lora / qp >= 4.0   # exactly 4.0 at these dims, matching Table 4
