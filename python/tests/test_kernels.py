"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Hypothesis sweeps shapes and seeds; every kernel must match ref.py to
f32 tolerance and its custom_vjp gradients must match autodiff through
the reference.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantum import pauli
from compile.kernels import ref
from compile.kernels.pauli_kernel import make_pauli_apply
from compile.kernels.taylor_kernel import make_taylor_apply
from compile.kernels.adapter_kernel import make_adapter_apply

RNG = np.random.default_rng(7)


def _f32(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------- pauli ---

@pytest.mark.parametrize("q,l,b", [(2, 1, 4), (3, 1, 17), (4, 2, 128),
                                   (5, 1, 130), (6, 1, 3)])
def test_pauli_kernel_matches_ref(q, l, b):
    circ = pauli.build(q, l)
    f = make_pauli_apply(circ)
    x = _f32(b, circ.dim)
    th = 0.5 * _f32(circ.num_params)
    np.testing.assert_allclose(np.asarray(f(x, th)),
                               np.asarray(ref.pauli_apply(x, th, circ)),
                               atol=1e-5)


def test_pauli_kernel_grads_match_ref():
    circ = pauli.build(4, 2)
    f = make_pauli_apply(circ)
    x = _f32(10, 16)
    th = 0.5 * _f32(circ.num_params)

    def loss_k(t, xx):
        return jnp.sum(f(xx, t) ** 3)

    def loss_r(t, xx):
        return jnp.sum(ref.pauli_apply(xx, t, circ) ** 3)

    gk = jax.grad(loss_k, argnums=(0, 1))(th, x)
    gr = jax.grad(loss_r, argnums=(0, 1))(th, x)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gr[0]), atol=1e-3)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gr[1]), atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(q=st.integers(2, 5), l=st.integers(1, 2), b=st.integers(1, 40),
       seed=st.integers(0, 99))
def test_pauli_kernel_property(q, l, b, seed):
    circ = pauli.build(q, l)
    f = make_pauli_apply(circ)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, circ.dim)).astype(np.float32))
    th = jnp.asarray(rng.normal(0, 0.6, circ.num_params).astype(np.float32))
    np.testing.assert_allclose(np.asarray(f(x, th)),
                               np.asarray(ref.pauli_apply(x, th, circ)),
                               atol=1e-4)


def test_pauli_kernel_preserves_norm():
    """Orthogonal apply preserves row norms — structural invariant."""
    circ = pauli.build(5, 1)
    f = make_pauli_apply(circ)
    x = _f32(8, 32)
    y = f(x, 0.5 * _f32(circ.num_params))
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=1),
                               np.linalg.norm(np.asarray(x), axis=1),
                               rtol=1e-4)


# --------------------------------------------------------------- taylor ---

@pytest.mark.parametrize("n,k,order,b", [(8, 2, 4, 5), (32, 4, 8, 64),
                                         (64, 8, 8, 129), (16, 16, 3, 2)])
def test_taylor_kernel_matches_ref(n, k, order, b):
    f = make_taylor_apply(order)
    x = _f32(b, n)
    bk = 0.2 * _f32(n, k)
    np.testing.assert_allclose(np.asarray(f(x, bk)),
                               np.asarray(ref.taylor_apply(x, bk, order)),
                               atol=1e-5)


def test_taylor_kernel_grads_match_ref():
    f = make_taylor_apply(6)
    x = _f32(7, 16)
    bk = 0.2 * _f32(16, 4)
    gk = jax.grad(lambda b: jnp.sum(jnp.tanh(f(x, b))))(bk)
    gr = jax.grad(lambda b: jnp.sum(jnp.tanh(ref.taylor_apply(x, b, 6))))(bk)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-4)


def test_taylor_transpose_identity():
    """f(x, -B) == x @ Q_T^T: the exact-transpose trick the adapter's
    V^T-side apply relies on (quantum_peft.py)."""
    n, k, order = 16, 4, 10
    f = make_taylor_apply(order)
    x = _f32(5, n)
    bk = 0.15 * _f32(n, k)
    q = np.asarray(ref.taylor_apply(jnp.eye(n), bk, order))
    np.testing.assert_allclose(np.asarray(f(x, -bk)),
                               np.asarray(x) @ q.T, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([8, 16, 32]), k=st.integers(1, 6),
       order=st.integers(1, 10), b=st.integers(1, 30), seed=st.integers(0, 99))
def test_taylor_kernel_property(n, k, order, b, seed):
    f = make_taylor_apply(order)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
    bk = jnp.asarray(0.2 * rng.normal(size=(n, k)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(f(x, bk)),
                               np.asarray(ref.taylor_apply(x, bk, order)),
                               atol=1e-4)


# -------------------------------------------------------------- adapter ---

@pytest.mark.parametrize("b,n,m,k", [(4, 8, 8, 2), (33, 64, 32, 4),
                                     (128, 16, 48, 1)])
def test_adapter_kernel_matches_ref(b, n, m, k):
    f = make_adapter_apply()
    x, w, u, v = _f32(b, n), _f32(n, m), _f32(n, k), _f32(m, k)
    lam = _f32(k)
    np.testing.assert_allclose(
        np.asarray(f(x, w, u, lam, v, jnp.float32(1.7))),
        np.asarray(ref.adapter_apply(x, w, u, lam, v, 1.7)), atol=1e-4)


def test_adapter_kernel_zero_lam_is_base_matmul():
    """lam = 0 => adapter contributes nothing (the Delta-W = 0 init)."""
    f = make_adapter_apply()
    x, w, u, v = _f32(6, 16), _f32(16, 16), _f32(16, 3), _f32(16, 3)
    y = f(x, w, u, jnp.zeros(3), v, jnp.float32(8.0))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-5)


def test_adapter_kernel_grads():
    f = make_adapter_apply()
    x, w, u, v = _f32(5, 8), _f32(8, 8), _f32(8, 2), _f32(8, 2)
    lam = _f32(2)

    def lk(args):
        return jnp.sum(f(x, w, *args, jnp.float32(1.0)) ** 2)

    def lr(args):
        return jnp.sum(ref.adapter_apply(x, w, *args, 1.0) ** 2)

    gk = jax.grad(lk)((u, lam, v))
    gr = jax.grad(lr)((u, lam, v))
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-3)
