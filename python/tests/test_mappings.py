"""Lie-algebra mappings (A.1): unitarity, Stiefel frames, K' masking."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantum import mappings

EXACT = ("exp", "cayley", "householder", "givens")
APPROX = ("taylor", "neumann")


def _theta(n, k, seed=0, scale=0.3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(
        0, scale, mappings.lower_params_count(n, k)).astype(np.float32))


def test_lower_params_count():
    # full lower triangle when k >= n-1
    assert mappings.lower_params_count(5, 4) == 10
    assert mappings.lower_params_count(5, 10) == 10
    assert mappings.lower_params_count(6, 2) == 5 + 4
    assert mappings.lower_params_count(1, 1) == 0


def test_params_to_lower_roundtrip():
    n, k = 6, 3
    th = _theta(n, k)
    bk = np.asarray(mappings.params_to_lower(th, n, k))
    assert bk.shape == (n, k)
    assert np.allclose(np.triu(bk), 0)          # strictly lower
    # every parameter lands somewhere exactly once
    assert np.count_nonzero(bk) == mappings.lower_params_count(n, k)


def test_skew_from_factor_is_skew():
    n, k = 8, 3
    bk = mappings.params_to_lower(_theta(n, k), n, k)
    a = np.asarray(mappings.skew_from_factor(bk, n))
    np.testing.assert_allclose(a, -a.T, atol=0)


@pytest.mark.parametrize("method", EXACT)
@pytest.mark.parametrize("n,k", [(8, 2), (16, 4), (12, 3)])
def test_exact_mappings_are_orthogonal(method, n, k):
    u = np.asarray(mappings.orthogonal(_theta(n, k), n, k, method))
    np.testing.assert_allclose(u.T @ u, np.eye(k), atol=1e-5)


@pytest.mark.parametrize("method", APPROX)
def test_approx_mappings_converge_with_order(method):
    # small scale keeps ||A|| < 1 so the Neumann series converges (A.1)
    n, k = 16, 4
    th = _theta(n, k, scale=0.1)
    errs = []
    for order in (2, 6, 16):
        u = np.asarray(mappings.orthogonal(th, n, k, method, order=order))
        errs.append(np.abs(u.T @ u - np.eye(k)).max())
    assert errs[2] < errs[0]
    assert errs[2] < 1e-4


def test_taylor_matches_exp_at_high_order():
    n, k = 10, 3
    th = _theta(n, k, scale=0.2)
    qt = np.asarray(mappings.orthogonal(th, n, k, "taylor", order=20))
    qe = np.asarray(mappings.orthogonal(th, n, k, "exp"))
    np.testing.assert_allclose(qt, qe, atol=1e-5)


def test_taylor_apply_matches_materialized():
    n, k, order = 12, 4, 8
    th = _theta(n, k)
    bk = mappings.params_to_lower(th, n, k)
    a = mappings.skew_from_factor(bk, n)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(5, n)).astype(np.float32))
    y = np.asarray(mappings.q_taylor_apply(a, x, order))
    q = np.asarray(mappings.q_taylor(a, order))
    np.testing.assert_allclose(y, np.asarray(x) @ q, atol=1e-5)


def test_intrinsic_mask_zeroes_columns():
    m = np.asarray(mappings.intrinsic_mask(6, 4, 2))
    assert m.shape == (6, 4)
    np.testing.assert_array_equal(m[:, :2], 1.0)
    np.testing.assert_array_equal(m[:, 2:], 0.0)


def test_intrinsic_rank_reduces_effective_params():
    """Masked columns must not affect the output (Table 8 mechanics)."""
    n, k = 10, 4
    th = _theta(n, k)
    u_full = mappings.orthogonal(th, n, k, "taylor", k_prime=4)
    u_kp1 = mappings.orthogonal(th, n, k, "taylor", k_prime=1)
    # zeroing all but col 0 of B must equal using only col-0 params
    th0 = np.array(mappings.params_to_lower(th, n, k))  # writable copy
    th0[:, 1:] = 0.0
    bk0 = jnp.asarray(th0)
    q = mappings.q_taylor(mappings.skew_from_factor(bk0, n), 8)[:, :k]
    np.testing.assert_allclose(np.asarray(u_kp1), np.asarray(q), atol=1e-6)
    assert np.abs(np.asarray(u_full) - np.asarray(u_kp1)).max() > 1e-4


def test_unitarity_error_metric():
    q = jnp.eye(5, dtype=jnp.float32)
    assert float(mappings.unitarity_error(q)) == 0.0


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([4, 8, 12, 16]), k=st.integers(1, 4),
       seed=st.integers(0, 1000))
def test_cayley_orthogonal_property(n, k, seed):
    u = np.asarray(mappings.orthogonal(_theta(n, k, seed), n, k, "cayley"))
    assert np.abs(u.T @ u - np.eye(k)).max() < 1e-4


def test_gradients_flow():
    n, k = 8, 2
    th = _theta(n, k)

    def f(t):
        u = mappings.orthogonal(t, n, k, "taylor")
        return jnp.sum(u ** 2 * jnp.arange(k, dtype=jnp.float32))

    g = np.asarray(jax.grad(f)(th))
    assert np.any(g != 0)
