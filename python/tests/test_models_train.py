"""Models + train-step machinery: shapes, loss finiteness, trainability,
loss decreases under the fused AdamW step, diagonal/tensor-network nodes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, train as T
from compile.models import decoder as dec
from compile.models import transformer as enc
from compile.models import vit as vit_mod
from compile.peft import make_method
from compile.quantum import diagonal

CFG = enc.EncoderConfig(vocab=64, d=16, n_heads=2, n_layers=2, ff=32,
                        seq_len=8, n_out=2)


def _tree(method, task="cls"):
    spec = dict(model="encoder", cfg=CFG, task=task, extras=("task_kind",),
                method=method.name, method_kw={})
    return aot.build_tree(spec, jax.random.PRNGKey(0), method)


def test_encoder_shapes():
    m = make_method("lora", k=2)
    tree = _tree(m)
    toks = jnp.ones((3, 8), dtype=jnp.int32)
    lg = enc.cls_logits(tree["base"], tree.get("adapters", {}),
                        {"cls": tree["head"]}, toks, CFG, m)
    assert lg.shape == (3, 2)


def test_encoder_loss_ce_vs_mse_selector():
    m = make_method("lora", k=2)
    tree = _tree(m)
    toks = jnp.ones((4, 8), dtype=jnp.int32)
    labels = jnp.asarray([0.0, 1.0, 0.0, 1.0])
    ce = enc.cls_loss(tree["base"], tree.get("adapters", {}),
                      {"cls": tree["head"]}, toks, labels, 0.0, CFG, m)
    mse = enc.cls_loss(tree["base"], tree.get("adapters", {}),
                       {"cls": tree["head"]}, toks, labels, 1.0, CFG, m)
    assert np.isfinite(float(ce)) and np.isfinite(float(mse))
    assert float(ce) != float(mse)


def test_decoder_causality():
    """Changing a future token must not change past logits."""
    cfg = dec.DecoderConfig(vocab=32, d=16, n_heads=2, n_layers=1, ff=32,
                            seq_len=8)
    m = make_method("ft")
    key = jax.random.PRNGKey(0)
    base = dec.init_base(key, cfg)
    head = dec.init_heads(key, cfg)["lm"]
    t1 = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], dtype=jnp.int32)
    t2 = t1.at[0, 6].set(9)
    l1 = dec.lm_logits(base, {}, {"lm": head}, t1, cfg, m)
    l2 = dec.lm_logits(base, {}, {"lm": head}, t2, cfg, m)
    np.testing.assert_allclose(np.asarray(l1[0, :6]), np.asarray(l2[0, :6]),
                               atol=1e-5)


def test_vit_patchify_roundtrip_size():
    cfg = vit_mod.ViTConfig(image=16, patch=4, d=16, n_heads=2, n_layers=1,
                            ff=32, n_out=4)
    imgs = jnp.ones((2, 16, 16, 3))
    p = vit_mod.patchify(imgs, cfg)
    assert p.shape == (2, 16, 48)


def test_vit_forward_finite():
    cfg = vit_mod.ViTConfig(image=16, patch=4, d=16, n_heads=2, n_layers=1,
                            ff=32, n_out=4)
    m = make_method("qpeft_pauli", k=1, n_layers=1)
    key = jax.random.PRNGKey(0)
    base = vit_mod.init_base(key, cfg)
    head = vit_mod.init_heads(key, cfg)["cls"]
    ad = vit_mod.init_adapters(key, cfg, m)
    lg = vit_mod.logits(base, ad, {"cls": head},
                        jnp.ones((2, 16, 16, 3)), cfg, m)
    assert lg.shape == (2, 4) and np.all(np.isfinite(np.asarray(lg)))


# ----------------------------------------------------------- partition ---

@pytest.mark.parametrize("name,kw", [("lora", dict(k=2)), ("bitfit", {}),
                                     ("ft", {}), ("qpeft_pauli",
                                                  dict(k=2, n_layers=1))])
def test_partition_trainability(name, kw):
    m = make_method(name, **kw)
    tree = _tree(m)
    part = T.make_partition(tree, m)
    tn = part.trainable_names
    assert any(n.startswith("head") for n in tn)
    if name == "ft":
        assert len(part.frozen_names) == 0
    elif name == "bitfit":
        assert all(n.startswith("head") or n.endswith(".b") for n in tn)
        assert not any(n.startswith("adapters") for n in tn)
    else:
        assert all(n.startswith(("adapters", "head")) for n in tn)
        assert all(n.startswith("base") for n in part.frozen_names)


def test_partition_merge_roundtrip():
    m = make_method("lora", k=2)
    tree = _tree(m)
    part = T.make_partition(tree, m)
    fz, tr = part.split(tree)
    merged = part.merge(fz, tr)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_step_decreases_loss():
    """20 fused AdamW steps on a fixed batch must reduce the loss — the
    end-to-end L2 training-graph signal."""
    m = make_method("lora", k=2)
    tree = _tree(m)
    part = T.make_partition(tree, m)
    spec = dict(model="encoder", cfg=CFG, task="cls", extras=("task_kind",),
                method="lora", method_kw={})
    loss_fn, _ = aot.make_loss_and_logits(spec, m)
    step = jax.jit(T.make_train_step(loss_fn, part, 1))
    fz, tr = part.split(tree)
    mm = [jnp.zeros_like(l) for l in tr]
    vv = [jnp.zeros_like(l) for l in tr]
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, 64, (8, 8)), dtype=jnp.int32)
    labels = jnp.asarray(rng.integers(0, 2, (8,)).astype(np.float32))
    losses = []
    for i in range(20):
        out = step(*fz, *tr, *mm, *vv, jnp.float32(i + 1),
                   jnp.float32(5e-2), jnp.float32(0.0), jnp.float32(0.0),
                   toks, labels)
        losses.append(float(out[0]))
        nt = len(tr)
        tr = list(out[1: 1 + nt])
        mm = list(out[1 + nt: 1 + 2 * nt])
        vv = list(out[1 + 2 * nt: 1 + 3 * nt])
    assert losses[-1] < losses[0]


def test_adamw_update_math():
    p = jnp.asarray(1.0)
    g = jnp.asarray(0.5)
    m0 = jnp.asarray(0.0)
    v0 = jnp.asarray(0.0)
    p1, m1, v1 = T.adamw_update(p, g, m0, v0, 1.0, 0.1, 0.0)
    # bias-corrected first step: update ~ lr * sign(g)
    np.testing.assert_allclose(float(p1), 1.0 - 0.1, atol=1e-3)
    assert float(m1) > 0 and float(v1) > 0


# ------------------------------------------------------------- diagonal ---

def test_reinmax_forward_is_sign():
    lam = jnp.asarray([0.3, -0.7, 0.0, 2.0])
    s = np.asarray(diagonal.rademacher_reinmax(lam))
    np.testing.assert_array_equal(s, [1.0, -1.0, 1.0, 1.0])


def test_reinmax_has_gradient():
    g = jax.grad(lambda l: jnp.sum(
        diagonal.rademacher_reinmax(l) * jnp.asarray([1.0, 2.0])))(
        jnp.asarray([0.3, -0.4]))
    assert np.any(np.asarray(g) != 0)


def test_gumbel_signs_are_binary():
    s = np.asarray(diagonal.rademacher_gumbel(
        jnp.zeros(16), jax.random.PRNGKey(0)))
    # straight-through forward: |s| == 1 up to one f32 ulp of the surrogate
    np.testing.assert_allclose(np.abs(s), 1.0, atol=1e-5)
