"""Pauli parameterization Q_P (eq. 2): structure, orthogonality, scaling."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantum import gates, pauli


def _rand_angles(circ, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 0.7, circ.num_params).astype(np.float32))


@pytest.mark.parametrize("q,l", [(1, 0), (1, 1), (2, 1), (3, 1), (3, 2),
                                 (4, 1), (5, 2), (6, 1), (7, 1)])
def test_param_count_formula(q, l):
    """(2L+1) log2(N) - 2L of §4.1 (q >= 2; q = 1 degenerates to 1 angle)."""
    circ = pauli.build(q, l)
    if q == 1:
        assert circ.num_params == 1
    else:
        assert circ.num_params == (2 * l + 1) * q - 2 * l
        assert circ.num_params == pauli.num_params(1 << q, l)


@pytest.mark.parametrize("q,l", [(2, 1), (3, 1), (4, 2), (5, 1), (6, 3)])
def test_orthogonality(q, l):
    circ = pauli.build(q, l)
    m = np.asarray(circ.materialize(_rand_angles(circ)))
    np.testing.assert_allclose(m @ m.T, np.eye(circ.dim), atol=1e-5)


@pytest.mark.parametrize("q,l", [(3, 1), (4, 1), (5, 2)])
def test_full_rank(q, l):
    """Q_P has full effective rank N despite tensor rank 2 (§4.1)."""
    circ = pauli.build(q, l)
    m = np.asarray(circ.materialize(_rand_angles(circ, seed=3)))
    s = np.linalg.svd(m, compute_uv=False)
    assert s.min() > 0.99  # orthogonal: all singular values are 1


@pytest.mark.parametrize("q,l", [(2, 1), (4, 2), (5, 1)])
def test_apply_matches_materialize(q, l):
    circ = pauli.build(q, l)
    th = _rand_angles(circ, seed=1)
    x = np.random.default_rng(1).normal(size=(9, circ.dim)).astype(np.float32)
    y = np.asarray(circ.apply(jnp.asarray(x), th))
    np.testing.assert_allclose(y, x @ np.asarray(circ.materialize(th)),
                               atol=1e-5)


@pytest.mark.parametrize("q,l", [(3, 1), (4, 2)])
def test_apply_t_is_transpose(q, l):
    circ = pauli.build(q, l)
    th = _rand_angles(circ, seed=2)
    x = np.random.default_rng(2).normal(size=(4, circ.dim)).astype(np.float32)
    yt = np.asarray(circ.apply_t(jnp.asarray(x), th))
    np.testing.assert_allclose(
        yt, x @ np.asarray(circ.materialize(th)).T, atol=1e-5)


@pytest.mark.parametrize("q,l", [(1, 0), (2, 0), (3, 1), (4, 2), (6, 1)])
def test_materialize_kron_equals_layered(q, l):
    """The compact Kronecker-chain product (the AOT model path, §Perf L2)
    must equal the layered apply exactly."""
    circ = pauli.build(q, l)
    th = _rand_angles(circ, seed=5)
    a = np.asarray(circ.materialize(th))
    b = np.asarray(circ.materialize_kron(th))
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_identity_at_zero_angles():
    circ = pauli.build(4, 2)
    m = np.asarray(circ.materialize(jnp.zeros(circ.num_params)))
    # CZ sign layers act even at zero rotation; composing layer signs gives
    # a diagonal +-1 matrix, i.e. |M| == I.
    np.testing.assert_allclose(np.abs(m), np.eye(16), atol=1e-6)


def test_stiefel_columns():
    circ = pauli.build(5, 1)
    u = np.asarray(circ.columns(_rand_angles(circ), 4))
    assert u.shape == (32, 4)
    np.testing.assert_allclose(u.T @ u, np.eye(4), atol=1e-5)


def test_gradients_flow_to_all_angles():
    circ = pauli.build(3, 2)
    x = jnp.ones((2, 8), dtype=jnp.float32)

    def f(th):
        return jnp.sum(circ.apply(x, th) ** 2 * jnp.arange(8.0))

    g = np.asarray(jax.grad(f)(_rand_angles(circ)))
    assert np.count_nonzero(g) == circ.num_params


@settings(max_examples=20, deadline=None)
@given(q=st.integers(2, 6), l=st.integers(0, 3), seed=st.integers(0, 2**16))
def test_orthogonality_property(q, l, seed):
    """Hypothesis: every (q, L, angles) circuit is orthogonal."""
    circ = pauli.build(q, l)
    m = np.asarray(circ.materialize(_rand_angles(circ, seed)))
    assert np.abs(m @ m.T - np.eye(circ.dim)).max() < 1e-4


def test_cz_sign_vector():
    s = gates.cz_sign_vector(2, [(0, 1)])
    np.testing.assert_array_equal(s, [1, 1, 1, -1])
    # disjoint pairs compose multiplicatively
    s2 = gates.cz_sign_vector(4, [(0, 1), (2, 3)])
    assert s2[0b1111] == 1.0 and s2[0b0011] == -1.0 and s2[0b1100] == -1.0


def test_adjacent_pairs():
    assert gates.adjacent_pairs([0, 1, 2, 3, 4]) == [(0, 1), (2, 3)]
    assert gates.adjacent_pairs([1]) == []
