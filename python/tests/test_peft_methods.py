"""PEFT methods: shapes, zero-init Delta-W, apply == W + Delta-W, counts."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.peft import ALL_METHODS, make_method

N, M = 16, 32
PER_WEIGHT = [m for m in ALL_METHODS
              if m not in ("ft", "bitfit", "hadapter", "padapter")]


def _method(name):
    kw = {}
    if name in ("lora", "adalora", "loha", "lokr", "mora", "qpeft_taylor"):
        kw = dict(k=4)
    if name == "qpeft_pauli":
        kw = dict(k=3, n_layers=1)
    if name == "qpeft_tn":
        kw = dict(network="ttd", k=4)
    return make_method(name, **kw)


@pytest.mark.parametrize("name", PER_WEIGHT)
def test_init_and_count(name):
    m = _method(name)
    p = m.init(jax.random.PRNGKey(0), N, M)
    actual = sum(int(np.prod(a.shape)) for a in jax.tree_util.tree_leaves(p))
    assert actual == m.num_params(N, M), f"{name}: count formula mismatch"


@pytest.mark.parametrize("name", PER_WEIGHT)
def test_delta_w_zero_at_init(name):
    """Every method must start at Delta-W = 0 (fine-tuning identity init)."""
    m = _method(name)
    p = m.init(jax.random.PRNGKey(1), N, M)
    dw = np.asarray(m.delta_w(p, N, M))
    np.testing.assert_allclose(dw, 0.0, atol=1e-6)


@pytest.mark.parametrize("name", PER_WEIGHT)
def test_apply_consistent_with_delta(name):
    """y = x(W + Delta-W) must hold for the fused/apply path."""
    m = _method(name)
    key = jax.random.PRNGKey(2)
    p = m.init(key, N, M)
    # push adapters off the zero init so the test is non-trivial
    p = jax.tree_util.tree_map(
        lambda a: a + 0.1 * jax.random.normal(key, a.shape, a.dtype), p)
    x = jax.random.normal(jax.random.PRNGKey(3), (9, N), dtype=jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (N, M), dtype=jnp.float32)
    y = np.asarray(m.apply(p, x, w))
    y_ref = np.asarray(x @ (w + m.delta_w(p, N, M)))
    np.testing.assert_allclose(y, y_ref, atol=2e-3)


def test_qpeft_pauli_fewer_params_than_lora_rank1():
    """The paper's headline: Pauli Quantum-PEFT beats even rank-1 LoRA."""
    big_n = 256
    qp = make_method("qpeft_pauli", k=3, n_layers=1)
    lora1 = make_method("lora", k=1)
    assert qp.num_params(big_n, big_n) < lora1.num_params(big_n, big_n)


def test_qpeft_pauli_log_scaling():
    qp = make_method("qpeft_pauli", k=3, n_layers=1)
    p64 = qp.num_params(64, 64)
    p1024 = qp.num_params(1024, 1024)
    # 16x the dimension, well under 2x the parameters
    assert p1024 < 2 * p64


def test_qpeft_taylor_param_formula():
    """2NK - K^2 at N'=N, K'=K and square N=M (§4.2): our count is the
    strictly-lower-triangle version (exact, not the paper's big-O)."""
    qt = make_method("qpeft_taylor", k=4)
    n = 32
    from compile.quantum.mappings import lower_params_count

    assert qt.num_params(n, n) == 2 * lower_params_count(n, 4) + 4


def test_adalora_orth_regularizer_decreases_for_orthogonal():
    m = make_method("adalora", k=4)
    p_orth = {"u": jnp.eye(N, 4), "v": jnp.eye(M, 4),
              "lam": jnp.zeros(4)}
    key = jax.random.PRNGKey(5)
    p_rand = {"u": jax.random.normal(key, (N, 4)),
              "v": jax.random.normal(key, (M, 4)), "lam": jnp.zeros(4)}
    assert float(m.extra_loss(p_orth)) < float(m.extra_loss(p_rand))


def test_bitfit_marks_biases():
    m = make_method("bitfit")
    assert m.bias_trainable and not m.base_trainable
    assert m.init(jax.random.PRNGKey(0), N, M) == {}


def test_bottleneck_adapters():
    for style, sites in (("hadapter", 2), ("padapter", 1)):
        m = make_method(style, bottleneck=4)
        p = m.init_bottleneck(jax.random.PRNGKey(0), 16)
        h = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 16))
        out = m.bottleneck_apply(p, h)
        assert out.shape == h.shape
        # zero-init up-projection => identity at start
        np.testing.assert_allclose(np.asarray(out), np.asarray(h), atol=1e-6)
        assert m.bottleneck_params(16) == 2 * 16 * 4


def test_lokr_kron_structure():
    m = make_method("lokr", k=2, f=4)
    p = m.init(jax.random.PRNGKey(0), 16, 32)
    assert p["c"].shape == (4, 4)
    assert p["b"].shape == (4, 2) and p["a"].shape == (8, 2)


def test_mora_square_matrix():
    m = make_method("mora", k=4)
    p = m.init(jax.random.PRNGKey(0), N, M)
    import math

    kh = math.isqrt((N + M) * 4)
    assert p["m"].shape == (kh, kh)
