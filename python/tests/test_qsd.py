"""Quantum Shannon decomposition (eq. 4): arbitrary-dimension unitaries."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantum import pauli, qsd


def _angles(node, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 0.5, node.num_params).astype(np.float32))


def test_split():
    assert qsd.split(12) == (8, 4)
    assert qsd.split(28) == (16, 12)
    assert qsd.split(257) == (256, 1)
    assert qsd.split(16) == (8, 8)  # power of two halves


def test_power_of_two_blocks_example_4_1():
    assert qsd.power_of_two_blocks(12) == [8, 4]
    assert qsd.power_of_two_blocks(28) == [16, 8, 4]
    assert qsd.power_of_two_blocks(257) == [256, 1]


@pytest.mark.parametrize("n", [2, 3, 5, 7, 10, 12, 28, 33])
def test_orthogonality_any_dim(n):
    node = qsd.build(n, 1)
    q = np.asarray(node.materialize(_angles(node)))
    np.testing.assert_allclose(q @ q.T, np.eye(n), atol=1e-5)


def test_pow2_leaf_is_pauli():
    node = qsd.build(16, 2)
    assert node.leaf is not None
    assert node.num_params == pauli.num_params(16, 2)


def test_recursion_structure_n12():
    """Example 4.1: N = 12 -> N1 = 8, N2 = 4, four power-of-two blocks."""
    node = qsd.build(12, 1)
    assert (node.n1, node.n2) == (8, 4)
    assert node.u1.leaf is not None and node.u2.leaf is not None
    assert node.v1.leaf is not None and node.v2.leaf is not None
    expected = (2 * pauli.num_params(8, 1) + 2 * pauli.num_params(4, 1) + 4)
    assert node.num_params == expected


def test_apply_matches_materialize():
    node = qsd.build(10, 1)
    th = _angles(node, seed=4)
    x = np.random.default_rng(4).normal(size=(6, 10)).astype(np.float32)
    y = np.asarray(node.apply(jnp.asarray(x), th))
    np.testing.assert_allclose(y, x @ np.asarray(node.materialize(th)),
                               atol=1e-5)


def test_columns_are_stiefel():
    node = qsd.build(12, 1)
    u = np.asarray(node.columns(_angles(node), 3))
    assert u.shape == (12, 3)
    np.testing.assert_allclose(u.T @ u, np.eye(3), atol=1e-5)


def test_param_scaling_sublinear():
    """QSD of power-of-two dims keeps the log scaling; CS couplings add
    the N2 angles the paper's eq. (4) requires."""
    p_256 = qsd.num_params(256, 1)
    p_4096 = qsd.num_params(4096, 1)
    assert p_4096 < 4 * p_256  # log-ish growth between pow2 leaves
    assert p_256 == pauli.num_params(256, 1)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 40), seed=st.integers(0, 500))
def test_orthogonality_property(n, seed):
    node = qsd.build(n, 1)
    q = np.asarray(node.materialize(_angles(node, seed)))
    assert np.abs(q @ q.T - np.eye(n)).max() < 1e-4
