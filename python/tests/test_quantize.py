"""Quantization & QAT (Table 7, Appendix A.5)."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.quantum import quantize


def _theta(n=300, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=n).astype(np.float32))


def test_quantize_exact_at_high_levels():
    th = _theta()
    q = quantize.quantize_groups(th, 2.0 ** 16 - 1, 128)
    np.testing.assert_allclose(np.asarray(q), np.asarray(th), atol=1e-3)


def test_quantize_error_shrinks_with_bits():
    th = _theta()
    errs = [float(jnp.abs(quantize.quantize_groups(th, 2.0 ** b - 1, 64)
                          - th).max()) for b in (1, 2, 4, 8)]
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < errs[0]


def test_quantize_respects_group_range():
    """Quantized values never leave the group's [min, max] interval."""
    th = _theta(256)
    q = np.asarray(quantize.quantize_groups(th, 3.0, 64)).reshape(4, 64)
    t = np.asarray(th).reshape(4, 64)
    for gq, gt in zip(q, t):
        assert gq.min() >= gt.min() - 1e-6
        assert gq.max() <= gt.max() + 1e-6


def test_fake_quant_straight_through_gradient():
    """QAT trick: forward quantized, backward identity."""
    th = _theta(64)
    g = jax.grad(lambda t: jnp.sum(quantize.fake_quant_st(t, 3.0, 32) * 2.0))(th)
    np.testing.assert_allclose(np.asarray(g), 2.0, atol=1e-6)


def test_fake_quant_forward_is_quantized():
    th = _theta(64)
    f = quantize.fake_quant_st(th, 1.0, 64)  # 1 level: endpoints only
    uniq = np.unique(np.round(np.asarray(f), 5))
    assert len(uniq) <= 2


def test_adaptive_bit_loading_prunes_flat_groups():
    """A flat group (tiny dynamic range) gets ~0 bits -> pruned to its
    zero point; a wide group keeps fidelity (A.5's structural pruning)."""
    flat = 1e-6 * np.ones(32, np.float32) + 0.5
    wide = np.random.default_rng(0).normal(0, 5, 32).astype(np.float32)
    th = jnp.asarray(np.concatenate([flat, wide]))
    out = np.asarray(quantize.adaptive_bit_loading(th, 3.0, 32))
    # wide group should track its values much better than 1-bit uniform
    uni = np.asarray(quantize.fake_quant_st(th, 1.0, 32))
    err_ada = np.abs(out[32:] - np.asarray(th)[32:]).mean()
    err_uni = np.abs(uni[32:] - np.asarray(th)[32:]).mean()
    assert err_ada < err_uni


def test_adaptive_gradient_is_straight_through():
    th = _theta(96)
    g = jax.grad(lambda t: jnp.sum(quantize.adaptive_bit_loading(t, 2.0, 32)))(th)
    np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)


def test_storage_bits_formula():
    assert quantize.storage_bits_per_param(4, 128) == 4 + 32 / 128
    assert quantize.storage_bits_per_param(1, 128) == 1.25  # Table 7 row


def test_base_weight_quantization_shape_preserved():
    w = jnp.asarray(np.random.default_rng(1).normal(
        size=(24, 16)).astype(np.float32))
    q = quantize.quantize_base_weights(w, 3, 64)
    assert q.shape == w.shape
    assert float(jnp.abs(q - w).max()) < float(jnp.abs(w).max())


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 400), g=st.sampled_from([16, 64, 128]),
       bits=st.integers(1, 8), seed=st.integers(0, 100))
def test_quantize_property_bounded_error(n, g, bits, seed):
    """|q - theta| <= group_range / levels for every element."""
    th = jnp.asarray(np.random.default_rng(seed).normal(
        size=n).astype(np.float32))
    levels = 2.0 ** bits - 1
    q = np.asarray(quantize.quantize_groups(th, levels, g))
    t = np.asarray(th)
    n_groups = -(-n // g)
    for i in range(n_groups):
        seg = slice(i * g, min((i + 1) * g, n))
        rng_ = t[seg].max() - t[seg].min()
        bound = rng_ / levels if rng_ > 0 else 1e-6
        assert np.abs(q[seg] - t[seg]).max() <= bound * 0.5 + 1e-5
