//! L3 hot-path benches: synthetic data generation and metric computation
//! (these run between every train step / after every eval in a sweep, so
//! the coordinator must not bottleneck the PJRT step — DESIGN.md §Perf L3).

use quantum_peft::data::{e2e::E2eData, glue, grammar::Grammar, images};
use quantum_peft::metrics::{classification as cls, ngram};
use quantum_peft::util::bench::{bench, black_box};
use quantum_peft::util::rng::Rng;

fn main() {
    println!("# L3 data + metrics throughput");
    let g = Grammar::new();

    bench("data/glue-batch-16x24 (sst2)", 300, || {
        let mut rng = Rng::new(1);
        let b: Vec<_> = (0..16)
            .map(|_| glue::example(&g, glue::Task::Sst2, &mut rng, 24))
            .collect();
        black_box(b);
    });

    bench("data/dae-pair-batch-16x24", 300, || {
        let mut rng = Rng::new(2);
        let b: Vec<_> = (0..16).map(|_| glue::dae_pair(&g, &mut rng, 24)).collect();
        black_box(b);
    });

    let d = E2eData::new();
    bench("data/e2e-batch-16x48", 300, || {
        let mut rng = Rng::new(3);
        let b: Vec<_> = (0..16).map(|_| d.training_example(&mut rng, 48)).collect();
        black_box(b);
    });

    bench("data/images-batch-16 (16x16x3)", 300, || {
        let mut rng = Rng::new(4);
        let b: Vec<_> = (0..16)
            .map(|_| images::render(&mut rng, images::PATTERNS[1], 2, 0.05))
            .collect();
        black_box(b);
    });

    // metric suite over a realistic corpus size (Table 3 eval)
    let mut rng = Rng::new(5);
    let cases: Vec<(Vec<u32>, Vec<Vec<u32>>)> = (0..96)
        .map(|_| {
            let mr = d.sample_mr(&mut rng);
            let refs = d.references(&mr);
            (refs[0].clone(), refs)
        })
        .collect();
    bench("metrics/bleu-96x3refs", 400, || {
        black_box(ngram::bleu(&cases, 4));
    });
    bench("metrics/nist-96x3refs", 400, || {
        black_box(ngram::nist(&cases, 5));
    });
    bench("metrics/cider-96x3refs", 400, || {
        black_box(ngram::cider(&cases));
    });
    bench("metrics/rouge-l-96x3refs", 400, || {
        black_box(ngram::rouge_l(&cases));
    });
    bench("metrics/meteor-96x3refs", 400, || {
        black_box(ngram::meteor(&cases));
    });

    let mut rng = Rng::new(6);
    let x: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
    bench("metrics/stsb-corr-256", 300, || {
        black_box(cls::stsb_corr(&x, &y));
    });
}
