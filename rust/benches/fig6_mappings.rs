//! Figure 6 bench: unitarity error and wall-clock of every unitary
//! mapping vs matrix size N (K = 4, P = 18) — the pure-Rust mirror of the
//! paper's RTX6000 comparison. Run: cargo bench --bench fig6_mappings

use quantum_peft::quantum::mappings::{self, Mapping};
use quantum_peft::quantum::pauli;
use quantum_peft::util::bench::{bench, black_box};
use quantum_peft::util::rng::Rng;

fn main() {
    println!("# Figure 6 — mapping speed (forward) and unitarity error");
    let sizes = [16usize, 64, 256, 1024];
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let th = mappings::random_theta(&mut rng, n, 4, 0.3);
        for m in Mapping::all(18) {
            // dense O(N^3) mappings get prohibitive on one core at large N
            // (the paper's figure shows exactly this blow-up) — keep the
            // bench under budget and report them up to N = 256
            if n > 256 && !matches!(m, Mapping::Taylor(_)) {
                continue;
            }
            let q = mappings::orthogonal(&th, n, 4, m);
            let err = q.unitarity_error();
            bench(&format!("fig6/N={n}/{}", m.name()), 300, || {
                black_box(mappings::orthogonal(&th, n, 4, m));
            });
            println!("  unitarity_error {:>12}: {err:.3e}", m.name());
        }
        // Pauli circuit: the O(N log N) apply path
        let qb = n.trailing_zeros() as usize;
        let circ = pauli::build(qb, 1);
        let tp: Vec<f32> = (0..circ.num_params)
            .map(|_| rng.normal() as f32 * 0.5).collect();
        let x0: Vec<f32> = (0..32 * n).map(|_| rng.normal() as f32).collect();
        bench(&format!("fig6/N={n}/pauli-apply(b=32)"), 300, || {
            let mut x = x0.clone();
            circ.apply(&mut x, 32, &tp);
            black_box(x);
        });
    }
}
