//! Literal-marshalling overhead: HostTensor <-> xla::Literal conversions
//! that run on every step (L3 §Perf — must stay well under the step's
//! compute time).

use quantum_peft::runtime::{tensors, HostTensor};
use quantum_peft::util::bench::{bench, black_box};
use quantum_peft::util::rng::Rng;

fn main() {
    println!("# HostTensor <-> Literal marshalling");
    let mut rng = Rng::new(1);

    // typical parameter tensor (64x64 f32)
    let w = HostTensor::f32(vec![64, 64],
                            (0..4096).map(|_| rng.normal() as f32).collect());
    bench("marshal/to_literal-64x64-f32", 300, || {
        black_box(w.to_literal().unwrap());
    });
    let lit = w.to_literal().unwrap();
    bench("marshal/from_literal-64x64-f32", 300, || {
        black_box(HostTensor::from_literal(&lit).unwrap());
    });

    // a full frozen set: 36 tensors of the encoder scale
    let frozen: Vec<HostTensor> = (0..36)
        .map(|_| HostTensor::f32(vec![64, 64],
                                 (0..4096).map(|_| rng.normal() as f32).collect()))
        .collect();
    bench("marshal/frozen-set-36x64x64", 400, || {
        let lits: Vec<_> = frozen.iter().map(|t| t.to_literal().unwrap()).collect();
        black_box(lits);
    });

    // batch assembly (the per-step data path)
    let rows: Vec<Vec<u32>> = (0..16)
        .map(|_| (0..24).map(|_| rng.below(200) as u32).collect())
        .collect();
    bench("marshal/stack-tokens-16x24", 300, || {
        black_box(tensors::stack_tokens(&rows));
    });
    let imgs: Vec<Vec<f32>> = (0..16)
        .map(|_| (0..768).map(|_| rng.normal() as f32).collect())
        .collect();
    bench("marshal/stack-images-16x768", 300, || {
        black_box(tensors::stack_f32(&imgs, &[16, 16, 3]));
    });
}
