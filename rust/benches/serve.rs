//! Multi-tenant serving benchmark: requests/sec and p99 latency across a
//! worker x tenant grid (the ISSUE-3 acceptance grid: 1/4/8 workers x
//! 1/16/256 tenants), plus the checkpoint bulk-I/O speedup measurement.
//!
//! Uses the in-tree harness conventions (criterion is unavailable
//! offline): self-contained, prints a stable one-line-per-cell report,
//! asserts nothing timing-dependent.

use std::time::Instant;

use quantum_peft::coordinator::checkpoint::{self, AdapterManifest};
use quantum_peft::coordinator::events::EventLog;
use quantum_peft::runtime::HostTensor;
use quantum_peft::serve::{BenchOpts, LoadSpec, PauliSpec};
use quantum_peft::util::bench::fmt_ns;

fn serve_grid() {
    println!("# serve: closed-loop seeded loadgen, q=5 L=1, zipf s=1.0");
    println!("{:>8} {:>8} {:>10} {:>12} {:>12} {:>12}",
             "workers", "tenants", "requests", "req/s", "p50", "p99");
    for &workers in &[1usize, 4, 8] {
        for &tenants in &[1usize, 16, 256] {
            let opts = BenchOpts {
                load: LoadSpec {
                    tenants,
                    requests: 2048,
                    concurrency: 64,
                    pauli: PauliSpec { q: 5, n_layers: 1 },
                    seed: 42,
                    zipf_s: 1.0,
                    open_rate_rps: 0.0,
                },
                serve: quantum_peft::serve::ServeConfig {
                    workers,
                    ..Default::default()
                },
                cache_bytes: 8 << 20,
            };
            match quantum_peft::serve::run_serve_bench(&opts, &EventLog::null()) {
                Ok((s, _)) => {
                    println!("{:>8} {:>8} {:>10} {:>12.0} {:>12} {:>12}",
                             workers, tenants, s.completed, s.rps,
                             fmt_ns(s.p50_us * 1e3), fmt_ns(s.p99_us * 1e3));
                }
                Err(e) => println!("{workers:>8} {tenants:>8} failed: {e}"),
            }
        }
    }
}

/// The satellite's evidence: bulk byte-slice checkpoint I/O vs the old
/// element-at-a-time reads. The writer is bulk-only now, so the
/// element-wise reference below re-implements the old read loop against
/// the same on-disk bytes.
fn checkpoint_io() {
    use std::io::Read as _;
    let dir = std::env::temp_dir().join("qp_serve_bench_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("big.qpck");
    let n = 1 << 20; // 1M f32 = 4 MiB payload
    let data: Vec<f32> = (0..n).map(|i| (i as f32 * 0.001).sin()).collect();
    let manifest = AdapterManifest { tenant: "bench".into(), q: 5, n_layers: 1 };
    let tensors = vec![("w".to_string(), HostTensor::f32(vec![n], data))];

    let t0 = Instant::now();
    checkpoint::save_adapter(&path, &manifest, &tensors).unwrap();
    let save_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let back = checkpoint::load(&path).unwrap();
    let load_s = t0.elapsed().as_secs_f64();
    assert_eq!(back[0].1, tensors[0].1, "roundtrip mismatch");

    // element-at-a-time reference: what load() did before the bulk-I/O
    // satellite — same file, same BufReader, one read_exact per element
    let t0 = Instant::now();
    let mut f = std::io::BufReader::new(std::fs::File::open(&path).unwrap());
    // header: magic 4 + version 4 + tenant_len 4 + "bench" 5 + q 4 + L 4
    // + count 4 + name_len 4 + "w" 1 + dtype 1 + ndim 4 + dim 8 = 47
    let mut skip = vec![0u8; 47];
    f.read_exact(&mut skip).unwrap();
    let mut out = vec![0f32; n];
    let mut u32buf = [0u8; 4];
    for x in out.iter_mut() {
        f.read_exact(&mut u32buf).unwrap();
        *x = f32::from_le_bytes(u32buf);
    }
    let slow_s = t0.elapsed().as_secs_f64();
    assert_eq!(out, *tensors[0].1.as_f32().unwrap(), "reference mismatch");

    let mb = (n * 4) as f64 / (1 << 20) as f64;
    println!("# checkpoint I/O, {mb:.0} MiB f32 payload");
    println!("save (bulk)          {:>10.1} MiB/s", mb / save_s);
    println!("load (bulk)          {:>10.1} MiB/s", mb / load_s);
    println!("load (element-wise)  {:>10.1} MiB/s", mb / slow_s);
    println!("bulk read speedup    {:>10.1}x", slow_s / load_s);
}

fn main() {
    checkpoint_io();
    serve_grid();
}
