//! Multi-tenant serving benchmark: requests/sec and p99 latency across a
//! worker x tenant grid (the ISSUE-3 acceptance grid: 1/4/8 workers x
//! 1/16/256 tenants), the checkpoint bulk-I/O speedup measurement, the
//! ISSUE-4 overload-shedding scenario (open loop at ~5x the admitted
//! budget: rejected share per worker count), the dense-vs-structured
//! apply-path comparison behind `STRUCTURED_APPLY_MIN_Q`, the ISSUE-5
//! durability lines: WAL append throughput per durability mode, and
//! recovery wall-clock for 256 tenants before vs after snapshot
//! compaction — and the ISSUE-6 shard-scaling grid (1/4/16 shards x
//! 256/4096 tenants, per-shard spread + fleet req/s).
//!
//! Uses the in-tree harness conventions (criterion is unavailable
//! offline): self-contained, prints a stable one-line-per-cell report,
//! asserts nothing timing-dependent. Every section also returns its
//! headline numbers as `(name, value)` counters, and `main` writes them
//! all to `BENCH_serve.json` (override the path with `BENCH_OUT`) so CI
//! can archive the run as a machine-readable artifact. `BENCH_CHEAP=1`
//! runs only the seconds-scale sections — the subset the CI bench job
//! executes on every push. The WAL and serving sections additionally
//! share one timed [`MetricsRegistry`]; its end-of-run snapshot lands
//! as `METRICS_serve.jsonl` + `.prom` (override with
//! `BENCH_METRICS_OUT`) — the same artifact a `--metrics-out` run of
//! `repro serve-bench` produces, archived next to the report.

use std::collections::BTreeMap;
use std::time::Instant;

use std::sync::Arc;

use quantum_peft::coordinator::checkpoint::{self, AdapterManifest};
use quantum_peft::coordinator::events::EventLog;
use quantum_peft::obs::{export, MetricsRegistry};
use quantum_peft::quantum::pauli;
use quantum_peft::runtime::HostTensor;
use quantum_peft::serve::registry::theta_checksum;
use quantum_peft::serve::scheduler::BatchPolicy;
use quantum_peft::serve::{
    AdmissionConfig, BenchOpts, LoadSpec, PauliSpec, ServeConfig,
};
use quantum_peft::store::{
    recover, Durability, StateRecord, StateStore, TenantState,
};
use quantum_peft::util::bench::fmt_ns;
use quantum_peft::util::json::{self, Json};
use quantum_peft::util::rng::Rng;

/// Headline numbers one section contributes to `BENCH_serve.json`.
type Counters = Vec<(String, f64)>;

fn serve_grid(reg: &Arc<MetricsRegistry>) -> Counters {
    let mut out = Counters::new();
    println!("# serve: closed-loop seeded loadgen, q=5 L=1, zipf s=1.0");
    println!("{:>8} {:>8} {:>10} {:>12} {:>12} {:>12}",
             "workers", "tenants", "requests", "req/s", "p50", "p99");
    for &workers in &[1usize, 4, 8] {
        for &tenants in &[1usize, 16, 256] {
            let opts = BenchOpts {
                load: LoadSpec {
                    tenants,
                    requests: 2048,
                    concurrency: 64,
                    pauli: PauliSpec { q: 5, n_layers: 1 },
                    seed: 42,
                    zipf_s: 1.0,
                    open_rate_rps: 0.0,
                },
                // timed mode: fifo latencies are logical (zero under a
                // closed loop), and this grid is about real wall time
                serve: ServeConfig {
                    workers,
                    fifo: false,
                    metrics: Some(reg.clone()),
                    ..ServeConfig::default()
                },
                cache_bytes: 8 << 20,
                ..BenchOpts::default()
            };
            match quantum_peft::serve::run_serve_bench(&opts, &EventLog::null()) {
                Ok((s, _)) => {
                    let q = |v: Option<f64>| {
                        v.map_or_else(|| "-".to_string(), |v| fmt_ns(v * 1e3))
                    };
                    println!("{:>8} {:>8} {:>10} {:>12.0} {:>12} {:>12}",
                             workers, tenants, s.completed, s.rps,
                             q(s.p50_us), q(s.p99_us));
                    out.push((format!("w{workers}_t{tenants}_rps"), s.rps));
                    out.push((format!("w{workers}_t{tenants}_p99_us"),
                              s.p99_us.unwrap_or(0.0)));
                }
                Err(e) => println!("{workers:>8} {tenants:>8} failed: {e}"),
            }
        }
    }
    out
}

/// The satellite's evidence: bulk byte-slice checkpoint I/O vs the old
/// element-at-a-time reads. The writer is bulk-only now, so the
/// element-wise reference below re-implements the old read loop against
/// the same on-disk bytes.
fn checkpoint_io() -> Counters {
    use std::io::Read as _;
    let dir = std::env::temp_dir().join("qp_serve_bench_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("big.qpck");
    let n = 1 << 20; // 1M f32 = 4 MiB payload
    let data: Vec<f32> = (0..n).map(|i| (i as f32 * 0.001).sin()).collect();
    let manifest = AdapterManifest { tenant: "bench".into(), q: 5, n_layers: 1 };
    let tensors = vec![("w".to_string(), HostTensor::f32(vec![n], data))];

    let t0 = Instant::now();
    checkpoint::save_adapter(&path, &manifest, &tensors).unwrap();
    let save_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let back = checkpoint::load(&path).unwrap();
    let load_s = t0.elapsed().as_secs_f64();
    assert_eq!(back[0].1, tensors[0].1, "roundtrip mismatch");

    // element-at-a-time reference: what load() did before the bulk-I/O
    // satellite — same file, same BufReader, one read_exact per element
    let t0 = Instant::now();
    let mut f = std::io::BufReader::new(std::fs::File::open(&path).unwrap());
    // header: magic 4 + version 4 + tenant_len 4 + "bench" 5 + q 4 + L 4
    // + count 4 + name_len 4 + "w" 1 + dtype 1 + ndim 4 + dim 8 = 47
    let mut skip = vec![0u8; 47];
    f.read_exact(&mut skip).unwrap();
    let mut out = vec![0f32; n];
    let mut u32buf = [0u8; 4];
    for x in out.iter_mut() {
        f.read_exact(&mut u32buf).unwrap();
        *x = f32::from_le_bytes(u32buf);
    }
    let slow_s = t0.elapsed().as_secs_f64();
    assert_eq!(out, *tensors[0].1.as_f32().unwrap(), "reference mismatch");

    let mb = (n * 4) as f64 / (1 << 20) as f64;
    println!("# checkpoint I/O, {mb:.0} MiB f32 payload");
    println!("save (bulk)          {:>10.1} MiB/s", mb / save_s);
    println!("load (bulk)          {:>10.1} MiB/s", mb / load_s);
    println!("load (element-wise)  {:>10.1} MiB/s", mb / slow_s);
    println!("bulk read speedup    {:>10.1}x", slow_s / load_s);
    vec![
        ("save_mib_s".into(), mb / save_s),
        ("load_bulk_mib_s".into(), mb / load_s),
        ("load_elementwise_mib_s".into(), mb / slow_s),
        ("bulk_read_speedup".into(), slow_s / load_s),
    ]
}

/// ISSUE-4 acceptance scenario: open-loop arrivals at ~5x the aggregate
/// admitted budget with per-tenant rate limits on. fifo mode, so the
/// seeded gaps drive a logical clock (no sleeping — the cell runs at
/// full speed) and the shed set is byte-deterministic at any worker
/// count. Latencies here are logical (the span clock only moves by the
/// declared interarrival gaps), so the report sticks to the shed
/// ledger: arrivals, admitted, global and hottest-tenant shed rates.
fn overload_shedding() -> Counters {
    let mut out = Counters::new();
    println!("# overload shedding: open loop 2000 req/s (logical) vs \
              16 tenants x 25 rps admitted budget, zipf s=1.0");
    println!("{:>8} {:>10} {:>10} {:>10} {:>12}",
             "workers", "arrivals", "admitted", "shed%", "hot-shed%");
    for &workers in &[1usize, 4, 8] {
        let opts = BenchOpts {
            load: LoadSpec {
                tenants: 16,
                requests: 4096,
                concurrency: 1,
                pauli: PauliSpec { q: 5, n_layers: 1 },
                seed: 42,
                zipf_s: 1.0,
                open_rate_rps: 2000.0,
            },
            serve: ServeConfig {
                workers,
                policy: BatchPolicy { max_batch: 8, max_wait_us: 1 },
                fifo: true,
                admission: AdmissionConfig {
                    rate_rps: 25.0,
                    burst: 25.0,
                    max_queue: 0,
                },
                ..ServeConfig::default()
            },
            cache_bytes: 8 << 20,
            ..BenchOpts::default()
        };
        match quantum_peft::serve::run_serve_bench(&opts, &EventLog::null()) {
            Ok((s, _)) => {
                let a = &s.admission;
                let arrivals = a.admitted + a.rejected_total();
                let shed = 100.0 * a.rejected_total() as f64
                    / arrivals.max(1) as f64;
                let hot = a.per_tenant.iter()
                    .find(|t| t.tenant == "tenant0000")
                    .map(|t| {
                        let att = t.admitted + t.rejected_rate_limited
                            + t.rejected_queue_full;
                        100.0 * (t.rejected_rate_limited
                                 + t.rejected_queue_full) as f64
                            / att.max(1) as f64
                    })
                    .unwrap_or(0.0);
                println!("{:>8} {:>10} {:>10} {:>9.1}% {:>11.1}%",
                         workers, arrivals, a.admitted, shed, hot);
                out.push((format!("w{workers}_shed_pct"), shed));
                out.push((format!("w{workers}_admitted"), a.admitted as f64));
            }
            Err(e) => println!("{workers:>8} failed: {e}"),
        }
    }
    out
}

/// The routing decision behind `STRUCTURED_APPLY_MIN_Q`, measured: dense
/// row-multiply against a pre-materialized Q_P (what the LRU path pays
/// per request once cached) vs structured gate application straight from
/// the thetas. Also prints the one-off dense materialization cost the
/// structured path never pays.
fn structured_vs_dense() -> Counters {
    let mut counters = Counters::new();
    println!("# apply path: dense x@Q_P row-multiply vs structured \
              PauliCircuit::apply, L=1, per row");
    println!("{:>4} {:>6} {:>12} {:>12} {:>12} {:>10}",
             "q", "dim", "dense/row", "struct/row", "material.", "speedup");
    let mut rng = Rng::new(7);
    for &q in &[4usize, 6, 8, 10, 12] {
        let circuit = pauli::build(q, 1);
        let n = circuit.dim();
        let thetas: Vec<f32> =
            (0..circuit.num_params).map(|_| rng.normal() as f32 * 0.5).collect();
        let input: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.5).collect();
        let t0 = Instant::now();
        let dense = circuit.materialize(&thetas);
        let mat_s = t0.elapsed().as_secs_f64();
        // enough rows to dominate timer noise, few enough that q=12
        // (4096-dim, 64 MiB dense) stays quick
        let iters = (1 << 22) / (n * n).max(1 << 14);
        let iters = iters.max(4);
        let mut sink = 0.0f32;
        let t0 = Instant::now();
        for _ in 0..iters {
            // dense row-multiply, exactly what the server's LRU path does
            let mut out = vec![0f32; n];
            for (k, &xv) in input.iter().enumerate() {
                let row = &dense[k * n..(k + 1) * n];
                for (o, &w) in out.iter_mut().zip(row) {
                    *o += xv * w;
                }
            }
            sink += out[0];
        }
        let dense_s = t0.elapsed().as_secs_f64() / iters as f64;
        let t0 = Instant::now();
        for _ in 0..iters {
            let mut row = input.clone();
            circuit.apply(&mut row, 1, &thetas);
            sink += row[0];
        }
        let struct_s = t0.elapsed().as_secs_f64() / iters as f64;
        assert!(sink.is_finite());
        println!("{:>4} {:>6} {:>12} {:>12} {:>12} {:>9.1}x",
                 q, n, fmt_ns(dense_s * 1e9), fmt_ns(struct_s * 1e9),
                 fmt_ns(mat_s * 1e9), dense_s / struct_s);
        counters.push((format!("q{q}_dense_row_ns"), dense_s * 1e9));
        counters.push((format!("q{q}_struct_row_ns"), struct_s * 1e9));
        counters.push((format!("q{q}_speedup"), dense_s / struct_s));
    }
    counters
}

/// One seeded register-record for the WAL benches (q=5 L=1 thetas
/// inline — the realistic few-KB adapter payload).
fn bench_record(tenant_index: usize, version: u64) -> StateRecord {
    let spec = PauliSpec { q: 5, n_layers: 1 };
    let mut rng = Rng::new(0xb0b ^ tenant_index as u64 ^ (version << 32));
    let thetas: Vec<f32> = (0..spec.num_params())
        .map(|_| rng.normal() as f32 * 0.5)
        .collect();
    let ts = TenantState {
        tenant: format!("tenant{tenant_index:04}"),
        version,
        q: spec.q,
        n_layers: spec.n_layers,
        checksum: theta_checksum(&thetas),
        path: format!("/spool/tenant{tenant_index:04}.qpck"),
        thetas,
    };
    if version == 1 {
        StateRecord::Register(ts)
    } else {
        StateRecord::Swap(ts)
    }
}

fn bench_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("qp_serve_bench_store")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// ISSUE-5 acceptance: WAL append throughput per durability mode. The
/// record payload is a real register record (tenant + manifest + theta
/// vector), so records/s is the adapter-churn rate the control plane
/// can absorb durably.
fn wal_append_throughput(reg: &MetricsRegistry) -> Counters {
    let mut out = Counters::new();
    println!("# state store: WAL append throughput, q=5 L=1 register records");
    println!("{:>12} {:>10} {:>14} {:>12}",
             "durability", "records", "records/s", "MiB/s");
    for (label, durability, n) in [
        ("buffered", Durability::Buffered, 20_000usize),
        ("every64", Durability::EveryN(64), 8_192),
        ("always", Durability::Always, 256),
    ] {
        let dir = bench_dir(&format!("wal_{label}"));
        let mut opened = StateStore::open(&dir, durability).unwrap();
        opened.store.instrument(reg, &opened.recovered);
        let store = opened.store;
        // one record re-appended n times: measures the log, not the RNG
        let rec = bench_record(0, 1);
        let t0 = Instant::now();
        for _ in 0..n {
            store.append(&rec).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let bytes = std::fs::metadata(dir.join(quantum_peft::store::WAL_FILE))
            .map(|m| m.len())
            .unwrap_or(0) as f64;
        println!("{:>12} {:>10} {:>14.0} {:>12.1}",
                 label, n, n as f64 / wall,
                 bytes / (1 << 20) as f64 / wall);
        out.push((format!("{label}_records_s"), n as f64 / wall));
        out.push((format!("{label}_mib_s"), bytes / (1 << 20) as f64 / wall));
        let _ = std::fs::remove_dir_all(&dir);
    }
    out
}

/// ISSUE-5 acceptance: recovery wall-clock for 256 tenants, full-WAL
/// replay (registers + 8 swap generations each = 2304 records) vs
/// recovery after snapshot compaction truncated the log. The
/// post-compaction number must be measurably cheaper — that is the
/// entire point of the snapshot.
fn recovery_wall_clock(reg: &MetricsRegistry) -> Counters {
    const TENANTS: usize = 256;
    const SWAPS: u64 = 8;
    let dir = bench_dir("recover");
    let mut opened = StateStore::open(&dir, Durability::Buffered).unwrap();
    opened.store.instrument(reg, &opened.recovered);
    let store = opened.store;
    for i in 0..TENANTS {
        store.append(&bench_record(i, 1)).unwrap();
    }
    for v in 2..=(1 + SWAPS) {
        for i in 0..TENANTS {
            store.append(&bench_record(i, v)).unwrap();
        }
    }
    let records = store.wal_records();
    drop(store);

    let t0 = Instant::now();
    let full = recover(&dir).unwrap();
    let full_s = t0.elapsed().as_secs_f64();
    assert_eq!(full.tenants.len(), TENANTS);

    // compact: the live state (final generation of each tenant) becomes
    // the snapshot, the WAL truncates. Instrumenting this reopen also
    // credits the full replay to wal_recovered_* in the artifact.
    let mut opened = StateStore::open(&dir, Durability::Buffered).unwrap();
    opened.store.instrument(reg, &opened.recovered);
    let store = opened.store;
    store.compact(&full.tenants).unwrap();
    drop(store);

    let t0 = Instant::now();
    let compacted = recover(&dir).unwrap();
    let compact_s = t0.elapsed().as_secs_f64();
    assert_eq!(compacted.tenants.len(), TENANTS);
    assert_eq!(compacted.tenants, full.tenants);

    println!("# state store: recovery wall-clock, {TENANTS} tenants");
    println!("full-WAL replay ({records} records)   {:>10}", fmt_ns(full_s * 1e9));
    println!("after snapshot+truncate           {:>10}  ({:.1}x cheaper)",
             fmt_ns(compact_s * 1e9), full_s / compact_s.max(1e-9));
    let _ = std::fs::remove_dir_all(&dir);
    vec![
        ("full_replay_s".into(), full_s),
        ("compacted_s".into(), compact_s),
        ("compaction_speedup".into(), full_s / compact_s.max(1e-9)),
        ("wal_records".into(), records as f64),
    ]
}

/// ISSUE-6 acceptance: horizontal scaling. The same closed-loop seeded
/// workload against 1, 4 and 16 shards at 256 and 4096 tenants; each
/// shard runs its own registry/batcher/worker pair, so fleet req/s
/// should grow with the shard count until the driving thread saturates.
/// Per-shard min/max served counts show how evenly the consistent-hash
/// ring spreads the Zipf-skewed tenants.
fn shard_scaling() -> Counters {
    let mut out = Counters::new();
    println!("# shard scaling: closed-loop loadgen, q=5 L=1, zipf s=1.0, \
              2 workers/shard");
    println!("{:>7} {:>8} {:>10} {:>12} {:>12} {:>12} {:>12}",
             "shards", "tenants", "requests", "fleet req/s", "worst p99",
             "shard min", "shard max");
    for &shards in &[1usize, 4, 16] {
        for &tenants in &[256usize, 4096] {
            let opts = BenchOpts {
                load: LoadSpec {
                    tenants,
                    requests: 4096,
                    concurrency: 64,
                    pauli: PauliSpec { q: 5, n_layers: 1 },
                    seed: 42,
                    zipf_s: 1.0,
                    open_rate_rps: 0.0,
                },
                serve: ServeConfig {
                    workers: 2,
                    ..ServeConfig::default()
                },
                cache_bytes: 8 << 20,
                ..BenchOpts::default()
            };
            match quantum_peft::serve::run_sharded_bench(
                &opts, shards, &EventLog::null())
            {
                Ok(report) => {
                    let served: Vec<u64> = report.fleet.sessions.iter()
                        .map(|(_, s)| s.completed)
                        .collect();
                    let min = served.iter().min().copied().unwrap_or(0);
                    let max = served.iter().max().copied().unwrap_or(0);
                    let p99 = report.fleet.p99_us()
                        .map_or_else(|| "-".to_string(), |v| fmt_ns(v * 1e3));
                    println!(
                        "{:>7} {:>8} {:>10} {:>12.0} {:>12} {:>12} {:>12}",
                        shards, tenants, report.fleet.completed(),
                        report.fleet.fleet_rps(), p99, min, max);
                    out.push((format!("s{shards}_t{tenants}_fleet_rps"),
                              report.fleet.fleet_rps()));
                }
                Err(e) => println!("{shards:>7} {tenants:>8} failed: {e}"),
            }
        }
    }
    out
}

/// Write every section's counters as one JSON object:
/// `{"bench": "serve", "schema": 1, "cheap": ..., "sections": {...}}`.
fn write_report(cheap: bool, sections: &[(&str, Counters)]) {
    let path = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let mut secs: BTreeMap<String, Json> = BTreeMap::new();
    for (name, counters) in sections {
        let m: BTreeMap<String, Json> = counters.iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        secs.insert((*name).to_string(), Json::Obj(m));
    }
    let report = json::obj(vec![
        ("bench", "serve".into()),
        ("schema", 1usize.into()),
        ("cheap", Json::Bool(cheap)),
        ("sections", Json::Obj(secs)),
    ]);
    match std::fs::write(&path, report.dump() + "\n") {
        Ok(()) => println!("# wrote {path}"),
        Err(e) => eprintln!("# failed to write {path}: {e}"),
    }
}

fn main() {
    // BENCH_CHEAP=1: only the seconds-scale sections (what CI runs)
    let cheap = std::env::var("BENCH_CHEAP").map(|v| v == "1").unwrap_or(false);
    // one timed (non-deterministic) registry across all sections: the
    // end-of-run snapshot is the second CI artifact next to the report
    let reg = MetricsRegistry::new(false);
    let mut sections: Vec<(&str, Counters)> = vec![
        ("checkpoint_io", checkpoint_io()),
        ("wal_append_throughput", wal_append_throughput(&reg)),
        ("recovery_wall_clock", recovery_wall_clock(&reg)),
        ("structured_vs_dense", structured_vs_dense()),
    ];
    if !cheap {
        sections.push(("overload_shedding", overload_shedding()));
        sections.push(("serve_grid", serve_grid(&reg)));
        sections.push(("shard_scaling", shard_scaling()));
    }
    write_report(cheap, &sections);
    let mpath = std::path::PathBuf::from(
        std::env::var("BENCH_METRICS_OUT")
            .unwrap_or_else(|_| "METRICS_serve.jsonl".to_string()),
    );
    match export::write_snapshot(&reg, &mpath) {
        Ok(()) => println!("# wrote {} (+ {}.prom)", mpath.display(), mpath.display()),
        Err(e) => eprintln!("# failed to write {}: {e}", mpath.display()),
    }
}
