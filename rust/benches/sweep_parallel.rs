//! Sequential-vs-parallel sweep wall-clock (the tentpole's speedup
//! evidence). Part 1 needs no artifacts: the work-stealing pool runs a
//! grid of CPU-bound orthogonal-mapping cells (the Figure-6 math — the
//! same flavor of dense f64 compute a training cell spends its time in)
//! at jobs = 1/2/4/auto and reports the speedup and a bit-exactness
//! check. Part 2 drives a real mini GLUE sweep when artifacts + native
//! XLA bindings are present, and skips politely otherwise.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use quantum_peft::config;
use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::sweep::{self, SweepPlan};
use quantum_peft::data::glue;
use quantum_peft::quantum::mappings::{self, Mapping};
use quantum_peft::runtime::exe_cache::{CacheEvent, CompileLog, OnceMap};
use quantum_peft::runtime::{Manifest, Runtime};
use quantum_peft::util::pool;
use quantum_peft::util::rng::Rng;

/// One synthetic sweep cell: a few orthogonal-map constructions at the
/// Figure-6 scale. Returns a checksum so results can be compared
/// bit-exactly across jobs settings.
fn synthetic_cell(seed: u64) -> u64 {
    let n = 96;
    let k = 4;
    let mut rng = Rng::new(seed);
    let th = mappings::random_theta(&mut rng, n, k, 0.3);
    let mut acc = 0u64;
    for m in [Mapping::Taylor(18), Mapping::Cayley, Mapping::Householder] {
        let q = mappings::orthogonal(&th, n, k, m);
        acc ^= q.data.iter().fold(0u64, |h, v| {
            h.rotate_left(7) ^ v.to_bits()
        });
    }
    acc
}

fn run_grid(jobs: usize, cells: usize) -> (f64, Vec<u64>) {
    let t0 = Instant::now();
    let results = pool::run(jobs, (0..cells as u64).collect(),
                            |_ctx, seed| Ok(synthetic_cell(seed)));
    let secs = t0.elapsed().as_secs_f64();
    (secs, pool::collect_ordered(results).unwrap())
}

fn real_sweep(jobs: usize) -> anyhow::Result<f64> {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu()?;
    let cfg = config::preset("quick")?;
    let mut tcfg = config::train_config(&cfg);
    tcfg.steps = 20;
    tcfg.train_examples = 64;
    tcfg.test_examples = 32;
    let plan = SweepPlan {
        tags: vec!["enc_lora".into(), "enc_qpeft_pauli".into()],
        tasks: vec![glue::Task::Sst2, glue::Task::Cola],
        seeds: vec![0, 1],
        cfg: tcfg,
        backbone: None,
        task_lr: BTreeMap::new(),
    };
    let t0 = Instant::now();
    sweep::run_glue_sweep_jobs(&rt, &manifest, &plan, &EventLog::null(), jobs)?;
    Ok(t0.elapsed().as_secs_f64())
}

/// Parallel warm-up with a simulated compile (a sleep standing in for an
/// XLA compile): `shared = true` routes all workers through one cache
/// namespace (each path compiles once for the pool, as on CPU);
/// `shared = false` namespaces per worker (the old per-worker-cache
/// behavior, and today's fallback when clients cannot be shared).
/// Returns (wall seconds, number of compiles actually run).
fn cache_warmup(jobs: usize, paths: usize, shared: bool) -> (f64, usize) {
    let cache: OnceMap<(usize, PathBuf), u32> = OnceMap::new();
    let log = CompileLog::new();
    // every worker touches every path, like sweep cells sharing (train,
    // eval) computations across tasks and seeds
    let items: Vec<usize> = (0..jobs * 2).collect();
    let t0 = Instant::now();
    let results = pool::run(jobs, items, |ctx, i| {
        for p in 0..paths {
            // namespace by item slot, not executing worker: work stealing
            // makes ctx.worker nondeterministic, which would make the
            // per-worker baseline's compile count noisy run-to-run
            let ns = if shared { 0 } else { i % jobs };
            let key = (ns, PathBuf::from(format!("artifacts/a{p}.hlo")));
            cache.get_or_try_init(&key, || {
                std::thread::sleep(Duration::from_millis(10));
                log.record(&key.1, CacheEvent::Compile, 0.01,
                           Some(ctx.worker));
                Ok(0)
            })?;
        }
        Ok(())
    });
    pool::collect_ordered(results).unwrap();
    (t0.elapsed().as_secs_f64(), log.snapshot().len())
}

fn main() {
    println!("# parallel sweep engine: wall-clock vs --jobs");
    let cells = 24;
    let auto = pool::default_jobs();
    println!("(host reports {auto} available cores)");

    let (t1, base) = run_grid(1, cells);
    println!("bench sweep_synthetic/jobs=1   {cells} cells in {t1:.3}s (1.00x)");
    for jobs in [2usize, 4, auto] {
        if jobs <= 1 {
            continue;
        }
        let (t, out) = run_grid(jobs, cells);
        assert_eq!(out, base, "parallel results diverged from sequential");
        println!("bench sweep_synthetic/jobs={jobs}   {cells} cells in {t:.3}s \
                  ({:.2}x, bit-identical)", t1 / t);
    }

    println!("\n# shared compile cache: pool warm-up, 6 paths x 10ms compile");
    for jobs in [2usize, 4] {
        let (tp, np) = cache_warmup(jobs, 6, false);
        let (ts, ns) = cache_warmup(jobs, 6, true);
        println!("bench cache_warmup/jobs={jobs}   per-worker {np} compiles \
                  in {tp:.3}s | shared {ns} compiles in {ts:.3}s \
                  ({:.2}x less compile work)", np as f64 / ns as f64);
    }

    println!("\n# real GLUE sweep (needs artifacts + native XLA bindings)");
    match real_sweep(1).and_then(|t1| Ok((t1, real_sweep(4)?))) {
        Ok((seq, par)) => {
            println!("bench sweep_glue/jobs=1 {seq:.2}s, jobs=4 {par:.2}s \
                      ({:.2}x)", seq / par);
        }
        Err(e) => println!("SKIP: {e}"),
    }
}
