//! End-to-end train-step latency per artifact — the Table-4 timing basis
//! (ms/batch per PEFT method) and the L3 §Perf hot path. Skips politely
//! when artifacts/ has not been built.

use std::collections::BTreeMap;

use quantum_peft::coordinator::trainer::default_extras;
use quantum_peft::data::{glue, grammar::Grammar};
use quantum_peft::runtime::{tensors, HostTensor, Manifest, Runtime,
                            TrainSession};
use quantum_peft::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return Ok(());
    }
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu()?;
    let g = Grammar::new();

    println!("# train-step latency per method (Table 4 basis, enc family)");
    for tag in ["enc_ft", "enc_lora", "enc_adalora", "enc_loha", "enc_lokr",
                "enc_qpeft_taylor", "enc_qpeft_pauli"] {
        let entry = manifest.get(tag)?;
        let mut session = TrainSession::new(&rt, entry, 0)?;
        let bsz = entry.batch_size();
        let seq = entry.batch[0].shape[1];
        let ds = glue::dataset(&g, glue::Task::Sst2, 0, bsz, seq);
        let toks: Vec<Vec<u32>> = ds.iter().map(|x| x.tokens.clone()).collect();
        let labels: Vec<f32> = ds.iter().map(|x| x.label).collect();
        let batch = [tensors::stack_tokens(&toks),
                     HostTensor::f32(vec![bsz], labels)];
        let extras = default_extras(&session.entry, 0.0, &BTreeMap::new());
        bench(&format!("train_step/{tag}"), 1500, || {
            session.step(&batch, 1e-3, 0.01, &extras).unwrap();
        });
    }

    println!("\n# eval-step latency");
    for tag in ["enc_lora", "enc_qpeft_pauli"] {
        let entry = manifest.get(tag)?;
        let session = TrainSession::new(&rt, entry, 0)?;
        let bsz = entry.batch_size();
        let seq = entry.batch[0].shape[1];
        let ds = glue::dataset(&g, glue::Task::Sst2, 0, bsz, seq);
        let toks: Vec<Vec<u32>> = ds.iter().map(|x| x.tokens.clone()).collect();
        let x = tensors::stack_tokens(&toks);
        let extras = default_extras(&session.entry, 0.0, &BTreeMap::new());
        bench(&format!("eval_step/{tag}"), 1000, || {
            session.eval(&x, &extras).unwrap();
        });
    }
    println!("\n(total XLA compile: {:.1}s)", rt.total_compile_seconds());
    Ok(())
}
