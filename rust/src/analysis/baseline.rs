//! The ratchet baseline: a JSON file of fingerprinted, accepted
//! findings.
//!
//! A baseline lets a new (or newly strict) lint land *blocking* before
//! the tree is fully clean: the sweep's leftover findings are written
//! to a baseline file, the gate fails on anything **not** in it, and
//! the file can only shrink —
//!
//! - a finding whose fingerprint is in the baseline is accepted (it
//!   moves to [`Report::baselined`], not counted against cleanliness);
//! - a finding not in the baseline fails the gate like any other;
//! - a baseline entry that no longer matches any finding is *stale*
//!   and is itself reported as a finding (`baseline` lint), so fixed
//!   debt must be deleted from the file — the ratchet only turns one
//!   way.
//!
//! Fingerprints are FNV-1a 64 over `lint|file|message` with the file
//! path normalized (leading `./` and `rust/` stripped), so a run from
//! the repo root and a run from `rust/` agree, and a finding keeps its
//! identity across unrelated edits that only shift line numbers.
//! The message is part of the identity on purpose: messages embed the
//! reached site (`wal.rs:88`) for interprocedural findings, so a
//! *different* path to the same lint at the same file is a new
//! finding, not silently absorbed by old debt.

use super::lints::Finding;
use super::{Report, Suppressed};
use crate::util::json::{self, Json};

/// One accepted finding. The lint/file/message triple is stored next
/// to the fingerprint so the file is reviewable in a diff — the
/// fingerprint alone is what matching uses.
#[derive(Debug, Clone)]
pub struct Entry {
    pub fingerprint: String,
    pub lint: String,
    pub file: String,
    pub message: String,
}

#[derive(Debug, Default)]
pub struct Baseline {
    pub entries: Vec<Entry>,
}

/// Strip the path prefixes that vary with the invocation directory.
fn norm_file(file: &str) -> &str {
    let f = file.strip_prefix("./").unwrap_or(file);
    f.strip_prefix("rust/").unwrap_or(f)
}

/// FNV-1a 64 of `lint|normalized-file|message`, rendered as 16 hex
/// digits. Line numbers are deliberately excluded.
pub fn fingerprint(f: &Finding) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for b in bytes {
            h ^= u64::from(*b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(f.lint.as_bytes());
    eat(b"|");
    eat(norm_file(&f.file).as_bytes());
    eat(b"|");
    eat(f.message.as_bytes());
    format!("{h:016x}")
}

impl Baseline {
    /// Capture every current finding as accepted debt.
    pub fn from_report(report: &Report) -> Baseline {
        Baseline {
            entries: report
                .findings
                .iter()
                .map(|f| Entry {
                    fingerprint: fingerprint(f),
                    lint: f.lint.to_string(),
                    file: norm_file(&f.file).to_string(),
                    message: f.message.clone(),
                })
                .collect(),
        }
    }

    pub fn parse(text: &str) -> Result<Baseline, String> {
        let v = Json::parse(text).map_err(|e| format!("baseline is not valid JSON: {e}"))?;
        let entries = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .map_err(|_| "baseline has no `entries` array".to_string())?;
        let mut out = Vec::new();
        for e in entries {
            let field = |k: &str| -> Result<String, String> {
                e.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .map_err(|_| format!("baseline entry missing string `{k}`"))
            };
            out.push(Entry {
                fingerprint: field("fingerprint")?,
                lint: field("lint")?,
                file: field("file")?,
                message: field("message")?,
            });
        }
        Ok(Baseline { entries: out })
    }

    pub fn dump(&self) -> String {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                json::obj(vec![
                    ("fingerprint", e.fingerprint.as_str().into()),
                    ("lint", e.lint.as_str().into()),
                    ("file", e.file.as_str().into()),
                    ("message", e.message.as_str().into()),
                ])
            })
            .collect();
        json::obj(vec![("version", 1usize.into()), ("entries", Json::Arr(entries))]).dump()
    }
}

/// Apply the ratchet: move accepted findings to `report.baselined`,
/// report stale entries as findings. Matching is multiset — two
/// identical findings need two baseline entries.
pub fn apply(report: &mut Report, base: &Baseline) {
    let mut remaining: Vec<&Entry> = base.entries.iter().collect();
    let mut kept = Vec::new();
    for f in std::mem::take(&mut report.findings) {
        let fp = fingerprint(&f);
        match remaining.iter().position(|e| e.fingerprint == fp) {
            Some(pos) => {
                remaining.remove(pos);
                report
                    .baselined
                    .push(Suppressed { finding: f, reason: format!("accepted by baseline ({fp})") });
            }
            None => kept.push(f),
        }
    }
    for e in remaining {
        kept.push(Finding {
            lint: "baseline",
            file: e.file.clone(),
            line: 1,
            message: format!(
                "stale baseline entry {} ({}: {}) — the finding is gone; delete the \
                 entry (or regenerate with --write-baseline) so the ratchet only \
                 turns one way",
                e.fingerprint, e.lint, e.message
            ),
        });
    }
    kept.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    report.findings = kept;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(lint: &'static str, file: &str, line: u32, msg: &str) -> Finding {
        Finding { lint, file: file.to_string(), line, message: msg.to_string() }
    }

    #[test]
    fn fingerprint_ignores_lines_and_path_prefix() {
        let a = finding("panic-path", "rust/src/serve/a.rs", 10, "m");
        let b = finding("panic-path", "src/serve/a.rs", 99, "m");
        assert_eq!(fingerprint(&a), fingerprint(&b));
        let c = finding("panic-path", "src/serve/a.rs", 10, "other");
        assert_ne!(fingerprint(&a), fingerprint(&c));
    }

    #[test]
    fn roundtrip_and_accept() {
        let mut report = Report {
            findings: vec![finding("panic-path", "src/serve/a.rs", 3, "m")],
            ..Report::default()
        };
        let base = Baseline::parse(&Baseline::from_report(&report).dump()).unwrap();
        apply(&mut report, &base);
        assert!(report.findings.is_empty(), "{:?}", report.findings);
        assert_eq!(report.baselined.len(), 1);
    }

    #[test]
    fn new_finding_still_fails_and_stale_entry_is_a_finding() {
        let old = Report {
            findings: vec![finding("panic-path", "src/serve/a.rs", 3, "fixed later")],
            ..Report::default()
        };
        let base = Baseline::from_report(&old);
        let mut now = Report {
            findings: vec![finding("determinism", "src/serve/b.rs", 7, "fresh")],
            ..Report::default()
        };
        apply(&mut now, &base);
        let lints: Vec<&str> = now.findings.iter().map(|f| f.lint).collect();
        assert!(lints.contains(&"determinism"), "{lints:?}");
        assert!(lints.contains(&"baseline"), "{lints:?}");
        assert!(now.baselined.is_empty());
    }

    #[test]
    fn multiset_matching_consumes_entries() {
        // two identical findings, one baseline entry: one accepted,
        // one fails
        let f = finding("panic-path", "src/serve/a.rs", 3, "m");
        let base = Baseline::from_report(&Report {
            findings: vec![f.clone()],
            ..Report::default()
        });
        let mut now =
            Report { findings: vec![f.clone(), f.clone()], ..Report::default() };
        apply(&mut now, &base);
        assert_eq!(now.findings.len(), 1);
        assert_eq!(now.baselined.len(), 1);
    }
}
