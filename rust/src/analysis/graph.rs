//! The crate-wide call graph and its transitive closures.
//!
//! Resolution is by name, in three precision tiers:
//!
//! 1. `self.method(..)` inside an `impl Type` block resolves to
//!    `Type::method` when the crate defines it;
//! 2. `Type::method(..)` / `module::function(..)` resolves against the
//!    qualified name, falling back to the bare name (the qualifier may
//!    be a module path segment the model cannot see through);
//! 3. `receiver.method(..)` with an unresolvable receiver falls back
//!    to **every** crate function with that method name.
//!
//! Tier 3 is the conservative any-method fallback: it can only
//! over-approximate the real call target set, so the closures computed
//! here (which locks / blocking calls are reachable from a function)
//! may contain edges the program never takes — a finding built on them
//! can be a false positive, answered with a reasoned
//! `// analyze: allow`. What the fallback can *not* do is miss a
//! crate-local callee, which is the direction that matters for a gate:
//! absence of findings is meaningful. Two carve-outs keep the
//! over-approximation usable rather than universal: method names on
//! the [`STD_METHODS`] list (container/iterator/atomic vocabulary like
//! `get`, `len`, `send`) never enter the union — a crate fn that
//! shadows one of those names is only reached through tiers 1 and 2 —
//! and a qualified call whose qualifier is a std type or module
//! ([`STD_QUALS`], e.g. `Arc::new`) resolves to nothing instead of
//! falling back to every crate `new`. Calls that resolve to nothing
//! contribute no edges.
//!
//! Spawn closures are the one deliberate cut: calls inside a
//! `spawn(..)` argument list run on the new thread, so they are
//! excluded from the spawning function's closure and instead seed the
//! [`CallGraph::spawn_reachable`] set, which the atomics lint uses to
//! tell main-thread accesses from spawned-thread accesses.

use std::collections::BTreeMap;

use super::model::FileModel;

/// Method names the any-method fallback must NOT union: they are so
/// ubiquitous on std containers, iterators, atomics, `Option`/`Result`
/// and strings that treating every `.get(..)` or `.len(..)` as a
/// possible call to a same-named crate fn would hang a lock footprint
/// on nearly every statement (`Registry::len` acquires `tenants`; a
/// `HashMap::len` under any held guard would then report an
/// inversion). Crate methods with these names are still resolved
/// precisely through `self.method(..)` and `Type::method(..)` calls —
/// only the opaque-receiver union skips them.
const STD_METHODS: &[&str] = &[
    // containers / slices
    "get", "get_mut", "insert", "remove", "entry", "or_insert", "or_default",
    "contains", "contains_key", "keys", "values", "iter", "iter_mut",
    "into_iter", "len", "is_empty", "push", "pop", "push_str", "extend",
    "drain", "clear", "retain", "first", "last", "split_off", "truncate",
    "sort", "sort_by", "sort_by_key", "sort_unstable", "dedup", "binary_search",
    "resize", "fill", "concat", "join", "windows", "chunks", "to_vec",
    // iterators
    "map", "filter", "filter_map", "flat_map", "flatten", "find", "position",
    "any", "all", "count", "sum", "product", "fold", "chain", "zip", "rev",
    "skip", "take_while", "skip_while", "step_by", "enumerate", "copied",
    "cloned", "collect", "next", "nth", "peekable", "peek", "by_ref",
    "min", "max", "min_by", "max_by", "min_by_key", "max_by_key",
    // Option / Result
    "unwrap", "unwrap_or", "unwrap_or_else", "unwrap_or_default", "expect",
    "ok", "err", "is_some", "is_none", "is_ok", "is_err", "map_err",
    "and_then", "or_else", "ok_or", "ok_or_else", "take", "replace",
    "get_or_insert", "get_or_insert_with", "as_ref", "as_mut", "as_deref",
    // atomics / channels
    "load", "store", "swap", "fetch_add", "fetch_sub", "fetch_or",
    "fetch_and", "compare_exchange", "compare_exchange_weak", "send", "recv",
    "try_recv", "recv_timeout", "wait", "wait_timeout", "notify_one",
    "notify_all", "into_inner",
    // strings / conversion / numbers
    "clone", "to_string", "to_owned", "as_str", "as_bytes", "as_slice",
    "parse", "trim", "split", "splitn", "lines", "chars", "bytes",
    "starts_with", "ends_with", "strip_prefix", "strip_suffix", "repeat",
    "saturating_add", "saturating_sub", "saturating_mul", "wrapping_add",
    "wrapping_mul", "checked_add", "checked_sub", "checked_mul", "clamp",
    "to_le_bytes", "to_be_bytes", "abs", "sqrt", "powi", "exp", "ln",
    "floor", "ceil", "round", "rem_euclid", "hypot", "is_finite", "is_nan",
    // time / paths / misc std
    "elapsed", "as_secs_f64", "as_secs", "as_millis", "as_micros",
    "as_nanos", "from_secs", "from_millis", "from_micros", "from_nanos",
    "duration_since", "display", "exists", "is_dir", "is_file", "extension",
    "file_name", "file_stem", "parent", "to_path_buf", "with_extension",
    "eq", "ne", "cmp", "partial_cmp", "hash", "fmt", "into", "try_into",
    "borrow", "borrow_mut", "as_any", "context", "with_context",
];

/// Qualifiers that name std (or std-adjacent) types and modules:
/// `Arc::new(..)` / `Vec::with_capacity(..)` must resolve to nothing,
/// not fall back to every crate fn named `new`.
const STD_QUALS: &[&str] = &[
    "Arc", "Rc", "Box", "Vec", "VecDeque", "String", "str", "HashMap",
    "HashSet", "BTreeMap", "BTreeSet", "Mutex", "RwLock", "Condvar",
    "Option", "Result", "Some", "Ok", "Err", "Instant", "Duration",
    "SystemTime", "Ordering", "PathBuf", "Path", "File", "OpenOptions",
    "mpsc", "thread", "fs", "io", "fmt", "mem", "process", "env", "cmp",
    "iter", "slice", "f32", "f64", "u8", "u16", "u32", "u64", "u128",
    "usize", "i8", "i16", "i32", "i64", "isize", "char", "bool",
    "AtomicBool", "AtomicUsize", "AtomicU32", "AtomicU64", "AtomicI64",
    "Default", "Iterator", "AssertUnwindSafe", "Cow",
];

/// Where something (an acquisition, a blocking call) actually lives.
#[derive(Debug, Clone)]
pub struct Site {
    pub file: String,
    pub line: u32,
}

pub struct CallGraph {
    /// Flattened fn ids: `fns[id] = (file index, fn index in file)`.
    pub fns: Vec<(usize, usize)>,
    /// Per fn, parallel to its `FnDef::calls`: resolved callee ids.
    pub call_targets: Vec<Vec<Vec<usize>>>,
    /// Held-lock acquisitions reachable from each fn, including its
    /// own (spawn-closure sites excluded): lock name -> example site.
    pub locks_out: Vec<BTreeMap<String, Site>>,
    /// Blocking calls reachable from each fn: kind -> example site.
    pub blocking_out: Vec<BTreeMap<&'static str, Site>>,
    /// Reachable from inside any spawn closure (runs off-thread).
    pub spawn_reachable: Vec<bool>,
    display: Vec<String>,
    ids: Vec<Vec<usize>>,
}

impl CallGraph {
    /// The fn id for file `fi`, fn `fj` of that file's model.
    pub fn id_of(&self, fi: usize, fj: usize) -> usize {
        self.ids[fi][fj]
    }

    /// `Type::name` or bare `name`, for messages.
    pub fn display_name(&self, id: usize) -> &str {
        &self.display[id]
    }
}

pub fn build(models: &[FileModel]) -> CallGraph {
    let mut fns = Vec::new();
    let mut display = Vec::new();
    let mut ids: Vec<Vec<usize>> = Vec::new();
    let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    let mut by_qual: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (fi, m) in models.iter().enumerate() {
        let mut file_ids = Vec::new();
        for (fj, f) in m.fns.iter().enumerate() {
            let id = fns.len();
            fns.push((fi, fj));
            display.push(match &f.qual {
                Some(q) => format!("{q}::{}", f.name),
                None => f.name.clone(),
            });
            by_name.entry(f.name.as_str()).or_default().push(id);
            if let Some(q) = &f.qual {
                by_qual.entry(format!("{q}::{}", f.name)).or_default().push(id);
            }
            file_ids.push(id);
        }
        ids.push(file_ids);
    }

    let n = fns.len();
    let mut call_targets: Vec<Vec<Vec<usize>>> = Vec::with_capacity(n);
    let mut edges: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut spawn_roots: Vec<usize> = Vec::new();
    for &(fi, fj) in &fns {
        let caller = call_targets.len();
        let f = &models[fi].fns[fj];
        let mut per_call = Vec::with_capacity(f.calls.len());
        for c in &f.calls {
            let union_ok = !STD_METHODS.contains(&c.name.as_str());
            let targets: Vec<usize> = if c.on_self {
                f.qual
                    .as_ref()
                    .and_then(|q| by_qual.get(&format!("{q}::{}", c.name)))
                    .or_else(|| by_name.get(c.name.as_str()).filter(|_| union_ok))
                    .cloned()
                    .unwrap_or_default()
            } else if let Some(q) = c.qual.as_deref() {
                // `Self::x` means the enclosing impl type; a std
                // qualifier means the call never enters the crate
                let q = if q == "Self" { f.qual.as_deref().unwrap_or(q) } else { q };
                if STD_QUALS.contains(&q) {
                    Vec::new()
                } else {
                    by_qual
                        .get(&format!("{q}::{}", c.name))
                        .or_else(|| by_name.get(c.name.as_str()).filter(|_| union_ok))
                        .cloned()
                        .unwrap_or_default()
                }
            } else if c.method && !union_ok {
                Vec::new()
            } else {
                by_name.get(c.name.as_str()).cloned().unwrap_or_default()
            };
            if c.in_spawn {
                spawn_roots.extend(targets.iter().copied());
            } else {
                for t in &targets {
                    if !edges[caller].contains(t) {
                        edges[caller].push(*t);
                    }
                }
            }
            per_call.push(targets);
        }
        call_targets.push(per_call);
    }

    // Seed the closures with each fn's own footprint.
    let mut locks_out: Vec<BTreeMap<String, Site>> = vec![BTreeMap::new(); n];
    let mut blocking_out: Vec<BTreeMap<&'static str, Site>> = vec![BTreeMap::new(); n];
    for (id, &(fi, fj)) in fns.iter().enumerate() {
        let m = &models[fi];
        let f = &m.fns[fj];
        // Temporary acquisitions count too: the callee releasing its
        // guard at statement end does not help the caller, whose own
        // guard is held across the whole call.
        for a in &f.acqs {
            if !a.in_spawn {
                locks_out[id]
                    .entry(a.name.clone())
                    .or_insert(Site { file: m.rel.clone(), line: a.line });
            }
        }
        for b in &f.blocking {
            if !b.in_spawn {
                blocking_out[id]
                    .entry(b.what)
                    .or_insert(Site { file: m.rel.clone(), line: b.line });
            }
        }
    }

    // Fixpoint: propagate callee footprints up. Both maps only grow
    // and their key spaces are finite, so this terminates — cycles in
    // the graph (recursion) simply stop adding entries.
    let mut changed = true;
    while changed {
        changed = false;
        for id in 0..n {
            for ci in 0..edges[id].len() {
                let callee = edges[id][ci];
                if callee == id {
                    continue;
                }
                let add: Vec<(String, Site)> = locks_out[callee]
                    .iter()
                    .filter(|(k, _)| !locks_out[id].contains_key(*k))
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect();
                for (k, v) in add {
                    locks_out[id].insert(k, v);
                    changed = true;
                }
                let add: Vec<(&'static str, Site)> = blocking_out[callee]
                    .iter()
                    .filter(|(k, _)| !blocking_out[id].contains_key(*k))
                    .map(|(k, v)| (*k, v.clone()))
                    .collect();
                for (k, v) in add {
                    blocking_out[id].insert(k, v);
                    changed = true;
                }
            }
        }
    }

    // Everything reachable from a spawn closure runs off-thread.
    let mut spawn_reachable = vec![false; n];
    let mut stack = spawn_roots;
    while let Some(id) = stack.pop() {
        if spawn_reachable[id] {
            continue;
        }
        spawn_reachable[id] = true;
        stack.extend(edges[id].iter().copied());
    }

    CallGraph { fns, call_targets, locks_out, blocking_out, spawn_reachable, display, ids }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;
    use crate::analysis::model::extract;

    fn graph_of(files: &[(&str, &str)]) -> (Vec<FileModel>, CallGraph) {
        let models: Vec<FileModel> =
            files.iter().map(|(rel, src)| extract(rel, &lex(src))).collect();
        let g = build(&models);
        (models, g)
    }

    #[test]
    fn self_call_resolves_within_impl_type() {
        let (_, g) = graph_of(&[(
            "x/serve/a.rs",
            "impl A { fn go(&self) { self.step(); } fn step(&self) {} }\n\
             impl B { fn step(&self) {} }\n",
        )]);
        // A::go's one call resolves to exactly A::step, not B::step.
        let targets = &g.call_targets[0][0];
        assert_eq!(targets.len(), 1);
        assert_eq!(g.display_name(targets[0]), "A::step");
    }

    #[test]
    fn any_method_fallback_unions_all_candidates() {
        let (_, g) = graph_of(&[(
            "x/serve/a.rs",
            "fn go(r: &R) { r.step(); }\n\
             impl A { fn step(&self) {} }\n impl B { fn step(&self) {} }\n",
        )]);
        assert_eq!(g.call_targets[0][0].len(), 2);
    }

    #[test]
    fn transitive_lock_closure_crosses_files() {
        let (_, g) = graph_of(&[
            ("x/serve/a.rs", "impl A { fn outer(&self) { self.helper(); } \
                              fn helper(&self) { inner_fn(); } }\n"),
            ("x/serve/b.rs", "fn inner_fn() { let g = lock_or_recover(&GLOBAL.wal); }\n"),
        ]);
        let outer = g.locks_out[0].clone();
        let site = outer.get("wal").expect("wal reachable from outer");
        assert_eq!(site.file, "x/serve/b.rs");
        assert_eq!(site.line, 1);
    }

    #[test]
    fn spawn_closure_calls_do_not_leak_into_caller_closure() {
        let (_, g) = graph_of(&[(
            "x/serve/a.rs",
            "fn run() { thread::spawn(|| { worker(); }); }\n\
             fn worker() { let g = lock_or_recover(&S.wal); q.recv(); }\n",
        )]);
        assert!(g.locks_out[0].is_empty(), "spawned lock must not count against run()");
        assert!(g.blocking_out[0].is_empty());
        assert!(g.spawn_reachable[1], "worker() runs off-thread");
    }

    #[test]
    fn blocking_closure_reports_the_real_site() {
        let (_, g) = graph_of(&[(
            "x/store/a.rs",
            "fn save(f: &File) { persist(f); }\n\
             fn persist(f: &File) { f.sync_all(); }\n",
        )]);
        let site = g.blocking_out[0].get("sync_all").expect("sync_all reachable");
        assert_eq!(site.line, 2);
    }
}
