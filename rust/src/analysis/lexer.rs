//! A deliberately small Rust lexer for the static-analysis pass.
//!
//! This is not a parser: it produces a flat token stream with line
//! numbers, which is exactly enough for the token-sequence scanners in
//! [`super::lints`]. What it must get right — and what a regex pass
//! cannot — is *suppression of non-code text*: string literals
//! (including raw and byte strings), char literals vs. lifetimes, and
//! nested block comments must never leak tokens, or a log message
//! containing the word `unwrap` would trip the panic-path lint.
//!
//! Two side channels ride along with the token stream:
//! - `// analyze: allow(<lints>) <reason>` comments, parsed into
//!   [`Allow`] records for the suppression matcher;
//! - `#[cfg(test)]` / `#[test]` regions, marked per-token so lints can
//!   skip test code (where `unwrap` and friends are the contract).

/// One lexed token with the 1-based source line it starts on.
#[derive(Debug, Clone, PartialEq)]
pub struct Tok {
    pub line: u32,
    pub kind: TokKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unwrap`, `HashMap`, ...).
    Ident(String),
    /// Integer literal (`0`, `0xff`, `1_000u32`). Value is irrelevant
    /// to every lint; only the *shape* (e.g. `buf[0]`) matters.
    Int,
    /// String literal (plain, raw, or byte string), carrying its
    /// source text verbatim (escapes unprocessed) — the
    /// metrics-discipline lint checks metric-name literals.
    Str(String),
    /// Any other literal: float or char.
    Lit,
    /// Single punctuation character (`::` is two `:` tokens).
    Punct(char),
}

impl TokKind {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(self, TokKind::Ident(i) if i == s)
    }
    pub fn is_punct(&self, c: char) -> bool {
        matches!(self, TokKind::Punct(p) if *p == c)
    }
}

/// A parsed `// analyze: ...` suppression comment.
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: u32,
    /// Lint names inside `allow(...)`, e.g. `["panic-path"]`. Empty if
    /// the directive was malformed (reported as a `suppression` finding).
    pub lints: Vec<String>,
    /// Free text after the closing paren. Required: a bare allow is
    /// itself a finding.
    pub reason: String,
    /// True when the directive could not be parsed as `allow(<list>)`.
    pub malformed: bool,
}

/// The lexed form of one source file.
pub struct LexedFile {
    pub toks: Vec<Tok>,
    /// `is_test[i]` — token `i` lies inside a `#[cfg(test)]` or
    /// `#[test]` item body.
    pub is_test: Vec<bool>,
    pub allows: Vec<Allow>,
}

pub fn lex(source: &str) -> LexedFile {
    let mut toks = Vec::new();
    let mut allows = Vec::new();
    let chars: Vec<char> = source.chars().collect();
    let mut i = 0usize;
    let mut line = 1u32;
    let n = chars.len();

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comments (incl. doc comments). `// analyze:` directives
        // are captured; everything else is discarded.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            if let Some(directive) = text.trim_start_matches('/').trim().strip_prefix("analyze:") {
                allows.push(parse_allow(line, directive.trim()));
            }
            continue;
        }
        // Block comments, nested.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Raw strings / raw identifiers / byte strings: r"..", r#".."#,
        // br".."; b"..", b'x'; r#ident.
        if (c == 'r' || c == 'b') && i + 1 < n {
            let (skip, is_b) = if c == 'b' && i + 1 < n && chars[i + 1] == 'r' {
                (2, true)
            } else {
                (1, c == 'b')
            };
            let rest = i + skip;
            if rest < n
                && (chars[rest] == '"' || chars[rest] == '#')
                && (!is_b || skip == 2 || chars[rest] == '"')
            {
                if c == 'r' || skip == 2 {
                    // raw (byte) string r##"..."## — count hashes.
                    let mut j = rest;
                    let mut hashes = 0usize;
                    while j < n && chars[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && chars[j] == '"' {
                        let tok_line = line;
                        j += 1;
                        let body_start = j;
                        let mut body_end = None;
                        'raw: while j < n {
                            if chars[j] == '\n' {
                                line += 1;
                                j += 1;
                            } else if chars[j] == '"' {
                                let mut k = j + 1;
                                let mut seen = 0usize;
                                while k < n && seen < hashes && chars[k] == '#' {
                                    seen += 1;
                                    k += 1;
                                }
                                if seen == hashes {
                                    body_end = Some(j);
                                    j = k;
                                    break 'raw;
                                }
                                j += 1;
                            } else {
                                j += 1;
                            }
                        }
                        let body: String = chars[body_start..body_end.unwrap_or(j)]
                            .iter()
                            .collect();
                        toks.push(Tok {
                            line: tok_line,
                            kind: TokKind::Str(body),
                        });
                        i = j;
                        continue;
                    }
                    if hashes > 0 && c == 'r' {
                        // r#ident — raw identifier.
                        let mut j = rest + 1;
                        let start = j;
                        while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                            j += 1;
                        }
                        let ident: String = chars[start..j].iter().collect();
                        toks.push(Tok { line, kind: TokKind::Ident(ident) });
                        i = j;
                        continue;
                    }
                }
            }
            if is_b && skip == 1 && rest < n && (chars[rest] == '"' || chars[rest] == '\'') {
                // b"..." / b'x' — lex as the underlying (char) string.
                i += 1; // consume the 'b'; fall through on the quote.
            } else if c == 'r' || c == 'b' {
                // plain identifier starting with r/b — handled below.
            }
        }
        let c = chars[i];
        // String literal.
        if c == '"' {
            let tok_line = line;
            i += 1;
            let body_start = i;
            let mut body_end = n;
            while i < n {
                match chars[i] {
                    '\\' => i += 2,
                    '\n' => {
                        line += 1;
                        i += 1;
                    }
                    '"' => {
                        body_end = i;
                        i += 1;
                        break;
                    }
                    _ => i += 1,
                }
            }
            let body: String =
                chars[body_start..body_end.min(n)].iter().collect();
            toks.push(Tok { line: tok_line, kind: TokKind::Str(body) });
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            let next = chars.get(i + 1).copied().unwrap_or(' ');
            if next == '\\' {
                // escaped char literal '\n', '\'', '\u{..}'
                i += 2;
                while i < n && chars[i] != '\'' {
                    if chars[i] == '\\' {
                        i += 1;
                    }
                    i += 1;
                }
                i += 1;
                toks.push(Tok { line, kind: TokKind::Lit });
                continue;
            }
            if chars.get(i + 2).copied() == Some('\'')
                && !(next.is_alphanumeric() || next == '_')
            {
                // 'x' where x is punctuation — a char literal for sure.
                i += 3;
                toks.push(Tok { line, kind: TokKind::Lit });
                continue;
            }
            if (next.is_alphanumeric() || next == '_') && chars.get(i + 2).copied() == Some('\'') {
                // 'a' — single ident-char literal.
                i += 3;
                toks.push(Tok { line, kind: TokKind::Lit });
                continue;
            }
            // Lifetime: consume the quote + identifier, emit nothing.
            i += 1;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            continue;
        }
        // Numbers.
        if c.is_ascii_digit() {
            let mut has_dot = false;
            i += 1;
            while i < n {
                let d = chars[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                } else if d == '.'
                    && !has_dot
                    && chars.get(i + 1).is_some_and(|x| x.is_ascii_digit())
                {
                    has_dot = true;
                    i += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok {
                line,
                kind: if has_dot { TokKind::Lit } else { TokKind::Int },
            });
            continue;
        }
        // Identifiers / keywords.
        if c.is_alphanumeric() || c == '_' {
            let start = i;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            let ident: String = chars[start..i].iter().collect();
            toks.push(Tok { line, kind: TokKind::Ident(ident) });
            continue;
        }
        toks.push(Tok { line, kind: TokKind::Punct(c) });
        i += 1;
    }

    let is_test = mark_test_regions(&toks);
    LexedFile { toks, is_test, allows }
}

fn parse_allow(line: u32, directive: &str) -> Allow {
    // Expected shape: allow(lint-a, lint-b) free-text reason
    let Some(rest) = directive.strip_prefix("allow") else {
        return Allow { line, lints: Vec::new(), reason: String::new(), malformed: true };
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix('(') else {
        return Allow { line, lints: Vec::new(), reason: String::new(), malformed: true };
    };
    let Some(close) = rest.find(')') else {
        return Allow { line, lints: Vec::new(), reason: String::new(), malformed: true };
    };
    let lints: Vec<String> = rest[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let reason = rest[close + 1..].trim().to_string();
    Allow { line, lints, reason, malformed: lints.is_empty() }
}

/// Mark tokens inside `#[cfg(test)]` / `#[test]` item bodies. An
/// attribute arms the flag; the body of the next `mod`/`fn` item (its
/// outermost brace pair) is the marked region. A `;` before any `{`
/// (e.g. `#[cfg(test)] mod tests;`) disarms it.
fn mark_test_regions(toks: &[Tok]) -> Vec<bool> {
    let mut is_test = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].kind.is_punct('#') && is_test_attr(toks, i) {
            // Find the start of the next item body.
            let mut j = i + 1;
            let mut found = None;
            while j < toks.len() {
                match &toks[j].kind {
                    TokKind::Ident(id) if id == "mod" || id == "fn" => {
                        found = Some(j);
                        break;
                    }
                    TokKind::Punct(';') => break,
                    _ => {}
                }
                j += 1;
            }
            if let Some(item) = found {
                let mut k = item;
                while k < toks.len()
                    && !toks[k].kind.is_punct('{')
                    && !toks[k].kind.is_punct(';')
                {
                    k += 1;
                }
                if k < toks.len() && toks[k].kind.is_punct('{') {
                    let mut depth = 0i32;
                    let open = k;
                    while k < toks.len() {
                        if toks[k].kind.is_punct('{') {
                            depth += 1;
                        } else if toks[k].kind.is_punct('}') {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        k += 1;
                    }
                    let close = k.min(toks.len().saturating_sub(1));
                    for flag in is_test.iter_mut().take(close + 1).skip(open) {
                        *flag = true;
                    }
                    i = close + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    is_test
}

/// `toks[i]` is `#`; does `#[cfg(test)]` or `#[test]` start here?
fn is_test_attr(toks: &[Tok], i: usize) -> bool {
    let at = |off: usize| toks.get(i + off).map(|t| &t.kind);
    if !matches!(at(1), Some(k) if k.is_punct('[')) {
        return false;
    }
    match at(2) {
        Some(k) if k.is_ident("test") => matches!(at(3), Some(k) if k.is_punct(']')),
        Some(k) if k.is_ident("cfg") => {
            matches!(at(3), Some(k) if k.is_punct('('))
                && matches!(at(4), Some(k) if k.is_ident("test"))
                && matches!(at(5), Some(k) if k.is_punct(')'))
                && matches!(at(6), Some(k) if k.is_punct(']'))
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .iter()
            .filter_map(|t| match &t.kind {
                TokKind::Ident(s) => Some(s.clone()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_and_comments_emit_no_idents() {
        let src = r##"
            let s = "call .unwrap() here"; // unwrap in a comment
            /* unwrap /* nested unwrap */ still comment */
            let r = r#"raw unwrap"#;
            let c = 'u';
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()), "{ids:?}");
        assert!(ids.contains(&"let".to_string()));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }";
        let ids = idents(src);
        assert!(ids.contains(&"str".to_string()));
        // the lifetime name never becomes a stray literal
        let lits = lex(src).toks.iter().filter(|t| t.kind == TokKind::Lit).count();
        assert_eq!(lits, 0);
    }

    #[test]
    fn line_numbers_track_newlines_everywhere() {
        let src = "a\n\"two\nline\"\nb";
        let lx = lex(src);
        let b = lx.toks.iter().find(|t| t.kind.is_ident("b")).unwrap();
        assert_eq!(b.line, 4);
    }

    #[test]
    fn allow_directive_parses() {
        let src = "// analyze: allow(panic-path, determinism) bounded by take()\nlet x = 1;";
        let lx = lex(src);
        assert_eq!(lx.allows.len(), 1);
        let a = &lx.allows[0];
        assert_eq!(a.line, 1);
        assert_eq!(a.lints, vec!["panic-path", "determinism"]);
        assert_eq!(a.reason, "bounded by take()");
        assert!(!a.malformed);
    }

    #[test]
    fn bare_allow_has_empty_reason() {
        let lx = lex("// analyze: allow(panic-path)\n");
        assert_eq!(lx.allows[0].reason, "");
        assert!(!lx.allows[0].malformed);
    }

    #[test]
    fn malformed_directive_is_marked() {
        let lx = lex("// analyze: suppress everything\n");
        assert!(lx.allows[0].malformed);
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n\
                   fn t() { y.unwrap(); }\n}\n";
        let lx = lex(src);
        let unwraps: Vec<(u32, bool)> = lx
            .toks
            .iter()
            .zip(&lx.is_test)
            .filter(|(t, _)| t.kind.is_ident("unwrap"))
            .map(|(t, test)| (t.line, *test))
            .collect();
        assert_eq!(unwraps, vec![(1, false), (4, true)]);
    }

    #[test]
    fn string_literals_carry_their_text() {
        let src = "let a = \"wal_fsyncs_total\";\nlet b = r#\"raw body\"#;\n\
                   let c = \"esc\\\"aped\";";
        let strs: Vec<String> = lex(src)
            .toks
            .iter()
            .filter_map(|t| match &t.kind {
                TokKind::Str(s) => Some(s.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(strs,
                   vec!["wal_fsyncs_total", "raw body", "esc\\\"aped"]);
    }

    #[test]
    fn integer_vs_float_literals() {
        let lx = lex("a[0] + 1.5 + 0x1f");
        let ints = lx.toks.iter().filter(|t| t.kind == TokKind::Int).count();
        let lits = lx.toks.iter().filter(|t| t.kind == TokKind::Lit).count();
        assert_eq!(ints, 2);
        assert_eq!(lits, 1);
    }

    #[test]
    fn range_does_not_eat_dots() {
        let lx = lex("for i in 0..10 {}");
        let dots = lx.toks.iter().filter(|t| t.kind.is_punct('.')).count();
        assert_eq!(dots, 2);
    }
}
