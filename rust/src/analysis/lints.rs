//! The repo-specific lint passes.
//!
//! Each pass is a token-sequence scanner over [`super::lexer::LexedFile`];
//! none of them parse Rust. The trade-off is spelled out per lint: a
//! pattern is chosen so that the *absence* of findings is meaningful
//! (no false-negative shapes exist in this codebase), while the rare
//! legitimate hit is suppressed inline with a reasoned
//! `// analyze: allow(<lint>) <reason>`.
//!
//! Scopes are path-substring based so the fixture corpus under
//! `tests/analysis_fixtures/` classifies the same way the live tree
//! does (`.../analysis_fixtures/serve/foo.rs` is "in `serve/`").

use super::graph::CallGraph;
use super::lexer::{LexedFile, Tok, TokKind};
use super::model::{
    self, acquisitions, binding_name, fn_spans, ident_at, is_int, is_punct, FileModel,
    SpawnBinding, SpawnKind, LOCK_METHODS,
};
use super::order;

/// One unsuppressed (or to-be-suppressed) lint hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub lint: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// Every lint the pass knows; `allow(<name>)` directives are checked
/// against this list so a typo'd suppression is a finding, not a no-op.
pub const LINT_NAMES: &[&str] = &[
    "determinism",
    "lock-discipline",
    "lock-order-transitive",
    "blocking-under-lock",
    "atomics-discipline",
    "resource-leak",
    "panic-path",
    "framing-casts",
    "log-discipline",
    "io-durability",
    "obs-discipline",
    "metrics-discipline",
    "suppression",
];

/// fifo / EventLog-emitting modules: anything here that iterates an
/// unordered map or reads a wall clock can break byte-determinism.
fn fifo_scope(rel: &str) -> bool {
    rel.contains("serve/") || rel.contains("store/") || rel.contains("coordinator/")
}

/// Serving + durability tier: typed errors are the contract, panics are
/// findings.
fn serve_store_scope(rel: &str) -> bool {
    rel.contains("serve/") || rel.contains("store/")
}

/// The serving path (serving tier + its telemetry layer), where the
/// span clock is the only sanctioned wall-clock source. `obs/span.rs`
/// defines that clock and is the one exempt module.
fn obs_scope(rel: &str) -> bool {
    (rel.contains("serve/") || rel.contains("obs/")) && !rel.contains("obs/span.rs")
}

/// Binary framing code: every narrowing cast is a silent-truncation bug
/// waiting for a >64 KiB tenant name.
fn framing_scope(rel: &str) -> bool {
    ["store/wal.rs", "store/snapshot.rs", "store/recover.rs", "coordinator/checkpoint.rs"]
        .iter()
        .any(|f| rel.contains(f))
}

/// Library modules where the EventLog is the only sanctioned sink.
/// `main.rs` (the CLI), `report/` (table rendering) and `util/bench.rs`
/// (the bench timer) print by design and are out of scope.
fn log_scope(rel: &str) -> bool {
    let included = [
        "serve/", "store/", "coordinator/", "runtime/", "quantum/", "peft/", "data/",
        "metrics/", "config/", "util/",
    ];
    included.iter().any(|d| rel.contains(d)) && !rel.contains("util/bench.rs")
}

/// The serving/durability/telemetry tier plus the worker pool: where
/// the interprocedural (call-graph) lints report. Models are extracted
/// crate-wide so closures see through every module; only findings in
/// these files surface.
fn interproc_scope(rel: &str) -> bool {
    rel.contains("serve/")
        || rel.contains("store/")
        || rel.contains("obs/")
        || rel.contains("util/pool.rs")
}

/// Everywhere metrics registration happens. `obs/metrics.rs` is the
/// registry implementation itself (its internals and doctests register
/// freely) and is the one exempt module.
fn metrics_scope(rel: &str) -> bool {
    let included = [
        "serve/", "store/", "coordinator/", "runtime/", "obs/", "util/",
        "quantum/", "peft/", "data/", "config/",
    ];
    included.iter().any(|d| rel.contains(d)) && !rel.ends_with("obs/metrics.rs")
}

pub fn run_all(rel: &str, lx: &LexedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    determinism(rel, lx, &mut out);
    lock_discipline(rel, lx, &mut out);
    panic_path(rel, lx, &mut out);
    framing_casts(rel, lx, &mut out);
    log_discipline(rel, lx, &mut out);
    io_durability(rel, lx, &mut out);
    obs_discipline(rel, lx, &mut out);
    out
}

// ---------------------------------------------------------------- determinism

const ITER_METHODS: &[&str] = &[
    "iter", "iter_mut", "into_iter", "keys", "into_keys", "values", "values_mut",
    "into_values", "drain", "retain",
];

fn determinism(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !fifo_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    // Pass 1: names bound (field or let) to a HashMap/HashSet type.
    let mut unordered: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if ident_at(toks, i).is_some_and(|id| id == "HashMap" || id == "HashSet") {
            if let Some(name) = binding_name(toks, i) {
                if !unordered.contains(&name) {
                    unordered.push(name);
                }
            }
        }
    }
    // Pass 2: iteration over those names, and wall-clock reads.
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if let Some(src) = ident_at(toks, i).filter(|id| *id == "Instant" || *id == "SystemTime")
        {
            if is_punct(toks, i + 1, ':')
                && is_punct(toks, i + 2, ':')
                && ident_at(toks, i + 3) == Some("now")
            {
                out.push(Finding {
                    lint: "determinism",
                    file: rel.to_string(),
                    line: toks[i].line,
                    message: format!(
                        "{src}::now() in a fifo/EventLog module — wall-clock reads break \
                         byte-determinism; thread a logical clock through, or allow with \
                         the reason the value never reaches a deterministic output"
                    ),
                });
            }
        }
        let Some(name) = ident_at(toks, i).filter(|n| unordered.iter().any(|u| u.as_str() == *n))
        else {
            continue;
        };
        let method_iter = is_punct(toks, i + 1, '.')
            && ident_at(toks, i + 2).is_some_and(|m| ITER_METHODS.contains(&m))
            && is_punct(toks, i + 3, '(');
        let for_iter = preceded_by_in(toks, i);
        if method_iter || for_iter {
            out.push(Finding {
                lint: "determinism",
                file: rel.to_string(),
                line: toks[i].line,
                message: format!(
                    "iteration over unordered map/set `{name}` — HashMap order is \
                     nondeterministic; use BTreeMap or sort the keys first \
                     (fifo byte-determinism)"
                ),
            });
        }
    }
}

/// Is `toks[i]` (the map name, possibly the tail of a dotted path) the
/// iterated expression of a `for ... in` / preceded by `&`/`&mut`?
fn preceded_by_in(toks: &[Tok], i: usize) -> bool {
    let mut j = i;
    // walk back over `owner .` chains: `inner . entries`
    while j >= 2 && is_punct(toks, j - 1, '.') && ident_at(toks, j - 2).is_some() {
        j -= 2;
    }
    // skip `&` / `mut`
    while j >= 1 && (is_punct(toks, j - 1, '&') || ident_at(toks, j - 1) == Some("mut")) {
        j -= 1;
    }
    j >= 1 && ident_at(toks, j - 1) == Some("in")
}

// ------------------------------------------------------------ lock-discipline

fn lock_discipline(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !serve_store_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    // a) `.lock().unwrap()` / `.read().expect(...)` etc: poison panics.
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if is_punct(toks, i, '.')
            && ident_at(toks, i + 1).is_some_and(|m| LOCK_METHODS.contains(&m))
            && is_punct(toks, i + 2, '(')
            && is_punct(toks, i + 3, ')')
            && is_punct(toks, i + 4, '.')
            && ident_at(toks, i + 5).is_some_and(|u| u == "unwrap" || u == "expect")
            && is_punct(toks, i + 6, '(')
        {
            let m = ident_at(toks, i + 1).unwrap_or("lock");
            out.push(Finding {
                lint: "lock-discipline",
                file: rel.to_string(),
                line: toks[i + 5].line,
                message: format!(
                    "`.{m}()` + unwrap poisons-and-panics the whole fleet after one \
                     worker crash — use util::sync::{m}_or_recover"
                ),
            });
        }
    }
    // b) nested acquisition order vs analysis/order.rs.
    let declared = order::order_for(rel);
    for span in fn_spans(lx) {
        let acqs = acquisitions(toks, span);
        match declared {
            Some(list) => {
                let mut max_idx: Option<usize> = None;
                let mut max_name = String::new();
                for a in &acqs {
                    if !a.held {
                        continue;
                    }
                    let Some(idx) = list.iter().position(|n| *n == a.name.as_str()) else {
                        continue;
                    };
                    if let Some(m) = max_idx {
                        if idx < m {
                            out.push(Finding {
                                lint: "lock-discipline",
                                file: rel.to_string(),
                                line: a.line,
                                message: format!(
                                    "lock `{}` acquired while `{}` is held — declared \
                                     order in analysis/order.rs is {:?}",
                                    a.name, max_name, list
                                ),
                            });
                        }
                    }
                    let is_new_max = match max_idx {
                        Some(m) => idx > m,
                        None => true,
                    };
                    if is_new_max {
                        max_idx = Some(idx);
                        max_name = a.name.clone();
                    }
                }
            }
            None => {
                let mut held: Vec<&model::Acq> = Vec::new();
                for a in &acqs {
                    if a.held && !held.iter().any(|h| h.name == a.name) {
                        held.push(a);
                    }
                }
                if held.len() >= 2 {
                    let names: Vec<&str> = held.iter().map(|a| a.name.as_str()).collect();
                    out.push(Finding {
                        lint: "lock-discipline",
                        file: rel.to_string(),
                        line: held[1].line,
                        message: format!(
                            "nested held locks {names:?} in one fn but this file has no \
                             entry in analysis/order.rs — declare the acquisition order"
                        ),
                    });
                }
            }
        }
    }
}

// ----------------------------------------------------------------- panic-path

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

fn panic_path(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !serve_store_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if is_punct(toks, i, '.')
            && ident_at(toks, i + 1).is_some_and(|m| m == "unwrap" || m == "expect")
            && is_punct(toks, i + 2, '(')
        {
            // `.lock().unwrap()` already reported by lock-discipline.
            let lock_chain = i >= 4
                && is_punct(toks, i - 1, ')')
                && is_punct(toks, i - 2, '(')
                && ident_at(toks, i - 3).is_some_and(|m| LOCK_METHODS.contains(&m))
                && is_punct(toks, i - 4, '.');
            if !lock_chain {
                let m = ident_at(toks, i + 1).unwrap_or("unwrap");
                out.push(Finding {
                    lint: "panic-path",
                    file: rel.to_string(),
                    line: toks[i + 1].line,
                    message: format!(
                        "`.{m}()` in serve/store non-test code — typed errors are the \
                         contract here; propagate or handle, or allow with the \
                         invariant that makes it unreachable"
                    ),
                });
            }
        }
        if ident_at(toks, i).is_some_and(|m| PANIC_MACROS.contains(&m))
            && is_punct(toks, i + 1, '!')
            && is_punct(toks, i + 2, '(')
        {
            let m = ident_at(toks, i).unwrap_or("panic");
            out.push(Finding {
                lint: "panic-path",
                file: rel.to_string(),
                line: toks[i].line,
                message: format!(
                    "`{m}!` in serve/store non-test code — a panicking worker takes \
                     its whole shard down; return a typed error"
                ),
            });
        }
        if is_punct(toks, i, '[')
            && is_int(toks, i + 1)
            && is_punct(toks, i + 2, ']')
            && i >= 1
            && (ident_at(toks, i - 1).is_some()
                || is_punct(toks, i - 1, ')')
                || is_punct(toks, i - 1, ']'))
        {
            out.push(Finding {
                lint: "panic-path",
                file: rel.to_string(),
                line: toks[i].line,
                message: "literal indexing can panic — use .get()/.first() or a slice \
                          pattern, or allow with the bound that guarantees the length"
                    .to_string(),
            });
        }
    }
}

// -------------------------------------------------------------- framing-casts

fn framing_casts(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !framing_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if ident_at(toks, i) == Some("as") {
            if let Some(ty) =
                ident_at(toks, i + 1).filter(|t| ["u16", "u32", "usize"].contains(t))
            {
                out.push(Finding {
                    lint: "framing-casts",
                    file: rel.to_string(),
                    line: toks[i].line,
                    message: format!(
                        "bare `as {ty}` in framing code silently truncates — use \
                         {ty}::try_from and surface a typed encode/CorruptState error"
                    ),
                });
            }
        }
    }
}

// ------------------------------------------------------------- log-discipline

const PRINT_MACROS: &[&str] = &["println", "eprintln", "print", "eprint"];

fn log_discipline(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !log_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if ident_at(toks, i).is_some_and(|m| PRINT_MACROS.contains(&m))
            && is_punct(toks, i + 1, '!')
            && is_punct(toks, i + 2, '(')
        {
            let m = ident_at(toks, i).unwrap_or("println");
            out.push(Finding {
                lint: "log-discipline",
                file: rel.to_string(),
                line: toks[i].line,
                message: format!(
                    "`{m}!` in a library module — the EventLog is the only sanctioned \
                     sink (stdout interleaving breaks fifo log comparisons)"
                ),
            });
        }
    }
}

// -------------------------------------------------------------- io-durability

fn io_durability(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !rel.contains("store/") {
        return;
    }
    let toks = &lx.toks;
    let spans = fn_spans(lx);
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        let creates = (ident_at(toks, i) == Some("File")
            && is_punct(toks, i + 1, ':')
            && is_punct(toks, i + 2, ':')
            && ident_at(toks, i + 3) == Some("create"))
            || (ident_at(toks, i) == Some("fs")
                && is_punct(toks, i + 1, ':')
                && is_punct(toks, i + 2, ':')
                && ident_at(toks, i + 3) == Some("write"));
        if !creates {
            continue;
        }
        let span = spans.iter().find(|(open, close)| i >= *open && i <= *close);
        let synced = span.is_some_and(|(open, close)| {
            (*open..*close)
                .any(|k| ident_at(toks, k).is_some_and(|s| s == "sync_all" || s == "sync_data"))
        });
        if !synced {
            out.push(Finding {
                lint: "io-durability",
                file: rel.to_string(),
                line: toks[i].line,
                message: "file written in store/ without an fsync in the same fn — \
                          durability requires the write-temp + sync_all + atomic-rename \
                          idiom"
                    .to_string(),
            });
        }
    }
}

// ------------------------------------------------------------- obs-discipline

fn obs_discipline(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !obs_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if let Some(src) = ident_at(toks, i).filter(|id| *id == "Instant" || *id == "SystemTime")
        {
            if is_punct(toks, i + 1, ':')
                && is_punct(toks, i + 2, ':')
                && ident_at(toks, i + 3) == Some("now")
            {
                out.push(Finding {
                    lint: "obs-discipline",
                    file: rel.to_string(),
                    line: toks[i].line,
                    message: format!(
                        "{src}::now() on the serving path outside obs/span.rs — the \
                         SpanClock is the only sanctioned wall-clock source (fifo \
                         latencies are logical); take timestamps from the session's \
                         clock, or allow with the reason the read never shapes a \
                         latency or an emitted line"
                    ),
                });
            }
        }
    }
}

// --------------------------------------------------------- metrics-discipline

/// Metric names are an operational contract: a dashboard, an alert or a
/// grep must find the one registration site from the exported name
/// alone. Three shapes break that and are findings:
/// - a computed name (`reg.counter(&format!(..), ..)`) — unfindable;
/// - a non-snake_case literal — breaks the naming convention every
///   exporter and dashboard assumes (`[a-z][a-z0-9_]*`);
/// - the same literal registered at two non-test call sites — the name
///   no longer identifies its owner; route both through one
///   `register()` helper.
///
/// The once-crate-wide check is global, so this pass runs over the
/// whole file set (routed like [`run_interproc`], not [`run_all`]).
pub fn metrics_discipline(files: &[(&str, &LexedFile)]) -> Vec<Finding> {
    let mut out = Vec::new();
    // literal registration sites in scan order: (name, file, line)
    let mut sites: Vec<(String, String, u32)> = Vec::new();
    for (rel, lx) in files {
        if !metrics_scope(rel) {
            continue;
        }
        let toks = &lx.toks;
        for i in 0..toks.len() {
            if lx.is_test[i] {
                continue;
            }
            let Some(kind) = ident_at(toks, i + 1)
                .filter(|m| ["counter", "gauge", "hist"].contains(m))
            else {
                continue;
            };
            if !(is_punct(toks, i, '.') && is_punct(toks, i + 2, '(')) {
                continue;
            }
            match toks.get(i + 3).map(|t| &t.kind) {
                Some(TokKind::Str(name)) => {
                    if snake_case_metric(name) {
                        sites.push((name.clone(), rel.to_string(), toks[i + 3].line));
                    } else {
                        out.push(Finding {
                            lint: "metrics-discipline",
                            file: rel.to_string(),
                            line: toks[i + 3].line,
                            message: format!(
                                "metric name \"{name}\" is not snake_case — exported \
                                 names are a grep/dashboard contract ([a-z][a-z0-9_]*, \
                                 prefixes like wal_/serve_, counters end in _total)"
                            ),
                        });
                    }
                }
                _ => {
                    out.push(Finding {
                        lint: "metrics-discipline",
                        file: rel.to_string(),
                        line: toks[i + 1].line,
                        message: format!(
                            "`.{kind}(` with a computed metric name — names must be \
                             string literals so every exported metric greps back to \
                             its one registration site"
                        ),
                    });
                }
            }
        }
    }
    for (k, (name, file, line)) in sites.iter().enumerate() {
        if let Some((_, f0, l0)) = sites[..k].iter().find(|(n, _, _)| n == name) {
            out.push(Finding {
                lint: "metrics-discipline",
                file: file.clone(),
                line: *line,
                message: format!(
                    "metric `{name}` already registered at {f0}:{l0} — each name has \
                     exactly one non-test registration site; share the handle or \
                     route both through one register() helper"
                ),
            });
        }
    }
    out
}

/// `[a-z][a-z0-9_]*` — the exported-name grammar every dashboard query
/// in this repo assumes.
fn snake_case_metric(name: &str) -> bool {
    let mut chars = name.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_lowercase())
        && chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

// ------------------------------------------------------- interprocedural pass

/// The four call-graph lints. Models cover the whole crate; findings
/// are attributed to the *caller's* file and line (the place a human
/// would add the allow or restructure the code), with the reached
/// site named in the message.
pub fn run_interproc(models: &[FileModel], graph: &CallGraph) -> Vec<Finding> {
    let mut out = Vec::new();
    for (id, &(fi, fj)) in graph.fns.iter().enumerate() {
        if !interproc_scope(&models[fi].rel) {
            continue;
        }
        walk_fn(models, graph, id, fi, fj, &mut out);
    }
    atomics_discipline(models, graph, &mut out);
    resource_leak(models, &mut out);
    out
}

/// One event inside a fn body, ordered by token position.
enum Ev<'a> {
    Acq(&'a model::Acq),
    Drop(&'a model::DropSite),
    Block(&'a model::BlockingSite),
    Call(usize),
}

/// Walk one fn body in token order, tracking the set of *declared*
/// held guards, and report `lock-order-transitive` /
/// `blocking-under-lock` findings at the sites where a held guard
/// meets a reachable acquisition or a blocking call.
fn walk_fn(
    models: &[FileModel],
    graph: &CallGraph,
    id: usize,
    fi: usize,
    fj: usize,
    out: &mut Vec<Finding>,
) {
    let m = &models[fi];
    let f = &m.fns[fj];
    let mut evs: Vec<(usize, Ev)> = Vec::new();
    for a in &f.acqs {
        if a.held && !a.in_spawn && order::global_idx(&a.name).is_some() {
            evs.push((a.tok, Ev::Acq(a)));
        }
    }
    for d in &f.drops {
        evs.push((d.tok, Ev::Drop(d)));
    }
    for b in &f.blocking {
        if !b.in_spawn {
            evs.push((b.tok, Ev::Block(b)));
        }
    }
    for (ci, c) in f.calls.iter().enumerate() {
        if !c.in_spawn {
            evs.push((c.tok, Ev::Call(ci)));
        }
    }
    evs.sort_by_key(|(tok, _)| *tok);

    // (lock name, global index, guard binding, acquisition line, scope end)
    let mut held: Vec<(&str, usize, Option<&str>, u32, usize)> = Vec::new();
    let mut seen: Vec<(u32, String)> = Vec::new(); // (line, dedup key)
    for (tok, ev) in &evs {
        // block-scoped guards (`{ let g = lock(..); ... }`) release at
        // their closing brace, not at fn end
        held.retain(|&(_, _, _, _, se)| se >= *tok);
        match ev {
            Ev::Acq(a) => {
                let idx = order::global_idx(&a.name).unwrap_or(usize::MAX);
                held.push((a.name.as_str(), idx, a.binding.as_deref(), a.line, a.scope_end));
            }
            Ev::Drop(d) => held.retain(|(_, _, b, _, _)| *b != Some(d.name.as_str())),
            Ev::Block(b) => {
                let Some((lock, _, _, aline, _)) = held.last() else { continue };
                let key = (b.line, format!("local:{}", b.what));
                if seen.contains(&key) {
                    continue;
                }
                out.push(Finding {
                    lint: "blocking-under-lock",
                    file: m.rel.clone(),
                    line: b.line,
                    message: format!(
                        "`{}` while `{lock}` (acquired line {aline}) is held — blocking \
                         I/O under a declared lock stalls every waiter; move it after \
                         the guard drops",
                        b.what
                    ),
                });
                seen.push(key);
            }
            Ev::Call(ci) => {
                if held.is_empty() {
                    continue;
                }
                let c = &f.calls[*ci];
                for &t in &graph.call_targets[id][*ci] {
                    if t == id {
                        continue;
                    }
                    for (lock, site) in &graph.locks_out[t] {
                        let Some(lidx) = order::global_idx(lock) else { continue };
                        for &(hname, hidx, hbind, _, _) in &held {
                            if lidx < hidx {
                                let key = (c.line, format!("inv:{lock}:{hname}"));
                                if seen.contains(&key) {
                                    continue;
                                }
                                out.push(Finding {
                                    lint: "lock-order-transitive",
                                    file: m.rel.clone(),
                                    line: c.line,
                                    message: format!(
                                        "call to `{}` acquires `{lock}` ({}:{}) while \
                                         `{hname}` is held — `{lock}` precedes `{hname}` \
                                         in analysis/order.rs GLOBAL_ORDER",
                                        graph.display_name(t),
                                        site.file,
                                        site.line
                                    ),
                                });
                                seen.push(key);
                            } else if lidx == hidx {
                                // a method invoked *on the guard itself*
                                // (`wal.last_seq()` with `wal` the held
                                // guard) runs on the already-locked value
                                // and cannot re-acquire its own mutex; the
                                // name-unioned callee that does lock is a
                                // different fn
                                if c.recv.is_some() && c.recv.as_deref() == hbind {
                                    continue;
                                }
                                let key = (c.line, format!("re:{lock}"));
                                if seen.contains(&key) {
                                    continue;
                                }
                                out.push(Finding {
                                    lint: "lock-order-transitive",
                                    file: m.rel.clone(),
                                    line: c.line,
                                    message: format!(
                                        "call to `{}` re-acquires `{lock}` ({}:{}) \
                                         already held by the caller — self-deadlock on \
                                         a non-reentrant lock",
                                        graph.display_name(t),
                                        site.file,
                                        site.line
                                    ),
                                });
                                seen.push(key);
                            }
                        }
                    }
                    let (hname, _, _, _, _) = held[held.len() - 1];
                    for (what, site) in &graph.blocking_out[t] {
                        let key = (c.line, format!("blk:{what}"));
                        if seen.contains(&key) {
                            continue;
                        }
                        out.push(Finding {
                            lint: "blocking-under-lock",
                            file: m.rel.clone(),
                            line: c.line,
                            message: format!(
                                "call to `{}` reaches `{what}` ({}:{}) while `{hname}` \
                                 is held — blocking I/O under a declared lock stalls \
                                 every waiter",
                                graph.display_name(t),
                                site.file,
                                site.line
                            ),
                        });
                        seen.push(key);
                    }
                }
            }
        }
    }
}

/// `Ordering::Relaxed` on an `AtomicBool` that both the spawning side
/// and a spawned thread touch carries no happens-before edge: the
/// spawned thread can spin on a stale value past the store, or — worse
/// — observe the flag without the writes the flag was supposed to
/// publish. `compare_exchange_weak` outside a retry loop can fail
/// spuriously even when the comparison holds.
fn atomics_discipline(models: &[FileModel], graph: &CallGraph, out: &mut Vec<Finding>) {
    // Group every op crate-wide by flag name; crossing is a global
    // property (the flag may be stored in one module, polled in
    // another).
    let mut names: Vec<&str> = Vec::new();
    for m in models {
        for op in &m.atomic_ops {
            if !names.contains(&op.name.as_str()) {
                names.push(&op.name);
            }
        }
    }
    for name in names {
        let mut spawn_side = false;
        let mut main_side = false;
        for (fi, m) in models.iter().enumerate() {
            for op in m.atomic_ops.iter().filter(|o| o.name == name) {
                let off_thread = op.in_spawn
                    || op
                        .fn_idx
                        .is_some_and(|fj| graph.spawn_reachable[graph.id_of(fi, fj)]);
                if off_thread {
                    spawn_side = true;
                } else {
                    main_side = true;
                }
            }
        }
        if !(spawn_side && main_side) {
            continue;
        }
        for m in models.iter().filter(|m| interproc_scope(&m.rel)) {
            for op in m.atomic_ops.iter().filter(|o| o.name == name && o.relaxed) {
                out.push(Finding {
                    lint: "atomics-discipline",
                    file: m.rel.clone(),
                    line: op.line,
                    message: format!(
                        "`{name}.{}(Relaxed)` on a cross-thread AtomicBool flag — \
                         Relaxed carries no happens-before edge across the spawn; \
                         use Release for the store and Acquire for the load",
                        op.op
                    ),
                });
            }
        }
    }
    for m in models.iter().filter(|m| interproc_scope(&m.rel)) {
        for op in &m.atomic_ops {
            if op.op == "compare_exchange_weak" && !op.in_loop {
                out.push(Finding {
                    lint: "atomics-discipline",
                    file: m.rel.clone(),
                    line: op.line,
                    message: format!(
                        "`{}.compare_exchange_weak` outside a retry loop — the weak \
                         variant may fail spuriously even when the comparison holds; \
                         loop on it or use compare_exchange",
                        op.name
                    ),
                });
            }
        }
    }
}

/// Spawn handles that no path joins or stores. `thread::spawn` handles
/// dropped on the floor detach the thread (its panics and its work are
/// lost silently); a `Background` handle dropped at the spawn
/// statement *joins immediately* (Drop joins), silently serializing
/// what was meant to be concurrent. Scoped spawns are exempt — the
/// scope joins them.
fn resource_leak(models: &[FileModel], out: &mut Vec<Finding>) {
    for m in models.iter().filter(|m| interproc_scope(&m.rel)) {
        for f in &m.fns {
            for s in &f.spawns {
                if s.in_spawn || s.kind == SpawnKind::Scoped {
                    continue;
                }
                match (&s.kind, &s.bound) {
                    (SpawnKind::Thread, SpawnBinding::Discarded | SpawnBinding::Wildcard) => {
                        out.push(Finding {
                            lint: "resource-leak",
                            file: m.rel.clone(),
                            line: s.line,
                            message: "thread::spawn handle discarded — the thread is \
                                      detached and its panic/result is lost; bind the \
                                      handle and join it (or store it for shutdown)"
                                .to_string(),
                        });
                    }
                    (SpawnKind::Thread, SpawnBinding::Named(name)) => {
                        if !s.used_later {
                            out.push(Finding {
                                lint: "resource-leak",
                                file: m.rel.clone(),
                                line: s.line,
                                message: format!(
                                    "thread handle `{name}` is never joined or stored \
                                     after the spawn — the thread detaches when the \
                                     binding drops; join it before returning"
                                ),
                            });
                        }
                    }
                    (SpawnKind::Background, SpawnBinding::Discarded | SpawnBinding::Wildcard) => {
                        out.push(Finding {
                            lint: "resource-leak",
                            file: m.rel.clone(),
                            line: s.line,
                            message: "Background handle dropped at the spawn statement — \
                                      Drop joins immediately, so the work runs serially; \
                                      bind the handle for the concurrent section"
                                .to_string(),
                        });
                    }
                    _ => {}
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        run_all(rel, &lex(src))
    }

    #[test]
    fn hashmap_iteration_flagged_btreemap_not() {
        let src = "struct S { entries: HashMap<K, V>, sorted: BTreeMap<K, V> }\n\
                   fn f(s: &S) { for k in s.entries.keys() {} for k in s.sorted.keys() {} }\n";
        let f = findings("x/serve/cache.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, "determinism");
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn wall_clock_flagged_in_scope_only() {
        // store/ is fifo scope without the obs-discipline overlap, so
        // exactly the determinism lint fires
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(findings("x/store/a.rs", src).len(), 1);
        assert_eq!(findings("x/report/a.rs", src).len(), 0);
    }

    #[test]
    fn lock_unwrap_flagged_and_not_double_counted() {
        let src = "fn f(m: &Mutex<u32>) { let g = m.lock().unwrap(); }\n";
        let f = findings("x/serve/a.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, "lock-discipline");
    }

    #[test]
    fn order_inversion_flagged() {
        // registry order is inner < tenants: acquiring inner after
        // tenants (both held) is the inversion.
        let src = "fn f(&self) {\n let t = write_or_recover(&self.tenants);\n \
                   let i = lock_or_recover(&self.inner);\n}\n";
        let f = findings("x/serve/registry.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("declared order"), "{f:?}");
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn temporary_guard_is_not_held() {
        let src = "fn f(&self) {\n let t = write_or_recover(&self.tenants);\n \
                   *lock_or_recover(&self.inner) += 1;\n}\n";
        assert_eq!(findings("x/serve/registry.rs", src).len(), 0);
    }

    #[test]
    fn undeclared_nested_locks_flagged() {
        let src = "fn f(&self) {\n let a = lock_or_recover(&self.alpha);\n \
                   let b = lock_or_recover(&self.beta);\n}\n";
        let f = findings("x/serve/nolist_xyz.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, "lock-discipline");
        assert!(f[0].message.contains("analysis/order.rs"), "{f:?}");
    }

    #[test]
    fn panic_macros_and_literal_indexing() {
        let src = "fn f(v: &[u8]) -> u8 { if v.is_empty() { panic!(\"no\") } v[0] }\n";
        let f = findings("x/store/a.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n fn f(v: &[u8]) { v[0]; x.unwrap(); }\n}\n";
        assert_eq!(findings("x/serve/a.rs", src).len(), 0);
    }

    #[test]
    fn framing_cast_flagged_in_framing_files_only() {
        let src = "fn f(n: u64) -> u32 { n as u32 }\n";
        assert_eq!(findings("x/store/wal.rs", src).len(), 1);
        assert_eq!(findings("x/store/mod.rs", src).len(), 0);
    }

    #[test]
    fn println_flagged_in_library_not_report() {
        let src = "fn f() { println!(\"x\"); }\n";
        assert_eq!(findings("x/serve/a.rs", src).len(), 1);
        assert_eq!(findings("x/report/tables.rs", src).len(), 0);
        assert_eq!(findings("x/util/bench.rs", src).len(), 0);
    }

    #[test]
    fn wall_clock_in_serve_hits_both_clock_lints() {
        // serve/ is in both the determinism and obs-discipline scopes:
        // one bare Instant::now() yields one finding per lint
        let src = "fn f() { let t = Instant::now(); }\n";
        let f = findings("x/serve/a.rs", src);
        let lints: Vec<&str> = f.iter().map(|x| x.lint).collect();
        assert!(lints.contains(&"determinism"), "{f:?}");
        assert!(lints.contains(&"obs-discipline"), "{f:?}");
        assert_eq!(f.len(), 2, "{f:?}");
    }

    #[test]
    fn obs_discipline_covers_obs_but_exempts_span_clock() {
        // obs/ is outside the fifo (determinism) scope but inside the
        // obs-discipline scope — except span.rs, the sanctioned clock
        let src = "fn f() { let t = SystemTime::now(); }\n";
        let f = findings("x/obs/hist.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, "obs-discipline");
        assert_eq!(findings("x/obs/span.rs", src).len(), 0);
        // and modules off the serving path are untouched
        assert_eq!(findings("x/report/a.rs", src).len(), 0);
    }

    fn metrics_findings(files: &[(&str, &str)]) -> Vec<Finding> {
        let lexed: Vec<LexedFile> = files.iter().map(|(_, s)| lex(s)).collect();
        let pairs: Vec<(&str, &LexedFile)> =
            files.iter().map(|(r, _)| *r).zip(lexed.iter()).collect();
        metrics_discipline(&pairs)
    }

    #[test]
    fn metric_literal_once_is_clean() {
        let src = "fn r(reg: &MetricsRegistry) {\n\
                   let c = reg.counter(\"wal_appends_total\", &[], Class::Stable);\n}\n";
        assert_eq!(metrics_findings(&[("x/store/mod.rs", src)]), vec![]);
    }

    #[test]
    fn computed_metric_name_flagged() {
        let src = "fn r(reg: &R, n: &str) { reg.counter(&format!(\"{n}_total\"), \
                   &[], Class::Stable); }\n";
        let f = metrics_findings(&[("x/serve/a.rs", src)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, "metrics-discipline");
        assert!(f[0].message.contains("computed"), "{f:?}");
    }

    #[test]
    fn non_snake_case_metric_name_flagged() {
        let src = "fn r(reg: &R) { reg.hist(\"FxLatencyNs\", &[], Class::Stable); }\n";
        let f = metrics_findings(&[("x/obs/recorder.rs", src)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("snake_case"), "{f:?}");
    }

    #[test]
    fn duplicate_registration_flagged_at_second_site() {
        let a = "fn r(reg: &R) { reg.counter(\"dup_total\", &[], Class::Stable); }\n";
        let b = "fn s(reg: &R) {\n reg.counter(\"dup_total\", &[], Class::Stable); }\n";
        let f = metrics_findings(&[("x/serve/a.rs", a), ("x/store/b.rs", b)]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].file, "x/store/b.rs");
        assert_eq!(f[0].line, 2);
        assert!(f[0].message.contains("x/serve/a.rs:1"), "{f:?}");
    }

    #[test]
    fn metrics_registry_module_and_tests_are_exempt() {
        let src = "fn r(reg: &R) { reg.counter(&name, &[], Class::Stable); }\n";
        assert_eq!(metrics_findings(&[("x/obs/metrics.rs", src)]), vec![]);
        let test_src = "#[cfg(test)]\nmod tests {\n fn t(reg: &R) { \
                        reg.counter(&name, &[], Class::Stable); }\n}\n";
        assert_eq!(metrics_findings(&[("x/obs/export.rs", test_src)]), vec![]);
        // and out-of-scope modules (the CLI, report rendering) are free
        assert_eq!(metrics_findings(&[("x/report/a.rs", src)]), vec![]);
    }

    #[test]
    fn unsynced_create_flagged_synced_not() {
        let bad = "fn f(p: &Path) { let f = File::create(p); }\n";
        let good = "fn f(p: &Path) -> io::Result<()> { let f = File::create(p)?; \
                    f.sync_all()?; Ok(()) }\n";
        assert_eq!(findings("x/store/snap.rs", bad).len(), 1);
        assert_eq!(findings("x/store/snap.rs", good).len(), 0);
    }
}
