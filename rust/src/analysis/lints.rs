//! The repo-specific lint passes.
//!
//! Each pass is a token-sequence scanner over [`super::lexer::LexedFile`];
//! none of them parse Rust. The trade-off is spelled out per lint: a
//! pattern is chosen so that the *absence* of findings is meaningful
//! (no false-negative shapes exist in this codebase), while the rare
//! legitimate hit is suppressed inline with a reasoned
//! `// analyze: allow(<lint>) <reason>`.
//!
//! Scopes are path-substring based so the fixture corpus under
//! `tests/analysis_fixtures/` classifies the same way the live tree
//! does (`.../analysis_fixtures/serve/foo.rs` is "in `serve/`").

use super::lexer::{LexedFile, Tok, TokKind};
use super::order;

/// One unsuppressed (or to-be-suppressed) lint hit.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub lint: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// Every lint the pass knows; `allow(<name>)` directives are checked
/// against this list so a typo'd suppression is a finding, not a no-op.
pub const LINT_NAMES: &[&str] = &[
    "determinism",
    "lock-discipline",
    "panic-path",
    "framing-casts",
    "log-discipline",
    "io-durability",
    "obs-discipline",
    "suppression",
];

/// fifo / EventLog-emitting modules: anything here that iterates an
/// unordered map or reads a wall clock can break byte-determinism.
fn fifo_scope(rel: &str) -> bool {
    rel.contains("serve/") || rel.contains("store/") || rel.contains("coordinator/")
}

/// Serving + durability tier: typed errors are the contract, panics are
/// findings.
fn serve_store_scope(rel: &str) -> bool {
    rel.contains("serve/") || rel.contains("store/")
}

/// The serving path (serving tier + its telemetry layer), where the
/// span clock is the only sanctioned wall-clock source. `obs/span.rs`
/// defines that clock and is the one exempt module.
fn obs_scope(rel: &str) -> bool {
    (rel.contains("serve/") || rel.contains("obs/")) && !rel.contains("obs/span.rs")
}

/// Binary framing code: every narrowing cast is a silent-truncation bug
/// waiting for a >64 KiB tenant name.
fn framing_scope(rel: &str) -> bool {
    ["store/wal.rs", "store/snapshot.rs", "store/recover.rs", "coordinator/checkpoint.rs"]
        .iter()
        .any(|f| rel.contains(f))
}

/// Library modules where the EventLog is the only sanctioned sink.
/// `main.rs` (the CLI), `report/` (table rendering) and `util/bench.rs`
/// (the bench timer) print by design and are out of scope.
fn log_scope(rel: &str) -> bool {
    let included = [
        "serve/", "store/", "coordinator/", "runtime/", "quantum/", "peft/", "data/",
        "metrics/", "config/", "util/",
    ];
    included.iter().any(|d| rel.contains(d)) && !rel.contains("util/bench.rs")
}

pub fn run_all(rel: &str, lx: &LexedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    determinism(rel, lx, &mut out);
    lock_discipline(rel, lx, &mut out);
    panic_path(rel, lx, &mut out);
    framing_casts(rel, lx, &mut out);
    log_discipline(rel, lx, &mut out);
    io_durability(rel, lx, &mut out);
    obs_discipline(rel, lx, &mut out);
    out
}

fn ident_at<'a>(toks: &'a [Tok], i: usize) -> Option<&'a str> {
    match toks.get(i).map(|t| &t.kind) {
        Some(TokKind::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct_at(toks: &[Tok], i: usize) -> Option<char> {
    match toks.get(i).map(|t| &t.kind) {
        Some(TokKind::Punct(c)) => Some(*c),
        _ => None,
    }
}

fn is_punct(toks: &[Tok], i: usize, c: char) -> bool {
    punct_at(toks, i) == Some(c)
}

fn is_int(toks: &[Tok], i: usize) -> bool {
    matches!(toks.get(i).map(|t| &t.kind), Some(TokKind::Int))
}

// ---------------------------------------------------------------- determinism

const ITER_METHODS: &[&str] = &[
    "iter", "iter_mut", "into_iter", "keys", "into_keys", "values", "values_mut",
    "into_values", "drain", "retain",
];

fn determinism(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !fifo_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    // Pass 1: names bound (field or let) to a HashMap/HashSet type.
    let mut unordered: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if ident_at(toks, i).is_some_and(|id| id == "HashMap" || id == "HashSet") {
            if let Some(name) = binding_name(toks, i) {
                if !unordered.contains(&name) {
                    unordered.push(name);
                }
            }
        }
    }
    // Pass 2: iteration over those names, and wall-clock reads.
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if let Some(src) = ident_at(toks, i).filter(|id| *id == "Instant" || *id == "SystemTime")
        {
            if is_punct(toks, i + 1, ':')
                && is_punct(toks, i + 2, ':')
                && ident_at(toks, i + 3) == Some("now")
            {
                out.push(Finding {
                    lint: "determinism",
                    file: rel.to_string(),
                    line: toks[i].line,
                    message: format!(
                        "{src}::now() in a fifo/EventLog module — wall-clock reads break \
                         byte-determinism; thread a logical clock through, or allow with \
                         the reason the value never reaches a deterministic output"
                    ),
                });
            }
        }
        let Some(name) = ident_at(toks, i).filter(|n| unordered.iter().any(|u| u.as_str() == *n))
        else {
            continue;
        };
        let method_iter = is_punct(toks, i + 1, '.')
            && ident_at(toks, i + 2).is_some_and(|m| ITER_METHODS.contains(&m))
            && is_punct(toks, i + 3, '(');
        let for_iter = preceded_by_in(toks, i);
        if method_iter || for_iter {
            out.push(Finding {
                lint: "determinism",
                file: rel.to_string(),
                line: toks[i].line,
                message: format!(
                    "iteration over unordered map/set `{name}` — HashMap order is \
                     nondeterministic; use BTreeMap or sort the keys first \
                     (fifo byte-determinism)"
                ),
            });
        }
    }
}

/// `toks[i]` is `HashMap`/`HashSet`. Return the name it is bound to, for
/// `name: [path::]HashMap<...>` (field / typed let) and
/// `let [mut] name = [path::]HashMap::new()` shapes.
fn binding_name(toks: &[Tok], i: usize) -> Option<String> {
    let mut j = i;
    while j >= 3
        && is_punct(toks, j - 1, ':')
        && is_punct(toks, j - 2, ':')
        && ident_at(toks, j - 3).is_some()
    {
        j -= 3;
    }
    if j == 0 {
        return None;
    }
    if is_punct(toks, j - 1, ':') && j >= 2 && !is_punct(toks, j - 2, ':') {
        return ident_at(toks, j - 2).map(str::to_string);
    }
    if is_punct(toks, j - 1, '=') && j >= 2 {
        return ident_at(toks, j - 2).map(str::to_string);
    }
    None
}

/// Is `toks[i]` (the map name, possibly the tail of a dotted path) the
/// iterated expression of a `for ... in` / preceded by `&`/`&mut`?
fn preceded_by_in(toks: &[Tok], i: usize) -> bool {
    let mut j = i;
    // walk back over `owner .` chains: `inner . entries`
    while j >= 2 && is_punct(toks, j - 1, '.') && ident_at(toks, j - 2).is_some() {
        j -= 2;
    }
    // skip `&` / `mut`
    while j >= 1 && (is_punct(toks, j - 1, '&') || ident_at(toks, j - 1) == Some("mut")) {
        j -= 1;
    }
    j >= 1 && ident_at(toks, j - 1) == Some("in")
}

// ------------------------------------------------------------ lock-discipline

const LOCK_METHODS: &[&str] = &["lock", "read", "write"];
const RECOVER_HELPERS: &[&str] = &["lock_or_recover", "read_or_recover", "write_or_recover"];

fn lock_discipline(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !serve_store_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    // a) `.lock().unwrap()` / `.read().expect(...)` etc: poison panics.
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if is_punct(toks, i, '.')
            && ident_at(toks, i + 1).is_some_and(|m| LOCK_METHODS.contains(&m))
            && is_punct(toks, i + 2, '(')
            && is_punct(toks, i + 3, ')')
            && is_punct(toks, i + 4, '.')
            && ident_at(toks, i + 5).is_some_and(|u| u == "unwrap" || u == "expect")
            && is_punct(toks, i + 6, '(')
        {
            let m = ident_at(toks, i + 1).unwrap_or("lock");
            out.push(Finding {
                lint: "lock-discipline",
                file: rel.to_string(),
                line: toks[i + 5].line,
                message: format!(
                    "`.{m}()` + unwrap poisons-and-panics the whole fleet after one \
                     worker crash — use util::sync::{m}_or_recover"
                ),
            });
        }
    }
    // b) nested acquisition order vs analysis/order.rs.
    let declared = order::order_for(rel);
    for span in fn_spans(lx) {
        let acqs = acquisitions(toks, span);
        match declared {
            Some(list) => {
                let mut max_idx: Option<usize> = None;
                let mut max_name = String::new();
                for a in &acqs {
                    if !a.held {
                        continue;
                    }
                    let Some(idx) = list.iter().position(|n| *n == a.name.as_str()) else {
                        continue;
                    };
                    if let Some(m) = max_idx {
                        if idx < m {
                            out.push(Finding {
                                lint: "lock-discipline",
                                file: rel.to_string(),
                                line: a.line,
                                message: format!(
                                    "lock `{}` acquired while `{}` is held — declared \
                                     order in analysis/order.rs is {:?}",
                                    a.name, max_name, list
                                ),
                            });
                        }
                    }
                    let is_new_max = match max_idx {
                        Some(m) => idx > m,
                        None => true,
                    };
                    if is_new_max {
                        max_idx = Some(idx);
                        max_name = a.name.clone();
                    }
                }
            }
            None => {
                let mut held: Vec<&Acq> = Vec::new();
                for a in &acqs {
                    if a.held && !held.iter().any(|h| h.name == a.name) {
                        held.push(a);
                    }
                }
                if held.len() >= 2 {
                    let names: Vec<&str> = held.iter().map(|a| a.name.as_str()).collect();
                    out.push(Finding {
                        lint: "lock-discipline",
                        file: rel.to_string(),
                        line: held[1].line,
                        message: format!(
                            "nested held locks {names:?} in one fn but this file has no \
                             entry in analysis/order.rs — declare the acquisition order"
                        ),
                    });
                }
            }
        }
    }
}

struct Acq {
    name: String,
    line: u32,
    /// Let-bound guard (held to end of scope) vs a temporary dropped at
    /// the end of the statement (`*self.x.lock()... = v`). Heuristic: a
    /// `let [mut] name = <acquisition>` statement counts as held.
    held: bool,
}

/// Token index ranges of non-test `fn` bodies.
fn fn_spans(lx: &LexedFile) -> Vec<(usize, usize)> {
    let toks = &lx.toks;
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if ident_at(toks, i) == Some("fn") && !lx.is_test[i] {
            let mut k = i + 1;
            while k < toks.len() && !is_punct(toks, k, '{') && !is_punct(toks, k, ';') {
                k += 1;
            }
            if k < toks.len() && is_punct(toks, k, '{') {
                let open = k;
                let mut depth = 0i32;
                while k < toks.len() {
                    if is_punct(toks, k, '{') {
                        depth += 1;
                    } else if is_punct(toks, k, '}') {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                spans.push((open, k.min(toks.len())));
            }
        }
        i += 1;
    }
    spans
}

fn acquisitions(toks: &[Tok], (open, close): (usize, usize)) -> Vec<Acq> {
    let mut acqs = Vec::new();
    for i in open..close {
        // helper form: lock_or_recover(&self.buckets)
        if ident_at(toks, i).is_some_and(|h| RECOVER_HELPERS.contains(&h))
            && is_punct(toks, i + 1, '(')
        {
            let mut depth = 0i32;
            let mut k = i + 1;
            let mut last_ident: Option<&str> = None;
            while k < close {
                if is_punct(toks, k, '(') {
                    depth += 1;
                } else if is_punct(toks, k, ')') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if let Some(id) = ident_at(toks, k) {
                    last_ident = Some(id);
                }
                k += 1;
            }
            if let Some(name) = last_ident {
                acqs.push(Acq {
                    name: name.to_string(),
                    line: toks[i].line,
                    held: is_let_bound(toks, i),
                });
            }
            continue;
        }
        // raw form: path.lock( / .read( / .write(
        if is_punct(toks, i, '.')
            && ident_at(toks, i + 1).is_some_and(|m| LOCK_METHODS.contains(&m))
            && is_punct(toks, i + 2, '(')
            && ident_at(toks, i - 1).is_some()
        {
            let name = ident_at(toks, i - 1).unwrap_or_default().to_string();
            // walk back over the dotted path to the expression head
            let mut head = i - 1;
            while head >= 2 && is_punct(toks, head - 1, '.') && ident_at(toks, head - 2).is_some()
            {
                head -= 2;
            }
            acqs.push(Acq {
                name,
                line: toks[i].line,
                held: is_let_bound(toks, head),
            });
        }
    }
    acqs
}

/// Does the expression starting at `toks[start]` sit directly on the
/// right-hand side of a `let [mut] name = ...` statement?
fn is_let_bound(toks: &[Tok], start: usize) -> bool {
    if start < 3 || !is_punct(toks, start - 1, '=') {
        return false;
    }
    let mut p = start - 2;
    if ident_at(toks, p).is_none() {
        return false;
    }
    p -= 1;
    if ident_at(toks, p) == Some("mut") {
        if p == 0 {
            return false;
        }
        p -= 1;
    }
    ident_at(toks, p) == Some("let")
}

// ----------------------------------------------------------------- panic-path

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

fn panic_path(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !serve_store_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if is_punct(toks, i, '.')
            && ident_at(toks, i + 1).is_some_and(|m| m == "unwrap" || m == "expect")
            && is_punct(toks, i + 2, '(')
        {
            // `.lock().unwrap()` already reported by lock-discipline.
            let lock_chain = i >= 4
                && is_punct(toks, i - 1, ')')
                && is_punct(toks, i - 2, '(')
                && ident_at(toks, i - 3).is_some_and(|m| LOCK_METHODS.contains(&m))
                && is_punct(toks, i - 4, '.');
            if !lock_chain {
                let m = ident_at(toks, i + 1).unwrap_or("unwrap");
                out.push(Finding {
                    lint: "panic-path",
                    file: rel.to_string(),
                    line: toks[i + 1].line,
                    message: format!(
                        "`.{m}()` in serve/store non-test code — typed errors are the \
                         contract here; propagate or handle, or allow with the \
                         invariant that makes it unreachable"
                    ),
                });
            }
        }
        if ident_at(toks, i).is_some_and(|m| PANIC_MACROS.contains(&m))
            && is_punct(toks, i + 1, '!')
            && is_punct(toks, i + 2, '(')
        {
            let m = ident_at(toks, i).unwrap_or("panic");
            out.push(Finding {
                lint: "panic-path",
                file: rel.to_string(),
                line: toks[i].line,
                message: format!(
                    "`{m}!` in serve/store non-test code — a panicking worker takes \
                     its whole shard down; return a typed error"
                ),
            });
        }
        if is_punct(toks, i, '[')
            && is_int(toks, i + 1)
            && is_punct(toks, i + 2, ']')
            && i >= 1
            && (ident_at(toks, i - 1).is_some()
                || is_punct(toks, i - 1, ')')
                || is_punct(toks, i - 1, ']'))
        {
            out.push(Finding {
                lint: "panic-path",
                file: rel.to_string(),
                line: toks[i].line,
                message: "literal indexing can panic — use .get()/.first() or a slice \
                          pattern, or allow with the bound that guarantees the length"
                    .to_string(),
            });
        }
    }
}

// -------------------------------------------------------------- framing-casts

fn framing_casts(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !framing_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if ident_at(toks, i) == Some("as") {
            if let Some(ty) =
                ident_at(toks, i + 1).filter(|t| ["u16", "u32", "usize"].contains(t))
            {
                out.push(Finding {
                    lint: "framing-casts",
                    file: rel.to_string(),
                    line: toks[i].line,
                    message: format!(
                        "bare `as {ty}` in framing code silently truncates — use \
                         {ty}::try_from and surface a typed encode/CorruptState error"
                    ),
                });
            }
        }
    }
}

// ------------------------------------------------------------- log-discipline

const PRINT_MACROS: &[&str] = &["println", "eprintln", "print", "eprint"];

fn log_discipline(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !log_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if ident_at(toks, i).is_some_and(|m| PRINT_MACROS.contains(&m))
            && is_punct(toks, i + 1, '!')
            && is_punct(toks, i + 2, '(')
        {
            let m = ident_at(toks, i).unwrap_or("println");
            out.push(Finding {
                lint: "log-discipline",
                file: rel.to_string(),
                line: toks[i].line,
                message: format!(
                    "`{m}!` in a library module — the EventLog is the only sanctioned \
                     sink (stdout interleaving breaks fifo log comparisons)"
                ),
            });
        }
    }
}

// -------------------------------------------------------------- io-durability

fn io_durability(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !rel.contains("store/") {
        return;
    }
    let toks = &lx.toks;
    let spans = fn_spans(lx);
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        let creates = (ident_at(toks, i) == Some("File")
            && is_punct(toks, i + 1, ':')
            && is_punct(toks, i + 2, ':')
            && ident_at(toks, i + 3) == Some("create"))
            || (ident_at(toks, i) == Some("fs")
                && is_punct(toks, i + 1, ':')
                && is_punct(toks, i + 2, ':')
                && ident_at(toks, i + 3) == Some("write"));
        if !creates {
            continue;
        }
        let span = spans.iter().find(|(open, close)| i >= *open && i <= *close);
        let synced = span.is_some_and(|(open, close)| {
            (*open..*close)
                .any(|k| ident_at(toks, k).is_some_and(|s| s == "sync_all" || s == "sync_data"))
        });
        if !synced {
            out.push(Finding {
                lint: "io-durability",
                file: rel.to_string(),
                line: toks[i].line,
                message: "file written in store/ without an fsync in the same fn — \
                          durability requires the write-temp + sync_all + atomic-rename \
                          idiom"
                    .to_string(),
            });
        }
    }
}

// ------------------------------------------------------------- obs-discipline

fn obs_discipline(rel: &str, lx: &LexedFile, out: &mut Vec<Finding>) {
    if !obs_scope(rel) {
        return;
    }
    let toks = &lx.toks;
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if let Some(src) = ident_at(toks, i).filter(|id| *id == "Instant" || *id == "SystemTime")
        {
            if is_punct(toks, i + 1, ':')
                && is_punct(toks, i + 2, ':')
                && ident_at(toks, i + 3) == Some("now")
            {
                out.push(Finding {
                    lint: "obs-discipline",
                    file: rel.to_string(),
                    line: toks[i].line,
                    message: format!(
                        "{src}::now() on the serving path outside obs/span.rs — the \
                         SpanClock is the only sanctioned wall-clock source (fifo \
                         latencies are logical); take timestamps from the session's \
                         clock, or allow with the reason the read never shapes a \
                         latency or an emitted line"
                    ),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        run_all(rel, &lex(src))
    }

    #[test]
    fn hashmap_iteration_flagged_btreemap_not() {
        let src = "struct S { entries: HashMap<K, V>, sorted: BTreeMap<K, V> }\n\
                   fn f(s: &S) { for k in s.entries.keys() {} for k in s.sorted.keys() {} }\n";
        let f = findings("x/serve/cache.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, "determinism");
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn wall_clock_flagged_in_scope_only() {
        // store/ is fifo scope without the obs-discipline overlap, so
        // exactly the determinism lint fires
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(findings("x/store/a.rs", src).len(), 1);
        assert_eq!(findings("x/report/a.rs", src).len(), 0);
    }

    #[test]
    fn lock_unwrap_flagged_and_not_double_counted() {
        let src = "fn f(m: &Mutex<u32>) { let g = m.lock().unwrap(); }\n";
        let f = findings("x/serve/a.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, "lock-discipline");
    }

    #[test]
    fn order_inversion_flagged() {
        // registry order is inner < tenants: acquiring inner after
        // tenants (both held) is the inversion.
        let src = "fn f(&self) {\n let t = write_or_recover(&self.tenants);\n \
                   let i = lock_or_recover(&self.inner);\n}\n";
        let f = findings("x/serve/registry.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("declared order"), "{f:?}");
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn temporary_guard_is_not_held() {
        let src = "fn f(&self) {\n let t = write_or_recover(&self.tenants);\n \
                   *lock_or_recover(&self.inner) += 1;\n}\n";
        assert_eq!(findings("x/serve/registry.rs", src).len(), 0);
    }

    #[test]
    fn undeclared_nested_locks_flagged() {
        let src = "fn f(&self) {\n let a = lock_or_recover(&self.alpha);\n \
                   let b = lock_or_recover(&self.beta);\n}\n";
        let f = findings("x/serve/nolist_xyz.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, "lock-discipline");
        assert!(f[0].message.contains("analysis/order.rs"), "{f:?}");
    }

    #[test]
    fn panic_macros_and_literal_indexing() {
        let src = "fn f(v: &[u8]) -> u8 { if v.is_empty() { panic!(\"no\") } v[0] }\n";
        let f = findings("x/store/a.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n fn f(v: &[u8]) { v[0]; x.unwrap(); }\n}\n";
        assert_eq!(findings("x/serve/a.rs", src).len(), 0);
    }

    #[test]
    fn framing_cast_flagged_in_framing_files_only() {
        let src = "fn f(n: u64) -> u32 { n as u32 }\n";
        assert_eq!(findings("x/store/wal.rs", src).len(), 1);
        assert_eq!(findings("x/store/mod.rs", src).len(), 0);
    }

    #[test]
    fn println_flagged_in_library_not_report() {
        let src = "fn f() { println!(\"x\"); }\n";
        assert_eq!(findings("x/serve/a.rs", src).len(), 1);
        assert_eq!(findings("x/report/tables.rs", src).len(), 0);
        assert_eq!(findings("x/util/bench.rs", src).len(), 0);
    }

    #[test]
    fn wall_clock_in_serve_hits_both_clock_lints() {
        // serve/ is in both the determinism and obs-discipline scopes:
        // one bare Instant::now() yields one finding per lint
        let src = "fn f() { let t = Instant::now(); }\n";
        let f = findings("x/serve/a.rs", src);
        let lints: Vec<&str> = f.iter().map(|x| x.lint).collect();
        assert!(lints.contains(&"determinism"), "{f:?}");
        assert!(lints.contains(&"obs-discipline"), "{f:?}");
        assert_eq!(f.len(), 2, "{f:?}");
    }

    #[test]
    fn obs_discipline_covers_obs_but_exempts_span_clock() {
        // obs/ is outside the fifo (determinism) scope but inside the
        // obs-discipline scope — except span.rs, the sanctioned clock
        let src = "fn f() { let t = SystemTime::now(); }\n";
        let f = findings("x/obs/hist.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, "obs-discipline");
        assert_eq!(findings("x/obs/span.rs", src).len(), 0);
        // and modules off the serving path are untouched
        assert_eq!(findings("x/report/a.rs", src).len(), 0);
    }

    #[test]
    fn unsynced_create_flagged_synced_not() {
        let bad = "fn f(p: &Path) { let f = File::create(p); }\n";
        let good = "fn f(p: &Path) -> io::Result<()> { let f = File::create(p)?; \
                    f.sync_all()?; Ok(()) }\n";
        assert_eq!(findings("x/store/snap.rs", bad).len(), 1);
        assert_eq!(findings("x/store/snap.rs", good).len(), 0);
    }
}
