//! `repro analyze` — a std-only static-analysis pass over this repo's
//! own invariants.
//!
//! The fifo byte-determinism contract (identical logs and responses at
//! any worker count), the typed-error discipline in `serve/`/`store/`,
//! and the WAL/QPCK framing rules are all properties clippy cannot
//! express. This module enforces them with a lightweight lexer
//! ([`lexer`]) and token-sequence scanners ([`lints`]) — no `syn`, no
//! dependencies, fast enough to run as a blocking CI gate.
//!
//! ## Lints
//!
//! - `determinism` — in `serve/`, `store/`, `coordinator/`: iteration
//!   over `HashMap`/`HashSet` bindings; `Instant::now` /
//!   `SystemTime::now`.
//! - `lock-discipline` — in `serve/`, `store/`:
//!   `.lock()/.read()/.write()` + `unwrap`/`expect`; held-lock
//!   acquisition order vs [`order::LOCK_ORDER`].
//! - `panic-path` — in `serve/`, `store/`: `.unwrap()`, `.expect()`,
//!   `panic!`-family macros, literal indexing.
//! - `framing-casts` — in `store/wal.rs`, `store/snapshot.rs`,
//!   `store/recover.rs`, `coordinator/checkpoint.rs`: bare `as u16` /
//!   `as u32` / `as usize`.
//! - `log-discipline` — in library modules: `println!`-family macros
//!   (the EventLog is the sink).
//! - `io-durability` — in `store/`: `File::create`/`fs::write` in a fn
//!   with no `sync_all`/`sync_data`.
//! - `obs-discipline` — in `serve/`, `obs/` (except `obs/span.rs`):
//!   `Instant::now` / `SystemTime::now` — the [`crate::obs::SpanClock`]
//!   is the only sanctioned wall-clock source on the serving path.
//! - `suppression` — everywhere: malformed `// analyze:` directives,
//!   allows without a reason, unknown lint names.
//!
//! ## Suppression
//!
//! A finding is suppressed by `// analyze: allow(<lint>) <reason>` on
//! the same line or the line directly above. The reason is mandatory:
//! a bare `allow(...)` suppresses nothing and is itself a `suppression`
//! finding — every exception in the tree carries its justification.
//!
//! Test code (`#[cfg(test)]` / `#[test]` bodies) is exempt from every
//! lint except `suppression`: unwraps and wall clocks are the test
//! contract.

pub mod lexer;
pub mod lints;
pub mod order;

pub use lints::{Finding, LINT_NAMES};

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// A finding silenced by a reasoned allow, kept for reporting.
#[derive(Debug, Clone)]
pub struct Suppressed {
    pub finding: Finding,
    pub reason: String,
}

/// The result of analyzing a set of paths.
#[derive(Debug, Default)]
pub struct Report {
    /// Unsuppressed findings, sorted by (file, line, lint).
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppressed>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Analyze one file's source text. `rel` is the path used both for
/// reporting and for scope classification (normalized to `/`).
pub fn analyze_source(rel: &str, source: &str) -> (Vec<Finding>, Vec<Suppressed>) {
    let lx = lexer::lex(source);
    let raw = lints::run_all(rel, &lx);
    let mut findings = Vec::new();
    let mut suppressed = Vec::new();

    // Directive hygiene first: malformed directives, missing reasons,
    // unknown lint names. These are never themselves suppressible.
    for a in &lx.allows {
        if a.malformed {
            findings.push(Finding {
                lint: "suppression",
                file: rel.to_string(),
                line: a.line,
                message: "unrecognized analyze directive — expected \
                          `// analyze: allow(<lint>) <reason>`"
                    .to_string(),
            });
            continue;
        }
        if a.reason.is_empty() {
            findings.push(Finding {
                lint: "suppression",
                file: rel.to_string(),
                line: a.line,
                message: format!(
                    "allow({}) without a reason — every suppression must say why \
                     the invariant holds here",
                    a.lints.join(", ")
                ),
            });
        }
        for l in &a.lints {
            if !LINT_NAMES.contains(&l.as_str()) {
                findings.push(Finding {
                    lint: "suppression",
                    file: rel.to_string(),
                    line: a.line,
                    message: format!("allow names unknown lint `{l}` (known: {LINT_NAMES:?})"),
                });
            }
        }
    }

    for f in raw {
        let matched = lx.allows.iter().find(|a| {
            !a.malformed
                && !a.reason.is_empty()
                && a.lints.iter().any(|l| l == f.lint)
                && (a.line == f.line || a.line + 1 == f.line)
        });
        match matched {
            Some(a) => suppressed.push(Suppressed { finding: f, reason: a.reason.clone() }),
            None => findings.push(f),
        }
    }
    (findings, suppressed)
}

/// Analyze `.rs` files under each path (files are taken as-is,
/// directories walked recursively; `target/`, `vendor/`, and dot-dirs
/// are skipped). Paths inside the report keep the caller's prefix.
pub fn analyze_paths(paths: &[PathBuf]) -> std::io::Result<Report> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs(p, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut report = Report::default();
    for f in &files {
        let source = std::fs::read_to_string(f)?;
        let rel = f.to_string_lossy().replace('\\', "/");
        let (findings, suppressed) = analyze_source(&rel, &source);
        report.findings.extend(findings);
        report.suppressed.extend(suppressed);
        report.files_scanned += 1;
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    report
        .suppressed
        .sort_by(|a, b| (&a.finding.file, a.finding.line).cmp(&(&b.finding.file, b.finding.line)));
    Ok(report)
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let md = std::fs::metadata(path)?;
    if md.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    for entry in std::fs::read_dir(path)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with('.') || name == "target" || name == "vendor" {
            continue;
        }
        collect_rs(&entry.path(), out)?;
    }
    Ok(())
}

/// Per-lint finding counts, sorted by lint name.
pub fn counts(report: &Report) -> Vec<(&'static str, usize)> {
    let mut out: Vec<(&'static str, usize)> = Vec::new();
    for f in &report.findings {
        match out.iter_mut().find(|(l, _)| *l == f.lint) {
            Some((_, n)) => *n += 1,
            None => out.push((f.lint, 1)),
        }
    }
    out.sort_by_key(|(l, _)| *l);
    out
}

/// Human-readable rendering: one `file:line: [lint] message` per
/// finding, then a summary block.
pub fn render_text(report: &Report) -> String {
    let mut out = String::new();
    for f in &report.findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.lint, f.message));
    }
    if !report.findings.is_empty() {
        out.push('\n');
    }
    for (lint, n) in counts(report) {
        out.push_str(&format!("{lint}: {n}\n"));
    }
    out.push_str(&format!(
        "{} finding(s), {} suppressed, {} file(s) scanned\n",
        report.findings.len(),
        report.suppressed.len(),
        report.files_scanned
    ));
    out
}

fn finding_json(f: &Finding) -> Json {
    json::obj(vec![
        ("lint", f.lint.into()),
        ("file", f.file.as_str().into()),
        ("line", (f.line as usize).into()),
        ("message", f.message.as_str().into()),
    ])
}

/// Machine-readable rendering for the CI gate.
pub fn render_json(report: &Report) -> String {
    let findings: Vec<Json> = report.findings.iter().map(finding_json).collect();
    let suppressed: Vec<Json> = report
        .suppressed
        .iter()
        .map(|s| {
            let mut o = finding_json(&s.finding);
            if let Json::Obj(map) = &mut o {
                map.insert("reason".to_string(), s.reason.as_str().into());
            }
            o
        })
        .collect();
    let count_pairs: Vec<(&str, Json)> =
        counts(report).into_iter().map(|(l, n)| (l, Json::from(n))).collect();
    json::obj(vec![
        ("version", 1usize.into()),
        ("files_scanned", report.files_scanned.into()),
        ("findings", Json::Arr(findings)),
        ("suppressed", Json::Arr(suppressed)),
        ("counts", json::obj(count_pairs)),
    ])
    .dump()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_with_reason_suppresses() {
        let src = "// analyze: allow(panic-path) v is non-empty by construction\n\
                   fn f(v: &[u8]) -> u8 { v[0] }\n";
        let (findings, suppressed) = analyze_source("x/serve/a.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(suppressed.len(), 1);
        assert_eq!(suppressed[0].reason, "v is non-empty by construction");
    }

    #[test]
    fn trailing_allow_on_same_line_suppresses() {
        let src = "fn f(v: &[u8]) -> u8 { v[0] } // analyze: allow(panic-path) len checked\n";
        let (findings, suppressed) = analyze_source("x/serve/a.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(suppressed.len(), 1);
    }

    #[test]
    fn bare_allow_is_a_finding_and_does_not_suppress() {
        let src = "// analyze: allow(panic-path)\nfn f(v: &[u8]) -> u8 { v[0] }\n";
        let (findings, _) = analyze_source("x/serve/a.rs", src);
        let lints: Vec<&str> = findings.iter().map(|f| f.lint).collect();
        assert!(lints.contains(&"suppression"), "{findings:?}");
        assert!(lints.contains(&"panic-path"), "{findings:?}");
    }

    #[test]
    fn unknown_lint_name_is_a_finding() {
        let src = "// analyze: allow(panics) typo'd lint name\n";
        let (findings, _) = analyze_source("x/serve/a.rs", src);
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("unknown lint"), "{findings:?}");
    }

    #[test]
    fn wrong_lint_does_not_suppress() {
        let src = "// analyze: allow(determinism) wrong lint\nfn f(v: &[u8]) -> u8 { v[0] }\n";
        let (findings, _) = analyze_source("x/serve/a.rs", src);
        assert!(findings.iter().any(|f| f.lint == "panic-path"), "{findings:?}");
    }

    #[test]
    fn json_schema_round_trips() {
        let src = "fn f(v: &[u8]) -> u8 { v[0] }\n";
        let (findings, suppressed) = analyze_source("x/store/a.rs", src);
        let report = Report { findings, suppressed, files_scanned: 1 };
        let parsed = Json::parse(&render_json(&report)).unwrap();
        assert_eq!(parsed.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(parsed.get("files_scanned").unwrap().as_usize().unwrap(), 1);
        let arr = parsed.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        let f = &arr[0];
        assert_eq!(f.get("lint").unwrap().as_str().unwrap(), "panic-path");
        assert_eq!(f.get("file").unwrap().as_str().unwrap(), "x/store/a.rs");
        assert_eq!(f.get("line").unwrap().as_usize().unwrap(), 1);
        assert!(parsed.get("counts").is_ok());
    }

    #[test]
    fn text_render_has_anchors_and_summary() {
        // store/ is in the determinism scope but not the obs one, so a
        // wall-clock read here renders exactly one anchored finding
        let src = "fn f() { let t = Instant::now(); }\n";
        let (findings, suppressed) = analyze_source("x/store/a.rs", src);
        let report = Report { findings, suppressed, files_scanned: 1 };
        let text = render_text(&report);
        assert!(text.contains("x/store/a.rs:1: [determinism]"), "{text}");
        assert!(text.contains("1 finding(s), 0 suppressed, 1 file(s) scanned"), "{text}");
    }
}
