//! `repro analyze` — a std-only static-analysis pass over this repo's
//! own invariants.
//!
//! The fifo byte-determinism contract (identical logs and responses at
//! any worker count), the typed-error discipline in `serve/`/`store/`,
//! and the WAL/QPCK framing rules are all properties clippy cannot
//! express. This module enforces them with a lightweight lexer
//! ([`lexer`]), token-sequence scanners ([`lints`]), and — since the
//! interprocedural pass — a per-file semantic model ([`model`]) joined
//! into a crate-wide call graph ([`graph`]). No `syn`, no
//! dependencies, fast enough to run as a blocking CI gate.
//!
//! ## Intra-function lints
//!
//! - `determinism` — in `serve/`, `store/`, `coordinator/`: iteration
//!   over `HashMap`/`HashSet` bindings; `Instant::now` /
//!   `SystemTime::now`.
//! - `lock-discipline` — in `serve/`, `store/`:
//!   `.lock()/.read()/.write()` + `unwrap`/`expect`; held-lock
//!   acquisition order vs [`order::LOCK_ORDER`].
//! - `panic-path` — in `serve/`, `store/`: `.unwrap()`, `.expect()`,
//!   `panic!`-family macros, literal indexing.
//! - `framing-casts` — in `store/wal.rs`, `store/snapshot.rs`,
//!   `store/recover.rs`, `coordinator/checkpoint.rs`: bare `as u16` /
//!   `as u32` / `as usize`.
//! - `log-discipline` — in library modules: `println!`-family macros
//!   (the EventLog is the sink).
//! - `io-durability` — in `store/`: `File::create`/`fs::write` in a fn
//!   with no `sync_all`/`sync_data`.
//! - `obs-discipline` — in `serve/`, `obs/` (except `obs/span.rs`):
//!   `Instant::now` / `SystemTime::now` — the [`crate::obs::SpanClock`]
//!   is the only sanctioned wall-clock source on the serving path.
//! - `suppression` — everywhere: malformed `// analyze:` directives,
//!   allows without a reason, unknown lint names.
//! - `metrics-discipline` — crate-wide (non-test code, `obs/metrics.rs`
//!   itself exempt): every `.counter(`/`.gauge(`/`.hist(` registration
//!   must pass a snake_case string-literal name, and each name must
//!   have exactly one registration site — exported metric names are a
//!   grep/dashboard contract, so every name greps back to one line.
//!
//! ## Interprocedural lints
//!
//! These run on the crate-wide call graph and report in `serve/`,
//! `store/`, `obs/` and `util/pool.rs` (models are extracted
//! everywhere so closures see through `util/`, `runtime/`, ...):
//!
//! - `lock-order-transitive` — the held-guard set is propagated
//!   through the call graph: a call made while a declared guard is
//!   held must not reach an acquisition that precedes (inversion) or
//!   equals (self-deadlock) the held lock in
//!   [`order::GLOBAL_ORDER`]. The intra-function `lock-discipline`
//!   order check only sees same-body nesting; this lint covers the
//!   call-boundary cases it cannot.
//! - `blocking-under-lock` — a blocking call (`sync_all`/`sync_data`,
//!   `write_all`, `recv`/`recv_timeout`, a no-arg `join`, `sleep`)
//!   made or reached while any guard from `order.rs` is held.
//! - `atomics-discipline` — `Ordering::Relaxed` on an `AtomicBool`
//!   flag that is accessed both from spawned-thread code (inside a
//!   spawn closure, or reachable from one) and from the spawning side;
//!   `compare_exchange_weak` outside a retry loop.
//! - `resource-leak` — `thread::spawn` handles that no path joins or
//!   stores (the thread detaches, its panic is lost); `Background`
//!   handles dropped at the spawn statement (Drop joins immediately,
//!   silently serializing the work). Scoped spawns are exempt.
//!
//! ### Call-graph conservatism
//!
//! Resolution is by name with an **any-method fallback**: a
//! `receiver.method(..)` whose receiver cannot be typed resolves to
//! *every* crate fn named `method` (`self.method(..)` narrows to the
//! enclosing impl type first, `Type::method(..)` to the qualified
//! name). The fallback over-approximates — a finding can name a path
//! the program never takes, answered with a reasoned
//! `// analyze: allow` — and it misses a crate-local callee in exactly
//! two carved-out cases (see [`graph`]): methods with ubiquitous std
//! names (`get`, `len`, `send`, ...) and paths qualified by a std type
//! or module (`Arc::new`) resolve to nothing instead of to every
//! same-named crate fn, because unioning those buries the gate in
//! false inversions. A crate method with a std name is still resolved
//! precisely through `self.`/`Type::` call forms — only the
//! opaque-receiver union skips it. Everything else the graph cannot
//! prove absent stays an edge, so "no finding" means no reachable
//! violation up to that documented union. Spawn-closure bodies are
//! excluded from the spawning fn's footprint (they run on the new
//! thread) and instead seed the spawn-reachability set the atomics
//! lint uses.
//!
//! ## Baseline / ratchet workflow
//!
//! `repro analyze --baseline <file>` lets a new lint land blocking
//! before the tree is fully clean: accepted findings live in a JSON
//! baseline ([`baseline`]) keyed by line-insensitive fingerprints.
//! New findings still fail; fixed findings leave stale entries, which
//! are themselves findings until deleted — the debt can only shrink.
//! `--write-baseline <file>` captures the current findings to start
//! (or re-shrink) the file. An empty tree needs no baseline; this
//! repo's gate runs without one and the flag exists for the next
//! lint's rollout.
//!
//! ## Suppression
//!
//! A finding is suppressed by `// analyze: allow(<lint>) <reason>` on
//! the same line or the line directly above. The reason is mandatory:
//! a bare `allow(...)` suppresses nothing and is itself a `suppression`
//! finding — every exception in the tree carries its justification.
//!
//! Test code (`#[cfg(test)]` / `#[test]` bodies) is exempt from every
//! lint except `suppression`: unwraps and wall clocks are the test
//! contract. The fixture corpus under `tests/analysis_fixtures/` is
//! excluded from directory walks for the same reason — fixtures are
//! deliberate violations.

pub mod baseline;
pub mod graph;
pub mod lexer;
pub mod lints;
pub mod model;
pub mod order;

pub use lints::{Finding, LINT_NAMES};

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// A finding silenced by a reasoned allow, kept for reporting.
#[derive(Debug, Clone)]
pub struct Suppressed {
    pub finding: Finding,
    pub reason: String,
}

/// The result of analyzing a set of paths.
#[derive(Debug, Default)]
pub struct Report {
    /// Unsuppressed findings, sorted by (file, line, lint).
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppressed>,
    /// Findings accepted by a `--baseline` file (empty without one).
    pub baselined: Vec<Suppressed>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// The full pipeline over a set of in-memory sources analyzed as one
/// crate: lex every file, run the per-file lints, extract the
/// semantic models, build the joint call graph, run the
/// interprocedural lints (findings land on the *caller's* file), then
/// match each file's suppressions. Returns per-file
/// `(findings, suppressed)` in raw pass order (unsorted).
fn analyze_set(files: &[(String, String)]) -> Vec<(Vec<Finding>, Vec<Suppressed>)> {
    let lexed: Vec<lexer::LexedFile> = files.iter().map(|(_, src)| lexer::lex(src)).collect();
    let models: Vec<model::FileModel> = files
        .iter()
        .zip(&lexed)
        .map(|((rel, _), lx)| model::extract(rel, lx))
        .collect();
    let g = graph::build(&models);
    let mut raw: Vec<Vec<Finding>> = files
        .iter()
        .zip(&lexed)
        .map(|((rel, _), lx)| lints::run_all(rel, lx))
        .collect();
    for f in lints::run_interproc(&models, &g) {
        if let Some(i) = files.iter().position(|(rel, _)| *rel == f.file) {
            raw[i].push(f);
        }
    }
    // metrics-discipline is crate-wide like the call-graph lints (the
    // registered-once check is a global property), but needs only the
    // token streams
    let pairs: Vec<(&str, &lexer::LexedFile)> = files
        .iter()
        .map(|(rel, _)| rel.as_str())
        .zip(lexed.iter())
        .collect();
    for f in lints::metrics_discipline(&pairs) {
        if let Some(i) = files.iter().position(|(rel, _)| *rel == f.file) {
            raw[i].push(f);
        }
    }
    files
        .iter()
        .zip(lexed.iter().zip(raw))
        .map(|((rel, _), (lx, raw))| match_suppressions(rel, lx, raw))
        .collect()
}

/// Directive hygiene + suppression matching for one file's raw
/// findings.
fn match_suppressions(
    rel: &str,
    lx: &lexer::LexedFile,
    raw: Vec<Finding>,
) -> (Vec<Finding>, Vec<Suppressed>) {
    let mut findings = Vec::new();
    let mut suppressed = Vec::new();

    // Directive hygiene first: malformed directives, missing reasons,
    // unknown lint names. These are never themselves suppressible.
    for a in &lx.allows {
        if a.malformed {
            findings.push(Finding {
                lint: "suppression",
                file: rel.to_string(),
                line: a.line,
                message: "unrecognized analyze directive — expected \
                          `// analyze: allow(<lint>) <reason>`"
                    .to_string(),
            });
            continue;
        }
        if a.reason.is_empty() {
            findings.push(Finding {
                lint: "suppression",
                file: rel.to_string(),
                line: a.line,
                message: format!(
                    "allow({}) without a reason — every suppression must say why \
                     the invariant holds here",
                    a.lints.join(", ")
                ),
            });
        }
        for l in &a.lints {
            if !LINT_NAMES.contains(&l.as_str()) {
                findings.push(Finding {
                    lint: "suppression",
                    file: rel.to_string(),
                    line: a.line,
                    message: format!("allow names unknown lint `{l}` (known: {LINT_NAMES:?})"),
                });
            }
        }
    }

    for f in raw {
        let matched = lx.allows.iter().find(|a| {
            !a.malformed
                && !a.reason.is_empty()
                && a.lints.iter().any(|l| l == f.lint)
                && (a.line == f.line || a.line + 1 == f.line)
        });
        match matched {
            Some(a) => suppressed.push(Suppressed { finding: f, reason: a.reason.clone() }),
            None => findings.push(f),
        }
    }
    (findings, suppressed)
}

/// Analyze one file's source text. `rel` is the path used both for
/// reporting and for scope classification (normalized to `/`). The
/// interprocedural lints run over the single-file call graph — use
/// [`analyze_sources`] / [`analyze_paths`] for cross-file resolution.
pub fn analyze_source(rel: &str, source: &str) -> (Vec<Finding>, Vec<Suppressed>) {
    analyze_set(&[(rel.to_string(), source.to_string())])
        .pop()
        .unwrap_or_default()
}

/// Analyze a set of `(rel path, source)` pairs as one crate.
pub fn analyze_sources(files: &[(String, String)]) -> Report {
    let mut report = Report { files_scanned: files.len(), ..Report::default() };
    for (findings, suppressed) in analyze_set(files) {
        report.findings.extend(findings);
        report.suppressed.extend(suppressed);
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    report
        .suppressed
        .sort_by(|a, b| (&a.finding.file, a.finding.line).cmp(&(&b.finding.file, b.finding.line)));
    report
}

/// Analyze `.rs` files under each path (files are taken as-is,
/// directories walked recursively; `target/`, `vendor/`, dot-dirs and
/// `analysis_fixtures/` are skipped — fixtures are deliberate
/// violations). Paths inside the report keep the caller's prefix.
pub fn analyze_paths(paths: &[PathBuf]) -> std::io::Result<Report> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs(p, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut sources = Vec::with_capacity(files.len());
    for f in &files {
        let source = std::fs::read_to_string(f)?;
        let rel = f.to_string_lossy().replace('\\', "/");
        sources.push((rel, source));
    }
    Ok(analyze_sources(&sources))
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let md = std::fs::metadata(path)?;
    if md.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    for entry in std::fs::read_dir(path)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with('.')
            || name == "target"
            || name == "vendor"
            || name == "analysis_fixtures"
        {
            continue;
        }
        collect_rs(&entry.path(), out)?;
    }
    Ok(())
}

/// Per-lint finding counts, sorted by lint name.
pub fn counts(report: &Report) -> Vec<(&'static str, usize)> {
    let mut out: Vec<(&'static str, usize)> = Vec::new();
    for f in &report.findings {
        match out.iter_mut().find(|(l, _)| *l == f.lint) {
            Some((_, n)) => *n += 1,
            None => out.push((f.lint, 1)),
        }
    }
    out.sort_by_key(|(l, _)| *l);
    out
}

fn summary_line(report: &Report) -> String {
    let mut s = format!(
        "{} finding(s), {} suppressed, {} file(s) scanned",
        report.findings.len(),
        report.suppressed.len(),
        report.files_scanned
    );
    if !report.baselined.is_empty() {
        s.push_str(&format!(", {} baselined", report.baselined.len()));
    }
    s.push('\n');
    s
}

/// Human-readable rendering: one `file:line: [lint] message` per
/// finding, then a summary block.
pub fn render_text(report: &Report) -> String {
    let mut out = String::new();
    for f in &report.findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.lint, f.message));
    }
    if !report.findings.is_empty() {
        out.push('\n');
    }
    for (lint, n) in counts(report) {
        out.push_str(&format!("{lint}: {n}\n"));
    }
    out.push_str(&summary_line(report));
    out
}

fn finding_json(f: &Finding) -> Json {
    json::obj(vec![
        ("lint", f.lint.into()),
        ("file", f.file.as_str().into()),
        ("line", (f.line as usize).into()),
        ("message", f.message.as_str().into()),
    ])
}

fn suppressed_json(s: &Suppressed) -> Json {
    let mut o = finding_json(&s.finding);
    if let Json::Obj(map) = &mut o {
        map.insert("reason".to_string(), s.reason.as_str().into());
    }
    o
}

/// Machine-readable rendering for the CI gate.
pub fn render_json(report: &Report) -> String {
    let findings: Vec<Json> = report.findings.iter().map(finding_json).collect();
    let suppressed: Vec<Json> = report.suppressed.iter().map(suppressed_json).collect();
    let count_pairs: Vec<(&str, Json)> =
        counts(report).into_iter().map(|(l, n)| (l, Json::from(n))).collect();
    let mut fields = vec![
        ("version", 1usize.into()),
        ("files_scanned", report.files_scanned.into()),
        ("findings", Json::Arr(findings)),
        ("suppressed", Json::Arr(suppressed)),
        ("counts", json::obj(count_pairs)),
    ];
    if !report.baselined.is_empty() {
        let baselined: Vec<Json> = report.baselined.iter().map(suppressed_json).collect();
        fields.push(("baselined", Json::Arr(baselined)));
    }
    json::obj(fields).dump()
}

/// Escape for a GitHub workflow-command *message* (after the `::`).
fn gh_data(s: &str) -> String {
    s.replace('%', "%25").replace('\r', "%0D").replace('\n', "%0A")
}

/// Escape for a workflow-command *property* value (`file=`, `title=`).
fn gh_prop(s: &str) -> String {
    gh_data(s).replace(':', "%3A").replace(',', "%2C")
}

/// GitHub Actions annotation rendering: one `::error` workflow command
/// per finding so findings show inline on the PR diff, then the plain
/// summary line (annotation-free, so it only lands in the job log).
pub fn render_github(report: &Report) -> String {
    let mut out = String::new();
    for f in &report.findings {
        out.push_str(&format!(
            "::error file={},line={},title={}::{}\n",
            gh_prop(&f.file),
            f.line,
            gh_prop(&format!("analyze: {}", f.lint)),
            gh_data(&f.message)
        ));
    }
    out.push_str(&summary_line(report));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_with_reason_suppresses() {
        let src = "// analyze: allow(panic-path) v is non-empty by construction\n\
                   fn f(v: &[u8]) -> u8 { v[0] }\n";
        let (findings, suppressed) = analyze_source("x/serve/a.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(suppressed.len(), 1);
        assert_eq!(suppressed[0].reason, "v is non-empty by construction");
    }

    #[test]
    fn trailing_allow_on_same_line_suppresses() {
        let src = "fn f(v: &[u8]) -> u8 { v[0] } // analyze: allow(panic-path) len checked\n";
        let (findings, suppressed) = analyze_source("x/serve/a.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(suppressed.len(), 1);
    }

    #[test]
    fn bare_allow_is_a_finding_and_does_not_suppress() {
        let src = "// analyze: allow(panic-path)\nfn f(v: &[u8]) -> u8 { v[0] }\n";
        let (findings, _) = analyze_source("x/serve/a.rs", src);
        let lints: Vec<&str> = findings.iter().map(|f| f.lint).collect();
        assert!(lints.contains(&"suppression"), "{findings:?}");
        assert!(lints.contains(&"panic-path"), "{findings:?}");
    }

    #[test]
    fn unknown_lint_name_is_a_finding() {
        let src = "// analyze: allow(panics) typo'd lint name\n";
        let (findings, _) = analyze_source("x/serve/a.rs", src);
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("unknown lint"), "{findings:?}");
    }

    #[test]
    fn wrong_lint_does_not_suppress() {
        let src = "// analyze: allow(determinism) wrong lint\nfn f(v: &[u8]) -> u8 { v[0] }\n";
        let (findings, _) = analyze_source("x/serve/a.rs", src);
        assert!(findings.iter().any(|f| f.lint == "panic-path"), "{findings:?}");
    }

    #[test]
    fn json_schema_round_trips() {
        let src = "fn f(v: &[u8]) -> u8 { v[0] }\n";
        let (findings, suppressed) = analyze_source("x/store/a.rs", src);
        let report = Report { findings, suppressed, files_scanned: 1, ..Report::default() };
        let parsed = Json::parse(&render_json(&report)).unwrap();
        assert_eq!(parsed.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(parsed.get("files_scanned").unwrap().as_usize().unwrap(), 1);
        let arr = parsed.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        let f = &arr[0];
        assert_eq!(f.get("lint").unwrap().as_str().unwrap(), "panic-path");
        assert_eq!(f.get("file").unwrap().as_str().unwrap(), "x/store/a.rs");
        assert_eq!(f.get("line").unwrap().as_usize().unwrap(), 1);
        assert!(parsed.get("counts").is_ok());
    }

    #[test]
    fn text_render_has_anchors_and_summary() {
        // store/ is in the determinism scope but not the obs one, so a
        // wall-clock read here renders exactly one anchored finding
        let src = "fn f() { let t = Instant::now(); }\n";
        let (findings, suppressed) = analyze_source("x/store/a.rs", src);
        let report = Report { findings, suppressed, files_scanned: 1, ..Report::default() };
        let text = render_text(&report);
        assert!(text.contains("x/store/a.rs:1: [determinism]"), "{text}");
        assert!(text.contains("1 finding(s), 0 suppressed, 1 file(s) scanned"), "{text}");
    }

    #[test]
    fn github_render_escapes_and_annotates() {
        let report = Report {
            findings: vec![Finding {
                lint: "panic-path",
                file: "src/serve/a.rs".to_string(),
                line: 7,
                message: "50% done\nnext".to_string(),
            }],
            files_scanned: 1,
            ..Report::default()
        };
        let gh = render_github(&report);
        assert!(
            gh.contains("::error file=src/serve/a.rs,line=7,title=analyze%3A panic-path::"),
            "{gh}"
        );
        assert!(gh.contains("50%25 done%0Anext"), "{gh}");
        assert!(gh.contains("1 finding(s)"), "{gh}");
    }

    #[test]
    fn cross_file_lock_inversion_found_by_multi_file_analysis() {
        // File A holds `tenants` and calls into file B, which acquires
        // `inner` — `inner` precedes `tenants` in GLOBAL_ORDER, so the
        // pair is an inversion only visible across the call boundary.
        let a = "impl Hub { fn rebalance(&self) {\n\
                 let tenants = write_or_recover(&self.tenants);\n\
                 purge_mat_cache(&self.cache);\n} }\n";
        let b = "pub fn purge_mat_cache(c: &Cache) {\n\
                 let inner = lock_or_recover(&c.inner);\n}\n";
        let report = analyze_sources(&[
            ("x/serve/hub.rs".to_string(), a.to_string()),
            ("x/serve/cache_util.rs".to_string(), b.to_string()),
        ]);
        let inv: Vec<&Finding> =
            report.findings.iter().filter(|f| f.lint == "lock-order-transitive").collect();
        assert_eq!(inv.len(), 1, "{:?}", report.findings);
        assert_eq!(inv[0].file, "x/serve/hub.rs");
        assert_eq!(inv[0].line, 3);
        assert!(inv[0].message.contains("cache_util.rs:2"), "{}", inv[0].message);
        // single-file analysis of A alone cannot see it
        let (solo, _) = analyze_source("x/serve/hub.rs", a);
        assert!(!solo.iter().any(|f| f.lint == "lock-order-transitive"), "{solo:?}");
    }
}
