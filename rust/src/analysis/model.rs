//! Per-file item extraction: the semantic model the interprocedural
//! lints run on.
//!
//! [`super::lexer`] gives a flat token stream; this module lifts it to
//! a per-file list of function definitions, each carrying its call
//! sites, lock-acquisition sites, blocking-call sites and spawn sites.
//! Nothing here parses Rust — the extraction is the same
//! token-sequence pattern matching the intra-function lints use, which
//! keeps the two layers honest with each other: a shape the lints can
//! see is a shape the model records, and vice versa.
//!
//! Conservatism contract (see [`super::graph`] for how resolution uses
//! it): the model errs toward *recording* — an unresolvable receiver
//! still records the method name, a dotted path still records its head
//! — and leaves precision to the resolver. The one deliberate
//! *exclusion*: everything inside a spawn closure's argument list is
//! flagged `in_spawn` and kept out of the spawning function's own
//! lock/blocking footprint, because those tokens execute on the new
//! thread, not under the caller's guards.

use super::lexer::{LexedFile, Tok, TokKind};

// ---------------------------------------------------------- token helpers

pub(crate) fn ident_at<'a>(toks: &'a [Tok], i: usize) -> Option<&'a str> {
    match toks.get(i).map(|t| &t.kind) {
        Some(TokKind::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

pub(crate) fn punct_at(toks: &[Tok], i: usize) -> Option<char> {
    match toks.get(i).map(|t| &t.kind) {
        Some(TokKind::Punct(c)) => Some(*c),
        _ => None,
    }
}

pub(crate) fn is_punct(toks: &[Tok], i: usize, c: char) -> bool {
    punct_at(toks, i) == Some(c)
}

pub(crate) fn is_int(toks: &[Tok], i: usize) -> bool {
    matches!(toks.get(i).map(|t| &t.kind), Some(TokKind::Int))
}

pub(crate) const LOCK_METHODS: &[&str] = &["lock", "read", "write"];
pub(crate) const RECOVER_HELPERS: &[&str] = &[
    "lock_or_recover",
    "read_or_recover",
    "write_or_recover",
    "lock_observed",
    "read_observed",
    "write_observed",
];

/// Methods that can block the calling thread: file durability calls,
/// bulk writes, channel receives, thread joins and sleeps. `.join()`
/// and `.recv()` only count with empty argument lists so `Vec::join`
/// on strings and `recv_timeout`-style shims stay out; `recv_timeout`
/// is listed explicitly (a bounded block is still a block under a
/// lock).
pub(crate) const BLOCKING_METHODS: &[&str] =
    &["sync_all", "sync_data", "write_all", "recv", "recv_timeout", "join", "sleep"];

const KEYWORDS: &[&str] = &[
    "if", "else", "while", "for", "loop", "match", "return", "in", "as", "fn", "let", "move",
    "ref", "mut", "pub", "use", "mod", "impl", "where", "break", "continue",
];

// ---------------------------------------------------------------- the model

/// One lock acquisition site.
pub struct Acq {
    pub name: String,
    pub line: u32,
    /// Let-bound guard (held to end of scope) vs a temporary dropped at
    /// the end of the statement (`*self.x.lock()... = v`). Heuristic: a
    /// `let [mut] name = <acquisition>...` statement counts as held —
    /// deliberately including chains like
    /// `let x = lock_or_recover(&m).clone();` whose guard really dies
    /// at the semicolon: the acquisition *order* discipline applies to
    /// those sites all the same, and a later refactor that extends the
    /// binding's life must not be what first surfaces an inversion.
    /// Scope the statement in a block (or `drop` the binding) where the
    /// over-approximation pinches.
    pub held: bool,
    pub tok: usize,
    /// Inside a spawn closure — executes on the new thread.
    pub in_spawn: bool,
    /// The guard's binding name when held (`let guard = ...`), so an
    /// explicit `drop(guard)` can end the hold early.
    pub binding: Option<String>,
    /// Token index of the closing brace of the innermost block the
    /// acquisition lives in (the fn's own close when unnested). A held
    /// guard is released here — `{ let g = lock(..); ... }` scoping is
    /// the idiomatic way to bound a critical section, and the walk must
    /// honor it or everything after the block reports phantom holds.
    pub scope_end: usize,
}

/// One call site inside a fn body.
pub struct CallSite {
    pub name: String,
    /// `Type::name(...)` — the path segment before the final `::`.
    pub qual: Option<String>,
    /// `.name(...)` receiver call.
    pub method: bool,
    /// Method call whose receiver is literally `self`.
    pub on_self: bool,
    /// Receiver ident for simple method calls (`guard.last_seq()` →
    /// `Some("guard")`); `None` for free/qualified calls and chained
    /// receivers (`a.b().c()`). Lets the interprocedural walk tell a
    /// call *on a held guard* — which operates on the already-locked
    /// value and cannot re-acquire its mutex — from a call that could.
    pub recv: Option<String>,
    pub line: u32,
    pub tok: usize,
    pub in_spawn: bool,
}

/// A call that can block the current thread (see [`BLOCKING_METHODS`]).
pub struct BlockingSite {
    pub what: &'static str,
    pub line: u32,
    pub tok: usize,
    pub in_spawn: bool,
}

#[derive(PartialEq, Clone, Copy, Debug)]
pub enum SpawnKind {
    /// `thread::spawn` — a detached-unless-joined OS thread.
    Thread,
    /// `Background::spawn` — joined on drop.
    Background,
    /// `scope.spawn(..)` — joined when the scope ends.
    Scoped,
}

/// What the spawn expression's handle is bound to.
#[derive(PartialEq, Debug)]
pub enum SpawnBinding {
    /// Statement position — the handle is dropped immediately.
    Discarded,
    /// `let _ = ...` — explicitly dropped.
    Wildcard,
    /// `let name = ...`.
    Named(String),
    /// Part of a larger expression (pushed, collected, returned).
    Expr,
}

pub struct SpawnSite {
    pub kind: SpawnKind,
    pub line: u32,
    pub tok: usize,
    /// Token range of the spawn's argument list (the closure body).
    pub args: (usize, usize),
    pub bound: SpawnBinding,
    pub in_spawn: bool,
    /// For a named binding: the handle's name appears again after the
    /// spawn expression (joined, pushed, returned, ...).
    pub used_later: bool,
}

/// `drop(name)` — ends the hold of guard `name`.
pub struct DropSite {
    pub name: String,
    pub tok: usize,
}

pub struct FnDef {
    pub name: String,
    /// Enclosing `impl` type, when the fn is a method.
    pub qual: Option<String>,
    pub line: u32,
    /// Token range of the body (open brace ..= close brace).
    pub span: (usize, usize),
    pub calls: Vec<CallSite>,
    pub acqs: Vec<Acq>,
    pub blocking: Vec<BlockingSite>,
    pub spawns: Vec<SpawnSite>,
    pub drops: Vec<DropSite>,
}

/// An operation on a named atomic flag.
pub struct AtomicSite {
    pub name: String,
    pub op: String,
    pub relaxed: bool,
    pub line: u32,
    pub tok: usize,
    pub in_spawn: bool,
    /// Index into [`FileModel::fns`], when inside a fn body.
    pub fn_idx: Option<usize>,
    /// For `compare_exchange_weak`: a `loop`/`while` appears earlier in
    /// the same fn (the weak variant may fail spuriously and must be
    /// retried).
    pub in_loop: bool,
}

pub struct FileModel {
    pub rel: String,
    pub fns: Vec<FnDef>,
    /// Names bound (field, let, static) to `AtomicBool` in this file.
    pub atomic_bools: Vec<String>,
    pub atomic_ops: Vec<AtomicSite>,
}

// ----------------------------------------------------------- shared shapes

/// Token index ranges of non-test `fn` bodies.
pub(crate) fn fn_spans(lx: &LexedFile) -> Vec<(usize, usize)> {
    let toks = &lx.toks;
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if ident_at(toks, i) == Some("fn") && !lx.is_test[i] {
            let mut k = i + 1;
            while k < toks.len() && !is_punct(toks, k, '{') && !is_punct(toks, k, ';') {
                k += 1;
            }
            if k < toks.len() && is_punct(toks, k, '{') {
                let open = k;
                let mut depth = 0i32;
                while k < toks.len() {
                    if is_punct(toks, k, '{') {
                        depth += 1;
                    } else if is_punct(toks, k, '}') {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                spans.push((open, k.min(toks.len())));
            }
        }
        i += 1;
    }
    spans
}

pub(crate) fn acquisitions(toks: &[Tok], (open, close): (usize, usize)) -> Vec<Acq> {
    let mut acqs = Vec::new();
    for i in open..close {
        // helper form: lock_or_recover(&self.buckets)
        if ident_at(toks, i).is_some_and(|h| RECOVER_HELPERS.contains(&h))
            && is_punct(toks, i + 1, '(')
        {
            let mut depth = 0i32;
            let mut k = i + 1;
            let mut last_ident: Option<&str> = None;
            while k < close {
                if is_punct(toks, k, '(') {
                    depth += 1;
                } else if is_punct(toks, k, ')') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if let Some(id) = ident_at(toks, k) {
                    last_ident = Some(id);
                }
                k += 1;
            }
            if let Some(name) = last_ident {
                let held = is_let_bound(toks, i);
                acqs.push(Acq {
                    name: name.to_string(),
                    line: toks[i].line,
                    held,
                    tok: i,
                    in_spawn: false,
                    binding: if held { ident_at(toks, i - 2).map(str::to_string) } else { None },
                    scope_end: scope_end(toks, i, close),
                });
            }
            continue;
        }
        // raw form: path.lock() / .read() / .write() — the empty parens
        // are load-bearing: `w.write(buf)` / `r.read(&mut buf)` are
        // std::io calls, not lock acquisitions
        if is_punct(toks, i, '.')
            && ident_at(toks, i + 1).is_some_and(|m| LOCK_METHODS.contains(&m))
            && is_punct(toks, i + 2, '(')
            && is_punct(toks, i + 3, ')')
            && i >= 1
            && ident_at(toks, i - 1).is_some()
        {
            let name = ident_at(toks, i - 1).unwrap_or_default().to_string();
            // walk back over the dotted path to the expression head
            let mut head = i - 1;
            while head >= 2 && is_punct(toks, head - 1, '.') && ident_at(toks, head - 2).is_some()
            {
                head -= 2;
            }
            let held = is_let_bound(toks, head);
            acqs.push(Acq {
                name,
                line: toks[i].line,
                held,
                tok: i,
                in_spawn: false,
                binding: if held { ident_at(toks, head - 2).map(str::to_string) } else { None },
                scope_end: scope_end(toks, i, close),
            });
        }
    }
    acqs
}

/// The token index where a guard acquired at `from` goes out of scope:
/// the first `}` that closes a block opened *before* `from`, bounded by
/// the fn's own closing brace.
fn scope_end(toks: &[Tok], from: usize, close: usize) -> usize {
    let mut depth = 0i32;
    let mut k = from;
    while k < close {
        if is_punct(toks, k, '{') {
            depth += 1;
        } else if is_punct(toks, k, '}') {
            depth -= 1;
            if depth < 0 {
                return k;
            }
        }
        k += 1;
    }
    close
}

/// Does the expression starting at `toks[start]` sit directly on the
/// right-hand side of a `let [mut] name = ...` statement?
pub(crate) fn is_let_bound(toks: &[Tok], start: usize) -> bool {
    if start < 3 || !is_punct(toks, start - 1, '=') {
        return false;
    }
    let mut p = start - 2;
    if ident_at(toks, p).is_none() {
        return false;
    }
    p -= 1;
    if ident_at(toks, p) == Some("mut") {
        if p == 0 {
            return false;
        }
        p -= 1;
    }
    ident_at(toks, p) == Some("let")
}

/// `toks[i]` is a type name (`HashMap`, `AtomicBool`, ...). Return the
/// name it is bound to, for `name: [path::]Type<...>` (field / typed
/// let / static) and `let [mut] name = [path::]Type::new()` shapes.
pub(crate) fn binding_name(toks: &[Tok], i: usize) -> Option<String> {
    let mut j = i;
    while j >= 3
        && is_punct(toks, j - 1, ':')
        && is_punct(toks, j - 2, ':')
        && ident_at(toks, j - 3).is_some()
    {
        j -= 3;
    }
    if j == 0 {
        return None;
    }
    if is_punct(toks, j - 1, ':') && j >= 2 && !is_punct(toks, j - 2, ':') {
        return ident_at(toks, j - 2).map(str::to_string);
    }
    if is_punct(toks, j - 1, '=') && j >= 2 {
        return ident_at(toks, j - 2).map(str::to_string);
    }
    None
}

// --------------------------------------------------------------- extraction

/// Extract the semantic model for one lexed file.
pub fn extract(rel: &str, lx: &LexedFile) -> FileModel {
    let toks = &lx.toks;
    let impls = impl_ranges(lx);
    let mut fns = Vec::new();
    for (open, close) in fn_spans(lx) {
        // fn name: the ident right after the `fn` keyword preceding the
        // open brace. Walk back from the brace to the nearest `fn` that
        // is followed by a name — a bare `fn(` in a fn-pointer
        // parameter type is not the definition keyword.
        let mut f = open;
        while f > 0 && !(ident_at(toks, f) == Some("fn") && ident_at(toks, f + 1).is_some()) {
            f -= 1;
        }
        let Some(name) = ident_at(toks, f + 1) else { continue };
        let qual = impls
            .iter()
            .find(|(o, c, _)| f > *o && f < *c)
            .map(|(_, _, ty)| ty.clone());
        let spawns = spawn_sites(toks, (open, close));
        let in_spawn = |tok: usize| spawns.iter().any(|s| tok > s.args.0 && tok < s.args.1);
        let mut acqs = acquisitions(toks, (open, close));
        for a in &mut acqs {
            a.in_spawn = in_spawn(a.tok);
        }
        let calls = call_sites(toks, (open, close), &in_spawn);
        let blocking = blocking_sites(toks, (open, close), &in_spawn);
        let drops = drop_sites(toks, (open, close));
        fns.push(FnDef {
            name: name.to_string(),
            qual,
            line: toks[f].line,
            span: (open, close),
            calls,
            acqs,
            blocking,
            spawns,
            drops,
        });
    }
    let (atomic_bools, atomic_ops) = atomics(lx, &fns);
    FileModel { rel: rel.to_string(), fns, atomic_bools, atomic_ops }
}

/// `(open, close, type)` token ranges of `impl` blocks, used to qualify
/// method names. The type is the last segment of the path after `for`
/// (trait impls) or after `impl` (inherent impls).
fn impl_ranges(lx: &LexedFile) -> Vec<(usize, usize, String)> {
    let toks = &lx.toks;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if ident_at(toks, i) != Some("impl") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        // skip the generic parameter list, if any
        if is_punct(toks, j, '<') {
            let mut depth = 0i32;
            while j < toks.len() {
                if is_punct(toks, j, '<') {
                    depth += 1;
                } else if is_punct(toks, j, '>') {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        // scan the header up to `{`; remember the last path segment
        // seen after `impl` and, separately, after `for`.
        let mut first: Option<String> = None;
        let mut after_for: Option<String> = None;
        let mut saw_for = false;
        let mut depth = 0i32; // angle-bracket depth: ignore generic args
        while j < toks.len() && !is_punct(toks, j, '{') && !is_punct(toks, j, ';') {
            if is_punct(toks, j, '<') {
                depth += 1;
            } else if is_punct(toks, j, '>') {
                depth -= 1;
            } else if depth == 0 {
                if ident_at(toks, j) == Some("for") {
                    saw_for = true;
                } else if ident_at(toks, j) == Some("where") {
                    break;
                } else if let Some(id) = ident_at(toks, j) {
                    if id != "mut" && id != "dyn" {
                        // take the first path's segments; a later
                        // segment (preceded by `::`) overwrites so the
                        // final one wins (`fmt::Display` -> `Display`)
                        if saw_for {
                            if after_for.is_none() || is_punct(toks, j - 1, ':') {
                                after_for = Some(id.to_string());
                            }
                        } else if first.is_none() || is_punct(toks, j - 1, ':') {
                            first = Some(id.to_string());
                        }
                    }
                }
            }
            j += 1;
        }
        if j >= toks.len() || !is_punct(toks, j, '{') {
            i += 1;
            continue;
        }
        let open = j;
        let mut brace = 0i32;
        while j < toks.len() {
            if is_punct(toks, j, '{') {
                brace += 1;
            } else if is_punct(toks, j, '}') {
                brace -= 1;
                if brace == 0 {
                    break;
                }
            }
            j += 1;
        }
        if let Some(ty) = after_for.or(first) {
            out.push((open, j.min(toks.len()), ty));
        }
        i = open + 1;
    }
    out
}

fn call_sites(
    toks: &[Tok],
    (open, close): (usize, usize),
    in_spawn: &dyn Fn(usize) -> bool,
) -> Vec<CallSite> {
    let mut out = Vec::new();
    for i in open..close {
        let Some(name) = ident_at(toks, i) else { continue };
        if !is_punct(toks, i + 1, '(') || KEYWORDS.contains(&name) {
            continue;
        }
        // lock sites, blocking sites and spawn sites are modeled
        // separately; don't double-record them as calls.
        if RECOVER_HELPERS.contains(&name) || name == "spawn" || name == "drop" {
            continue;
        }
        let method = i >= 1 && is_punct(toks, i - 1, '.');
        if method && (LOCK_METHODS.contains(&name) || BLOCKING_METHODS.contains(&name)) {
            continue;
        }
        let qual = if !method
            && i >= 3
            && is_punct(toks, i - 1, ':')
            && is_punct(toks, i - 2, ':')
            && ident_at(toks, i - 3).is_some()
        {
            ident_at(toks, i - 3).map(str::to_string)
        } else {
            None
        };
        let on_self = method && i >= 2 && ident_at(toks, i - 2) == Some("self");
        let recv = if method && !on_self {
            ident_at(toks, i - 2).map(str::to_string)
        } else {
            None
        };
        out.push(CallSite {
            name: name.to_string(),
            qual,
            method,
            on_self,
            recv,
            line: toks[i].line,
            tok: i,
            in_spawn: in_spawn(i),
        });
    }
    out
}

fn blocking_sites(
    toks: &[Tok],
    (open, close): (usize, usize),
    in_spawn: &dyn Fn(usize) -> bool,
) -> Vec<BlockingSite> {
    let mut out = Vec::new();
    for i in open..close {
        let Some(name) = ident_at(toks, i) else { continue };
        let Some(what) = BLOCKING_METHODS.iter().find(|m| **m == name) else { continue };
        if !is_punct(toks, i + 1, '(') {
            continue;
        }
        let method = i >= 1 && is_punct(toks, i - 1, '.');
        // `sleep` is a free/qualified call (thread::sleep); the rest
        // are methods.
        if name != "sleep" && !method {
            continue;
        }
        // `.join()` / `.recv()` must be no-arg: `sep.join(parts)` is
        // string joining, not a thread join.
        if (name == "join" || name == "recv") && !is_punct(toks, i + 2, ')') {
            continue;
        }
        out.push(BlockingSite { what, line: toks[i].line, tok: i, in_spawn: in_spawn(i) });
    }
    out
}

fn spawn_sites(toks: &[Tok], (open, close): (usize, usize)) -> Vec<SpawnSite> {
    let mut out: Vec<SpawnSite> = Vec::new();
    for i in open..close {
        if ident_at(toks, i) != Some("spawn") || !is_punct(toks, i + 1, '(') {
            continue;
        }
        let method = i >= 1 && is_punct(toks, i - 1, '.');
        let qual = if !method
            && i >= 3
            && is_punct(toks, i - 1, ':')
            && is_punct(toks, i - 2, ':')
            && ident_at(toks, i - 3).is_some()
        {
            ident_at(toks, i - 3)
        } else {
            None
        };
        let kind = match qual {
            Some("thread") => SpawnKind::Thread,
            Some(_) => SpawnKind::Background,
            None if method => SpawnKind::Scoped,
            None => continue,
        };
        // argument-list token range
        let mut depth = 0i32;
        let mut k = i + 1;
        while k < close {
            if is_punct(toks, k, '(') {
                depth += 1;
            } else if is_punct(toks, k, ')') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            k += 1;
        }
        let args = (i + 1, k);
        // expression head: walk back over the `a::b::spawn` path
        let mut head = i;
        while head >= 3
            && is_punct(toks, head - 1, ':')
            && is_punct(toks, head - 2, ':')
            && ident_at(toks, head - 3).is_some()
        {
            head -= 3;
        }
        if method && head >= 2 && ident_at(toks, head - 2).is_some() {
            head -= 2; // receiver ident
        }
        let bound = if head == 0 {
            SpawnBinding::Discarded
        } else if is_punct(toks, head - 1, ';')
            || is_punct(toks, head - 1, '{')
            || is_punct(toks, head - 1, '}')
        {
            SpawnBinding::Discarded
        } else if is_let_bound(toks, head) {
            let name = ident_at(toks, head - 2).unwrap_or("_");
            if name == "_" {
                SpawnBinding::Wildcard
            } else {
                SpawnBinding::Named(name.to_string())
            }
        } else if head >= 2 && is_punct(toks, head - 1, '=') && ident_at(toks, head - 2) == Some("_")
        {
            SpawnBinding::Wildcard
        } else {
            SpawnBinding::Expr
        };
        let used_later = match &bound {
            SpawnBinding::Named(name) => {
                (args.1..close).any(|k| ident_at(toks, k) == Some(name.as_str()))
            }
            _ => false,
        };
        let in_spawn = out.iter().any(|s| i > s.args.0 && i < s.args.1);
        out.push(SpawnSite { kind, line: toks[i].line, tok: i, args, bound, in_spawn, used_later });
    }
    out
}

fn drop_sites(toks: &[Tok], (open, close): (usize, usize)) -> Vec<DropSite> {
    let mut out = Vec::new();
    for i in open..close {
        if ident_at(toks, i) == Some("drop")
            && is_punct(toks, i + 1, '(')
            && ident_at(toks, i + 2).is_some()
            && is_punct(toks, i + 3, ')')
        {
            out.push(DropSite { name: ident_at(toks, i + 2).unwrap().to_string(), tok: i });
        }
    }
    out
}

/// Collect `AtomicBool` binding names and all operations on them.
/// Restricted to `AtomicBool` deliberately: boolean flags are the
/// cross-thread signaling shape where `Relaxed` is a bug, while
/// `Relaxed` on `AtomicU64` counters is this repo's sanctioned idiom.
fn atomics(lx: &LexedFile, fns: &[FnDef]) -> (Vec<String>, Vec<AtomicSite>) {
    let toks = &lx.toks;
    let mut names: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        if ident_at(toks, i) == Some("AtomicBool") {
            if let Some(name) = binding_name(toks, i) {
                if !names.contains(&name) {
                    names.push(name);
                }
            }
        }
    }
    const ATOMIC_OPS: &[&str] = &[
        "load", "store", "swap", "fetch_and", "fetch_or", "fetch_xor", "compare_exchange",
        "compare_exchange_weak",
    ];
    let mut ops = Vec::new();
    for i in 0..toks.len() {
        if lx.is_test[i] {
            continue;
        }
        let Some(name) = ident_at(toks, i).filter(|n| names.iter().any(|x| x == *n)) else {
            continue;
        };
        if !is_punct(toks, i + 1, '.') {
            continue;
        }
        let Some(op) = ident_at(toks, i + 2).filter(|o| ATOMIC_OPS.contains(o)) else {
            continue;
        };
        if !is_punct(toks, i + 3, '(') {
            continue;
        }
        // scan the argument list for an `Ordering::Relaxed`
        let mut depth = 0i32;
        let mut k = i + 3;
        let mut relaxed = false;
        while k < toks.len() {
            if is_punct(toks, k, '(') {
                depth += 1;
            } else if is_punct(toks, k, ')') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if ident_at(toks, k) == Some("Relaxed") {
                relaxed = true;
            }
            k += 1;
        }
        let fn_idx = fns.iter().position(|f| i > f.span.0 && i < f.span.1);
        let in_spawn = fn_idx.is_some_and(|fi| {
            fns[fi].spawns.iter().any(|s| i > s.args.0 && i < s.args.1)
        });
        let in_loop = fn_idx.is_some_and(|fi| {
            (fns[fi].span.0..i)
                .any(|k| ident_at(toks, k).is_some_and(|id| id == "loop" || id == "while"))
        });
        ops.push(AtomicSite {
            name: name.to_string(),
            op: op.to_string(),
            relaxed,
            line: toks[i].line,
            tok: i,
            in_spawn,
            fn_idx,
            in_loop,
        });
    }
    (names, ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn model(src: &str) -> FileModel {
        extract("x/serve/a.rs", &lex(src))
    }

    #[test]
    fn fn_names_and_impl_quals() {
        let src = "impl Registry { fn evict(&self) {} }\n\
                   impl fmt::Display for Summary { fn fmt(&self) {} }\n\
                   fn free() {}\n";
        let m = model(src);
        let names: Vec<(String, Option<String>)> =
            m.fns.iter().map(|f| (f.name.clone(), f.qual.clone())).collect();
        assert_eq!(
            names,
            vec![
                ("evict".into(), Some("Registry".into())),
                ("fmt".into(), Some("Summary".into())),
                ("free".into(), None),
            ]
        );
    }

    #[test]
    fn calls_record_quals_methods_and_receivers() {
        let src = "fn f(&self) { self.emit(1); Registry::restore(p); helper(); x.len(); }\n";
        let m = model(src);
        let c = &m.fns[0].calls;
        assert_eq!(c.len(), 4, "{:?}", c.iter().map(|c| &c.name).collect::<Vec<_>>());
        assert!(c[0].on_self && c[0].method && c[0].name == "emit");
        assert_eq!(c[1].qual.as_deref(), Some("Registry"));
        assert!(!c[2].method && c[2].qual.is_none());
        assert!(c[3].method && !c[3].on_self);
    }

    #[test]
    fn spawn_closure_contents_are_marked() {
        let src = "fn f(&self) { let h = thread::spawn(move || { g(); q.recv(); }); h.join(); }\n";
        let m = model(src);
        let f = &m.fns[0];
        assert_eq!(f.spawns.len(), 1);
        assert_eq!(f.spawns[0].kind, SpawnKind::Thread);
        assert_eq!(f.spawns[0].bound, SpawnBinding::Named("h".into()));
        let g = f.calls.iter().find(|c| c.name == "g").unwrap();
        assert!(g.in_spawn);
        let recv = f.blocking.iter().find(|b| b.what == "recv").unwrap();
        assert!(recv.in_spawn);
        let join = f.blocking.iter().find(|b| b.what == "join").unwrap();
        assert!(!join.in_spawn);
    }

    #[test]
    fn spawn_bindings_classified() {
        let src = "fn f() { thread::spawn(|| {}); let _ = thread::spawn(|| {});\n\
                   v.push(thread::spawn(|| {})); s.spawn(|| {}); }\n";
        let m = model(src);
        let kinds: Vec<(SpawnKind, &SpawnBinding)> =
            m.fns[0].spawns.iter().map(|s| (s.kind, &s.bound)).collect();
        assert_eq!(kinds[0], (SpawnKind::Thread, &SpawnBinding::Discarded));
        assert_eq!(kinds[1], (SpawnKind::Thread, &SpawnBinding::Wildcard));
        assert_eq!(kinds[2], (SpawnKind::Thread, &SpawnBinding::Expr));
        assert_eq!(kinds[3].0, SpawnKind::Scoped);
    }

    #[test]
    fn string_join_is_not_blocking() {
        let src = "fn f(v: &[String]) -> String { v.join(\", \") }\n";
        assert!(model(src).fns[0].blocking.is_empty());
    }

    #[test]
    fn atomic_bool_relaxed_tracked_with_spawn_scope() {
        let src = "fn f() { let stop = AtomicBool::new(false);\n\
                   thread::spawn(|| { while !stop.load(Ordering::Relaxed) {} });\n\
                   stop.store(true, Ordering::Relaxed); }\n";
        let m = model(src);
        assert_eq!(m.atomic_bools, vec!["stop".to_string()]);
        assert_eq!(m.atomic_ops.len(), 2);
        assert!(m.atomic_ops[0].in_spawn && m.atomic_ops[0].relaxed);
        assert!(!m.atomic_ops[1].in_spawn && m.atomic_ops[1].relaxed);
    }

    #[test]
    fn guard_drop_sites_recorded() {
        let src = "fn f(&self) { let g = lock_or_recover(&self.wal); drop(g); }\n";
        let m = model(src);
        assert_eq!(m.fns[0].acqs.len(), 1);
        assert!(m.fns[0].acqs[0].held);
        assert_eq!(m.fns[0].drops.len(), 1);
        assert_eq!(m.fns[0].drops[0].name, "g");
    }
}
