//! The declared lock-acquisition order, one list per file.
//!
//! The `lock-discipline` lint checks every function against this table:
//! within one function body, guards that are *held* (let-bound — see
//! [`super::lints`] for the held/temporary heuristic) must be acquired
//! in list order. Acquiring a lock that sits earlier in the list while
//! a later one is held is an inversion finding.
//!
//! Files in scope (`serve/` + `store/`) that are **not** listed here
//! get the stricter default: any two distinct held locks nested in one
//! function is a finding — the fix is to add (and think through) an
//! entry below.
//!
//! Rationale for each entry:
//! - `serve/registry.rs` — the mat-cache (`inner`) consults tenant pins
//!   while evicting, and pin checks read the `tenants` table, so
//!   `inner` must come first; registration/restore hold `tenants` while
//!   swapping a slot's `current` adapter. Cache purges run *after* the
//!   tenants guard drops (see `try_evict_tenant`) — nesting the other
//!   way is exactly the inversion this table rejects.
//! - `serve/server.rs` — summarize reads the per-tenant observability
//!   map (`tenants`) and drops that guard before snapshotting the
//!   batch-size log (`batch_sizes`); the batcher and per-worker flight
//!   recorders are only ever locked stand-alone (temporary guards), but
//!   declare the order anyway so a future held use is checked rather
//!   than "undeclared".
//! - `serve/shard.rs` — the router's result channel is drained while
//!   sessions are appended to `collected`; seat-level `registry`/`store`
//!   handles are cloned out last during shutdown.
//! - `store/mod.rs` — the WAL mutex is the store's only lock.
//! - `serve/scheduler.rs` — each response slot's `state` is the only
//!   lock; listed so nesting two slots is caught as an inversion of
//!   "same name after same name" rather than slipping by undeclared.
//! - `serve/admission.rs` — the limiter snapshots its `cfg` (a copied
//!   read, never a held guard) before touching the token `buckets`;
//!   declared so a future held-cfg refactor is checked.
//! - `serve/spool.rs` — the tick-stats mutex is the spooler's only
//!   lock.
//! - `util/pool.rs` — the service queue `state` is taken on every
//!   dispatch; the two error-collection mutexes are only touched
//!   during startup/teardown, after any queue guard is gone.

/// `(file-path substring, lock field names in required acquisition order)`.
pub const LOCK_ORDER: &[(&str, &[&str])] = &[
    ("serve/registry.rs", &["inner", "tenants", "current"]),
    ("serve/server.rs", &["batcher", "tenants", "batch_sizes"]),
    ("serve/shard.rs", &["table", "results_rx", "collected", "registry", "store"]),
    ("serve/scheduler.rs", &["state"]),
    ("serve/admission.rs", &["cfg", "buckets"]),
    ("serve/spool.rs", &["stats"]),
    ("util/pool.rs", &["state", "init_errors", "first_error"]),
    ("store/mod.rs", &["wal"]),
];

/// The crate-wide total order the interprocedural pass checks against.
///
/// Each per-file list above must project onto this order (asserted in
/// the tests below): the per-file lists are the readable, per-module
/// contracts; this list is their join, needed once the held-guard set
/// propagates across call boundaries. Names are bare lock fields —
/// two structs sharing a field name share an order slot, which is
/// conservative (a false inversion between unrelated locks is answered
/// by renaming one field or a reasoned allow, never by a missed real
/// inversion).
///
/// Rationale for the cross-file constraints (the per-file rationale
/// lives on `LOCK_ORDER`):
/// - router locks (`table`..`collected`) come first: the shard router
///   calls into seat registries/stores while routing, never the other
///   way around;
/// - `batcher`/`inner` precede `tenants`: submit paths push into the
///   batcher and the mat-cache consults pins before touching the
///   tenant tables;
/// - seat handles (`registry`, `store`) and the admission pair sit
///   between the serving tables and the leaf locks;
/// - `wal` is last: the WAL mutex is a leaf — code holding it must
///   not call back into the serving tier.
pub const GLOBAL_ORDER: &[&str] = &[
    "table",
    "results_rx",
    "collected",
    "batcher",
    "inner",
    "tenants",
    "batch_sizes",
    "current",
    "registry",
    "store",
    "cfg",
    "buckets",
    "stats",
    "state",
    "init_errors",
    "first_error",
    "wal",
];

/// Position of `name` in [`GLOBAL_ORDER`], if it is a declared lock.
pub fn global_idx(name: &str) -> Option<usize> {
    GLOBAL_ORDER.iter().position(|n| *n == name)
}

/// The declared order for `rel` (normalized with `/` separators), if any.
pub fn order_for(rel: &str) -> Option<&'static [&'static str]> {
    LOCK_ORDER
        .iter()
        .find(|(file, _)| rel.contains(file))
        .map(|(_, names)| *names)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_order_is_declared() {
        let order = order_for("rust/src/serve/registry.rs").unwrap();
        let inner = order.iter().position(|n| *n == "inner").unwrap();
        let tenants = order.iter().position(|n| *n == "tenants").unwrap();
        assert!(inner < tenants, "cache lock precedes the tenant table");
    }

    #[test]
    fn unlisted_file_has_no_order() {
        assert!(order_for("serve/batcher.rs").is_none());
    }

    #[test]
    fn global_order_has_no_duplicates() {
        for (i, a) in GLOBAL_ORDER.iter().enumerate() {
            assert!(
                !GLOBAL_ORDER[i + 1..].contains(a),
                "duplicate lock name `{a}` in GLOBAL_ORDER"
            );
        }
    }

    /// Every per-file list must be an increasing projection of the
    /// global order, or the intra- and inter-procedural checks would
    /// disagree about which nesting is the inversion.
    #[test]
    fn per_file_lists_project_onto_global_order() {
        for (file, list) in LOCK_ORDER {
            let mut last = None;
            for name in *list {
                let idx = global_idx(name)
                    .unwrap_or_else(|| panic!("{file}: `{name}` missing from GLOBAL_ORDER"));
                if let Some(prev) = last {
                    assert!(idx > prev, "{file}: `{name}` out of global order");
                }
                last = Some(idx);
            }
        }
    }
}
