//! The declared lock-acquisition order, one list per file.
//!
//! The `lock-discipline` lint checks every function against this table:
//! within one function body, guards that are *held* (let-bound — see
//! [`super::lints`] for the held/temporary heuristic) must be acquired
//! in list order. Acquiring a lock that sits earlier in the list while
//! a later one is held is an inversion finding.
//!
//! Files in scope (`serve/` + `store/`) that are **not** listed here
//! get the stricter default: any two distinct held locks nested in one
//! function is a finding — the fix is to add (and think through) an
//! entry below.
//!
//! Rationale for each entry:
//! - `serve/registry.rs` — the mat-cache (`inner`) consults tenant pins
//!   while evicting, and pin checks read the `tenants` table, so
//!   `inner` must come first; registration/restore hold `tenants` while
//!   swapping a slot's `current` adapter. Cache purges run *after* the
//!   tenants guard drops (see `try_evict_tenant`) — nesting the other
//!   way is exactly the inversion this table rejects.
//! - `serve/server.rs` — summarize reads the per-tenant observability
//!   map (`tenants`) and drops that guard before snapshotting the
//!   batch-size log (`batch_sizes`); the batcher and per-worker flight
//!   recorders are only ever locked stand-alone (temporary guards), but
//!   declare the order anyway so a future held use is checked rather
//!   than "undeclared".
//! - `serve/shard.rs` — the router's result channel is drained while
//!   sessions are appended to `collected`; seat-level `registry`/`store`
//!   handles are cloned out last during shutdown.
//! - `store/mod.rs` — the WAL mutex is the store's only lock.
//! - `serve/scheduler.rs` — each response slot's `state` is the only
//!   lock; listed so nesting two slots is caught as an inversion of
//!   "same name after same name" rather than slipping by undeclared.

/// `(file-path substring, lock field names in required acquisition order)`.
pub const LOCK_ORDER: &[(&str, &[&str])] = &[
    ("serve/registry.rs", &["inner", "tenants", "current"]),
    ("serve/server.rs", &["batcher", "tenants", "batch_sizes"]),
    ("serve/shard.rs", &["table", "results_rx", "collected", "registry", "store"]),
    ("serve/scheduler.rs", &["state"]),
    ("store/mod.rs", &["wal"]),
];

/// The declared order for `rel` (normalized with `/` separators), if any.
pub fn order_for(rel: &str) -> Option<&'static [&'static str]> {
    LOCK_ORDER
        .iter()
        .find(|(file, _)| rel.contains(file))
        .map(|(_, names)| *names)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_order_is_declared() {
        let order = order_for("rust/src/serve/registry.rs").unwrap();
        let inner = order.iter().position(|n| *n == "inner").unwrap();
        let tenants = order.iter().position(|n| *n == "tenants").unwrap();
        assert!(inner < tenants, "cache lock precedes the tenant table");
    }

    #[test]
    fn unlisted_file_has_no_order() {
        assert!(order_for("serve/spool.rs").is_none());
    }
}
