//! Experiment configuration: a minimal TOML-subset parser (key = value
//! with [section] headers; strings, numbers, booleans, inline arrays of
//! scalars) plus the preset experiment profiles shipped in configs/.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_strs(&self) -> Result<Vec<String>> {
        match self {
            Value::Arr(v) => v.iter().map(|x| Ok(x.as_str()?.to_string())).collect(),
            _ => bail!("not an array: {self:?}"),
        }
    }
}

/// section -> key -> value; top-level keys live in section "".
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}", lineno + 1))?;
            cfg.sections.entry(section.clone()).or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).and_then(|v| v.as_str().ok().map(String::from))
            .unwrap_or_else(|| default.to_string())
    }
}

fn parse_value(s: &str) -> Result<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    s.parse::<f64>().map(Value::Num)
        .map_err(|_| anyhow::anyhow!("cannot parse value {s:?}"))
}

/// Training-profile defaults used by the CLI when no config file is given.
/// `quick` keeps the full pipeline exercised in minutes on one core;
/// `full` approaches the paper's budgets (hours).
pub fn preset(name: &str) -> Result<Config> {
    let text = match name {
        "quick" => "\
[train]\nsteps = 60\nlr = 0.01\nweight_decay = 0.01\n\
train_examples = 256\ntest_examples = 128\neval_every = 30\n\
[pretrain]\nsteps = 150\nlr = 0.003\n\
[sweep]\nseeds = [0]\n",
        "default" => "\
[train]\nsteps = 150\nlr = 0.01\nweight_decay = 0.01\n\
train_examples = 512\ntest_examples = 256\neval_every = 50\n\
[pretrain]\nsteps = 400\nlr = 0.003\n\
[sweep]\nseeds = [0, 1]\n",
        "full" => "\
[train]\nsteps = 400\nlr = 0.01\nweight_decay = 0.01\n\
train_examples = 1024\ntest_examples = 512\neval_every = 100\n\
[pretrain]\nsteps = 1000\nlr = 0.003\n\
[sweep]\nseeds = [0, 1, 2, 3, 4]\n",
        other => bail!("unknown preset {other:?} (quick|default|full)"),
    };
    Config::parse(text)
}

/// Build a TrainConfig from a parsed profile.
pub fn train_config(cfg: &Config) -> crate::coordinator::trainer::TrainConfig {
    crate::coordinator::trainer::TrainConfig {
        steps: cfg.f64_or("train", "steps", 150.0) as usize,
        lr: cfg.f64_or("train", "lr", 0.01) as f32,
        weight_decay: cfg.f64_or("train", "weight_decay", 0.01) as f32,
        warmup_frac: cfg.f64_or("train", "warmup_frac", 0.1) as f32,
        eval_every: cfg.f64_or("train", "eval_every", 50.0) as usize,
        seed: cfg.f64_or("train", "seed", 0.0) as u64,
        train_examples: cfg.f64_or("train", "train_examples", 512.0) as usize,
        test_examples: cfg.f64_or("train", "test_examples", 256.0) as usize,
    }
}

pub fn sweep_seeds(cfg: &Config) -> Vec<u64> {
    match cfg.get("sweep", "seeds") {
        Some(Value::Arr(v)) => v.iter()
            .filter_map(|x| x.as_f64().ok().map(|f| f as u64)).collect(),
        _ => vec![0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(
            "top = 1\n[a]\nx = 2.5\nname = \"hi\" # comment\nflag = true\n\
             seeds = [0, 1, 2]\n[b]\ny = -3\n").unwrap();
        assert_eq!(c.f64_or("", "top", 0.0), 1.0);
        assert_eq!(c.f64_or("a", "x", 0.0), 2.5);
        assert_eq!(c.str_or("a", "name", ""), "hi");
        assert_eq!(c.get("a", "flag"), Some(&Value::Bool(true)));
        assert_eq!(c.f64_or("b", "y", 0.0), -3.0);
        if let Some(Value::Arr(v)) = c.get("a", "seeds") {
            assert_eq!(v.len(), 3);
        } else {
            panic!("seeds not parsed");
        }
    }

    #[test]
    fn presets_parse_and_scale() {
        let q = preset("quick").unwrap();
        let f = preset("full").unwrap();
        assert!(train_config(&q).steps < train_config(&f).steps);
        assert_eq!(sweep_seeds(&f).len(), 5);
        assert!(preset("nope").is_err());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("no equals sign here").is_err());
        assert!(Config::parse("x = @@@").is_err());
    }
}
