//! Checkpoint format (own binary container; no external deps):
//!
//!   magic "QPCK" | u32 version
//!   versions 2 and 3 (adapter manifest):
//!     u32 tenant_len | tenant utf8 | u32 q | u32 n_layers
//!   all versions: u32 count
//!   per tensor: u32 name_len | name utf8 | u8 dtype (0=f32, 1=i32)
//!               | u32 ndim | u64 dims... | payload (LE)
//!   version 3 only: u64 FNV-1a digest of every byte after the version
//!                   field (trailer; integrity checksum)
//!
//! Stores either a full model (pretraining output, version 1), adapters
//! only (PEFT fine-tuning output — the paper's few-KB artifact story),
//! or an adapter plus the manifest the serving registry needs to
//! validate tenant identity and Pauli shape *before* materializing.
//! Adapter checkpoints are written as **version 3**: the whole-payload
//! FNV-1a trailer means any single-byte corruption anywhere after the
//! version field is detected at load time, before anything
//! materializes (the xor-multiply FNV step is injective per byte, so a
//! same-length substitution always changes the digest). Version-2
//! files — written before the checksum existed — still load, without
//! verification. The spool watcher quarantines mismatches to
//! `rejected/` like any other validation failure; this is the
//! integrity half of upload trust (authenticity/signatures remain
//! future work).
//!
//! Loading is hardened against corrupt or hostile files: every
//! length/count field read from the file is capped before it sizes an
//! allocation, and payloads are bulk byte-slice reads so truncation
//! surfaces as one contextual error instead of a multi-GB `vec!` attempt.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;
use crate::util::fnv;

const MAGIC: &[u8; 4] = b"QPCK";
const VERSION: u32 = 1;
/// Legacy adapter format: manifest, no integrity trailer (read-only).
const VERSION_ADAPTER: u32 = 2;
/// Current adapter format: manifest + whole-payload FNV-1a trailer.
const VERSION_ADAPTER_CK: u32 = 3;

/// `Write` adapter that FNV-digests everything written through it
/// while `active` (the v3 save path; the digest becomes the file's
/// trailer — v1 full-model saves skip the per-byte pass entirely).
struct HashWriter<W: Write> {
    inner: W,
    digest: u64,
    active: bool,
}

impl<W: Write> Write for HashWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        if self.active {
            self.digest = fnv::update(self.digest, &buf[..n]);
        }
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// `Read` adapter that FNV-digests everything read through it while
/// `active` (the v3 load path; switched off to read the trailer itself).
struct HashReader<R: Read> {
    inner: R,
    digest: u64,
    active: bool,
}

impl<R: Read> Read for HashReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        if self.active {
            self.digest = fnv::update(self.digest, &buf[..n]);
        }
        Ok(n)
    }
}

/// Header caps: far above anything the repro writes, far below anything
/// that could turn a short garbage file into a giant allocation.
const MAX_TENSORS: usize = 65_536;
const MAX_NAME_LEN: usize = 4_096;
const MAX_NDIM: usize = 16;
const MAX_NUMEL: usize = 1 << 28; // 256M elements = 1 GiB of f32
const MAX_TENANT_LEN: usize = 256;

/// Serving metadata stored in version-2 checkpoints: which tenant this
/// adapter belongs to and the Pauli circuit shape its thetas parameterize
/// (`q` qubits, `n_layers` entanglement blocks — eq. 2). The registry
/// validates both against the tensor payload before materializing Q_P.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AdapterManifest {
    pub tenant: String,
    pub q: u32,
    pub n_layers: u32,
}

pub fn save(path: &Path, tensors: &[(String, HostTensor)]) -> Result<()> {
    save_impl(path, None, tensors)
}

/// Save an adapter checkpoint (version 3): manifest header + tensors +
/// whole-payload FNV-1a integrity trailer.
pub fn save_adapter(path: &Path, manifest: &AdapterManifest,
                    tensors: &[(String, HostTensor)]) -> Result<()> {
    if manifest.tenant.len() > MAX_TENANT_LEN {
        bail!("tenant id of {} bytes exceeds cap {MAX_TENANT_LEN}",
              manifest.tenant.len());
    }
    save_impl(path, Some(manifest), tensors)
}

/// Save an adapter checkpoint through a hidden temp file plus an
/// atomic same-directory rename — the uploader-side half of the spool
/// protocol ([`crate::serve::spool`]): a watcher polling the target
/// directory can never observe a partially-written file under the final
/// name (it skips dot-files, and the rename is atomic). The temp name
/// embeds the pid and a process-global sequence number, so concurrent
/// uploaders of the *same* adapter write disjoint temp files and the
/// last rename wins whole — never a byte-interleaved hybrid. The temp
/// file is removed on a failed save.
pub fn save_adapter_atomic(path: &Path, manifest: &AdapterManifest,
                           tensors: &[(String, HostTensor)]) -> Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let file_name = path.file_name()
        .with_context(|| format!("checkpoint path {path:?} has no file name"))?;
    let tmp = path.with_file_name(format!(
        ".tmp.{}.{}.{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        file_name.to_string_lossy()));
    if let Err(e) = save_adapter(&tmp, manifest, tensors) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("atomic rename {tmp:?} -> {path:?}"))
}

fn save_impl(path: &Path, manifest: Option<&AdapterManifest>,
             tensors: &[(String, HostTensor)]) -> Result<()> {
    // enforce the same caps load enforces, with write-time messages: a
    // file save can produce but load rejects would read as "corrupt"
    // when the data is merely out of spec — fail before writing instead
    if tensors.len() > MAX_TENSORS {
        bail!("refusing to save {} tensors (cap {MAX_TENSORS})", tensors.len());
    }
    for (name, t) in tensors {
        if name.len() > MAX_NAME_LEN {
            bail!("refusing to save tensor with a {}-byte name (cap \
                   {MAX_NAME_LEN})", name.len());
        }
        if t.shape().len() > MAX_NDIM {
            bail!("refusing to save {name:?} with {} dims (cap {MAX_NDIM})",
                  t.shape().len());
        }
        if t.numel() > MAX_NUMEL {
            bail!("refusing to save {name:?} with {} elements (cap {MAX_NUMEL})",
                  t.numel());
        }
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("create checkpoint dir {parent:?}"))?;
    }
    let mut raw = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?);
    raw.write_all(MAGIC)?;
    let version = match manifest {
        None => VERSION,
        Some(_) => VERSION_ADAPTER_CK,
    };
    raw.write_all(&version.to_le_bytes())?;
    // everything after the version field streams through the digest
    // (adapter files only — v1 skips the hashing pass); the trailer is
    // written outside it
    let mut f = HashWriter {
        inner: raw,
        digest: fnv::OFFSET,
        active: manifest.is_some(),
    };
    if let Some(m) = manifest {
        f.write_all(&len_u32(m.tenant.len(), "tenant id length")?.to_le_bytes())?;
        f.write_all(m.tenant.as_bytes())?;
        f.write_all(&m.q.to_le_bytes())?;
        f.write_all(&m.n_layers.to_le_bytes())?;
    }
    f.write_all(&len_u32(tensors.len(), "tensor count")?.to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&len_u32(name.len(), "tensor name length")?.to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        match t {
            HostTensor::F32 { shape, data } => {
                f.write_all(&[0u8])?;
                write_shape(&mut f, shape)?;
                write_f32s(&mut f, data)?;
            }
            HostTensor::I32 { shape, data } => {
                f.write_all(&[1u8])?;
                write_shape(&mut f, shape)?;
                write_i32s(&mut f, data)?;
            }
        }
    }
    if manifest.is_some() {
        let digest = f.digest;
        f.inner.write_all(&digest.to_le_bytes())?;
    }
    f.flush().with_context(|| format!("flush {path:?}"))?;
    Ok(())
}

fn write_shape(f: &mut impl Write, shape: &[usize]) -> Result<()> {
    f.write_all(&len_u32(shape.len(), "shape rank")?.to_le_bytes())?;
    for &d in shape {
        f.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

/// Bulk LE payload writes: one buffer fill + one `write_all` per tensor
/// instead of one 4-byte write per element (benches/serve.rs records the
/// resulting MB/s next to an element-at-a-time reference).
fn write_f32s(f: &mut impl Write, data: &[f32]) -> Result<()> {
    let mut buf = vec![0u8; data.len() * 4];
    for (c, x) in buf.chunks_exact_mut(4).zip(data) {
        c.copy_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

fn write_i32s(f: &mut impl Write, data: &[i32]) -> Result<()> {
    let mut buf = vec![0u8; data.len() * 4];
    for (c, x) in buf.chunks_exact_mut(4).zip(data) {
        c.copy_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Vec<(String, HostTensor)>> {
    Ok(load_impl(path)?.1)
}

/// Load an adapter checkpoint: the manifest plus its tensors. Version-3
/// files have their whole-payload FNV-1a checksum verified before
/// anything is returned (any single-byte corruption after the version
/// field fails here); version-2 legacy files load without verification.
/// A version-1 file (no manifest) is an error — the registry must never
/// guess which tenant or circuit shape an adapter belongs to.
pub fn load_adapter(path: &Path)
                    -> Result<(AdapterManifest, Vec<(String, HostTensor)>)> {
    let (manifest, tensors) = load_impl(path)?;
    match manifest {
        Some(m) => Ok((m, tensors)),
        None => bail!("{path:?} is a v1 checkpoint with no adapter manifest; \
                       re-save with save_adapter (tenant + pauli config)"),
    }
}

fn load_impl(path: &Path)
             -> Result<(Option<AdapterManifest>, Vec<(String, HostTensor)>)> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    // actual file size bounds every payload allocation below: a ~50-byte
    // hostile file whose header passes the caps must not be able to
    // demand a 1 GiB zeroed buffer before read_exact notices the EOF
    let file_len = file.metadata().map(|m| m.len()).unwrap_or(u64::MAX);
    let mut raw = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    raw.read_exact(&mut magic)
        .with_context(|| format!("{path:?}: reading magic (truncated file?)"))?;
    if &magic != MAGIC {
        bail!("{path:?}: not a QPCK checkpoint");
    }
    let version = read_u32(&mut raw, path, "version")?;
    // v3 files digest everything between the version field and the
    // trailer; other versions read through the same adapter unhashed
    let mut f = HashReader {
        inner: raw,
        digest: fnv::OFFSET,
        active: version == VERSION_ADAPTER_CK,
    };
    let manifest = match version {
        VERSION => None,
        VERSION_ADAPTER | VERSION_ADAPTER_CK => {
            let tenant_len = read_len(&mut f, path, "tenant_len")?;
            if tenant_len > MAX_TENANT_LEN {
                bail!("{path:?}: tenant_len {tenant_len} exceeds cap \
                       {MAX_TENANT_LEN} (corrupt header?)");
            }
            let mut tenant = vec![0u8; tenant_len];
            f.read_exact(&mut tenant)
                .with_context(|| format!("{path:?}: reading tenant id"))?;
            let tenant = String::from_utf8(tenant)
                .with_context(|| format!("{path:?}: tenant id is not utf8"))?;
            let q = read_u32(&mut f, path, "q")?;
            let n_layers = read_u32(&mut f, path, "n_layers")?;
            Some(AdapterManifest { tenant, q, n_layers })
        }
        other => bail!("{path:?}: unsupported checkpoint version {other}"),
    };
    let count = read_len(&mut f, path, "tensor count")?;
    if count > MAX_TENSORS {
        bail!("{path:?}: tensor count {count} exceeds cap {MAX_TENSORS} \
               (corrupt header?)");
    }
    let mut out = Vec::with_capacity(count);
    for ti in 0..count {
        let name_len = read_len(&mut f, path, "name_len")?;
        if name_len > MAX_NAME_LEN {
            bail!("{path:?}: tensor {ti} name_len {name_len} exceeds cap \
                   {MAX_NAME_LEN} (corrupt header?)");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name).with_context(|| {
            format!("{path:?}: reading tensor {ti} name (truncated file?)")
        })?;
        let name = String::from_utf8(name)
            .with_context(|| format!("{path:?}: tensor {ti} name is not utf8"))?;
        let mut dt = [0u8; 1];
        f.read_exact(&mut dt).with_context(|| {
            format!("{path:?}: reading {name:?} dtype (truncated file?)")
        })?;
        let ndim = read_len(&mut f, path, "ndim")?;
        if ndim > MAX_NDIM {
            bail!("{path:?}: tensor {name:?} ndim {ndim} exceeds cap {MAX_NDIM} \
                   (corrupt header?)");
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut u64buf = [0u8; 8];
        for _ in 0..ndim {
            f.read_exact(&mut u64buf).with_context(|| {
                format!("{path:?}: reading {name:?} dims (truncated file?)")
            })?;
            let d = u64::from_le_bytes(u64buf);
            if d > MAX_NUMEL as u64 {
                bail!("{path:?}: tensor {name:?} dim {d} exceeds cap {MAX_NUMEL}");
            }
            shape.push(usize::try_from(d).with_context(|| {
                format!("{path:?}: tensor {name:?} dim {d} overflows usize")
            })?);
        }
        let numel = shape.iter().try_fold(1usize, |a, &d| a.checked_mul(d))
            .filter(|&n| n <= MAX_NUMEL)
            .with_context(|| format!(
                "{path:?}: tensor {name:?} shape {shape:?} exceeds element cap \
                 {MAX_NUMEL} (corrupt header?)"))?;
        if (numel as u64).saturating_mul(4) > file_len {
            bail!("{path:?}: tensor {name:?} claims {numel} elements but the \
                   whole file is only {file_len} bytes (truncated or corrupt)");
        }
        let tensor = match dt[0] {
            0 => HostTensor::F32 { data: read_f32s(&mut f, numel, path, &name)?,
                                   shape },
            1 => HostTensor::I32 { data: read_i32s(&mut f, numel, path, &name)?,
                                   shape },
            other => bail!("{path:?}: tensor {name:?} has bad dtype byte {other}"),
        };
        out.push((name, tensor));
    }
    if version == VERSION_ADAPTER_CK {
        let computed = f.digest;
        f.active = false; // the trailer is not part of its own digest
        let mut trailer = [0u8; 8];
        f.read_exact(&mut trailer).with_context(|| format!(
            "{path:?}: reading payload checksum trailer (truncated file?)"))?;
        let stored = u64::from_le_bytes(trailer);
        if stored != computed {
            bail!("{path:?}: payload checksum mismatch (stored \
                   {stored:016x}, computed {computed:016x}) — corrupt or \
                   tampered checkpoint");
        }
    }
    // strict container: nothing may follow the last tensor (or the v3
    // trailer). Without this, a corrupted version field could demote a
    // checksummed file to the legacy format and skip verification with
    // the trailer silently ignored.
    let mut probe = [0u8; 1];
    let extra = f.read(&mut probe)
        .with_context(|| format!("{path:?}: probing for trailing bytes"))?;
    if extra != 0 {
        bail!("{path:?}: trailing bytes after the last tensor (corrupt \
               header or truncated rewrite?)");
    }
    Ok((manifest, out))
}

fn read_u32(f: &mut impl Read, path: &Path, what: &str) -> Result<u32> {
    let mut buf = [0u8; 4];
    f.read_exact(&mut buf)
        .with_context(|| format!("{path:?}: reading {what} (truncated file?)"))?;
    Ok(u32::from_le_bytes(buf))
}

/// [`read_u32`] widened to a checked `usize` — length/count fields that
/// size allocations or reads.
fn read_len(f: &mut impl Read, path: &Path, what: &str) -> Result<usize> {
    let v = read_u32(f, path, what)?;
    usize::try_from(v)
        .with_context(|| format!("{path:?}: {what} {v} overflows usize"))
}

/// A `usize` length narrowed to the format's `u32` field, with a typed
/// error instead of a silent wrap.
fn len_u32(n: usize, what: &str) -> Result<u32> {
    u32::try_from(n)
        .with_context(|| format!("{what} of {n} overflows the u32 field"))
}

/// Bulk LE payload reads: one `read_exact` of the whole payload, then an
/// in-memory decode — the counterpart of [`write_f32s`]. A truncated file
/// fails here with the tensor named, before any decode work.
fn read_f32s(f: &mut impl Read, numel: usize, path: &Path, name: &str)
             -> Result<Vec<f32>> {
    let mut buf = vec![0u8; numel * 4];
    f.read_exact(&mut buf).with_context(|| format!(
        "{path:?}: reading {name:?} f32 payload ({numel} elements; \
         truncated file?)"))?;
    Ok(buf.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_i32s(f: &mut impl Read, numel: usize, path: &Path, name: &str)
             -> Result<Vec<i32>> {
    let mut buf = vec![0u8; numel * 4];
    f.read_exact(&mut buf).with_context(|| format!(
        "{path:?}: reading {name:?} i32 payload ({numel} elements; \
         truncated file?)"))?;
    Ok(buf.chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("qp_ckpt_test").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip() {
        let path = tdir("rt").join("t.qpck");
        let tensors = vec![
            ("base.w".to_string(),
             HostTensor::f32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-8, 9.0])),
            ("tokens".to_string(), HostTensor::i32(vec![4], vec![1, -5, 7, 0])),
            ("scalar".to_string(), HostTensor::f32(vec![], vec![42.0])),
        ];
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(tensors.len(), back.len());
        for ((n1, t1), (n2, t2)) in tensors.iter().zip(&back) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
    }

    #[test]
    fn adapter_roundtrip_and_v1_interop() {
        let path = tdir("ad").join("a.qpck");
        let m = AdapterManifest { tenant: "acme-042".into(), q: 5, n_layers: 2 };
        let tensors = vec![
            ("thetas".to_string(), HostTensor::f32(vec![21], vec![0.25; 21])),
        ];
        save_adapter(&path, &m, &tensors).unwrap();
        let (back_m, back_t) = load_adapter(&path).unwrap();
        assert_eq!(back_m, m);
        assert_eq!(back_t, tensors);
        // plain load skips the manifest but returns the same tensors
        assert_eq!(load(&path).unwrap(), tensors);
        // a v1 file has no manifest: load_adapter must refuse, not guess
        let v1 = tdir("ad").join("v1.qpck");
        save(&v1, &tensors).unwrap();
        let e = load_adapter(&v1).unwrap_err().to_string();
        assert!(e.contains("no adapter manifest"), "{e}");
    }

    #[test]
    fn atomic_adapter_save_leaves_no_temp_and_roundtrips() {
        let dir = tdir("atomic");
        let path = dir.join("acme.qpck");
        let m = AdapterManifest { tenant: "acme".into(), q: 3, n_layers: 1 };
        let tensors = vec![
            ("thetas".to_string(), HostTensor::f32(vec![7], vec![0.5; 7])),
        ];
        save_adapter_atomic(&path, &m, &tensors).unwrap();
        let (back_m, back_t) = load_adapter(&path).unwrap();
        assert_eq!(back_m, m);
        assert_eq!(back_t, tensors);
        // the staging dot-file must not linger next to the final file
        let stray: Vec<_> = std::fs::read_dir(&dir).unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with(".tmp."))
            .collect();
        assert!(stray.is_empty(), "{stray:?}");
        // a failed save cleans its temp file up too
        let bad = vec![(
            "n".repeat(MAX_NAME_LEN + 1),
            HostTensor::f32(vec![1], vec![0.0]),
        )];
        assert!(save_adapter_atomic(&path, &m, &bad).is_err());
        let stray: Vec<_> = std::fs::read_dir(&dir).unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with(".tmp."))
            .collect();
        assert!(stray.is_empty(), "{stray:?}");
        // and the previously-saved final file is untouched
        assert!(load_adapter(&path).is_ok());
    }

    #[test]
    fn adapter_checksum_catches_any_single_byte_corruption() {
        let dir = tdir("cksum");
        let path = dir.join("a.qpck");
        let m = AdapterManifest { tenant: "acme".into(), q: 3, n_layers: 1 };
        let tensors = vec![
            ("thetas".to_string(),
             HostTensor::f32(vec![7], vec![0.5, -1.0, 0.25, 2.0, 0.0, 1.5, -0.125])),
        ];
        save_adapter(&path, &m, &tensors).unwrap();
        let clean = std::fs::read(&path).unwrap();
        assert!(load_adapter(&path).is_ok());
        // flip one byte at a time across the whole file — header,
        // manifest, tensor payload, trailer — and every flip must be
        // caught (magic/version by their own checks, everything else by
        // the FNV trailer, whose per-byte xor-multiply step is injective
        // so a same-length substitution always changes the digest)
        let bad_path = dir.join("bad.qpck");
        for pos in 0..clean.len() {
            let mut bad = clean.clone();
            bad[pos] ^= 0x20;
            std::fs::write(&bad_path, &bad).unwrap();
            assert!(load_adapter(&bad_path).is_err(),
                    "byte flip at {pos} loaded successfully");
        }
        // and the pristine bytes still load
        std::fs::write(&bad_path, &clean).unwrap();
        assert!(load_adapter(&bad_path).is_ok());
    }

    #[test]
    fn corrupt_payload_reports_a_checksum_mismatch() {
        let dir = tdir("cksum_msg");
        let path = dir.join("a.qpck");
        let m = AdapterManifest { tenant: "acme".into(), q: 3, n_layers: 1 };
        save_adapter(&path, &m, &[(
            "thetas".to_string(),
            HostTensor::f32(vec![4], vec![0.5; 4]),
        )]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flip a bit inside the theta payload (well past the header,
        // before the 8-byte trailer)
        let pos = bytes.len() - 12;
        bytes[pos] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let e = load_adapter(&path).unwrap_err().to_string();
        assert!(e.contains("payload checksum mismatch"), "{e}");
    }

    #[test]
    fn legacy_v2_adapter_without_trailer_still_loads() {
        // hand-built v2 file: magic | version 2 | tenant "t" | q | L |
        // count 0 — written before the integrity trailer existed
        let dir = tdir("v2_legacy");
        let path = dir.join("legacy.qpck");
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&VERSION_ADAPTER.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b't');
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        let (m, tensors) = load_adapter(&path).unwrap();
        assert_eq!(m, AdapterManifest { tenant: "t".into(), q: 3, n_layers: 1 });
        assert!(tensors.is_empty());
        // everything written today is v3 (checksummed)
        let out = dir.join("fresh.qpck");
        save_adapter(&out, &m, &[]).unwrap();
        let bytes = std::fs::read(&out).unwrap();
        assert_eq!(&bytes[4..8], &3u32.to_le_bytes());
    }

    #[test]
    fn rejects_garbage() {
        let path = tdir("bad").join("bad.qpck");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn truncated_file_errors_with_context() {
        let dir = tdir("trunc");
        let full = dir.join("full.qpck");
        let tensors = vec![
            ("w".to_string(), HostTensor::f32(vec![64], vec![0.5; 64])),
        ];
        save(&full, &tensors).unwrap();
        let bytes = std::fs::read(&full).unwrap();
        // cut at several depths: mid-payload, mid-header, mid-magic
        for cut in [bytes.len() - 1, bytes.len() / 2, 24, 13, 2] {
            let p = dir.join(format!("cut{cut}.qpck"));
            std::fs::write(&p, &bytes[..cut]).unwrap();
            let e = load(&p).unwrap_err().to_string();
            assert!(
                e.contains("truncated") || e.contains("not a QPCK"),
                "cut={cut}: {e}"
            );
        }
    }

    /// A hostile header must fail on its cap check, never reach the
    /// allocation it tried to size.
    #[test]
    fn oversized_header_fields_are_rejected() {
        let dir = tdir("hostile");
        let header = |fields: &[u8]| {
            let mut b = Vec::new();
            b.extend_from_slice(MAGIC);
            b.extend_from_slice(&1u32.to_le_bytes());
            b.extend_from_slice(fields);
            b
        };
        // count = u32::MAX
        let p = dir.join("count.qpck");
        std::fs::write(&p, header(&u32::MAX.to_le_bytes())).unwrap();
        let e = load(&p).unwrap_err().to_string();
        assert!(e.contains("exceeds cap"), "{e}");
        // one tensor with name_len = 1 GiB
        let p = dir.join("name.qpck");
        let mut b = header(&1u32.to_le_bytes());
        b.extend_from_slice(&(1u32 << 30).to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        let e = load(&p).unwrap_err().to_string();
        assert!(e.contains("name_len") && e.contains("exceeds cap"), "{e}");
        // ndim = 1000
        let p = dir.join("ndim.qpck");
        let mut b = header(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes()); // name_len 1
        b.push(b'x');
        b.push(0u8); // dtype f32
        b.extend_from_slice(&1000u32.to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        let e = load(&p).unwrap_err().to_string();
        assert!(e.contains("ndim") && e.contains("exceeds cap"), "{e}");
        // numel overflow: dims whose product wraps usize
        let p = dir.join("numel.qpck");
        let mut b = header(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b'x');
        b.push(0u8);
        b.extend_from_slice(&4u32.to_le_bytes()); // ndim 4
        for _ in 0..4 {
            b.extend_from_slice(&(1u64 << 24).to_le_bytes());
        }
        std::fs::write(&p, &b).unwrap();
        let e = load(&p).unwrap_err().to_string();
        assert!(e.contains("element cap"), "{e}");
        // numel under the cap but far beyond the file's actual size: the
        // ~50-byte file must be rejected before the 1 GiB zeroed buffer
        // it tries to demand is ever allocated
        let p = dir.join("bigclaim.qpck");
        let mut b = header(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b'x');
        b.push(0u8);
        b.extend_from_slice(&1u32.to_le_bytes()); // ndim 1
        b.extend_from_slice(&(1u64 << 28).to_le_bytes()); // dim = MAX_NUMEL
        std::fs::write(&p, &b).unwrap();
        let e = load(&p).unwrap_err().to_string();
        assert!(e.contains("whole file is only"), "{e}");
        // oversized tenant_len in a v2 header
        let p = dir.join("tenant.qpck");
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&(1u32 << 20).to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        let e = load(&p).unwrap_err().to_string();
        assert!(e.contains("tenant_len") && e.contains("exceeds cap"), "{e}");
    }

    #[test]
    fn save_enforces_the_same_caps_as_load() {
        let path = tdir("savecap").join("t.qpck");
        let t = vec![(
            "n".repeat(MAX_NAME_LEN + 1),
            HostTensor::f32(vec![1], vec![0.0]),
        )];
        let e = save(&path, &t).unwrap_err().to_string();
        assert!(e.contains("refusing to save") && e.contains("name"), "{e}");
        assert!(!path.exists(), "cap failure must not leave a file behind");
    }

    #[test]
    fn save_propagates_unwritable_dir() {
        // a parent that exists as a *file* makes create_dir_all fail;
        // the old code swallowed this with .ok() and failed confusingly
        let dir = tdir("unwritable");
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, b"file, not dir").unwrap();
        let path = blocker.join("sub").join("t.qpck");
        let e = save(&path, &[]).unwrap_err().to_string();
        assert!(e.contains("create checkpoint dir"), "{e}");
    }
}
