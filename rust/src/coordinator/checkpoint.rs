//! Checkpoint format (own binary container; no external deps):
//!
//!   magic "QPCK" | u32 version | u32 count
//!   per tensor: u32 name_len | name utf8 | u8 dtype (0=f32, 1=i32)
//!               | u32 ndim | u64 dims... | payload (LE)
//!
//! Stores either a full model (pretraining output) or adapters only
//! (PEFT fine-tuning output — the paper's few-KB artifact story).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;

const MAGIC: &[u8; 4] = b"QPCK";
const VERSION: u32 = 1;

pub fn save(path: &Path, tensors: &[(String, HostTensor)]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        match t {
            HostTensor::F32 { shape, data } => {
                f.write_all(&[0u8])?;
                f.write_all(&(shape.len() as u32).to_le_bytes())?;
                for &d in shape {
                    f.write_all(&(d as u64).to_le_bytes())?;
                }
                for &x in data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            HostTensor::I32 { shape, data } => {
                f.write_all(&[1u8])?;
                f.write_all(&(shape.len() as u32).to_le_bytes())?;
                for &d in shape {
                    f.write_all(&(d as u64).to_le_bytes())?;
                }
                for &x in data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<Vec<(String, HostTensor)>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a QPCK checkpoint");
    }
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != VERSION {
        bail!("{path:?}: unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        f.read_exact(&mut u32buf)?;
        let name_len = u32::from_le_bytes(u32buf) as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut dt = [0u8; 1];
        f.read_exact(&mut dt)?;
        f.read_exact(&mut u32buf)?;
        let ndim = u32::from_le_bytes(u32buf) as usize;
        let mut shape = Vec::with_capacity(ndim);
        let mut u64buf = [0u8; 8];
        for _ in 0..ndim {
            f.read_exact(&mut u64buf)?;
            shape.push(u64::from_le_bytes(u64buf) as usize);
        }
        let numel: usize = shape.iter().product();
        let tensor = match dt[0] {
            0 => {
                let mut data = vec![0f32; numel];
                for x in data.iter_mut() {
                    f.read_exact(&mut u32buf)?;
                    *x = f32::from_le_bytes(u32buf);
                }
                HostTensor::F32 { shape, data }
            }
            1 => {
                let mut data = vec![0i32; numel];
                for x in data.iter_mut() {
                    f.read_exact(&mut u32buf)?;
                    *x = i32::from_le_bytes(u32buf);
                }
                HostTensor::I32 { shape, data }
            }
            other => bail!("bad dtype byte {other}"),
        };
        out.push((name, tensor));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("qp_ckpt_test");
        let path = dir.join("t.qpck");
        let tensors = vec![
            ("base.w".to_string(),
             HostTensor::f32(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-8, 9.0])),
            ("tokens".to_string(), HostTensor::i32(vec![4], vec![1, -5, 7, 0])),
            ("scalar".to_string(), HostTensor::f32(vec![], vec![42.0])),
        ];
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(tensors.len(), back.len());
        for ((n1, t1), (n2, t2)) in tensors.iter().zip(&back) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("qp_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.qpck");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }
}
