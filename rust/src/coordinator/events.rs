//! Structured event/metrics log (JSONL): every training run appends
//! step losses, eval metrics and timing so experiments are auditable and
//! EXPERIMENTS.md numbers can be traced to a log line.

use std::io::Write;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::Result;

use crate::util::json::{obj, Json};

pub struct EventLog {
    file: Option<Mutex<std::fs::File>>,
    pub echo: bool,
}

impl EventLog {
    /// Log to `path` (append), or a null logger when path is None.
    pub fn new(path: Option<PathBuf>, echo: bool) -> Result<EventLog> {
        let file = match path {
            Some(p) => {
                if let Some(parent) = p.parent() {
                    std::fs::create_dir_all(parent).ok();
                }
                Some(Mutex::new(std::fs::OpenOptions::new()
                    .create(true).append(true).open(p)?))
            }
            None => None,
        };
        Ok(EventLog { file, echo })
    }

    pub fn null() -> EventLog {
        EventLog { file: None, echo: false }
    }

    pub fn emit(&self, kind: &str, mut fields: Vec<(&str, Json)>) {
        let ts = SystemTime::now().duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64()).unwrap_or(0.0);
        fields.insert(0, ("ts", Json::Num(ts)));
        fields.insert(0, ("event", Json::Str(kind.to_string())));
        let line = obj(fields).dump();
        if self.echo {
            println!("{line}");
        }
        if let Some(f) = &self.file {
            let mut f = f.lock().unwrap();
            let _ = writeln!(f, "{line}");
        }
    }

    pub fn train_step(&self, tag: &str, task: &str, step: usize, loss: f32) {
        self.emit("train_step", vec![
            ("tag", tag.into()), ("task", task.into()),
            ("step", step.into()), ("loss", Json::Num(loss as f64)),
        ]);
    }

    pub fn eval(&self, tag: &str, task: &str, metric: &str, value: f64,
                step: usize) {
        self.emit("eval", vec![
            ("tag", tag.into()), ("task", task.into()),
            ("metric", metric.into()), ("value", Json::Num(value)),
            ("step", step.into()),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_parseable_jsonl() {
        let path = std::env::temp_dir().join("qp_events_test.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = EventLog::new(Some(path.clone()), false).unwrap();
        log.train_step("enc_lora", "sst2", 3, 0.5);
        log.eval("enc_lora", "sst2", "accuracy", 0.91, 3);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            let j = Json::parse(l).unwrap();
            assert!(j.get("ts").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn null_logger_is_silent() {
        EventLog::null().train_step("x", "y", 0, 1.0);
    }
}
