//! Structured event/metrics log (JSONL): every training run appends
//! step losses, eval metrics and timing so experiments are auditable and
//! EXPERIMENTS.md numbers can be traced to a log line.
//!
//! The log is thread-safe and shareable: the sink is an `Arc<Mutex<File>>`
//! and every event is serialized to a single `write_all` of one complete
//! line, so concurrent sweep workers can emit through the same file with
//! no interleaving (line-atomic JSONL). `for_worker(id)` derives a handle
//! that stamps a `"worker"` field on every line it emits, which is how
//! parallel sweep output stays attributable per worker.

use std::io::Write;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::Result;

use crate::util::json::{obj, Json};

#[derive(Clone)]
pub struct EventLog {
    sink: Option<Arc<Mutex<std::fs::File>>>,
    pub echo: bool,
    /// When set, every emitted line carries a `"worker"` field.
    worker: Option<usize>,
}

impl EventLog {
    /// Log to `path` (append), or a null logger when path is None.
    pub fn new(path: Option<PathBuf>, echo: bool) -> Result<EventLog> {
        let sink = match path {
            Some(p) => {
                if let Some(parent) = p.parent() {
                    std::fs::create_dir_all(parent).ok();
                }
                Some(Arc::new(Mutex::new(std::fs::OpenOptions::new()
                    .create(true).append(true).open(p)?)))
            }
            None => None,
        };
        Ok(EventLog { sink, echo, worker: None })
    }

    pub fn null() -> EventLog {
        EventLog { sink: None, echo: false, worker: None }
    }

    /// A handle onto the same sink that tags every line with `worker`.
    /// Handles are cheap (Arc clone) and safe to use from other threads.
    pub fn for_worker(&self, worker: usize) -> EventLog {
        EventLog { sink: self.sink.clone(), echo: self.echo, worker: Some(worker) }
    }

    pub fn emit(&self, kind: &str, mut fields: Vec<(&str, Json)>) {
        // analyze: allow(determinism) ts is wall-clock by design; fifo diffs ignore it
        let ts = SystemTime::now().duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64()).unwrap_or(0.0);
        fields.insert(0, ("ts", Json::Num(ts)));
        fields.insert(0, ("event", Json::Str(kind.to_string())));
        if let Some(w) = self.worker {
            fields.push(("worker", w.into()));
        }
        let line = obj(fields).dump();
        if self.echo {
            // analyze: allow(log-discipline) echo is the explicit opt-in stdout sink
            println!("{line}");
        }
        if let Some(f) = &self.sink {
            // one write_all per event keeps each JSONL line atomic even
            // under contention from multiple sweep workers
            let mut buf = line.into_bytes();
            buf.push(b'\n');
            let _ = crate::util::sync::lock_or_recover(f).write_all(&buf);
        }
    }

    pub fn train_step(&self, tag: &str, task: &str, step: usize, loss: f32) {
        self.emit("train_step", vec![
            ("tag", tag.into()), ("task", task.into()),
            ("step", step.into()), ("loss", Json::Num(loss as f64)),
        ]);
    }

    pub fn eval(&self, tag: &str, task: &str, metric: &str, value: f64,
                step: usize) {
        self.emit("eval", vec![
            ("tag", tag.into()), ("task", task.into()),
            ("metric", metric.into()), ("value", Json::Num(value)),
            ("step", step.into()),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_parseable_jsonl() {
        let path = std::env::temp_dir().join("qp_events_test.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = EventLog::new(Some(path.clone()), false).unwrap();
        log.train_step("enc_lora", "sst2", 3, 0.5);
        log.eval("enc_lora", "sst2", "accuracy", 0.91, 3);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            let j = Json::parse(l).unwrap();
            assert!(j.get("ts").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn null_logger_is_silent() {
        EventLog::null().train_step("x", "y", 0, 1.0);
    }

    #[test]
    fn worker_handles_tag_lines() {
        let path = std::env::temp_dir().join("qp_events_worker_tag.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = EventLog::new(Some(path.clone()), false).unwrap();
        log.emit("plain", vec![]);
        log.for_worker(3).emit("tagged", vec![("x", 1usize.into())]);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let plain = Json::parse(lines[0]).unwrap();
        assert!(plain.opt("worker").is_none());
        let tagged = Json::parse(lines[1]).unwrap();
        assert_eq!(tagged.get("worker").unwrap().as_usize().unwrap(), 3);
        assert_eq!(tagged.get("x").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn concurrent_emit_is_line_atomic() {
        // N workers x M events through one sink: every line must parse
        // back as complete JSON with intact fields and the right worker id
        let path = std::env::temp_dir().join("qp_events_contention.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = EventLog::new(Some(path.clone()), false).unwrap();
        const WORKERS: usize = 8;
        const EVENTS: usize = 50;
        std::thread::scope(|scope| {
            for w in 0..WORKERS {
                let wlog = log.for_worker(w);
                scope.spawn(move || {
                    for i in 0..EVENTS {
                        wlog.emit("contend", vec![
                            ("i", i.into()),
                            ("payload", format!("w{w}-padding-{}", "x".repeat(64)).into()),
                        ]);
                    }
                });
            }
        });
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), WORKERS * EVENTS, "lost or split lines");
        let mut per_worker = vec![0usize; WORKERS];
        for l in lines {
            let j = Json::parse(l).unwrap_or_else(|e| panic!("bad line {l:?}: {e}"));
            assert_eq!(j.get("event").unwrap().as_str().unwrap(), "contend");
            let w = j.get("worker").unwrap().as_usize().unwrap();
            assert!(w < WORKERS);
            assert!(j.get("i").unwrap().as_usize().unwrap() < EVENTS);
            per_worker[w] += 1;
        }
        assert!(per_worker.iter().all(|&c| c == EVENTS), "{per_worker:?}");
    }
}
