//! The Layer-3 coordinator: training sessions, experiment sweeps,
//! checkpoints and event logging. This is the process that owns the
//! paper's experimental protocol end to end.

pub mod checkpoint;
pub mod events;
pub mod sweep;
pub mod trainer;
