//! Experiment scheduler: plans a grid of (artifact, task, seed) cells,
//! executes them through the task drivers — sequentially or on a
//! work-stealing pool — and aggregates per-cell results into the paper's
//! table rows (mean over seeds, as in §5.1's five-run protocol).
//!
//! Determinism contract: results are always returned in `plan.cells()`
//! order and every cell derives its RNG streams from its own seed, so
//! `aggregate()` output is byte-identical for any `--jobs` value and any
//! completion order. The JSONL event log is NOT part of the contract:
//! across jobs settings the line order differs (workers interleave) and
//! parallel runs additionally stamp a `"worker"` field on each line.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::data::glue;
use crate::obs::metrics::{Class, Counter, MetricsRegistry};
use crate::runtime::{Manifest, Runtime};
use crate::util::pool;

use super::events::EventLog;
use super::trainer::{self, GlueRunSpec, RunResult, TrainConfig};

#[derive(Clone, Debug)]
pub struct SweepPlan {
    pub tags: Vec<String>,
    pub tasks: Vec<glue::Task>,
    pub seeds: Vec<u64>,
    pub cfg: TrainConfig,
    pub backbone: Option<PathBuf>,
    /// per-task learning-rate overrides (the paper sweeps LRs per task)
    pub task_lr: BTreeMap<String, f32>,
}

#[derive(Clone, Debug)]
pub struct Cell {
    pub tag: String,
    pub task: glue::Task,
    pub seed: u64,
}

impl SweepPlan {
    /// Every (tag, task, seed) cell, exactly once.
    pub fn cells(&self) -> Vec<Cell> {
        let mut out = Vec::new();
        for tag in &self.tags {
            for &task in &self.tasks {
                for &seed in &self.seeds {
                    out.push(Cell { tag: tag.clone(), task, seed });
                }
            }
        }
        out
    }

    /// The train config for one cell: the plan config with the cell's
    /// seed and any per-task LR override applied. All cell-level RNG
    /// streams derive from this seed, so cells are isolated by
    /// construction no matter which worker runs them.
    pub fn cell_config(&self, cell: &Cell) -> TrainConfig {
        let mut cfg = self.cfg.clone();
        cfg.seed = cell.seed;
        if let Some(&lr) = self.task_lr.get(cell.task.name()) {
            cfg.lr = lr;
        }
        cfg
    }
}

/// Aggregated result of one (tag, task): mean over seeds.
#[derive(Clone, Debug, PartialEq)]
pub struct AggResult {
    pub tag: String,
    pub task: String,
    pub metric_name: String,
    pub mean_metric: f64,
    pub std_metric: f64,
    pub n_seeds: usize,
    pub adapter_params: usize,
    pub trainable_params: usize,
    pub mean_step_ms: f64,
}

pub fn aggregate(results: &[RunResult]) -> Vec<AggResult> {
    let mut groups: BTreeMap<(String, String), Vec<&RunResult>> = BTreeMap::new();
    for r in results {
        groups.entry((r.tag.clone(), r.task.clone())).or_default().push(r);
    }
    groups.into_iter()
        .map(|((tag, task), rs)| {
            let vals: Vec<f64> = rs.iter().map(|r| r.best_metric).collect();
            let n = vals.len() as f64;
            let mean = vals.iter().sum::<f64>() / n;
            let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
            AggResult {
                tag,
                task,
                metric_name: rs[0].metric_name.clone(),
                mean_metric: mean,
                std_metric: var.sqrt(),
                n_seeds: rs.len(),
                adapter_params: rs[0].adapter_params,
                trainable_params: rs[0].trainable_params,
                mean_step_ms: rs.iter().map(|r| r.step_ms).sum::<f64>() / n,
            }
        })
        .collect()
}

/// Metrics handles for a sweep run: the single registration site for
/// the `sweep_*` metric family plus the worker-pool instrumentation
/// threaded into [`crate::util::pool`]. `sweep_cells_total` is
/// [`Class::Stable`] — the number of executed cells is a pure function
/// of the plan, so it lands in deterministic snapshots byte-identically
/// at any `--jobs` value.
pub struct SweepObs {
    cells_total: Arc<Counter>,
    pool: pool::PoolObs,
}

impl SweepObs {
    pub fn register(reg: &MetricsRegistry, jobs: usize) -> SweepObs {
        SweepObs {
            cells_total: reg.counter("sweep_cells_total", &[], Class::Stable),
            pool: pool::PoolObs::register(reg, "sweep", jobs.max(1)),
        }
    }

    /// Detached handles: instrumented code paths stay unconditional in
    /// sessions that never built a registry.
    pub fn disabled() -> SweepObs {
        SweepObs {
            cells_total: Counter::detached(),
            pool: pool::PoolObs::disabled(),
        }
    }

    pub fn cells(&self) -> u64 {
        self.cells_total.get()
    }
}

/// Generic parallel executor for a sweep plan: every cell runs through
/// `run_cell` on one of `jobs` workers, each worker owning private state
/// from `init(worker_id)` (for real sweeps: its own PJRT runtime). The
/// returned vector is in `plan.cells()` order regardless of jobs or
/// completion order. Cell lifecycle events carry the worker id.
pub fn run_plan_with<S, I, F>(plan: &SweepPlan, jobs: usize, log: &EventLog,
                              init: I, run_cell: F) -> Result<Vec<RunResult>>
where
    I: Fn(usize) -> Result<S> + Sync,
    F: Fn(&mut S, &Cell, TrainConfig, &EventLog) -> Result<RunResult> + Sync,
{
    run_plan_with_obs(plan, jobs, log, init, run_cell, &SweepObs::disabled())
}

/// [`run_plan_with`] with sweep metrics attached: each completed cell
/// bumps `sweep_cells_total` and the pool reports steal/park/panic and
/// per-worker busy-time counters under `pool="sweep"`.
pub fn run_plan_with_obs<S, I, F>(plan: &SweepPlan, jobs: usize,
                                  log: &EventLog, init: I, run_cell: F,
                                  obs: &SweepObs) -> Result<Vec<RunResult>>
where
    I: Fn(usize) -> Result<S> + Sync,
    F: Fn(&mut S, &Cell, TrainConfig, &EventLog) -> Result<RunResult> + Sync,
{
    let cells = plan.cells();
    let total = cells.len();
    let results = pool::run_stateful_obs(jobs, cells, init, |state, ctx, cell| {
        let wlog = log.for_worker(ctx.worker);
        let cfg = plan.cell_config(&cell);
        wlog.emit("cell_start", vec![
            ("i", ctx.index.into()), ("total", total.into()),
            ("tag", cell.tag.as_str().into()),
            ("task", cell.task.name().into()),
            ("seed", (cell.seed as usize).into()),
        ]);
        let r = run_cell(state, &cell, cfg, &wlog)?;
        obs.cells_total.inc();
        wlog.emit("cell_done", vec![
            ("tag", cell.tag.as_str().into()),
            ("task", cell.task.name().into()),
            ("metric", crate::util::json::Json::Num(r.best_metric)),
        ]);
        Ok(r)
    }, &obs.pool);
    pool::collect_ordered(results)
}

/// Generic parallel executor for a *panel*: an ordered list of
/// independent items, one result row each (no (task, seed) grid — the
/// E2E Table-3/4 tag panel and the ViT ablation panels are this shape).
/// Same contract as [`run_plan_with`]: results come back in input order
/// for any `jobs`, each worker owns private state from `init(worker_id)`,
/// and item lifecycle events carry the worker id.
pub fn run_panel_with<T, S, I, F>(items: Vec<T>, jobs: usize, log: &EventLog,
                                  init: I, run_item: F)
                                  -> Result<Vec<RunResult>>
where
    T: Send,
    I: Fn(usize) -> Result<S> + Sync,
    F: Fn(&mut S, &T, &EventLog) -> Result<RunResult> + Sync,
{
    let total = items.len();
    let results = pool::run_stateful(jobs, items, init, |state, ctx, item| {
        let wlog = log.for_worker(ctx.worker);
        wlog.emit("panel_start", vec![
            ("i", ctx.index.into()), ("total", total.into()),
        ]);
        let r = run_item(state, &item, &wlog)?;
        wlog.emit("panel_done", vec![
            ("i", ctx.index.into()),
            ("tag", r.tag.as_str().into()),
            ("metric", crate::util::json::Json::Num(r.best_metric)),
        ]);
        Ok(r)
    });
    pool::collect_ordered(results)
}

/// Execute a GLUE-family sweep sequentially on the caller's runtime (one
/// shared compile cache; every cell exactly once; per-cell RNG streams
/// isolated via the cell seed).
pub fn run_glue_sweep(rt: &Runtime, manifest: &Manifest, plan: &SweepPlan,
                      log: &EventLog) -> Result<Vec<RunResult>> {
    run_glue_sweep_obs(rt, manifest, plan, log, &SweepObs::disabled())
}

/// [`run_glue_sweep`] with sweep metrics attached (sequential path:
/// `sweep_cells_total` advances, pool counters stay at zero).
pub fn run_glue_sweep_obs(rt: &Runtime, manifest: &Manifest,
                          plan: &SweepPlan, log: &EventLog, obs: &SweepObs)
                          -> Result<Vec<RunResult>> {
    let cells = plan.cells();
    let mut results = Vec::with_capacity(cells.len());
    let total = cells.len();
    for (i, cell) in cells.into_iter().enumerate() {
        let cfg = plan.cell_config(&cell);
        log.emit("cell_start", vec![
            ("i", i.into()), ("total", total.into()),
            ("tag", cell.tag.as_str().into()),
            ("task", cell.task.name().into()),
            ("seed", (cell.seed as usize).into()),
        ]);
        let spec = GlueRunSpec {
            tag: &cell.tag,
            task: cell.task,
            cfg,
            backbone: plan.backbone.as_deref(),
            extras_override: BTreeMap::new(),
        };
        let r = trainer::run_glue(rt, manifest, &spec, log)?;
        obs.cells_total.inc();
        log.emit("cell_done", vec![
            ("tag", cell.tag.as_str().into()),
            ("task", cell.task.name().into()),
            ("metric", crate::util::json::Json::Num(r.best_metric)),
        ]);
        results.push(r);
    }
    Ok(results)
}

/// Execute a GLUE-family sweep across `jobs` workers. `jobs <= 1` is the
/// sequential path on `rt`. With `jobs > 1` cells are distributed by work
/// stealing and every worker acquires its runtime via `rt.for_worker`:
/// all workers share `rt`'s compile cache, so on backends that allow
/// client sharing (CPU) each distinct artifact path compiles exactly once
/// for the whole sweep, and otherwise workers fall back to private
/// clients that still share parsed HLO protos and the aggregated compile
/// log. Either way the result vector — and therefore `aggregate()` — is
/// byte-identical for any `jobs`.
pub fn run_glue_sweep_jobs(rt: &Runtime, manifest: &Manifest, plan: &SweepPlan,
                           log: &EventLog, jobs: usize)
                           -> Result<Vec<RunResult>> {
    run_glue_sweep_jobs_obs(rt, manifest, plan, log, jobs,
                            &SweepObs::disabled())
}

/// [`run_glue_sweep_jobs`] with sweep metrics attached: the entry point
/// `repro sweep --metrics-out` drives.
pub fn run_glue_sweep_jobs_obs(rt: &Runtime, manifest: &Manifest,
                               plan: &SweepPlan, log: &EventLog, jobs: usize,
                               obs: &SweepObs) -> Result<Vec<RunResult>> {
    if jobs <= 1 || plan.cells().len() <= 1 {
        return run_glue_sweep_obs(rt, manifest, plan, log, obs);
    }
    run_plan_with_obs(plan, jobs, log,
        |worker| rt.for_worker(worker),
        |wrt, cell, cfg, wlog| {
            let spec = GlueRunSpec {
                tag: &cell.tag,
                task: cell.task,
                cfg,
                backbone: plan.backbone.as_deref(),
                extras_override: BTreeMap::new(),
            };
            trainer::run_glue(wrt.rt(), manifest, &spec, wlog)
        }, obs)
}

/// The GLUE "Avg." column of Tables 2/5: mean of per-task means for one tag.
pub fn glue_average(aggs: &[AggResult], tag: &str) -> Option<f64> {
    let vals: Vec<f64> = aggs.iter()
        .filter(|a| a.tag == tag)
        .map(|a| a.mean_metric)
        .collect();
    if vals.is_empty() {
        None
    } else {
        Some(vals.iter().sum::<f64>() / vals.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;

    #[test]
    fn cells_cover_grid_exactly_once() {
        check_property("sweep covers grid", 15, |rng| {
            let tags: Vec<String> = (0..rng.range(1, 4))
                .map(|i| format!("tag{i}")).collect();
            let tasks = vec![glue::Task::Sst2, glue::Task::Cola];
            let seeds: Vec<u64> = (0..rng.range(1, 4) as u64).collect();
            let plan = SweepPlan {
                tags: tags.clone(), tasks: tasks.clone(), seeds: seeds.clone(),
                cfg: TrainConfig::default(), backbone: None,
                task_lr: BTreeMap::new(),
            };
            let cells = plan.cells();
            assert_eq!(cells.len(), tags.len() * tasks.len() * seeds.len());
            let mut set = std::collections::HashSet::new();
            for c in &cells {
                assert!(set.insert((c.tag.clone(), c.task.name(), c.seed)),
                        "duplicate cell");
            }
        });
    }

    #[test]
    fn aggregate_means_and_stds() {
        let mk = |metric: f64| RunResult {
            tag: "t".into(), task: "sst2".into(), metric_name: "accuracy".into(),
            best_metric: metric, final_metric: metric, losses: vec![],
            adapter_params: 10, trainable_params: 20, wall_seconds: 1.0,
            step_ms: 5.0, extra_metrics: BTreeMap::new(),
        };
        let aggs = aggregate(&[mk(0.8), mk(0.9), mk(1.0)]);
        assert_eq!(aggs.len(), 1);
        assert!((aggs[0].mean_metric - 0.9).abs() < 1e-12);
        assert!(aggs[0].std_metric > 0.0);
        assert_eq!(aggs[0].n_seeds, 3);
    }

    #[test]
    fn aggregate_single_seed_std_is_zero_not_nan() {
        let r = RunResult {
            tag: "t".into(), task: "sst2".into(), metric_name: "accuracy".into(),
            best_metric: 0.75, final_metric: 0.75, losses: vec![],
            adapter_params: 1, trainable_params: 2, wall_seconds: 1.0,
            step_ms: 5.0, extra_metrics: BTreeMap::new(),
        };
        let aggs = aggregate(&[r]);
        assert_eq!(aggs.len(), 1);
        assert_eq!(aggs[0].n_seeds, 1);
        assert_eq!(aggs[0].std_metric, 0.0);
        assert!(!aggs[0].std_metric.is_nan());
        assert_eq!(aggs[0].mean_metric, 0.75);
    }

    #[test]
    fn aggregate_empty_is_empty() {
        assert!(aggregate(&[]).is_empty());
    }

    #[test]
    fn cell_config_applies_seed_and_task_lr() {
        let mut task_lr = BTreeMap::new();
        task_lr.insert("cola".to_string(), 0.5f32);
        let plan = SweepPlan {
            tags: vec!["t".into()],
            tasks: vec![glue::Task::Sst2, glue::Task::Cola],
            seeds: vec![7],
            cfg: TrainConfig::default(),
            backbone: None,
            task_lr,
        };
        let cells = plan.cells();
        let c_sst2 = plan.cell_config(&cells[0]);
        assert_eq!(c_sst2.seed, 7);
        assert_eq!(c_sst2.lr, TrainConfig::default().lr);
        let c_cola = plan.cell_config(&cells[1]);
        assert_eq!(c_cola.lr, 0.5);
    }

    #[test]
    fn glue_average_over_tasks() {
        let mk = |task: &str, m: f64| AggResult {
            tag: "t".into(), task: task.into(), metric_name: "x".into(),
            mean_metric: m, std_metric: 0.0, n_seeds: 1, adapter_params: 0,
            trainable_params: 0, mean_step_ms: 0.0,
        };
        let aggs = vec![mk("sst2", 0.9), mk("cola", 0.5)];
        assert!((glue_average(&aggs, "t").unwrap() - 0.7).abs() < 1e-12);
        assert!(glue_average(&aggs, "missing").is_none());
    }
}
