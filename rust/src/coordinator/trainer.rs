//! Task drivers: the coordinator-side training/eval loops per experiment
//! family (GLUE-substitute classification, E2E generation, ViT transfer,
//! and the pretraining runs that produce frozen backbones).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::{batcher::Batcher, e2e::E2eData, glue, grammar::Grammar,
                  images};
use crate::metrics::{classification as cls, ngram};
use crate::runtime::{tensors, HostTensor, Manifest, Runtime, TrainSession};
use crate::util::rng::Rng;

use super::events::EventLog;

/// Linear warmup + linear decay (the paper's schedule, Tables 12/14).
/// Degenerate configs are clamped instead of panicking: the warmup span
/// never exceeds `total` (so `warmup_frac >= 1` or `total == 0` cannot
/// underflow the decay span) and the decay denominator stays >= 1.
pub fn lr_at(step: usize, total: usize, base: f32, warmup_frac: f32) -> f32 {
    let warmup = ((total as f32 * warmup_frac) as usize).max(1).min(total);
    if step < warmup {
        base * (step + 1) as f32 / warmup as f32
    } else {
        let rest = total.saturating_sub(warmup).max(1) as f32;
        base * (1.0 - (step - warmup) as f32 / rest).max(0.0)
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub warmup_frac: f32,
    pub eval_every: usize,
    pub seed: u64,
    pub train_examples: usize,
    pub test_examples: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr: 1e-2,
            weight_decay: 0.01,
            warmup_frac: 0.1,
            eval_every: 50,
            seed: 0,
            train_examples: 512,
            test_examples: 256,
        }
    }
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub tag: String,
    pub task: String,
    pub metric_name: String,
    pub best_metric: f64,
    pub final_metric: f64,
    pub losses: Vec<f32>,
    pub adapter_params: usize,
    pub trainable_params: usize,
    pub wall_seconds: f64,
    pub step_ms: f64,
    /// extra named metrics (BLEU/NIST/... for generation runs)
    pub extra_metrics: BTreeMap<String, f64>,
}

/// Default values for a config's runtime extras, given the task and the
/// method hyperparameters recorded in the manifest. Overridable per run
/// (Tables 7/8 sweep exactly these).
pub fn default_extras(entry: &crate::runtime::ArtifactEntry, task_kind: f32,
                      overrides: &BTreeMap<String, f32>) -> Vec<f32> {
    entry.extras.iter()
        .map(|name| {
            if let Some(v) = overrides.get(name) {
                return *v;
            }
            match name.as_str() {
                "task_kind" => task_kind,
                "k_prime" => entry.method_kw.get("k").copied().unwrap_or(4.0) as f32,
                "quant_levels" => 0.0, // quantization off
                "quant_mode" => 0.0,   // uniform
                _ => 0.0,
            }
        })
        .collect()
}

// ---------------------------------------------------------------- GLUE ---

pub struct GlueRunSpec<'a> {
    pub tag: &'a str,
    pub task: glue::Task,
    pub cfg: TrainConfig,
    pub backbone: Option<&'a Path>,
    pub extras_override: BTreeMap<String, f32>,
}

/// Fine-tune one (artifact, task) pair and report the task metric.
pub fn run_glue(rt: &Runtime, manifest: &Manifest, spec: &GlueRunSpec,
                log: &EventLog) -> Result<RunResult> {
    let entry = manifest.get(spec.tag)?;
    let g = Grammar::new();
    let seq_len = entry.batch[0].shape[1];
    let bsz = entry.batch_size();
    let train = glue::dataset(&g, spec.task, spec.cfg.seed,
                              spec.cfg.train_examples, seq_len);
    let test = glue::dataset(&g, spec.task, spec.cfg.seed ^ 0xE7A1,
                             spec.cfg.test_examples, seq_len);

    let mut session = TrainSession::new(rt, entry, spec.cfg.seed as i32)?;
    if let Some(ckpt) = spec.backbone {
        let named = super::checkpoint::load(ckpt)
            .with_context(|| format!("loading backbone {ckpt:?}"))?;
        let n = session.load_named(&named)?;
        log.emit("backbone_loaded", vec![("tag", spec.tag.into()),
                                         ("tensors", n.into())]);
    }
    let task_kind = spec.task.task_kind();
    let extras = default_extras(&session.entry, task_kind,
                                &spec.extras_override);

    let mut batcher = Batcher::new(train.len(), bsz, spec.cfg.seed ^ 0xba7c4);
    let mut losses = Vec::with_capacity(spec.cfg.steps);
    let mut best = f64::NEG_INFINITY;
    // analyze: allow(determinism) wall-clock step timing; tables derive from losses
    let t0 = Instant::now();
    for step in 0..spec.cfg.steps {
        let idx = batcher.next_batch();
        let toks: Vec<Vec<u32>> = idx.iter().map(|&i| train[i].tokens.clone())
            .collect();
        let labels: Vec<f32> = idx.iter().map(|&i| train[i].label).collect();
        let batch = [tensors::stack_tokens(&toks),
                     HostTensor::f32(vec![bsz], labels)];
        let lr = lr_at(step, spec.cfg.steps, spec.cfg.lr, spec.cfg.warmup_frac);
        let loss = session.step(&batch, lr, spec.cfg.weight_decay, &extras)?;
        losses.push(loss);
        log.train_step(spec.tag, spec.task.name(), step, loss);
        if (step + 1) % spec.cfg.eval_every == 0 || step + 1 == spec.cfg.steps {
            let m = eval_glue(&session, &test, spec.task, &extras)?;
            log.eval(spec.tag, spec.task.name(), spec.task.metric_name(), m,
                     step + 1);
            best = best.max(m);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let final_metric = eval_glue(&session, &test, spec.task, &extras)?;
    best = best.max(final_metric);
    Ok(RunResult {
        tag: spec.tag.to_string(),
        task: spec.task.name().to_string(),
        metric_name: spec.task.metric_name().to_string(),
        best_metric: best,
        final_metric,
        losses,
        adapter_params: entry.adapter_param_count,
        trainable_params: entry.trainable_param_count,
        wall_seconds: wall,
        step_ms: wall * 1000.0 / spec.cfg.steps.max(1) as f64,
        extra_metrics: BTreeMap::new(),
    })
}

pub fn eval_glue(session: &TrainSession, test: &[glue::Example],
                 task: glue::Task, extras: &[f32]) -> Result<f64> {
    let bsz = session.entry.batch_size();
    let mut preds_cls: Vec<u32> = Vec::new();
    let mut preds_reg: Vec<f64> = Vec::new();
    for batch_idx in Batcher::eval_batches(test.len(), bsz) {
        let toks: Vec<Vec<u32>> = batch_idx.iter()
            .map(|&i| test[i].tokens.clone()).collect();
        let logits = session.eval(&tensors::stack_tokens(&toks), extras)?;
        let data = logits.as_f32()?;
        let n_out = logits.shape()[1];
        for row in 0..batch_idx.len() {
            let r = &data[row * n_out..(row + 1) * n_out];
            if task == glue::Task::Stsb {
                preds_reg.push(r[0] as f64);
            } else {
                let p = if r[1] > r[0] { 1u32 } else { 0u32 };
                preds_cls.push(p);
            }
        }
    }
    // trim wrap-padding
    if task == glue::Task::Stsb {
        preds_reg.truncate(test.len());
        let gold: Vec<f64> = test.iter().map(|e| e.label as f64).collect();
        Ok(cls::stsb_corr(&preds_reg, &gold))
    } else {
        preds_cls.truncate(test.len());
        let gold: Vec<u32> = test.iter().map(|e| e.label as u32).collect();
        Ok(match task {
            glue::Task::Cola => cls::matthews(&preds_cls, &gold),
            _ => cls::accuracy(&preds_cls, &gold),
        })
    }
}

// ------------------------------------------------------------ pretrain ---

/// Pretrain the encoder backbone with the denoising objective and save a
/// full checkpoint. Returns the final loss curve.
pub fn pretrain_encoder(rt: &Runtime, manifest: &Manifest, tag: &str,
                        steps: usize, lr: f32, seed: u64, out: &Path,
                        log: &EventLog) -> Result<Vec<f32>> {
    let entry = manifest.get(tag)?;
    let g = Grammar::new();
    let seq_len = entry.batch[0].shape[1];
    let bsz = entry.batch_size();
    let mut session = TrainSession::new(rt, entry, seed as i32)?;
    let mut rng = Rng::new(seed ^ 0xdae);
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let mut corr = Vec::with_capacity(bsz);
        let mut clean = Vec::with_capacity(bsz);
        for _ in 0..bsz {
            let (c, cl) = glue::dae_pair(&g, &mut rng, seq_len);
            corr.push(c);
            clean.push(cl);
        }
        let batch = [tensors::stack_tokens(&corr), tensors::stack_tokens(&clean)];
        let lr_t = lr_at(step, steps, lr, 0.1);
        let loss = session.step(&batch, lr_t, 0.01, &[])?;
        losses.push(loss);
        if step % 25 == 0 {
            log.train_step(tag, "pretrain", step, loss);
        }
    }
    super::checkpoint::save(out, &session.export_named()?)?;
    log.emit("checkpoint_saved", vec![("path", format!("{out:?}").into())]);
    Ok(losses)
}

/// Pretrain the decoder LM on domain text (reference realizations without
/// MR prefixes — the "generic corpus" for the E2E family).
pub fn pretrain_decoder(rt: &Runtime, manifest: &Manifest, tag: &str,
                        steps: usize, lr: f32, seed: u64, out: &Path,
                        log: &EventLog) -> Result<Vec<f32>> {
    let entry = manifest.get(tag)?;
    let data = E2eData::new();
    let seq_len = entry.batch[0].shape[1];
    let bsz = entry.batch_size();
    let mut session = TrainSession::new(rt, entry, seed as i32)?;
    let mut rng = Rng::new(seed ^ 0x1a);
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let mut toks = Vec::with_capacity(bsz);
        let mut masks = Vec::with_capacity(bsz);
        for _ in 0..bsz {
            let mr = data.sample_mr(&mut rng);
            let refs = data.references(&mr);
            let text = refs[rng.below(refs.len())].clone();
            let mut t = vec![crate::data::tokenizer::CLS];
            t.extend(&text);
            t.push(crate::data::tokenizer::EOS);
            let end = t.len();
            let t = crate::data::tokenizer::pad_to(t, seq_len);
            let mut m = vec![0.0f32; seq_len];
            for mm in m.iter_mut().take(end.min(seq_len)).skip(1) {
                *mm = 1.0;
            }
            toks.push(t);
            masks.push(m);
        }
        let batch = [tensors::stack_tokens(&toks),
                     tensors::stack_f32(&masks, &[seq_len])];
        let loss = session.step(&batch, lr_at(step, steps, lr, 0.1), 0.01, &[])?;
        losses.push(loss);
        if step % 25 == 0 {
            log.train_step(tag, "pretrain", step, loss);
        }
    }
    super::checkpoint::save(out, &session.export_named()?)?;
    Ok(losses)
}

/// Pretrain the ViT on the 20-class synthetic pretask.
pub fn pretrain_vit(rt: &Runtime, manifest: &Manifest, tag: &str,
                    steps: usize, lr: f32, seed: u64, out: &Path,
                    log: &EventLog) -> Result<Vec<f32>> {
    let entry = manifest.get(tag)?;
    let bsz = entry.batch_size();
    let ds = images::dataset(seed, 2048, false, 0.05);
    let mut session = TrainSession::new(rt, entry, seed as i32)?;
    let mut batcher = Batcher::new(ds.len(), bsz, seed ^ 0x717);
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let idx = batcher.next_batch();
        let pix: Vec<Vec<f32>> = idx.iter().map(|&i| ds[i].pixels.clone()).collect();
        let labels: Vec<i32> = idx.iter().map(|&i| ds[i].label as i32).collect();
        let batch = [
            tensors::stack_f32(&pix, &[images::IMG, images::IMG, images::CH]),
            HostTensor::i32(vec![bsz], labels),
        ];
        let loss = session.step(&batch, lr_at(step, steps, lr, 0.1), 0.01, &[])?;
        losses.push(loss);
        if step % 25 == 0 {
            log.train_step(tag, "pretrain", step, loss);
        }
    }
    super::checkpoint::save(out, &session.export_named()?)?;
    Ok(losses)
}

// ----------------------------------------------------------------- ViT ---

pub struct VitRunSpec<'a> {
    pub tag: &'a str,
    pub cfg: TrainConfig,
    pub backbone: Option<&'a Path>,
    /// quantize the frozen backbone to this many bits (Table 6: 3)
    pub base_bits: Option<u32>,
    pub extras_override: BTreeMap<String, f32>,
}

pub fn run_vit(rt: &Runtime, manifest: &Manifest, spec: &VitRunSpec,
               log: &EventLog) -> Result<RunResult> {
    let entry = manifest.get(spec.tag)?;
    let bsz = entry.batch_size();
    let train = images::dataset(spec.cfg.seed ^ 0x77, spec.cfg.train_examples,
                                true, 0.05);
    let test = images::dataset(spec.cfg.seed ^ 0x7e57, spec.cfg.test_examples,
                               true, 0.05);
    let mut session = TrainSession::new(rt, entry, spec.cfg.seed as i32)?;
    if let Some(ckpt) = spec.backbone {
        let named = super::checkpoint::load(ckpt)?;
        session.load_named(&named)?;
    }
    if let Some(bits) = spec.base_bits {
        session.map_frozen(|_, data| {
            crate::peft::quantization::quantize_inplace(data, bits, 128);
        })?;
    }
    let extras = default_extras(&session.entry, 0.0, &spec.extras_override);
    let mut batcher = Batcher::new(train.len(), bsz, spec.cfg.seed ^ 0xb);
    let mut losses = Vec::new();
    let mut best = f64::NEG_INFINITY;
    // analyze: allow(determinism) wall-clock step timing; tables derive from losses
    let t0 = Instant::now();
    for step in 0..spec.cfg.steps {
        let idx = batcher.next_batch();
        let pix: Vec<Vec<f32>> = idx.iter().map(|&i| train[i].pixels.clone()).collect();
        let labels: Vec<i32> = idx.iter().map(|&i| train[i].label as i32).collect();
        let batch = [
            tensors::stack_f32(&pix, &[images::IMG, images::IMG, images::CH]),
            HostTensor::i32(vec![bsz], labels),
        ];
        let lr = lr_at(step, spec.cfg.steps, spec.cfg.lr, spec.cfg.warmup_frac);
        let loss = session.step(&batch, lr, spec.cfg.weight_decay, &extras)?;
        losses.push(loss);
        log.train_step(spec.tag, "vit", step, loss);
        if (step + 1) % spec.cfg.eval_every == 0 || step + 1 == spec.cfg.steps {
            let acc = eval_vit(&session, &test, &extras)?;
            log.eval(spec.tag, "vit", "accuracy", acc, step + 1);
            best = best.max(acc);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let final_metric = eval_vit(&session, &test, &extras)?;
    best = best.max(final_metric);
    Ok(RunResult {
        tag: spec.tag.to_string(),
        task: "vit".into(),
        metric_name: "accuracy".into(),
        best_metric: best,
        final_metric,
        losses,
        adapter_params: entry.adapter_param_count,
        trainable_params: entry.trainable_param_count,
        wall_seconds: wall,
        step_ms: wall * 1000.0 / spec.cfg.steps.max(1) as f64,
        extra_metrics: BTreeMap::new(),
    })
}

pub fn eval_vit(session: &TrainSession, test: &[images::LabeledImage],
                extras: &[f32]) -> Result<f64> {
    let bsz = session.entry.batch_size();
    let mut preds: Vec<u32> = Vec::new();
    for batch_idx in Batcher::eval_batches(test.len(), bsz) {
        let pix: Vec<Vec<f32>> = batch_idx.iter()
            .map(|&i| test[i].pixels.clone()).collect();
        let logits = session.eval(
            &tensors::stack_f32(&pix, &[images::IMG, images::IMG, images::CH]),
            extras)?;
        let data = logits.as_f32()?;
        let n_out = logits.shape()[1];
        for row in 0..batch_idx.len() {
            let r = &data[row * n_out..(row + 1) * n_out];
            let arg = r.iter().enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
            preds.push(arg as u32);
        }
    }
    preds.truncate(test.len());
    let gold: Vec<u32> = test.iter().map(|e| e.label).collect();
    Ok(cls::accuracy(&preds, &gold))
}

// ----------------------------------------------------------------- E2E ---

pub struct E2eRunSpec<'a> {
    pub tag: &'a str,
    pub cfg: TrainConfig,
    pub backbone: Option<&'a Path>,
    pub gen_cases: usize,
}

pub fn run_e2e(rt: &Runtime, manifest: &Manifest, spec: &E2eRunSpec,
               log: &EventLog) -> Result<RunResult> {
    let entry = manifest.get(spec.tag)?;
    let data = E2eData::new();
    let seq_len = entry.batch[0].shape[1];
    let bsz = entry.batch_size();
    let mut session = TrainSession::new(rt, entry, spec.cfg.seed as i32)?;
    if let Some(ckpt) = spec.backbone {
        let named = super::checkpoint::load(ckpt)?;
        session.load_named(&named)?;
    }
    let extras = default_extras(&session.entry, 0.0, &BTreeMap::new());
    let mut rng = Rng::new(spec.cfg.seed ^ 0xe2e);
    let mut losses = Vec::new();
    // analyze: allow(determinism) wall-clock step timing; tables derive from losses
    let t0 = Instant::now();
    for step in 0..spec.cfg.steps {
        let mut toks = Vec::with_capacity(bsz);
        let mut masks = Vec::with_capacity(bsz);
        for _ in 0..bsz {
            let (t, m, _) = data.training_example(&mut rng, seq_len);
            toks.push(t);
            masks.push(m);
        }
        let batch = [tensors::stack_tokens(&toks),
                     tensors::stack_f32(&masks, &[seq_len])];
        let lr = lr_at(step, spec.cfg.steps, spec.cfg.lr, spec.cfg.warmup_frac);
        let loss = session.step(&batch, lr, spec.cfg.weight_decay, &extras)?;
        losses.push(loss);
        log.train_step(spec.tag, "e2e", step, loss);
    }
    let wall = t0.elapsed().as_secs_f64();

    // --- generation + n-gram metrics ---
    let mut gen_rng = Rng::new(spec.cfg.seed ^ 0x9e4);
    let mut cases: Vec<(Vec<u32>, Vec<Vec<u32>>)> = Vec::new();
    let mut batch_mrs = Vec::new();
    for _ in 0..spec.gen_cases {
        batch_mrs.push(data.sample_mr(&mut gen_rng));
    }
    for chunk in batch_mrs.chunks(bsz) {
        let hyps = greedy_generate(&session, &data, chunk, seq_len, &extras)?;
        for (mr, hyp) in chunk.iter().zip(hyps) {
            cases.push((hyp, data.references(mr)));
        }
    }
    let mut extra_metrics: BTreeMap<String, f64> = BTreeMap::new();
    extra_metrics.insert("bleu".to_string(), ngram::bleu(&cases, 4));
    extra_metrics.insert("nist".to_string(), ngram::nist(&cases, 5));
    extra_metrics.insert("meteor".to_string(), ngram::meteor(&cases));
    extra_metrics.insert("rouge_l".to_string(), ngram::rouge_l(&cases));
    extra_metrics.insert("cider".to_string(), ngram::cider(&cases));
    for (k, v) in &extra_metrics {
        log.eval(spec.tag, "e2e", k, *v, spec.cfg.steps);
    }
    let bleu = extra_metrics["bleu"];
    Ok(RunResult {
        tag: spec.tag.to_string(),
        task: "e2e".into(),
        metric_name: "bleu".into(),
        best_metric: bleu,
        final_metric: bleu,
        losses,
        adapter_params: entry.adapter_param_count,
        trainable_params: entry.trainable_param_count,
        wall_seconds: wall,
        step_ms: wall * 1000.0 / spec.cfg.steps.max(1) as f64,
        extra_metrics,
    })
}

/// Greedy decoding for a batch of MRs using the eval (logits) artifact.
/// Feeds the growing sequence each step (O(T^2), T <= 48 — fine on CPU).
pub fn greedy_generate(session: &TrainSession, data: &E2eData,
                       mrs: &[crate::data::e2e::Mr], seq_len: usize,
                       extras: &[f32]) -> Result<Vec<Vec<u32>>> {
    let bsz = session.entry.batch_size();
    let mut rows: Vec<Vec<u32>> = mrs.iter()
        .map(|mr| crate::data::tokenizer::pad_to(data.prompt(mr), seq_len))
        .collect();
    let prompt_len = data.prompt(&mrs[0]).len();
    while rows.len() < bsz {
        rows.push(rows[0].clone()); // pad batch with copies
    }
    let mut done = vec![false; rows.len()];
    for t in prompt_len..seq_len {
        if done.iter().all(|&d| d) {
            break;
        }
        let logits = session.eval(&tensors::stack_tokens(&rows), extras)?;
        let d = logits.as_f32()?;
        let vocab = logits.shape()[2];
        for (b, row) in rows.iter_mut().enumerate() {
            if done[b] {
                continue;
            }
            let base = (b * seq_len + (t - 1)) * vocab;
            let next = d[base..base + vocab].iter().enumerate()
                .max_by(|a, c| a.1.partial_cmp(c.1).unwrap()).unwrap().0 as u32;
            row[t] = next;
            if next == crate::data::tokenizer::EOS {
                done[b] = true;
            }
        }
    }
    Ok(rows.into_iter().take(mrs.len())
        .map(|row| {
            let gen: Vec<u32> = row[prompt_len..].iter()
                .take_while(|&&t| t != crate::data::tokenizer::EOS
                            && t != crate::data::tokenizer::PAD)
                .copied().collect();
            gen
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let total = 100;
        let base = 0.01;
        assert!(lr_at(0, total, base, 0.1) < base * 0.2);
        assert!((lr_at(9, total, base, 0.1) - base).abs() < 1e-6);
        assert!(lr_at(50, total, base, 0.1) < base);
        assert!(lr_at(99, total, base, 0.1) < lr_at(50, total, base, 0.1));
        assert!(lr_at(99, total, base, 0.1) >= 0.0);
    }

    #[test]
    fn lr_schedule_degenerate_configs_do_not_underflow() {
        // warmup_frac = 1.0: every step is warmup; the decay span used to
        // compute `total - warmup` and wrap/panic
        for step in 0..10 {
            let lr = lr_at(step, 10, 0.01, 1.0);
            assert!(lr.is_finite() && lr >= 0.0 && lr <= 0.01 + 1e-9,
                    "step {step}: {lr}");
        }
        // warmup_frac > 1 used to make warmup > total
        let lr = lr_at(5, 10, 0.01, 2.5);
        assert!(lr.is_finite() && (0.0..=0.01).contains(&lr));
        // total == 0: nothing to schedule, but no step may panic
        assert!(lr_at(0, 0, 0.01, 0.1).is_finite());
        assert!(lr_at(3, 0, 0.01, 0.1) >= 0.0);
        // past-the-end steps decay to zero, never negative
        assert_eq!(lr_at(1000, 10, 0.01, 0.1), 0.0);
    }

    #[test]
    fn default_extras_mapping() {
        use crate::runtime::manifest::*;
        let entry = ArtifactEntry {
            tag: "t".into(), model: "vit".into(), method: "qpeft_taylor".into(),
            task: "img".into(),
            init_file: "x".into(), train_file: "x".into(), eval_file: "x".into(),
            frozen: vec![], trainable: vec![],
            extras: vec!["task_kind".into(), "k_prime".into(),
                         "quant_levels".into(), "quant_mode".into()],
            batch: vec![], trainable_param_count: 0, adapter_param_count: 0,
            total_param_count: 0, cfg: Default::default(),
            method_kw: [("k".to_string(), 8.0)].into_iter().collect(),
        };
        let e = default_extras(&entry, 1.0, &Default::default());
        assert_eq!(e, vec![1.0, 8.0, 0.0, 0.0]);
        let ov: std::collections::BTreeMap<String, f32> =
            [("k_prime".to_string(), 2.0)].into_iter().collect();
        let e = default_extras(&entry, 0.0, &ov);
        assert_eq!(e, vec![0.0, 2.0, 0.0, 0.0]);
    }
}
