//! Epoch batching with shuffling — every sample visited exactly once per
//! epoch (proptest invariant), fixed batch size with wrap-around fill so
//! batch shapes always match the AOT graphs.

use crate::util::rng::Rng;

pub struct Batcher {
    n: usize,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Batcher {
        assert!(n > 0 && batch > 0);
        let mut b = Batcher { n, batch, order: (0..n).collect(), cursor: 0,
                              rng: Rng::new(seed) };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.n.div_ceil(self.batch)
    }

    /// Next batch of sample indices; reshuffles at epoch end. The last
    /// batch of an epoch wraps with samples from the new epoch's head so
    /// the batch shape stays constant.
    pub fn next_batch(&mut self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.batch);
        while out.len() < self.batch {
            if self.cursor == self.n {
                self.reshuffle();
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// Sequential batches over the full set (evaluation; no shuffle), last
    /// batch padded by repeating the final index.
    pub fn eval_batches(n: usize, batch: usize) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let mut b: Vec<usize> = (i..(i + batch).min(n)).collect();
            while b.len() < batch {
                b.push(n - 1);
            }
            out.push(b);
            i += batch;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;
    use std::collections::HashSet;

    #[test]
    fn epoch_covers_all_once_property() {
        check_property("batcher covers epoch", 20, |rng| {
            let n = rng.range(5, 200);
            let bs = rng.range(1, 17);
            let mut b = Batcher::new(n, bs, 42);
            let mut seen: Vec<usize> = Vec::new();
            // consume exactly one epoch's worth of *positions*
            while seen.len() + bs <= n {
                seen.extend(b.next_batch());
            }
            let set: HashSet<usize> = seen.iter().copied().collect();
            assert_eq!(set.len(), seen.len(), "duplicate before epoch end");
        });
    }

    #[test]
    fn batch_shape_constant() {
        let mut b = Batcher::new(10, 4, 1);
        for _ in 0..20 {
            assert_eq!(b.next_batch().len(), 4);
        }
    }

    #[test]
    fn eval_batches_cover_everything() {
        let bs = Batcher::eval_batches(11, 4);
        assert_eq!(bs.len(), 3);
        let all: HashSet<usize> = bs.iter().flatten().copied().collect();
        assert_eq!(all, (0..11).collect());
        assert!(bs.iter().all(|b| b.len() == 4));
    }
}
