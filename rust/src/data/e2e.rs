//! Synthetic E2E-NLG substitute (Table 3/4 — DESIGN.md §2).
//!
//! Meaning representations over restaurant-domain slots are rendered
//! through templated realizations with multiple references per MR, mirroring
//! the structure of Novikova et al.'s E2E dataset: the model must learn
//! slot->surface mappings and template grammar. Token stream layout:
//!
//!   [CLS] <mr tokens> [SEP] <text tokens> [EOS] <pad...>
//!
//! with loss_mask = 1 exactly on the text segment (the lm_loss contract in
//! python/compile/models/decoder.py).

use super::tokenizer::{pad_to, Vocab, CLS, EOS, SEP};
use crate::util::rng::Rng;

pub const NAMES: &[&str] = &["alimentum", "aromi", "bibimbap", "clowns",
                             "cocum", "cotto", "fitzbillies", "giraffe",
                             "strada", "travellers"];
pub const FOODS: &[&str] = &["chinese", "english", "french", "indian",
                             "italian", "japanese", "fast", "pub"];
pub const PRICES: &[&str] = &["cheap", "moderate", "high"];
pub const AREAS: &[&str] = &["riverside", "city"];
pub const RATINGS: &[&str] = &["low", "average", "excellent"];
pub const EXTRA_WORDS: &[&str] = &[
    "name", "food", "price", "area", "rating", "serves", "is", "a", "it",
    "has", "restaurant", "in", "the", "an", "with", "prices", "located",
    "near", "centre", "offering", "cuisine", "place", "rated", "customers",
    "by", "quality", "range", "priced",
];

#[derive(Clone, Debug, PartialEq)]
pub struct Mr {
    pub name: usize,
    pub food: usize,
    pub price: usize,
    pub area: usize,
    pub rating: usize,
}

pub struct E2eData {
    pub vocab: Vocab,
}

impl Default for E2eData {
    fn default() -> Self {
        Self::new()
    }
}

impl E2eData {
    pub fn new() -> E2eData {
        let mut words: Vec<&str> = Vec::new();
        for set in [NAMES, FOODS, PRICES, AREAS, RATINGS, EXTRA_WORDS] {
            for w in set {
                if !words.contains(w) {
                    words.push(w);
                }
            }
        }
        E2eData { vocab: Vocab::new(&words) }
    }

    pub fn sample_mr(&self, rng: &mut Rng) -> Mr {
        Mr {
            name: rng.below(NAMES.len()),
            food: rng.below(FOODS.len()),
            price: rng.below(PRICES.len()),
            area: rng.below(AREAS.len()),
            rating: rng.below(RATINGS.len()),
        }
    }

    /// Slot-value prefix tokens: "name <v> food <v> price <v> area <v>
    /// rating <v>".
    pub fn mr_tokens(&self, mr: &Mr) -> Vec<u32> {
        let v = &self.vocab;
        vec![
            v.id("name"), v.id(NAMES[mr.name]),
            v.id("food"), v.id(FOODS[mr.food]),
            v.id("price"), v.id(PRICES[mr.price]),
            v.id("area"), v.id(AREAS[mr.area]),
            v.id("rating"), v.id(RATINGS[mr.rating]),
        ]
    }

    /// All reference realizations of an MR (template bank). The paper's
    /// E2E has ~arbitrary human references; we use 3 templates.
    pub fn references(&self, mr: &Mr) -> Vec<Vec<u32>> {
        let v = &self.vocab;
        let name = NAMES[mr.name];
        let food = FOODS[mr.food];
        let price = PRICES[mr.price];
        let area = AREAS[mr.area];
        let rating = RATINGS[mr.rating];
        let t1: Vec<&str> = vec![
            name, "is", "a", price, food, "restaurant", "in", "the", area,
            "with", "an", rating, "rating",
        ];
        let t2: Vec<&str> = vec![
            "the", food, "place", name, "in", "the", area, "has", rating,
            "quality", "and", price, "prices",
        ];
        let t3: Vec<&str> = vec![
            name, "serves", price, food, "cuisine", "near", "the", area,
            "centre", "rated", rating, "by", "customers",
        ];
        // "and" may be absent from vocab; add safe fallback
        [t1, t2, t3]
            .into_iter()
            .map(|t| t.iter()
                 .filter(|w| **w != "and" || v.id("and") != super::tokenizer::UNK)
                 .map(|w| v.id(w)).collect())
            .collect()
    }

    /// One training example: (tokens, loss_mask) at fixed seq_len, using a
    /// randomly chosen reference as the target text.
    pub fn training_example(&self, rng: &mut Rng, seq_len: usize)
                            -> (Vec<u32>, Vec<f32>, Mr) {
        let mr = self.sample_mr(rng);
        let refs = self.references(&mr);
        let text = refs[rng.below(refs.len())].clone();
        let mut toks = vec![CLS];
        toks.extend(self.mr_tokens(&mr));
        toks.push(SEP);
        let text_start = toks.len();
        toks.extend(&text);
        toks.push(EOS);
        let text_end = toks.len();
        let toks = pad_to(toks, seq_len);
        let mut mask = vec![0.0f32; seq_len];
        for m in mask.iter_mut().take(text_end.min(seq_len)).skip(text_start) {
            *m = 1.0;
        }
        (toks, mask, mr)
    }

    /// Decode prompt for generation: [CLS] mr [SEP].
    pub fn prompt(&self, mr: &Mr) -> Vec<u32> {
        let mut toks = vec![CLS];
        toks.extend(self.mr_tokens(mr));
        toks.push(SEP);
        toks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;

    #[test]
    fn vocab_fits() {
        let d = E2eData::new();
        assert!(d.vocab.len() <= 256);
    }

    #[test]
    fn references_mention_all_slots() {
        let d = E2eData::new();
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let mr = d.sample_mr(&mut rng);
            for r in d.references(&mr) {
                assert!(r.contains(&d.vocab.id(NAMES[mr.name])));
                assert!(r.contains(&d.vocab.id(FOODS[mr.food])));
                assert!(r.contains(&d.vocab.id(RATINGS[mr.rating])));
            }
        }
    }

    #[test]
    fn loss_mask_covers_exactly_text() {
        check_property("e2e mask aligns", 20, |rng| {
            let d = E2eData::new();
            let (toks, mask, _) = d.training_example(rng, 48);
            assert_eq!(toks.len(), 48);
            let sep = toks.iter().position(|&t| t == SEP).unwrap();
            // mask zero on MR prefix including SEP
            assert!(mask[..=sep].iter().all(|&m| m == 0.0));
            // mask one right after SEP
            assert_eq!(mask[sep + 1], 1.0);
        });
    }

    #[test]
    fn prompt_is_mr_prefix() {
        let d = E2eData::new();
        let mut rng = Rng::new(2);
        let (toks, _, mr) = d.training_example(&mut rng, 48);
        let p = d.prompt(&mr);
        assert_eq!(&toks[..p.len()], &p[..]);
    }
}
