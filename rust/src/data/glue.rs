//! Synthetic GLUE suite (Tables 2 & 5 substitute — DESIGN.md §2).
//!
//! Five tasks with the same *shape* as the paper's GLUE subset:
//!   sst2   sentence -> binary sentiment            (accuracy)
//!   cola   sentence -> grammatical?                (Matthews corr)
//!   rte    premise/hypothesis -> entailment?       (accuracy)
//!   mrpc   pair -> paraphrase?                     (accuracy)
//!   stsb   pair -> similarity in [0, 5]            (Pearson/Spearman avg)
//!
//! Each example is (tokens[T], label f32); pair tasks use the
//! [CLS] a [SEP] b [EOS] encoding. Labels are latent *rules* of the
//! grammar, not surface artifacts, so a frozen pretrained backbone helps
//! and adapter capacity matters — the regime Table 2 probes.

use super::grammar::{Grammar, NOUNS, VERBS};
use super::tokenizer::{encode_pair, pad_to, CLS, EOS};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    Sst2,
    Cola,
    Rte,
    Mrpc,
    Stsb,
}

pub const ALL_TASKS: [Task; 5] = [Task::Sst2, Task::Cola, Task::Rte,
                                  Task::Mrpc, Task::Stsb];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Sst2 => "sst2",
            Task::Cola => "cola",
            Task::Rte => "rte",
            Task::Mrpc => "mrpc",
            Task::Stsb => "stsb",
        }
    }

    pub fn from_name(s: &str) -> Option<Task> {
        ALL_TASKS.iter().copied().find(|t| t.name() == s)
    }

    /// 1.0 for the regression task (selects MSE in the AOT graph).
    pub fn task_kind(&self) -> f32 {
        if *self == Task::Stsb { 1.0 } else { 0.0 }
    }

    pub fn metric_name(&self) -> &'static str {
        match self {
            Task::Cola => "matthews",
            Task::Stsb => "pearson+spearman/2",
            _ => "accuracy",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<u32>,
    pub label: f32,
}

/// Generate one example of `task` at sequence length `seq_len`.
pub fn example(g: &Grammar, task: Task, rng: &mut Rng, seq_len: usize) -> Example {
    match task {
        Task::Sst2 => {
            let label = rng.chance(0.5);
            let s = g.sentence(rng, if label { 1 } else { -1 });
            let mut toks = vec![CLS];
            toks.extend(g.encode(&s));
            toks.push(EOS);
            Example { tokens: pad_to(toks, seq_len), label: label as u32 as f32 }
        }
        Task::Cola => {
            let s = g.sentence(rng, 0);
            let label = rng.chance(0.5);
            let words = if label {
                s.words.clone()
            } else {
                g.corrupt_grammar(rng, &s)
            };
            let mut toks = vec![CLS];
            toks.extend(words.iter().map(|w| g.vocab.id(w)));
            toks.push(EOS);
            Example { tokens: pad_to(toks, seq_len), label: label as u32 as f32 }
        }
        Task::Rte => {
            // premise: full sentence; hypothesis: "DET subject verb DET
            // object" — entailed iff roles match the premise.
            let p = g.sentence(rng, 0);
            let label = rng.chance(0.5);
            let (subj, verb, obj) = if label {
                (p.subject.clone(), p.verb.clone(), p.object.clone())
            } else {
                // break one role
                match rng.below(3) {
                    0 => (NOUNS[rng.below(NOUNS.len())].to_string(),
                          p.verb.clone(), p.object.clone()),
                    1 => (p.subject.clone(),
                          VERBS[rng.below(VERBS.len())].to_string(),
                          p.object.clone()),
                    _ => (p.subject.clone(), p.verb.clone(),
                          NOUNS[rng.below(NOUNS.len())].to_string()),
                }
            };
            let hyp = [
                "the".to_string(), subj, verb, "the".to_string(), obj,
            ];
            let pa = g.encode(&p);
            let hb: Vec<u32> = hyp.iter().map(|w| g.vocab.id(w)).collect();
            Example { tokens: encode_pair(&pa, &hb, seq_len),
                      label: label as u32 as f32 }
        }
        Task::Mrpc => {
            let a = g.sentence(rng, 0);
            let label = rng.chance(0.5);
            let b_words = if label {
                g.paraphrase(rng, &a)
            } else {
                g.sentence(rng, 0).words
            };
            let ta = g.encode(&a);
            let tb: Vec<u32> = b_words.iter().map(|w| g.vocab.id(w)).collect();
            Example { tokens: encode_pair(&ta, &tb, seq_len),
                      label: label as u32 as f32 }
        }
        Task::Stsb => {
            // graded similarity: interpolate between paraphrase (5.0),
            // shared-topic (2-3), and unrelated (0-1) by shared content.
            let a = g.sentence(rng, 0);
            let level = rng.below(3);
            let (b_words, base) = match level {
                0 => (g.paraphrase(rng, &a), 4.0),
                1 => {
                    // same subject, new everything else
                    let mut b = g.sentence(rng, 0);
                    let pos = b.words.iter().position(|w| *w == b.subject);
                    if let Some(p) = pos {
                        b.words[p] = a.subject.clone();
                    }
                    (b.words, 2.0)
                }
                _ => (g.sentence(rng, 0).words, 0.0),
            };
            let jitter = rng.f32();
            let ta = g.encode(&a);
            let tb: Vec<u32> = b_words.iter().map(|w| g.vocab.id(w)).collect();
            Example { tokens: encode_pair(&ta, &tb, seq_len),
                      label: base + jitter }
        }
    }
}

/// A full split: deterministic in (task, seed, n).
pub fn dataset(g: &Grammar, task: Task, seed: u64, n: usize,
               seq_len: usize) -> Vec<Example> {
    let mut rng = Rng::new(seed ^ 0x61_75_67 ^ (task as u64) << 32);
    (0..n).map(|_| example(g, task, &mut rng, seq_len)).collect()
}

/// Denoising-pretraining pair: (corrupted, clean), 15% token replacement.
pub fn dae_pair(g: &Grammar, rng: &mut Rng, seq_len: usize) -> (Vec<u32>, Vec<u32>) {
    let sentiment = if rng.chance(0.5) { 1 } else { -1 };
    let s = g.sentence(rng, sentiment);
    let mut toks = vec![CLS];
    toks.extend(g.encode(&s));
    toks.push(EOS);
    let clean = pad_to(toks, seq_len);
    let vocab_hi = g.vocab.len() as u32;
    let corrupted: Vec<u32> = clean.iter()
        .map(|&t| {
            if t != 0 && rng.chance(0.15) {
                rng.range(5, vocab_hi as usize) as u32
            } else {
                t
            }
        })
        .collect();
    (corrupted, clean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;

    #[test]
    fn deterministic_datasets() {
        let g = Grammar::new();
        let a = dataset(&g, Task::Sst2, 7, 32, 24);
        let b = dataset(&g, Task::Sst2, 7, 32, 24);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.label, y.label);
        }
    }

    #[test]
    fn labels_balanced() {
        let g = Grammar::new();
        for task in [Task::Sst2, Task::Cola, Task::Rte, Task::Mrpc] {
            let ds = dataset(&g, task, 3, 400, 24);
            let pos = ds.iter().filter(|e| e.label > 0.5).count();
            assert!(pos > 120 && pos < 280, "{}: {pos}/400", task.name());
        }
    }

    #[test]
    fn stsb_labels_in_range() {
        let g = Grammar::new();
        for e in dataset(&g, Task::Stsb, 1, 200, 24) {
            assert!((0.0..=5.0).contains(&e.label));
        }
    }

    #[test]
    fn token_shape_property() {
        check_property("glue examples well-formed", 20, |rng| {
            let g = Grammar::new();
            let t = *rng.pick(&ALL_TASKS);
            let e = example(&g, t, rng, 24);
            assert_eq!(e.tokens.len(), 24);
            assert_eq!(e.tokens[0], CLS);
            assert!(e.tokens.iter().all(|&x| (x as usize) < g.vocab.len()));
        });
    }

    #[test]
    fn dae_pair_corrupts_some_tokens() {
        let g = Grammar::new();
        let mut rng = Rng::new(5);
        let mut diffs = 0;
        for _ in 0..50 {
            let (c, cl) = dae_pair(&g, &mut rng, 24);
            assert_eq!(c.len(), 24);
            diffs += c.iter().zip(&cl).filter(|(a, b)| a != b).count();
        }
        assert!(diffs > 20, "too few corruptions: {diffs}");
    }

    #[test]
    fn sst2_is_learnable_from_lexicon() {
        // sanity: a bag-of-words linear rule must separate the classes
        use super::super::grammar::{NEG_ADJ, POS_ADJ};
        let g = Grammar::new();
        let ds = dataset(&g, Task::Sst2, 11, 300, 24);
        let mut correct = 0;
        let mut undecided = 0;
        for e in &ds {
            let pos = e.tokens.iter()
                .filter(|&&t| POS_ADJ.contains(&g.vocab.word(t))).count();
            let neg = e.tokens.iter()
                .filter(|&&t| NEG_ADJ.contains(&g.vocab.word(t))).count();
            if pos == neg {
                undecided += 1;
            } else if (pos > neg) == (e.label > 0.5) {
                correct += 1;
            }
        }
        let decided = ds.len() - undecided;
        assert!(correct as f64 > 0.95 * decided as f64,
                "lexicon rule acc {correct}/{decided}");
    }
}
