//! The synthetic language: a toy probabilistic grammar with a sentiment-
//! and-semantics-bearing lexicon. All GLUE-substitute tasks (data/glue.rs)
//! and the pretraining corpus derive from this grammar, so a backbone
//! pretrained on it learns features the downstream tasks genuinely reuse —
//! the property the paper's transfer-learning claims rely on (DESIGN.md §2).

use super::tokenizer::Vocab;
use crate::util::rng::Rng;

pub const NOUNS: &[&str] = &[
    "cat", "dog", "bird", "chef", "pilot", "teacher", "robot", "violin",
    "garden", "river", "engine", "novel", "painter", "island", "market",
    "piano", "doctor", "sailor", "lantern", "bridge",
];
pub const VERBS: &[&str] = &[
    "sees", "finds", "follows", "builds", "paints", "plays", "repairs",
    "visits", "studies", "watches", "carries", "greets", "admires",
    "describes", "examines", "observes",
];
pub const POS_ADJ: &[&str] = &[
    "good", "great", "lovely", "bright", "charming", "splendid", "warm",
    "gentle", "brilliant", "delightful", "graceful", "superb",
];
pub const NEG_ADJ: &[&str] = &[
    "bad", "awful", "gloomy", "broken", "dreadful", "bitter", "harsh",
    "rusty", "dismal", "bleak", "clumsy", "grim",
];
pub const NEU_ADJ: &[&str] = &[
    "small", "large", "old", "young", "quiet", "round", "distant", "wooden",
    "early", "narrow",
];
pub const DETS: &[&str] = &["the", "a", "every", "some", "this"];
pub const ADVS: &[&str] = &["quickly", "slowly", "often", "rarely", "calmly", "eagerly"];
pub const CONJ: &[&str] = &["and", "while", "because"];
pub const NEGATION: &str = "never";

/// One generated sentence plus the semantic roles the tasks key on.
#[derive(Clone, Debug)]
pub struct Sentence {
    pub words: Vec<String>,
    pub subject: String,
    pub verb: String,
    pub object: String,
    pub adjectives: Vec<String>,
    pub negated: bool,
}

pub struct Grammar {
    pub vocab: Vocab,
}

impl Default for Grammar {
    fn default() -> Self {
        Self::new()
    }
}

impl Grammar {
    pub fn new() -> Grammar {
        let mut words: Vec<&str> = Vec::new();
        for set in [NOUNS, VERBS, POS_ADJ, NEG_ADJ, NEU_ADJ, DETS, ADVS, CONJ] {
            words.extend_from_slice(set);
        }
        words.push(NEGATION);
        Grammar { vocab: Vocab::new(&words) }
    }

    /// Sample an adjective with the given sentiment in {-1, 0, +1}.
    pub fn adjective(&self, rng: &mut Rng, sentiment: i32) -> &'static str {
        match sentiment {
            1 => POS_ADJ[rng.below(POS_ADJ.len())],
            -1 => NEG_ADJ[rng.below(NEG_ADJ.len())],
            _ => NEU_ADJ[rng.below(NEU_ADJ.len())],
        }
    }

    /// DET (ADJ) NOUN VERB (never) DET (ADJ) NOUN (ADV) — the canonical
    /// grammatical template. `sentiment` biases the adjective draws.
    pub fn sentence(&self, rng: &mut Rng, sentiment: i32) -> Sentence {
        let subject = NOUNS[rng.below(NOUNS.len())].to_string();
        let object = NOUNS[rng.below(NOUNS.len())].to_string();
        let verb = VERBS[rng.below(VERBS.len())].to_string();
        let negated = rng.chance(0.15);
        let mut adjectives = Vec::new();
        let mut words: Vec<String> = Vec::new();
        words.push(DETS[rng.below(DETS.len())].into());
        if rng.chance(0.8) {
            let s = if rng.chance(0.7) { sentiment } else { 0 };
            let a = self.adjective(rng, s);
            adjectives.push(a.to_string());
            words.push(a.into());
        }
        words.push(subject.clone());
        if negated {
            words.push(NEGATION.into());
        }
        words.push(verb.clone());
        words.push(DETS[rng.below(DETS.len())].into());
        if rng.chance(0.6) {
            let s = if rng.chance(0.7) { sentiment } else { 0 };
            let a = self.adjective(rng, s);
            adjectives.push(a.to_string());
            words.push(a.into());
        }
        words.push(object.clone());
        if rng.chance(0.4) {
            words.push(ADVS[rng.below(ADVS.len())].into());
        }
        Sentence { words, subject, verb, object, adjectives, negated }
    }

    /// Token ids of a sentence.
    pub fn encode(&self, s: &Sentence) -> Vec<u32> {
        s.words.iter().map(|w| self.vocab.id(w)).collect()
    }

    /// Agrammatical corruption for the CoLA substitute: structural edits
    /// that break the template (word-order swap across roles, doubled
    /// determiner, dropped verb).
    pub fn corrupt_grammar(&self, rng: &mut Rng, s: &Sentence) -> Vec<String> {
        let mut w = s.words.clone();
        match rng.below(4) {
            0 => {
                // move the verb to the front (aux-less inversion)
                if let Some(pos) = w.iter().position(|x| *x == s.verb) {
                    let v = w.remove(pos);
                    w.insert(0, v);
                }
            }
            1 => {
                // double determiner
                let d = DETS[rng.below(DETS.len())].to_string();
                w.insert(0, d);
                w.insert(0, DETS[rng.below(DETS.len())].to_string());
            }
            2 => {
                // drop the verb entirely
                w.retain(|x| *x != s.verb);
            }
            _ => {
                // shuffle a random window of 4
                if w.len() >= 4 {
                    let start = rng.below(w.len() - 3);
                    let mut win: Vec<String> = w[start..start + 4].to_vec();
                    let orig = win.clone();
                    rng.shuffle(&mut win);
                    if win == orig {
                        win.swap(0, 3);
                    }
                    w.splice(start..start + 4, win);
                }
            }
        }
        w
    }

    /// Paraphrase for MRPC/STS-B: synonym-free but role-preserving edits
    /// (determiner swap, adverb add/remove, adjective reorder).
    pub fn paraphrase(&self, rng: &mut Rng, s: &Sentence) -> Vec<String> {
        let mut w = s.words.clone();
        for word in w.iter_mut() {
            if DETS.contains(&word.as_str()) && rng.chance(0.7) {
                *word = DETS[rng.below(DETS.len())].to_string();
            }
        }
        if rng.chance(0.5) {
            if let Some(last) = w.last().cloned() {
                if ADVS.contains(&last.as_str()) {
                    w.pop();
                } else {
                    w.push(ADVS[rng.below(ADVS.len())].to_string());
                }
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;

    #[test]
    fn vocab_fits_256() {
        let g = Grammar::new();
        assert!(g.vocab.len() <= 256);
        assert!(g.vocab.len() > 80);
    }

    #[test]
    fn sentence_contains_roles() {
        check_property("sentence roles present", 30, |rng| {
            let g = Grammar::new();
            let s = g.sentence(rng, 1);
            assert!(s.words.contains(&s.subject));
            assert!(s.words.contains(&s.verb));
            assert!(s.words.contains(&s.object));
            assert!(s.words.len() >= 4 && s.words.len() <= 12);
        });
    }

    #[test]
    fn sentiment_bias_shows_up() {
        let g = Grammar::new();
        let mut rng = Rng::new(11);
        let mut pos = 0;
        let mut neg = 0;
        for _ in 0..300 {
            let s = g.sentence(&mut rng, 1);
            pos += s.adjectives.iter().filter(|a| POS_ADJ.contains(&a.as_str())).count();
            neg += s.adjectives.iter().filter(|a| NEG_ADJ.contains(&a.as_str())).count();
        }
        assert!(pos > 5 * neg.max(1), "pos {pos} neg {neg}");
    }

    #[test]
    fn corruption_changes_word_sequence() {
        check_property("corruption differs", 30, |rng| {
            let g = Grammar::new();
            let s = g.sentence(rng, 0);
            let c = g.corrupt_grammar(rng, &s);
            assert_ne!(c, s.words);
        });
    }

    #[test]
    fn encode_uses_no_unk() {
        let g = Grammar::new();
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let s = g.sentence(&mut rng, -1);
            let ids = g.encode(&s);
            assert!(ids.iter().all(|&i| i >= super::super::tokenizer::FIRST_WORD));
        }
    }
}
