//! Synthetic shape/texture image corpus (ViT transfer substitute,
//! Tables 6-10 — DESIGN.md §2). 16x16x3 f32 images in [0, 1].
//!
//! A class is a (pattern, palette) combination. The *pretrain* task uses
//! 20 classes (all 5 patterns x 4 palettes); the *transfer* task uses 10
//! held-out pairings at shifted phases/noise — same features, new labels,
//! i.e. genuine transfer as in ImageNet-21k -> CIFAR10.

use crate::util::rng::Rng;

pub const IMG: usize = 16;
pub const CH: usize = 3;

#[derive(Clone, Copy, Debug)]
pub enum Pattern {
    HStripes,
    VStripes,
    Checker,
    Blob,
    Cross,
}

pub const PATTERNS: [Pattern; 5] = [Pattern::HStripes, Pattern::VStripes,
                                    Pattern::Checker, Pattern::Blob,
                                    Pattern::Cross];

/// RGB palettes (foreground, background).
pub const PALETTES: [([f32; 3], [f32; 3]); 4] = [
    ([0.9, 0.2, 0.2], [0.1, 0.1, 0.3]),
    ([0.2, 0.9, 0.3], [0.3, 0.1, 0.1]),
    ([0.2, 0.4, 0.9], [0.3, 0.3, 0.1]),
    ([0.9, 0.9, 0.2], [0.1, 0.3, 0.3]),
];

fn pattern_value(p: Pattern, x: usize, y: usize, phase: usize, period: usize) -> bool {
    match p {
        Pattern::HStripes => ((y + phase) / period) % 2 == 0,
        Pattern::VStripes => ((x + phase) / period) % 2 == 0,
        Pattern::Checker => (((x + phase) / period) + ((y + phase) / period)) % 2 == 0,
        Pattern::Blob => {
            let cx = (IMG / 2 + phase % 5) as f32;
            let cy = (IMG / 2 + (phase / 5) % 5) as f32;
            let dx = x as f32 - cx;
            let dy = y as f32 - cy;
            dx * dx + dy * dy < (period * 2) as f32 * (period * 2) as f32
        }
        Pattern::Cross => {
            let c = IMG / 2 + phase % 3;
            x.abs_diff(c) < period || y.abs_diff(c) < period
        }
    }
}

/// Render one image of (pattern, palette) with random phase/period/noise.
pub fn render(rng: &mut Rng, pattern: Pattern, palette: usize,
              noise: f32) -> Vec<f32> {
    let (fg, bg) = PALETTES[palette];
    let phase = rng.below(8);
    let period = rng.range(2, 5);
    let mut img = vec![0.0f32; IMG * IMG * CH];
    for y in 0..IMG {
        for x in 0..IMG {
            let on = pattern_value(pattern, x, y, phase, period);
            let col = if on { fg } else { bg };
            for c in 0..CH {
                let v = col[c] + noise * rng.normal() as f32;
                img[(y * IMG + x) * CH + c] = v.clamp(0.0, 1.0);
            }
        }
    }
    img
}

/// Class id -> (pattern, palette) for the 20-class pretrain task.
pub fn pretrain_class(id: usize) -> (Pattern, usize) {
    assert!(id < 20);
    (PATTERNS[id % 5], id / 5)
}

/// Class id -> (pattern, palette) for the 10-class transfer task:
/// held-out pairings (diagonal-shifted) the pretrain task never used as
/// *labels* (features transfer, labels do not).
pub fn transfer_class(id: usize) -> (Pattern, usize) {
    assert!(id < 10);
    (PATTERNS[(id * 2 + 1) % 5], (id + id / 5 + 1) % 4)
}

#[derive(Clone, Debug)]
pub struct LabeledImage {
    pub pixels: Vec<f32>,
    pub label: u32,
}

pub fn dataset(seed: u64, n: usize, transfer: bool, noise: f32) -> Vec<LabeledImage> {
    let mut rng = Rng::new(seed ^ if transfer { 0x1000 } else { 0 });
    let n_classes = if transfer { 10 } else { 20 };
    (0..n)
        .map(|_| {
            let label = rng.below(n_classes);
            let (p, pal) = if transfer {
                transfer_class(label)
            } else {
                pretrain_class(label)
            };
            LabeledImage { pixels: render(&mut rng, p, pal, noise),
                           label: label as u32 }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;

    #[test]
    fn image_shape_and_range() {
        check_property("images in range", 20, |rng| {
            let p = *rng.pick(&PATTERNS);
            let pal = rng.below(4);
            let img = render(rng, p, pal, 0.05);
            assert_eq!(img.len(), IMG * IMG * CH);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        });
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean-pixel distance between two classes exceeds within-class
        let mut rng = Rng::new(9);
        let mean = |p: Pattern, pal: usize, rng: &mut Rng| -> Vec<f32> {
            let mut acc = vec![0.0f32; IMG * IMG * CH];
            for _ in 0..10 {
                for (a, b) in acc.iter_mut().zip(render(rng, p, pal, 0.02)) {
                    *a += b / 10.0;
                }
            }
            acc
        };
        let a = mean(Pattern::HStripes, 0, &mut rng);
        let b = mean(Pattern::Blob, 2, &mut rng);
        let a2 = mean(Pattern::HStripes, 0, &mut rng);
        let d_between: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        let d_within: f32 = a.iter().zip(&a2).map(|(x, y)| (x - y).abs()).sum();
        assert!(d_between > 2.0 * d_within, "between {d_between} within {d_within}");
    }

    #[test]
    fn dataset_deterministic_and_labeled() {
        let a = dataset(4, 50, true, 0.05);
        let b = dataset(4, 50, true, 0.05);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.pixels, y.pixels);
        }
        assert!(a.iter().all(|e| e.label < 10));
        assert!(dataset(4, 50, false, 0.05).iter().any(|e| e.label >= 10));
    }
}
