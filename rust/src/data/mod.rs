//! Synthetic data substrates (the paper's datasets are substituted per
//! DESIGN.md §2): a toy probabilistic grammar, GLUE-shaped tasks, an
//! E2E-NLG-shaped generation corpus, and a shape/texture image corpus —
//! all seeded and exactly reproducible.

pub mod batcher;
pub mod e2e;
pub mod glue;
pub mod grammar;
pub mod images;
pub mod tokenizer;
