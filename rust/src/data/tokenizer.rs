//! Word-level vocabulary shared by every text substrate. Token ids fit
//! the AOT graphs' vocab=256; ids 0..4 are reserved specials.

use std::collections::HashMap;

pub const PAD: u32 = 0;
pub const CLS: u32 = 1;
pub const SEP: u32 = 2;
pub const EOS: u32 = 3;
pub const UNK: u32 = 4;
pub const FIRST_WORD: u32 = 5;

#[derive(Clone, Debug)]
pub struct Vocab {
    word_to_id: HashMap<String, u32>,
    id_to_word: Vec<String>,
}

impl Vocab {
    pub fn new(words: &[&str]) -> Vocab {
        let mut id_to_word: Vec<String> =
            ["<pad>", "<cls>", "<sep>", "<eos>", "<unk>"]
                .iter().map(|s| s.to_string()).collect();
        for w in words {
            assert!(!id_to_word.iter().any(|x| x == w), "duplicate word {w}");
            id_to_word.push(w.to_string());
        }
        assert!(id_to_word.len() <= 256, "vocab exceeds the AOT graphs' 256");
        let word_to_id = id_to_word.iter().enumerate()
            .map(|(i, w)| (w.clone(), i as u32)).collect();
        Vocab { word_to_id, id_to_word }
    }

    pub fn len(&self) -> usize {
        self.id_to_word.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn id(&self, word: &str) -> u32 {
        *self.word_to_id.get(word).unwrap_or(&UNK)
    }

    pub fn word(&self, id: u32) -> &str {
        self.id_to_word.get(id as usize).map(|s| s.as_str()).unwrap_or("<bad>")
    }

    pub fn encode(&self, words: &[&str]) -> Vec<u32> {
        words.iter().map(|w| self.id(w)).collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .filter(|&&i| i != PAD && i != CLS && i != SEP && i != EOS)
            .map(|&i| self.word(i))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Pad/truncate a token sequence to exactly `len`.
pub fn pad_to(mut toks: Vec<u32>, len: usize) -> Vec<u32> {
    toks.truncate(len);
    while toks.len() < len {
        toks.push(PAD);
    }
    toks
}

/// [CLS] a... [SEP] b... [EOS], padded to `len` (pair-task encoding).
pub fn encode_pair(a: &[u32], b: &[u32], len: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(len);
    out.push(CLS);
    out.extend_from_slice(a);
    out.push(SEP);
    out.extend_from_slice(b);
    out.push(EOS);
    pad_to(out, len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let v = Vocab::new(&["cat", "dog", "runs"]);
        let ids = v.encode(&["dog", "runs", "cat"]);
        assert_eq!(v.decode(&ids), "dog runs cat");
        assert_eq!(v.id("zebra"), UNK);
    }

    #[test]
    fn specials_reserved() {
        let v = Vocab::new(&["a"]);
        assert_eq!(v.id("a"), FIRST_WORD);
        assert_eq!(v.word(PAD), "<pad>");
    }

    #[test]
    fn pair_encoding_layout() {
        let e = encode_pair(&[10, 11], &[12], 8);
        assert_eq!(e, vec![CLS, 10, 11, SEP, 12, EOS, PAD, PAD]);
        assert_eq!(e.len(), 8);
    }

    #[test]
    fn pad_truncates() {
        assert_eq!(pad_to(vec![1, 2, 3, 4], 2), vec![1, 2]);
        assert_eq!(pad_to(vec![1], 3), vec![1, 0, 0]);
    }
}
