//! Quantum-PEFT reproduction — Layer-3 Rust coordinator.
//!
//! The paper's contribution (quantum unitary PEFT parameterizations) lives
//! in the AOT-compiled JAX/Pallas artifacts under `artifacts/`; this crate
//! owns everything at run time: the PJRT runtime that loads and executes
//! those artifacts, synthetic data substrates, evaluation metrics, the
//! fine-tuning coordinator (training sessions, sweeps, checkpoints), a
//! pure-Rust mirror of the unitary math (Figure 6 benches, accounting),
//! and table/report generation for every experiment in the paper.
//!
//! Python never runs on any path in this crate — `make artifacts` is the
//! only Python invocation in the whole system.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod peft;
pub mod quantum;
pub mod report;
pub mod runtime;
pub mod util;
