//! Quantum-PEFT reproduction — Layer-3 Rust coordinator.
//!
//! The paper's contribution (quantum unitary PEFT parameterizations) lives
//! in the AOT-compiled JAX/Pallas artifacts under `artifacts/`; this crate
//! owns everything at run time: the PJRT runtime that loads and executes
//! those artifacts, synthetic data substrates, evaluation metrics, the
//! fine-tuning coordinator (training sessions, sweeps, checkpoints), a
//! pure-Rust mirror of the unitary math (Figure 6 benches, accounting),
//! and table/report generation for every experiment in the paper.
//!
//! Python never runs on any path in this crate — `make artifacts` is the
//! only Python invocation in the whole system.
//!
//! ## Parallelism and the shared compile cache
//!
//! Sweep-backed tables (2/5/6–10), the E2E panel (Tables 3/4), and
//! `repro sweep` run their cells on a work-stealing pool
//! ([`util::pool`]). Worker count: the `$REPRO_JOBS` env var beats the
//! preset's `[sweep] jobs` key; both accept a count or `auto`/`0` (one
//! worker per core) and default to 1 (sequential). Results — and every
//! rendered table — are byte-identical for any jobs value; only
//! wall-clock and event-log interleaving change.
//!
//! ## Multi-tenant adapter serving
//!
//! [`serve`] turns the few-KB-adapter storage story (Table 1) into a
//! serving story: a concurrent tenant registry with versioned hot-swap
//! and an LRU-bounded materialization cache, a micro-batching scheduler
//! over the same work-stealing pool, per-tenant latency/throughput
//! metrics through the `EventLog`, and a seeded load generator
//! (`repro serve-bench`). The control plane on top: per-tenant
//! token-bucket rate limits and a global queue-depth cap enforced at
//! submit time (overload sheds with a typed, counted rejection instead
//! of unbounded queue growth), and a spool-directory watcher that
//! hot-loads `QPCK` v2 adapter uploads — validated through the hardened
//! checkpoint loader, quarantined on failure — and evicts tenants whose
//! files are deleted, deferring on in-flight pins. The `fifo` mode plus
//! the seeded loadgen give a byte-identical response log — and, with
//! admission on a logical clock, a byte-identical rejection ledger — at
//! any worker count: the same determinism contract the sweep engine
//! makes.
//!
//! ## The sharded serving tier
//!
//! [`serve::shard`] scales that single instance horizontally: N
//! independent shards — each with its own registry, mat-cache LRU,
//! batcher/worker pool, admission ledger and durable state dir
//! (`<state_root>/shard-NNNN`) — behind a consistent-hash router
//! (FNV-1a virtual-node ring over tenant names, `repro serve-bench
//! --shards N`). Tenants migrate live between shards (write-ahead
//! re-register on the target at the recorded version, atomic
//! routing-table flip, pin-drain on the source) without dropping
//! in-flight requests; a dead shard sheds its traffic with a typed
//! rejection while the rest of the fleet keeps serving, and restarts
//! from its own WAL with exactly its tenants. Deterministic routing
//! composes with fifo mode: per-shard response logs stay byte-identical
//! at any worker count.
//!
//! ## Observability
//!
//! [`obs`] is the process-wide observability layer, in two halves.
//!
//! The **metrics backplane** ([`obs::metrics`]) is a std-only registry
//! of named counters, gauges and log₂-bucket histograms, registered
//! once per `(name, labels)` under `&'static str` names and handed out
//! as `Arc`-cheap handles whose hot path is a single relaxed atomic op
//! — no locks, no allocation, no formatting. It is threaded through
//! every layer: [`util::sync`] observed-lock wrappers (wait time,
//! acquisitions, poison recoveries per site), [`util::pool`]
//! (steals, parks, panics, queue depth, per-worker busy time),
//! [`runtime::exe_cache`] (hits, misses, deduplicated in-flight
//! waits, compile time), [`store`] (WAL appends/bytes/fsyncs,
//! snapshot writes, recovery counters), the serve request path
//! (submitted/completed/failed, latency and batch-size histograms)
//! and the sweep engine (`sweep_cells_total`). Exporters
//! ([`obs::export`]) render one atomic snapshot as Prometheus text
//! and as JSONL — `--metrics-out FILE` on `repro sweep` and `repro
//! serve-bench` writes both, and `repro stat FILE` renders the JSONL
//! as a table. Every metric carries a [`obs::metrics::Class`]:
//! deterministic registries export only `Stable` metrics (pure
//! functions of the seeded stream), so a fifo-mode snapshot is
//! byte-identical at any worker count — the same contract as the
//! response log, and `tests/obs_metrics.rs` pins it. Volatile
//! metrics (lock waits, pool timings, compile durations) appear in
//! timed-mode snapshots, where wall-clock truth matters more than
//! reproducibility.
//!
//! The **tracing half** is per-request: every request carries an
//! [`obs::TraceCtx`] (trace id derived from the seeded stream)
//! through admission → coalesce → queue → cache-lookup → materialize
//! → apply → respond, with per-phase durations taken from the
//! [`obs::SpanClock`] — wall-clock in timed mode, a driver-advanced
//! logical counter in fifo mode. Per-tenant latency lives in
//! mergeable log₂-bucket histograms ([`obs::Hist`]: fixed 64
//! buckets, lock-free increments, O(buckets) memory per tenant). A
//! per-worker flight recorder ([`obs::FlightRecorder`]) keeps the
//! last N completed spans and dumps them as `serve_trace` lines
//! (plus optional `--trace-dir` JSONL). `--metrics-interval` emits
//! live `serve_interval` snapshots; `--slo-p99-us`/
//! `--slo-error-budget` track per-tenant SLO error-budget burn
//! ([`obs::SloPolicy`]) as `serve_slo` lines and a compliance
//! section in the serve-bench summary.
//!
//! ## Durability model
//!
//! [`store`] makes the serving control plane's state durable: registry
//! mutations (register / hot-swap / evict, with tenant, version, theta
//! checksum and originating `QPCK` path) stream through a
//! [`store::StateSink`] into a CRC-framed write-ahead log, periodically
//! compacted into an atomic-rename snapshot. A server restarted with
//! the same `--state-dir` recovers the same tenants at the same
//! versions and serves byte-identical responses. fsync cadence is the
//! [`store::Durability`] knob (`Buffered` = OS-crash-safe, `EveryN` /
//! `Always` = power-cut-safe up to a bounded tail); recovery tolerates
//! exactly one torn trailing WAL record and reports anything worse as a
//! typed [`store::CorruptState`] error. The default
//! [`store::NullSink`] keeps the purely in-RAM behavior — and the
//! serving determinism guarantees — unchanged.
//!
//! All workers load artifacts through one shared
//! [`runtime::exe_cache::ExeCache`]: parsed HLO protos are shared
//! unconditionally, and on backends whose client tolerates concurrent
//! execution (CPU PJRT) the compiled executable is shared too, so each
//! distinct artifact path compiles **exactly once per process**, with
//! in-flight compiles deduplicated (a path being compiled by one worker
//! blocks, not re-compiles, in the others). On backends that cannot
//! share a client, [`runtime::Runtime::for_worker`] falls back to a
//! private same-platform client per worker that still shares the parse
//! cache and the aggregated compile log; `REPRO_SHARE_CLIENT=0` forces
//! that fallback on CPU (an A/B knob for shared vs per-worker warm-up).
//!
//! ## Static invariants (`repro analyze`)
//!
//! The properties the tests lean on hardest — fifo byte-determinism,
//! typed errors on serving paths, checked WAL/QPCK framing — are
//! enforced *statically* by [`analysis`], a std-only lexer + scanner
//! pass wired into CI as a blocking gate (`repro analyze --format
//! json`). The lints:
//!
//! - **determinism** — no `HashMap`/`HashSet` iteration and no
//!   `Instant::now`/`SystemTime::now` in `serve/`, `store/`,
//!   `coordinator/` (the fifo/EventLog-emitting modules); unordered
//!   iteration or a wall-clock read anywhere near an emitted line is
//!   how byte-reproducibility dies.
//! - **lock-discipline** — no `.lock().unwrap()` (poison cascades; use
//!   [`util::sync::lock_or_recover`] and friends), and held-lock
//!   acquisition order per function must follow the declared table in
//!   [`analysis::order::LOCK_ORDER`]; serve/store files absent from
//!   that table may not nest held locks at all.
//! - **panic-path** — no `unwrap`/`expect`/`panic!`/literal indexing in
//!   `serve/`+`store/` non-test code; typed errors
//!   ([`serve::Rejected`], [`store::CorruptState`], ...) are the
//!   contract.
//! - **framing-casts** — no bare `as u16`/`as u32`/`as usize` in
//!   `store/wal.rs`, `store/snapshot.rs`, `store/recover.rs`, or
//!   `coordinator/checkpoint.rs`; narrowing goes through `try_from`
//!   with a typed error.
//! - **log-discipline** — no `println!`/`eprintln!` in library modules;
//!   the `EventLog` is the only sanctioned sink.
//! - **io-durability** — `File::create`/`fs::write` in `store/` must
//!   share a function with an fsync (the write-temp + `sync_all` +
//!   atomic-rename idiom).
//! - **obs-discipline** — `serve/` and `obs/` may only read the wall
//!   clock through [`obs::SpanClock`] (defined in `obs/span.rs`, the
//!   one exempt module); a direct `Instant::now`/`SystemTime::now`
//!   anywhere else on the serving path bypasses the logical clock and
//!   breaks fifo latency determinism.
//! - **metrics-discipline** — metric names passed to
//!   `.counter(`/`.gauge(`/`.hist(` must be snake_case string
//!   literals (a computed name defeats grep and dashboards) and each
//!   name must be registered at exactly one non-test call site
//!   crate-wide, so the registration site *is* the metric's
//!   documentation; `obs/metrics.rs` itself is exempt.
//!
//! Four lints are *interprocedural*: they run over a crate-wide
//! name-resolved call graph ([`analysis::graph`]) built from per-file
//! item models ([`analysis::model`]), so a violation two calls away
//! from the held guard is still attributed to the call site that
//! reaches it:
//!
//! - **lock-order-transitive** — the held-guard set is propagated
//!   through every resolvable call; any reachable acquisition is
//!   checked against [`analysis::order::GLOBAL_ORDER`] (inversions and
//!   re-entrant re-acquisition both report).
//! - **blocking-under-lock** — fsync / `write_all` / blocking `recv` /
//!   `join` / `sleep` reachable while any `GLOBAL_ORDER` guard is held.
//! - **atomics-discipline** — `Ordering::Relaxed` on an `AtomicBool`
//!   flag that crosses a spawn boundary (stored on one side, loaded on
//!   the other) carries no happens-before edge; also
//!   `compare_exchange_weak` outside a retry loop.
//! - **resource-leak** — `thread::spawn` / `pool::Background` handles
//!   that no path joins or stores.
//!
//! The call graph is deliberately conservative: `self.`/`Type::` calls
//! resolve precisely; a method on an opaque receiver unions *every*
//! crate fn of that name — except ubiquitous std names (`get`, `len`,
//! `send`, ...) and std-qualified paths (`Arc::new`), which resolve to
//! nothing rather than to every same-named crate fn. So "no finding"
//! proves the absence of a reachable violation only up to that union,
//! and a finding may name a callee the receiver's real type can never
//! be — which is why suppressions carry reasons instead of the
//! analyzer guessing types.
//!
//! Exceptions are inline and reasoned:
//! `// analyze: allow(<lint>) <reason>` on the finding's line or the
//! line above. The reason is mandatory — a bare allow is itself a
//! finding — so every suppression in the tree documents the invariant
//! that makes it sound. Test code is exempt. `tests/analysis.rs`
//! self-runs the pass over `src/`, `benches/`, and `tests/` (fixtures
//! excluded) and asserts zero unsuppressed findings. For incremental
//! adoption there is a ratchet: `repro analyze --baseline <file>`
//! compares findings against a fingerprinted baseline
//! ([`analysis::baseline`]) — new findings fail, fixed ones shrink the
//! baseline on `--write-baseline`, and a stale baseline entry is
//! itself a finding, so the accepted set only moves down.

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod obs;
pub mod peft;
pub mod quantum;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod util;
