//! `repro` — the Quantum-PEFT reproduction CLI (Layer-3 leader process).
//!
//!   repro list                             show artifacts + param counts
//!   repro pretrain --family enc|encw|dec|vit [--preset quick|default|full]
//!   repro train --tag enc_lora --task sst2 [--steps N] [--lr F] [--seed S]
//!   repro table --id table1..table10|fig6|fig5-params [--preset ...]
//!   repro e2e   --tag dec_lora             one E2E generation run
//!
//! Argument parsing is hand-rolled (no clap in the offline registry);
//! flags are `--key value` pairs after the subcommand.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use quantum_peft::config;
use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::trainer::{self, GlueRunSpec};
use quantum_peft::data::glue;
use quantum_peft::report::{self, tables};
use quantum_peft::runtime::{Manifest, Runtime};

struct Args {
    cmd: String,
    flags: BTreeMap<String, String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut flags = BTreeMap::new();
    while let Some(k) = it.next() {
        let key = k.strip_prefix("--")
            .with_context(|| format!("expected --flag, got {k:?}"))?;
        let v = it.next().with_context(|| format!("--{key} needs a value"))?;
        flags.insert(key.to_string(), v);
    }
    Ok(Args { cmd, flags })
}

fn main() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        "list" => cmd_list(),
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "e2e" => cmd_e2e(&args),
        "table" => cmd_table(&args),
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "repro — Quantum-PEFT (ICLR 2025) reproduction
commands:
  list                              artifacts + parameter accounting
  pretrain --family enc|encw|dec|vit [--preset quick|default|full]
  train    --tag <tag> [--task sst2|cola|rte|mrpc|stsb] [--steps N]
           [--lr F] [--seed S] [--preset P] [--no-backbone true]
  e2e      --tag <dec_tag> [--preset P]
  table    --id table1|table2|...|table10|fig6|fig5-params [--preset P]
env: REPRO_ARTIFACTS (default ./artifacts), REPRO_RUNS (default ./runs)";

fn load_env() -> Result<(Runtime, Manifest)> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&Manifest::default_dir())?;
    Ok((rt, manifest))
}

fn preset_of(args: &Args) -> Result<config::Config> {
    if let Some(path) = args.flags.get("config") {
        return config::Config::load(std::path::Path::new(path));
    }
    let name = args.flags.get("preset").map(|s| s.as_str()).unwrap_or("default");
    config::preset(name)
}

fn event_log() -> Result<EventLog> {
    EventLog::new(Some(tables::runs_dir().join("events.jsonl")), false)
}

fn cmd_list() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let mut rows = Vec::new();
    for (tag, e) in &manifest.artifacts {
        rows.push(vec![
            tag.clone(),
            e.model.clone(),
            e.method.clone(),
            report::fmt_params(e.adapter_param_count),
            report::fmt_params(e.trainable_param_count),
            report::fmt_params(e.total_param_count),
        ]);
    }
    print!("{}", report::render_table(
        &["tag", "model", "method", "adapter", "trainable", "total"], &rows));
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env()?;
    let cfg = preset_of(args)?;
    let log = event_log()?;
    let family = args.flags.get("family").map(|s| s.as_str()).unwrap_or("enc");
    let path = tables::ensure_backbone(&rt, &manifest, family, &cfg, &log)?;
    println!("backbone ready: {path:?}");
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env()?;
    let cfg = preset_of(args)?;
    let log = event_log()?;
    let tag = args.flags.get("tag").context("--tag required")?;
    let task_name = args.flags.get("task").map(|s| s.as_str()).unwrap_or("sst2");
    let task = glue::Task::from_name(task_name)
        .with_context(|| format!("unknown task {task_name:?}"))?;
    let mut tcfg = config::train_config(&cfg);
    if let Some(s) = args.flags.get("steps") {
        tcfg.steps = s.parse()?;
    }
    if let Some(s) = args.flags.get("lr") {
        tcfg.lr = s.parse()?;
    }
    if let Some(s) = args.flags.get("seed") {
        tcfg.seed = s.parse()?;
    }
    let family = if tag.starts_with("encw") { "encw" } else { "enc" };
    let backbone = if args.flags.get("no-backbone").is_some() {
        None
    } else {
        Some(tables::ensure_backbone(&rt, &manifest, family, &cfg, &log)?)
    };
    let spec = GlueRunSpec {
        tag,
        task,
        cfg: tcfg,
        backbone: backbone.as_deref(),
        extras_override: BTreeMap::new(),
    };
    let r = trainer::run_glue(&rt, &manifest, &spec, &log)?;
    println!("tag={} task={} {}={:.4} (best {:.4})  adapter_params={}  \
              step={:.1}ms  compile={:.1}s",
             r.tag, r.task, r.metric_name, r.final_metric, r.best_metric,
             r.adapter_params, r.step_ms, rt.total_compile_seconds());
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env()?;
    let cfg = preset_of(args)?;
    let log = event_log()?;
    let tag = args.flags.get("tag").context("--tag required")?;
    let backbone = tables::ensure_backbone(&rt, &manifest, "dec", &cfg, &log)?;
    let tcfg = config::train_config(&cfg);
    let spec = trainer::E2eRunSpec {
        tag,
        cfg: tcfg,
        backbone: Some(&backbone),
        gen_cases: 64,
    };
    let r = trainer::run_e2e(&rt, &manifest, &spec, &log)?;
    println!("tag={tag}");
    for (k, v) in &r.extra_metrics {
        println!("  {k:10} {v:.4}");
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let id = args.flags.get("id").context("--id required")?.as_str();
    // analytic tables need no runtime
    match id {
        "table1" => {
            tables::print_table("Table 1 — storage (analytic, exact dims)",
                                &tables::table1());
            return Ok(());
        }
        "fig6" => {
            let sizes = [16usize, 32, 64, 128, 256, 512, 1024];
            tables::print_table("Figure 6 — unitarity error & speed vs N",
                                &tables::fig6(&sizes));
            return Ok(());
        }
        "fig5-params" => {
            tables::print_table("Figure 5 — params per adapted weight (N=768, K=4)",
                                &tables::fig5_params(768, 4));
            return Ok(());
        }
        _ => {}
    }
    let (rt, manifest) = load_env()?;
    let cfg = preset_of(args)?;
    let log = event_log()?;
    match id {
        "table2" => tables::print_table(
            "Table 2 — synthetic-GLUE, encoder backbone",
            &tables::table2(&rt, &manifest, &cfg, &log)?),
        "table3" | "table4" => {
            let (t3, t4) = tables::table3_and_4(&rt, &manifest, &cfg, &log)?;
            tables::print_table("Table 3 — E2E-substitute generation", &t3);
            tables::print_table("Table 4 — efficiency", &t4);
        }
        "table5" => tables::print_table(
            "Table 5 — wide encoder (Mistral-7B stand-in)",
            &tables::table5(&rt, &manifest, &cfg, &log)?),
        "table6" => tables::print_table(
            "Table 6 — ViT transfer (3-bit base)",
            &tables::table6(&rt, &manifest, &cfg, &log)?),
        "table7" => tables::print_table(
            "Table 7 — Lie-parameter quantization (QAT)",
            &tables::table7(&rt, &manifest, &cfg, &log)?),
        "table8" => tables::print_table(
            "Table 8 — intrinsic rank K'",
            &tables::table8(&rt, &manifest, &cfg, &log)?),
        "table9" => tables::print_table(
            "Table 9 — entanglement layers L",
            &tables::table9(&rt, &manifest, &cfg, &log)?),
        "table10" => tables::print_table(
            "Table 10 — tensor networks",
            &tables::table10(&rt, &manifest, &cfg, &log)?),
        other => bail!("unknown table id {other:?}"),
    }
    println!("\n(total XLA compile time: {:.1}s)", rt.total_compile_seconds());
    Ok(())
}
