//! `repro` — the Quantum-PEFT reproduction CLI (Layer-3 leader process).
//!
//!   repro list                             show artifacts + param counts
//!   repro pretrain --family enc|encw|dec|vit [--preset quick|default|full]
//!   repro train --tag enc_lora --task sst2 [--steps N] [--lr F] [--seed S]
//!   repro sweep --tags a,b [--tasks sst2,cola] [--seeds 0..4] [--jobs N]
//!   repro table --id table1..table10|fig6|fig5-params [--preset ...]
//!   repro e2e   --tag dec_lora             one E2E generation run
//!
//! Argument parsing is hand-rolled (no clap in the offline registry);
//! flags are `--key value` pairs after the subcommand.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use quantum_peft::analysis;
use quantum_peft::config;
use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::sweep::{self, SweepObs, SweepPlan};
use quantum_peft::obs::export;
use quantum_peft::obs::MetricsRegistry;
use quantum_peft::coordinator::trainer::{self, GlueRunSpec};
use quantum_peft::data::glue;
use quantum_peft::report::{self, tables};
use quantum_peft::runtime::{Manifest, Runtime};
use quantum_peft::util::pool;

struct Args {
    cmd: String,
    flags: BTreeMap<String, String>,
    /// Non-flag operands (only `analyze` takes any; everything else
    /// rejects them to keep the old strict `--key value` contract).
    positional: Vec<String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut flags = BTreeMap::new();
    let mut positional = Vec::new();
    while let Some(k) = it.next() {
        let Some(key) = k.strip_prefix("--") else {
            positional.push(k);
            continue;
        };
        let v = it.next().with_context(|| format!("--{key} needs a value"))?;
        flags.insert(key.to_string(), v);
    }
    Ok(Args { cmd, flags, positional })
}

fn main() -> Result<()> {
    let args = parse_args()?;
    if args.cmd != "analyze" && args.cmd != "stat" && !args.positional.is_empty() {
        bail!("unexpected argument {:?} (flags are --key value pairs)", args.positional[0]);
    }
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        "list" => cmd_list(),
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "e2e" => cmd_e2e(&args),
        "table" => cmd_table(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "stat" => cmd_stat(&args),
        "analyze" => cmd_analyze(&args),
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "repro — Quantum-PEFT (ICLR 2025) reproduction
commands:
  list                              artifacts + parameter accounting
  pretrain --family enc|encw|dec|vit [--preset quick|default|full]
  train    --tag <tag> [--task sst2|cola|rte|mrpc|stsb] [--steps N]
           [--lr F] [--seed S] [--preset P] [--no-backbone true|false]
  sweep    --tags <a,b,...> [--tasks sst2,cola,...] [--seeds 0..4|0,1,2]
           [--jobs N|auto] [--steps N] [--lr F] [--preset P]
           [--no-backbone true|false] [--metrics-out FILE]
           runs the (tag, task, seed) grid on a work-stealing pool
           (--jobs workers sharing one compile cache; default 1) and
           prints mean±std over seeds. --seeds a..b is INCLUSIVE
           (0..4 = the paper's five-seed protocol). Results and
           aggregates are byte-identical for every --jobs value; only
           wall-clock and the event log's interleaving and per-line
           worker tags change (jobs > 1 stamps a \"worker\" field).
           --metrics-out FILE writes an end-of-run metrics snapshot:
           JSONL at FILE plus Prometheus text at FILE.prom. The
           deterministic (Stable) subset — e.g. sweep_cells_total — is
           byte-identical for every --jobs value.
  e2e      --tag <dec_tag> [--preset P]
  table    --id table1|table2|...|table10|fig6|fig5-params [--preset P]
           (sweep- and panel-backed tables — including the Table 3/4 E2E
           panel — honor REPRO_JOBS / [sweep] jobs)
  serve-bench  [--workers N|auto] [--tenants N] [--requests N] [--seed S]
           [--skew F] [--qubits Q] [--layers L] [--max-batch N]
           [--max-wait-us N] [--mode fifo|timed] [--concurrency C]
           [--rate RPS] [--cache-mb F] [--tenant-quota-mb F]
           [--rate-rps F] [--burst F] [--max-queue N]
           [--admission-config FILE] [--spool-dir PATH]
           [--state-dir PATH] [--durability buffered|always|N]
           [--shards N] [--metrics-interval N] [--slo-p99-us F]
           [--slo-error-budget F] [--trace-dir PATH] [--recorder-cap N]
           [--metrics-out FILE]
           multi-tenant adapter serving benchmark: seeded Zipf loadgen
           against the serve registry/scheduler (closed loop by default;
           --rate > 0 switches to open-loop arrivals and timed batching).
           admission control: --rate-rps caps each tenant's sustained
           admission rate (token bucket, capacity --burst; default one
           second's worth) and --max-queue caps global queue depth —
           overload sheds with per-tenant rejection counters in the
           event log instead of growing the queue. --admission-config
           FILE seeds rate/burst/queue-cap from a JSON file and
           hot-reloads it live (spool-style stability window) without
           dropping in-flight requests. --tenant-quota-mb caps any one
           tenant's share of the materialization cache (its own LRU
           entries recycle first; quota rejections are counted).
           --spool-dir starts a watcher that hot-loads QPCK adapter
           uploads dropped into that directory (quarantining malformed
           or checksum-mismatched ones to rejected/) and evicts tenants
           whose files are deleted. --state-dir makes registry state
           durable: mutations append to a CRC-framed WAL (fsync cadence
           per --durability: buffered, always, or every N appends),
           compacted to a snapshot at session end; a restart with the
           same --state-dir recovers every tenant at its recorded
           version and serves byte-identical responses.
           --shards N runs N independent serving shards (each its own
           registry, batcher, cache, admission ledger and
           --state-dir subdirectory shard-NNNN) behind a
           consistent-hash router and prints per-shard + fleet
           metrics; tenant placement is a pure function of the name,
           so per-shard response logs stay fifo-deterministic.
           observability: --metrics-interval N emits live serve_interval
           snapshots (req/s, histogram p50/p95/p99, queue depth, cache
           hit rate, per-tenant rejects) every N completed requests in
           fifo mode / every N ms in timed mode; --slo-p99-us F with
           --slo-error-budget B tracks per-tenant SLO error-budget burn
           (serve_slo lines + a compliance section in the summary);
           every request carries a trace span through admission ->
           coalesce -> queue -> cache -> materialize -> apply ->
           respond, with the last --recorder-cap spans per worker dumped
           as serve_trace lines at session end (--trace-dir also writes
           them as JSONL files).
           --metrics-out FILE dumps the process-wide metrics registry
           at session end: JSONL at FILE plus Prometheus text at
           FILE.prom (render with `repro stat FILE`). In fifo mode the
           snapshot holds the deterministic (Stable) subset — request /
           WAL / sweep counters and the serve latency and batch-size
           histograms — and is byte-identical at any --workers and any
           --shards split; timed mode adds lock-wait, pool, compile
           cache and fsync timing metrics. Nonsense observability knobs
           (--metrics-interval 0, --recorder-cap 0, negative
           --slo-error-budget) fail fast with a typed error before the
           bench starts.
           fifo mode is byte-deterministic per seed at any --workers,
           rejections included (open-loop gaps advance a logical clock
           instead of sleeping); summary (p50/p95/p99, req/s, batch
           histogram, cache + admission counters, SLO compliance) prints
           here and lands in the event log as serve_* lines.
  stat     FILE                       render a --metrics-out JSONL
           snapshot as an aligned NAME/LABELS/TYPE/CLASS/VALUE table
           (histograms show count and approximate p50/p90/p99)
  analyze  [--format text|json|github] [--baseline FILE]
           [--write-baseline FILE] [paths...]
           repo-invariant static analysis (determinism, lock-discipline,
           panic-path, framing-casts, log-discipline, io-durability,
           obs-discipline, metrics-discipline, plus the
           interprocedural call-graph lints
           lock-order-transitive, blocking-under-lock,
           atomics-discipline, resource-leak):
           lexes the given .rs files/directories (default: the crate's
           src/, benches/ and tests/ trees, fixtures excluded), builds
           the crate-wide call graph, and reports per-lint findings
           with file:line anchors. Suppress inline with
           `// analyze: allow(<lint>) <reason>` — the reason is
           mandatory. --baseline FILE accepts previously ratcheted
           findings (new ones still fail; stale entries are findings);
           --write-baseline FILE captures the current findings.
           --format github emits ::error workflow commands for inline
           PR annotations. Exits non-zero on any unsuppressed finding
           (the blocking CI gate runs `analyze --format json`).
all parallel paths share one compile cache: each distinct artifact path
compiles exactly once per process on CPU (in-flight compiles dedup across
workers); other backends fall back to per-worker compiles that still
share parsed HLO protos and one aggregated compile log.
env: REPRO_ARTIFACTS (default ./artifacts), REPRO_RUNS (default ./runs),
     REPRO_JOBS (sweep/panel workers; 'auto' = one per core),
     REPRO_SHARE_CLIENT=0 (force per-worker clients; still shares the
     parse cache + aggregated compile log)";

fn load_env() -> Result<(Runtime, Manifest)> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&Manifest::default_dir())?;
    Ok((rt, manifest))
}

fn preset_of(args: &Args) -> Result<config::Config> {
    if let Some(path) = args.flags.get("config") {
        return config::Config::load(std::path::Path::new(path));
    }
    let name = args.flags.get("preset").map(|s| s.as_str()).unwrap_or("default");
    config::preset(name)
}

fn event_log() -> Result<EventLog> {
    EventLog::new(Some(tables::runs_dir().join("events.jsonl")), false)
}

/// Parse a boolean-valued flag. Absent flags are `false`; present flags
/// must carry an explicit value, so `--no-backbone false` really means
/// "use the backbone" (the flag's *value* decides, not its presence).
fn flag_bool(args: &Args, key: &str) -> Result<bool> {
    match args.flags.get(key) {
        None => Ok(false),
        Some(v) => match v.as_str() {
            "true" | "1" | "yes" => Ok(true),
            "false" | "0" | "no" => Ok(false),
            other => bail!("--{key} expects true|false, got {other:?}"),
        },
    }
}

/// Seed-list syntax: "0,1,2" or an INCLUSIVE range "a..b" / "a..=b"
/// (so `--seeds 0..4` is the paper's five-seed protocol, §5.1).
fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    if let Some((lo, hi)) = s.split_once("..") {
        let lo: u64 = lo.trim().parse()
            .with_context(|| format!("bad seed range start in {s:?}"))?;
        let hi: u64 = hi.trim().trim_start_matches('=').parse()
            .with_context(|| format!("bad seed range end in {s:?}"))?;
        if hi < lo {
            bail!("empty seed range {s:?}");
        }
        return Ok((lo..=hi).collect());
    }
    s.split(',')
        .map(|p| p.trim().parse::<u64>()
             .with_context(|| format!("bad seed {p:?} in {s:?}")))
        .collect()
}

fn parse_jobs(args: &Args) -> Result<usize> {
    match args.flags.get("jobs") {
        None => Ok(1),
        Some(v) => pool::parse_jobs_value(v).context("--jobs"),
    }
}

/// Backbone family of a GLUE-capable encoder tag. The GLUE drivers
/// (`train`, `sweep`) only make sense for enc*/encw* artifacts — the
/// ViT/decoder panels live behind `repro table`.
fn glue_family(tag: &str) -> Result<&'static str> {
    if tag.starts_with("encw") {
        Ok("encw")
    } else if tag.starts_with("enc") {
        Ok("enc")
    } else {
        bail!("tag {tag:?} is not a GLUE-family (enc*/encw*) artifact; \
               use `repro table` for the ViT/decoder panels")
    }
}

fn cmd_list() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let mut rows = Vec::new();
    for (tag, e) in &manifest.artifacts {
        rows.push(vec![
            tag.clone(),
            e.model.clone(),
            e.method.clone(),
            report::fmt_params(e.adapter_param_count),
            report::fmt_params(e.trainable_param_count),
            report::fmt_params(e.total_param_count),
        ]);
    }
    print!("{}", report::render_table(
        &["tag", "model", "method", "adapter", "trainable", "total"], &rows));
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env()?;
    let cfg = preset_of(args)?;
    let log = event_log()?;
    let family = args.flags.get("family").map(|s| s.as_str()).unwrap_or("enc");
    let path = tables::ensure_backbone(&rt, &manifest, family, &cfg, &log)?;
    println!("backbone ready: {path:?}");
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env()?;
    let cfg = preset_of(args)?;
    let log = event_log()?;
    let tag = args.flags.get("tag").context("--tag required")?;
    let task_name = args.flags.get("task").map(|s| s.as_str()).unwrap_or("sst2");
    let task = glue::Task::from_name(task_name)
        .with_context(|| format!("unknown task {task_name:?}"))?;
    let mut tcfg = config::train_config(&cfg);
    if let Some(s) = args.flags.get("steps") {
        tcfg.steps = s.parse()?;
    }
    if let Some(s) = args.flags.get("lr") {
        tcfg.lr = s.parse()?;
    }
    if let Some(s) = args.flags.get("seed") {
        tcfg.seed = s.parse()?;
    }
    let backbone = if flag_bool(args, "no-backbone")? {
        None
    } else {
        let family = glue_family(tag)?;
        Some(tables::ensure_backbone(&rt, &manifest, family, &cfg, &log)?)
    };
    let spec = GlueRunSpec {
        tag,
        task,
        cfg: tcfg,
        backbone: backbone.as_deref(),
        extras_override: BTreeMap::new(),
    };
    let r = trainer::run_glue(&rt, &manifest, &spec, &log)?;
    println!("tag={} task={} {}={:.4} (best {:.4})  adapter_params={}  \
              step={:.1}ms  compile={:.1}s",
             r.tag, r.task, r.metric_name, r.final_metric, r.best_metric,
             r.adapter_params, r.step_ms, rt.total_compile_seconds());
    Ok(())
}

/// The grid axes must be duplicate-free, or `cells()`'s "every cell
/// exactly once" breaks and aggregate() inflates the seed count.
fn reject_duplicates<T: PartialEq + std::fmt::Debug>(what: &str, xs: &[T])
                                                    -> Result<()> {
    for (i, x) in xs.iter().enumerate() {
        if xs[..i].contains(x) {
            bail!("--{what} lists {x:?} more than once");
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env()?;
    let cfg = preset_of(args)?;
    let log = event_log()?;
    // the singular train-style spellings are silently-dropped typos here
    for (bad, good) in [("seed", "seeds"), ("task", "tasks"), ("tag", "tags")] {
        if args.flags.contains_key(bad) {
            bail!("sweep takes --{good}, not --{bad}");
        }
    }
    let tags: Vec<String> = args.flags.get("tags")
        .context("--tags required (comma-separated artifact tags)")?
        .split(',').map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty()).collect();
    if tags.is_empty() {
        bail!("--tags is empty");
    }
    let tasks: Vec<glue::Task> = match args.flags.get("tasks") {
        None => glue::ALL_TASKS.to_vec(),
        Some(list) => list.split(',')
            .map(|p| glue::Task::from_name(p.trim())
                 .with_context(|| format!("unknown task {p:?}")))
            .collect::<Result<_>>()?,
    };
    let seeds = match args.flags.get("seeds") {
        Some(s) => parse_seeds(s)?,
        None => config::sweep_seeds(&cfg),
    };
    reject_duplicates("tags", &tags)?;
    reject_duplicates("tasks", &tasks)?;
    reject_duplicates("seeds", &seeds)?;
    let mut tcfg = config::train_config(&cfg);
    if let Some(s) = args.flags.get("steps") {
        tcfg.steps = s.parse()?;
    }
    if let Some(s) = args.flags.get("lr") {
        tcfg.lr = s.parse()?;
    }
    let jobs = parse_jobs(args)?;
    // fail fast, before any backbone pretraining: every tag must exist
    // in the manifest, and when a backbone is used all tags must share
    // one GLUE-capable encoder family (mixed or non-GLUE families would
    // silently fine-tune against the wrong family's checkpoint)
    for tag in &tags {
        manifest.get(tag)?;
    }
    let backbone = if flag_bool(args, "no-backbone")? {
        None
    } else {
        let families = tags.iter().map(|t| glue_family(t))
            .collect::<Result<Vec<_>>>()?;
        let family = families[0];
        if families.iter().any(|f| *f != family) {
            bail!("--tags mixes model families {families:?}; run one sweep \
                   per family (each family uses its own backbone checkpoint)");
        }
        Some(tables::ensure_backbone(&rt, &manifest, family, &cfg, &log)?)
    };
    let plan = SweepPlan {
        tags,
        tasks,
        seeds,
        cfg: tcfg,
        backbone,
        task_lr: BTreeMap::new(),
    };
    let n_cells = plan.cells().len();
    println!("sweep: {n_cells} cells ({} tags x {} tasks x {} seeds), jobs={jobs}",
             plan.tags.len(), plan.tasks.len(), plan.seeds.len());
    // --metrics-out: a deterministic registry (only Stable metrics land
    // in the snapshot, so the dump is byte-identical for every --jobs)
    // threaded through the sweep pool and the shared compile cache
    let metrics_out = args.flags.get("metrics-out")
        .map(std::path::PathBuf::from);
    let (mreg, sobs) = match &metrics_out {
        Some(_) => {
            let reg = MetricsRegistry::new(true);
            rt.cache().instrument(&reg);
            let sobs = SweepObs::register(&reg, jobs);
            (Some(reg), sobs)
        }
        None => (None, SweepObs::disabled()),
    };
    let t0 = Instant::now();
    let results =
        sweep::run_glue_sweep_jobs_obs(&rt, &manifest, &plan, &log, jobs, &sobs)?;
    let wall = t0.elapsed().as_secs_f64();
    if let (Some(path), Some(reg)) = (&metrics_out, &mreg) {
        export::write_snapshot(reg, path)?;
        println!("metrics snapshot: {} (+ {}.prom)",
                 path.display(), path.display());
    }
    let aggs = sweep::aggregate(&results);
    let rows: Vec<Vec<String>> = aggs.iter()
        .map(|a| vec![
            a.tag.clone(),
            a.task.clone(),
            a.metric_name.clone(),
            format!("{:.2} ± {:.2}", 100.0 * a.mean_metric,
                    100.0 * a.std_metric),
            a.n_seeds.to_string(),
            report::fmt_params(a.adapter_params),
            format!("{:.1}", a.mean_step_ms),
        ])
        .collect();
    print!("{}", report::render_table(
        &["tag", "task", "metric", "mean ± std %", "seeds", "adapter",
          "ms/step"], &rows));
    for tag in &plan.tags {
        if let Some(avg) = sweep::glue_average(&aggs, tag) {
            println!("{tag}: GLUE avg {:.2}", 100.0 * avg);
        }
    }
    println!("\n{n_cells} cells in {wall:.1}s with {jobs} worker(s)");
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env()?;
    let cfg = preset_of(args)?;
    let log = event_log()?;
    let tag = args.flags.get("tag").context("--tag required")?;
    let backbone = tables::ensure_backbone(&rt, &manifest, "dec", &cfg, &log)?;
    let tcfg = config::train_config(&cfg);
    let spec = trainer::E2eRunSpec {
        tag,
        cfg: tcfg,
        backbone: Some(&backbone),
        gen_cases: 64,
    };
    let r = trainer::run_e2e(&rt, &manifest, &spec, &log)?;
    println!("tag={tag}");
    for (k, v) in &r.extra_metrics {
        println!("  {k:10} {v:.4}");
    }
    Ok(())
}

/// `--durability` values: `buffered` | `always` | a number N (fsync
/// every N appends).
fn parse_durability(v: &str) -> Result<quantum_peft::store::Durability> {
    use quantum_peft::store::Durability;
    match v {
        "buffered" => Ok(Durability::Buffered),
        "always" => Ok(Durability::Always),
        n => {
            let every: u64 = n.parse().with_context(|| format!(
                "--durability expects buffered|always|<N>, got {v:?}"))?;
            if every == 0 {
                bail!("--durability 0 is ambiguous; use buffered or always");
            }
            Ok(Durability::EveryN(every))
        }
    }
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    use quantum_peft::serve::{self, AdmissionConfig, BenchOpts, LoadSpec,
                              ServeConfig};
    let mut opts = BenchOpts::default();
    if let Some(v) = args.flags.get("workers") {
        opts.serve.workers = pool::parse_jobs_value(v).context("--workers")?;
    }
    let mut load = LoadSpec::default();
    if let Some(v) = args.flags.get("tenants") {
        load.tenants = v.parse().context("--tenants")?;
    }
    if let Some(v) = args.flags.get("requests") {
        load.requests = v.parse().context("--requests")?;
    }
    if let Some(v) = args.flags.get("seed") {
        load.seed = v.parse().context("--seed")?;
    }
    if let Some(v) = args.flags.get("skew") {
        load.zipf_s = v.parse().context("--skew")?;
    }
    if let Some(v) = args.flags.get("qubits") {
        load.pauli.q = v.parse().context("--qubits")?;
    }
    if let Some(v) = args.flags.get("layers") {
        load.pauli.n_layers = v.parse().context("--layers")?;
    }
    if let Some(v) = args.flags.get("concurrency") {
        load.concurrency = v.parse().context("--concurrency")?;
    }
    if let Some(v) = args.flags.get("rate") {
        load.open_rate_rps = v.parse().context("--rate")?;
    }
    let mut serve_cfg = ServeConfig { workers: opts.serve.workers,
                                      ..ServeConfig::default() };
    if let Some(v) = args.flags.get("max-batch") {
        let n: usize = v.parse().context("--max-batch")?;
        if n == 0 {
            bail!("--max-batch must be >= 1: a batch of 0 requests can \
                   never dispatch");
        }
        serve_cfg.policy.max_batch = n;
    }
    if let Some(v) = args.flags.get("max-wait-us") {
        serve_cfg.policy.max_wait_us = v.parse().context("--max-wait-us")?;
    }
    serve_cfg.fifo = match args.flags.get("mode").map(|s| s.as_str()) {
        None => load.open_rate_rps <= 0.0, // open loop implies timed
        Some("fifo") => true,
        Some("timed") => false,
        Some(other) => bail!("--mode expects fifo|timed, got {other:?}"),
    };
    // --admission-config seeds the initial limits from the file AND
    // arms the hot-reload watcher on it; explicit --rate-rps/--burst/
    // --max-queue flags still override the file's initial values
    let mut burst_pinned = false;
    if let Some(p) = args.flags.get("admission-config") {
        // AdmissionReloadSpec::read records the file's pre-read
        // signature, so an edit racing session startup still reloads
        let (spec, text) = quantum_peft::serve::AdmissionReloadSpec::read(p)
            .with_context(|| format!("--admission-config {p:?}"))?;
        // only an explicit "burst" key pins the burst; a file-derived
        // default re-derives if a CLI flag changes the rate below
        let (cfg, pinned) = AdmissionConfig::from_json_spec(&text)
            .with_context(|| format!("parse --admission-config {p:?}"))?;
        serve_cfg.admission = cfg;
        burst_pinned = pinned;
        serve_cfg.admission_reload = Some(spec);
    }
    if let Some(v) = args.flags.get("rate-rps") {
        serve_cfg.admission.rate_rps = v.parse().context("--rate-rps")?;
    }
    if let Some(v) = args.flags.get("burst") {
        serve_cfg.admission.burst = v.parse().context("--burst")?;
        burst_pinned = true;
    }
    // default burst: one second's worth of the final sustained rate,
    // unless the file or a flag pinned an explicit value
    if !burst_pinned && serve_cfg.admission.rate_rps > 0.0 {
        serve_cfg.admission.burst = serve_cfg.admission.rate_rps.max(1.0);
    }
    if let Some(v) = args.flags.get("max-queue") {
        serve_cfg.admission.max_queue = v.parse().context("--max-queue")?;
    }
    if let Some(v) = args.flags.get("metrics-interval") {
        serve_cfg.metrics_interval = v.parse().context("--metrics-interval")?;
        // absent = interval snapshots off; an explicit 0 is a request
        // for snapshots that can never fire — reject it, typed
        if serve_cfg.metrics_interval == 0 {
            return Err(quantum_peft::serve::InvalidObsKnob {
                knob: "metrics_interval",
                value: 0.0,
                detail: "an explicit --metrics-interval 0 would never \
                         snapshot; omit the flag to disable interval \
                         metrics",
            }
            .into());
        }
    }
    if let Some(v) = args.flags.get("slo-p99-us") {
        serve_cfg.slo_p99_us = v.parse().context("--slo-p99-us")?;
    }
    if let Some(v) = args.flags.get("slo-error-budget") {
        serve_cfg.slo_error_budget = v.parse().context("--slo-error-budget")?;
    }
    serve_cfg.trace_dir = args.flags.get("trace-dir")
        .map(std::path::PathBuf::from);
    if let Some(v) = args.flags.get("recorder-cap") {
        serve_cfg.recorder_cap = v.parse().context("--recorder-cap")?;
    }
    opts.spool_dir = args.flags.get("spool-dir").map(std::path::PathBuf::from);
    opts.state_dir = args.flags.get("state-dir").map(std::path::PathBuf::from);
    if let Some(v) = args.flags.get("durability") {
        if opts.state_dir.is_none() {
            bail!("--durability needs --state-dir");
        }
        opts.durability = parse_durability(v)?;
    }
    if let Some(v) = args.flags.get("tenant-quota-mb") {
        let mb: f64 = v.parse().context("--tenant-quota-mb")?;
        opts.tenant_quota_bytes = (mb * (1 << 20) as f64) as usize;
    }
    if let Some(v) = args.flags.get("cache-mb") {
        let mb: f64 = v.parse().context("--cache-mb")?;
        opts.cache_bytes = (mb * (1 << 20) as f64) as usize;
    }
    let shards: usize = match args.flags.get("shards") {
        None => 1,
        Some(v) => {
            let n = v.parse().context("--shards")?;
            if n == 0 {
                bail!("--shards must be >= 1");
            }
            n
        }
    };
    // one validation choke point for every observability knob
    // (zero recorder cap, negative SLO target, zero/negative budget):
    // fail fast with the typed InvalidObsKnob before any bench work
    serve_cfg.validate_obs()?;
    // --metrics-out: registry determinism follows the bench mode, so a
    // fifo snapshot is byte-identical at any --workers / --shards
    let metrics_out = args.flags.get("metrics-out")
        .map(std::path::PathBuf::from);
    if metrics_out.is_some() {
        serve_cfg.metrics = Some(MetricsRegistry::new(serve_cfg.fifo));
    }
    opts.load = load;
    opts.serve = serve_cfg;
    let log = event_log()?;
    println!(
        "serve-bench: {} tenants (zipf s={}), q={} L={}, {} mode, \
         max-batch {} / max-wait {}µs{}",
        opts.load.tenants, opts.load.zipf_s, opts.load.pauli.q,
        opts.load.pauli.n_layers,
        if opts.serve.fifo { "fifo" } else { "timed" },
        opts.serve.policy.max_batch, opts.serve.policy.max_wait_us,
        if shards > 1 { format!(", {shards} shards") } else { String::new() });
    if shards > 1 {
        let report = serve::run_sharded_bench(&opts, shards, &log)?;
        print!("{}", report.fleet.render());
    } else {
        let (summary, _log_text) = serve::run_serve_bench(&opts, &log)?;
        print!("{}", summary.render());
    }
    if let (Some(path), Some(reg)) = (&metrics_out, &opts.serve.metrics) {
        export::write_snapshot(reg, path)?;
        println!("metrics snapshot: {} (+ {}.prom)",
                 path.display(), path.display());
    }
    Ok(())
}

/// `repro stat FILE` — render a `--metrics-out` JSONL snapshot as an
/// aligned table (the human-facing view; the JSONL and `.prom` files
/// are the machine-facing ones).
fn cmd_stat(args: &Args) -> Result<()> {
    if args.positional.len() != 1 {
        bail!("stat takes exactly one metrics JSONL file \
               (written by --metrics-out)");
    }
    let path = &args.positional[0];
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading metrics snapshot {path}"))?;
    print!("{}", export::render_stat_table(&text)
        .with_context(|| format!("rendering {path}"))?);
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let format = args.flags.get("format").map(String::as_str).unwrap_or("text");
    if format != "text" && format != "json" && format != "github" {
        bail!("--format must be text, json or github, got {format:?}");
    }
    let paths: Vec<std::path::PathBuf> = if args.positional.is_empty() {
        // Default to the whole crate — src, benches and tests (the
        // fixture corpus under tests/analysis_fixtures/ is excluded by
        // the walker) — from either the repo root or rust/.
        let roots = if std::path::Path::new("rust/src").is_dir() {
            ["rust/src", "rust/benches", "rust/tests"]
        } else if std::path::Path::new("src").is_dir() {
            ["src", "benches", "tests"]
        } else {
            bail!("no rust/src or src directory here; pass paths explicitly");
        };
        roots
            .iter()
            .map(std::path::PathBuf::from)
            .filter(|p| p.is_dir())
            .collect()
    } else {
        args.positional.iter().map(std::path::PathBuf::from).collect()
    };
    let mut report = analysis::analyze_paths(&paths)
        .with_context(|| format!("analyzing {paths:?}"))?;
    if let Some(path) = args.flags.get("write-baseline") {
        let base = analysis::baseline::Baseline::from_report(&report);
        std::fs::write(path, base.dump()).with_context(|| format!("writing {path}"))?;
        println!(
            "wrote {} accepted finding(s) to {path}",
            base.entries.len()
        );
    }
    if let Some(path) = args.flags.get("baseline") {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading baseline {path}"))?;
        let base = analysis::baseline::Baseline::parse(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        analysis::baseline::apply(&mut report, &base);
    }
    match format {
        "json" => println!("{}", analysis::render_json(&report)),
        "github" => print!("{}", analysis::render_github(&report)),
        _ => print!("{}", analysis::render_text(&report)),
    }
    if !report.clean() {
        bail!("analyze: {} unsuppressed finding(s)", report.findings.len());
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let id = args.flags.get("id").context("--id required")?.as_str();
    // analytic tables need no runtime
    match id {
        "table1" => {
            tables::print_table("Table 1 — storage (analytic, exact dims)",
                                &tables::table1());
            return Ok(());
        }
        "fig6" => {
            let sizes = [16usize, 32, 64, 128, 256, 512, 1024];
            tables::print_table("Figure 6 — unitarity error & speed vs N",
                                &tables::fig6(&sizes));
            return Ok(());
        }
        "fig5-params" => {
            tables::print_table("Figure 5 — params per adapted weight (N=768, K=4)",
                                &tables::fig5_params(768, 4));
            return Ok(());
        }
        _ => {}
    }
    let (rt, manifest) = load_env()?;
    let cfg = preset_of(args)?;
    let log = event_log()?;
    // validate worker settings up front, not after hours of table work
    let jobs = tables::sweep_jobs(&cfg)?;
    match id {
        "table2" => tables::print_table(
            "Table 2 — synthetic-GLUE, encoder backbone",
            &tables::table2(&rt, &manifest, &cfg, &log)?),
        "table3" | "table4" => {
            let (t3, t4) = tables::table3_and_4(&rt, &manifest, &cfg, &log)?;
            tables::print_table("Table 3 — E2E-substitute generation", &t3);
            tables::print_table("Table 4 — efficiency", &t4);
        }
        "table5" => tables::print_table(
            "Table 5 — wide encoder (Mistral-7B stand-in)",
            &tables::table5(&rt, &manifest, &cfg, &log)?),
        "table6" => tables::print_table(
            "Table 6 — ViT transfer (3-bit base)",
            &tables::table6(&rt, &manifest, &cfg, &log)?),
        "table7" => tables::print_table(
            "Table 7 — Lie-parameter quantization (QAT)",
            &tables::table7(&rt, &manifest, &cfg, &log)?),
        "table8" => tables::print_table(
            "Table 8 — intrinsic rank K'",
            &tables::table8(&rt, &manifest, &cfg, &log)?),
        "table9" => tables::print_table(
            "Table 9 — entanglement layers L",
            &tables::table9(&rt, &manifest, &cfg, &log)?),
        "table10" => tables::print_table(
            "Table 10 — tensor networks",
            &tables::table10(&rt, &manifest, &cfg, &log)?),
        other => bail!("unknown table id {other:?}"),
    }
    // every worker loads through the caller's shared compile cache, so
    // this figure aggregates the whole pool's compiles at any --jobs
    let n_compiles = rt.compile_log().len();
    println!("\n(total XLA compile time: {:.1}s across {n_compiles} \
              cache event(s), {jobs} worker(s) configured)",
             rt.total_compile_seconds());
    Ok(())
}
