//! Classification/regression metrics for the GLUE-substitute tables:
//! accuracy, F1, Matthews correlation (CoLA), Pearson & Spearman (STS-B).

pub fn accuracy(pred: &[u32], gold: &[u32]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(gold).filter(|(a, b)| a == b).count() as f64
        / pred.len() as f64
}

/// Binary F1 (positive class = 1).
pub fn f1(pred: &[u32], gold: &[u32]) -> f64 {
    let tp = pred.iter().zip(gold).filter(|(&p, &g)| p == 1 && g == 1).count() as f64;
    let fp = pred.iter().zip(gold).filter(|(&p, &g)| p == 1 && g == 0).count() as f64;
    let fnn = pred.iter().zip(gold).filter(|(&p, &g)| p == 0 && g == 1).count() as f64;
    if tp == 0.0 {
        return 0.0;
    }
    2.0 * tp / (2.0 * tp + fp + fnn)
}

/// Matthews correlation coefficient (binary) — the CoLA metric.
pub fn matthews(pred: &[u32], gold: &[u32]) -> f64 {
    let tp = pred.iter().zip(gold).filter(|(&p, &g)| p == 1 && g == 1).count() as f64;
    let tn = pred.iter().zip(gold).filter(|(&p, &g)| p == 0 && g == 0).count() as f64;
    let fp = pred.iter().zip(gold).filter(|(&p, &g)| p == 1 && g == 0).count() as f64;
    let fnn = pred.iter().zip(gold).filter(|(&p, &g)| p == 0 && g == 1).count() as f64;
    let den = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    if den == 0.0 {
        return 0.0;
    }
    (tp * tn - fp * fnn) / den
}

pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Average ranks with ties (fractional ranking).
fn ranks(x: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..x.len()).collect();
    idx.sort_by(|&a, &b| x[a].partial_cmp(&x[b]).unwrap());
    let mut r = vec![0.0; x.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && x[idx[j + 1]] == x[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            r[k] = avg;
        }
        i = j + 1;
    }
    r
}

pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

/// The STS-B reported metric: (Pearson + Spearman) / 2.
pub fn stsb_corr(pred: &[f64], gold: &[f64]) -> f64 {
    (pearson(pred, gold) + spearman(pred, gold)) / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;
    use crate::util::rng::Rng;

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 0, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[1, 1], &[1, 1]), 1.0);
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        assert_eq!(f1(&[1, 0, 1], &[1, 0, 1]), 1.0);
        assert_eq!(f1(&[0, 0], &[1, 1]), 0.0);
    }

    #[test]
    fn matthews_bounds_and_signs() {
        assert!((matthews(&[1, 0, 1, 0], &[1, 0, 1, 0]) - 1.0).abs() < 1e-12);
        assert!((matthews(&[0, 1, 0, 1], &[1, 0, 1, 0]) + 1.0).abs() < 1e-12);
        assert_eq!(matthews(&[1, 1, 1, 1], &[1, 0, 1, 0]), 0.0);
    }

    #[test]
    fn pearson_linear_invariance() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v - 7.0).collect();
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg: Vec<f64> = x.iter().map(|v| -2.0 * v).collect();
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_invariance() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y: Vec<f64> = x.iter().map(|v| f64::exp(*v)).collect(); // nonlinear monotone
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_handle_ties() {
        assert_eq!(ranks(&[1.0, 2.0, 2.0, 3.0]), vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn bounds_property() {
        check_property("classification metrics bounded", 25, |rng: &mut Rng| {
            let n = rng.range(4, 60);
            let p: Vec<u32> = (0..n).map(|_| rng.below(2) as u32).collect();
            let g: Vec<u32> = (0..n).map(|_| rng.below(2) as u32).collect();
            assert!((0.0..=1.0).contains(&accuracy(&p, &g)));
            assert!((0.0..=1.0).contains(&f1(&p, &g)));
            let m = matthews(&p, &g);
            assert!((-1.0..=1.0).contains(&m), "mcc {m}");
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            assert!(pearson(&x, &y).abs() <= 1.0 + 1e-9);
            assert!(spearman(&x, &y).abs() <= 1.0 + 1e-9);
        });
    }
}
