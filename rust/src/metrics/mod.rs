//! Evaluation metrics, mirrored after the paper's reporting: GLUE
//! (accuracy / Matthews / Pearson+Spearman) and E2E NLG
//! (BLEU / NIST / METEOR / ROUGE-L / CIDEr).

pub mod classification;
pub mod ngram;
