//! N-gram generation metrics for the E2E table (Table 3): BLEU, NIST,
//! METEOR (unigram-F variant), ROUGE-L, CIDEr. All corpus-level with
//! multi-reference support, operating on token-id sequences.

use std::collections::HashMap;

type Gram = Vec<u32>;

fn ngrams(seq: &[u32], n: usize) -> HashMap<Gram, usize> {
    let mut m = HashMap::new();
    if seq.len() >= n {
        for w in seq.windows(n) {
            *m.entry(w.to_vec()).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus BLEU-4 with brevity penalty and +1 smoothing on higher orders
/// (the standard NLG setup). `cases`: (hypothesis, references).
pub fn bleu(cases: &[(Vec<u32>, Vec<Vec<u32>>)], max_n: usize) -> f64 {
    let mut match_n = vec![0usize; max_n];
    let mut total_n = vec![0usize; max_n];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    for (hyp, refs) in cases {
        hyp_len += hyp.len();
        // closest reference length
        ref_len += refs.iter()
            .map(|r| r.len())
            .min_by_key(|&l| (l as i64 - hyp.len() as i64).abs())
            .unwrap_or(0);
        for n in 1..=max_n {
            let h = ngrams(hyp, n);
            let mut matches = 0usize;
            for (g, &c) in &h {
                let max_ref = refs.iter()
                    .map(|r| *ngrams(r, n).get(g).unwrap_or(&0))
                    .max().unwrap_or(0);
                matches += c.min(max_ref);
            }
            match_n[n - 1] += matches;
            total_n[n - 1] += hyp.len().saturating_sub(n - 1);
        }
    }
    let mut logsum = 0.0;
    for n in 0..max_n {
        let (num, den) = if n == 0 {
            (match_n[0] as f64, total_n[0] as f64)
        } else {
            // +1 smoothing for higher orders
            (match_n[n] as f64 + 1.0, total_n[n] as f64 + 1.0)
        };
        if den == 0.0 || num == 0.0 {
            return 0.0;
        }
        logsum += (num / den).ln() / max_n as f64;
    }
    let bp = if hyp_len >= ref_len || hyp_len == 0 {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    bp * logsum.exp()
}

/// Corpus NIST-5: information-weighted n-gram precision. Info weights are
/// computed from the reference corpus; score is the standard NIST sum with
/// its length penalty.
pub fn nist(cases: &[(Vec<u32>, Vec<Vec<u32>>)], max_n: usize) -> f64 {
    // reference-corpus n-gram counts for info weights
    let mut ref_counts: Vec<HashMap<Gram, usize>> = vec![HashMap::new(); max_n + 1];
    let mut n_ref_words = 0usize;
    for (_, refs) in cases {
        for r in refs {
            n_ref_words += r.len();
            for n in 1..=max_n {
                for (g, c) in ngrams(r, n) {
                    *ref_counts[n].entry(g).or_insert(0) += c;
                }
            }
        }
    }
    let info = |g: &Gram| -> f64 {
        let n = g.len();
        let c_full = *ref_counts[n].get(g).unwrap_or(&0) as f64;
        if c_full == 0.0 {
            return 0.0;
        }
        let c_prefix = if n == 1 {
            n_ref_words as f64
        } else {
            *ref_counts[n - 1].get(&g[..n - 1].to_vec()).unwrap_or(&1) as f64
        };
        (c_prefix / c_full).log2().max(0.0)
    };
    let mut score = 0.0;
    let mut hyp_len = 0usize;
    let mut ref_len = 0.0f64;
    for n in 1..=max_n {
        let mut num = 0.0;
        let mut den = 0usize;
        for (hyp, refs) in cases {
            if n == 1 {
                hyp_len += hyp.len();
                // per-case mean reference length, in f64 — integer
                // division truncated (refs of len 2 and 3 averaged to 2,
                // not 2.5) and skewed the length penalty below
                ref_len += refs.iter().map(|r| r.len()).sum::<usize>() as f64
                    / refs.len().max(1) as f64;
            }
            let h = ngrams(hyp, n);
            let mut ref_merged: HashMap<Gram, usize> = HashMap::new();
            for r in refs {
                for (g, c) in ngrams(r, n) {
                    let e = ref_merged.entry(g).or_insert(0);
                    *e = (*e).max(c);
                }
            }
            for (g, &c) in &h {
                let m = c.min(*ref_merged.get(g).unwrap_or(&0));
                num += m as f64 * info(g);
            }
            den += hyp.len().saturating_sub(n - 1);
        }
        if den > 0 {
            score += num / den as f64;
        }
    }
    // NIST length penalty: exp(beta * log^2(min(1, Lh/Lr)))
    let ratio = (hyp_len as f64 / ref_len.max(1.0)).min(1.0);
    let beta = -(0.5f64.ln()) / (1.5f64.ln() * 1.5f64.ln());
    let penalty = (-beta * ratio.ln() * ratio.ln()).exp();
    score * penalty
}

/// ROUGE-L: corpus-mean LCS F-measure against the best reference.
pub fn rouge_l(cases: &[(Vec<u32>, Vec<Vec<u32>>)]) -> f64 {
    fn lcs(a: &[u32], b: &[u32]) -> usize {
        let mut dp = vec![0usize; b.len() + 1];
        for &x in a {
            let mut prev = 0;
            for (j, &y) in b.iter().enumerate() {
                let cur = dp[j + 1];
                dp[j + 1] = if x == y { prev + 1 } else { dp[j + 1].max(dp[j]) };
                prev = cur;
            }
        }
        dp[b.len()]
    }
    let beta2 = 1.2f64 * 1.2;
    let mut total = 0.0;
    for (hyp, refs) in cases {
        let mut best = 0.0f64;
        for r in refs {
            if hyp.is_empty() || r.is_empty() {
                continue;
            }
            let l = lcs(hyp, r) as f64;
            let p = l / hyp.len() as f64;
            let rc = l / r.len() as f64;
            if p + rc > 0.0 {
                let f = (1.0 + beta2) * p * rc / (rc + beta2 * p);
                best = best.max(f);
            }
        }
        total += best;
    }
    total / cases.len().max(1) as f64
}

/// METEOR (exact-match variant): unigram F_{9P R/(R+9P)} with the
/// fragmentation penalty over contiguous match chunks.
pub fn meteor(cases: &[(Vec<u32>, Vec<Vec<u32>>)]) -> f64 {
    let mut total = 0.0;
    for (hyp, refs) in cases {
        let mut best = 0.0f64;
        for r in refs {
            // greedy left-to-right alignment on exact matches
            let mut used = vec![false; r.len()];
            let mut align: Vec<Option<usize>> = Vec::with_capacity(hyp.len());
            for &h in hyp {
                let mut found = None;
                for (j, &rv) in r.iter().enumerate() {
                    if !used[j] && rv == h {
                        found = Some(j);
                        break;
                    }
                }
                if let Some(j) = found {
                    used[j] = true;
                }
                align.push(found);
            }
            let m = align.iter().flatten().count() as f64;
            if m == 0.0 {
                continue;
            }
            let p = m / hyp.len() as f64;
            let rc = m / r.len() as f64;
            let fmean = 10.0 * p * rc / (rc + 9.0 * p);
            // chunks: maximal runs of consecutive aligned positions
            let mut chunks = 0usize;
            let mut prev: Option<usize> = None;
            for a in &align {
                match (a, prev) {
                    (Some(j), Some(pj)) if *j == pj + 1 => {}
                    (Some(_), _) => chunks += 1,
                    (None, _) => {}
                }
                prev = *a;
            }
            let frag = chunks as f64 / m;
            let score = fmean * (1.0 - 0.5 * frag.powi(3));
            best = best.max(score);
        }
        total += best;
    }
    total / cases.len().max(1) as f64
}

/// CIDEr: mean tf-idf cosine over n = 1..4, idf from the reference corpus,
/// scaled by 10 as in the original metric.
pub fn cider(cases: &[(Vec<u32>, Vec<Vec<u32>>)]) -> f64 {
    let max_n = 4;
    let n_docs = cases.len() as f64;
    // document frequency of each n-gram over reference sets
    let mut df: Vec<HashMap<Gram, f64>> = vec![HashMap::new(); max_n + 1];
    for (_, refs) in cases {
        for n in 1..=max_n {
            let mut seen: HashMap<Gram, bool> = HashMap::new();
            for r in refs {
                for g in ngrams(r, n).into_keys() {
                    seen.insert(g, true);
                }
            }
            for g in seen.into_keys() {
                *df[n].entry(g).or_insert(0.0) += 1.0;
            }
        }
    }
    let tfidf = |seq: &[u32], n: usize| -> HashMap<Gram, f64> {
        let counts = ngrams(seq, n);
        let total: usize = counts.values().sum();
        counts.into_iter()
            .map(|(g, c)| {
                // standard CIDEr idf: log(N / df), df >= 1
                let idf = (n_docs / df[n].get(&g).copied().unwrap_or(0.0).max(1.0))
                    .ln().max(0.0);
                (g, c as f64 / total.max(1) as f64 * idf)
            })
            .collect()
    };
    let cosine = |a: &HashMap<Gram, f64>, b: &HashMap<Gram, f64>| -> f64 {
        let dot: f64 = a.iter()
            .map(|(g, v)| v * b.get(g).copied().unwrap_or(0.0)).sum();
        let na: f64 = a.values().map(|v| v * v).sum::<f64>().sqrt();
        let nb: f64 = b.values().map(|v| v * v).sum::<f64>().sqrt();
        if na == 0.0 || nb == 0.0 { 0.0 } else { dot / (na * nb) }
    };
    let mut total = 0.0;
    for (hyp, refs) in cases {
        let mut case_score = 0.0;
        for n in 1..=max_n {
            let h = tfidf(hyp, n);
            let mut s = 0.0;
            for r in refs {
                s += cosine(&h, &tfidf(r, n));
            }
            case_score += s / refs.len().max(1) as f64 / max_n as f64;
        }
        total += case_score;
    }
    10.0 * total / cases.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;
    use crate::util::rng::Rng;

    fn perfect_case() -> Vec<(Vec<u32>, Vec<Vec<u32>>)> {
        vec![
            (vec![5, 6, 7, 8, 9, 10], vec![vec![5, 6, 7, 8, 9, 10]]),
            (vec![11, 12, 13, 14, 15], vec![vec![11, 12, 13, 14, 15],
                                            vec![11, 12, 13, 20, 21]]),
        ]
    }

    #[test]
    fn perfect_hypothesis_maxes_metrics() {
        let c = perfect_case();
        assert!(bleu(&c, 4) > 0.99, "bleu {}", bleu(&c, 4));
        assert!((rouge_l(&c) - 1.0).abs() < 1e-9);
        assert!(meteor(&c) > 0.99);
        assert!(cider(&c) > 5.0);
        assert!(nist(&c, 5) > 1.0);
    }

    #[test]
    fn disjoint_hypothesis_scores_zero() {
        let c = vec![(vec![100u32, 101, 102, 103],
                      vec![vec![5u32, 6, 7, 8, 9]])];
        assert_eq!(bleu(&c, 4), 0.0);
        assert_eq!(rouge_l(&c), 0.0);
        assert_eq!(meteor(&c), 0.0);
        assert!(cider(&c) < 1e-9);
    }

    #[test]
    fn bleu_brevity_penalty_bites() {
        let full = vec![(vec![5u32, 6, 7, 8, 9, 10, 11, 12],
                         vec![vec![5u32, 6, 7, 8, 9, 10, 11, 12]])];
        let short = vec![(vec![5u32, 6, 7, 8],
                          vec![vec![5u32, 6, 7, 8, 9, 10, 11, 12]])];
        assert!(bleu(&short, 4) < bleu(&full, 4));
    }

    #[test]
    fn metrics_bounded_property() {
        check_property("ngram metrics bounded", 15, |rng| {
            let mk = |len: usize, r: &mut Rng| -> Vec<u32> {
                (0..len).map(|_| r.range(5, 30) as u32).collect()
            };
            let cases: Vec<(Vec<u32>, Vec<Vec<u32>>)> = (0..4)
                .map(|_| {
                    let h = mk(rng.range(1, 15), rng);
                    let refs = (0..rng.range(1, 4))
                        .map(|_| mk(rng.range(1, 15), rng)).collect();
                    (h, refs)
                })
                .collect();
            let b = bleu(&cases, 4);
            assert!((0.0..=1.0).contains(&b), "bleu {b}");
            let r = rouge_l(&cases);
            assert!((0.0..=1.0).contains(&r), "rouge {r}");
            let m = meteor(&cases);
            assert!((0.0..=1.0).contains(&m), "meteor {m}");
            assert!(nist(&cases, 5) >= 0.0);
            assert!(cider(&cases) >= 0.0);
        });
    }

    #[test]
    fn nist_length_penalty_uses_fractional_mean_ref_len() {
        // One case, hyp exactly matching the short reference:
        //   hyp  = [1,2]             (len 2)
        //   refs = [1,2], [1,2,3]    (mean len 2.5)
        // Reference-corpus unigram counts: 1 -> 2, 2 -> 2, 3 -> 1 over 5
        // words, so info(1) = info(2) = log2(5/2) and the matched
        // info-weighted precision at n=1 is exactly log2(2.5). The length
        // penalty must use ratio = 2/2.5 = 0.8; the old integer division
        // truncated the mean to 2 (ratio 1.0, penalty 1.0) and overstated
        // the score.
        let c = vec![(vec![1u32, 2], vec![vec![1u32, 2], vec![1, 2, 3]])];
        let got = nist(&c, 1);
        let precision = 2.5f64.log2();
        let beta = -(0.5f64.ln()) / (1.5f64.ln() * 1.5f64.ln());
        let ratio: f64 = 2.0 / 2.5;
        let penalty = (-beta * ratio.ln() * ratio.ln()).exp();
        let want = precision * penalty;
        assert!((got - want).abs() < 1e-12, "got {got}, want {want}");
        // the truncated-mean value (penalty 1.0) is measurably different
        assert!((got - precision).abs() > 0.2,
                "length penalty did not bite: {got} vs {precision}");
    }

    #[test]
    fn rouge_prefers_longer_overlap() {
        let better = vec![(vec![5u32, 6, 7, 8, 20],
                           vec![vec![5u32, 6, 7, 8, 9]])];
        let worse = vec![(vec![5u32, 20, 21, 22, 23],
                          vec![vec![5u32, 6, 7, 8, 9]])];
        assert!(rouge_l(&better) > rouge_l(&worse));
    }
}
