//! Snapshot exporters for the metrics registry.
//!
//! One [`MetricsRegistry::snapshot`] call feeds every renderer, so the
//! Prometheus text and the JSONL file written by
//! [`write_snapshot`] describe the *same* instant. Both formats are
//! fully sorted (the snapshot is ordered by `(name, labels)` and JSON
//! objects serialize with sorted keys), so a deterministic registry's
//! exports are byte-identical at any worker count — the property
//! `tests/obs_metrics.rs` pins across workers 1/4/8.
//!
//! - **Prometheus text exposition**: `# TYPE` comment per metric name,
//!   `name{labels} value` samples; histograms render as cumulative
//!   `_bucket{le="..."}` samples over the nonzero log₂ buckets plus
//!   `+Inf` and `_count`.
//! - **JSONL**: one object per metric per line (`util::json`, sorted
//!   keys), the machine-diffable form `repro stat` reads back.
//! - [`render_stat_table`]: the `repro stat` pretty-printer — a sorted
//!   fixed-width table with nearest-rank p50/p90/p99 reconstructed
//!   from histogram buckets.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::obs::hist::Hist;
use crate::obs::metrics::{Class, MetricsRegistry, MetricValue, Reading};
use crate::util::json::{obj, Json};

fn label_str(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
        .collect();
    format!("{{{}}}", inner.join(","))
}

/// Render a snapshot in the Prometheus text exposition format.
pub fn render_prometheus(snap: &[MetricValue]) -> String {
    let mut out = String::new();
    let mut last_name = "";
    for v in snap {
        if v.name != last_name {
            let ty = match v.reading {
                Reading::Counter(_) => "counter",
                Reading::Gauge(_) => "gauge",
                Reading::Hist { .. } => "histogram",
            };
            out.push_str(&format!("# TYPE {} {}\n", v.name, ty));
            last_name = &v.name;
        }
        match &v.reading {
            Reading::Counter(n) => {
                out.push_str(&format!("{}{} {}\n", v.name, label_str(&v.labels), n));
            }
            Reading::Gauge(n) => {
                out.push_str(&format!("{}{} {}\n", v.name, label_str(&v.labels), n));
            }
            Reading::Hist { count, buckets } => {
                let mut cum = 0u64;
                for &(i, n) in buckets {
                    cum += n;
                    // bucket i holds values <= 2^(i+1) - 1
                    let le = if i >= 63 {
                        "+Inf".to_string()
                    } else {
                        ((1u64 << (i + 1)) - 1).to_string()
                    };
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        v.name,
                        hist_labels(&v.labels, &le),
                        cum
                    ));
                }
                if buckets.last().map(|&(i, _)| i < 63).unwrap_or(true) {
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        v.name,
                        hist_labels(&v.labels, "+Inf"),
                        count
                    ));
                }
                out.push_str(&format!(
                    "{}_count{} {}\n",
                    v.name,
                    label_str(&v.labels),
                    count
                ));
            }
        }
    }
    out
}

fn hist_labels(labels: &[(String, String)], le: &str) -> String {
    let mut ls: Vec<(String, String)> = labels.to_vec();
    ls.push(("le".to_string(), le.to_string()));
    label_str(&ls)
}

fn value_json(v: &MetricValue) -> Json {
    let labels = Json::Obj(
        v.labels
            .iter()
            .map(|(k, val)| (k.clone(), Json::Str(val.clone())))
            .collect(),
    );
    let class = match v.class {
        Class::Stable => "stable",
        Class::Volatile => "volatile",
    };
    match &v.reading {
        Reading::Counter(n) => obj(vec![
            ("class", class.into()),
            ("labels", labels),
            ("name", v.name.as_str().into()),
            ("type", "counter".into()),
            ("value", (*n as f64).into()),
        ]),
        Reading::Gauge(n) => obj(vec![
            ("class", class.into()),
            ("labels", labels),
            ("name", v.name.as_str().into()),
            ("type", "gauge".into()),
            ("value", (*n as f64).into()),
        ]),
        Reading::Hist { count, buckets } => obj(vec![
            ("buckets", Json::Arr(
                buckets
                    .iter()
                    .map(|&(i, n)| {
                        Json::Arr(vec![
                            (Hist::bucket_floor(i) as f64).into(),
                            (n as f64).into(),
                        ])
                    })
                    .collect(),
            )),
            ("class", class.into()),
            ("count", (*count as f64).into()),
            ("labels", labels),
            ("name", v.name.as_str().into()),
            ("type", "hist".into()),
        ]),
    }
}

/// Render a snapshot as JSONL: one sorted-key JSON object per line.
pub fn render_jsonl(snap: &[MetricValue]) -> String {
    let mut out = String::new();
    for v in snap {
        out.push_str(&value_json(v).dump());
        out.push('\n');
    }
    out
}

/// Write one atomic snapshot of `reg` to `path` (JSONL) and to
/// `path` + `.prom` (Prometheus text). Both files render the same
/// snapshot vector.
pub fn write_snapshot(reg: &MetricsRegistry, path: &Path) -> Result<()> {
    let snap = reg.snapshot();
    std::fs::write(path, render_jsonl(&snap))
        .with_context(|| format!("writing metrics snapshot {}", path.display()))?;
    let prom = PathBuf::from(format!("{}.prom", path.display()));
    std::fs::write(&prom, render_prometheus(&snap))
        .with_context(|| format!("writing metrics snapshot {}", prom.display()))?;
    Ok(())
}

/// Nearest-rank quantile over `(floor, count)` bucket pairs — the same
/// walk [`Hist::quantile`] does, reconstructed from an exported
/// snapshot line.
fn bucket_quantile(buckets: &[(u64, u64)], total: u64, p: f64) -> u64 {
    let rank = ((p / 100.0 * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for &(floor, n) in buckets {
        cum += n;
        if cum >= rank {
            return floor;
        }
    }
    buckets.last().map(|&(floor, _)| floor).unwrap_or(0)
}

/// Pretty-print a JSONL snapshot (the `--metrics-out` file) as a
/// sorted fixed-width table — the `repro stat` subcommand.
pub fn render_stat_table(jsonl: &str) -> Result<String> {
    let mut rows: Vec<[String; 5]> = Vec::new();
    for (lineno, line) in jsonl.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .with_context(|| format!("snapshot line {}", lineno + 1))?;
        let name = v.get("name")?.as_str()?.to_string();
        let labels = v
            .get("labels")?
            .as_obj()?
            .iter()
            .map(|(k, val)| {
                Ok(format!("{k}={}", val.as_str()?))
            })
            .collect::<Result<Vec<String>>>()?
            .join(",");
        let class = v.get("class")?.as_str()?.to_string();
        let ty = v.get("type")?.as_str()?.to_string();
        let value = match ty.as_str() {
            "hist" => {
                let count = v.get("count")?.as_f64()? as u64;
                let buckets = v
                    .get("buckets")?
                    .as_arr()?
                    .iter()
                    .map(|b| {
                        let pair = b.as_arr()?;
                        anyhow::ensure!(pair.len() == 2, "bucket pair");
                        Ok((pair[0].as_f64()? as u64, pair[1].as_f64()? as u64))
                    })
                    .collect::<Result<Vec<(u64, u64)>>>()?;
                if count == 0 {
                    "count=0".to_string()
                } else {
                    format!(
                        "count={} p50>={} p90>={} p99>={}",
                        count,
                        bucket_quantile(&buckets, count, 50.0),
                        bucket_quantile(&buckets, count, 90.0),
                        bucket_quantile(&buckets, count, 99.0)
                    )
                }
            }
            _ => v.get("value")?.as_f64()?.to_string(),
        };
        rows.push([name, labels, ty, class, value]);
    }
    rows.sort();
    let mut w = [4usize, 6, 4, 5, 5]; // header widths: NAME LABELS TYPE CLASS VALUE
    for r in &rows {
        for (i, cell) in r.iter().enumerate() {
            w[i] = w[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let header = ["NAME", "LABELS", "TYPE", "CLASS", "VALUE"];
    for (i, h) in header.iter().enumerate() {
        out.push_str(&format!("{:<width$}  ", h, width = w[i]));
    }
    out.push('\n');
    for r in &rows {
        for (i, cell) in r.iter().enumerate() {
            out.push_str(&format!("{:<width$}  ", cell, width = w[i]));
        }
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_registry() -> std::sync::Arc<MetricsRegistry> {
        let reg = MetricsRegistry::new(false);
        reg.counter("req_total", &[("tenant", "a")], Class::Stable).add(3);
        reg.counter("req_total", &[("tenant", "b")], Class::Stable).add(1);
        reg.gauge("depth", &[], Class::Volatile).set(-2);
        let h = reg.hist("lat_ns", &[], Class::Stable);
        h.record(1);
        h.record(9);
        h.record(9);
        reg
    }

    #[test]
    fn prometheus_text_is_sorted_and_typed() {
        let text = render_prometheus(&sample_registry().snapshot());
        let expected = "\
# TYPE depth gauge
depth -2
# TYPE lat_ns histogram
lat_ns_bucket{le=\"1\"} 1
lat_ns_bucket{le=\"15\"} 3
lat_ns_bucket{le=\"+Inf\"} 3
lat_ns_count 3
# TYPE req_total counter
req_total{tenant=\"a\"} 3
req_total{tenant=\"b\"} 1
";
        assert_eq!(text, expected);
    }

    #[test]
    fn jsonl_round_trips_through_the_stat_table() {
        let jsonl = render_jsonl(&sample_registry().snapshot());
        // every line parses as standalone JSON
        for line in jsonl.lines() {
            Json::parse(line).unwrap();
        }
        let table = render_stat_table(&jsonl).unwrap();
        assert!(table.starts_with("NAME"), "{table}");
        assert!(table.contains("req_total"), "{table}");
        assert!(table.contains("tenant=a"), "{table}");
        assert!(table.contains("count=3 p50>=8 p90>=8 p99>=8"), "{table}");
    }

    #[test]
    fn stat_table_rejects_garbage() {
        assert!(render_stat_table("not json\n").is_err());
        assert!(render_stat_table("{\"no\":\"name\"}\n").is_err());
    }

    #[test]
    fn deterministic_export_is_stable_only() {
        let reg = MetricsRegistry::new(true);
        reg.counter("a_total", &[], Class::Stable).inc();
        reg.counter("b_total", &[], Class::Volatile).inc();
        let jsonl = render_jsonl(&reg.snapshot());
        assert!(jsonl.contains("a_total"));
        assert!(!jsonl.contains("b_total"));
    }

    #[test]
    fn write_snapshot_emits_both_formats() {
        let dir = std::env::temp_dir().join(format!(
            "obs_export_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.jsonl");
        write_snapshot(&sample_registry(), &path).unwrap();
        let jsonl = std::fs::read_to_string(&path).unwrap();
        let prom =
            std::fs::read_to_string(dir.join("metrics.jsonl.prom")).unwrap();
        assert!(jsonl.contains("\"name\":\"req_total\""));
        assert!(prom.contains("# TYPE req_total counter"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
