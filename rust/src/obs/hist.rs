//! Mergeable log₂-bucket histograms.
//!
//! [`Hist`] is a fixed 64-bucket histogram over `u64` samples
//! (nanoseconds on the serving path): bucket 0 holds values `< 2` and
//! bucket `i ≥ 1` holds `[2^i, 2^(i+1))` — `v.ilog2()` is the bucket
//! index. Recording is one relaxed `fetch_add`, so all workers share
//! one histogram with no locks and no allocation; merging adds counts
//! bucket-wise, so per-tenant histograms roll up into session and fleet
//! views. Memory is O(buckets) per tenant, replacing the unbounded
//! sorted `Vec<u64>` the serving metrics used to keep per tenant.
//!
//! [`Hist::quantile`] walks the buckets to the nearest-rank sample and
//! returns that bucket's lower bound, so its error versus the exact
//! nearest-rank statistic is bounded by one bucket width (the exact
//! value lies in `[q, max(2q, 2))`); `tests/serve.rs` pins that
//! tolerance against the exact `percentile_us` oracle. A quantile of
//! an empty histogram is a typed [`EmptyHist`] error, not a fake 0:
//! a tenant with no completed requests must render as "no data", never
//! as a perfect 0µs p99.

use std::sync::atomic::{AtomicU64, Ordering};

/// Typed error for a quantile query against a histogram with no
/// samples. There is no meaningful value to report — returning 0 would
/// make an idle tenant look like it met every latency target — so
/// callers decide: summaries carry `Option` percentiles and render `-`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EmptyHist;

impl std::fmt::Display for EmptyHist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("quantile of an empty histogram")
    }
}

impl std::error::Error for EmptyHist {}

/// Number of log₂ buckets: `u64::ilog2` never exceeds 63.
pub const BUCKETS: usize = 64;

/// Fixed-size, lock-free, mergeable log₂ histogram.
#[derive(Debug)]
pub struct Hist {
    buckets: [AtomicU64; BUCKETS],
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    fn bucket(v: u64) -> usize {
        if v < 2 { 0 } else { v.ilog2() as usize }
    }

    /// The lower bound of bucket `i` — the value
    /// [`quantile`](Hist::quantile) reports for samples landing there.
    pub fn bucket_floor(i: usize) -> u64 {
        if i == 0 { 0 } else { 1u64 << i }
    }

    /// Record one sample: a single relaxed `fetch_add`.
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Bucket-count snapshot (index = log₂ bucket).
    pub fn counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Add every bucket of `other` into `self`.
    pub fn merge_from(&self, other: &Hist) {
        for (b, o) in self.buckets.iter().zip(&other.buckets) {
            let n = o.load(Ordering::Relaxed);
            if n > 0 {
                b.fetch_add(n, Ordering::Relaxed);
            }
        }
    }

    /// Nearest-rank quantile (`p` in percent): the lower bound of the
    /// bucket holding the rank-⌈p/100·n⌉ sample; [`EmptyHist`] when no
    /// sample was ever recorded.
    pub fn quantile(&self, p: f64) -> Result<u64, EmptyHist> {
        let counts = self.counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return Err(EmptyHist);
        }
        let rank = ((p / 100.0 * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Ok(Self::bucket_floor(i));
            }
        }
        Ok(Self::bucket_floor(BUCKETS - 1))
    }

    /// [`quantile`](Hist::quantile) scaled ns → µs, the unit the
    /// serving reports use.
    pub fn quantile_us(&self, p: f64) -> Result<f64, EmptyHist> {
        Ok(self.quantile(p)? as f64 / 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_log2() {
        assert_eq!(Hist::bucket(0), 0);
        assert_eq!(Hist::bucket(1), 0);
        assert_eq!(Hist::bucket(2), 1);
        assert_eq!(Hist::bucket(3), 1);
        assert_eq!(Hist::bucket(4), 2);
        assert_eq!(Hist::bucket(u64::MAX), 63);
        assert_eq!(Hist::bucket_floor(0), 0);
        assert_eq!(Hist::bucket_floor(5), 32);
    }

    #[test]
    fn quantile_walks_to_the_nearest_rank_bucket() {
        let h = Hist::new();
        // 90 samples in bucket 3 ([8,16)), 10 in bucket 10 ([1024,2048))
        for _ in 0..90 {
            h.record(9);
        }
        for _ in 0..10 {
            h.record(1500);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile(50.0), Ok(8));
        assert_eq!(h.quantile(90.0), Ok(8));
        assert_eq!(h.quantile(91.0), Ok(1024));
        assert_eq!(h.quantile(99.0), Ok(1024));
        assert_eq!(h.quantile(100.0), Ok(1024));
    }

    #[test]
    fn empty_histogram_quantile_is_a_typed_error() {
        // regression: this used to report 0 — an idle tenant read as a
        // perfect 0µs p99 instead of "no data"
        let h = Hist::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(99.0), Err(EmptyHist));
        assert_eq!(h.quantile_us(50.0), Err(EmptyHist));
        assert_eq!(EmptyHist.to_string(), "quantile of an empty histogram");
        // one sample flips every quantile to a value
        h.record(3);
        assert_eq!(h.quantile(1.0), Ok(2));
        assert_eq!(h.quantile(100.0), Ok(2));
    }

    #[test]
    fn merge_adds_bucket_counts() {
        let a = Hist::new();
        let b = Hist::new();
        a.record(5);
        b.record(5);
        b.record(4096);
        a.merge_from(&b);
        assert_eq!(a.count(), 3);
        let c = a.counts();
        assert_eq!(c[2], 2, "{c:?}");
        assert_eq!(c[12], 1, "{c:?}");
        // merging an empty histogram is a no-op
        a.merge_from(&Hist::new());
        assert_eq!(a.count(), 3);
    }

    #[test]
    fn quantile_error_is_within_one_bucket_width() {
        // exact nearest-rank value always lies in [q, max(2q, 2))
        let vals: Vec<u64> =
            (0..500).map(|i| (i * i * 37 + i) as u64 % 1_000_000).collect();
        let h = Hist::new();
        for &v in &vals {
            h.record(v);
        }
        let mut sorted = vals.clone();
        sorted.sort_unstable();
        for p in [1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let rank = ((p / 100.0 * sorted.len() as f64).ceil() as usize)
                .clamp(1, sorted.len());
            let exact = sorted[rank - 1];
            let q = h.quantile(p).unwrap();
            assert!(q <= exact, "p{p}: q={q} exact={exact}");
            assert!(exact < (2 * q).max(2), "p{p}: q={q} exact={exact}");
        }
    }
}
