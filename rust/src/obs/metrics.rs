//! Process-wide metrics registry: the observability backplane.
//!
//! [`MetricsRegistry`] is a std-only, process-wide registry of named
//! metrics. Handles ([`Counter`], [`Gauge`], [`Hist`]) are `Arc`-cheap:
//! registration takes the registry lock once per `(name, labels)` key
//! and every subsequent update is a single relaxed atomic op — the hot
//! path never locks. Registering the same key twice returns the *same*
//! handle, which is how the sharded serving tier rolls up fleet totals:
//! each shard clones one `ServeConfig` (and therefore one registry
//! `Arc`), so `serve_requests_completed_total` counts across the fleet
//! without any merge step.
//!
//! # Determinism contract
//!
//! Every metric declares a [`Class`] at registration:
//!
//! - [`Class::Stable`] — a pure function of the (seeded) input stream
//!   in fifo mode: request counts, WAL append counts/bytes, logical
//!   latency histograms. Exported snapshots of a deterministic registry
//!   contain *only* these, so the export is byte-identical at any
//!   worker count (pinned by `tests/obs_metrics.rs`).
//! - [`Class::Volatile`] — scheduling- or wall-clock-dependent: lock
//!   wait histograms, steal/park counters, cache hit ratios, fsync
//!   latencies. Present in [`MetricsRegistry::snapshot_full`] and in
//!   timed-mode exports, excluded from deterministic exports.
//!
//! A deterministic registry's [`SpanClock`] is logical, so any duration
//! self-measured through [`MetricsRegistry::clock`] reads 0 in fifo
//! mode — instrumentation code is identical in both modes and the lint
//! gate (`obs-discipline`) keeps `Instant::now` out of this module.
//!
//! Subsystems that may run without a registry hold *detached* handles
//! ([`Counter::detached`] etc.): same types, never exported, so the
//! instrumented code paths stay branch-free.

use std::collections::btree_map;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::obs::hist::Hist;
use crate::obs::span::SpanClock;
use crate::util::sync::lock_or_recover;

/// Export class of a metric: is its value a pure function of the
/// seeded input stream under fifo mode?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    /// Deterministic under fifo mode — included in every export.
    Stable,
    /// Scheduling/wall-clock dependent — excluded from deterministic
    /// exports, visible in full snapshots and timed-mode exports.
    Volatile,
}

/// Monotone counter; one relaxed `fetch_add` per update.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// A handle not attached to any registry (never exported).
    pub fn detached() -> Arc<Counter> {
        Arc::new(Counter::default())
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins signed gauge (queue depths, in-flight counts).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// A handle not attached to any registry (never exported).
    pub fn detached() -> Arc<Gauge> {
        Arc::new(Gauge::default())
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A detached histogram handle (never exported).
pub fn detached_hist() -> Arc<Hist> {
    Arc::new(Hist::new())
}

#[derive(Clone, Debug)]
enum Handle {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Hist>),
}

#[derive(Debug)]
struct Registered {
    class: Class,
    handle: Handle,
}

/// `(name, sorted labels)` — the registry key and the export sort key.
type MetricKey = (String, Vec<(String, String)>);

/// One metric's value at snapshot time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reading {
    Counter(u64),
    Gauge(i64),
    /// Total sample count plus the nonzero `(log₂ bucket index, count)`
    /// pairs, in bucket order.
    Hist { count: u64, buckets: Vec<(usize, u64)> },
}

/// One row of a registry snapshot, sorted by `(name, labels)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MetricValue {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub class: Class,
    pub reading: Reading,
}

/// The process-wide registry. See the module docs for the determinism
/// contract; see [`crate::obs`] for naming conventions.
#[derive(Debug)]
pub struct MetricsRegistry {
    deterministic: bool,
    clock: Arc<SpanClock>,
    metrics: Mutex<BTreeMap<MetricKey, Registered>>,
}

impl MetricsRegistry {
    /// A deterministic registry carries a logical [`SpanClock`] (reads
    /// 0 unless advanced) and exports only [`Class::Stable`] metrics.
    pub fn new(deterministic: bool) -> Arc<MetricsRegistry> {
        Arc::new(MetricsRegistry {
            deterministic,
            clock: Arc::new(SpanClock::new(deterministic)),
            metrics: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn is_deterministic(&self) -> bool {
        self.deterministic
    }

    /// The clock instrumentation sites measure durations on: logical
    /// (always 0 unless advanced) for a deterministic registry, wall
    /// otherwise. Duration metrics recorded through it are `Volatile`.
    pub fn clock(&self) -> Arc<SpanClock> {
        self.clock.clone()
    }

    fn key(name: &str, labels: &[(&str, &str)]) -> MetricKey {
        let mut ls: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        ls.sort();
        (name.to_string(), ls)
    }

    /// Get-or-create a counter. Re-registering an existing key returns
    /// the same handle; a kind clash (the key already names a gauge or
    /// histogram) returns a detached handle — the `metrics-discipline`
    /// lint flags the duplicate registration site statically.
    pub fn counter(
        &self,
        name: &'static str,
        labels: &[(&'static str, &str)],
        class: Class,
    ) -> Arc<Counter> {
        let mut m = lock_or_recover(&self.metrics);
        match m.entry(Self::key(name, labels)) {
            btree_map::Entry::Occupied(e) => match &e.get().handle {
                Handle::Counter(c) => c.clone(),
                _ => Counter::detached(),
            },
            btree_map::Entry::Vacant(v) => {
                let c = Counter::detached();
                v.insert(Registered { class, handle: Handle::Counter(c.clone()) });
                c
            }
        }
    }

    /// Get-or-create a gauge (same semantics as
    /// [`counter`](MetricsRegistry::counter)).
    pub fn gauge(
        &self,
        name: &'static str,
        labels: &[(&'static str, &str)],
        class: Class,
    ) -> Arc<Gauge> {
        let mut m = lock_or_recover(&self.metrics);
        match m.entry(Self::key(name, labels)) {
            btree_map::Entry::Occupied(e) => match &e.get().handle {
                Handle::Gauge(g) => g.clone(),
                _ => Gauge::detached(),
            },
            btree_map::Entry::Vacant(v) => {
                let g = Gauge::detached();
                v.insert(Registered { class, handle: Handle::Gauge(g.clone()) });
                g
            }
        }
    }

    /// Get-or-create a log₂-bucket histogram (same semantics as
    /// [`counter`](MetricsRegistry::counter)).
    pub fn hist(
        &self,
        name: &'static str,
        labels: &[(&'static str, &str)],
        class: Class,
    ) -> Arc<Hist> {
        let mut m = lock_or_recover(&self.metrics);
        match m.entry(Self::key(name, labels)) {
            btree_map::Entry::Occupied(e) => match &e.get().handle {
                Handle::Hist(h) => h.clone(),
                _ => detached_hist(),
            },
            btree_map::Entry::Vacant(v) => {
                let h = detached_hist();
                v.insert(Registered { class, handle: Handle::Hist(h.clone()) });
                h
            }
        }
    }

    /// The export view: every metric for a timed registry, only
    /// [`Class::Stable`] metrics for a deterministic one — this filter
    /// is what makes fifo exports byte-identical at any worker count.
    pub fn snapshot(&self) -> Vec<MetricValue> {
        self.snap(self.deterministic)
    }

    /// Every registered metric regardless of class (debugging, the
    /// timed-mode smoke tests).
    pub fn snapshot_full(&self) -> Vec<MetricValue> {
        self.snap(false)
    }

    fn snap(&self, stable_only: bool) -> Vec<MetricValue> {
        let m = lock_or_recover(&self.metrics);
        m.iter()
            .filter(|(_, r)| !stable_only || r.class == Class::Stable)
            .map(|((name, labels), r)| MetricValue {
                name: name.clone(),
                labels: labels.clone(),
                class: r.class,
                reading: match &r.handle {
                    Handle::Counter(c) => Reading::Counter(c.get()),
                    Handle::Gauge(g) => Reading::Gauge(g.get()),
                    Handle::Hist(h) => {
                        let counts = h.counts();
                        Reading::Hist {
                            count: counts.iter().sum(),
                            buckets: counts
                                .iter()
                                .enumerate()
                                .filter(|(_, &n)| n > 0)
                                .map(|(i, &n)| (i, n))
                                .collect(),
                        }
                    }
                },
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reregistration_returns_the_same_handle() {
        let reg = MetricsRegistry::new(true);
        let a = reg.counter("x_total", &[("site", "a")], Class::Stable);
        let b = reg.counter("x_total", &[("site", "a")], Class::Stable);
        a.inc();
        b.add(2);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.get(), 3);
        // a different label set is a different metric
        let c = reg.counter("x_total", &[("site", "b")], Class::Stable);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn label_order_does_not_split_metrics() {
        let reg = MetricsRegistry::new(true);
        let a = reg.gauge("g", &[("a", "1"), ("b", "2")], Class::Stable);
        let b = reg.gauge("g", &[("b", "2"), ("a", "1")], Class::Stable);
        a.set(7);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(b.get(), 7);
    }

    #[test]
    fn deterministic_snapshot_excludes_volatile_metrics() {
        let reg = MetricsRegistry::new(true);
        reg.counter("stable_total", &[], Class::Stable).inc();
        reg.counter("volatile_total", &[], Class::Volatile).inc();
        reg.hist("wait_ns", &[], Class::Volatile).record(5);
        let names: Vec<&str> =
            reg.snapshot().iter().map(|v| v.name.as_str()).collect();
        assert_eq!(names, ["stable_total"]);
        let full: Vec<&str> =
            reg.snapshot_full().iter().map(|v| v.name.as_str()).collect();
        assert_eq!(full, ["stable_total", "volatile_total", "wait_ns"]);
    }

    #[test]
    fn timed_registry_exports_everything() {
        let reg = MetricsRegistry::new(false);
        reg.counter("volatile_total", &[], Class::Volatile).inc();
        assert_eq!(reg.snapshot().len(), 1);
        assert!(!reg.clock().is_logical());
    }

    #[test]
    fn kind_clash_yields_a_detached_handle() {
        let reg = MetricsRegistry::new(false);
        let c = reg.counter("mixed", &[], Class::Stable);
        c.inc();
        let g = reg.gauge("mixed", &[], Class::Stable);
        g.set(99);
        // the registered counter is untouched by the detached gauge
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].reading, Reading::Counter(1));
    }

    #[test]
    fn hist_reading_carries_nonzero_buckets_only() {
        let reg = MetricsRegistry::new(false);
        let h = reg.hist("lat_ns", &[], Class::Stable);
        h.record(1);
        h.record(9);
        h.record(9);
        let snap = reg.snapshot();
        assert_eq!(
            snap[0].reading,
            Reading::Hist { count: 3, buckets: vec![(0, 1), (3, 2)] }
        );
    }

    #[test]
    fn deterministic_clock_is_logical() {
        let reg = MetricsRegistry::new(true);
        assert!(reg.is_deterministic());
        let clock = reg.clock();
        assert!(clock.is_logical());
        assert_eq!(clock.now_ns(), 0);
    }
}
