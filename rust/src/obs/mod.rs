//! Observability: trace spans, mergeable histograms, flight recorders,
//! SLO budgets — and the process-wide metrics backplane.
//!
//! Two layers live here. The *serving telemetry* layer (PR 8) rides
//! inside each request: [`span`] ([`SpanClock`], [`TraceCtx`]),
//! [`hist`] ([`Hist`]), [`recorder`] ([`FlightRecorder`]), [`slo`]
//! ([`SloPolicy`]). The *metrics backplane* ([`metrics`], [`export`])
//! spans the whole process: every subsystem — `util::sync` locks,
//! the `util::pool` workers, the `runtime` compile cache, the `store`
//! WAL, the serving tier — registers named handles on one
//! [`MetricsRegistry`] and exports a single atomic snapshot as
//! Prometheus text or JSONL (`--metrics-out`, `repro stat`).
//!
//! # Metrics walk-through
//!
//! ```
//! use quantum_peft::obs::metrics::{Class, MetricsRegistry};
//! use quantum_peft::obs::export;
//!
//! // One registry per process (or per fleet: shards share one Arc).
//! let reg = MetricsRegistry::new(/* deterministic = */ true);
//!
//! // Register once, update lock-free forever after.
//! let served = reg.counter("demo_requests_total", &[("tenant", "a")],
//!                          Class::Stable);
//! let lat = reg.hist("demo_latency_ns", &[], Class::Stable);
//! served.inc();
//! lat.record(4096);
//!
//! // One atomic snapshot feeds every exporter.
//! let snap = reg.snapshot();
//! let text = export::render_prometheus(&snap);
//! assert!(text.contains("demo_requests_total{tenant=\"a\"} 1"));
//! let jsonl = export::render_jsonl(&snap);
//! assert!(jsonl.lines().count() == 2);
//! ```
//!
//! # Naming conventions (enforced by the `metrics-discipline` lint)
//!
//! - Names are `snake_case` **string literals**, registered at exactly
//!   one call site crate-wide; variance goes in labels, never in
//!   computed names (`format!` in a name is a lint finding).
//! - `<subsystem>_` prefix: `lock_`, `pool_`, `exe_cache_`, `wal_`,
//!   `serve_`, `sweep_`.
//! - Counters end in `_total`; byte counters in `_bytes_total`.
//! - Durations are nanosecond histograms ending in `_ns`, recorded
//!   from a [`SpanClock`] (never `Instant::now` — the `obs-discipline`
//!   lint keeps the wall clock out of `obs/` and `serve/`).
//! - Gauges are bare nouns (`pool_queue_depth`).
//!
//! # Determinism contract
//!
//! Every metric declares [`Class::Stable`](metrics::Class) (a pure
//! function of the seeded input stream under fifo mode: request
//! counts, WAL bytes, logical-latency histograms) or
//! [`Class::Volatile`](metrics::Class) (scheduling/wall-clock
//! dependent: lock waits, steals, cache hits, fsync latency).
//! Deterministic registries export only `Stable` metrics and carry a
//! logical [`SpanClock`], so fifo-mode exports are byte-identical at
//! any worker count — `tests/obs_metrics.rs` pins this across workers
//! 1/4/8 for both the sweep and the sharded serving tier.

pub mod export;
pub mod hist;
pub mod metrics;
pub mod recorder;
pub mod slo;
pub mod span;

pub use hist::{EmptyHist, Hist};
pub use metrics::{Class, Counter, Gauge, MetricValue, MetricsRegistry, Reading};
pub use recorder::{FlightRecorder, TraceRecord};
pub use slo::{SloPolicy, TenantSloStatus};
pub use span::{Span, SpanClock, TraceCtx, PHASES};
