//! Serving telemetry: trace spans, mergeable histograms, per-worker
//! flight recorders, and per-tenant SLO error budgets.
//!
//! This is the observability substrate the serving tier threads through
//! every request (admission → coalesce → queue → cache lookup →
//! materialize → apply → respond):
//!
//! - [`span`]: the [`SpanClock`] — the **only** module on the serving
//!   path allowed to read the wall clock (enforced by the
//!   `obs-discipline` lint in [`crate::analysis`]) — plus the
//!   per-request [`TraceCtx`] (seeded-stream-derived trace ids,
//!   per-phase durations via the [`Span`] guard);
//! - [`hist`]: [`Hist`], a fixed 64-bucket log₂ histogram with
//!   lock-free atomic increments and bucket-wise merging — O(buckets)
//!   memory per tenant instead of O(requests), cheap mid-run quantiles;
//! - [`recorder`]: [`FlightRecorder`], a fixed-capacity per-worker ring
//!   of the last N completed [`TraceRecord`]s, dumped as `serve_trace`
//!   EventLog lines (and optional `--trace-dir` JSONL) on demand, at
//!   session end, and by `kill_shard` for post-mortems;
//! - [`slo`]: [`SloPolicy`] / [`TenantSloStatus`] — per-tenant latency
//!   SLO targets with error-budget burn accounting, rendered as the
//!   serve-bench compliance section.
//!
//! Everything here is std-only and deterministic under fifo mode: the
//! span clock is logical, trace ids are a pure function of the seeded
//! request stream, and histograms/SLO counters are order-independent
//! atomics — so `serve_interval`, `serve_trace` and `serve_slo` lines
//! stay byte-identical at any worker count.

pub mod hist;
pub mod recorder;
pub mod slo;
pub mod span;

pub use hist::{EmptyHist, Hist};
pub use recorder::{FlightRecorder, TraceRecord};
pub use slo::{SloPolicy, TenantSloStatus};
pub use span::{Span, SpanClock, TraceCtx, PHASES};
