//! Per-worker flight recorder: a fixed-capacity ring buffer of the
//! last N completed trace spans.
//!
//! Each serve worker owns one [`FlightRecorder`] (so pushes never
//! contend across workers); the retained [`TraceRecord`]s are merged,
//! sorted by `(trace_id, meta)` and dumped as `serve_trace` EventLog
//! lines — plus optional `--trace-dir` JSONL files — on demand
//! (`ServerHandle::dump_traces`), at session end, and therefore by
//! `kill_shard` (stopping a shard ends its serve session, whose
//! session-end dump runs) for post-mortems.
//!
//! In fifo mode every record field is a pure function of the seeded
//! submission stream (logical clock, deterministic batch formation), so
//! the *merged* dump is byte-identical at any worker count — provided
//! the per-worker capacity retains every span (set the recorder cap ≥
//! the request count; beyond that, which spans age out depends on how
//! batches landed on workers).

use super::span::TraceCtx;

/// One completed (or failed) request's trace, as retained by the
/// recorder.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    pub tenant: String,
    pub meta: u64,
    /// Size of the batch this request rode in.
    pub batch: usize,
    /// False when the request failed (its batch's tenant resolution or
    /// apply errored).
    pub ok: bool,
    /// [`SpanClock`](super::span::SpanClock) time at completion.
    pub completed_ns: u64,
    pub ctx: TraceCtx,
}

impl TraceRecord {
    pub fn latency_ns(&self) -> u64 {
        self.completed_ns.saturating_sub(self.ctx.submitted_ns)
    }
}

/// Fixed-capacity ring of the last N completed spans. Oldest records
/// are overwritten once `cap` is reached; `total` keeps counting.
#[derive(Debug)]
pub struct FlightRecorder {
    cap: usize,
    buf: Vec<TraceRecord>,
    /// Next write position once the ring is full.
    next: usize,
    total: u64,
}

impl FlightRecorder {
    /// A recorder retaining the last `cap` records (minimum 1).
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder { cap: cap.max(1), buf: Vec::new(), next: 0, total: 0 }
    }

    pub fn push(&mut self, rec: TraceRecord) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(rec);
        } else {
            self.buf[self.next] = rec;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Retained records, oldest first.
    pub fn records(&self) -> Vec<TraceRecord> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.cap);
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
            out
        }
    }

    /// Spans pushed over the recorder's lifetime (≥ retained count).
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(meta: u64) -> TraceRecord {
        TraceRecord {
            tenant: "t".to_string(),
            meta,
            batch: 1,
            ok: true,
            completed_ns: meta * 10,
            ctx: TraceCtx::new("t", meta, 0),
        }
    }

    #[test]
    fn under_capacity_keeps_everything_in_order() {
        let mut r = FlightRecorder::new(8);
        for m in 0..5 {
            r.push(rec(m));
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.total(), 5);
        let metas: Vec<u64> = r.records().iter().map(|x| x.meta).collect();
        assert_eq!(metas, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn wraparound_retains_the_last_cap_records_oldest_first() {
        let mut r = FlightRecorder::new(4);
        for m in 0..10 {
            r.push(rec(m));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total(), 10);
        let metas: Vec<u64> = r.records().iter().map(|x| x.meta).collect();
        assert_eq!(metas, vec![6, 7, 8, 9]);
        // one more push evicts exactly the oldest
        r.push(rec(10));
        let metas: Vec<u64> = r.records().iter().map(|x| x.meta).collect();
        assert_eq!(metas, vec![7, 8, 9, 10]);
    }

    #[test]
    fn exact_fill_boundary_is_in_order() {
        let mut r = FlightRecorder::new(3);
        for m in 0..3 {
            r.push(rec(m));
        }
        let metas: Vec<u64> = r.records().iter().map(|x| x.meta).collect();
        assert_eq!(metas, vec![0, 1, 2]);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut r = FlightRecorder::new(0);
        r.push(rec(1));
        r.push(rec(2));
        assert_eq!(r.len(), 1);
        assert_eq!(r.records()[0].meta, 2);
        assert_eq!(r.total(), 2);
    }

    #[test]
    fn latency_is_completed_minus_submitted() {
        let mut t = rec(3);
        t.ctx.submitted_ns = 25;
        assert_eq!(t.latency_ns(), 5);
        t.ctx.submitted_ns = 40; // clock never goes backwards, but saturate
        assert_eq!(t.latency_ns(), 0);
    }
}
