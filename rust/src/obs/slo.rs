//! Per-tenant latency SLOs with error budgets.
//!
//! An SLO here is "request latency ≤ target µs" (the serve-bench flags
//! `--slo-p99-us` / `--slo-error-budget`); the error budget is the
//! fraction of a tenant's requests allowed to violate the target.
//! Violations are counted **exactly at record time** against each
//! request's latency — never reconstructed from histogram buckets, so
//! bucket quantization cannot hide a breach. Budget burn is
//! `violations / (budget · requests)`: 1.0 means the budget is exactly
//! exhausted, above 1.0 the tenant is out of compliance.
//!
//! Note on fifo mode: latencies are logical (the span clock only moves
//! when the driver advances it), so a closed-loop fifo run reports zero
//! burn deterministically — the SLO machinery is exercised end-to-end
//! while the byte-identity contract holds. Timed mode burns real
//! wall-clock budget.

/// The serving SLO policy: a per-request latency target plus the
/// allowed violating fraction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloPolicy {
    /// Per-request latency target in µs (the p99 objective);
    /// 0 = SLO tracking off.
    pub p99_target_us: f64,
    /// Allowed violating fraction of requests (0.01 = 1%).
    pub error_budget: f64,
}

impl Default for SloPolicy {
    fn default() -> SloPolicy {
        SloPolicy { p99_target_us: 0.0, error_budget: 0.01 }
    }
}

impl SloPolicy {
    pub fn enabled(&self) -> bool {
        self.p99_target_us > 0.0
    }

    /// Does this latency violate the target?
    pub fn violated(&self, latency_ns: u64) -> bool {
        self.enabled() && latency_ns as f64 / 1000.0 > self.p99_target_us
    }
}

/// One tenant's SLO accounting over a session (or a fleet rollup).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TenantSloStatus {
    pub tenant: String,
    pub requests: u64,
    pub violations: u64,
}

impl TenantSloStatus {
    /// Error-budget burn: violations over the budgeted allowance.
    /// ≥ 1.0 means the budget is exhausted.
    pub fn burn(&self, budget: f64) -> f64 {
        let allowance = budget * self.requests as f64;
        if allowance <= 0.0 {
            if self.violations == 0 { 0.0 } else { f64::INFINITY }
        } else {
            self.violations as f64 / allowance
        }
    }

    pub fn compliant(&self, budget: f64) -> bool {
        self.violations as f64 <= budget * self.requests as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_policy_never_violates() {
        let p = SloPolicy::default();
        assert!(!p.enabled());
        assert!(!p.violated(u64::MAX));
    }

    #[test]
    fn violation_is_a_strict_microsecond_comparison() {
        let p = SloPolicy { p99_target_us: 100.0, error_budget: 0.01 };
        assert!(p.enabled());
        assert!(!p.violated(100_000)); // exactly at target: ok
        assert!(p.violated(100_001));
        assert!(!p.violated(0));
    }

    #[test]
    fn burn_and_compliance_track_the_budget() {
        let t = TenantSloStatus {
            tenant: "a".into(), requests: 1000, violations: 5,
        };
        // budget 1%: allowance 10, burn 0.5, compliant
        assert!((t.burn(0.01) - 0.5).abs() < 1e-12);
        assert!(t.compliant(0.01));
        // budget 0.1%: allowance 1, burn 5.0, breached
        assert!((t.burn(0.001) - 5.0).abs() < 1e-12);
        assert!(!t.compliant(0.001));
    }

    #[test]
    fn zero_allowance_edge_cases() {
        let clean = TenantSloStatus {
            tenant: "a".into(), requests: 0, violations: 0,
        };
        assert_eq!(clean.burn(0.01), 0.0);
        assert!(clean.compliant(0.01));
        let dirty = TenantSloStatus {
            tenant: "b".into(), requests: 10, violations: 1,
        };
        assert!(dirty.burn(0.0).is_infinite());
        assert!(!dirty.compliant(0.0));
    }
}
