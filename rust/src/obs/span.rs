//! The serving span clock and per-request trace context.
//!
//! [`SpanClock`] is the **only** module on the serving path allowed to
//! read the wall clock (the `obs-discipline` lint enforces this): in
//! timed mode it wraps a session-start [`Instant`]; in fifo mode it is
//! a logical nanosecond counter the driver advances explicitly
//! ([`SpanClock::advance_ns`]), so every timestamp derived from it —
//! and therefore every latency, span duration, and interval snapshot —
//! is a pure function of the submission sequence, preserving the fifo
//! byte-determinism contract.
//!
//! [`TraceCtx`] rides inside each `PendingRequest`: a trace id derived
//! from the seeded request stream (FNV-1a over the tenant name and the
//! request meta, so fifo trace ids are byte-reproducible), submit and
//! dispatch timestamps, and one duration slot per phase of the span
//! taxonomy:
//!
//! | phase | covers |
//! |---|---|
//! | `admission` | token-bucket + queue-cap check at submit |
//! | `coalesce` | batcher buffering + formed-batch queue wait |
//! | `queue` | submit → dispatch, i.e. `dispatched_ns - submitted_ns` |
//! | `cache_lookup` | registry adapter-snapshot resolution |
//! | `materialize` | mat-cache get-or-build of the dense `Q_P` |
//! | `apply` | the structured/dense apply over the batch rows |
//! | `respond` | response fill + metrics accounting |
//!
//! Phase durations measured inside a batch are batch-level: every
//! request in a batch reports the batch's shared `cache_lookup` /
//! `materialize` / `apply` / `respond` spans. [`Span`] is the guard:
//! it reads the clock on entry and adds the elapsed nanoseconds into
//! its slot on drop.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::fnv;

/// Phase names, in span-taxonomy order (indexes into
/// [`TraceCtx::phase_ns`]).
pub const PHASES: [&str; 7] = [
    "admission", "coalesce", "queue", "cache_lookup", "materialize",
    "apply", "respond",
];

pub const PH_ADMISSION: usize = 0;
pub const PH_COALESCE: usize = 1;
pub const PH_QUEUE: usize = 2;
pub const PH_CACHE_LOOKUP: usize = 3;
pub const PH_MATERIALIZE: usize = 4;
pub const PH_APPLY: usize = 5;
pub const PH_RESPOND: usize = 6;

/// The serving clock: wall in timed mode, logical in fifo mode.
#[derive(Debug)]
pub enum SpanClock {
    /// Timed mode: nanoseconds since session start.
    Wall(Instant),
    /// Fifo mode: a logical nanosecond counter the driver advances.
    Logical(AtomicU64),
}

impl SpanClock {
    /// Logical for fifo sessions, wall otherwise.
    pub fn new(fifo: bool) -> SpanClock {
        if fifo {
            SpanClock::Logical(AtomicU64::new(0))
        } else {
            SpanClock::Wall(Instant::now())
        }
    }

    /// Now, in nanoseconds since session start. The wall arm's
    /// `u128 → u64` narrowing is checked (saturating): 2^64 ns is ~584
    /// years of session.
    pub fn now_ns(&self) -> u64 {
        match self {
            SpanClock::Wall(t0) => {
                u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX)
            }
            SpanClock::Logical(ns) => ns.load(Ordering::Acquire),
        }
    }

    /// Seconds since session start.
    pub fn elapsed_s(&self) -> f64 {
        self.now_ns() as f64 / 1e9
    }

    /// Advance the logical clock; no-op on the wall arm, which advances
    /// itself.
    pub fn advance_ns(&self, dt: u64) {
        if let SpanClock::Logical(ns) = self {
            ns.fetch_add(dt, Ordering::AcqRel);
        }
    }

    pub fn is_logical(&self) -> bool {
        matches!(self, SpanClock::Logical(_))
    }
}

/// Per-request trace context, derived from the seeded request stream.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceCtx {
    /// FNV-1a over (tenant bytes, meta le-bytes): a pure function of
    /// the seeded stream, so fifo trace ids are byte-reproducible.
    pub trace_id: u64,
    /// [`SpanClock::now_ns`] at submit.
    pub submitted_ns: u64,
    /// [`SpanClock::now_ns`] when a worker picked up the batch.
    pub dispatched_ns: u64,
    /// Per-phase durations, indexed by the `PH_*` constants.
    pub phase_ns: [u64; PHASES.len()],
}

impl TraceCtx {
    pub fn new(tenant: &str, meta: u64, submitted_ns: u64) -> TraceCtx {
        TraceCtx {
            trace_id: fnv::update(fnv::hash(tenant.as_bytes()),
                                  &meta.to_le_bytes()),
            submitted_ns,
            dispatched_ns: submitted_ns,
            phase_ns: [0; PHASES.len()],
        }
    }

    /// `trace_id` as the fixed-width hex string the EventLog carries
    /// (u64 ids don't round-trip through JSON's f64 numbers).
    pub fn trace_hex(&self) -> String {
        format!("{:016x}", self.trace_id)
    }
}

/// Span guard: measures from construction to drop on `clock`, adding
/// the elapsed nanoseconds into `slot`.
pub struct Span<'c, 's> {
    clock: &'c SpanClock,
    start: u64,
    slot: &'s mut u64,
}

impl<'c, 's> Span<'c, 's> {
    pub fn enter(clock: &'c SpanClock, slot: &'s mut u64) -> Span<'c, 's> {
        Span { start: clock.now_ns(), clock, slot }
    }
}

impl Drop for Span<'_, '_> {
    fn drop(&mut self) {
        *self.slot += self.clock.now_ns().saturating_sub(self.start);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logical_clock_only_moves_when_advanced() {
        let c = SpanClock::new(true);
        assert!(c.is_logical());
        assert_eq!(c.now_ns(), 0);
        c.advance_ns(1500);
        assert_eq!(c.now_ns(), 1500);
        assert!((c.elapsed_s() - 1.5e-6).abs() < 1e-12);
    }

    #[test]
    fn wall_clock_advances_by_itself() {
        let c = SpanClock::new(false);
        assert!(!c.is_logical());
        let a = c.now_ns();
        std::thread::sleep(std::time::Duration::from_millis(1));
        assert!(c.now_ns() > a);
        // advance is a no-op on the wall arm
        c.advance_ns(u64::MAX / 2);
        assert!(c.now_ns() < u64::MAX / 4);
    }

    #[test]
    fn trace_ids_are_a_pure_function_of_tenant_and_meta() {
        let a = TraceCtx::new("tenant0000", 7, 0);
        let b = TraceCtx::new("tenant0000", 7, 123);
        assert_eq!(a.trace_id, b.trace_id);
        assert_ne!(a.trace_id, TraceCtx::new("tenant0000", 8, 0).trace_id);
        assert_ne!(a.trace_id, TraceCtx::new("tenant0001", 7, 0).trace_id);
        assert_eq!(a.trace_hex().len(), 16);
    }

    #[test]
    fn span_guard_accumulates_into_its_slot() {
        let c = SpanClock::new(true);
        let mut slot = 0u64;
        {
            let _sp = Span::enter(&c, &mut slot);
            c.advance_ns(40);
        }
        assert_eq!(slot, 40);
        {
            let _sp = Span::enter(&c, &mut slot);
            c.advance_ns(2);
        }
        assert_eq!(slot, 42, "spans accumulate, not overwrite");
    }
}
