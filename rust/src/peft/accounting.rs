//! Analytic trainable-parameter / storage accounting (Table 1, Table 5,
//! Table 4 memory column) — mirror of python/compile/quantum/accounting.py.

use crate::quantum::{pauli, qsd};

pub fn lora_params(n: usize, m: usize, k: usize) -> usize {
    (n + m) * k
}

pub fn adalora_params(n: usize, m: usize, k: usize) -> usize {
    (n + m) * k + k
}

pub fn loha_params(n: usize, m: usize, k: usize) -> usize {
    2 * (n + m) * k
}

pub fn lokr_params(n: usize, m: usize, k: usize, f: usize) -> usize {
    f * f + (n / f + m / f) * k
}

fn lower_params_count(n: usize, k: usize) -> usize {
    crate::quantum::mappings::lower_params_count(n, k)
}

/// Pauli Q_P on both sides + K-dim diagonal; QSD for non-power-of-two dims.
pub fn qpeft_pauli_params(n: usize, m: usize, k: usize, l: usize) -> usize {
    let side = |d: usize| -> usize {
        if d >= 2 && d.is_power_of_two() {
            pauli::num_params(d, l)
        } else {
            qsd::num_params(d, l)
        }
    };
    side(n) + side(m) + k
}

/// Taylor mapping both sides + diagonal (2NK - K^2 in the paper's count).
pub fn qpeft_taylor_params(n: usize, m: usize, k: usize, k_prime: usize) -> usize {
    lower_params_count(n, k_prime) + lower_params_count(m, k_prime) + k
}

/// One Table-1 model geometry: PEFT on q/v projections.
pub struct ModelGeom {
    pub name: &'static str,
    pub dim: usize,
    pub sites: usize,
}

pub const TABLE1_MODELS: [ModelGeom; 3] = [
    ModelGeom { name: "DeBERTaV3-base", dim: 768, sites: 24 },
    ModelGeom { name: "Llama-3.1-405B", dim: 16384, sites: 252 },
    ModelGeom { name: "GPT-4 (assumed 120x24576)", dim: 24576, sites: 240 },
];

pub struct Table1Row {
    pub model: &'static str,
    pub rank: usize,
    pub lora_params: usize,
    pub qpeft_params: usize,
}

impl Table1Row {
    pub fn lora_bytes(&self) -> usize {
        self.lora_params * 4
    }
    pub fn qpeft_bytes(&self) -> usize {
        self.qpeft_params * 4
    }
}

pub fn table1() -> Vec<Table1Row> {
    let mut rows = Vec::new();
    for geom in &TABLE1_MODELS {
        for &k in &[1usize, 16, 256] {
            rows.push(Table1Row {
                model: geom.name,
                rank: k,
                lora_params: geom.sites * lora_params(geom.dim, geom.dim, k),
                qpeft_params: geom.sites
                    * qpeft_pauli_params(geom.dim, geom.dim, k, 1),
            });
        }
    }
    rows
}

/// Optimizer-state bytes for AdamW fine-tuning: params + grads + m + v,
/// 4 bytes each — the "Memory Ratio" column of Tables 2/4 is the ratio of
/// this quantity across methods.
pub fn adamw_state_bytes(trainable_params: usize) -> usize {
    trainable_params * 4 * 4
}

/// Lie-parameter storage under n-bit group quantization: n + 32/g bits
/// per parameter (fp16 scale + zero per group) — §4.2 "Quantization".
pub fn quantized_bits_per_param(n_bits: f64, group: usize) -> f64 {
    n_bits + 32.0 / group as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lora_matches_paper() {
        let rows = table1();
        let deberta_k1 = rows.iter()
            .find(|r| r.model.starts_with("DeBERTa") && r.rank == 1).unwrap();
        assert_eq!(deberta_k1.lora_params, 36_864);         // paper: 36.9K
        let deberta_k16 = rows.iter()
            .find(|r| r.model.starts_with("DeBERTa") && r.rank == 16).unwrap();
        assert_eq!(deberta_k16.lora_params, 589_824);       // paper: 589.8K
        let llama_k1 = rows.iter()
            .find(|r| r.model.starts_with("Llama") && r.rank == 1).unwrap();
        assert_eq!(llama_k1.lora_params, 8_257_536);        // paper: 8.26M
    }

    #[test]
    fn qpeft_always_orders_of_magnitude_smaller_at_high_rank() {
        for r in table1() {
            if r.rank >= 16 {
                assert!(r.qpeft_params * 10 < r.lora_params,
                        "{} K={}", r.model, r.rank);
            }
        }
    }

    #[test]
    fn python_rust_agreement() {
        // values cross-checked against compile.quantum.accounting
        assert_eq!(qpeft_pauli_params(64, 64, 3, 1), 35);
        assert_eq!(qpeft_taylor_params(32, 32, 4, 4), 2 * 118 + 4);
        assert_eq!(lora_params(768, 768, 1) * 24, 36_864);
    }

    #[test]
    fn quantized_storage_formula() {
        assert!((quantized_bits_per_param(1.0, 128) - 1.25).abs() < 1e-12);
    }
}
