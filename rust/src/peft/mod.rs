//! PEFT-side host logic: analytic accounting (Tables 1/4/5) and frozen
//! base-model quantization (Tables 6/7).

pub mod accounting;
pub mod quantization;
