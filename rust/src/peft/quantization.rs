//! Host-side groupwise integer quantization of *frozen* base weights
//! (Table 6's 3-bit ViT backbone, §B.3). Asymmetric min-anchored uniform
//! quantization:
//!   w_q = round((w - lo) / beta) * beta + lo,  beta = (hi - lo) / (2^n - 1)
//! with lo/hi the per-group min/max — the §4.2 uniform-grid scheme
//! anchored at the group *minimum* rather than a midpoint `mu`, so the
//! grid's end levels land exactly on lo and hi (a zero-point-free,
//! range-exact variant; the midpoint form shifts both ends off the
//! observed extremes). Applied by the coordinator to pretrained
//! checkpoints before feeding them to the fine-tuning artifacts
//! (adapters stay full precision; QAT of Lie parameters happens *inside*
//! the graph via runtime extras).

/// Quantize a flat f32 buffer in place, groups of `g`, `bits`-bit levels.
///
/// `g == 0` means "no grouping": one group spanning the whole buffer
/// (identical to any `g >= w.len()`). `chunks_mut(0)` would panic, so the
/// degenerate value is clamped here rather than left to the slice API.
pub fn quantize_inplace(w: &mut [f32], bits: u32, g: usize) {
    assert!((1..=16).contains(&bits));
    let g = if g == 0 { w.len().max(1) } else { g };
    let levels = ((1u32 << bits) - 1) as f32;
    for chunk in w.chunks_mut(g) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in chunk.iter() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let beta = (hi - lo) / levels;
        if beta <= 0.0 || !beta.is_finite() {
            continue; // constant group: exact already
        }
        for x in chunk.iter_mut() {
            *x = ((*x - lo) / beta).round() * beta + lo;
        }
    }
}

/// Storage bytes of a quantized buffer: n bits per weight + fp16 scale
/// and zero point per group.
///
/// `g == 0` is the same "one group over the whole buffer" shorthand as in
/// [`quantize_inplace`] (it would otherwise be a `div_ceil` by zero).
pub fn quantized_storage_bytes(len: usize, bits: u32, g: usize) -> usize {
    let payload_bits = len * bits as usize;
    let g = if g == 0 { len.max(1) } else { g };
    let groups = len.div_ceil(g);
    payload_bits.div_ceil(8) + groups * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn high_bits_nearly_exact() {
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let mut w = orig.clone();
        quantize_inplace(&mut w, 16, 128);
        for (a, b) in w.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn error_bounded_by_step() {
        let mut rng = Rng::new(2);
        let orig: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        for bits in [1u32, 2, 3, 4, 8] {
            let mut w = orig.clone();
            quantize_inplace(&mut w, bits, 64);
            let levels = ((1u32 << bits) - 1) as f32;
            for (grp_w, grp_o) in w.chunks(64).zip(orig.chunks(64)) {
                let lo = grp_o.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = grp_o.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let step = (hi - lo) / levels;
                for (a, b) in grp_w.iter().zip(grp_o) {
                    assert!((a - b).abs() <= step / 2.0 + 1e-6);
                }
            }
        }
    }

    #[test]
    fn monotone_in_bits() {
        let mut rng = Rng::new(3);
        let orig: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
        let mut last_err = f32::INFINITY;
        for bits in [1u32, 2, 4, 8] {
            let mut w = orig.clone();
            quantize_inplace(&mut w, bits, 128);
            let err: f32 = w.iter().zip(&orig).map(|(a, b)| (a - b).abs()).sum();
            assert!(err <= last_err);
            last_err = err;
        }
    }

    #[test]
    fn zero_group_size_means_one_whole_buffer_group() {
        // regression: g == 0 used to panic (chunks_mut(0) / div_ceil(0));
        // it is now the documented "no grouping" shorthand
        let mut rng = Rng::new(4);
        let orig: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let mut zero_g = orig.clone();
        quantize_inplace(&mut zero_g, 4, 0);
        let mut whole = orig.clone();
        quantize_inplace(&mut whole, 4, orig.len());
        assert_eq!(zero_g, whole);
        assert_eq!(quantized_storage_bytes(256, 4, 0),
                   quantized_storage_bytes(256, 4, 256));
        // degenerate shapes stay total too
        quantize_inplace(&mut [], 3, 0);
        assert_eq!(quantized_storage_bytes(0, 3, 0), 0);
    }

    #[test]
    fn storage_accounting() {
        // 330 MiB fp32 ViT -> ~34 MiB at 3 bits (paper §B.3 ratio ~9.7x)
        let fp32 = 86_000_000 * 4usize;
        let q3 = quantized_storage_bytes(86_000_000, 3, 128);
        let ratio = fp32 as f64 / q3 as f64;
        assert!(ratio > 8.0 && ratio < 11.0, "ratio {ratio}");
    }
}
