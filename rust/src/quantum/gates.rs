//! RY/CZ gate primitives — bit-for-bit mirror of python/compile/quantum/
//! gates.py (qubit k = bit k of the basis index, little-endian).

/// Sign vector in {±1}^(2^q) of a CZ layer on the given qubit pairs.
pub fn cz_sign_vector(q: usize, pairs: &[(usize, usize)]) -> Vec<f32> {
    let n = 1usize << q;
    let mut sign = vec![1.0f32; n];
    for &(a, b) in pairs {
        for (idx, s) in sign.iter_mut().enumerate() {
            if (idx >> a) & 1 == 1 && (idx >> b) & 1 == 1 {
                *s = -*s;
            }
        }
    }
    sign
}

/// [(q0,q1), (q2,q3), ...] over a qubit list; odd leftover untouched.
pub fn adjacent_pairs(qubits: &[usize]) -> Vec<(usize, usize)> {
    qubits.chunks_exact(2).map(|c| (c[0], c[1])).collect()
}

/// In-place RY(theta) on qubit k of a batch of states, x: [b, 2^q]
/// flattened row-major. Strided pairwise rotation, O(b * N).
pub fn apply_ry_axis(x: &mut [f32], b: usize, q: usize, k: usize, theta: f32) {
    let n = 1usize << q;
    let (c, s) = ((theta / 2.0).cos(), (theta / 2.0).sin());
    let stride = 1usize << k;
    for row in 0..b {
        let base = row * n;
        let mut blk = 0;
        while blk < n {
            for off in 0..stride {
                let i0 = base + blk + off;
                let i1 = i0 + stride;
                let (x0, x1) = (x[i0], x[i1]);
                x[i0] = c * x0 - s * x1;
                x[i1] = s * x0 + c * x1;
            }
            blk += 2 * stride;
        }
    }
}

/// Elementwise multiply each row by a sign vector.
pub fn apply_sign(x: &mut [f32], b: usize, sign: &[f32]) {
    let n = sign.len();
    for row in 0..b {
        for (v, s) in x[row * n..(row + 1) * n].iter_mut().zip(sign) {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cz_matches_diag() {
        assert_eq!(cz_sign_vector(2, &[(0, 1)]), vec![1.0, 1.0, 1.0, -1.0]);
    }

    #[test]
    fn ry_preserves_norm() {
        let mut x = vec![0.3f32, -1.2, 0.7, 2.0, 0.0, 1.0, -1.0, 0.5];
        let before: f32 = x.iter().map(|v| v * v).sum();
        apply_ry_axis(&mut x, 1, 3, 1, 0.9);
        let after: f32 = x.iter().map(|v| v * v).sum();
        assert!((before - after).abs() < 1e-4);
    }

    #[test]
    fn ry_on_qubit0_rotates_adjacent_pairs() {
        let mut x = vec![1.0f32, 0.0, 0.0, 0.0];
        apply_ry_axis(&mut x, 1, 2, 0, std::f32::consts::PI);
        // RY(pi) sends e0 -> e1 within the (0,1) pair
        assert!((x[0]).abs() < 1e-6 && (x[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn pairs() {
        assert_eq!(adjacent_pairs(&[0, 1, 2, 3, 4]), vec![(0, 1), (2, 3)]);
        assert_eq!(adjacent_pairs(&[2]), vec![]);
    }
}
