//! Dense f64 matrix kernel for the pure-Rust unitary-mapping mirror
//! (Figure 6 benches + property tests). Row-major, cache-blocked matmul;
//! LU solve for the Cayley transform; scaling-and-squaring expm.

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn t(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols,
              data: self.data.iter().map(|x| x * s).collect() }
    }

    pub fn add(&self, o: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        Mat { rows: self.rows, cols: self.cols,
              data: self.data.iter().zip(&o.data).map(|(a, b)| a + b).collect() }
    }

    pub fn sub(&self, o: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        Mat { rows: self.rows, cols: self.cols,
              data: self.data.iter().zip(&o.data).map(|(a, b)| a - b).collect() }
    }

    /// Cache-friendly ikj matmul (the L3 hot loop for dense mappings).
    pub fn matmul(&self, o: &Mat) -> Mat {
        assert_eq!(self.cols, o.rows, "matmul dim mismatch");
        let (n, k, m) = (self.rows, self.cols, o.cols);
        let mut out = Mat::zeros(n, m);
        for i in 0..n {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * m..(i + 1) * m];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &o.data[kk * m..(kk + 1) * m];
                for j in 0..m {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// y = x A for a batch of row-vectors x: [b, n] @ [n, m].
    pub fn apply_rows(&self, x: &Mat) -> Mat {
        x.matmul(self)
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, x| m.max(x.abs()))
    }

    /// inf-norm (max row sum) — used by expm scaling.
    pub fn norm_inf(&self) -> f64 {
        (0..self.rows)
            .map(|i| self.data[i * self.cols..(i + 1) * self.cols]
                 .iter().map(|x| x.abs()).sum::<f64>())
            .fold(0.0_f64, f64::max)
    }

    /// ||Q Q^T - I||_inf-elementwise — Figure 6's unitarity error.
    pub fn unitarity_error(&self) -> f64 {
        let qqt = self.matmul(&self.t());
        let n = self.rows;
        let mut err = 0.0_f64;
        for i in 0..n {
            for j in 0..n {
                let target = if i == j { 1.0 } else { 0.0 };
                err = err.max((qqt[(i, j)] - target).abs());
            }
        }
        err
    }

    /// Solve A X = B via LU with partial pivoting (A consumed).
    pub fn solve(mut self, mut b: Mat) -> Mat {
        assert_eq!(self.rows, self.cols);
        assert_eq!(self.rows, b.rows);
        let n = self.rows;
        let m = b.cols;
        for col in 0..n {
            // pivot
            let mut piv = col;
            for r in col + 1..n {
                if self[(r, col)].abs() > self[(piv, col)].abs() {
                    piv = r;
                }
            }
            if piv != col {
                for j in 0..n {
                    self.data.swap(col * n + j, piv * n + j);
                }
                for j in 0..m {
                    b.data.swap(col * m + j, piv * m + j);
                }
            }
            let d = self[(col, col)];
            assert!(d.abs() > 1e-14, "singular matrix in solve");
            for r in col + 1..n {
                let f = self[(r, col)] / d;
                if f == 0.0 {
                    continue;
                }
                for j in col..n {
                    let v = self[(col, j)];
                    self[(r, j)] -= f * v;
                }
                for j in 0..m {
                    let v = b[(col, j)];
                    b[(r, j)] -= f * v;
                }
            }
        }
        // back substitution
        let mut x = Mat::zeros(n, m);
        for r in (0..n).rev() {
            for j in 0..m {
                let mut s = b[(r, j)];
                for kk in r + 1..n {
                    s -= self[(r, kk)] * x[(kk, j)];
                }
                x[(r, j)] = s / self[(r, r)];
            }
        }
        x
    }

    /// Matrix exponential via scaling-and-squaring with a 12-term Taylor
    /// core — ample accuracy for skew-symmetric generators of modest norm.
    pub fn expm(&self) -> Mat {
        assert_eq!(self.rows, self.cols);
        let norm = self.norm_inf();
        let s = if norm > 0.5 { (norm / 0.5).log2().ceil() as i32 } else { 0 };
        let a = self.scale(1.0 / 2f64.powi(s));
        let mut term = Mat::eye(self.rows);
        let mut sum = Mat::eye(self.rows);
        for p in 1..=12 {
            term = term.matmul(&a).scale(1.0 / p as f64);
            sum = sum.add(&term);
        }
        let mut r = sum;
        for _ in 0..s {
            r = r.matmul(&r);
        }
        r
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        assert_eq!(Mat::eye(3).matmul(&a), a);
        assert_eq!(a.matmul(&Mat::eye(4)), a);
    }

    #[test]
    fn solve_roundtrip() {
        let a = Mat::from_fn(4, 4, |i, j| {
            if i == j { 3.0 } else { 0.5 / (1.0 + i as f64 + j as f64) }
        });
        let x_true = Mat::from_fn(4, 2, |i, j| (i + 2 * j) as f64);
        let b = a.matmul(&x_true);
        let x = a.clone().solve(b);
        for (u, v) in x.data.iter().zip(&x_true.data) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn expm_of_zero_is_identity() {
        let z = Mat::zeros(5, 5);
        let e = z.expm();
        assert!(e.sub(&Mat::eye(5)).max_abs() < 1e-12);
    }

    #[test]
    fn expm_skew_is_orthogonal() {
        let mut a = Mat::zeros(6, 6);
        for i in 0..6 {
            for j in 0..i {
                let v = ((i * 7 + j * 3) % 5) as f64 * 0.2 - 0.4;
                a[(i, j)] = v;
                a[(j, i)] = -v;
            }
        }
        let q = a.expm();
        assert!(q.unitarity_error() < 1e-10, "err {}", q.unitarity_error());
    }

    #[test]
    fn expm_matches_rotation() {
        // exp([[0,-t],[t,0]]) = [[cos t, -sin t],[sin t, cos t]]
        let t = 0.7_f64;
        let mut a = Mat::zeros(2, 2);
        a[(0, 1)] = -t;
        a[(1, 0)] = t;
        let e = a.expm();
        assert!((e[(0, 0)] - t.cos()).abs() < 1e-12);
        assert!((e[(1, 0)] - t.sin()).abs() < 1e-12);
    }
}
