//! Lie-algebra -> orthogonal mappings in pure Rust (Appendix A.1):
//! exponential, Cayley, Taylor, Neumann, Householder, Givens. Drives the
//! Figure-6 unitarity/speed benchmark (`repro table --id fig6` and
//! `cargo bench fig6_mappings`), mirroring python/compile/quantum/mappings.py.

use super::linalg::Mat;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mapping {
    Exp,
    Cayley,
    Taylor(usize),
    Neumann(usize),
    Householder,
    Givens,
}

impl Mapping {
    pub fn name(&self) -> String {
        match self {
            Mapping::Exp => "exp".into(),
            Mapping::Cayley => "cayley".into(),
            Mapping::Taylor(p) => format!("taylor(P={p})"),
            Mapping::Neumann(p) => format!("neumann(P={p})"),
            Mapping::Householder => "householder".into(),
            Mapping::Givens => "givens".into(),
        }
    }

    pub fn all(order: usize) -> Vec<Mapping> {
        vec![Mapping::Exp, Mapping::Cayley, Mapping::Taylor(order),
             Mapping::Neumann(order), Mapping::Householder, Mapping::Givens]
    }
}

/// #strictly-lower entries in the first k columns of an n x n matrix.
pub fn lower_params_count(n: usize, k: usize) -> usize {
    let k = k.min(n.saturating_sub(1));
    (0..k).map(|j| n - 1 - j).sum()
}

/// Random Lie parameters (the B_K factor content) for benchmarking.
pub fn random_theta(rng: &mut Rng, n: usize, k: usize, scale: f64) -> Vec<f64> {
    (0..lower_params_count(n, k)).map(|_| rng.normal() * scale).collect()
}

/// Scatter flat params into the strictly-lower N x K factor (column-major
/// fill — same convention as params_to_lower in python).
pub fn params_to_lower(theta: &[f64], n: usize, k: usize) -> Mat {
    let mut bk = Mat::zeros(n, k);
    let mut ofs = 0;
    for j in 0..k.min(n.saturating_sub(1)) {
        for i in j + 1..n {
            bk[(i, j)] = theta[ofs];
            ofs += 1;
        }
    }
    assert_eq!(ofs, theta.len());
    bk
}

/// A = B - B^T from the N x K strictly-lower factor.
pub fn skew_from_factor(bk: &Mat, n: usize) -> Mat {
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..bk.cols.min(i) {
            a[(i, j)] = bk[(i, j)];
            a[(j, i)] = -bk[(i, j)];
        }
    }
    a
}

pub fn q_exp(a: &Mat) -> Mat {
    a.expm()
}

pub fn q_cayley(a: &Mat) -> Mat {
    let n = a.rows;
    let i_plus = Mat::eye(n).add(a);
    let i_minus = Mat::eye(n).sub(a);
    // (I+A)(I-A)^{-1} = solve((I-A)^T, (I+A)^T)^T
    i_minus.t().solve(i_plus.t()).t()
}

pub fn q_taylor(a: &Mat, order: usize) -> Mat {
    let n = a.rows;
    let mut acc = Mat::eye(n);
    for p in (1..=order).rev() {
        acc = Mat::eye(n).add(&a.matmul(&acc).scale(1.0 / p as f64));
    }
    acc
}

pub fn q_neumann(a: &Mat, order: usize) -> Mat {
    let n = a.rows;
    let mut acc = Mat::eye(n);
    for _ in 0..order {
        acc = Mat::eye(n).add(&a.matmul(&acc));
    }
    Mat::eye(n).add(a).matmul(&acc)
}

pub fn q_householder(bk: &Mat, n: usize) -> Mat {
    let mut q = Mat::eye(n);
    for j in 0..bk.cols {
        let mut v: Vec<f64> = (0..n).map(|i| bk[(i, j)]).collect();
        let nrm2: f64 = v.iter().map(|x| x * x).sum::<f64>().max(1e-12);
        for x in &mut v {
            *x /= nrm2.sqrt();
        }
        // q <- q (I - 2 v v^T): rank-1 update, O(n^2)
        let mut qv = vec![0.0f64; n];
        for i in 0..n {
            let row = q.row(i);
            qv[i] = row.iter().zip(&v).map(|(a, b)| a * b).sum();
        }
        for i in 0..n {
            for jj in 0..n {
                q[(i, jj)] -= 2.0 * qv[i] * v[jj];
            }
        }
    }
    q
}

pub fn q_givens(bk: &Mat, n: usize) -> Mat {
    let mut q = Mat::eye(n);
    for j in 0..bk.cols.min(n.saturating_sub(1)) {
        for m in j + 1..n {
            let th = bk[(m, j)];
            let (c, s) = (th.cos(), th.sin());
            // rotate rows m-1, m
            for col in 0..n {
                let a = q[(m - 1, col)];
                let b = q[(m, col)];
                q[(m - 1, col)] = c * a - s * b;
                q[(m, col)] = s * a + c * b;
            }
        }
    }
    q
}

/// Figure 3(a) pipeline: flat Lie params -> orthogonal Q (square; callers
/// truncate columns for the Stiefel frame).
pub fn orthogonal(theta: &[f64], n: usize, k: usize, mapping: Mapping) -> Mat {
    let bk = params_to_lower(theta, n, k);
    match mapping {
        Mapping::Householder => q_householder(&bk, n),
        Mapping::Givens => q_givens(&bk, n),
        m => {
            let a = skew_from_factor(&bk, n);
            match m {
                Mapping::Exp => q_exp(&a),
                Mapping::Cayley => q_cayley(&a),
                Mapping::Taylor(p) => q_taylor(&a, p),
                Mapping::Neumann(p) => q_neumann(&a, p),
                _ => unreachable!(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;

    #[test]
    fn counts() {
        assert_eq!(lower_params_count(5, 4), 10);
        assert_eq!(lower_params_count(5, 99), 10);
        assert_eq!(lower_params_count(6, 2), 9);
    }

    #[test]
    fn exact_mappings_orthogonal_property() {
        check_property("exact mappings orthogonal", 12, |rng| {
            let n = rng.range(4, 24);
            let k = rng.range(1, 5.min(n));
            let th = random_theta(rng, n, k, 0.3);
            for m in [Mapping::Exp, Mapping::Cayley, Mapping::Householder,
                      Mapping::Givens] {
                let q = orthogonal(&th, n, k, m);
                assert!(q.unitarity_error() < 1e-8,
                        "{} err {}", m.name(), q.unitarity_error());
            }
        });
    }

    #[test]
    fn taylor_converges_to_exp() {
        let mut rng = Rng::new(5);
        let n = 12;
        let th = random_theta(&mut rng, n, 3, 0.2);
        let qt = orthogonal(&th, n, 3, Mapping::Taylor(18));
        let qe = orthogonal(&th, n, 3, Mapping::Exp);
        assert!(qt.sub(&qe).max_abs() < 1e-9);
    }

    #[test]
    fn neumann_approaches_cayley() {
        let mut rng = Rng::new(6);
        let n = 10;
        let th = random_theta(&mut rng, n, 2, 0.05);
        let qn = orthogonal(&th, n, 2, Mapping::Neumann(30));
        let qc = orthogonal(&th, n, 2, Mapping::Cayley);
        assert!(qn.sub(&qc).max_abs() < 1e-8);
    }

    #[test]
    fn error_ordering_matches_figure6() {
        // exact mappings beat truncated series at moderate angle scale
        let mut rng = Rng::new(7);
        let n = 32;
        let th = random_theta(&mut rng, n, 4, 0.3);
        let e_exact = orthogonal(&th, n, 4, Mapping::Cayley).unitarity_error();
        let e_taylor = orthogonal(&th, n, 4, Mapping::Taylor(6)).unitarity_error();
        assert!(e_exact < e_taylor);
    }

    #[test]
    fn householder_all_zero_column_hits_clamp_path() {
        // A zero reflection vector exercises the 1e-12 norm clamp: the
        // normalized v stays zero, the rank-1 update is a no-op, and Q
        // must remain exactly orthogonal (no NaN/Inf from 0/0).
        let n = 8;
        let k = 3;
        let bk = Mat::zeros(n, k); // every column all-zero
        let q = q_householder(&bk, n);
        assert!(q.data.iter().all(|v| v.is_finite()));
        assert!(q.unitarity_error() < 1e-12, "err {}", q.unitarity_error());
        // mixed case: one live column between two zero columns
        let mut rng = Rng::new(11);
        let mut bk = Mat::zeros(n, 3);
        for i in 1..n {
            bk[(i, 1)] = rng.normal() * 0.3;
        }
        let q = q_householder(&bk, n);
        assert!(q.data.iter().all(|v| v.is_finite()));
        assert!(q.unitarity_error() < 1e-8);
    }

    #[test]
    fn k_at_least_n_is_capped_not_out_of_bounds() {
        // lower_params_count caps k at n-1; params_to_lower, q_givens and
        // the full orthogonal() pipeline must agree on that cap for
        // k == n and k > n instead of indexing out of bounds.
        let n = 6;
        for k in [n, n + 1, n + 5] {
            assert_eq!(lower_params_count(n, k), lower_params_count(n, n - 1));
            let mut rng = Rng::new(13 ^ k as u64);
            let th = random_theta(&mut rng, n, k, 0.2);
            assert_eq!(th.len(), lower_params_count(n, n - 1));
            let bk = params_to_lower(&th, n, k);
            assert_eq!(bk.cols, k); // trailing columns stay zero
            for m in [Mapping::Givens, Mapping::Householder, Mapping::Cayley] {
                let q = orthogonal(&th, n, k, m);
                assert!(q.unitarity_error() < 1e-8,
                        "{} err {} at k={k}", m.name(), q.unitarity_error());
            }
        }
    }

    #[test]
    fn python_convention_agreement() {
        // same column-major scatter as mappings.params_to_lower
        let th = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let bk = params_to_lower(&th, 4, 2);
        assert_eq!(bk[(1, 0)], 1.0);
        assert_eq!(bk[(2, 0)], 2.0);
        assert_eq!(bk[(3, 0)], 3.0);
        assert_eq!(bk[(2, 1)], 4.0);
        assert_eq!(bk[(3, 1)], 5.0);
    }
}
