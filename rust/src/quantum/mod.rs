//! Pure-Rust mirror of the paper's unitary math. The training path always
//! executes the AOT artifacts; this mirror exists for (a) the Figure-6
//! mapping benchmark, (b) analytic accounting (Tables 1/5), and (c)
//! cross-layer property tests that pin the Python and Rust conventions
//! to each other.

pub mod gates;
pub mod linalg;
pub mod mappings;
pub mod pauli;
pub mod qsd;
