//! Pauli parameterization Q_P (eq. 2) in pure Rust — mirrors
//! python/compile/quantum/pauli.py exactly (same layer order, same angle
//! layout). Used by the Figure-6 speed/accuracy bench and the accounting
//! cross-checks; the *training* path always uses the AOT artifacts.

use super::gates;

#[derive(Clone)]
pub struct Layer {
    pub qubits: Vec<usize>,
    pub theta_ofs: usize,
    pub sign: Option<Vec<f32>>,
}

#[derive(Clone)]
pub struct PauliCircuit {
    pub q: usize,
    pub n_layers: usize,
    pub layers: Vec<Layer>,
    pub num_params: usize,
}

impl PauliCircuit {
    pub fn dim(&self) -> usize {
        1usize << self.q
    }

    /// Bytes a dense [`materialize`](Self::materialize) result occupies
    /// (f32 N x N) — the unit the serve registry's LRU byte budget counts.
    pub fn materialized_bytes(&self) -> usize {
        self.dim() * self.dim() * 4
    }

    /// x <- x @ Q_P for x: [b, 2^q] row-major. O(b · N · q · L).
    pub fn apply(&self, x: &mut [f32], b: usize, thetas: &[f32]) {
        assert_eq!(thetas.len(), self.num_params);
        for layer in &self.layers {
            for (i, &k) in layer.qubits.iter().enumerate() {
                gates::apply_ry_axis(x, b, self.q, k, thetas[layer.theta_ofs + i]);
            }
            if let Some(sign) = &layer.sign {
                gates::apply_sign(x, b, sign);
            }
        }
    }

    /// Dense Q_P (row i = e_i Q_P), for tests and unitarity checks.
    pub fn materialize(&self, thetas: &[f32]) -> Vec<f32> {
        let n = self.dim();
        let mut x = vec![0.0f32; n * n];
        for i in 0..n {
            x[i * n + i] = 1.0;
        }
        self.apply(&mut x, n, thetas);
        x
    }
}

/// Build the eq.-(2) structure for q qubits, L entanglement blocks.
pub fn build(q: usize, n_layers: usize) -> PauliCircuit {
    assert!(q >= 1);
    let mut layers = Vec::new();
    let mut ofs = 0usize;
    layers.push(Layer { qubits: (0..q).collect(), theta_ofs: ofs, sign: None });
    ofs += q;
    for _ in 0..n_layers {
        if q >= 2 {
            let qa: Vec<usize> = (0..q - 1).collect();
            layers.push(Layer {
                sign: Some(gates::cz_sign_vector(q, &gates::adjacent_pairs(&qa))),
                theta_ofs: ofs,
                qubits: qa.clone(),
            });
            ofs += qa.len();
            let qb: Vec<usize> = (1..q).collect();
            layers.push(Layer {
                sign: Some(gates::cz_sign_vector(q, &gates::adjacent_pairs(&qb))),
                theta_ofs: ofs,
                qubits: qb.clone(),
            });
            ofs += qb.len();
        }
    }
    PauliCircuit { q, n_layers, layers, num_params: ofs }
}

/// (2L+1) log2(N) - 2L (power-of-two N, q >= 2; q = 1 gives 1).
pub fn num_params(n: usize, n_layers: usize) -> usize {
    assert!(n.is_power_of_two() && n >= 2);
    let q = n.trailing_zeros() as usize;
    if q == 1 {
        1
    } else {
        q + 2 * n_layers * (q - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check_property;

    fn unit_err(m: &[f32], n: usize) -> f32 {
        let mut err = 0.0f32;
        for i in 0..n {
            for j in 0..n {
                let mut dot = 0.0f32;
                for k in 0..n {
                    dot += m[i * n + k] * m[j * n + k];
                }
                let target = if i == j { 1.0 } else { 0.0 };
                err = err.max((dot - target).abs());
            }
        }
        err
    }

    #[test]
    fn param_count_matches_formula() {
        for (q, l) in [(2, 1), (3, 1), (4, 2), (6, 1), (8, 3)] {
            assert_eq!(build(q, l).num_params, num_params(1 << q, l));
        }
    }

    #[test]
    fn orthogonality_property() {
        check_property("pauli circuit orthogonal", 25, |rng| {
            let q = rng.range(2, 7);
            let l = rng.range(0, 4);
            let c = build(q, l);
            let th: Vec<f32> = (0..c.num_params)
                .map(|_| rng.normal() as f32 * 0.7).collect();
            let m = c.materialize(&th);
            assert!(unit_err(&m, c.dim()) < 1e-4);
        });
    }

    #[test]
    fn matches_python_convention_q2() {
        // q=2, L=0: pure Kronecker RY(t0) (x) RY(t1); e_0 @ Q row:
        // basis |00> -> cos(t0/2)cos(t1/2) on |00>, sin on the bit axes.
        let c = build(2, 0);
        let (t0, t1) = (0.6f32, -0.8f32);
        let m = c.materialize(&[t0, t1]);
        let (c0, s0) = ((t0 / 2.0).cos(), (t0 / 2.0).sin());
        let (c1, s1) = ((t1 / 2.0).cos(), (t1 / 2.0).sin());
        // row 0 = e_0 rotated: [c0*c1, s0*c1, c1? ...] index = b1*2 + b0
        assert!((m[0] - c0 * c1).abs() < 1e-6);
        assert!((m[1] - s0 * c1).abs() < 1e-6);
        assert!((m[2] - c0 * s1).abs() < 1e-6);
        assert!((m[3] - s0 * s1).abs() < 1e-6);
    }

    #[test]
    fn apply_preserves_norms() {
        let c = build(5, 2);
        let th: Vec<f32> = (0..c.num_params).map(|i| (i as f32 * 0.37).sin()).collect();
        let n = c.dim();
        let mut x: Vec<f32> = (0..3 * n).map(|i| (i as f32 * 0.11).cos()).collect();
        let before: Vec<f32> = (0..3)
            .map(|r| x[r * n..(r + 1) * n].iter().map(|v| v * v).sum())
            .collect();
        c.apply(&mut x, 3, &th);
        for (r, &bn) in before.iter().enumerate() {
            let an: f32 = x[r * n..(r + 1) * n].iter().map(|v| v * v).sum();
            assert!((bn - an).abs() / bn.max(1.0) < 1e-4);
        }
    }
}
