//! Quantum Shannon decomposition split planning (eq. 4) — mirror of
//! python/compile/quantum/qsd.py for accounting and structure checks.

use super::pauli;

/// (N1, N2): N1 = largest power of two <= n (halved when n itself is 2^k).
pub fn split(n: usize) -> (usize, usize) {
    assert!(n >= 2);
    let mut n1 = 1usize << (usize::BITS - 1 - n.leading_zeros());
    if n1 == n {
        n1 >>= 1;
    }
    (n1, n - n1)
}

/// Greedy binary partition: 28 -> [16, 8, 4] (Example 4.1), 257 -> [256, 1].
pub fn power_of_two_blocks(mut n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    while n > 0 {
        let b = 1usize << (usize::BITS - 1 - n.leading_zeros());
        out.push(b);
        n -= b;
    }
    out
}

/// Trainable parameter count of the recursive QSD circuit with Pauli
/// leaves of depth L — [U1|U2|phi|V1|V2] per split, recursing on
/// non-power-of-two sub-blocks (same recursion as the python builder).
pub fn num_params(n: usize, n_layers: usize) -> usize {
    if n <= 1 {
        return 0;
    }
    if n.is_power_of_two() {
        return pauli::num_params(n, n_layers);
    }
    let (n1, n2) = split(n);
    2 * num_params(n1, n_layers) + 2 * num_params(n2, n_layers) + n2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_examples() {
        assert_eq!(split(12), (8, 4));
        assert_eq!(split(28), (16, 12));
        assert_eq!(split(257), (256, 1));
        assert_eq!(split(16), (8, 8));
    }

    #[test]
    fn blocks_example_4_1() {
        assert_eq!(power_of_two_blocks(28), vec![16, 8, 4]);
        assert_eq!(power_of_two_blocks(12), vec![8, 4]);
    }

    #[test]
    fn pow2_reduces_to_pauli() {
        assert_eq!(num_params(64, 1), pauli::num_params(64, 1));
    }

    #[test]
    fn n12_matches_python_builder() {
        // 2*pauli(8) + 2*pauli(4) + 4 = 2*7 + 2*4 + 4 = 26 at L=1
        assert_eq!(num_params(12, 1), 26);
    }
}
