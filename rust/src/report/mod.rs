//! Experiment orchestration + table rendering: one entry point per paper
//! table/figure (`repro table --id <id>`). Each regenerates its rows from
//! scratch (pretraining backbones on demand, cached under runs/).

pub mod tables;

/// Fixed-width table renderer (markdown-ish, matches EXPERIMENTS.md).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line = |cells: &[String], widths: &[usize]| -> String {
        let mut s = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            s.push_str(&format!(" {:<w$} |", c, w = w));
        }
        s
    };
    out.push_str(&line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
                       &widths));
    out.push('\n');
    out.push_str(&line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
                       &widths));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row, &widths));
        out.push('\n');
    }
    out
}

/// Human-readable byte counts (Table 1's MB/GB column).
pub fn fmt_bytes(b: usize) -> String {
    let bf = b as f64;
    if bf < 1024.0 * 1024.0 {
        format!("{:.2}MB", bf / 1e6)
    } else if bf < 1e9 {
        format!("{:.2}MB", bf / 1e6)
    } else {
        format!("{:.2}GB", bf / 1e9)
    }
}

/// Human-readable parameter counts (36.9K / 8.26M style).
pub fn fmt_params(p: usize) -> String {
    let pf = p as f64;
    if pf < 1e3 {
        format!("{p}")
    } else if pf < 1e6 {
        format!("{:.2}K", pf / 1e3)
    } else if pf < 1e9 {
        format!("{:.2}M", pf / 1e6)
    } else {
        format!("{:.2}B", pf / 1e9)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_alignment() {
        let t = super::render_table(&["a", "bb"], &[
            vec!["xxx".into(), "1".into()],
            vec!["y".into(), "22222".into()],
        ]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(super::fmt_params(36_864), "36.86K");
        assert_eq!(super::fmt_params(8_257_536), "8.26M");
        assert!(super::fmt_bytes(37_748_736).contains("MB"));
        assert!(super::fmt_bytes(8_455_716_864).contains("GB"));
    }
}
