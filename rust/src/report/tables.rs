//! One function per paper table/figure. Every function prints the rows in
//! the paper's layout and returns them as (headers, rows) so the CLI and
//! EXPERIMENTS.md generation share one source of truth.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::config;
use crate::coordinator::events::EventLog;
use crate::coordinator::sweep::{self, SweepPlan};
use crate::coordinator::trainer::{self, E2eRunSpec, TrainConfig, VitRunSpec};
use crate::data::glue;
use crate::peft::accounting;
use crate::quantum::mappings::{self, Mapping};
use crate::runtime::{Manifest, Runtime};
use crate::util::pool;
use crate::util::rng::Rng;

use super::{fmt_bytes, fmt_params, render_table};

pub type Table = (Vec<&'static str>, Vec<Vec<String>>);

pub fn runs_dir() -> PathBuf {
    std::env::var("REPRO_RUNS").map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("runs"))
}

/// Worker count for table sweeps: $REPRO_JOBS beats the config's
/// `[sweep] jobs` key; both default to 1 (sequential). "auto" or 0 means
/// one worker per available core. Any value yields byte-identical tables
/// (see coordinator::sweep's determinism contract). A malformed
/// $REPRO_JOBS is an error, not a silent fallback to sequential.
pub fn sweep_jobs(cfg: &config::Config) -> Result<usize> {
    use anyhow::Context as _;
    match std::env::var("REPRO_JOBS") {
        Ok(s) => pool::parse_jobs_value(&s).context("REPRO_JOBS"),
        Err(_) => match cfg.get("sweep", "jobs") {
            None => Ok(1),
            Some(config::Value::Num(v)) => {
                if *v < 0.0 || v.fract() != 0.0 {
                    anyhow::bail!(
                        "[sweep] jobs expects a non-negative integer \
                         (0 = auto), got {v}");
                }
                Ok(if *v == 0.0 { pool::default_jobs() } else { *v as usize })
            }
            Some(config::Value::Str(s)) => {
                pool::parse_jobs_value(s).context("[sweep] jobs")
            }
            Some(other) => anyhow::bail!(
                "[sweep] jobs expects a count or \"auto\", got {other:?}"),
        },
    }
}

/// Pretrain (or reuse) a backbone checkpoint for a model family.
pub fn ensure_backbone(rt: &Runtime, manifest: &Manifest, family: &str,
                       cfg: &config::Config, log: &EventLog) -> Result<PathBuf> {
    let path = runs_dir().join("backbones").join(format!("{family}.qpck"));
    if path.exists() {
        return Ok(path);
    }
    let steps = cfg.f64_or("pretrain", "steps", 300.0) as usize;
    let lr = cfg.f64_or("pretrain", "lr", 0.003) as f32;
    println!("[pretrain] {family}: {steps} steps (cached at {path:?})");
    let losses = match family {
        "enc" => trainer::pretrain_encoder(rt, manifest, "enc_pretrain",
                                           steps, lr, 0, &path, log)?,
        "encw" => trainer::pretrain_encoder(rt, manifest, "encw_pretrain",
                                            steps, lr, 0, &path, log)?,
        "dec" => trainer::pretrain_decoder(rt, manifest, "dec_pretrain",
                                           steps, lr, 0, &path, log)?,
        "vit" => trainer::pretrain_vit(rt, manifest, "vit_pretrain",
                                       steps, lr, 0, &path, log)?,
        other => anyhow::bail!("unknown backbone family {other:?}"),
    };
    println!("[pretrain] {family}: loss {:.4} -> {:.4}",
             losses.first().unwrap_or(&0.0), losses.last().unwrap_or(&0.0));
    Ok(path)
}

// ------------------------------------------------------------- Table 1 ---

/// Analytic storage table (exact reproduction — same model dims as paper).
pub fn table1() -> Table {
    let headers = vec!["Model", "Rank", "LoRA #Params", "LoRA Bytes",
                       "Q-PEFT #Params", "Q-PEFT Bytes", "Reduction"];
    let rows = accounting::table1().into_iter()
        .map(|r| vec![
            r.model.to_string(),
            r.rank.to_string(),
            fmt_params(r.lora_params),
            fmt_bytes(r.lora_bytes()),
            fmt_params(r.qpeft_params),
            fmt_bytes(r.qpeft_bytes()),
            format!("{:.0}x", r.lora_params as f64 / r.qpeft_params as f64),
        ])
        .collect();
    (headers, rows)
}

// --------------------------------------------------------- Tables 2 & 5 ---

const TABLE2_TAGS: &[&str] = &[
    "enc_ft", "enc_bitfit", "enc_hadapter", "enc_padapter", "enc_lora",
    "enc_adalora", "enc_loha", "enc_lokr", "enc_mora", "enc_quanta",
    "enc_qpeft_taylor", "enc_qpeft_pauli",
];

const TABLE5_TAGS: &[&str] = &["encw_lora", "encw_adalora", "encw_qpeft_taylor"];

fn glue_table(rt: &Runtime, manifest: &Manifest, tags: &[&str], family: &str,
              cfg: &config::Config, log: &EventLog) -> Result<Table> {
    let backbone = ensure_backbone(rt, manifest, family, cfg, log)?;
    let plan = SweepPlan {
        tags: tags.iter().map(|s| s.to_string()).collect(),
        tasks: glue::ALL_TASKS.to_vec(),
        seeds: config::sweep_seeds(cfg),
        cfg: config::train_config(cfg),
        backbone: Some(backbone),
        task_lr: BTreeMap::new(),
    };
    let results = sweep::run_glue_sweep_jobs(rt, manifest, &plan, log,
                                             sweep_jobs(cfg)?)?;
    let aggs = sweep::aggregate(&results);
    let headers = vec!["Method", "#Adapter Params", "SST-2", "CoLA", "RTE",
                       "MRPC", "STS-B", "Avg.", "Mem (opt-state)"];
    let mut rows = Vec::new();
    // memory ratios are relative to the most parameter-efficient method
    // in the panel (the paper normalizes to Quantum-PEFT = 1x)
    let qpeft_mem = aggs.iter()
        .filter(|a| a.tag.contains("qpeft_pauli"))
        .map(|a| accounting::adamw_state_bytes(a.trainable_params))
        .next()
        .unwrap_or_else(|| aggs.iter()
            .map(|a| accounting::adamw_state_bytes(a.trainable_params))
            .min().unwrap_or(1));
    for tag in tags {
        let per_task: BTreeMap<&str, &sweep::AggResult> = aggs.iter()
            .filter(|a| a.tag == *tag)
            .map(|a| (a.task.as_str(), a))
            .collect();
        if per_task.is_empty() {
            continue;
        }
        let avg = sweep::glue_average(&aggs, tag).unwrap_or(0.0);
        let any = per_task.values().next().unwrap();
        let mem = accounting::adamw_state_bytes(any.trainable_params);
        let cell = |t: &str| per_task.get(t)
            .map(|a| format!("{:.2}", 100.0 * a.mean_metric))
            .unwrap_or_else(|| "-".into());
        rows.push(vec![
            tag.to_string(),
            fmt_params(any.adapter_params),
            cell("sst2"), cell("cola"), cell("rte"), cell("mrpc"),
            cell("stsb"),
            format!("{:.2}", 100.0 * avg),
            format!("{:.2}x", mem as f64 / qpeft_mem as f64),
        ]);
    }
    Ok((headers, rows))
}

pub fn table2(rt: &Runtime, manifest: &Manifest, cfg: &config::Config,
              log: &EventLog) -> Result<Table> {
    glue_table(rt, manifest, TABLE2_TAGS, "enc", cfg, log)
}

pub fn table5(rt: &Runtime, manifest: &Manifest, cfg: &config::Config,
              log: &EventLog) -> Result<Table> {
    glue_table(rt, manifest, TABLE5_TAGS, "encw", cfg, log)
}

// --------------------------------------------------------- Tables 3 & 4 ---

const TABLE3_TAGS: &[&str] = &["dec_ft", "dec_lora", "dec_adalora",
                               "dec_loha", "dec_lokr", "dec_qpeft_taylor"];

/// Run the Table-3/4 E2E tag panel (fine-tune + greedy generation per
/// cell) across `jobs` workers on the shared compile cache: the decoder
/// backbone is pretrained once up front via `ensure_backbone`, results
/// come back in `TABLE3_TAGS` order, and the rendered tables are
/// byte-identical for any `jobs` value.
pub fn table3_and_4(rt: &Runtime, manifest: &Manifest, cfg: &config::Config,
                    log: &EventLog) -> Result<(Table, Table)> {
    let backbone = ensure_backbone(rt, manifest, "dec", cfg, log)?;
    let tcfg = config::train_config(cfg);
    let results = e2e_panel(rt, manifest, TABLE3_TAGS, &tcfg, &backbone,
                            sweep_jobs(cfg)?, log)?;
    Ok(table3_and_4_rows(&results))
}

fn e2e_panel(rt: &Runtime, manifest: &Manifest, tags: &[&str],
             tcfg: &TrainConfig, backbone: &PathBuf, jobs: usize,
             log: &EventLog) -> Result<Vec<trainer::RunResult>> {
    let items: Vec<String> = tags.iter().map(|s| s.to_string()).collect();
    sweep::run_panel_with(items, jobs, log,
        |worker| rt.for_worker(worker),
        |wrt, tag, wlog| {
            let spec = E2eRunSpec {
                tag: tag.as_str(),
                cfg: tcfg.clone(),
                backbone: Some(backbone),
                gen_cases: tcfg.test_examples.min(96),
            };
            trainer::run_e2e(wrt.rt(), manifest, &spec, wlog)
        })
}

/// Pure row construction from E2E panel results (in input order), shared
/// with the determinism tests: identical result vectors render
/// byte-identical tables.
pub fn table3_and_4_rows(results: &[trainer::RunResult]) -> (Table, Table) {
    let mut qpeft_mem = 1usize;
    for r in results {
        if r.tag.contains("qpeft") {
            qpeft_mem = accounting::adamw_state_bytes(r.trainable_params);
        }
    }
    let mut t3_rows = Vec::new();
    let mut t4_rows = Vec::new();
    for r in results {
        t3_rows.push(vec![
            r.tag.clone(),
            fmt_params(r.adapter_params),
            format!("{:.2}", 100.0 * r.extra_metrics["bleu"]),
            format!("{:.2}", r.extra_metrics["nist"]),
            format!("{:.2}", 100.0 * r.extra_metrics["meteor"]),
            format!("{:.2}", 100.0 * r.extra_metrics["rouge_l"]),
            format!("{:.2}", r.extra_metrics["cider"]),
        ]);
        let mem = accounting::adamw_state_bytes(r.trainable_params);
        t4_rows.push(vec![
            r.tag.clone(),
            format!("{:.1}", r.step_ms),
            format!("{:.2}x", mem as f64 / qpeft_mem.max(1) as f64),
        ]);
    }
    ((vec!["Method", "#Adapter Params", "BLEU", "NIST", "METEOR",
           "ROUGE-L", "CIDEr"], t3_rows),
     (vec!["Method", "Train ms/batch", "Opt-state Memory Ratio"], t4_rows))
}

// -------------------------------------------------------- Tables 6..10 ---

/// One independent fine-tuning cell of a ViT ablation panel.
struct VitCell {
    tag: String,
    base_bits: Option<u32>,
    overrides: BTreeMap<String, f32>,
}

impl VitCell {
    fn new(tag: &str, base_bits: Option<u32>,
           overrides: BTreeMap<String, f32>) -> VitCell {
        VitCell { tag: tag.to_string(), base_bits, overrides }
    }
}

/// Run a panel of independent ViT cells, in input order, across `jobs`
/// workers on the shared compile cache (`rt.for_worker`; the backbone
/// checkpoint is built once and shared). `jobs <= 1` runs inline on the
/// caller's thread — both paths produce identical results (per-cell RNG
/// derives only from the train config seed).
fn vit_panel(rt: &Runtime, manifest: &Manifest, cells: Vec<VitCell>,
             tcfg: &TrainConfig, backbone: &PathBuf, jobs: usize,
             log: &EventLog) -> Result<Vec<trainer::RunResult>> {
    sweep::run_panel_with(cells, jobs, log,
        |worker| rt.for_worker(worker),
        |wrt, c, wlog| {
            let spec = VitRunSpec {
                tag: &c.tag,
                cfg: tcfg.clone(),
                backbone: Some(backbone),
                base_bits: c.base_bits,
                extras_override: c.overrides.clone(),
            };
            trainer::run_vit(wrt.rt(), manifest, &spec, wlog)
        })
}

pub fn table6(rt: &Runtime, manifest: &Manifest, cfg: &config::Config,
              log: &EventLog) -> Result<Table> {
    let backbone = ensure_backbone(rt, manifest, "vit", cfg, log)?;
    let tcfg = config::train_config(cfg);
    let tags = ["vit_ft", "vit_lora_k1", "vit_lora_k2", "vit_lora_k4",
                "vit_qpt_pauli"];
    let cells = tags.iter()
        .map(|t| VitCell::new(t, Some(3), BTreeMap::new()))
        .collect();
    let panel = vit_panel(rt, manifest, cells, &tcfg, &backbone,
                          sweep_jobs(cfg)?, log)?;
    let mut rows = Vec::new();
    // "Original" row: transfer accuracy with untrained head ~ chance
    rows.push(vec!["original (no FT)".into(), "-".into(), "~10.00 (chance)".into()]);
    for (tag, r) in tags.iter().zip(&panel) {
        rows.push(vec![
            tag.to_string(),
            fmt_params(r.adapter_params),
            format!("{:.2}", 100.0 * r.best_metric),
        ]);
    }
    Ok((vec!["Method (3-bit base)", "#Adapter Params", "Accuracy %"], rows))
}

pub fn table7(rt: &Runtime, manifest: &Manifest, cfg: &config::Config,
              log: &EventLog) -> Result<Table> {
    let backbone = ensure_backbone(rt, manifest, "vit", cfg, log)?;
    let tcfg = config::train_config(cfg);
    let levels = [("FP32", 0.0f32), ("INT8", 8.0), ("INT4", 4.0),
                  ("INT3", 3.0), ("INT2", 2.0), ("INT1", 1.0)];
    // FP32 is one cell (uniform == adaptive by construction); each INT
    // level is two cells (uniform, adaptive) — all independent. Each row
    // records the panel indices of its cells so the pairing between
    // construction and consumption is structural, not positional.
    let mut cells = Vec::new();
    let mut row_cells: Vec<(&str, f32, Vec<usize>)> = Vec::new();
    for (label, bits) in levels {
        let modes: &[f32] = if bits == 0.0 { &[0.0] } else { &[0.0, 1.0] };
        let mut ixs = Vec::new();
        for &mode in modes {
            let mut ov = BTreeMap::new();
            if bits > 0.0 {
                ov.insert("quant_levels".to_string(),
                          (2f32.powf(bits) - 1.0) as f32);
                ov.insert("quant_mode".to_string(), mode);
            }
            ixs.push(cells.len());
            cells.push(VitCell::new("vit_qpt_taylor", None, ov));
        }
        row_cells.push((label, bits, ixs));
    }
    let panel = vit_panel(rt, manifest, cells, &tcfg, &backbone,
                          sweep_jobs(cfg)?, log)?;
    let rows = row_cells.into_iter()
        .map(|(label, bits, ixs)| {
            let mut row = vec![label.to_string(),
                               if bits == 0.0 { "32".into() }
                               else {
                                   format!("{:.2}",
                                           accounting::quantized_bits_per_param(
                                               bits as f64, 32))
                               }];
            // FP32's single cell fills both mode columns
            for col in 0..2 {
                let r = &panel[ixs[col.min(ixs.len() - 1)]];
                row.push(format!("{:.2}", 100.0 * r.best_metric));
            }
            row
        })
        .collect();
    Ok((vec!["Quantization", "Bits/param", "Acc % (Uniform)",
             "Acc % (Adaptive)"], rows))
}

pub fn table8(rt: &Runtime, manifest: &Manifest, cfg: &config::Config,
              log: &EventLog) -> Result<Table> {
    let backbone = ensure_backbone(rt, manifest, "vit", cfg, log)?;
    let tcfg = config::train_config(cfg);
    let entry = manifest.get("vit_qpt_taylor")?;
    let d = entry.cfg.get("d").copied().unwrap_or(64.0) as usize;
    let kps: Vec<usize> = (1..=8).collect();
    let cells = kps.iter()
        .map(|&kp| {
            let mut ov = BTreeMap::new();
            ov.insert("k_prime".to_string(), kp as f32);
            VitCell::new("vit_qpt_taylor", None, ov)
        })
        .collect();
    let panel = vit_panel(rt, manifest, cells, &tcfg, &backbone,
                          sweep_jobs(cfg)?, log)?;
    let rows = kps.iter().zip(&panel)
        .map(|(&kp, r)| {
            // effective params at this K' (analytic; masked columns train 0)
            let eff = 4 * accounting::qpeft_taylor_params(d, d, 8, kp);
            vec![
                kp.to_string(),
                fmt_params(eff),
                format!("{:.2}", 100.0 * r.best_metric),
            ]
        })
        .collect();
    Ok((vec!["Intrinsic rank K'", "#Effective Params", "Accuracy %"], rows))
}

pub fn table9(rt: &Runtime, manifest: &Manifest, cfg: &config::Config,
              log: &EventLog) -> Result<Table> {
    let backbone = ensure_backbone(rt, manifest, "vit", cfg, log)?;
    let tcfg = config::train_config(cfg);
    let variants = [(1usize, "vit_qpt_pauli"), (2, "vit_qpt_pauli_l2"),
                    (3, "vit_qpt_pauli_l3"), (4, "vit_qpt_pauli_l4")];
    let cells = variants.iter()
        .map(|(_, tag)| VitCell::new(tag, Some(2), BTreeMap::new()))
        .collect();
    let panel = vit_panel(rt, manifest, cells, &tcfg, &backbone,
                          sweep_jobs(cfg)?, log)?;
    let rows = variants.iter().zip(&panel)
        .map(|((l, _), r)| vec![
            l.to_string(),
            fmt_params(r.adapter_params),
            format!("{:.2}", 100.0 * r.best_metric),
        ])
        .collect();
    Ok((vec!["Entanglement layers L (2-bit base)", "#Adapter Params",
             "Accuracy %"], rows))
}

pub fn table10(rt: &Runtime, manifest: &Manifest, cfg: &config::Config,
               log: &EventLog) -> Result<Table> {
    let backbone = ensure_backbone(rt, manifest, "vit", cfg, log)?;
    let tcfg = config::train_config(cfg);
    let variants = [("CP", "vit_tn_cp"), ("TRD", "vit_tn_trd"),
                    ("HTD (TTN)", "vit_tn_htd"), ("TD", "vit_tn_td"),
                    ("TTD (MPS)", "vit_tn_ttd")];
    let cells = variants.iter()
        .map(|(_, tag)| VitCell::new(tag, None, BTreeMap::new()))
        .collect();
    let panel = vit_panel(rt, manifest, cells, &tcfg, &backbone,
                          sweep_jobs(cfg)?, log)?;
    let rows = variants.iter().zip(&panel)
        .map(|((name, _), r)| vec![
            name.to_string(),
            fmt_params(r.adapter_params),
            format!("{:.2}", 100.0 * r.best_metric),
        ])
        .collect();
    Ok((vec!["Tensor network", "#Adapter Params", "Accuracy %"], rows))
}

// ------------------------------------------------------------- Figure 6 ---

/// Unitarity error + wall-clock per mapping vs matrix size N (K = 4).
pub fn fig6(sizes: &[usize]) -> Table {
    let mut rows = Vec::new();
    let order = 18; // paper's P = 18
    for &n in sizes {
        for m in Mapping::all(order) {
            // givens/householder over full K get slow at large N — cap work
            if n > 1024 && matches!(m, Mapping::Givens) {
                continue;
            }
            let mut rng = Rng::new(42 ^ n as u64);
            let th = mappings::random_theta(&mut rng, n, 4, 0.3);
            let t0 = Instant::now();
            let q = mappings::orthogonal(&th, n, 4, m);
            let secs = t0.elapsed().as_secs_f64();
            let err = q.unitarity_error();
            rows.push(vec![
                n.to_string(),
                m.name(),
                format!("{err:.3e}"),
                format!("{:.2}", secs * 1e3),
            ]);
        }
        // Pauli circuit apply (the log-params path): measure the *apply*
        // to a batch of 32 vectors + materialized unitarity error
        if n.is_power_of_two() {
            let q_bits = n.trailing_zeros() as usize;
            let circ = crate::quantum::pauli::build(q_bits, 1);
            let mut rng = Rng::new(7 ^ n as u64);
            let th: Vec<f32> = (0..circ.num_params)
                .map(|_| rng.normal() as f32 * 0.5).collect();
            let mut x: Vec<f32> = (0..32 * n).map(|_| rng.normal() as f32).collect();
            let t0 = Instant::now();
            circ.apply(&mut x, 32, &th);
            let secs = t0.elapsed().as_secs_f64();
            let mat = circ.materialize(&th);
            let mat64 = crate::quantum::linalg::Mat {
                rows: n, cols: n,
                data: mat.iter().map(|&v| v as f64).collect(),
            };
            rows.push(vec![
                n.to_string(),
                "pauli (Q_P, L=1)".into(),
                format!("{:.3e}", mat64.unitarity_error()),
                format!("{:.2}", secs * 1e3),
            ]);
        }
    }
    (vec!["N", "Mapping", "Unitarity error", "Time ms"], rows)
}

// ------------------------------------------------- Fig 5 param counts ---

/// Parameter-count panel of Figure 5's tensor diagrams (per N, K).
pub fn fig5_params(n: usize, k: usize) -> Table {
    let rows = vec![
        vec!["LoRA (2-mode TTD)".into(), fmt_params(accounting::lora_params(n, n, k))],
        vec!["AdaLoRA (CP)".into(), fmt_params(accounting::adalora_params(n, n, k))],
        vec!["LoHa (Hadamard)".into(), fmt_params(accounting::loha_params(n, n, k))],
        vec!["LoKr (Kronecker)".into(), fmt_params(accounting::lokr_params(n, n, k, 8))],
        vec!["Quantum-PEFT Q_T".into(),
             fmt_params(accounting::qpeft_taylor_params(n, n, k, k))],
        vec!["Quantum-PEFT Q_P (L=1)".into(),
             fmt_params(accounting::qpeft_pauli_params(n, n, k, 1))],
    ];
    (vec!["Parameterization", "#Params / adapted weight"], rows)
}

pub fn print_table(title: &str, t: &Table) {
    println!("\n== {title} ==");
    print!("{}", render_table(&t.0, &t.1));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_is_analytic_and_exact() {
        let (h, rows) = table1();
        assert_eq!(h.len(), 7);
        assert_eq!(rows.len(), 9);
        // DeBERTa K=1 row: LoRA 36.86K (paper 36.9K)
        assert!(rows[0][2].contains("36.86K"));
    }

    #[test]
    fn fig6_rows_cover_mappings() {
        let (_, rows) = fig6(&[16, 32]);
        assert!(rows.iter().any(|r| r[1].contains("cayley")));
        assert!(rows.iter().any(|r| r[1].contains("pauli")));
        // exact mappings should report tiny error
        for r in &rows {
            if r[1] == "cayley" {
                let err: f64 = r[2].parse().unwrap();
                assert!(err < 1e-6);
            }
        }
    }

    #[test]
    fn fig5_ordering() {
        let (_, rows) = fig5_params(768, 4);
        // Q_P row must be the smallest count
        let parse = |s: &str| -> f64 {
            let s = s.trim();
            if let Some(x) = s.strip_suffix('K') {
                x.parse::<f64>().unwrap() * 1e3
            } else if let Some(x) = s.strip_suffix('M') {
                x.parse::<f64>().unwrap() * 1e6
            } else {
                s.parse().unwrap()
            }
        };
        let qp = parse(&rows[5][1]);
        for r in &rows[..5] {
            assert!(qp < parse(&r[1]), "Q_P not smallest vs {}", r[0]);
        }
    }
}
