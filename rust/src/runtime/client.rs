//! PJRT client wrapper + executable cache.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax >= 0.5 serialized protos — see /opt/xla-example/README.md); the
//! text parser reassigns instruction ids and round-trips cleanly.
//! Compiles are cached per artifact path: a sweep touching the same
//! (train, eval) computations across tasks/seeds compiles each exactly
//! once.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub struct Runtime {
    client: PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<PjRtLoadedExecutable>>>,
    pub compile_log: Mutex<Vec<(PathBuf, f64)>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()),
                     compile_log: Mutex::new(Vec::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp)
                .with_context(|| format!("XLA compile of {path:?}"))?,
        );
        let secs = t0.elapsed().as_secs_f64();
        self.compile_log.lock().unwrap().push((path.to_path_buf(), secs));
        self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Execute with literal inputs (owned or borrowed); returns the
    /// flattened output tuple.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self, exe: &PjRtLoadedExecutable, inputs: &[L])
        -> Result<Vec<xla::Literal>> {
        let bufs = exe.execute::<L>(inputs)
            .context("PJRT execute")?;
        let out = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple
        Ok(out.to_tuple()?)
    }

    pub fn total_compile_seconds(&self) -> f64 {
        self.compile_log.lock().unwrap().iter().map(|(_, s)| s).sum()
    }
}
