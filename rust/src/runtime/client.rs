//! PJRT client wrapper over the shared compile cache.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax >= 0.5 serialized protos — see /opt/xla-example/README.md); the
//! text parser reassigns instruction ids and round-trips cleanly.
//!
//! Compiles go through `runtime::exe_cache`: one `ExeCache` can back any
//! number of runtimes, sharing parsed HLO protos, the aggregated compile
//! log, and — for runtimes on the same client — the compiled executables
//! themselves, with in-flight deduplication under concurrency. A sweep
//! touching the same (train, eval) computations across workers, tasks and
//! seeds compiles each artifact path exactly once on backends that allow
//! client sharing (see `Runtime::for_worker`).

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};
use xla::PjRtClient;

use super::exe_cache::{CompileRecord, ExeCache};

pub struct Runtime {
    client: PjRtClient,
    cache: Arc<ExeCache>,
    client_id: u64,
    /// Pool worker this runtime serves (stamped into compile records).
    worker: Option<usize>,
}

impl Runtime {
    /// A CPU runtime with its own fresh compile cache.
    pub fn cpu() -> Result<Runtime> {
        Runtime::cpu_with_cache(Arc::new(ExeCache::new()), None)
    }

    /// A CPU runtime attached to an existing shared cache: parsed HLO
    /// protos and the aggregated compile log are shared with every other
    /// runtime on `cache`; compiled executables stay per-client (a PJRT
    /// executable is only valid on the client that compiled it).
    pub fn cpu_with_cache(cache: Arc<ExeCache>, worker: Option<usize>)
                          -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let client_id = cache.register_client();
        Ok(Runtime { client, cache, client_id, worker })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The shared compile cache this runtime loads through.
    pub fn cache(&self) -> &Arc<ExeCache> {
        &self.cache
    }

    /// Whether this runtime's client tolerates concurrent compilation and
    /// execution from multiple worker threads (so one compiled executable
    /// can serve the whole pool). True for host-side CPU PJRT; device
    /// backends with per-thread contexts must answer false and take the
    /// private-client fallback in [`Runtime::for_worker`]. Setting
    /// `REPRO_SHARE_CLIENT=0` forces false, which makes the fallback a
    /// live, testable path on CPU (and an A/B knob for benchmarking
    /// shared vs per-worker warm-up).
    ///
    /// NOTE for the real-bindings swap (rust/vendor/xla is a stub): the
    /// shared path also relies on `PjRtClient`/`PjRtLoadedExecutable`
    /// being `Sync` so `&Runtime` can cross pool threads. If the real
    /// types are not, or the native client is not safe under concurrent
    /// execute, this must return false — the fallback keeps parse-once
    /// and the aggregated log either way.
    pub fn supports_concurrent_execution(&self) -> bool {
        if let Ok(v) = std::env::var("REPRO_SHARE_CLIENT") {
            // setting the var at all signals intent to override: only an
            // explicit truthy value keeps sharing, so "0"/"off"/"no"/""
            // and any other spelling all force the private fallback
            // instead of silently doing nothing
            let v = v.trim().to_ascii_lowercase();
            if !matches!(v.as_str(), "1" | "true" | "yes" | "on") {
                return false;
            }
        }
        self.client.platform_name().starts_with("cpu")
    }

    /// A runtime handle for one pool worker: the caller's own client when
    /// the backend allows concurrent execution — every artifact then
    /// compiles exactly once for the whole pool — or, as the fallback, a
    /// private same-platform client on the same shared cache (parses
    /// exactly once; compiles once per worker; one aggregated log either
    /// way). A backend with no per-worker client constructor is an error,
    /// not a silent CPU substitution: jobs > 1 must never train on a
    /// different device than jobs = 1.
    pub fn for_worker(&self, worker: usize) -> Result<WorkerRuntime<'_>> {
        if self.supports_concurrent_execution() {
            Ok(WorkerRuntime::Shared(self))
        } else if self.client.platform_name().starts_with("cpu") {
            Ok(WorkerRuntime::Private(Runtime::cpu_with_cache(
                self.cache.clone(), Some(worker))?))
        } else {
            anyhow::bail!(
                "backend {:?} cannot share its client across sweep workers \
                 and has no per-worker client constructor; run with jobs=1",
                self.platform())
        }
    }

    /// Load + compile an HLO-text artifact through the shared cache
    /// (parse-once, compile-once per client, in-flight deduplicated).
    pub fn load(&self, path: &Path) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.cache.load(&self.client, self.client_id, path, self.worker)
    }

    /// Execute with literal inputs (owned or borrowed); returns the
    /// flattened output tuple.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self, exe: &xla::PjRtLoadedExecutable, inputs: &[L])
        -> Result<Vec<xla::Literal>> {
        let bufs = exe.execute::<L>(inputs)
            .context("PJRT execute")?;
        let buf = bufs.first().and_then(|d| d.first()).ok_or_else(|| {
            anyhow!("PJRT execute returned no output buffer \
                     (devices={}, first-device outputs={})",
                    bufs.len(), bufs.first().map_or(0, |d| d.len()))
        })?;
        let out = buf.to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple
        Ok(out.to_tuple()?)
    }

    /// Seconds spent in XLA compiles, aggregated across every runtime
    /// sharing this cache (all pool workers included).
    pub fn total_compile_seconds(&self) -> f64 {
        self.cache.log().total_compile_seconds()
    }

    /// Snapshot of the shared cache's parse/compile records.
    pub fn compile_log(&self) -> Vec<CompileRecord> {
        self.cache.log().snapshot()
    }
}

/// One pool worker's view of a runtime — either a borrow of the shared
/// runtime (backend allows concurrent execution; executables shared) or a
/// private runtime on the same cache (parse cache + log shared). Dropping
/// a private worker runtime evicts its executables from the shared cache:
/// its client id is never reused, so they could never be requested again
/// and would otherwise accumulate across panels.
pub enum WorkerRuntime<'a> {
    Shared(&'a Runtime),
    Private(Runtime),
}

impl WorkerRuntime<'_> {
    pub fn rt(&self) -> &Runtime {
        match self {
            WorkerRuntime::Shared(rt) => rt,
            WorkerRuntime::Private(rt) => rt,
        }
    }

    /// Whether this worker shares the caller's client (compile-once for
    /// the whole pool) or owns a private one. Serving and sweep drivers
    /// report this so benchmark output records which warm-up regime ran.
    pub fn is_shared(&self) -> bool {
        matches!(self, WorkerRuntime::Shared(_))
    }
}

impl Drop for WorkerRuntime<'_> {
    fn drop(&mut self) {
        if let WorkerRuntime::Private(rt) = self {
            rt.cache.evict_client(rt.client_id);
        }
    }
}
