//! Shared concurrent compile cache (`runtime::exe_cache`).
//!
//! Every `Runtime` loads artifacts through an `ExeCache`. The cache is a
//! process-wide (Arc-shared) subsystem with three guarantees the parallel
//! sweep/panel engines rely on:
//!
//! - **In-flight deduplication** (`OnceMap`): a path being compiled by
//!   one worker *blocks* — rather than re-compiles — in every other
//!   worker that requests it; all of them share the one result.
//! - **Parse-once, everywhere**: the HLO text proto for a path is parsed
//!   exactly once per process and shared across all clients on the cache.
//! - **Compile-once where the backend allows**: executables are keyed by
//!   (client id, path) because a PJRT executable is only valid on the
//!   client that compiled it. Workers that share one client (the CPU
//!   path — see `Runtime::for_worker`) therefore compile each distinct
//!   artifact path exactly once for the whole pool; workers that must
//!   own private clients fall back to one compile per (worker, path)
//!   while still sharing the parse cache and the aggregated log.
//!
//! The `CompileLog` aggregates every parse/compile across all sharing
//! runtimes, so `repro table`'s compile-time figure is the whole-pool
//! total no matter how many workers ran.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::obs::hist::Hist;
use crate::obs::metrics::{Class, Counter, MetricsRegistry};

// ---------------------------------------------------------------- OnceMap ---

use crate::util::panic_msg;

/// Per-cache observability handles: hit/miss/in-flight-dedup counters,
/// labeled `cache=<name>`. All `Volatile` — which worker wins the
/// compile race is scheduling-dependent.
#[derive(Clone, Debug)]
pub struct CacheObs {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    dedup_waits: Arc<Counter>,
}

impl CacheObs {
    pub fn register(reg: &MetricsRegistry, cache: &str) -> CacheObs {
        CacheObs {
            hits: reg.counter("exe_cache_hits_total", &[("cache", cache)], Class::Volatile),
            misses: reg
                .counter("exe_cache_misses_total", &[("cache", cache)], Class::Volatile),
            dedup_waits: reg.counter(
                "exe_cache_dedup_waits_total",
                &[("cache", cache)],
                Class::Volatile,
            ),
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    pub fn dedup_waits(&self) -> u64 {
        self.dedup_waits.get()
    }
}

enum SlotState<V> {
    InFlight,
    Ready(V),
    Failed(String),
}

struct Slot<V> {
    state: Mutex<SlotState<V>>,
    cv: Condvar,
}

/// Concurrent fill-once map with in-flight deduplication: for each key,
/// exactly one caller runs the init closure; concurrent callers for the
/// same key block until it finishes and then clone its result. A failed
/// init propagates its error to the initiator and to everyone already
/// waiting, and is *not* cached — the key becomes initializable again
/// (matching the old per-runtime cache, which retried failed compiles).
///
/// The init closure runs without any map-wide lock held, so inits for
/// different keys proceed in parallel; it must not recurse into the same
/// map with the same key (that would self-deadlock).
pub struct OnceMap<K, V> {
    slots: Mutex<HashMap<K, Arc<Slot<V>>>>,
    obs: OnceLock<CacheObs>,
}

impl<K, V> Default for OnceMap<K, V> {
    fn default() -> OnceMap<K, V> {
        OnceMap { slots: Mutex::new(HashMap::new()), obs: OnceLock::new() }
    }
}

impl<K: Clone + Eq + Hash, V: Clone> OnceMap<K, V> {
    pub fn new() -> OnceMap<K, V> {
        OnceMap::default()
    }

    /// Attach hit/miss/dedup counters. First call wins; later calls are
    /// no-ops (the map may already be shared across runtimes).
    pub fn instrument(&self, obs: CacheObs) {
        let _ = self.obs.set(obs);
    }

    /// Number of keys present (ready or in flight).
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remove every key matching `pred`. Used for client eviction; the
    /// caller must ensure no init for a matching key is still in flight
    /// (waiters already holding the slot are unaffected — they see its
    /// terminal state — but the key becomes initializable again).
    pub fn remove_where(&self, pred: impl Fn(&K) -> bool) {
        self.slots.lock().unwrap().retain(|k, _| !pred(k));
    }

    /// The cached value for `key`, or the result of running `init` —
    /// exactly once per key under any amount of concurrency.
    pub fn get_or_try_init<F>(&self, key: &K, init: F) -> Result<V>
    where
        F: FnOnce() -> Result<V>,
    {
        let (slot, claimed) = {
            let mut slots = self.slots.lock().unwrap();
            match slots.get(key) {
                Some(s) => (s.clone(), false),
                None => {
                    let s = Arc::new(Slot {
                        state: Mutex::new(SlotState::InFlight),
                        cv: Condvar::new(),
                    });
                    slots.insert(key.clone(), s.clone());
                    (s, true)
                }
            }
        };
        if claimed {
            if let Some(o) = self.obs.get() {
                o.misses.inc();
            }
            // contain init panics: a panic that left the slot InFlight
            // would deadlock every waiter (the pool catches the panic at
            // the cell boundary, but sibling workers block in here)
            let r = match std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(init)) {
                Ok(r) => r,
                Err(p) => Err(anyhow!("init panicked: {}", panic_msg(p.as_ref()))),
            };
            let mut st = slot.state.lock().unwrap();
            return match r {
                Ok(v) => {
                    *st = SlotState::Ready(v.clone());
                    slot.cv.notify_all();
                    Ok(v)
                }
                Err(e) => {
                    // alternate formatting renders the full context chain
                    // (root cause included) under real anyhow too
                    *st = SlotState::Failed(format!("{e:#}"));
                    slot.cv.notify_all();
                    drop(st);
                    // failures are retryable: forget the slot (waiters
                    // already hold an Arc to it and will see Failed)
                    self.slots.lock().unwrap().remove(key);
                    Err(e)
                }
            };
        }
        let mut st = slot.state.lock().unwrap();
        if let Some(o) = self.obs.get() {
            // an existing slot is a hit when its value is already
            // terminal, an in-flight-dedup wait otherwise
            if matches!(&*st, SlotState::InFlight) {
                o.dedup_waits.inc();
            } else {
                o.hits.inc();
            }
        }
        loop {
            match &*st {
                SlotState::Ready(v) => return Ok(v.clone()),
                SlotState::Failed(msg) => {
                    return Err(anyhow!("shared compile failed: {msg}"));
                }
                SlotState::InFlight => st = slot.cv.wait(st).unwrap(),
            }
        }
    }
}

// ------------------------------------------------------------- CompileLog ---

/// What kind of work a cache record describes: an HLO-text parse (shared
/// across all clients) or an XLA compile (per client).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheEvent {
    Parse,
    Compile,
}

/// One record in the aggregated compile log.
#[derive(Clone, Debug)]
pub struct CompileRecord {
    pub path: PathBuf,
    pub event: CacheEvent,
    pub secs: f64,
    /// Pool worker on whose behalf the work ran. Populated by
    /// private-client fallback runtimes (`Runtime::cpu_with_cache` with a
    /// worker tag, e.g. under `REPRO_SHARE_CLIENT=0`); `None` on the
    /// shared-client path, where a compile serves every worker at once
    /// and single-worker attribution would be arbitrary.
    pub worker: Option<usize>,
}

/// Thread-safe, append-only log of every parse/compile the cache ran,
/// aggregated across all runtimes sharing it.
pub struct CompileLog {
    records: Mutex<Vec<CompileRecord>>,
}

impl Default for CompileLog {
    fn default() -> CompileLog {
        CompileLog { records: Mutex::new(Vec::new()) }
    }
}

impl CompileLog {
    pub fn new() -> CompileLog {
        CompileLog::default()
    }

    pub fn record(&self, path: &Path, event: CacheEvent, secs: f64,
                  worker: Option<usize>) {
        self.records.lock().unwrap().push(CompileRecord {
            path: path.to_path_buf(),
            event,
            secs,
            worker,
        });
    }

    /// Snapshot of all records, in recording order.
    pub fn snapshot(&self) -> Vec<CompileRecord> {
        self.records.lock().unwrap().clone()
    }

    /// Total seconds spent in XLA compiles (parses excluded).
    pub fn total_compile_seconds(&self) -> f64 {
        self.records.lock().unwrap().iter()
            .filter(|r| r.event == CacheEvent::Compile)
            .map(|r| r.secs)
            .sum()
    }

    /// Compile count per artifact path — the "each distinct path compiles
    /// exactly once" guard asserted by the parallel-panel tests.
    pub fn compiles_per_path(&self) -> BTreeMap<PathBuf, usize> {
        let mut out = BTreeMap::new();
        for r in self.records.lock().unwrap().iter() {
            if r.event == CacheEvent::Compile {
                *out.entry(r.path.clone()).or_insert(0) += 1;
            }
        }
        out
    }
}

// --------------------------------------------------------------- ExeCache ---

/// The shared artifact cache: parse-once HLO protos, compile-once
/// executables per client, one aggregated log. Construct once, wrap in an
/// `Arc`, and hand to every `Runtime` that should share warm-up work.
pub struct ExeCache {
    protos: OnceMap<PathBuf, Arc<HloModuleProto>>,
    exes: OnceMap<(u64, PathBuf), Arc<PjRtLoadedExecutable>>,
    log: CompileLog,
    next_client: AtomicU64,
    compile_ns: OnceLock<Arc<Hist>>,
}

impl Default for ExeCache {
    fn default() -> ExeCache {
        ExeCache {
            protos: OnceMap::new(),
            exes: OnceMap::new(),
            log: CompileLog::new(),
            next_client: AtomicU64::new(0),
            compile_ns: OnceLock::new(),
        }
    }
}

impl ExeCache {
    pub fn new() -> ExeCache {
        ExeCache::default()
    }

    /// Register this cache's metrics on `reg`: hit/miss/dedup counters
    /// for both the parse and executable maps, plus a compile
    /// wall-time histogram. First call wins (the cache may be shared).
    pub fn instrument(&self, reg: &MetricsRegistry) {
        self.protos.instrument(CacheObs::register(reg, "hlo_proto"));
        self.exes.instrument(CacheObs::register(reg, "exe"));
        let _ = self
            .compile_ns
            .set(reg.hist("exe_compile_ns", &[], Class::Volatile));
    }

    /// Register one PJRT client with this cache, returning its executable
    /// namespace id. Compiled executables never cross client ids (a PJRT
    /// executable is only valid on the client that compiled it); parsed
    /// protos and the log are shared across all of them.
    pub fn register_client(&self) -> u64 {
        self.next_client.fetch_add(1, Ordering::Relaxed)
    }

    /// The aggregated parse/compile log.
    pub fn log(&self) -> &CompileLog {
        &self.log
    }

    /// Number of distinct (client, path) executables currently cached or
    /// in flight.
    pub fn cached_executables(&self) -> usize {
        self.exes.len()
    }

    /// Drop every executable compiled for one client. Called when a
    /// private worker runtime is released: its client id is never handed
    /// out again, so its executables could otherwise never be requested —
    /// or, under real PJRT, even remain valid — yet would stay alive in
    /// the process-wide map. Parsed protos and the log are kept.
    pub fn evict_client(&self, client_id: u64) {
        self.exes.remove_where(|(id, _)| *id == client_id);
    }

    /// Parse-once: the HLO text proto for `path`, shared across clients.
    pub fn proto(&self, path: &Path, worker: Option<usize>)
                 -> Result<Arc<HloModuleProto>> {
        self.protos.get_or_try_init(&path.to_path_buf(), || {
            let t0 = Instant::now();
            let proto = HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            self.log.record(path, CacheEvent::Parse,
                            t0.elapsed().as_secs_f64(), worker);
            Ok(Arc::new(proto))
        })
    }

    /// Load + compile an artifact for one client — compile-once per
    /// (client, path), with concurrent requests for the same executable
    /// blocking on the in-flight compile instead of duplicating it.
    pub fn load(&self, client: &PjRtClient, client_id: u64, path: &Path,
                worker: Option<usize>) -> Result<Arc<PjRtLoadedExecutable>> {
        self.exes.get_or_try_init(&(client_id, path.to_path_buf()), || {
            let proto = self.proto(path, worker)?;
            let t0 = Instant::now();
            let comp = XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)
                .with_context(|| format!("XLA compile of {path:?}"))?;
            let secs = t0.elapsed().as_secs_f64();
            self.log.record(path, CacheEvent::Compile, secs, worker);
            if let Some(h) = self.compile_ns.get() {
                h.record((secs * 1e9) as u64);
            }
            Ok(Arc::new(exe))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn once_map_initializes_each_key_exactly_once_under_contention() {
        let map: OnceMap<PathBuf, u64> = OnceMap::new();
        let log = CompileLog::new();
        let inits = AtomicUsize::new(0);
        const THREADS: usize = 8;
        const PATHS: usize = 5;
        std::thread::scope(|scope| {
            for w in 0..THREADS {
                let map = &map;
                let log = &log;
                let inits = &inits;
                scope.spawn(move || {
                    for p in 0..PATHS {
                        let path = PathBuf::from(format!("artifacts/{p}.hlo"));
                        let v = map.get_or_try_init(&path, || {
                            inits.fetch_add(1, Ordering::SeqCst);
                            // widen the in-flight window so threads pile up
                            std::thread::sleep(Duration::from_millis(5));
                            log.record(&path, CacheEvent::Compile, 0.005,
                                       Some(w));
                            Ok(p as u64 * 10)
                        }).unwrap();
                        assert_eq!(v, p as u64 * 10);
                    }
                });
            }
        });
        assert_eq!(inits.load(Ordering::SeqCst), PATHS,
                   "a concurrent request re-ran an init");
        let per_path = log.compiles_per_path();
        assert_eq!(per_path.len(), PATHS);
        for (path, n) in per_path {
            assert_eq!(n, 1, "{path:?} compiled more than once");
        }
    }

    #[test]
    fn failed_init_propagates_and_is_retryable() {
        let map: OnceMap<u32, u32> = OnceMap::new();
        let e = map.get_or_try_init(&7, || Err(anyhow!("no backend")))
            .unwrap_err();
        assert!(e.to_string().contains("no backend"), "{e}");
        // the failure is not cached: a later caller re-runs init
        let v = map.get_or_try_init(&7, || Ok(42)).unwrap();
        assert_eq!(v, 42);
        assert_eq!(map.len(), 1);
        // and the ready value sticks
        let v = map.get_or_try_init(&7, || Ok(99)).unwrap();
        assert_eq!(v, 42);
    }

    #[test]
    fn panicking_init_fails_cleanly_and_releases_the_key() {
        let map: OnceMap<u32, u32> = OnceMap::new();
        let e = map.get_or_try_init(&3, || panic!("compile exploded"))
            .unwrap_err();
        assert!(e.to_string().contains("compile exploded"), "{e}");
        // the key is retryable afterwards, exactly like an Err init
        assert_eq!(map.get_or_try_init(&3, || Ok(9)).unwrap(), 9);
    }

    #[test]
    fn waiters_observe_the_in_flight_failure_or_retry_cleanly() {
        use std::sync::atomic::AtomicBool;
        let map: OnceMap<u32, u32> = OnceMap::new();
        let entered = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let map = &map;
            let entered = &entered;
            scope.spawn(move || {
                let r = map.get_or_try_init(&1, || {
                    entered.store(true, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(40));
                    Err(anyhow!("boom"))
                });
                assert!(r.unwrap_err().to_string().contains("boom"));
            });
            scope.spawn(move || {
                while !entered.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                // arrives during the in-flight failure (gets its error) or
                // just after the retryable removal (runs its own init)
                match map.get_or_try_init(&1, || Ok(5)) {
                    Err(e) => assert!(e.to_string().contains("boom"), "{e}"),
                    Ok(v) => assert_eq!(v, 5),
                }
            });
        });
    }

    #[test]
    fn remove_where_evicts_one_client_namespace_and_allows_reinit() {
        let map: OnceMap<(u64, PathBuf), u32> = OnceMap::new();
        let inits = AtomicUsize::new(0);
        let get = |id: u64, p: &str| {
            map.get_or_try_init(&(id, PathBuf::from(p)), || {
                inits.fetch_add(1, Ordering::SeqCst);
                Ok(id as u32)
            }).unwrap()
        };
        assert_eq!(get(0, "a.hlo"), 0);
        assert_eq!(get(1, "a.hlo"), 1);
        assert_eq!(get(1, "b.hlo"), 1);
        assert_eq!(map.len(), 3);
        // evict client 1: its keys go, client 0's survive
        map.remove_where(|(id, _)| *id == 1);
        assert_eq!(map.len(), 1);
        assert_eq!(get(0, "a.hlo"), 0); // still cached
        assert_eq!(inits.load(Ordering::SeqCst), 3);
        assert_eq!(get(1, "a.hlo"), 1); // evicted: re-initializable
        assert_eq!(inits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn exe_cache_namespaces_clients_and_shares_the_log() {
        let cache = ExeCache::new();
        let a = cache.register_client();
        let b = cache.register_client();
        assert_ne!(a, b);
        assert_eq!(cache.cached_executables(), 0);
        assert_eq!(cache.log().total_compile_seconds(), 0.0);
        assert!(cache.log().compiles_per_path().is_empty());
        cache.log().record(Path::new("x.hlo"), CacheEvent::Compile, 1.5, None);
        cache.log().record(Path::new("x.hlo"), CacheEvent::Parse, 0.5, Some(2));
        assert!((cache.log().total_compile_seconds() - 1.5).abs() < 1e-12);
        assert_eq!(cache.log().compiles_per_path()[Path::new("x.hlo")], 1);
        assert_eq!(cache.log().snapshot().len(), 2);
    }

    #[test]
    fn once_map_obs_counts_hits_misses_and_dedup_waits() {
        let reg = MetricsRegistry::new(false);
        let map: OnceMap<u32, u32> = OnceMap::new();
        map.instrument(CacheObs::register(&reg, "unit"));
        assert_eq!(map.get_or_try_init(&1, || Ok(10)).unwrap(), 10);
        assert_eq!(map.get_or_try_init(&1, || Ok(99)).unwrap(), 10);
        assert_eq!(map.get_or_try_init(&2, || Ok(20)).unwrap(), 20);
        // re-registering the same cache name shares the counters
        let obs = CacheObs::register(&reg, "unit");
        assert_eq!(obs.misses(), 2);
        assert_eq!(obs.hits(), 1);
        assert_eq!(obs.dedup_waits(), 0);

        // dedup: a second caller arriving mid-init waits, not re-runs
        let entered = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|scope| {
            let map = &map;
            let entered = &entered;
            scope.spawn(move || {
                map.get_or_try_init(&3, || {
                    entered.store(true, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(30));
                    Ok(30)
                })
                .unwrap();
            });
            scope.spawn(move || {
                while !entered.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                assert_eq!(map.get_or_try_init(&3, || Ok(99)).unwrap(), 30);
            });
        });
        assert_eq!(obs.misses(), 3);
        assert_eq!(obs.dedup_waits() + obs.hits(), 2,
                   "the second caller either waited in flight or hit");
    }

    #[test]
    fn exe_cache_load_fails_loudly_without_bindings() {
        // the offline xla stub cannot parse/compile; the cache must
        // surface that with path context and cache nothing for the key
        let cache = ExeCache::new();
        let client = PjRtClient::cpu().unwrap();
        let id = cache.register_client();
        let err = cache.load(&client, id, Path::new("/nonexistent.hlo"), None)
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("/nonexistent.hlo"), "{msg}");
        assert_eq!(cache.log().snapshot().len(), 0);
        assert_eq!(cache.cached_executables(), 0);
    }
}
