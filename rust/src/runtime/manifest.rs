//! artifacts/manifest.json parsing — the contract emitted by
//! python/compile/aot.py. After `make artifacts`, this file fully
//! describes every computation's I/O so the coordinator never needs
//! Python.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j.get("shape")?.as_arr()?
                .iter().map(|x| x.as_usize()).collect::<Result<_>>()?,
            dtype: DType::parse(j.get("dtype")?.as_str()?)?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub tag: String,
    pub model: String,
    pub method: String,
    pub task: String,
    pub init_file: PathBuf,
    pub train_file: PathBuf,
    pub eval_file: PathBuf,
    pub frozen: Vec<TensorSpec>,
    pub trainable: Vec<TensorSpec>,
    pub extras: Vec<String>,
    pub batch: Vec<TensorSpec>,
    pub trainable_param_count: usize,
    pub adapter_param_count: usize,
    pub total_param_count: usize,
    pub cfg: BTreeMap<String, f64>,
    /// Numeric method hyperparameters (k, order, n_layers, ...).
    pub method_kw: BTreeMap<String, f64>,
}

impl ArtifactEntry {
    pub fn batch_size(&self) -> usize {
        self.batch.first().map(|b| b.shape[0]).unwrap_or(0)
    }

    /// Number of train-step inputs:
    /// frozen + 3*trainable + (step, lr, wd) + extras + batch.
    pub fn train_input_count(&self) -> usize {
        self.frozen.len() + 3 * self.trainable.len() + 3 + self.extras.len()
            + self.batch.len()
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = BTreeMap::new();
        for (tag, entry) in root.get("artifacts")?.as_obj()? {
            let files = entry.get("files")?;
            let cfg = entry.get("cfg")?.as_obj()?
                .iter()
                .filter_map(|(k, v)| v.as_f64().ok().map(|f| (k.clone(), f)))
                .collect();
            let method_kw = entry.opt("method_kw")
                .and_then(|m| m.as_obj().ok())
                .map(|m| m.iter()
                     .filter_map(|(k, v)| v.as_f64().ok().map(|f| (k.clone(), f)))
                     .collect())
                .unwrap_or_default();
            artifacts.insert(tag.clone(), ArtifactEntry {
                tag: tag.clone(),
                model: entry.get("model")?.as_str()?.to_string(),
                method: entry.get("method")?.as_str()?.to_string(),
                task: entry.get("task")?.as_str()?.to_string(),
                init_file: dir.join(files.get("init")?.as_str()?),
                train_file: dir.join(files.get("train")?.as_str()?),
                eval_file: dir.join(files.get("eval")?.as_str()?),
                frozen: entry.get("frozen")?.as_arr()?
                    .iter().map(TensorSpec::from_json).collect::<Result<_>>()?,
                trainable: entry.get("trainable")?.as_arr()?
                    .iter().map(TensorSpec::from_json).collect::<Result<_>>()?,
                extras: entry.get("extras")?.as_arr()?
                    .iter().map(|x| Ok(x.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
                batch: entry.get("batch")?.as_arr()?
                    .iter().map(TensorSpec::from_json).collect::<Result<_>>()?,
                trainable_param_count: entry.get("trainable_param_count")?
                    .as_usize()?,
                adapter_param_count: entry.get("adapter_param_count")?
                    .as_usize()?,
                total_param_count: entry.get("total_param_count")?.as_usize()?,
                cfg,
                method_kw,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, tag: &str) -> Result<&ArtifactEntry> {
        self.artifacts.get(tag).with_context(|| {
            format!("artifact {tag:?} not in manifest (have: {:?})",
                    self.artifacts.keys().take(8).collect::<Vec<_>>())
        })
    }

    /// Default artifacts directory: $REPRO_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("REPRO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_synthetic_manifest() {
        let dir = std::env::temp_dir().join("qp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let j = r#"{"artifacts": {"toy": {
            "tag": "toy", "model": "encoder", "method": "lora", "task": "cls",
            "files": {"init": "t.init", "train": "t.train", "eval": "t.eval"},
            "frozen": [{"name": "base.w", "shape": [4, 4], "dtype": "float32"}],
            "trainable": [{"name": "head.w", "shape": [4, 2], "dtype": "float32"}],
            "extras": ["task_kind"],
            "batch": [{"name": "tokens", "shape": [8, 16], "dtype": "int32"}],
            "cfg": {"d": 64, "vocab": 256},
            "trainable_param_count": 8, "adapter_param_count": 0,
            "total_param_count": 24}}, "version": 1}"#;
        std::fs::write(dir.join("manifest.json"), j).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.get("toy").unwrap();
        assert_eq!(e.frozen[0].numel(), 16);
        assert_eq!(e.batch_size(), 8);
        assert_eq!(e.train_input_count(), 1 + 3 + 3 + 1 + 1);
        assert_eq!(e.cfg["d"], 64.0);
        assert!(m.get("missing").is_err());
    }

    #[test]
    fn dtype_rejects_unknown() {
        assert!(DType::parse("bfloat16").is_err());
    }
}
