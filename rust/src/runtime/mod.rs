//! PJRT runtime: load AOT artifacts (HLO text) once, execute them from
//! the coordinator's hot path. Python never runs here.

pub mod client;
pub mod exe_cache;
pub mod manifest;
pub mod session;
pub mod tensors;

pub use client::{Runtime, WorkerRuntime};
pub use exe_cache::ExeCache;
pub use manifest::{ArtifactEntry, DType, Manifest, TensorSpec};
pub use session::TrainSession;
pub use tensors::HostTensor;
