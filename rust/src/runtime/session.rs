//! TrainSession — one fine-tuning run of one artifact config.
//!
//! Owns the parameter state as XLA literals. Frozen backbone tensors are
//! converted to literals once and *borrowed* into every step (host
//! memcpy only at PJRT ingestion); trainable/optimizer state cycles
//! through the step outputs. Argument layout is the aot.py contract:
//!
//!   train: (frozen..., train..., m..., v..., step, lr, wd, extras..., batch...)
//!          -> (loss, train', m', v')
//!   eval:  (frozen..., train..., extras..., batch_x) -> (logits,)

use anyhow::{bail, Context, Result};
use xla::Literal;

use super::client::Runtime;
use super::manifest::ArtifactEntry;
use super::tensors::HostTensor;

pub struct TrainSession<'rt> {
    rt: &'rt Runtime,
    pub entry: ArtifactEntry,
    train_exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    eval_exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    pub frozen: Vec<Literal>,
    pub train: Vec<Literal>,
    m: Vec<Literal>,
    v: Vec<Literal>,
    pub step_count: usize,
}

impl<'rt> TrainSession<'rt> {
    /// Initialize from the artifact's init computation at `seed`.
    pub fn new(rt: &'rt Runtime, entry: &ArtifactEntry, seed: i32)
               -> Result<TrainSession<'rt>> {
        let init_exe = rt.load(&entry.init_file)?;
        let train_exe = rt.load(&entry.train_file)?;
        let eval_exe = rt.load(&entry.eval_file)?;
        let outs = rt.run(&init_exe, &[Literal::scalar(seed)])
            .context("running init artifact")?;
        let nf = entry.frozen.len();
        let nt = entry.trainable.len();
        if outs.len() != nf + nt {
            bail!("init returned {} tensors, manifest says {}+{}",
                  outs.len(), nf, nt);
        }
        let mut it = outs.into_iter();
        let frozen: Vec<Literal> = (&mut it).take(nf).collect();
        let train: Vec<Literal> = it.collect();
        let zeros = |specs: &[super::manifest::TensorSpec]| -> Result<Vec<Literal>> {
            specs.iter()
                .map(|s| HostTensor::zeros_like_spec(s).to_literal())
                .collect()
        };
        Ok(TrainSession {
            rt,
            entry: entry.clone(),
            train_exe,
            eval_exe,
            frozen,
            m: zeros(&entry.trainable)?,
            v: zeros(&entry.trainable)?,
            train,
            step_count: 0,
        })
    }

    /// Replace tensors by name from a checkpoint (pretrained backbone).
    /// Tensors whose name or shape does not match this config are
    /// *skipped* — a pretraining checkpoint legitimately carries a
    /// different task head (DAE vocab head vs 2-class classifier) that
    /// the fine-tune config re-initializes. Returns how many loaded.
    pub fn load_named(&mut self, named: &[(String, HostTensor)]) -> Result<usize> {
        let mut loaded = 0;
        for (name, tensor) in named {
            if let Some(ix) = self.entry.frozen.iter().position(|s| &s.name == name) {
                if tensor.matches_spec(&self.entry.frozen[ix]) {
                    self.frozen[ix] = tensor.to_literal()?;
                    loaded += 1;
                }
            } else if let Some(ix) =
                self.entry.trainable.iter().position(|s| &s.name == name)
            {
                if tensor.matches_spec(&self.entry.trainable[ix]) {
                    self.train[ix] = tensor.to_literal()?;
                    loaded += 1;
                }
            }
        }
        Ok(loaded)
    }

    /// Apply a host-side transform to every frozen f32 tensor (base-model
    /// quantization for Tables 6/7).
    pub fn map_frozen(&mut self, f: impl Fn(&str, &mut Vec<f32>)) -> Result<()> {
        for (spec, lit) in self.entry.frozen.clone().iter().zip(self.frozen.iter_mut()) {
            let ht = HostTensor::from_literal(lit)?;
            if let HostTensor::F32 { shape, mut data } = ht {
                f(&spec.name, &mut data);
                *lit = HostTensor::f32(shape, data).to_literal()?;
            }
        }
        Ok(())
    }

    /// One fused AdamW step. `extras` must match entry.extras in length.
    pub fn step(&mut self, batch: &[HostTensor], lr: f32, wd: f32,
                extras: &[f32]) -> Result<f32> {
        if extras.len() != self.entry.extras.len() {
            bail!("expected {} extras ({:?}), got {}",
                  self.entry.extras.len(), self.entry.extras, extras.len());
        }
        if batch.len() != self.entry.batch.len() {
            bail!("expected {} batch tensors, got {}",
                  self.entry.batch.len(), batch.len());
        }
        self.step_count += 1;
        let mut args: Vec<&Literal> = Vec::with_capacity(
            self.entry.train_input_count());
        args.extend(self.frozen.iter());
        args.extend(self.train.iter());
        args.extend(self.m.iter());
        args.extend(self.v.iter());
        let scalars = [
            Literal::scalar(self.step_count as f32),
            Literal::scalar(lr),
            Literal::scalar(wd),
        ];
        args.extend(scalars.iter());
        let extra_lits: Vec<Literal> =
            extras.iter().map(|&e| Literal::scalar(e)).collect();
        args.extend(extra_lits.iter());
        let batch_lits: Vec<Literal> = batch.iter()
            .map(|t| t.to_literal()).collect::<Result<_>>()?;
        args.extend(batch_lits.iter());

        let outs = self.rt.run(&self.train_exe, &args)?;
        let nt = self.train.len();
        if outs.len() != 1 + 3 * nt {
            bail!("train step returned {} tensors, expected {}",
                  outs.len(), 1 + 3 * nt);
        }
        let mut it = outs.into_iter();
        let loss_lit = it.next().unwrap();
        let loss = loss_lit.to_vec::<f32>()?[0];
        self.train = (&mut it).take(nt).collect();
        self.m = (&mut it).take(nt).collect();
        self.v = it.collect();
        Ok(loss)
    }

    /// Forward pass: logits for one eval batch.
    pub fn eval(&self, batch_x: &HostTensor, extras: &[f32]) -> Result<HostTensor> {
        let mut args: Vec<&Literal> = Vec::new();
        args.extend(self.frozen.iter());
        args.extend(self.train.iter());
        let extra_lits: Vec<Literal> =
            extras.iter().map(|&e| Literal::scalar(e)).collect();
        args.extend(extra_lits.iter());
        let x = batch_x.to_literal()?;
        args.push(&x);
        let outs = self.rt.run(&self.eval_exe, &args)?;
        let logits = match outs.into_iter().next() {
            Some(l) => l,
            None => bail!("eval artifact for {:?} returned an empty output \
                           tuple (expected logits)", self.entry.tag),
        };
        HostTensor::from_literal(&logits)
    }

    /// Snapshot all state as named host tensors (checkpointing).
    pub fn export_named(&self) -> Result<Vec<(String, HostTensor)>> {
        let mut out = Vec::new();
        for (spec, lit) in self.entry.frozen.iter().zip(&self.frozen) {
            out.push((spec.name.clone(), HostTensor::from_literal(lit)?));
        }
        for (spec, lit) in self.entry.trainable.iter().zip(&self.train) {
            out.push((spec.name.clone(), HostTensor::from_literal(lit)?));
        }
        Ok(out)
    }

    /// Trainable-only snapshot — what a PEFT checkpoint stores (the
    /// paper's storage story: adapters are the only delta).
    pub fn export_adapters(&self) -> Result<Vec<(String, HostTensor)>> {
        let mut out = Vec::new();
        for (spec, lit) in self.entry.trainable.iter().zip(&self.train) {
            out.push((spec.name.clone(), HostTensor::from_literal(lit)?));
        }
        Ok(out)
    }
}
