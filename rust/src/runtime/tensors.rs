//! Host tensors and Literal marshalling — the only place where raw data
//! crosses the Rust/XLA boundary.

use anyhow::{bail, Result};
use xla::Literal;

use super::manifest::{DType, TensorSpec};

/// A host-side tensor (f32 or i32) with shape.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros_like_spec(spec: &TensorSpec) -> HostTensor {
        match spec.dtype {
            DType::F32 => HostTensor::F32 { shape: spec.shape.clone(),
                                            data: vec![0.0; spec.numel()] },
            DType::I32 => HostTensor::I32 { shape: spec.shape.clone(),
                                            data: vec![0; spec.numel()] },
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Host -> XLA literal (reshaped to the stored dims).
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => Literal::vec1(data),
            HostTensor::I32 { data, .. } => Literal::vec1(data),
        };
        if dims.len() == 1 {
            return Ok(lit);
        }
        Ok(lit.reshape(&dims)?)
    }

    /// XLA literal -> host (shape taken from the literal itself).
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    pub fn matches_spec(&self, spec: &TensorSpec) -> bool {
        let dt_ok = matches!(
            (self, spec.dtype),
            (HostTensor::F32 { .. }, DType::F32) | (HostTensor::I32 { .. }, DType::I32)
        );
        dt_ok && self.shape() == spec.shape.as_slice()
    }
}

/// Batch assembly: stack rows of token sequences into an i32 [b, t] tensor.
pub fn stack_tokens(rows: &[Vec<u32>]) -> HostTensor {
    let b = rows.len();
    let t = rows.first().map(|r| r.len()).unwrap_or(0);
    let mut data = Vec::with_capacity(b * t);
    for r in rows {
        assert_eq!(r.len(), t, "ragged token batch");
        data.extend(r.iter().map(|&x| x as i32));
    }
    HostTensor::i32(vec![b, t], data)
}

/// Stack f32 feature rows into [b, ...dims].
pub fn stack_f32(rows: &[Vec<f32>], item_shape: &[usize]) -> HostTensor {
    let b = rows.len();
    let numel: usize = item_shape.iter().product();
    let mut data = Vec::with_capacity(b * numel);
    for r in rows {
        assert_eq!(r.len(), numel, "row size mismatch");
        data.extend_from_slice(r);
    }
    let mut shape = vec![b];
    shape.extend_from_slice(item_shape);
    HostTensor::f32(shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let l = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&l).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_and_scalar() {
        let t = HostTensor::i32(vec![4], vec![7, -1, 0, 3]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        let s = HostTensor::scalar_f32(2.5);
        let back = HostTensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_f32().unwrap(), &[2.5]);
    }

    #[test]
    fn stacking() {
        let t = stack_tokens(&[vec![1, 2, 3], vec![4, 5, 6]]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.as_i32().unwrap(), &[1, 2, 3, 4, 5, 6]);
        let f = stack_f32(&[vec![0.0; 6], vec![1.0; 6]], &[2, 3]);
        assert_eq!(f.shape(), &[2, 2, 3]);
    }

    #[test]
    fn spec_matching() {
        use crate::runtime::manifest::{DType, TensorSpec};
        let spec = TensorSpec { name: "x".into(), shape: vec![2, 3],
                                dtype: DType::F32 };
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).matches_spec(&spec));
        assert!(!HostTensor::i32(vec![2, 3], vec![0; 6]).matches_spec(&spec));
        assert!(!HostTensor::f32(vec![3, 2], vec![0.0; 6]).matches_spec(&spec));
    }
}
