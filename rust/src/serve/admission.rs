//! Admission control: per-tenant token-bucket rate limits and a global
//! queue-depth cap, enforced at the serving front door
//! ([`crate::serve::server::ServerHandle::submit`]) *before* a request is
//! ever enqueued.
//!
//! Rejected requests fail fast with the typed [`Rejected`] error — they
//! never consume a batcher slot, a queue entry, or a worker. Open-loop
//! drivers (the loadgen, `repro serve-bench`) recover the type with
//! `anyhow`'s `downcast_ref`, count the shed share, and keep going
//! instead of aborting the run. Per-tenant and global rejection counters
//! are exported at session end as `serve_admission` /
//! `serve_admission_tenant` EventLog lines (see
//! [`crate::serve::server::ServeSummary::emit`]).
//!
//! Two clocks, preserving the [`crate::serve`] fifo-determinism contract:
//! - **wall** (timed mode): buckets refill on `Instant` time and the
//!   queue cap reads the server's real outstanding gauge — true
//!   backpressure under overload;
//! - **logical** (fifo mode): the clock moves only when the driver calls
//!   [`AdmissionController::advance`] — the open-loop loadgen advances it
//!   by its seeded interarrival gaps instead of sleeping — and the queue
//!   cap reads the deterministic buffered backlog. Every admission
//!   decision is then a pure function of the submission sequence, so
//!   rejection counts and the response log stay byte-identical at any
//!   worker count.

use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Instant, SystemTime};

use anyhow::{bail, Context, Result};

use crate::coordinator::events::EventLog;
use crate::util::json::Json;
use crate::util::sync::{lock_or_recover, read_or_recover, write_or_recover};

use super::spool::FileWatch;

/// Why admission turned a request away.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The tenant's token bucket was empty.
    RateLimited,
    /// The global queue-depth cap was reached.
    QueueFull,
    /// The shard this tenant routes to is down (sharded tier only — the
    /// [`super::shard`] router sheds instead of queueing behind a dead
    /// shard; a restarted shard serves the tenant again).
    ShardDown,
}

impl RejectReason {
    pub fn as_str(self) -> &'static str {
        match self {
            RejectReason::RateLimited => "rate_limited",
            RejectReason::QueueFull => "queue_full",
            RejectReason::ShardDown => "shard_down",
        }
    }
}

/// Typed fail-fast admission error. Implements `std::error::Error`, so it
/// converts into `anyhow::Error` through `?` and stays recoverable on the
/// caller side via `err.downcast_ref::<Rejected>()` however much context
/// wraps it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Rejected {
    pub tenant: String,
    pub reason: RejectReason,
}

impl fmt::Display for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "tenant {:?} rejected at admission: {}", self.tenant, self.reason.as_str())
    }
}

impl std::error::Error for Rejected {}

/// Admission policy knobs. The all-zeros default admits everything.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Sustained per-tenant admission rate in requests per second
    /// (logical seconds in fifo mode). `0.0` disables rate limiting.
    pub rate_rps: f64,
    /// Token-bucket capacity: how many requests a tenant may burst above
    /// the sustained rate. Clamped to at least 1 when rate limiting is
    /// on (a bucket that can never hold one token admits nothing).
    pub burst: f64,
    /// Global queue-depth cap (`0` disables): timed mode caps the real
    /// outstanding-request count, fifo mode the deterministic buffered
    /// backlog (see the module docs).
    pub max_queue: usize,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig { rate_rps: 0.0, burst: 1.0, max_queue: 0 }
    }
}

impl AdmissionConfig {
    pub fn enabled(&self) -> bool {
        self.rate_rps > 0.0 || self.max_queue > 0
    }

    /// Parse the `--admission-config` file format: a JSON object with
    /// optional `rate_rps`, `burst` and `max_queue` keys (`{}` disables
    /// admission). An absent `burst` with a positive `rate_rps`
    /// defaults to one second's worth of the rate, matching the
    /// `--rate-rps` CLI behavior. Unknown keys are **errors**, not
    /// ignored: a typo'd limit in a hot-reloaded file must never
    /// silently disable admission control on a live server.
    pub fn from_json(text: &str) -> Result<AdmissionConfig> {
        Ok(AdmissionConfig::from_json_spec(text)?.0)
    }

    /// [`from_json`](AdmissionConfig::from_json) plus whether the file
    /// *explicitly pinned* `burst` — the CLI needs this to decide if
    /// the one-second's-worth default should re-derive after a
    /// `--rate-rps` flag overrides the file's rate.
    pub fn from_json_spec(text: &str) -> Result<(AdmissionConfig, bool)> {
        let j = Json::parse(text).context("admission config is not valid JSON")?;
        let obj = j.as_obj().context("admission config must be a JSON object")?;
        for key in obj.keys() {
            if !matches!(key.as_str(), "rate_rps" | "burst" | "max_queue") {
                bail!("admission config has unknown key {key:?} (expected \
                       rate_rps, burst, max_queue)");
            }
        }
        let mut cfg = AdmissionConfig::default();
        if let Some(v) = j.opt("rate_rps") {
            cfg.rate_rps = v.as_f64().context("admission config rate_rps")?;
        }
        if !cfg.rate_rps.is_finite() || cfg.rate_rps < 0.0 {
            bail!("admission config rate_rps must be finite and >= 0, got {}",
                  cfg.rate_rps);
        }
        let mut burst_pinned = false;
        match j.opt("burst") {
            Some(v) => {
                cfg.burst = v.as_f64().context("admission config burst")?;
                burst_pinned = true;
            }
            // default burst: one second's worth of the sustained rate
            None if cfg.rate_rps > 0.0 => {
                cfg.burst = cfg.rate_rps.max(1.0);
            }
            None => {}
        }
        if !cfg.burst.is_finite() || cfg.burst < 0.0 {
            bail!("admission config burst must be finite and >= 0, got {}",
                  cfg.burst);
        }
        if let Some(v) = j.opt("max_queue") {
            // validate the raw number: as_usize would saturate a
            // negative (sign typo) to 0 = "no queue cap", silently
            // disabling protection on a live reload
            let raw = v.as_f64().context("admission config max_queue")?;
            if !raw.is_finite() || raw < 0.0 || raw.fract() != 0.0 {
                bail!("admission config max_queue must be a non-negative \
                       integer, got {raw}");
            }
            cfg.max_queue = raw as usize;
        }
        Ok((cfg, burst_pinned))
    }
}

enum Clock {
    Wall(Instant),
    /// Seconds, advanced only by [`AdmissionController::advance`].
    Logical(Mutex<f64>),
}

struct Bucket {
    tokens: f64,
    last_s: f64,
    admitted: u64,
    rejected_rate_limited: u64,
    rejected_queue_full: u64,
}

/// One tenant's admission counters, snapshotted at session end.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantAdmissionStats {
    pub tenant: String,
    pub admitted: u64,
    pub rejected_rate_limited: u64,
    pub rejected_queue_full: u64,
}

/// Counter snapshot of an [`AdmissionController`]. `per_tenant` is sorted
/// by tenant name (deterministic) and only populated while admission is
/// enabled.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AdmissionStats {
    pub enabled: bool,
    pub rate_rps: f64,
    pub max_queue: usize,
    /// Hot-reloads applied over the controller's lifetime.
    pub reloads: u64,
    pub admitted: u64,
    pub rejected_rate_limited: u64,
    pub rejected_queue_full: u64,
    pub per_tenant: Vec<TenantAdmissionStats>,
}

impl AdmissionStats {
    pub fn rejected_total(&self) -> u64 {
        self.rejected_rate_limited + self.rejected_queue_full
    }
}

/// The admission decision point, shared by the submission side of a serve
/// session. All methods are callable from any thread, but determinism in
/// logical mode assumes what the server already guarantees: submissions
/// arrive from one driving thread in a defined order.
pub struct AdmissionController {
    /// Live policy — behind an `RwLock` so
    /// [`reconfigure`](Self::reconfigure) (the `--admission-config`
    /// hot-reload path) can swap limits without touching in-flight
    /// requests or per-tenant bucket history.
    cfg: RwLock<AdmissionConfig>,
    clock: Clock,
    buckets: Mutex<BTreeMap<String, Bucket>>,
    admitted: AtomicU64,
    rejected_rate_limited: AtomicU64,
    rejected_queue_full: AtomicU64,
    reloads: AtomicU64,
}

impl AdmissionController {
    /// `logical = true` (fifo mode) freezes the clock except for explicit
    /// [`advance`](Self::advance) calls; `false` uses wall time.
    pub fn new(cfg: AdmissionConfig, logical: bool) -> AdmissionController {
        AdmissionController {
            cfg: RwLock::new(cfg),
            clock: if logical {
                Clock::Logical(Mutex::new(0.0))
            } else {
                // analyze: allow(determinism, obs-discipline) timed mode is wall-clock by design
                Clock::Wall(Instant::now())
            },
            buckets: Mutex::new(BTreeMap::new()),
            admitted: AtomicU64::new(0),
            rejected_rate_limited: AtomicU64::new(0),
            rejected_queue_full: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        read_or_recover(&self.cfg).enabled()
    }

    /// The policy currently in force.
    pub fn config(&self) -> AdmissionConfig {
        *read_or_recover(&self.cfg)
    }

    /// Swap the policy live. In-flight requests are untouched (admission
    /// only ever runs at submit time), per-tenant bucket levels carry
    /// over (a shrunken burst takes effect at the next refill, which
    /// clamps tokens to the new cap), and counters keep accumulating
    /// across the change.
    pub fn reconfigure(&self, cfg: AdmissionConfig) {
        *write_or_recover(&self.cfg) = cfg;
        self.reloads.fetch_add(1, Ordering::Relaxed);
    }

    fn now_s(&self) -> f64 {
        match &self.clock {
            Clock::Wall(t0) => t0.elapsed().as_secs_f64(),
            Clock::Logical(t) => *lock_or_recover(t),
        }
    }

    /// Advance the logical clock by `dt` seconds. No-op on a wall clock
    /// (which advances by itself) and for non-positive `dt`.
    pub fn advance(&self, dt_s: f64) {
        if let Clock::Logical(t) = &self.clock {
            if dt_s > 0.0 && dt_s.is_finite() {
                *lock_or_recover(t) += dt_s;
            }
        }
    }

    /// Decide one request: `queue_depth` is the caller's current depth
    /// gauge (mode-dependent, see the module docs). On `Err` nothing was
    /// consumed except the rejection counter.
    pub fn try_admit(&self, tenant: &str, queue_depth: usize) -> Result<(), Rejected> {
        let cfg = *read_or_recover(&self.cfg);
        if !cfg.enabled() {
            self.admitted.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        let burst = cfg.burst.max(1.0);
        let mut buckets = lock_or_recover(&self.buckets);
        let now = self.now_s();
        let b = buckets.entry(tenant.to_string()).or_insert(Bucket {
            tokens: burst,
            last_s: now,
            admitted: 0,
            rejected_rate_limited: 0,
            rejected_queue_full: 0,
        });
        if cfg.max_queue > 0 && queue_depth >= cfg.max_queue {
            b.rejected_queue_full += 1;
            self.rejected_queue_full.fetch_add(1, Ordering::Relaxed);
            return Err(Rejected {
                tenant: tenant.to_string(),
                reason: RejectReason::QueueFull,
            });
        }
        if cfg.rate_rps > 0.0 {
            let dt = (now - b.last_s).max(0.0);
            b.tokens = (b.tokens + dt * cfg.rate_rps).min(burst);
            b.last_s = now;
            if b.tokens < 1.0 {
                b.rejected_rate_limited += 1;
                self.rejected_rate_limited.fetch_add(1, Ordering::Relaxed);
                return Err(Rejected {
                    tenant: tenant.to_string(),
                    reason: RejectReason::RateLimited,
                });
            }
            b.tokens -= 1.0;
        }
        b.admitted += 1;
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    pub fn stats(&self) -> AdmissionStats {
        let cfg = *read_or_recover(&self.cfg);
        let buckets = lock_or_recover(&self.buckets);
        AdmissionStats {
            enabled: cfg.enabled(),
            rate_rps: cfg.rate_rps,
            max_queue: cfg.max_queue,
            reloads: self.reloads.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected_rate_limited: self.rejected_rate_limited.load(Ordering::Relaxed),
            rejected_queue_full: self.rejected_queue_full.load(Ordering::Relaxed),
            per_tenant: buckets
                .iter()
                .map(|(tenant, b)| TenantAdmissionStats {
                    tenant: tenant.clone(),
                    admitted: b.admitted,
                    rejected_rate_limited: b.rejected_rate_limited,
                    rejected_queue_full: b.rejected_queue_full,
                })
                .collect(),
        }
    }
}

// -------------------------------------------------------------- hot reload ---

/// Where the hot-reload watcher polls, plus the (len, mtime) signature
/// of the version the session was configured from. The baseline is
/// captured when the file is **read** ([`AdmissionReloadSpec::read`]),
/// not when the watcher starts: session startup (state recovery,
/// populate) can take a while, and an edit landing in that window must
/// be detected as a change, never silently counted as already applied.
#[derive(Clone, Debug)]
pub struct AdmissionReloadSpec {
    pub path: PathBuf,
    pub baseline: Option<(u64, SystemTime)>,
}

impl AdmissionReloadSpec {
    /// Stat-then-read: returns the spec (baseline = the signature
    /// observed *before* the read — an edit racing the read itself is
    /// re-detected by the watcher rather than swallowed) and the file's
    /// contents for the caller to parse.
    pub fn read(path: impl Into<PathBuf>)
                -> Result<(AdmissionReloadSpec, String)> {
        let path = path.into();
        let baseline = std::fs::metadata(&path)
            .ok()
            .filter(|md| md.is_file())
            .map(|md| {
                (md.len(),
                 md.modified().unwrap_or(SystemTime::UNIX_EPOCH))
            });
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read admission config {path:?}"))?;
        Ok((AdmissionReloadSpec { path, baseline }, text))
    }
}

/// The `--admission-config` hot-reload poller: a [`FileWatch`]
/// stability window on the config file; each new stable version is
/// parsed ([`AdmissionConfig::from_json`]) and applied to the live
/// controller via [`AdmissionController::reconfigure`] — rate, burst
/// and queue-cap changes take effect for the *next* submit, and no
/// in-flight request is dropped or re-evaluated. A malformed file never
/// kills serving: the current limits stay in force, the failure is
/// logged (`serve_admission_reload_error`), and the watcher retries
/// when the file changes again.
///
/// Note the trade: a reload arrives on wall-clock file polls, so runs
/// that exercise it are not covered by the fifo byte-identity
/// guarantee. Determinism suites simply do not use the watcher (or
/// drive [`poll`](AdmissionReload::poll) explicitly, which is
/// deterministic).
pub struct AdmissionReload {
    watch: FileWatch,
    ctrl: Arc<AdmissionController>,
    log: EventLog,
}

impl AdmissionReload {
    /// `spec.baseline` — the version the session was configured from —
    /// counts as already applied; only edits *after* that signature
    /// reload (including any that landed while the session was still
    /// starting up).
    pub fn new(spec: AdmissionReloadSpec, ctrl: Arc<AdmissionController>,
               log: EventLog) -> AdmissionReload {
        AdmissionReload {
            watch: FileWatch::starting_from(spec.path, spec.baseline),
            ctrl,
            log,
        }
    }

    /// One poll; returns the newly applied config when a reload landed.
    pub fn poll(&mut self) -> Option<AdmissionConfig> {
        let bytes = self.watch.poll()?;
        let text = String::from_utf8_lossy(&bytes);
        let file = self.watch.path().display().to_string();
        match AdmissionConfig::from_json(&text) {
            Ok(cfg) => {
                self.ctrl.reconfigure(cfg);
                self.log.emit("serve_admission_reload", vec![
                    ("file", file.as_str().into()),
                    ("rate_rps", Json::Num(cfg.rate_rps)),
                    ("burst", Json::Num(cfg.burst)),
                    ("max_queue", cfg.max_queue.into()),
                ]);
                Some(cfg)
            }
            Err(e) => {
                self.log.emit("serve_admission_reload_error", vec![
                    ("file", file.as_str().into()),
                    ("error", e.to_string().into()),
                ]);
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(rate_rps: f64, burst: f64, max_queue: usize) -> AdmissionConfig {
        AdmissionConfig { rate_rps, burst, max_queue }
    }

    #[test]
    fn disabled_config_admits_everything() {
        let c = AdmissionController::new(AdmissionConfig::default(), true);
        for _ in 0..1000 {
            c.try_admit("t", usize::MAX - 1).unwrap();
        }
        let s = c.stats();
        assert!(!s.enabled);
        assert_eq!(s.admitted, 1000);
        assert_eq!(s.rejected_total(), 0);
        assert!(s.per_tenant.is_empty());
    }

    #[test]
    fn logical_bucket_is_a_pure_function_of_the_submit_sequence() {
        let run = || {
            let c = AdmissionController::new(cfg(2.0, 3.0, 0), true);
            let mut decisions = Vec::new();
            // burst of 5 at t=0: 3 admitted, 2 rejected
            for _ in 0..5 {
                decisions.push(c.try_admit("t", 0).is_ok());
            }
            // +1 logical second refills 2 tokens
            c.advance(1.0);
            for _ in 0..3 {
                decisions.push(c.try_admit("t", 0).is_ok());
            }
            // +10s refills to the burst cap (3), never beyond
            c.advance(10.0);
            for _ in 0..4 {
                decisions.push(c.try_admit("t", 0).is_ok());
            }
            (decisions, c.stats())
        };
        let (d1, s1) = run();
        let (d2, s2) = run();
        assert_eq!(d1, d2);
        assert_eq!(s1, s2);
        assert_eq!(
            d1,
            vec![
                true, true, true, false, false, // burst
                true, true, false, // refill 2
                true, true, true, false, // capped refill
            ]
        );
        assert_eq!(s1.admitted, 8);
        assert_eq!(s1.rejected_rate_limited, 4);
        assert_eq!(s1.per_tenant.len(), 1);
        assert_eq!(s1.per_tenant[0].tenant, "t");
        assert_eq!(s1.per_tenant[0].admitted, 8);
        assert_eq!(s1.per_tenant[0].rejected_rate_limited, 4);
    }

    #[test]
    fn buckets_are_per_tenant() {
        let c = AdmissionController::new(cfg(1.0, 1.0, 0), true);
        assert!(c.try_admit("a", 0).is_ok());
        // a's bucket is empty, b's is untouched
        let e = c.try_admit("a", 0).unwrap_err();
        assert_eq!(e.reason, RejectReason::RateLimited);
        assert_eq!(e.tenant, "a");
        assert!(c.try_admit("b", 0).is_ok());
        let s = c.stats();
        assert_eq!(s.per_tenant.len(), 2);
        // sorted by tenant name, deterministic
        assert_eq!(s.per_tenant[0].tenant, "a");
        assert_eq!(s.per_tenant[1].tenant, "b");
    }

    #[test]
    fn queue_cap_rejects_without_consuming_tokens() {
        let c = AdmissionController::new(cfg(1000.0, 1.0, 4), true);
        let e = c.try_admit("t", 4).unwrap_err();
        assert_eq!(e.reason, RejectReason::QueueFull);
        let e = c.try_admit("t", 5).unwrap_err();
        assert_eq!(e.reason, RejectReason::QueueFull);
        // below the cap the single burst token is still there
        assert!(c.try_admit("t", 3).is_ok());
        let s = c.stats();
        assert_eq!(s.admitted, 1);
        assert_eq!(s.rejected_queue_full, 2);
        assert_eq!(s.rejected_rate_limited, 0);
    }

    #[test]
    fn burst_below_one_still_admits_at_rate() {
        // a sub-1 burst would deadlock the bucket; it is clamped to 1
        let c = AdmissionController::new(cfg(1.0, 0.0, 0), true);
        assert!(c.try_admit("t", 0).is_ok());
        assert!(c.try_admit("t", 0).is_err());
        c.advance(1.0);
        assert!(c.try_admit("t", 0).is_ok());
    }

    #[test]
    fn wall_clock_refills_on_its_own() {
        let c = AdmissionController::new(cfg(10_000.0, 1.0, 0), false);
        assert!(c.try_admit("t", 0).is_ok());
        // at 10k rps a token is back within 100µs; poll briefly
        let t0 = Instant::now();
        let mut admitted_again = false;
        while t0.elapsed() < std::time::Duration::from_secs(5) {
            if c.try_admit("t", 0).is_ok() {
                admitted_again = true;
                break;
            }
        }
        assert!(admitted_again, "wall bucket never refilled");
        // advance() is a documented no-op on a wall clock
        c.advance(1e9);
    }

    #[test]
    fn config_parses_from_json_with_defaults_and_caps() {
        let c = AdmissionConfig::from_json(
            r#"{"rate_rps": 25.0, "burst": 5, "max_queue": 64}"#).unwrap();
        assert_eq!((c.rate_rps, c.burst, c.max_queue), (25.0, 5.0, 64));
        // absent keys fall back to defaults: {} disables admission
        let c = AdmissionConfig::from_json("{}").unwrap();
        assert!(!c.enabled());
        let c = AdmissionConfig::from_json(r#"{"max_queue": 8}"#).unwrap();
        assert!(c.enabled());
        assert_eq!(c.rate_rps, 0.0);
        // absent burst with a rate defaults to one second's worth —
        // the same rule as the --rate-rps CLI flag
        let c = AdmissionConfig::from_json(r#"{"rate_rps": 100}"#).unwrap();
        assert_eq!(c.burst, 100.0);
        let c = AdmissionConfig::from_json(r#"{"rate_rps": 0.5}"#).unwrap();
        assert_eq!(c.burst, 1.0);
        // from_json_spec reports whether burst was explicitly pinned
        let (_, pinned) =
            AdmissionConfig::from_json_spec(r#"{"burst": 3}"#).unwrap();
        assert!(pinned);
        let (_, pinned) =
            AdmissionConfig::from_json_spec(r#"{"rate_rps": 9}"#).unwrap();
        assert!(!pinned);
        // malformed JSON and out-of-range values are errors
        assert!(AdmissionConfig::from_json("not json").is_err());
        assert!(AdmissionConfig::from_json(r#"{"rate_rps": -1}"#).is_err());
        assert!(AdmissionConfig::from_json(r#"{"burst": -0.5}"#).is_err());
        // a negative or fractional max_queue must error, not saturate
        // to 0 (= cap disabled)
        assert!(AdmissionConfig::from_json(r#"{"max_queue": -1}"#).is_err());
        assert!(AdmissionConfig::from_json(r#"{"max_queue": 2.5}"#).is_err());
        // a typo'd key must error, never silently disable limits; and
        // the config must be an object
        let e = AdmissionConfig::from_json(r#"{"rate": 50}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown key"), "{e}");
        assert!(AdmissionConfig::from_json("[1, 2]").is_err());
        assert!(AdmissionConfig::from_json("42").is_err());
    }

    #[test]
    fn reconfigure_applies_live_without_resetting_counters() {
        let c = AdmissionController::new(cfg(0.0, 1.0, 1), true);
        c.try_admit("t", 0).unwrap();
        assert!(c.try_admit("t", 1).is_err()); // queue cap 1
        // raise the cap live: the same depth now admits
        c.reconfigure(cfg(0.0, 1.0, 8));
        c.try_admit("t", 1).unwrap();
        // disable entirely: everything admits
        c.reconfigure(AdmissionConfig::default());
        c.try_admit("t", usize::MAX - 1).unwrap();
        let s = c.stats();
        assert_eq!(s.admitted, 3);
        assert_eq!(s.rejected_queue_full, 1);
        assert_eq!(s.reloads, 2);
        assert!(!s.enabled);
    }

    #[test]
    fn reload_poller_applies_stable_config_and_survives_garbage() {
        let dir = std::env::temp_dir()
            .join("qp_admission_reload")
            .join(format!("unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("admission.json");
        let ctrl = Arc::new(AdmissionController::new(cfg(0.0, 1.0, 1), true));
        let spec =
            AdmissionReloadSpec { path: path.clone(), baseline: None };
        let mut reload =
            AdmissionReload::new(spec, ctrl.clone(), EventLog::null());
        // no file yet: nothing happens
        assert!(reload.poll().is_none());
        std::fs::write(&path, r#"{"max_queue": 32}"#).unwrap();
        assert!(reload.poll().is_none()); // stability window arms
        let applied = reload.poll().expect("stable config applies");
        assert_eq!(applied.max_queue, 32);
        assert_eq!(ctrl.config().max_queue, 32);
        // garbage keeps the current limits in force
        std::fs::write(&path, b"{ definitely not json").unwrap();
        reload.poll();
        assert!(reload.poll().is_none());
        assert_eq!(ctrl.config().max_queue, 32);
        assert_eq!(ctrl.stats().reloads, 1);
    }

    #[test]
    fn rejected_is_a_recoverable_typed_error() {
        fn submit_like() -> anyhow::Result<()> {
            let c = AdmissionController::new(cfg(0.0, 1.0, 1), true);
            c.try_admit("acme", 1)?;
            Ok(())
        }
        let e = submit_like().unwrap_err();
        let r = e.downcast_ref::<Rejected>().expect("typed rejection lost");
        assert_eq!(r.tenant, "acme");
        assert_eq!(r.reason, RejectReason::QueueFull);
        assert!(e.to_string().contains("queue_full"), "{e}");
    }
}
