//! Seeded synthetic load generator: measurable throughput and tail
//! latency for the serving subsystem *today*, before real PJRT bindings
//! land.
//!
//! Two driving disciplines:
//! - **closed loop**: waves of `concurrency` outstanding requests; the
//!   next wave starts when the previous one has fully responded. Purely
//!   seed-deterministic (no wall clock in any decision), which is what
//!   the `fifo`-mode byte-reproducibility guarantee builds on.
//! - **open loop**: requests arrive at `open_rate_rps` with exponential
//!   interarrival gaps, regardless of completions — the discipline that
//!   actually exposes queueing tail latency (closed loops self-throttle).
//!
//! Tenant choice is Zipf-skewed (`zipf_s = 0` is uniform): real
//! multi-tenant traffic concentrates on few hot tenants, which is
//! exactly what exercises the materialization cache's LRU policy — and,
//! under admission control, what makes hot tenants hit their per-tenant
//! rate budgets first. Both drivers *shed* on a typed
//! [`Rejected`](super::admission::Rejected) rejection (count it, move
//! on) rather than aborting; in fifo sessions the open-loop driver
//! advances the admission controller's logical clock by its seeded gaps
//! instead of sleeping, so overload runs are deterministic end to end.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::events::EventLog;
use crate::runtime::Runtime;
use crate::store::{Durability, StateStore};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::admission::Rejected;
use super::registry::{theta_checksum, PauliSpec, Registry};
use super::scheduler::{Response, ResponseHandle};
use super::server::{serve, ServeConfig, ServeSummary, SubmitTarget};
use super::shard::{serve_sharded, FleetSummary, ShardConfig, ShardRouter};
use super::spool::{SpoolConfig, SpoolWatcher};

/// Load shape: how many tenants, how much traffic, how skewed.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    pub tenants: usize,
    pub pauli: PauliSpec,
    pub requests: usize,
    pub seed: u64,
    /// Zipf skew exponent over tenant ranks; 0.0 = uniform.
    pub zipf_s: f64,
    /// Closed-loop wave size (outstanding requests per wave).
    pub concurrency: usize,
    /// > 0 switches to open-loop arrivals at this rate (req/s).
    pub open_rate_rps: f64,
}

impl Default for LoadSpec {
    fn default() -> LoadSpec {
        LoadSpec {
            tenants: 16,
            pauli: PauliSpec { q: 5, n_layers: 1 },
            requests: 512,
            seed: 0,
            zipf_s: 1.0,
            concurrency: 32,
            open_rate_rps: 0.0,
        }
    }
}

/// Stable tenant naming shared by the populate and driving phases.
pub fn tenant_name(i: usize) -> String {
    format!("tenant{i:04}")
}

/// Zipf sampler over ranks `0..n` (rank 0 hottest), via inverse CDF.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n >= 1);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for rank in 1..=n {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        self.sample_u(rng.f64())
    }

    /// The rank for one uniform draw `u` in [0, 1) — the inverse-CDF step
    /// behind [`sample`](Self::sample), exposed so boundary behavior is
    /// pinned with exact values. An exact hit on `cdf[i]` belongs to rank
    /// `i` (the standard right-continuous inverse CDF,
    /// `min {i : cdf[i] >= u}`). The boundary is reachable: with `s = 0`
    /// and a power-of-two `n`, every cdf value is a dyadic rational that
    /// the 53-bit grid `Rng::f64` draws from represents exactly — and the
    /// old `Ok(i) => i + 1` mapping shifted that boundary mass onto the
    /// next rank.
    pub fn sample_u(&self, u: f64) -> usize {
        let i = match self.cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i,
        };
        i.min(self.cdf.len() - 1)
    }
}

/// Register `tenants` seeded adapters (version 1 each), keeping any
/// already-registered tenant whose live adapter is *exactly* what this
/// seed would produce (same Pauli spec, same theta checksum) — which is
/// what lets a `--state-dir` restart serve its recovered tenants at
/// their recorded versions instead of hot-swapping every one of them.
/// A tenant that exists with a different spec or different thetas
/// (state dir from another seed or shape) is hot-swapped to this run's
/// seeded adapter rather than silently served stale. Returns the
/// per-tenant theta checksums so callers can verify responses came from
/// consistent (version, params) pairs.
pub fn populate(registry: &Registry, load: &LoadSpec) -> Result<Vec<u64>> {
    if load.tenants == 0 {
        bail!("loadgen needs at least one tenant");
    }
    let mut checksums = Vec::with_capacity(load.tenants);
    for i in 0..load.tenants {
        checksums.push(populate_one(registry, load, i)?);
    }
    Ok(checksums)
}

/// The seeded adapter for tenant `i`: a pure function of (seed, i), so
/// every placement — one registry or a sharded fleet — produces the same
/// thetas and checksum.
fn seeded_adapter(load: &LoadSpec, i: usize) -> (Vec<f32>, u64) {
    let mut rng = Rng::new(load.seed ^ (i as u64 + 1).wrapping_mul(
        0x9e37_79b9_7f4a_7c15));
    let thetas: Vec<f32> = (0..load.pauli.num_params())
        .map(|_| rng.normal() as f32 * 0.5)
        .collect();
    let checksum = theta_checksum(&thetas);
    (thetas, checksum)
}

/// Register tenant `i`'s seeded adapter into `registry` (skip-if-live,
/// see [`populate`]); returns its theta checksum.
fn populate_one(registry: &Registry, load: &LoadSpec, i: usize) -> Result<u64> {
    let (thetas, checksum) = seeded_adapter(load, i);
    let name = tenant_name(i);
    let already_live = registry.snapshot(&name)
        .map(|snap| snap.spec == load.pauli && snap.checksum == checksum)
        .unwrap_or(false);
    if !already_live {
        registry.register(&name, load.pauli, thetas)?;
    }
    Ok(checksum)
}

/// [`populate`] for a sharded fleet: each tenant's seeded adapter is
/// registered into the registry of the shard it *routes* to, so the
/// fleet serves exactly the adapters a single instance would (identical
/// thetas, checksums, and initial versions).
pub fn populate_sharded(router: &ShardRouter<'_>, load: &LoadSpec)
                        -> Result<Vec<u64>> {
    if load.tenants == 0 {
        bail!("loadgen needs at least one tenant");
    }
    let mut checksums = Vec::with_capacity(load.tenants);
    for i in 0..load.tenants {
        let name = tenant_name(i);
        let registry = router.registry(router.shard_of(&name))?;
        checksums.push(populate_one(&registry, load, i)?);
    }
    Ok(checksums)
}

/// The input vector for global request number `k` — a pure function of
/// (seed, k), so any driver discipline generates identical payloads.
fn request_input(load: &LoadSpec, k: u64) -> Vec<f32> {
    let mut rng = Rng::new(load.seed ^ (k + 1).wrapping_mul(0x2545_f491_4f6c_dd1d));
    (0..load.pauli.dim()).map(|_| rng.normal() as f32 * 0.5).collect()
}

/// Submit one loadgen request, translating a typed admission rejection
/// ([`Rejected`]) into `Ok(None)` — open-loop overload *sheds* load, it
/// doesn't abort the run; the per-tenant shed counts surface in the
/// session's admission stats. Any other submit error still fails the
/// driver.
fn submit_or_shed<T: SubmitTarget>(handle: &T, tenant: &str, meta: u64,
                                   input: Vec<f32>)
                                   -> Result<Option<ResponseHandle>> {
    match handle.submit(tenant, meta, input) {
        Ok(h) => Ok(Some(h)),
        Err(e) if e.downcast_ref::<Rejected>().is_some() => Ok(None),
        Err(e) => Err(e),
    }
}

/// Closed-loop driver: waves of `concurrency` requests, fully collected
/// before the next wave. Returns responses in submission order (admitted
/// requests only — request numbering always advances, so the workload is
/// a pure function of the seed whether or not admission sheds).
pub fn closed_loop<T: SubmitTarget>(handle: &T, load: &LoadSpec)
                                    -> Result<Vec<Response>> {
    let zipf = Zipf::new(load.tenants, load.zipf_s);
    let mut pick = Rng::new(load.seed ^ 0xc1ed_1007);
    let mut out = Vec::with_capacity(load.requests);
    // one counter, one type: `sent` counts in the same usize domain as
    // `load.requests` (it only widens — losslessly on every supported
    // platform — where the request id becomes the u64 wire `meta`)
    let mut sent = 0usize;
    while sent < load.requests {
        let wave = load.concurrency.max(1).min(load.requests - sent);
        let mut handles = Vec::with_capacity(wave);
        for _ in 0..wave {
            let t = zipf.sample(&mut pick);
            let meta = sent as u64;
            if let Some(h) = submit_or_shed(
                handle, &tenant_name(t), meta, request_input(load, meta))?
            {
                handles.push(h);
            }
            sent += 1;
        }
        handle.flush();
        for h in handles {
            out.push(h.wait()?);
        }
        // wave boundary = a quiescent sync point: every submitted request
        // has completed, so the fifo interval snapshot (completion-count
        // cadence) is a pure function of the seed here
        handle.tick();
    }
    Ok(out)
}

/// Open-loop driver: seeded-exponential interarrival gaps at
/// `open_rate_rps`, submissions never waiting on completions. Responses
/// are collected at the end, in submission order.
///
/// In a fifo (deterministic) session the driver does not sleep: each gap
/// advances the admission controller's *logical* clock instead
/// ([`ServerHandle::advance_clock`]), so an overload run — arrivals
/// beyond the per-tenant rate budget — sheds exactly the same requests
/// at any worker count. In timed mode the gaps are real sleeps and
/// admission runs on the wall clock.
pub fn open_loop<T: SubmitTarget>(handle: &T, load: &LoadSpec)
                                  -> Result<Vec<Response>> {
    if load.open_rate_rps <= 0.0 {
        bail!("open_loop needs open_rate_rps > 0");
    }
    let zipf = Zipf::new(load.tenants, load.zipf_s);
    let mut pick = Rng::new(load.seed ^ 0xc1ed_1007);
    let mut gaps = Rng::new(load.seed ^ 0x0be9_1007);
    let mean_gap = 1.0 / load.open_rate_rps;
    let logical = handle.is_fifo();
    let mut handles = Vec::with_capacity(load.requests);
    for k in 0..load.requests as u64 {
        let t = zipf.sample(&mut pick);
        if let Some(h) = submit_or_shed(
            handle, &tenant_name(t), k, request_input(load, k))?
        {
            handles.push(h);
        }
        // honor the requested rate faithfully — a clamp here would make
        // the emitted summary describe a different workload than asked
        let gap = -mean_gap * (1.0 - gaps.f64()).ln();
        if logical {
            handle.advance_clock(gap);
        } else if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap));
        }
    }
    handle.flush();
    let responses: Result<Vec<Response>> =
        handles.into_iter().map(|h| h.wait()).collect();
    // all arrivals resolved: emit any interval snapshots the completed
    // count has crossed (fifo cadence; timed sessions snapshot from the
    // flusher thread instead)
    handle.tick();
    responses
}

/// Render responses as a canonical text log (sorted by request `meta`):
/// one line per response with the adapter identity that served it and an
/// FNV digest of the output bits. Byte-identical across worker counts in
/// `fifo` mode — the serving determinism guarantee tests assert on.
pub fn response_log(responses: &[Response]) -> String {
    use std::fmt::Write as _;
    let mut sorted: Vec<&Response> = responses.iter().collect();
    sorted.sort_by_key(|r| r.meta);
    let mut s = String::new();
    for r in sorted {
        let _ = writeln!(
            s,
            "meta={} tenant={} version={} checksum={:016x} out={:016x}",
            r.meta, r.tenant, r.version, r.checksum,
            theta_checksum(&r.output));
    }
    s
}

/// Everything `repro serve-bench` needs in one struct.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    pub load: LoadSpec,
    pub serve: ServeConfig,
    pub cache_bytes: usize,
    /// Per-tenant byte quota on the materialization cache (0 = off).
    pub tenant_quota_bytes: usize,
    /// When set, a [`SpoolWatcher`] ingests adapter uploads from this
    /// directory for the duration of the bench (joined on exit).
    pub spool_dir: Option<std::path::PathBuf>,
    /// When set, registry mutations are durable: the directory is
    /// opened-or-recovered on startup (`--state-dir`), recovered tenants
    /// are restored at their recorded versions before the seeded
    /// populate runs, and the log is compacted into a snapshot at
    /// session end.
    pub state_dir: Option<std::path::PathBuf>,
    /// WAL fsync cadence for `state_dir` (`--durability`).
    pub durability: Durability,
}

impl Default for BenchOpts {
    fn default() -> BenchOpts {
        BenchOpts {
            load: LoadSpec::default(),
            serve: ServeConfig::default(),
            cache_bytes: 8 << 20,
            tenant_quota_bytes: 0,
            spool_dir: None,
            state_dir: None,
            durability: Durability::Buffered,
        }
    }
}

/// Build a registry, populate it with seeded adapters, run the loadgen
/// through a serve session, and emit the summary through `log`. Returns
/// the summary and the canonical response log. With a spool dir set, a
/// watcher thread ingests uploads for the whole session and is stopped
/// and joined before this returns.
pub fn run_serve_bench(opts: &BenchOpts, log: &EventLog)
                       -> Result<(ServeSummary, String)> {
    if opts.serve.fifo
        && opts.serve.admission.rate_rps > 0.0
        && opts.load.open_rate_rps <= 0.0
    {
        // in fifo mode the admission clock is logical and only the
        // open-loop driver advances it (by its seeded gaps); a closed
        // loop would leave it frozen at 0, so each tenant gets exactly
        // `burst` admissions for the whole run and everything after is
        // silently shed — reject the combination instead of reporting
        // a meaningless benchmark
        bail!("--rate-rps with fifo mode needs open-loop arrivals \
               (--rate > 0), or use --mode timed: the closed-loop fifo \
               driver never advances the logical admission clock");
    }
    let mut registry = Registry::new(opts.cache_bytes)
        .with_tenant_quota(opts.tenant_quota_bytes);
    // open-or-recover the durable state store BEFORE populate: recovered
    // tenants come back at their recorded versions (and byte-identical
    // thetas), and populate skips them
    let store = match &opts.state_dir {
        Some(dir) => {
            let mut opened = StateStore::open(dir, opts.durability)
                .with_context(|| format!("open state dir {dir:?}"))?;
            // attach the process-wide metrics backplane while the store
            // is still exclusively owned: recovery counters are credited
            // once, and every later append/fsync/compaction is observed
            if let Some(reg) = &opts.serve.metrics {
                opened.store.instrument(reg, &opened.recovered);
            }
            for ts in &opened.recovered.tenants {
                registry.restore(ts).with_context(|| {
                    format!("restoring recovered tenant {:?}", ts.tenant)
                })?;
            }
            let r = &opened.recovered;
            log.emit("serve_state_recovered", vec![
                ("dir", dir.display().to_string().into()),
                ("tenants", r.tenants.len().into()),
                ("snapshot_entries", r.snapshot_entries.into()),
                ("wal_records", Json::Num(r.wal_records as f64)),
                ("last_seq", Json::Num(r.last_seq as f64)),
                ("torn_tail", r.torn_tail.to_string().into()),
            ]);
            let store = std::sync::Arc::new(opened.store);
            registry = registry.with_state_sink(store.clone());
            Some(store)
        }
        None => None,
    };
    let registry = std::sync::Arc::new(registry);
    populate(&registry, &opts.load)?;
    let rt = Runtime::cpu()?;
    if let Some(reg) = &opts.serve.metrics {
        rt.cache().instrument(reg);
    }
    let mode = if opts.serve.fifo { "fifo" } else { "timed" };
    let discipline = if opts.load.open_rate_rps > 0.0 { "open" } else { "closed" };
    log.emit("serve_bench", vec![
        ("tenants", opts.load.tenants.into()),
        ("requests", opts.load.requests.into()),
        ("workers", opts.serve.workers.into()),
        ("seed", Json::Num(opts.load.seed as f64)),
        ("zipf_s", Json::Num(opts.load.zipf_s)),
        ("q", (opts.load.pauli.q as usize).into()),
        ("n_layers", (opts.load.pauli.n_layers as usize).into()),
        ("max_batch", opts.serve.policy.max_batch.into()),
        ("max_wait_us", Json::Num(opts.serve.policy.max_wait_us as f64)),
        ("mode", mode.into()),
        ("discipline", discipline.into()),
        ("cache_bytes", opts.cache_bytes.into()),
        ("rate_rps", Json::Num(opts.serve.admission.rate_rps)),
        ("burst", Json::Num(opts.serve.admission.burst)),
        ("max_queue", opts.serve.admission.max_queue.into()),
        ("spool",
         opts.spool_dir.as_ref()
             .map(|p| p.display().to_string())
             .unwrap_or_default()
             .into()),
        ("state_dir",
         opts.state_dir.as_ref()
             .map(|p| p.display().to_string())
             .unwrap_or_default()
             .into()),
        ("durability", format!("{:?}", opts.durability).into()),
        ("tenant_quota_bytes", opts.tenant_quota_bytes.into()),
        ("metrics_interval", Json::Num(opts.serve.metrics_interval as f64)),
        ("slo_p99_us", Json::Num(opts.serve.slo_p99_us)),
        ("slo_error_budget", Json::Num(opts.serve.slo_error_budget)),
        ("trace_dir",
         opts.serve.trace_dir.as_ref()
             .map(|p| p.display().to_string())
             .unwrap_or_default()
             .into()),
    ]);
    let watcher = match &opts.spool_dir {
        Some(dir) => Some(SpoolWatcher::start(
            registry.clone(), SpoolConfig::new(dir), log.clone())?),
        None => None,
    };
    let outcome = serve(&rt, &registry, &opts.serve, log, |h| {
        if opts.load.open_rate_rps > 0.0 {
            open_loop(h, &opts.load)
        } else {
            closed_loop(h, &opts.load)
        }
    });
    // stop and JOIN the watcher before reporting, success or failure:
    // the session's shutdown must never leak its poller
    if let Some(w) = watcher {
        w.shutdown();
    }
    let outcome = outcome?;
    // session-end compaction: the next restart recovers from one
    // snapshot instead of replaying the whole mutation history
    if let Some(store) = &store {
        registry.compact_into(store).context("compact state store")?;
        log.emit("serve_state_compacted", vec![
            ("tenants", registry.len().into()),
            ("last_seq", Json::Num(store.last_seq() as f64)),
        ]);
    }
    Ok((outcome.summary, response_log(&outcome.body)))
}

/// A finished sharded bench: fleet metrics, one canonical response log
/// per shard (the byte-determinism oracle — each is sorted by `meta`
/// within the shard's admitted subset), and the merged fleet-wide log.
pub struct ShardBenchReport {
    pub fleet: FleetSummary,
    /// Index `i` holds shard `i`'s response log (grouped by where each
    /// response's tenant routes at collection time).
    pub shard_logs: Vec<String>,
    /// All responses merged into one meta-sorted log — byte-identical
    /// to a single-instance run over the same admitted set.
    pub merged_log: String,
}

/// [`run_serve_bench`] over a sharded fleet (`repro serve-bench
/// --shards N`): per-shard registries are populated through the router,
/// the same seeded driver runs against the fleet, and per-shard +
/// merged response logs come back with the fleet summary. `state_dir`
/// becomes the fleet's `state_root` (per-shard dirs underneath);
/// spool ingestion is not wired into the sharded tier yet.
pub fn run_sharded_bench(opts: &BenchOpts, shards: usize, log: &EventLog)
                         -> Result<ShardBenchReport> {
    if opts.serve.fifo
        && opts.serve.admission.rate_rps > 0.0
        && opts.load.open_rate_rps <= 0.0
    {
        bail!("--rate-rps with fifo mode needs open-loop arrivals \
               (--rate > 0), or use --mode timed: the closed-loop fifo \
               driver never advances the logical admission clock");
    }
    if opts.spool_dir.is_some() {
        bail!("--spool-dir is not supported with --shards > 1: the spool \
               watcher feeds a single registry, not a routed fleet");
    }
    let cfg = ShardConfig {
        shards,
        serve: opts.serve.clone(),
        cache_bytes: opts.cache_bytes,
        tenant_quota_bytes: opts.tenant_quota_bytes,
        state_root: opts.state_dir.clone(),
        durability: opts.durability,
    };
    let rt = Runtime::cpu()?;
    if let Some(reg) = &opts.serve.metrics {
        rt.cache().instrument(reg);
    }
    log.emit("serve_shard_bench", vec![
        ("shards", shards.into()),
        ("tenants", opts.load.tenants.into()),
        ("requests", opts.load.requests.into()),
        ("workers_per_shard", opts.serve.workers.into()),
        ("seed", Json::Num(opts.load.seed as f64)),
        ("zipf_s", Json::Num(opts.load.zipf_s)),
        ("mode", if opts.serve.fifo { "fifo" } else { "timed" }.into()),
        ("state_root",
         opts.state_dir.as_ref()
             .map(|p| p.display().to_string())
             .unwrap_or_default()
             .into()),
    ]);
    let outcome = serve_sharded(&rt, &cfg, log, |router| {
        populate_sharded(router, &opts.load)?;
        let responses = if opts.load.open_rate_rps > 0.0 {
            open_loop(router, &opts.load)?
        } else {
            closed_loop(router, &opts.load)?
        };
        let mut per_shard: Vec<Vec<Response>> = (0..shards)
            .map(|_| Vec::new())
            .collect();
        for r in responses {
            let shard = router.shard_of(&r.tenant);
            per_shard[shard].push(r);
        }
        Ok(per_shard)
    })?;
    let fleet = FleetSummary { shards, sessions: outcome.sessions };
    fleet.emit(log);
    let shard_logs: Vec<String> =
        outcome.body.iter().map(|rs| response_log(rs)).collect();
    let merged_log = response_log(&outcome.body.concat());
    Ok(ShardBenchReport { fleet, shard_logs, merged_log })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let zipf = Zipf::new(8, 1.2);
        let mut rng = Rng::new(11);
        let mut counts = [0usize; 8];
        for _ in 0..4000 {
            counts[zipf.sample(&mut rng)] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 4000);
        assert!(counts[0] > counts[3], "{counts:?}");
        assert!(counts[0] > counts[7], "{counts:?}");
        // uniform: roughly even
        let uni = Zipf::new(4, 0.0);
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[uni.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((800..1200).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn zipf_cdf_is_strictly_increasing_and_exactly_normalized() {
        for n in [1usize, 2, 7, 64, 1000] {
            for s in [0.0f64, 0.7, 1.0, 2.0] {
                let zipf = Zipf::new(n, s);
                assert_eq!(zipf.cdf.len(), n);
                // every rank has positive mass, so the CDF is *strictly*
                // increasing — a flat step would make its rank unreachable
                for w in zipf.cdf.windows(2) {
                    assert!(w[1] > w[0], "n={n} s={s}: {:?}", &w);
                }
                // dividing the running sum by its own total makes the
                // last element exactly 1.0 (x/x == 1.0 in IEEE 754 for
                // finite positive x), not merely close
                assert_eq!(*zipf.cdf.last().unwrap(), 1.0, "n={n} s={s}");
                // a draw just under 1.0 lands past cdf[n-2], so the
                // inverse CDF returns the max rank — the tail is
                // reachable and never indexes out of range
                assert_eq!(zipf.sample_u(1.0 - 1e-12), n - 1, "n={n} s={s}");
            }
        }
    }

    #[test]
    fn zipf_exact_cdf_hit_returns_the_boundary_rank() {
        // s = 0, power-of-two n: cdf = [0.25, 0.5, 0.75, 1.0], every
        // value exactly representable on the 53-bit grid Rng::f64 draws
        // from, so a synthetic draw can hit a boundary dead-on. The
        // right-continuous inverse CDF assigns the hit to rank i itself;
        // the old `Ok(i) => i + 1` skipped it onto the next rank.
        let uni = Zipf::new(4, 0.0);
        assert_eq!(uni.sample_u(0.0), 0);
        assert_eq!(uni.sample_u(0.25), 0);
        assert_eq!(uni.sample_u(0.25 + f64::EPSILON), 1);
        assert_eq!(uni.sample_u(0.5), 1);
        assert_eq!(uni.sample_u(0.75), 2);
        assert_eq!(uni.sample_u(0.999), 3);
        // u is drawn from [0, 1), but even a hostile u = 1.0 stays in
        // range instead of indexing one past the end
        assert_eq!(uni.sample_u(1.0), 3);
        // sample() is exactly sample_u over the rng's f64 stream, so the
        // boundary fix applies to the real driver path too
        let zipf = Zipf::new(16, 1.0);
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        for _ in 0..256 {
            assert_eq!(zipf.sample(&mut a), zipf.sample_u(b.f64()));
        }
    }

    #[test]
    fn zipf_sampling_is_seed_deterministic() {
        let zipf = Zipf::new(16, 1.0);
        let a: Vec<usize> = {
            let mut r = Rng::new(3);
            (0..64).map(|_| zipf.sample(&mut r)).collect()
        };
        let b: Vec<usize> = {
            let mut r = Rng::new(3);
            (0..64).map(|_| zipf.sample(&mut r)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn populate_is_deterministic_per_seed() {
        let load = LoadSpec { tenants: 4, ..LoadSpec::default() };
        let r1 = Registry::new(1 << 20);
        let r2 = Registry::new(1 << 20);
        let c1 = populate(&r1, &load).unwrap();
        let c2 = populate(&r2, &load).unwrap();
        assert_eq!(c1, c2);
        assert_eq!(r1.len(), 4);
        // different seed, different adapters
        let r3 = Registry::new(1 << 20);
        let c3 = populate(&r3, &LoadSpec { seed: 9, ..load }).unwrap();
        assert_ne!(c1, c3);
    }

    #[test]
    fn request_inputs_differ_by_index_not_call_order() {
        let load = LoadSpec::default();
        let a = request_input(&load, 5);
        let b = request_input(&load, 6);
        assert_ne!(a, b);
        assert_eq!(a, request_input(&load, 5));
        assert_eq!(a.len(), load.pauli.dim());
    }
}
