//! Seeded synthetic load generator: measurable throughput and tail
//! latency for the serving subsystem *today*, before real PJRT bindings
//! land.
//!
//! Two driving disciplines:
//! - **closed loop**: waves of `concurrency` outstanding requests; the
//!   next wave starts when the previous one has fully responded. Purely
//!   seed-deterministic (no wall clock in any decision), which is what
//!   the `fifo`-mode byte-reproducibility guarantee builds on.
//! - **open loop**: requests arrive at `open_rate_rps` with exponential
//!   interarrival gaps, regardless of completions — the discipline that
//!   actually exposes queueing tail latency (closed loops self-throttle).
//!
//! Tenant choice is Zipf-skewed (`zipf_s = 0` is uniform): real
//! multi-tenant traffic concentrates on few hot tenants, which is
//! exactly what exercises the materialization cache's LRU policy.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::events::EventLog;
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::registry::{theta_checksum, PauliSpec, Registry};
use super::scheduler::Response;
use super::server::{serve, ServeConfig, ServeSummary, ServerHandle};

/// Load shape: how many tenants, how much traffic, how skewed.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    pub tenants: usize,
    pub pauli: PauliSpec,
    pub requests: usize,
    pub seed: u64,
    /// Zipf skew exponent over tenant ranks; 0.0 = uniform.
    pub zipf_s: f64,
    /// Closed-loop wave size (outstanding requests per wave).
    pub concurrency: usize,
    /// > 0 switches to open-loop arrivals at this rate (req/s).
    pub open_rate_rps: f64,
}

impl Default for LoadSpec {
    fn default() -> LoadSpec {
        LoadSpec {
            tenants: 16,
            pauli: PauliSpec { q: 5, n_layers: 1 },
            requests: 512,
            seed: 0,
            zipf_s: 1.0,
            concurrency: 32,
            open_rate_rps: 0.0,
        }
    }
}

/// Stable tenant naming shared by the populate and driving phases.
pub fn tenant_name(i: usize) -> String {
    format!("tenant{i:04}")
}

/// Zipf sampler over ranks `0..n` (rank 0 hottest), via inverse CDF.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n >= 1);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for rank in 1..=n {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        let i = match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i + 1,
            Err(i) => i,
        };
        i.min(self.cdf.len() - 1)
    }
}

/// Register `tenants` seeded adapters (version 1 each). Returns the
/// per-tenant theta checksums so callers can verify responses came from
/// consistent (version, params) pairs.
pub fn populate(registry: &Registry, load: &LoadSpec) -> Result<Vec<u64>> {
    if load.tenants == 0 {
        bail!("loadgen needs at least one tenant");
    }
    let n_params = load.pauli.num_params();
    let mut checksums = Vec::with_capacity(load.tenants);
    for i in 0..load.tenants {
        let mut rng = Rng::new(load.seed ^ (i as u64 + 1).wrapping_mul(
            0x9e37_79b9_7f4a_7c15));
        let thetas: Vec<f32> = (0..n_params)
            .map(|_| rng.normal() as f32 * 0.5)
            .collect();
        checksums.push(theta_checksum(&thetas));
        registry.register(&tenant_name(i), load.pauli, thetas)?;
    }
    Ok(checksums)
}

/// The input vector for global request number `k` — a pure function of
/// (seed, k), so any driver discipline generates identical payloads.
fn request_input(load: &LoadSpec, k: u64) -> Vec<f32> {
    let mut rng = Rng::new(load.seed ^ (k + 1).wrapping_mul(0x2545_f491_4f6c_dd1d));
    (0..load.pauli.dim()).map(|_| rng.normal() as f32 * 0.5).collect()
}

/// Closed-loop driver: waves of `concurrency` requests, fully collected
/// before the next wave. Returns responses in submission order.
pub fn closed_loop(handle: &ServerHandle<'_>, load: &LoadSpec)
                   -> Result<Vec<Response>> {
    let zipf = Zipf::new(load.tenants, load.zipf_s);
    let mut pick = Rng::new(load.seed ^ 0xc1ed_1007);
    let mut out = Vec::with_capacity(load.requests);
    let mut sent = 0u64;
    while (sent as usize) < load.requests {
        let wave = load.concurrency.max(1).min(load.requests - sent as usize);
        let mut handles = Vec::with_capacity(wave);
        for _ in 0..wave {
            let t = zipf.sample(&mut pick);
            handles.push(handle.submit(
                &tenant_name(t), sent, request_input(load, sent))?);
            sent += 1;
        }
        handle.flush();
        for h in handles {
            out.push(h.wait()?);
        }
    }
    Ok(out)
}

/// Open-loop driver: seeded-exponential interarrival gaps at
/// `open_rate_rps`, submissions never waiting on completions. Responses
/// are collected at the end, in submission order.
pub fn open_loop(handle: &ServerHandle<'_>, load: &LoadSpec)
                 -> Result<Vec<Response>> {
    if load.open_rate_rps <= 0.0 {
        bail!("open_loop needs open_rate_rps > 0");
    }
    let zipf = Zipf::new(load.tenants, load.zipf_s);
    let mut pick = Rng::new(load.seed ^ 0xc1ed_1007);
    let mut gaps = Rng::new(load.seed ^ 0x0be9_1007);
    let mean_gap = 1.0 / load.open_rate_rps;
    let mut handles = Vec::with_capacity(load.requests);
    for k in 0..load.requests as u64 {
        let t = zipf.sample(&mut pick);
        handles.push(handle.submit(&tenant_name(t), k, request_input(load, k))?);
        // honor the requested rate faithfully — a clamp here would make
        // the emitted summary describe a different workload than asked
        let gap = -mean_gap * (1.0 - gaps.f64()).ln();
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap));
        }
    }
    handle.flush();
    handles.into_iter().map(|h| h.wait()).collect()
}

/// Render responses as a canonical text log (sorted by request `meta`):
/// one line per response with the adapter identity that served it and an
/// FNV digest of the output bits. Byte-identical across worker counts in
/// `fifo` mode — the serving determinism guarantee tests assert on.
pub fn response_log(responses: &[Response]) -> String {
    use std::fmt::Write as _;
    let mut sorted: Vec<&Response> = responses.iter().collect();
    sorted.sort_by_key(|r| r.meta);
    let mut s = String::new();
    for r in sorted {
        let _ = writeln!(
            s,
            "meta={} tenant={} version={} checksum={:016x} out={:016x}",
            r.meta, r.tenant, r.version, r.checksum,
            theta_checksum(&r.output));
    }
    s
}

/// Everything `repro serve-bench` needs in one struct.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub load: LoadSpec,
    pub serve: ServeConfig,
    pub cache_bytes: usize,
}

impl Default for BenchOpts {
    fn default() -> BenchOpts {
        BenchOpts {
            load: LoadSpec::default(),
            serve: ServeConfig::default(),
            cache_bytes: 8 << 20,
        }
    }
}

/// Build a registry, populate it with seeded adapters, run the loadgen
/// through a serve session, and emit the summary through `log`. Returns
/// the summary and the canonical response log.
pub fn run_serve_bench(opts: &BenchOpts, log: &EventLog)
                       -> Result<(ServeSummary, String)> {
    let registry = Registry::new(opts.cache_bytes);
    populate(&registry, &opts.load)?;
    let rt = Runtime::cpu()?;
    let mode = if opts.serve.fifo { "fifo" } else { "timed" };
    let discipline = if opts.load.open_rate_rps > 0.0 { "open" } else { "closed" };
    log.emit("serve_bench", vec![
        ("tenants", opts.load.tenants.into()),
        ("requests", opts.load.requests.into()),
        ("workers", opts.serve.workers.into()),
        ("seed", Json::Num(opts.load.seed as f64)),
        ("zipf_s", Json::Num(opts.load.zipf_s)),
        ("q", (opts.load.pauli.q as usize).into()),
        ("n_layers", (opts.load.pauli.n_layers as usize).into()),
        ("max_batch", opts.serve.policy.max_batch.into()),
        ("max_wait_us", Json::Num(opts.serve.policy.max_wait_us as f64)),
        ("mode", mode.into()),
        ("discipline", discipline.into()),
        ("cache_bytes", opts.cache_bytes.into()),
    ]);
    let outcome = serve(&rt, &registry, &opts.serve, log, |h| {
        if opts.load.open_rate_rps > 0.0 {
            open_loop(h, &opts.load)
        } else {
            closed_loop(h, &opts.load)
        }
    })?;
    Ok((outcome.summary, response_log(&outcome.body)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let zipf = Zipf::new(8, 1.2);
        let mut rng = Rng::new(11);
        let mut counts = [0usize; 8];
        for _ in 0..4000 {
            counts[zipf.sample(&mut rng)] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 4000);
        assert!(counts[0] > counts[3], "{counts:?}");
        assert!(counts[0] > counts[7], "{counts:?}");
        // uniform: roughly even
        let uni = Zipf::new(4, 0.0);
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[uni.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((800..1200).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn zipf_sampling_is_seed_deterministic() {
        let zipf = Zipf::new(16, 1.0);
        let a: Vec<usize> = {
            let mut r = Rng::new(3);
            (0..64).map(|_| zipf.sample(&mut r)).collect()
        };
        let b: Vec<usize> = {
            let mut r = Rng::new(3);
            (0..64).map(|_| zipf.sample(&mut r)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn populate_is_deterministic_per_seed() {
        let load = LoadSpec { tenants: 4, ..LoadSpec::default() };
        let r1 = Registry::new(1 << 20);
        let r2 = Registry::new(1 << 20);
        let c1 = populate(&r1, &load).unwrap();
        let c2 = populate(&r2, &load).unwrap();
        assert_eq!(c1, c2);
        assert_eq!(r1.len(), 4);
        // different seed, different adapters
        let r3 = Registry::new(1 << 20);
        let c3 = populate(&r3, &LoadSpec { seed: 9, ..load }).unwrap();
        assert_ne!(c1, c3);
    }

    #[test]
    fn request_inputs_differ_by_index_not_call_order() {
        let load = LoadSpec::default();
        let a = request_input(&load, 5);
        let b = request_input(&load, 6);
        assert_ne!(a, b);
        assert_eq!(a, request_input(&load, 5));
        assert_eq!(a.len(), load.pauli.dim());
    }
}
