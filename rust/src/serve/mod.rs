//! Multi-tenant adapter serving.
//!
//! The paper's eq.-(2) Pauli parameterization makes a fine-tuned task a
//! few-KB theta vector (log-scale in the ambient dimension), so — unlike
//! LoRA-scale PEFT, whose adapters grow linearly with dimension —
//! thousands of per-tenant adapters fit in RAM next to one shared
//! backbone. This subsystem is the runtime half of that claim:
//!
//! - [`registry`]: concurrent tenant -> adapter map, loading/evicting
//!   `QPCK` v2 adapter checkpoints, versioned torn-read-free hot-swap,
//!   and a byte-budgeted LRU of materialized dense `Q_P` matrices with
//!   hit/miss/eviction counters;
//! - [`scheduler`]: micro-batching — same-tenant requests coalesce under
//!   a max-batch / max-wait policy into tenant-homogeneous batches;
//! - [`server`]: the scoped request loop (submit -> future-like handle
//!   -> response) over [`crate::util::pool`] service workers, each
//!   holding a `Runtime::for_worker` handle onto the shared compile
//!   cache, with per-tenant and global p50/p95/p99, throughput, queue
//!   depth and batch-size metrics exported through the `EventLog`;
//! - [`loadgen`]: seeded closed-/open-loop synthetic load with Zipf
//!   tenant skew, so throughput and tail latency are measurable offline
//!   today (`repro serve-bench`, `benches/serve.rs`);
//! - [`admission`]: the control plane's front door — per-tenant
//!   token-bucket rate limits and a global queue-depth cap enforced at
//!   submit time; overload sheds with a typed `Rejected` error (counted
//!   per tenant in the `EventLog`) instead of growing the queue without
//!   bound;
//! - [`spool`]: adapter persistence — a joined-on-shutdown watcher
//!   thread ingests `QPCK` v2 uploads from a spool directory (validated
//!   through the hardened checkpoint loader, hot-swapped live,
//!   quarantined to `rejected/` on failure) and evicts tenants whose
//!   files are deleted, deferring while requests are in flight;
//! - [`shard`]: the horizontal tier — N independent shard instances
//!   (each its own registry, batcher, worker pool, admission ledger and
//!   state dir) behind a consistent-hash router, with live tenant
//!   migration and per-shard crash recovery (`repro serve-bench
//!   --shards N`).
//!
//! Determinism knobs: `fifo` server mode forms batches purely from the
//! submission sequence (no wall clock), admission runs on a logical
//! clock the driver advances explicitly, and the loadgen derives every
//! tenant pick, input payload and interarrival gap from its seed —
//! together, one seed yields a byte-identical response log *and
//! rejection ledger* at any worker count, which is the property
//! `tests/serve.rs` pins.
//!
//! ## Durability
//!
//! With a state sink attached
//! ([`Registry::with_state_sink`](registry::Registry::with_state_sink),
//! `repro serve-bench --state-dir`), every registry mutation — direct
//! registration, spool ingest, hot-swap, eviction — is appended to the
//! [`crate::store`] write-ahead log *before* it applies (so RAM never
//! runs ahead of the log), and compacted into a snapshot at session
//! end. What is durable: tenant identity, version, Pauli shape, theta
//! payload + checksum, and the originating `QPCK` path. When fsync
//! happens is the [`crate::store::Durability`] knob: `Buffered` is
//! process-crash-safe (OS page cache), `EveryN`/`Always` shrink the
//! power-cut loss window to a bounded tail. On restart, recovery
//! replays snapshot + WAL tail: a single *torn trailing record* (a
//! crash mid-append) is expected, tolerated and truncated away — the
//! restart simply doesn't know about the one mutation whose append
//! never completed; anything worse is a typed
//! [`crate::store::CorruptState`] error. A recovered server serves the
//! surviving tenants at their recorded versions with byte-identical
//! responses (`tests/store.rs` pins this with a crash-injection
//! matrix).
//!
//! ## Observability
//!
//! The serving path is instrumented end-to-end by [`crate::obs`]:
//!
//! - **Trace spans** — every request carries a
//!   [`TraceCtx`](crate::obs::TraceCtx) with per-phase durations
//!   (`admission`, `coalesce`, `queue`, `cache_lookup`, `materialize`,
//!   `apply`, `respond`) measured on the
//!   [`SpanClock`](crate::obs::SpanClock): wall-clock in timed mode, a
//!   driver-advanced logical counter in fifo mode. Per-worker flight
//!   recorders retain the last `recorder_cap` completed spans; the
//!   merged, `(trace_id, meta)`-sorted dump lands as `serve_trace`
//!   EventLog lines — fields: `trace` (16-hex id), `tenant`, `meta`,
//!   `batch`, `ok`, `submitted_ns`, `completed_ns`, `latency_us`,
//!   `phases` (array of `[name, ns]` pairs) — at session end, on
//!   demand ([`ServerHandle::dump_traces`](server::ServerHandle)), and
//!   optionally as JSONL under `--trace-dir`.
//! - **Histograms** — per-tenant and global latency is held in
//!   mergeable log₂-bucket histograms ([`Hist`](crate::obs::Hist)):
//!   O(buckets) memory per tenant, lock-free recording, quantiles with
//!   ≤ one-bucket-width error ([`server::percentile_us`] remains as
//!   the exact test oracle).
//! - **Live snapshots** — `--metrics-interval N` emits
//!   `serve_interval` lines (fields: `seq`, `completed`, `submitted`,
//!   `failed`, `rps`, `p50_us`/`p95_us`/`p99_us`, `queue_depth`,
//!   `cache_hits`/`cache_misses`/`cache_hit_rate`, `rejected`,
//!   `tenant_rejects`). Cadence is every N *completed requests* in
//!   fifo mode (driven by [`SubmitTarget::tick`]) and every N
//!   *milliseconds* of span-clock time in timed mode.
//! - **SLOs** — `--slo-p99-us T --slo-error-budget B` counts, per
//!   tenant, requests whose span-clock latency exceeds `T` µs
//!   (exactly, at record time — never reconstructed from buckets) and
//!   reports burn = violations / (B · requests) as `serve_slo` lines
//!   (fields: `tenant`, `p99_target_us`, `error_budget`, `requests`,
//!   `violations`, `burn`, `compliant`) plus a compliance section in
//!   the rendered summary ([`server::SloSummary`]). Closed-loop fifo
//!   latencies are logical (zero unless the driver advances the
//!   clock), so fifo burn is deterministic.
//!
//! - **Process-wide metrics** — a [`ServeConfig::metrics`] registry
//!   ([`crate::obs::metrics::MetricsRegistry`], `repro serve-bench
//!   --metrics-out`) mirrors the session counters into shared
//!   `serve_requests_*`, `serve_latency_ns` and `serve_batch_size`
//!   handles: shards handed the same registry sum into fleet totals
//!   while each session's own `ServeSummary`/EventLog lines stay
//!   byte-identical, because the summary reads session-private
//!   atomics, never the shared registry. The batcher mutex reports
//!   wait time and acquisitions as `lock_*{site="serve_batcher"}`
//!   through [`crate::util::sync::LockObs`]. All `serve_*` registry
//!   metrics are `Stable` (pure functions of the seeded stream), so a
//!   fifo snapshot is byte-identical at any worker count or shard
//!   split.
//!
//! All of it preserves the fifo byte-identity contract: the only
//! sanctioned wall-clock reads on the serving path live in
//! `obs/span.rs` (statically enforced by the `obs-discipline` lint).
//!
//! ## The shard tier
//!
//! [`shard`] composes N complete serving stacks behind one
//! [`ShardRouter`](shard::ShardRouter). Placement is a consistent hash:
//! tenant names map onto a virtual-node ring of FNV-1a hashes
//! ([`crate::util::fnv`]), so routing is a pure function of (tenant
//! name, shard count) and growing the fleet moves only ~1/N of tenants.
//! Each shard persists to its *own* `StateStore` dir
//! (`<state_root>/shard-NNNN`): a dead shard restarts from its own WAL
//! and recovers exactly the tenants it owned, while the router sheds
//! that shard's traffic with the typed
//! [`RejectReason::ShardDown`](admission::RejectReason::ShardDown) and
//! every other shard keeps serving. Live migration re-registers a
//! tenant on the target at its recorded version (write-ahead into the
//! target's WAL), flips the routing table atomically, then pin-drains
//! the source through the `RequestGuard`/`EvictAttempt` deferral
//! machinery — no in-flight request drops. Fifo determinism survives
//! sharding because per-shard submission order is exactly the driver's
//! submission order (synchronous routed round-trips) and every
//! response's content depends only on (adapter thetas, version, input):
//! per-shard response logs are byte-identical at any worker count, and
//! a mid-run migration leaves the merged meta-sorted log byte-identical
//! to a no-migration control over the same admitted set
//! (`tests/serve.rs` pins all three).

pub mod admission;
pub mod loadgen;
pub mod registry;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod spool;

pub use admission::{
    AdmissionConfig, AdmissionController, AdmissionReload,
    AdmissionReloadSpec, AdmissionStats, RejectReason, Rejected,
};
pub use loadgen::{
    populate_sharded, run_serve_bench, run_sharded_bench, BenchOpts,
    LoadSpec, ShardBenchReport,
};
pub use registry::{AdapterVersion, CacheStats, EvictAttempt, PauliSpec, Registry};
pub use scheduler::{BatchPolicy, InvalidBatchPolicy, Response, ResponseHandle};
pub use server::{
    percentile_us, serve, InvalidObsKnob, ServeConfig, ServeOutcome,
    ServeSummary, ServerHandle, SloSummary, SubmitTarget,
    STRUCTURED_APPLY_MIN_Q,
};
pub use shard::{
    serve_sharded, FleetSummary, ShardConfig, ShardOutcome, ShardRouter,
};
pub use spool::{FileWatch, Spool, SpoolConfig, SpoolStats, SpoolWatcher};
