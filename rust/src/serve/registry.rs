//! Concurrent multi-tenant adapter registry with versioned hot-swap and
//! an LRU-bounded cache of materialized Q_P matrices.
//!
//! The Quantum-PEFT serving story: an adapter is a few-KB theta vector
//! (log-scale in the ambient dimension, eq. 2), so thousands of tenants
//! fit in RAM next to one shared backbone. What is *not* few-KB is the
//! dense N x N `Q_P` a tenant's thetas materialize into — so those live
//! in a byte-budgeted LRU cache with hit/miss/eviction counters, while
//! the registry proper holds only the cheap theta vectors.
//!
//! Hot-swap is torn-read-free by construction: an [`AdapterVersion`] is
//! immutable once registered (thetas behind an `Arc`, version tag and
//! checksum computed at registration), and a swap atomically replaces
//! the tenant's `Arc` — an in-flight request keeps serving the snapshot
//! it already resolved, and can never observe old params under a new
//! version tag.
//!
//! Eviction safety: requests hold a [`RequestGuard`] (per-tenant
//! in-flight count) from admission to response. The LRU never evicts a
//! materialization whose tenant has in-flight requests, and
//! [`Registry::evict_tenant`] refuses outright while requests are in
//! flight, so eviction can temporarily overshoot the byte budget rather
//! than ever dropping live work.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::coordinator::checkpoint::{self, AdapterManifest};
use crate::quantum::pauli;
use crate::runtime::exe_cache::OnceMap;

/// Largest supported circuit: q = 12 is a 4096-dim Q_P (64 MiB dense) —
/// far beyond the adapter sizes the paper uses, small enough that a
/// hostile manifest cannot request a multi-GiB materialization.
pub const MAX_QUBITS: u32 = 12;

/// Pauli circuit shape an adapter parameterizes (eq. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PauliSpec {
    pub q: u32,
    pub n_layers: u32,
}

impl PauliSpec {
    pub fn dim(&self) -> usize {
        1usize << self.q
    }

    pub fn num_params(&self) -> usize {
        pauli::build(self.q as usize, self.n_layers as usize).num_params
    }
}

/// One immutable registered adapter version. All fields are fixed at
/// registration; `checksum` is a digest of the theta bits, which is what
/// lets tests prove a response was served from a consistent
/// (version, params) pair.
pub struct AdapterVersion {
    pub tenant: String,
    pub version: u64,
    pub spec: PauliSpec,
    pub thetas: Arc<Vec<f32>>,
    pub checksum: u64,
}

/// FNV-1a over the LE bytes of a theta vector — the adapter identity
/// digest stamped into [`AdapterVersion::checksum`] and responses.
pub fn theta_checksum(thetas: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for t in thetas {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

struct TenantSlot {
    current: Mutex<Arc<AdapterVersion>>,
    inflight: AtomicUsize,
}

/// Admission token for one in-flight request: holds the tenant's
/// in-flight count up from submit to response, which is what pins the
/// tenant's materializations in cache and blocks tenant eviction.
pub struct RequestGuard {
    slot: Arc<TenantSlot>,
}

/// Outcome of [`Registry::try_evict_tenant`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictAttempt {
    /// Tenant removed; its materializations were purged.
    Evicted,
    /// Tenant has this many in-flight requests — try again later.
    Deferred(usize),
    /// No such tenant (already gone).
    Unknown,
}

impl Drop for RequestGuard {
    fn drop(&mut self) {
        self.slot.inflight.fetch_sub(1, Ordering::Release);
    }
}

// ------------------------------------------------------------- mat cache ---

/// Counter snapshot of the materialization cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub bytes: usize,
    pub capacity_bytes: usize,
    pub entries: usize,
}

struct MatEntry {
    mat: Arc<Vec<f32>>,
    bytes: usize,
    last_used: u64,
}

/// Cache key: (tenant, version, theta checksum). The checksum term is
/// load-bearing: per-tenant version numbers restart at 1 when a tenant
/// is evicted and re-registered, so (tenant, version) alone could pair a
/// stale generation's matrix with a new adapter's identity.
type MatKey = (String, u64, u64);

struct MatInner {
    entries: HashMap<MatKey, MatEntry>,
    bytes: usize,
    tick: u64,
}

/// LRU cache of dense Q_P materializations, bounded in bytes. Keyed by
/// [`MatKey`] so a hot-swap naturally ages the old version out instead
/// of serving stale matrices. Concurrent first touches of one key
/// deduplicate in flight (reusing the compile cache's [`OnceMap`]):
/// one worker materializes, the others block and share the result.
struct MatCache {
    inner: Mutex<MatInner>,
    inflight: OnceMap<MatKey, Arc<Vec<f32>>>,
    capacity_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl MatCache {
    fn new(capacity_bytes: usize) -> MatCache {
        MatCache {
            inner: Mutex::new(MatInner {
                entries: HashMap::new(),
                bytes: 0,
                tick: 0,
            }),
            inflight: OnceMap::new(),
            capacity_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The materialized Q_P for `adapter`, from cache or built now.
    /// `pinned(tenant)` reports whether a tenant has in-flight requests;
    /// pinned entries are skipped by eviction (the budget may overshoot
    /// until their guards drop, never the other way around).
    fn get(&self, adapter: &AdapterVersion, pinned: &dyn Fn(&str) -> bool)
           -> Result<Arc<Vec<f32>>> {
        let key = (adapter.tenant.clone(), adapter.version, adapter.checksum);
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.entries.get_mut(&key) {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(e.mat.clone());
            }
        }
        let mut built_here = false;
        let mut entry_bytes = 0usize;
        let mat = self.inflight.get_or_try_init(&key, || {
            built_here = true;
            self.misses.fetch_add(1, Ordering::Relaxed);
            let circuit = pauli::build(adapter.spec.q as usize,
                                       adapter.spec.n_layers as usize);
            entry_bytes = circuit.materialized_bytes();
            Ok(Arc::new(circuit.materialize(&adapter.thetas)))
        })?;
        if built_here {
            self.insert_and_evict(&key, &mat, entry_bytes, pinned);
            // un-park the key so a future re-materialization (after LRU
            // eviction) goes through a fresh init instead of the old slot
            self.inflight.remove_where(|k| k == &key);
        }
        Ok(mat)
    }

    fn insert_and_evict(&self, key: &MatKey, mat: &Arc<Vec<f32>>,
                        bytes: usize, pinned: &dyn Fn(&str) -> bool) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        // a racing re-build of the same key (both workers missed before
        // either inserted) replaces the old entry: account for it, or
        // inner.bytes inflates permanently and the budget shrinks
        if let Some(old) = inner.entries.insert(
            key.clone(),
            MatEntry { mat: mat.clone(), bytes, last_used: tick },
        ) {
            inner.bytes -= old.bytes;
        }
        inner.bytes += bytes;
        while inner.bytes > self.capacity_bytes {
            let victim = inner.entries.iter()
                .filter(|(k, _)| !pinned(&k.0))
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some(e) = inner.entries.remove(&k) {
                        inner.bytes -= e.bytes;
                    }
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                // everything left is pinned by in-flight requests:
                // overshoot the budget rather than evict live work
                None => break,
            }
        }
    }

    fn purge_tenant(&self, tenant: &str) {
        let mut inner = self.inner.lock().unwrap();
        let keys: Vec<MatKey> = inner.entries.keys()
            .filter(|k| k.0 == tenant)
            .cloned()
            .collect();
        for k in keys {
            if let Some(e) = inner.entries.remove(&k) {
                inner.bytes -= e.bytes;
            }
        }
        self.inflight.remove_where(|k| k.0 == tenant);
    }

    fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes: inner.bytes,
            capacity_bytes: self.capacity_bytes,
            entries: inner.entries.len(),
        }
    }
}

// -------------------------------------------------------------- registry ---

/// The multi-tenant adapter registry: tenant id -> current adapter
/// version, plus the shared materialization cache. All methods are safe
/// to call from any number of server workers concurrently.
pub struct Registry {
    tenants: RwLock<BTreeMap<String, Arc<TenantSlot>>>,
    cache: MatCache,
}

impl Registry {
    /// `cache_capacity_bytes` bounds the dense-Q_P LRU (the theta vectors
    /// themselves are few-KB and uncounted).
    pub fn new(cache_capacity_bytes: usize) -> Registry {
        Registry {
            tenants: RwLock::new(BTreeMap::new()),
            cache: MatCache::new(cache_capacity_bytes),
        }
    }

    /// Register (tenant absent) or hot-swap (tenant present) an adapter.
    /// Returns the version now live. Validation happens *before* any
    /// slot is touched: a bad upload can never leave a tenant broken.
    pub fn register(&self, tenant: &str, spec: PauliSpec, thetas: Vec<f32>)
                    -> Result<u64> {
        if tenant.is_empty() {
            bail!("empty tenant id");
        }
        if spec.q < 1 || spec.q > MAX_QUBITS {
            bail!("tenant {tenant:?}: q={} outside supported range 1..={}",
                  spec.q, MAX_QUBITS);
        }
        let want = spec.num_params();
        if thetas.len() != want {
            bail!("tenant {tenant:?}: adapter has {} thetas but a (q={}, L={}) \
                   pauli circuit takes {want}",
                  thetas.len(), spec.q, spec.n_layers);
        }
        let checksum = theta_checksum(&thetas);
        let mut tenants = self.tenants.write().unwrap();
        match tenants.get(tenant) {
            Some(slot) => {
                let mut cur = slot.current.lock().unwrap();
                let version = cur.version + 1;
                *cur = Arc::new(AdapterVersion {
                    tenant: tenant.to_string(),
                    version,
                    spec,
                    thetas: Arc::new(thetas),
                    checksum,
                });
                Ok(version)
            }
            None => {
                let version = 1;
                tenants.insert(tenant.to_string(), Arc::new(TenantSlot {
                    current: Mutex::new(Arc::new(AdapterVersion {
                        tenant: tenant.to_string(),
                        version,
                        spec,
                        thetas: Arc::new(thetas),
                        checksum,
                    })),
                    inflight: AtomicUsize::new(0),
                }));
                Ok(version)
            }
        }
    }

    /// Load a v2 `QPCK` adapter checkpoint and register it under the
    /// tenant named in its manifest. Shape is validated from the manifest
    /// before anything is materialized.
    pub fn load_checkpoint(&self, path: &std::path::Path) -> Result<(String, u64)> {
        let (manifest, tensors) = checkpoint::load_adapter(path)
            .with_context(|| format!("loading adapter checkpoint {path:?}"))?;
        let AdapterManifest { tenant, q, n_layers } = manifest;
        let spec = PauliSpec { q, n_layers };
        if q < 1 || q > MAX_QUBITS {
            bail!("{path:?}: manifest q={q} outside supported range 1..={}",
                  MAX_QUBITS);
        }
        let thetas = tensors.iter()
            .find(|(name, _)| name == "thetas")
            .with_context(|| format!("{path:?}: no \"thetas\" tensor"))?;
        let data = thetas.1.as_f32()
            .with_context(|| format!("{path:?}: \"thetas\" is not f32"))?;
        let want = spec.num_params();
        if data.len() != want {
            bail!("{path:?}: manifest (q={q}, L={n_layers}) implies {want} \
                   thetas but the tensor holds {}", data.len());
        }
        let version = self.register(&tenant, spec, data.to_vec())?;
        Ok((tenant, version))
    }

    /// The tenant's live adapter right now (an immutable snapshot — safe
    /// to keep using across a concurrent hot-swap).
    pub fn snapshot(&self, tenant: &str) -> Result<Arc<AdapterVersion>> {
        let tenants = self.tenants.read().unwrap();
        let slot = tenants.get(tenant)
            .with_context(|| format!("unknown tenant {tenant:?}"))?;
        Ok(slot.current.lock().unwrap().clone())
    }

    /// Admit one request for `tenant`: bumps its in-flight count until
    /// the returned guard drops (pins its cache entries, blocks tenant
    /// eviction).
    pub fn begin(&self, tenant: &str) -> Result<RequestGuard> {
        let tenants = self.tenants.read().unwrap();
        let slot = tenants.get(tenant)
            .with_context(|| format!("unknown tenant {tenant:?}"))?;
        slot.inflight.fetch_add(1, Ordering::Acquire);
        Ok(RequestGuard { slot: slot.clone() })
    }

    /// Current in-flight request count for a tenant (0 if unknown).
    pub fn inflight(&self, tenant: &str) -> usize {
        let tenants = self.tenants.read().unwrap();
        tenants.get(tenant)
            .map(|s| s.inflight.load(Ordering::Acquire))
            .unwrap_or(0)
    }

    /// The dense Q_P for an adapter snapshot, through the LRU cache.
    pub fn materialized(&self, adapter: &AdapterVersion) -> Result<Arc<Vec<f32>>> {
        self.cache.get(adapter, &|tenant| self.inflight(tenant) > 0)
    }

    /// Remove a tenant and purge its materializations. Refuses while the
    /// tenant has in-flight requests — eviction never drops live work.
    pub fn evict_tenant(&self, tenant: &str) -> Result<()> {
        match self.try_evict_tenant(tenant) {
            EvictAttempt::Evicted => Ok(()),
            EvictAttempt::Deferred(inflight) => {
                bail!("tenant {tenant:?} has {inflight} in-flight request(s); \
                       refusing to evict")
            }
            EvictAttempt::Unknown => bail!("unknown tenant {tenant:?}"),
        }
    }

    /// Non-erroring eviction probe (the spool watcher's deletion path):
    /// evict now if possible, report in-flight pins as a retryable
    /// deferral, and an absent tenant as already gone.
    pub fn try_evict_tenant(&self, tenant: &str) -> EvictAttempt {
        {
            let mut tenants = self.tenants.write().unwrap();
            let Some(slot) = tenants.get(tenant) else {
                return EvictAttempt::Unknown;
            };
            let inflight = slot.inflight.load(Ordering::Acquire);
            if inflight > 0 {
                return EvictAttempt::Deferred(inflight);
            }
            tenants.remove(tenant);
        }
        // cache purge happens after the tenant lock drops: the cache's
        // pin check takes the tenant lock, so nesting the other way
        // around would be a lock-order inversion
        self.cache.purge_tenant(tenant);
        EvictAttempt::Evicted
    }

    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.tenants.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn thetas_for(spec: PauliSpec, fill: f32) -> Vec<f32> {
        vec![fill; spec.num_params()]
    }

    #[test]
    fn register_validates_before_touching_state() {
        let reg = Registry::new(1 << 20);
        let spec = PauliSpec { q: 3, n_layers: 1 };
        assert!(reg.register("", spec, thetas_for(spec, 0.1)).is_err());
        assert!(reg.register("t", PauliSpec { q: 0, n_layers: 0 }, vec![]).is_err());
        assert!(reg.register("t", PauliSpec { q: 13, n_layers: 0 }, vec![]).is_err());
        // wrong theta count
        assert!(reg.register("t", spec, vec![0.0; 3]).is_err());
        assert_eq!(reg.len(), 0);
        assert_eq!(reg.register("t", spec, thetas_for(spec, 0.1)).unwrap(), 1);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn hot_swap_bumps_version_and_keeps_old_snapshot_alive() {
        let reg = Registry::new(1 << 20);
        let spec = PauliSpec { q: 2, n_layers: 0 };
        reg.register("acme", spec, thetas_for(spec, 0.1)).unwrap();
        let old = reg.snapshot("acme").unwrap();
        assert_eq!(old.version, 1);
        let v2 = reg.register("acme", spec, thetas_for(spec, 0.9)).unwrap();
        assert_eq!(v2, 2);
        let new = reg.snapshot("acme").unwrap();
        assert_eq!(new.version, 2);
        assert_ne!(old.checksum, new.checksum);
        // the pre-swap snapshot is still fully usable
        assert_eq!(old.thetas.len(), spec.num_params());
        assert_eq!(old.checksum, theta_checksum(&old.thetas));
    }

    #[test]
    fn cache_respects_byte_budget_with_counters() {
        let spec = PauliSpec { q: 4, n_layers: 1 }; // 16x16 f32 = 1 KiB each
        let one = 16 * 16 * 4;
        let reg = Registry::new(2 * one); // room for exactly two matrices
        for t in ["a", "b", "c"] {
            reg.register(t, spec, thetas_for(spec, 0.2)).unwrap();
        }
        let a = reg.snapshot("a").unwrap();
        let b = reg.snapshot("b").unwrap();
        let c = reg.snapshot("c").unwrap();
        reg.materialized(&a).unwrap(); // miss
        reg.materialized(&a).unwrap(); // hit
        reg.materialized(&b).unwrap(); // miss
        reg.materialized(&c).unwrap(); // miss -> evicts LRU ("a")
        let s = reg.cache_stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 3, 1), "{s:?}");
        assert!(s.bytes <= s.capacity_bytes, "{s:?}");
        assert_eq!(s.entries, 2);
        reg.materialized(&a).unwrap(); // re-materialize after eviction
        let s = reg.cache_stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 4, 2), "{s:?}");
        assert!(s.bytes <= s.capacity_bytes, "{s:?}");
    }

    #[test]
    fn pinned_tenants_survive_eviction_and_block_removal() {
        let spec = PauliSpec { q: 4, n_layers: 1 };
        let one = 16 * 16 * 4;
        let reg = Registry::new(one); // room for exactly one matrix
        reg.register("pinned", spec, thetas_for(spec, 0.3)).unwrap();
        reg.register("other", spec, thetas_for(spec, 0.4)).unwrap();
        let guard = reg.begin("pinned").unwrap();
        let guard_o = reg.begin("other").unwrap();
        assert_eq!(reg.inflight("pinned"), 1);
        let p = reg.snapshot("pinned").unwrap();
        let o = reg.snapshot("other").unwrap();
        reg.materialized(&p).unwrap();
        // over budget, but every candidate is pinned: overshoot, no drops
        reg.materialized(&o).unwrap();
        let s = reg.cache_stats();
        assert_eq!(s.entries, 2, "{s:?}");
        assert!(s.bytes > s.capacity_bytes, "expected overshoot: {s:?}");
        assert_eq!(s.evictions, 0, "{s:?}");
        // an unpinned materialization that does not fit next to a pinned
        // one is served but not retained (the cache self-evicts it
        // rather than touch the pinned entry)
        drop(guard_o);
        reg.materialized(&o).unwrap(); // hit: still cached from above
        let s = reg.cache_stats();
        assert_eq!(s.hits, 1, "{s:?}");
        // tenant eviction refuses while in flight
        let e = reg.evict_tenant("pinned").unwrap_err().to_string();
        assert!(e.contains("in-flight"), "{e}");
        drop(guard);
        assert_eq!(reg.inflight("pinned"), 0);
        reg.evict_tenant("pinned").unwrap();
        let s = reg.cache_stats();
        assert_eq!(s.entries, 1);
        assert!(s.bytes <= s.capacity_bytes, "{s:?}");
        assert!(reg.snapshot("pinned").is_err());
    }

    #[test]
    fn re_registered_tenant_never_hits_a_stale_generation_matrix() {
        // evict + re-register restarts the per-tenant version counter at
        // 1; the cache key's checksum term must keep the generations'
        // materializations apart
        let spec = PauliSpec { q: 3, n_layers: 1 };
        let reg = Registry::new(1 << 20);
        reg.register("t", spec, thetas_for(spec, 0.1)).unwrap();
        let old_snap = reg.snapshot("t").unwrap();
        reg.evict_tenant("t").unwrap();
        assert_eq!(reg.register("t", spec, thetas_for(spec, 0.9)).unwrap(), 1);
        let new_snap = reg.snapshot("t").unwrap();
        assert_eq!((old_snap.version, new_snap.version), (1, 1));
        // a holdover of the old snapshot re-populates the cache...
        let old_mat = reg.materialized(&old_snap).unwrap();
        // ...but the new generation must materialize its own matrix, not
        // hit the old generation's entry under the colliding version
        let new_mat = reg.materialized(&new_snap).unwrap();
        assert_ne!(old_mat.as_slice(), new_mat.as_slice());
        let s = reg.cache_stats();
        assert_eq!((s.hits, s.misses), (0, 2), "{s:?}");
    }

    #[test]
    fn checkpoint_roundtrip_through_registry() {
        use crate::coordinator::checkpoint::{save_adapter, AdapterManifest};
        use crate::runtime::HostTensor;
        let dir = std::env::temp_dir().join("qp_serve_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("acme.qpck");
        let spec = PauliSpec { q: 5, n_layers: 2 };
        let thetas: Vec<f32> = (0..spec.num_params())
            .map(|i| (i as f32 * 0.13).sin())
            .collect();
        let m = AdapterManifest { tenant: "acme".into(), q: 5, n_layers: 2 };
        save_adapter(&path, &m, &[(
            "thetas".to_string(),
            HostTensor::f32(vec![thetas.len()], thetas.clone()),
        )]).unwrap();
        let reg = Registry::new(1 << 20);
        let (tenant, version) = reg.load_checkpoint(&path).unwrap();
        assert_eq!((tenant.as_str(), version), ("acme", 1));
        let snap = reg.snapshot("acme").unwrap();
        assert_eq!(snap.thetas.as_slice(), thetas.as_slice());
        assert_eq!(snap.checksum, theta_checksum(&thetas));
        // manifest/tensor shape mismatch is caught before materialization
        let bad = dir.join("bad.qpck");
        let m2 = AdapterManifest { tenant: "acme".into(), q: 6, n_layers: 2 };
        save_adapter(&bad, &m2, &[(
            "thetas".to_string(),
            HostTensor::f32(vec![thetas.len()], thetas),
        )]).unwrap();
        let e = reg.load_checkpoint(&bad).unwrap_err().to_string();
        assert!(e.contains("implies"), "{e}");
    }
}
