//! Concurrent multi-tenant adapter registry with versioned hot-swap and
//! an LRU-bounded cache of materialized Q_P matrices.
//!
//! The Quantum-PEFT serving story: an adapter is a few-KB theta vector
//! (log-scale in the ambient dimension, eq. 2), so thousands of tenants
//! fit in RAM next to one shared backbone. What is *not* few-KB is the
//! dense N x N `Q_P` a tenant's thetas materialize into — so those live
//! in a byte-budgeted LRU cache with hit/miss/eviction counters, while
//! the registry proper holds only the cheap theta vectors.
//!
//! Hot-swap is torn-read-free by construction: an [`AdapterVersion`] is
//! immutable once registered (thetas behind an `Arc`, version tag and
//! checksum computed at registration), and a swap atomically replaces
//! the tenant's `Arc` — an in-flight request keeps serving the snapshot
//! it already resolved, and can never observe old params under a new
//! version tag.
//!
//! Eviction safety: requests hold a [`RequestGuard`] (per-tenant
//! in-flight count) from admission to response. The LRU never evicts a
//! materialization whose tenant has in-flight requests, and
//! [`Registry::evict_tenant`] refuses outright while requests are in
//! flight, so eviction can temporarily overshoot the byte budget rather
//! than ever dropping live work.
//!
//! Fairness: [`Registry::with_tenant_quota`] bounds how many cache
//! bytes any one tenant may occupy. A tenant over its quota recycles
//! its *own* least-recently-used entries first (in-flight users hold
//! their own `Arc`s, so dropping the cache's copy never breaks live
//! work); a single materialization that alone busts the quota is served
//! but not retained, counted in [`CacheStats::quota_rejections`]. One
//! hot tenant can therefore no longer evict everyone else.
//!
//! Durability: every successful mutation (register, hot-swap, evict)
//! is emitted through a [`StateSink`] *before* it is applied —
//! write-ahead discipline — under the registry's write lock, so the
//! log order is the mutation order. The default [`NullSink`] keeps the
//! registry purely in-RAM (and byte-identical to its pre-durability
//! behavior); [`Registry::with_state_sink`] attaches a
//! [`crate::store::StateStore`], and [`Registry::restore`] replays
//! recovered [`TenantState`]s back in — at their recorded versions,
//! without re-emitting — so a restarted server serves the same tenants
//! at the same versions.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::util::sync::{lock_or_recover, read_or_recover, write_or_recover};

use crate::coordinator::checkpoint::{self, AdapterManifest};
use crate::quantum::pauli;
use crate::runtime::exe_cache::OnceMap;
use crate::store::{NullSink, StateLogFailed, StateRecord, StateSink,
                   TenantState};

/// Largest supported circuit: q = 12 is a 4096-dim Q_P (64 MiB dense) —
/// far beyond the adapter sizes the paper uses, small enough that a
/// hostile manifest cannot request a multi-GiB materialization.
pub const MAX_QUBITS: u32 = 12;

/// Deepest supported circuit: generous headroom over the paper's L <= 3
/// while keeping a hostile manifest or state record from driving
/// `pauli::build` (which loops `n_layers` times allocating 2^q-element
/// sign vectors) into billions of iterations. Checked *before* anything
/// calls [`PauliSpec::num_params`].
pub const MAX_LAYERS: u32 = 4096;

/// Pauli circuit shape an adapter parameterizes (eq. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PauliSpec {
    pub q: u32,
    pub n_layers: u32,
}

impl PauliSpec {
    pub fn dim(&self) -> usize {
        1usize << self.q
    }

    pub fn num_params(&self) -> usize {
        pauli::build(self.q as usize, self.n_layers as usize).num_params
    }
}

/// One immutable registered adapter version. All fields are fixed at
/// registration; `checksum` is a digest of the theta bits, which is what
/// lets tests prove a response was served from a consistent
/// (version, params) pair.
pub struct AdapterVersion {
    pub tenant: String,
    pub version: u64,
    pub spec: PauliSpec,
    pub thetas: Arc<Vec<f32>>,
    pub checksum: u64,
    /// Originating `QPCK` path ("" for programmatic registrations) —
    /// carried into durable state records as provenance.
    pub origin: String,
}

/// FNV-1a ([`crate::util::fnv`]) over the LE bytes of a theta vector —
/// the adapter identity digest stamped into [`AdapterVersion::checksum`],
/// responses, and durable state records.
pub fn theta_checksum(thetas: &[f32]) -> u64 {
    let mut h = crate::util::fnv::OFFSET;
    for t in thetas {
        h = crate::util::fnv::update(h, &t.to_le_bytes());
    }
    h
}

struct TenantSlot {
    current: Mutex<Arc<AdapterVersion>>,
    inflight: AtomicUsize,
}

/// One slot's durable state (what a snapshot persists for it).
fn slot_state(name: &str, slot: &TenantSlot) -> TenantState {
    let cur = lock_or_recover(&slot.current);
    TenantState {
        tenant: name.to_string(),
        version: cur.version,
        q: cur.spec.q,
        n_layers: cur.spec.n_layers,
        checksum: cur.checksum,
        path: cur.origin.clone(),
        thetas: cur.thetas.as_ref().clone(),
    }
}

/// Admission token for one in-flight request: holds the tenant's
/// in-flight count up from submit to response, which is what pins the
/// tenant's materializations in cache and blocks tenant eviction.
pub struct RequestGuard {
    slot: Arc<TenantSlot>,
}

/// Outcome of [`Registry::try_evict_tenant`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictAttempt {
    /// Tenant removed; its materializations were purged.
    Evicted,
    /// Tenant has this many in-flight requests — try again later.
    Deferred(usize),
    /// No such tenant (already gone).
    Unknown,
}

impl Drop for RequestGuard {
    fn drop(&mut self) {
        self.slot.inflight.fetch_sub(1, Ordering::Release);
    }
}

// ------------------------------------------------------------- mat cache ---

/// Counter snapshot of the materialization cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Materializations served but not retained because the tenant's
    /// byte quota could not accommodate them.
    pub quota_rejections: u64,
    pub bytes: usize,
    pub capacity_bytes: usize,
    /// Per-tenant byte quota (0 = unlimited).
    pub per_tenant_quota_bytes: usize,
    pub entries: usize,
}

struct MatEntry {
    mat: Arc<Vec<f32>>,
    bytes: usize,
    last_used: u64,
}

/// Cache key: (tenant, version, theta checksum). The checksum term is
/// load-bearing: per-tenant version numbers restart at 1 when a tenant
/// is evicted and re-registered, so (tenant, version) alone could pair a
/// stale generation's matrix with a new adapter's identity.
type MatKey = (String, u64, u64);

struct MatInner {
    /// Ordered map on purpose: eviction scans break `last_used` ties by
    /// key order, so victim selection is deterministic at any worker
    /// count (a HashMap here made fifo-mode eviction order depend on
    /// hasher seed — exactly what the `determinism` lint now rejects).
    entries: BTreeMap<MatKey, MatEntry>,
    /// Cached bytes per tenant — the quota's accounting.
    tenant_bytes: BTreeMap<String, usize>,
    bytes: usize,
    tick: u64,
}

impl MatInner {
    /// Remove an entry, keeping both byte ledgers exact.
    fn remove_entry(&mut self, key: &MatKey) {
        if let Some(e) = self.entries.remove(key) {
            self.bytes -= e.bytes;
            if let Some(tb) = self.tenant_bytes.get_mut(&key.0) {
                *tb = tb.saturating_sub(e.bytes);
                if *tb == 0 {
                    self.tenant_bytes.remove(&key.0);
                }
            }
        }
    }
}

/// LRU cache of dense Q_P materializations, bounded in bytes globally
/// and (optionally) per tenant. Keyed by [`MatKey`] so a hot-swap
/// naturally ages the old version out instead of serving stale
/// matrices. Concurrent first touches of one key deduplicate in flight
/// (reusing the compile cache's [`OnceMap`]): one worker materializes,
/// the others block and share the result.
struct MatCache {
    inner: Mutex<MatInner>,
    inflight: OnceMap<MatKey, Arc<Vec<f32>>>,
    capacity_bytes: usize,
    /// Max cached bytes for any one tenant; 0 = unlimited.
    per_tenant_quota: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    quota_rejections: AtomicU64,
}

impl MatCache {
    fn new(capacity_bytes: usize) -> MatCache {
        MatCache {
            inner: Mutex::new(MatInner {
                entries: BTreeMap::new(),
                tenant_bytes: BTreeMap::new(),
                bytes: 0,
                tick: 0,
            }),
            inflight: OnceMap::new(),
            capacity_bytes,
            per_tenant_quota: 0,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            quota_rejections: AtomicU64::new(0),
        }
    }

    /// The materialized Q_P for `adapter`, from cache or built now.
    /// `pinned(tenant)` reports whether a tenant has in-flight requests;
    /// pinned entries are skipped by eviction (the budget may overshoot
    /// until their guards drop, never the other way around).
    fn get(&self, adapter: &AdapterVersion, pinned: &dyn Fn(&str) -> bool)
           -> Result<Arc<Vec<f32>>> {
        let key = (adapter.tenant.clone(), adapter.version, adapter.checksum);
        {
            let mut inner = lock_or_recover(&self.inner);
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.entries.get_mut(&key) {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(e.mat.clone());
            }
        }
        let mut built_here = false;
        let mut entry_bytes = 0usize;
        let mat = self.inflight.get_or_try_init(&key, || {
            built_here = true;
            self.misses.fetch_add(1, Ordering::Relaxed);
            let circuit = pauli::build(adapter.spec.q as usize,
                                       adapter.spec.n_layers as usize);
            entry_bytes = circuit.materialized_bytes();
            Ok(Arc::new(circuit.materialize(&adapter.thetas)))
        })?;
        if built_here {
            self.insert_and_evict(&key, &mat, entry_bytes, pinned);
            // un-park the key so a future re-materialization (after LRU
            // eviction) goes through a fresh init instead of the old slot
            self.inflight.remove_where(|k| k == &key);
        } else {
            // joined another worker's in-flight build: a hit for
            // accounting (hits + misses == lookups at every sync point,
            // which the fifo interval snapshots rely on)
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        Ok(mat)
    }

    fn insert_and_evict(&self, key: &MatKey, mat: &Arc<Vec<f32>>,
                        bytes: usize, pinned: &dyn Fn(&str) -> bool) {
        let mut inner = lock_or_recover(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        // a racing re-build of the same key (both workers missed before
        // either inserted) replaces the old entry: account for it, or
        // inner.bytes inflates permanently and the budget shrinks
        if let Some(old) = inner.entries.insert(
            key.clone(),
            MatEntry { mat: mat.clone(), bytes, last_used: tick },
        ) {
            inner.bytes -= old.bytes;
            if let Some(tb) = inner.tenant_bytes.get_mut(&key.0) {
                *tb = tb.saturating_sub(old.bytes);
            }
        }
        inner.bytes += bytes;
        *inner.tenant_bytes.entry(key.0.clone()).or_insert(0) += bytes;
        // per-tenant quota: an over-quota tenant recycles its OWN
        // least-recently-used entries first — never a neighbor's. The
        // in-flight pin is deliberately not consulted here: the pin
        // exists to stop cross-tenant thrashing, while a tenant over its
        // own budget is trading its own oldest entry (any live user
        // holds its own Arc, so nothing in flight breaks). An entry that
        // alone busts the quota is rejected *up front* — served but not
        // retained — so it can never flush the tenant's warm entries on
        // its way to an inevitable rejection.
        if self.per_tenant_quota > 0 {
            if bytes > self.per_tenant_quota {
                inner.remove_entry(key);
                self.quota_rejections.fetch_add(1, Ordering::Relaxed);
            } else {
                while inner.tenant_bytes.get(&key.0).copied().unwrap_or(0)
                    > self.per_tenant_quota
                {
                    let victim = inner.entries.iter()
                        .filter(|(k, _)| k.0 == key.0 && *k != key)
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(k, _)| k.clone());
                    match victim {
                        Some(k) => {
                            inner.remove_entry(&k);
                            self.evictions.fetch_add(1, Ordering::Relaxed);
                        }
                        // unreachable in practice: the new entry fits the
                        // quota, so an over-quota tenant has older entries
                        None => break,
                    }
                }
            }
        }
        while inner.bytes > self.capacity_bytes {
            let victim = inner.entries.iter()
                .filter(|(k, _)| !pinned(&k.0))
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    inner.remove_entry(&k);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                // everything left is pinned by in-flight requests:
                // overshoot the budget rather than evict live work
                None => break,
            }
        }
    }

    fn purge_tenant(&self, tenant: &str) {
        let mut inner = lock_or_recover(&self.inner);
        let keys: Vec<MatKey> = inner.entries.keys()
            .filter(|k| k.0 == tenant)
            .cloned()
            .collect();
        for k in keys {
            inner.remove_entry(&k);
        }
        inner.tenant_bytes.remove(tenant);
        self.inflight.remove_where(|k| k.0 == tenant);
    }

    fn stats(&self) -> CacheStats {
        let inner = lock_or_recover(&self.inner);
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            quota_rejections: self.quota_rejections.load(Ordering::Relaxed),
            bytes: inner.bytes,
            capacity_bytes: self.capacity_bytes,
            per_tenant_quota_bytes: self.per_tenant_quota,
            entries: inner.entries.len(),
        }
    }
}

// -------------------------------------------------------------- registry ---

/// The multi-tenant adapter registry: tenant id -> current adapter
/// version, plus the shared materialization cache. All methods are safe
/// to call from any number of server workers concurrently.
pub struct Registry {
    tenants: RwLock<BTreeMap<String, Arc<TenantSlot>>>,
    cache: MatCache,
    /// Durable mutation log (write-ahead: appended under the tenants
    /// write lock, *before* the mutation applies). [`NullSink`] by
    /// default.
    sink: Arc<dyn StateSink>,
}

impl Registry {
    /// `cache_capacity_bytes` bounds the dense-Q_P LRU (the theta vectors
    /// themselves are few-KB and uncounted).
    pub fn new(cache_capacity_bytes: usize) -> Registry {
        Registry {
            tenants: RwLock::new(BTreeMap::new()),
            cache: MatCache::new(cache_capacity_bytes),
            sink: Arc::new(NullSink),
        }
    }

    /// Bound any one tenant's share of the materialization cache
    /// (0 = unlimited, the default). Builder-style: call before serving.
    pub fn with_tenant_quota(mut self, quota_bytes: usize) -> Registry {
        self.cache.per_tenant_quota = quota_bytes;
        self
    }

    /// Attach a durable mutation sink (typically a
    /// [`crate::store::StateStore`]). Builder-style: call before
    /// serving, after [`Registry::restore`]-ing any recovered state —
    /// restores must not re-append to the log they came from.
    pub fn with_state_sink(mut self, sink: Arc<dyn StateSink>) -> Registry {
        self.sink = sink;
        self
    }

    /// Register (tenant absent) or hot-swap (tenant present) an adapter.
    /// Returns the version now live. Validation happens *before* any
    /// slot is touched: a bad upload can never leave a tenant broken.
    pub fn register(&self, tenant: &str, spec: PauliSpec, thetas: Vec<f32>)
                    -> Result<u64> {
        self.register_from(tenant, spec, thetas, "")
    }

    /// [`register`](Registry::register) with provenance: `origin` is the
    /// `QPCK` path the adapter came from ("" for programmatic
    /// registrations), stamped into the durable state record. The WAL
    /// record is appended before the slot mutates (write-ahead), so a
    /// sink failure leaves the registry untouched.
    pub fn register_from(&self, tenant: &str, spec: PauliSpec,
                         thetas: Vec<f32>, origin: &str) -> Result<u64> {
        if tenant.is_empty() {
            bail!("empty tenant id");
        }
        if spec.q < 1 || spec.q > MAX_QUBITS {
            bail!("tenant {tenant:?}: q={} outside supported range 1..={}",
                  spec.q, MAX_QUBITS);
        }
        if spec.n_layers > MAX_LAYERS {
            bail!("tenant {tenant:?}: n_layers={} exceeds cap {MAX_LAYERS}",
                  spec.n_layers);
        }
        let want = spec.num_params();
        if thetas.len() != want {
            bail!("tenant {tenant:?}: adapter has {} thetas but a (q={}, L={}) \
                   pauli circuit takes {want}",
                  thetas.len(), spec.q, spec.n_layers);
        }
        let checksum = theta_checksum(&thetas);
        let state = |version: u64, thetas: &Vec<f32>| TenantState {
            tenant: tenant.to_string(),
            version,
            q: spec.q,
            n_layers: spec.n_layers,
            checksum,
            path: origin.to_string(),
            thetas: thetas.clone(),
        };
        let mut tenants = write_or_recover(&self.tenants);
        match tenants.get(tenant) {
            Some(slot) => {
                let mut cur = lock_or_recover(&slot.current);
                let version = cur.version + 1;
                if self.sink.wants_records() {
                    self.sink
                        // analyze: allow(blocking-under-lock) WAL append is atomic with the in-RAM swap; RAM never diverges ahead of the log
                        .record(&StateRecord::Swap(state(version, &thetas)))
                        .map_err(|e| StateLogFailed {
                            tenant: tenant.to_string(),
                            detail: e.to_string(),
                        })?;
                }
                *cur = Arc::new(AdapterVersion {
                    tenant: tenant.to_string(),
                    version,
                    spec,
                    thetas: Arc::new(thetas),
                    checksum,
                    origin: origin.to_string(),
                });
                Ok(version)
            }
            None => {
                let version = 1;
                if self.sink.wants_records() {
                    self.sink
                        // analyze: allow(blocking-under-lock) WAL append is atomic with the registration; RAM never diverges ahead of the log
                        .record(&StateRecord::Register(state(version, &thetas)))
                        .map_err(|e| StateLogFailed {
                            tenant: tenant.to_string(),
                            detail: e.to_string(),
                        })?;
                }
                tenants.insert(tenant.to_string(), Arc::new(TenantSlot {
                    current: Mutex::new(Arc::new(AdapterVersion {
                        tenant: tenant.to_string(),
                        version,
                        spec,
                        thetas: Arc::new(thetas),
                        checksum,
                        origin: origin.to_string(),
                    })),
                    inflight: AtomicUsize::new(0),
                }));
                Ok(version)
            }
        }
    }

    /// Re-install one recovered [`TenantState`] at its *recorded*
    /// version (the recovery half of the durability contract; see
    /// [`mod@crate::store::recover`]). Validates shape and re-verifies
    /// the
    /// theta checksum; does **not** emit to the state sink — the record
    /// being restored is already in the log. Call before
    /// [`Registry::with_state_sink`] attaches the store.
    pub fn restore(&self, ts: &TenantState) -> Result<u64> {
        let spec = PauliSpec { q: ts.q, n_layers: ts.n_layers };
        if ts.tenant.is_empty() {
            bail!("recovered state has an empty tenant id");
        }
        if ts.q < 1 || ts.q > MAX_QUBITS {
            bail!("recovered tenant {:?}: q={} outside supported range 1..={}",
                  ts.tenant, ts.q, MAX_QUBITS);
        }
        if ts.n_layers > MAX_LAYERS {
            bail!("recovered tenant {:?}: n_layers={} exceeds cap {MAX_LAYERS}",
                  ts.tenant, ts.n_layers);
        }
        let want = spec.num_params();
        if ts.thetas.len() != want {
            bail!("recovered tenant {:?}: {} thetas but (q={}, L={}) takes \
                   {want}", ts.tenant, ts.thetas.len(), ts.q, ts.n_layers);
        }
        let computed = theta_checksum(&ts.thetas);
        if computed != ts.checksum {
            bail!("recovered tenant {:?}: theta checksum mismatch (recorded \
                   {:016x}, computed {computed:016x})", ts.tenant, ts.checksum);
        }
        let adapter = Arc::new(AdapterVersion {
            tenant: ts.tenant.clone(),
            version: ts.version,
            spec,
            thetas: Arc::new(ts.thetas.clone()),
            checksum: ts.checksum,
            origin: ts.path.clone(),
        });
        let mut tenants = write_or_recover(&self.tenants);
        match tenants.get(&ts.tenant) {
            Some(slot) => *lock_or_recover(&slot.current) = adapter,
            None => {
                tenants.insert(ts.tenant.clone(), Arc::new(TenantSlot {
                    current: Mutex::new(adapter),
                    inflight: AtomicUsize::new(0),
                }));
            }
        }
        Ok(ts.version)
    }

    /// Every tenant's durable state, sorted by tenant name — what a
    /// snapshot compaction persists.
    pub fn export_state(&self) -> Vec<TenantState> {
        let tenants = read_or_recover(&self.tenants);
        tenants.iter()
            .map(|(name, slot)| slot_state(name, slot))
            .collect()
    }

    /// Compact the attached store's WAL into a snapshot of this
    /// registry's live state. Holds the registry write lock for the
    /// duration, so the snapshot and its last-sequence pin are captured
    /// atomically with respect to concurrent mutations (both this and
    /// [`register`](Registry::register) take registry-lock-then-WAL-lock,
    /// so there is no ordering inversion).
    pub fn compact_into(&self, store: &crate::store::StateStore) -> Result<()> {
        let tenants = write_or_recover(&self.tenants);
        let entries: Vec<TenantState> = tenants.iter()
            .map(|(name, slot)| slot_state(name, slot))
            .collect();
        // analyze: allow(blocking-under-lock) deliberate: the snapshot must be atomic w.r.t. mutations, see the doc comment above
        store.compact(&entries)
    }

    /// Load a `QPCK` adapter checkpoint (v2 legacy or v3 checksummed)
    /// and register it under the tenant named in its manifest. Shape is
    /// validated from the manifest before anything is materialized.
    pub fn load_checkpoint(&self, path: &std::path::Path) -> Result<(String, u64)> {
        let (manifest, tensors) = checkpoint::load_adapter(path)
            .with_context(|| format!("loading adapter checkpoint {path:?}"))?;
        let AdapterManifest { tenant, q, n_layers } = manifest;
        let spec = PauliSpec { q, n_layers };
        if q < 1 || q > MAX_QUBITS {
            bail!("{path:?}: manifest q={q} outside supported range 1..={}",
                  MAX_QUBITS);
        }
        if n_layers > MAX_LAYERS {
            bail!("{path:?}: manifest n_layers={n_layers} exceeds cap \
                   {MAX_LAYERS}");
        }
        let thetas = tensors.iter()
            .find(|(name, _)| name == "thetas")
            .with_context(|| format!("{path:?}: no \"thetas\" tensor"))?;
        let data = thetas.1.as_f32()
            .with_context(|| format!("{path:?}: \"thetas\" is not f32"))?;
        let want = spec.num_params();
        if data.len() != want {
            bail!("{path:?}: manifest (q={q}, L={n_layers}) implies {want} \
                   thetas but the tensor holds {}", data.len());
        }
        let origin = path.display().to_string();
        let version =
            self.register_from(&tenant, spec, data.to_vec(), &origin)?;
        Ok((tenant, version))
    }

    /// The tenant's live adapter right now (an immutable snapshot — safe
    /// to keep using across a concurrent hot-swap).
    pub fn snapshot(&self, tenant: &str) -> Result<Arc<AdapterVersion>> {
        let tenants = read_or_recover(&self.tenants);
        let slot = tenants.get(tenant)
            .with_context(|| format!("unknown tenant {tenant:?}"))?;
        Ok(lock_or_recover(&slot.current).clone())
    }

    /// Admit one request for `tenant`: bumps its in-flight count until
    /// the returned guard drops (pins its cache entries, blocks tenant
    /// eviction).
    pub fn begin(&self, tenant: &str) -> Result<RequestGuard> {
        let tenants = read_or_recover(&self.tenants);
        let slot = tenants.get(tenant)
            .with_context(|| format!("unknown tenant {tenant:?}"))?;
        slot.inflight.fetch_add(1, Ordering::Acquire);
        Ok(RequestGuard { slot: slot.clone() })
    }

    /// Current in-flight request count for a tenant (0 if unknown).
    pub fn inflight(&self, tenant: &str) -> usize {
        let tenants = read_or_recover(&self.tenants);
        tenants.get(tenant)
            .map(|s| s.inflight.load(Ordering::Acquire))
            .unwrap_or(0)
    }

    /// The dense Q_P for an adapter snapshot, through the LRU cache.
    pub fn materialized(&self, adapter: &AdapterVersion) -> Result<Arc<Vec<f32>>> {
        self.cache.get(adapter, &|tenant| self.inflight(tenant) > 0)
    }

    /// Remove a tenant and purge its materializations. Refuses while the
    /// tenant has in-flight requests — eviction never drops live work.
    pub fn evict_tenant(&self, tenant: &str) -> Result<()> {
        match self.try_evict_tenant(tenant)? {
            EvictAttempt::Evicted => Ok(()),
            EvictAttempt::Deferred(inflight) => {
                bail!("tenant {tenant:?} has {inflight} in-flight request(s); \
                       refusing to evict")
            }
            EvictAttempt::Unknown => bail!("unknown tenant {tenant:?}"),
        }
    }

    /// Non-erroring eviction probe (the spool watcher's deletion path):
    /// evict now if possible, report in-flight pins as a retryable
    /// deferral, and an absent tenant as already gone. `Err` means the
    /// durable eviction record could not be appended — the tenant stays
    /// live (RAM never diverges ahead of the log).
    pub fn try_evict_tenant(&self, tenant: &str) -> Result<EvictAttempt> {
        {
            let mut tenants = write_or_recover(&self.tenants);
            let Some(slot) = tenants.get(tenant) else {
                return Ok(EvictAttempt::Unknown);
            };
            let inflight = slot.inflight.load(Ordering::Acquire);
            if inflight > 0 {
                return Ok(EvictAttempt::Deferred(inflight));
            }
            if self.sink.wants_records() {
                self.sink
                    // analyze: allow(blocking-under-lock) WAL append is atomic with the eviction; RAM never diverges ahead of the log
                    .record(&StateRecord::Evict { tenant: tenant.to_string() })
                    .map_err(|e| StateLogFailed {
                        tenant: tenant.to_string(),
                        detail: e.to_string(),
                    })?;
            }
            tenants.remove(tenant);
        }
        // cache purge happens after the tenant lock drops: the cache's
        // pin check takes the tenant lock, so nesting the other way
        // around would be a lock-order inversion
        self.cache.purge_tenant(tenant);
        Ok(EvictAttempt::Evicted)
    }

    pub fn tenant_names(&self) -> Vec<String> {
        read_or_recover(&self.tenants).keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        read_or_recover(&self.tenants).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn thetas_for(spec: PauliSpec, fill: f32) -> Vec<f32> {
        vec![fill; spec.num_params()]
    }

    #[test]
    fn register_validates_before_touching_state() {
        let reg = Registry::new(1 << 20);
        let spec = PauliSpec { q: 3, n_layers: 1 };
        assert!(reg.register("", spec, thetas_for(spec, 0.1)).is_err());
        assert!(reg.register("t", PauliSpec { q: 0, n_layers: 0 }, vec![]).is_err());
        assert!(reg.register("t", PauliSpec { q: 13, n_layers: 0 }, vec![]).is_err());
        // wrong theta count
        assert!(reg.register("t", spec, vec![0.0; 3]).is_err());
        assert_eq!(reg.len(), 0);
        assert_eq!(reg.register("t", spec, thetas_for(spec, 0.1)).unwrap(), 1);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn hot_swap_bumps_version_and_keeps_old_snapshot_alive() {
        let reg = Registry::new(1 << 20);
        let spec = PauliSpec { q: 2, n_layers: 0 };
        reg.register("acme", spec, thetas_for(spec, 0.1)).unwrap();
        let old = reg.snapshot("acme").unwrap();
        assert_eq!(old.version, 1);
        let v2 = reg.register("acme", spec, thetas_for(spec, 0.9)).unwrap();
        assert_eq!(v2, 2);
        let new = reg.snapshot("acme").unwrap();
        assert_eq!(new.version, 2);
        assert_ne!(old.checksum, new.checksum);
        // the pre-swap snapshot is still fully usable
        assert_eq!(old.thetas.len(), spec.num_params());
        assert_eq!(old.checksum, theta_checksum(&old.thetas));
    }

    #[test]
    fn cache_respects_byte_budget_with_counters() {
        let spec = PauliSpec { q: 4, n_layers: 1 }; // 16x16 f32 = 1 KiB each
        let one = 16 * 16 * 4;
        let reg = Registry::new(2 * one); // room for exactly two matrices
        for t in ["a", "b", "c"] {
            reg.register(t, spec, thetas_for(spec, 0.2)).unwrap();
        }
        let a = reg.snapshot("a").unwrap();
        let b = reg.snapshot("b").unwrap();
        let c = reg.snapshot("c").unwrap();
        reg.materialized(&a).unwrap(); // miss
        reg.materialized(&a).unwrap(); // hit
        reg.materialized(&b).unwrap(); // miss
        reg.materialized(&c).unwrap(); // miss -> evicts LRU ("a")
        let s = reg.cache_stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 3, 1), "{s:?}");
        assert!(s.bytes <= s.capacity_bytes, "{s:?}");
        assert_eq!(s.entries, 2);
        reg.materialized(&a).unwrap(); // re-materialize after eviction
        let s = reg.cache_stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 4, 2), "{s:?}");
        assert!(s.bytes <= s.capacity_bytes, "{s:?}");
        assert_eq!(s.quota_rejections, 0, "{s:?}");
    }

    #[test]
    fn pinned_tenants_survive_eviction_and_block_removal() {
        let spec = PauliSpec { q: 4, n_layers: 1 };
        let one = 16 * 16 * 4;
        let reg = Registry::new(one); // room for exactly one matrix
        reg.register("pinned", spec, thetas_for(spec, 0.3)).unwrap();
        reg.register("other", spec, thetas_for(spec, 0.4)).unwrap();
        let guard = reg.begin("pinned").unwrap();
        let guard_o = reg.begin("other").unwrap();
        assert_eq!(reg.inflight("pinned"), 1);
        let p = reg.snapshot("pinned").unwrap();
        let o = reg.snapshot("other").unwrap();
        reg.materialized(&p).unwrap();
        // over budget, but every candidate is pinned: overshoot, no drops
        reg.materialized(&o).unwrap();
        let s = reg.cache_stats();
        assert_eq!(s.entries, 2, "{s:?}");
        assert!(s.bytes > s.capacity_bytes, "expected overshoot: {s:?}");
        assert_eq!(s.evictions, 0, "{s:?}");
        // an unpinned materialization that does not fit next to a pinned
        // one is served but not retained (the cache self-evicts it
        // rather than touch the pinned entry)
        drop(guard_o);
        reg.materialized(&o).unwrap(); // hit: still cached from above
        let s = reg.cache_stats();
        assert_eq!(s.hits, 1, "{s:?}");
        // tenant eviction refuses while in flight
        let e = reg.evict_tenant("pinned").unwrap_err().to_string();
        assert!(e.contains("in-flight"), "{e}");
        drop(guard);
        assert_eq!(reg.inflight("pinned"), 0);
        reg.evict_tenant("pinned").unwrap();
        let s = reg.cache_stats();
        assert_eq!(s.entries, 1);
        assert!(s.bytes <= s.capacity_bytes, "{s:?}");
        assert!(reg.snapshot("pinned").is_err());
    }

    #[test]
    fn tenant_quota_recycles_own_entries_not_neighbors() {
        let spec = PauliSpec { q: 4, n_layers: 1 }; // 1 KiB dense each
        let one = 16 * 16 * 4;
        // global room for four matrices, but no tenant may hold more
        // than one of them
        let reg = Registry::new(4 * one).with_tenant_quota(one);
        reg.register("hot", spec, thetas_for(spec, 0.1)).unwrap();
        reg.register("cold", spec, thetas_for(spec, 0.2)).unwrap();
        let cold = reg.snapshot("cold").unwrap();
        reg.materialized(&cold).unwrap(); // miss: cold cached
        let hot1 = reg.snapshot("hot").unwrap();
        reg.materialized(&hot1).unwrap(); // miss: hot v1 cached
        // hot-swap; the new generation's materialization must push out
        // hot's OWN v1 entry, never cold's
        reg.register("hot", spec, thetas_for(spec, 0.9)).unwrap();
        let hot2 = reg.snapshot("hot").unwrap();
        reg.materialized(&hot2).unwrap(); // miss: evicts hot v1 by quota
        let s = reg.cache_stats();
        assert_eq!((s.misses, s.evictions, s.quota_rejections), (3, 1, 0),
                   "{s:?}");
        assert_eq!(s.entries, 2, "{s:?}");
        reg.materialized(&cold).unwrap(); // cold survived: hit
        reg.materialized(&hot2).unwrap(); // hot v2 cached: hit
        let s = reg.cache_stats();
        assert_eq!(s.hits, 2, "{s:?}");
        // hot v1 is gone: re-materializing it is a fresh miss (and
        // recycles v2, keeping the tenant at its quota)
        reg.materialized(&hot1).unwrap();
        let s = reg.cache_stats();
        assert_eq!((s.misses, s.evictions), (4, 2), "{s:?}");
        assert_eq!(s.entries, 2, "{s:?}");
    }

    #[test]
    fn entry_larger_than_quota_is_served_uncached() {
        let spec = PauliSpec { q: 4, n_layers: 1 };
        let one = 16 * 16 * 4;
        // quota below a single materialization: serve, don't retain
        let reg = Registry::new(4 * one).with_tenant_quota(one - 1);
        reg.register("t", spec, thetas_for(spec, 0.5)).unwrap();
        let snap = reg.snapshot("t").unwrap();
        let m1 = reg.materialized(&snap).unwrap();
        let s = reg.cache_stats();
        assert_eq!((s.misses, s.quota_rejections, s.entries), (1, 1, 0),
                   "{s:?}");
        assert_eq!(s.bytes, 0, "{s:?}");
        // next request misses again (nothing was retained) but still
        // serves the right matrix
        let m2 = reg.materialized(&snap).unwrap();
        assert_eq!(m1.as_slice(), m2.as_slice());
        let s = reg.cache_stats();
        assert_eq!((s.hits, s.misses, s.quota_rejections), (0, 2, 2), "{s:?}");
    }

    #[test]
    fn oversized_entry_rejection_spares_existing_warm_entries() {
        let small = PauliSpec { q: 3, n_layers: 1 }; // 8x8x4 = 256 B dense
        let big = PauliSpec { q: 4, n_layers: 1 }; // 16x16x4 = 1 KiB dense
        let reg = Registry::new(1 << 20).with_tenant_quota(512);
        reg.register("t", small, thetas_for(small, 0.1)).unwrap();
        let s_snap = reg.snapshot("t").unwrap();
        reg.materialized(&s_snap).unwrap(); // 256 B cached, under quota
        // hot-swap to a shape whose matrix alone busts the quota: it is
        // served uncached WITHOUT flushing the warm 256 B entry first
        reg.register("t", big, thetas_for(big, 0.2)).unwrap();
        let b_snap = reg.snapshot("t").unwrap();
        reg.materialized(&b_snap).unwrap();
        let s = reg.cache_stats();
        assert_eq!((s.misses, s.evictions, s.quota_rejections), (2, 0, 1),
                   "{s:?}");
        assert_eq!(s.entries, 1, "oversized entry flushed the warm cache: {s:?}");
        // the old generation's entry is still warm
        reg.materialized(&s_snap).unwrap();
        assert_eq!(reg.cache_stats().hits, 1);
    }

    #[test]
    fn layer_cap_rejects_hostile_depth_before_building_anything() {
        let reg = Registry::new(1 << 20);
        let deep = PauliSpec { q: 3, n_layers: u32::MAX };
        // must fail fast on the cap — not iterate u32::MAX layers inside
        // pauli::build on the way to a theta-count mismatch
        let e = reg.register("t", deep, vec![0.0; 8]).unwrap_err().to_string();
        assert!(e.contains("exceeds cap"), "{e}");
        let thetas = vec![0.5; 7];
        let e = reg.restore(&TenantState {
            tenant: "t".into(),
            version: 1,
            q: 3,
            n_layers: u32::MAX,
            checksum: theta_checksum(&thetas),
            path: String::new(),
            thetas,
        }).unwrap_err().to_string();
        assert!(e.contains("exceeds cap"), "{e}");
        assert!(reg.is_empty());
    }

    #[test]
    fn re_registered_tenant_never_hits_a_stale_generation_matrix() {
        // evict + re-register restarts the per-tenant version counter at
        // 1; the cache key's checksum term must keep the generations'
        // materializations apart
        let spec = PauliSpec { q: 3, n_layers: 1 };
        let reg = Registry::new(1 << 20);
        reg.register("t", spec, thetas_for(spec, 0.1)).unwrap();
        let old_snap = reg.snapshot("t").unwrap();
        reg.evict_tenant("t").unwrap();
        assert_eq!(reg.register("t", spec, thetas_for(spec, 0.9)).unwrap(), 1);
        let new_snap = reg.snapshot("t").unwrap();
        assert_eq!((old_snap.version, new_snap.version), (1, 1));
        // a holdover of the old snapshot re-populates the cache...
        let old_mat = reg.materialized(&old_snap).unwrap();
        // ...but the new generation must materialize its own matrix, not
        // hit the old generation's entry under the colliding version
        let new_mat = reg.materialized(&new_snap).unwrap();
        assert_ne!(old_mat.as_slice(), new_mat.as_slice());
        let s = reg.cache_stats();
        assert_eq!((s.hits, s.misses), (0, 2), "{s:?}");
    }

    #[test]
    fn checkpoint_roundtrip_through_registry() {
        use crate::coordinator::checkpoint::{save_adapter, AdapterManifest};
        use crate::runtime::HostTensor;
        let dir = std::env::temp_dir().join("qp_serve_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("acme.qpck");
        let spec = PauliSpec { q: 5, n_layers: 2 };
        let thetas: Vec<f32> = (0..spec.num_params())
            .map(|i| (i as f32 * 0.13).sin())
            .collect();
        let m = AdapterManifest { tenant: "acme".into(), q: 5, n_layers: 2 };
        save_adapter(&path, &m, &[(
            "thetas".to_string(),
            HostTensor::f32(vec![thetas.len()], thetas.clone()),
        )]).unwrap();
        let reg = Registry::new(1 << 20);
        let (tenant, version) = reg.load_checkpoint(&path).unwrap();
        assert_eq!((tenant.as_str(), version), ("acme", 1));
        let snap = reg.snapshot("acme").unwrap();
        assert_eq!(snap.thetas.as_slice(), thetas.as_slice());
        assert_eq!(snap.checksum, theta_checksum(&thetas));
        // provenance: the originating checkpoint path is recorded
        assert_eq!(snap.origin, path.display().to_string());
        // manifest/tensor shape mismatch is caught before materialization
        let bad = dir.join("bad.qpck");
        let m2 = AdapterManifest { tenant: "acme".into(), q: 6, n_layers: 2 };
        save_adapter(&bad, &m2, &[(
            "thetas".to_string(),
            HostTensor::f32(vec![thetas.len()], thetas),
        )]).unwrap();
        let e = reg.load_checkpoint(&bad).unwrap_err().to_string();
        assert!(e.contains("implies"), "{e}");
    }

    // ------------------------------------------------------ state sink ---

    /// Recording sink for tests: remembers every record, optionally
    /// failing to prove the write-ahead ordering.
    struct RecordingSink {
        records: Mutex<Vec<StateRecord>>,
        fail: std::sync::atomic::AtomicBool,
    }

    impl RecordingSink {
        fn new() -> Arc<RecordingSink> {
            Arc::new(RecordingSink {
                records: Mutex::new(Vec::new()),
                fail: std::sync::atomic::AtomicBool::new(false),
            })
        }
    }

    impl StateSink for RecordingSink {
        fn record(&self, rec: &StateRecord) -> Result<()> {
            if self.fail.load(Ordering::Relaxed) {
                bail!("sink down");
            }
            self.records.lock().unwrap().push(rec.clone());
            Ok(())
        }
    }

    #[test]
    fn mutations_emit_state_records_in_order() {
        let sink = RecordingSink::new();
        let spec = PauliSpec { q: 3, n_layers: 1 };
        let reg = Registry::new(1 << 20).with_state_sink(sink.clone());
        reg.register("a", spec, thetas_for(spec, 0.1)).unwrap();
        reg.register("a", spec, thetas_for(spec, 0.2)).unwrap();
        reg.register("b", spec, thetas_for(spec, 0.3)).unwrap();
        reg.evict_tenant("b").unwrap();
        let recs = sink.records.lock().unwrap();
        assert_eq!(recs.len(), 4);
        match (&recs[0], &recs[1], &recs[2], &recs[3]) {
            (
                StateRecord::Register(r0),
                StateRecord::Swap(r1),
                StateRecord::Register(r2),
                StateRecord::Evict { tenant },
            ) => {
                assert_eq!((r0.tenant.as_str(), r0.version), ("a", 1));
                assert_eq!((r1.tenant.as_str(), r1.version), ("a", 2));
                assert_eq!(r1.checksum, theta_checksum(&thetas_for(spec, 0.2)));
                assert_eq!((r2.tenant.as_str(), r2.version), ("b", 1));
                assert_eq!(tenant, "b");
            }
            other => panic!("unexpected record shapes: {other:?}"),
        }
    }

    #[test]
    fn sink_failure_aborts_the_mutation_before_it_applies() {
        let sink = RecordingSink::new();
        let spec = PauliSpec { q: 3, n_layers: 1 };
        let reg = Registry::new(1 << 20).with_state_sink(sink.clone());
        reg.register("t", spec, thetas_for(spec, 0.1)).unwrap();
        sink.fail.store(true, Ordering::Relaxed);
        // write-ahead: a failed log append must leave RAM untouched,
        // and surface as the typed (retryable) StateLogFailed
        let e = reg.register("t", spec, thetas_for(spec, 0.9)).unwrap_err();
        let typed = e.downcast_ref::<StateLogFailed>().expect("typed log failure");
        assert_eq!(typed.tenant, "t");
        assert_eq!(reg.snapshot("t").unwrap().version, 1);
        assert!(reg.register("u", spec, thetas_for(spec, 0.2)).is_err());
        assert_eq!(reg.len(), 1);
        assert!(reg.try_evict_tenant("t").is_err());
        assert_eq!(reg.snapshot("t").unwrap().version, 1);
        sink.fail.store(false, Ordering::Relaxed);
        assert_eq!(reg.register("t", spec, thetas_for(spec, 0.9)).unwrap(), 2);
    }

    #[test]
    fn restore_reinstalls_recorded_versions_without_emitting() {
        let spec = PauliSpec { q: 3, n_layers: 1 };
        let thetas = thetas_for(spec, 0.4);
        let ts = TenantState {
            tenant: "acme".into(),
            version: 7,
            q: 3,
            n_layers: 1,
            checksum: theta_checksum(&thetas),
            path: "/spool/acme.qpck".into(),
            thetas: thetas.clone(),
        };
        let sink = RecordingSink::new();
        let reg = Registry::new(1 << 20).with_state_sink(sink.clone());
        assert_eq!(reg.restore(&ts).unwrap(), 7);
        assert!(sink.records.lock().unwrap().is_empty(),
                "restore must not re-append");
        let snap = reg.snapshot("acme").unwrap();
        assert_eq!((snap.version, snap.checksum), (7, ts.checksum));
        assert_eq!(snap.origin, "/spool/acme.qpck");
        // the next real mutation continues from the recorded version
        assert_eq!(reg.register("acme", spec, thetas).unwrap(), 8);
        // a tampered recovered state (checksum mismatch) is refused
        let mut bad = ts.clone();
        bad.thetas[0] += 1.0;
        let e = reg.restore(&bad).unwrap_err().to_string();
        assert!(e.contains("checksum mismatch"), "{e}");
        // export round-trips the durable fields
        let exported = reg.export_state();
        assert_eq!(exported.len(), 1);
        assert_eq!(exported[0].version, 8);
        assert_eq!(exported[0].tenant, "acme");
    }
}
