//! Micro-batching request scheduler: coalesces same-tenant requests into
//! batches under a max-batch-size / max-wait policy, for dispatch onto
//! [`crate::util::pool`] service workers.
//!
//! A request's lifecycle: submit -> admission ([`super::admission`] —
//! a rejected request never reaches the batcher) -> [`PendingRequest`]
//! buffered in the [`Batcher`] -> grouped into a [`Batch`]
//! (tenant-homogeneous) -> popped by a worker -> response filled into
//! the request's [`ResponseSlot`]. The slot is a future-like completion
//! channel: the submitter holds a [`ResponseHandle`] and blocks in
//! [`ResponseHandle::wait`].
//!
//! No request is ever silently lost: if a `PendingRequest` is dropped
//! unserved (worker panic mid-batch, pool shut down, queue strand-drain)
//! its `Drop` impl fails the slot, so every `wait` call returns.
//!
//! Determinism: batch composition is a pure function of the submission
//! sequence (per-tenant buffers, flushed at `max_batch` or explicitly),
//! and the wall-clock `max_wait` path is only consulted when the caller
//! asks for expired batches — the `fifo` server mode never does, which
//! is what makes end-to-end runs byte-reproducible at any worker count.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::registry::RequestGuard;
use crate::obs::TraceCtx;
use crate::util::sync::{lock_or_recover, wait_or_recover};

/// Batching policy knobs: a batch dispatches when it holds `max_batch`
/// requests, or (timed mode) when its oldest request has waited
/// `max_wait_us` microseconds.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait_us: u64,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy { max_batch: 8, max_wait_us: 200 }
    }
}

impl BatchPolicy {
    /// Validated constructor: `max_batch == 0` is a typed
    /// [`InvalidBatchPolicy`] error, never a silent reinterpretation.
    pub fn new(max_batch: usize, max_wait_us: u64) -> Result<BatchPolicy> {
        let policy = BatchPolicy { max_batch, max_wait_us };
        policy.validate()?;
        Ok(policy)
    }

    /// Reject nonsense knob values with a typed error. [`serve`]
    /// (`crate::serve::serve`) calls this before a session starts, so a
    /// zero `max_batch` built via a struct literal fails fast there
    /// instead of being silently rewritten at push time (the old
    /// behavior).
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            return Err(InvalidBatchPolicy {
                detail: "max_batch must be >= 1 (a batch of 0 requests can \
                         never dispatch)".to_string(),
            }
            .into());
        }
        Ok(())
    }
}

/// Typed rejection of an unusable [`BatchPolicy`] — recoverable via
/// `err.downcast_ref::<InvalidBatchPolicy>()` like the other serving
/// errors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InvalidBatchPolicy {
    pub detail: String,
}

impl fmt::Display for InvalidBatchPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid batch policy: {}", self.detail)
    }
}

impl std::error::Error for InvalidBatchPolicy {}

/// One served response.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    /// Caller-chosen request identity (the loadgen packs client and
    /// request index here); response logs sort by it.
    pub meta: u64,
    pub tenant: String,
    /// Adapter version that served this request, with the checksum of
    /// the exact thetas behind it — a consistent pair by construction.
    pub version: u64,
    pub checksum: u64,
    pub output: Vec<f32>,
    pub latency_us: f64,
}

enum SlotState {
    Pending,
    Ready(Result<Response, String>),
    Taken,
}

/// Completion channel between a worker and the submitter.
pub struct ResponseSlot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl ResponseSlot {
    fn new() -> Arc<ResponseSlot> {
        Arc::new(ResponseSlot {
            state: Mutex::new(SlotState::Pending),
            cv: Condvar::new(),
        })
    }

    /// First fill wins; later fills (e.g. the drop-path error after a
    /// successful complete) are ignored.
    fn fill(&self, r: Result<Response, String>) {
        let mut st = lock_or_recover(&self.state);
        if matches!(*st, SlotState::Pending) {
            *st = SlotState::Ready(r);
            self.cv.notify_all();
        }
    }
}

/// Future-like handle to one submitted request.
pub struct ResponseHandle {
    slot: Arc<ResponseSlot>,
}

impl ResponseHandle {
    /// Block until the response (or the request's failure) arrives.
    pub fn wait(self) -> Result<Response> {
        let mut st = lock_or_recover(&self.slot.state);
        while matches!(*st, SlotState::Pending) {
            st = wait_or_recover(&self.slot.cv, st);
        }
        match std::mem::replace(&mut *st, SlotState::Taken) {
            SlotState::Ready(Ok(r)) => Ok(r),
            SlotState::Ready(Err(e)) => Err(anyhow!("{e}")),
            SlotState::Taken => Err(anyhow!("response already taken")),
            // the while loop above only exits on a non-Pending state
            SlotState::Pending => Err(anyhow!("response slot still pending")),
        }
    }
}

/// One admitted, not-yet-served request. Holds its tenant's
/// [`RequestGuard`] from admission to response, so the in-flight count
/// covers time spent buffered and queued, not just time on a worker.
pub struct PendingRequest {
    pub meta: u64,
    pub input: Vec<f32>,
    pub submitted: Instant,
    /// Per-request trace context (see [`crate::obs::span`]): the server
    /// fills it at submit with span-clock timestamps; defaults to an
    /// empty context for directly-constructed requests (tests).
    pub trace: TraceCtx,
    slot: Arc<ResponseSlot>,
    /// Held until this request drops: the tenant's in-flight pin covers
    /// buffering, queueing and service, releasing only after the slot
    /// has been filled.
    _guard: RequestGuard,
    completed: bool,
}

impl PendingRequest {
    pub fn new(meta: u64, input: Vec<f32>, guard: RequestGuard)
               -> (PendingRequest, ResponseHandle) {
        let slot = ResponseSlot::new();
        let req = PendingRequest {
            meta,
            input,
            // analyze: allow(determinism, obs-discipline) timed-mode expiry; latency is span-clock
            submitted: Instant::now(),
            trace: TraceCtx::default(),
            slot: slot.clone(),
            _guard: guard,
            completed: false,
        };
        (req, ResponseHandle { slot })
    }

    /// Deliver the response and consume the request.
    pub fn complete(mut self, r: Response) {
        self.completed = true;
        self.slot.fill(Ok(r));
    }

    /// Deliver a failure and consume the request.
    pub fn fail(mut self, msg: String) {
        self.completed = true;
        self.slot.fill(Err(msg));
    }
}

impl Drop for PendingRequest {
    fn drop(&mut self) {
        if !self.completed {
            self.slot.fill(Err(
                "request dropped unserved (server shut down or worker died)"
                    .to_string(),
            ));
        }
    }
}

/// A tenant-homogeneous batch ready for dispatch.
pub struct Batch {
    pub tenant: String,
    pub requests: Vec<PendingRequest>,
}

/// Per-tenant request coalescing. Not itself thread-safe — the server
/// wraps it in a mutex on the submission side; workers never touch it.
pub struct Batcher {
    policy: BatchPolicy,
    buffers: BTreeMap<String, Vec<PendingRequest>>,
}

impl Batcher {
    /// `policy` must already be validated ([`BatchPolicy::validate`] —
    /// `serve` does this before any batcher exists); a zero `max_batch`
    /// would make `push` buffer forever without ever forming a batch.
    pub fn new(policy: BatchPolicy) -> Batcher {
        debug_assert!(policy.validate().is_ok());
        Batcher { policy, buffers: BTreeMap::new() }
    }

    /// Buffer one request; returns a full batch if this push completed
    /// one.
    pub fn push(&mut self, tenant: &str, req: PendingRequest) -> Option<Batch> {
        // hot path: the common existing-key case must not allocate a
        // fresh String per request just to probe the map
        if !self.buffers.contains_key(tenant) {
            self.buffers.insert(tenant.to_string(), Vec::new());
        }
        // analyze: allow(panic-path) key inserted just above; entry() costs a String
        let buf = self.buffers.get_mut(tenant).expect("key just ensured");
        buf.push(req);
        if buf.len() >= self.policy.max_batch {
            let requests = std::mem::take(buf);
            Some(Batch { tenant: tenant.to_string(), requests })
        } else {
            None
        }
    }

    /// Flush every buffer whose oldest request has waited past
    /// `max_wait_us` (timed mode only; `fifo` mode never calls this).
    pub fn take_expired(&mut self, now: Instant) -> Vec<Batch> {
        let max_wait = Duration::from_micros(self.policy.max_wait_us);
        let expired: Vec<String> = self.buffers.iter()
            .filter(|(_, buf)| {
                buf.first().is_some_and(|r| {
                    now.saturating_duration_since(r.submitted) >= max_wait
                })
            })
            .map(|(t, _)| t.clone())
            .collect();
        expired.into_iter()
            .filter_map(|tenant| {
                let requests = std::mem::take(self.buffers.get_mut(&tenant)?);
                Some(Batch { tenant, requests })
            })
            .collect()
    }

    /// Flush everything, in tenant order (deterministic).
    pub fn drain(&mut self) -> Vec<Batch> {
        let buffers = std::mem::take(&mut self.buffers);
        buffers.into_iter()
            .filter(|(_, buf)| !buf.is_empty())
            .map(|(tenant, requests)| Batch { tenant, requests })
            .collect()
    }

    /// Buffered (not yet batched) request count. In fifo sessions this
    /// doubles as the admission queue-depth gauge: it moves only with
    /// the submission sequence, so a queue-cap decision made against it
    /// is deterministic at any worker count.
    pub fn pending(&self) -> usize {
        self.buffers.values().map(|b| b.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::registry::{PauliSpec, Registry};

    fn reg_with(tenants: &[&str]) -> Registry {
        let reg = Registry::new(1 << 20);
        let spec = PauliSpec { q: 2, n_layers: 0 };
        for t in tenants {
            reg.register(t, spec, vec![0.1; spec.num_params()]).unwrap();
        }
        reg
    }

    #[test]
    fn batcher_flushes_at_max_batch_in_push_order() {
        let reg = reg_with(&["a", "b"]);
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait_us: 0 });
        let mut handles = Vec::new();
        let mut full = Vec::new();
        for i in 0..7u64 {
            let tenant = if i % 2 == 0 { "a" } else { "b" };
            let (req, h) = PendingRequest::new(
                i, vec![0.0; 4], reg.begin(tenant).unwrap());
            handles.push(h);
            if let Some(batch) = b.push(tenant, req) {
                full.push(batch);
            }
        }
        // a got 0,2,4 (flush) then 6; b got 1,3,5 (flush)
        assert_eq!(full.len(), 2);
        assert_eq!(full[0].tenant, "a");
        assert_eq!(full[0].requests.iter().map(|r| r.meta).collect::<Vec<_>>(),
                   vec![0, 2, 4]);
        assert_eq!(full[1].tenant, "b");
        assert_eq!(full[1].requests.iter().map(|r| r.meta).collect::<Vec<_>>(),
                   vec![1, 3, 5]);
        assert_eq!(b.pending(), 1);
        let rest = b.drain();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].requests[0].meta, 6);
        assert_eq!(b.pending(), 0);
        // in-flight pins survive batching and release on request drop
        assert_eq!(reg.inflight("a"), 4);
        drop(full);
        drop(rest);
        assert_eq!(reg.inflight("a"), 0);
    }

    #[test]
    fn dropped_request_fails_its_handle() {
        let reg = reg_with(&["a"]);
        let (req, h) = PendingRequest::new(9, vec![0.0; 4],
                                           reg.begin("a").unwrap());
        drop(req);
        let e = h.wait().unwrap_err().to_string();
        assert!(e.contains("dropped unserved"), "{e}");
        assert_eq!(reg.inflight("a"), 0);
    }

    #[test]
    fn completed_request_delivers_response() {
        let reg = reg_with(&["a"]);
        let (req, h) = PendingRequest::new(5, vec![1.0; 4],
                                           reg.begin("a").unwrap());
        let resp = Response {
            meta: 5,
            tenant: "a".into(),
            version: 1,
            checksum: 42,
            output: vec![2.0; 4],
            latency_us: 10.0,
        };
        req.complete(resp.clone());
        assert_eq!(h.wait().unwrap(), resp);
    }

    #[test]
    fn zero_max_batch_is_a_typed_construction_error() {
        let e = BatchPolicy::new(0, 100).unwrap_err();
        let typed = e.downcast_ref::<InvalidBatchPolicy>()
            .expect("typed InvalidBatchPolicy lost");
        assert!(typed.detail.contains("max_batch"), "{typed:?}");
        assert!(e.to_string().contains("invalid batch policy"), "{e}");
        // the same check guards a struct-literal policy via validate()
        let lit = BatchPolicy { max_batch: 0, max_wait_us: 100 };
        assert!(lit.validate().is_err());
        assert!(BatchPolicy::new(1, 0).is_ok());
        assert!(BatchPolicy::default().validate().is_ok());
    }

    #[test]
    fn take_expired_is_per_tenant_and_tenant_ordered() {
        let reg = reg_with(&["a", "b", "c"]);
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait_us: 50 });
        let base = Instant::now();
        let mut handles = Vec::new();
        // push in non-alphabetical order; "b"'s requests are 100µs
        // younger than "a"'s and "c"'s
        for (tenant, meta, age_us) in
            [("c", 0u64, 0u64), ("a", 1, 0), ("b", 2, 100), ("c", 3, 0)]
        {
            let (mut req, h) = PendingRequest::new(
                meta, vec![0.0; 4], reg.begin(tenant).unwrap());
            req.submitted = base + Duration::from_micros(age_us);
            handles.push(h);
            assert!(b.push(tenant, req).is_none());
        }
        // at base+60µs only "a" and "c" have outwaited the 50µs policy;
        // expiry scans the BTreeMap, so batches come out in tenant order
        // regardless of push order — the contract shard-local batchers
        // inherit
        let batches = b.take_expired(base + Duration::from_micros(60));
        let tenants: Vec<&str> =
            batches.iter().map(|x| x.tenant.as_str()).collect();
        assert_eq!(tenants, vec!["a", "c"]);
        // within a tenant, requests keep their push order
        assert_eq!(batches[1].requests.iter().map(|r| r.meta).collect::<Vec<_>>(),
                   vec![0, 3]);
        assert_eq!(b.pending(), 1);
        // "b" expires once its own oldest request has waited long enough
        let late = b.take_expired(base + Duration::from_micros(200));
        assert_eq!(late.len(), 1);
        assert_eq!(late[0].tenant, "b");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn take_expired_respects_max_wait() {
        let reg = reg_with(&["a"]);
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait_us: 50 });
        let (req, _h) = PendingRequest::new(0, vec![0.0; 4],
                                            reg.begin("a").unwrap());
        let t0 = req.submitted;
        assert!(b.push("a", req).is_none());
        assert!(b.take_expired(t0).is_empty());
        let later = t0 + Duration::from_micros(60);
        let batches = b.take_expired(later);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 1);
    }
}
