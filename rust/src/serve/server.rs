//! In-process serving loop: submit -> future-like handle -> response.
//!
//! A serve session is scoped ([`serve`] wraps [`pool::run_service`]):
//! `workers` service threads each hold a [`Runtime::for_worker`] handle
//! (so any artifact compile goes through the process-wide
//! `runtime::exe_cache` exactly once) plus a worker-tagged [`EventLog`];
//! the caller's `body` closure drives traffic through a [`ServerHandle`].
//! When `body` returns, partial batches flush, the queue closes, workers
//! drain it, and the session's [`ServeSummary`] is computed and emitted.
//!
//! Two modes:
//! - **fifo** (deterministic, for tests): batches form purely from the
//!   submission sequence (`max_batch` or an explicit flush); no wall
//!   clock is consulted, so a seeded driver produces a byte-identical
//!   response log at any worker count;
//! - **timed**: submissions also flush any buffer whose oldest request
//!   has waited past `max_wait_us`, trading determinism for bounded
//!   batching delay.
//!
//! `submit` runs [`super::admission`] before anything is enqueued:
//! per-tenant token buckets and a global queue-depth cap reject overload
//! with a typed error instead of letting the queue grow without bound.
//! In fifo mode the buckets run on a logical clock and the cap reads the
//! buffered backlog, so rejections are part of the same byte-identity
//! guarantee; in timed mode both run on real time and real queue depth.
//!
//! Per batch, workers route through one of two apply paths: small
//! adapters multiply against the LRU-cached dense `Q_P`; adapters with
//! `q >= STRUCTURED_APPLY_MIN_Q` apply the Pauli gate structure directly
//! (O(N·q·L) per row instead of O(N²), and no dense materialization at
//! all — a q = 12 tenant never forces a 64 MiB cache entry).
//!
//! # Observability
//!
//! Every request carries a [`TraceCtx`] from submit to response; per-
//! phase durations are measured against the session's [`SpanClock`]
//! (logical in fifo mode, wall in timed mode — the only wall-clock
//! source on the serving path, enforced by the `obs-discipline` lint).
//! Latencies land in lock-free log₂-bucket [`Hist`]ograms (global and
//! per tenant, O(buckets) memory each), per-tenant SLO violations are
//! counted exactly at record time ([`SloPolicy`]), and each worker
//! keeps a fixed-capacity [`FlightRecorder`] ring of its last completed
//! spans, dumped as `serve_trace` lines at session end. With
//! `metrics_interval > 0`, live `serve_interval` snapshots are emitted
//! mid-session: driver-ticked every N completed requests in fifo mode,
//! every N milliseconds from the flusher thread in timed mode. See
//! [`crate::serve`] for the emitted line schemas.

use std::collections::BTreeMap;
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::events::EventLog;
use crate::obs::metrics::{Class, Counter, MetricsRegistry};
use crate::obs::span::{
    PH_ADMISSION, PH_APPLY, PH_CACHE_LOOKUP, PH_COALESCE, PH_MATERIALIZE,
    PH_QUEUE, PH_RESPOND,
};
use crate::obs::{
    FlightRecorder, Hist, SloPolicy, Span, SpanClock, TenantSloStatus,
    TraceCtx, TraceRecord, PHASES,
};
use crate::quantum::pauli;
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::pool::{self, Service, TaskCtx};
use crate::util::sync::{
    lock_observed, lock_or_recover, read_or_recover, write_or_recover, LockObs,
};

use super::admission::{
    AdmissionConfig, AdmissionController, AdmissionReload,
    AdmissionReloadSpec, AdmissionStats,
};
use super::registry::{CacheStats, Registry};
use super::scheduler::{
    Batch, Batcher, BatchPolicy, PendingRequest, Response, ResponseHandle,
};

/// Adapters with `q >= STRUCTURED_APPLY_MIN_Q` are served through the
/// structured [`pauli::PauliCircuit::apply`] path — O(N·q·L) per row —
/// instead of materializing and multiplying the dense N x N `Q_P`
/// (O(N²) per row, and a 64 MiB LRU entry at q = 12). Below the
/// threshold the cached dense matrix wins: the whole Q_P fits in L1/L2
/// and one row-multiply beats re-walking the gate sequence.
pub const STRUCTURED_APPLY_MIN_Q: u32 = 6;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub workers: usize,
    pub policy: BatchPolicy,
    /// Deterministic mode: never consult the wall clock for batching.
    pub fifo: bool,
    /// Admission control (rate limits + queue cap); default admits all.
    pub admission: AdmissionConfig,
    /// Hot-reload source for `admission`: a config file watched with a
    /// spool-style stability window for the whole session
    /// (`--admission-config`); limit changes apply live without
    /// dropping in-flight requests. `None` (default) keeps the static
    /// policy — and full fifo determinism.
    pub admission_reload: Option<AdmissionReloadSpec>,
    /// Live snapshot cadence for `serve_interval` lines; 0 = off. The
    /// unit differs by mode: fifo counts **completed requests** (the
    /// driver's `tick` claims due marks, so snapshots are part of the
    /// byte-identity guarantee), timed counts **milliseconds** of
    /// span-clock time (emitted from the flusher thread).
    pub metrics_interval: u64,
    /// Per-request latency SLO target in µs; 0 = SLO tracking off.
    pub slo_p99_us: f64,
    /// Allowed violating fraction of each tenant's requests (0.01 = 1%).
    pub slo_error_budget: f64,
    /// When set, the session-end flight-recorder dump also writes a
    /// JSONL file (`trace-<pid>-<seq>.jsonl`) under this directory.
    pub trace_dir: Option<PathBuf>,
    /// Per-worker flight-recorder capacity: each worker retains its last
    /// `recorder_cap` completed spans. The merged fifo dump is only
    /// byte-identical across worker counts while nothing has aged out
    /// (cap ≥ total requests).
    pub recorder_cap: usize,
    /// The process-wide metrics registry this session registers its
    /// `serve_*` handles on. `None` (default) gives the session a
    /// private registry matching its fifo mode — nothing changes unless
    /// the caller wires one in (the sharded tier hands every shard the
    /// same `Arc`, so shard counters sum into fleet totals).
    pub metrics: Option<Arc<MetricsRegistry>>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 1,
            policy: BatchPolicy::default(),
            fifo: true,
            admission: AdmissionConfig::default(),
            admission_reload: None,
            metrics_interval: 0,
            slo_p99_us: 0.0,
            slo_error_budget: 0.01,
            trace_dir: None,
            recorder_cap: 256,
            metrics: None,
        }
    }
}

impl ServeConfig {
    /// Fail fast on nonsense observability knobs — one typed
    /// [`InvalidObsKnob`] validation shared by [`serve`] and every CLI
    /// entry point, so a bad `--slo-error-budget` or `--recorder-cap`
    /// dies identically everywhere instead of half the paths silently
    /// clamping it.
    pub fn validate_obs(&self) -> Result<()> {
        if self.slo_p99_us < 0.0 {
            return Err(InvalidObsKnob {
                knob: "slo_p99_us",
                value: self.slo_p99_us,
                detail: "an SLO latency target cannot be negative \
                         (use 0 to disable SLO tracking)",
            }
            .into());
        }
        if self.slo_p99_us > 0.0 && self.slo_error_budget <= 0.0 {
            return Err(InvalidObsKnob {
                knob: "slo_error_budget",
                value: self.slo_error_budget,
                detail: "must be > 0 when an SLO target is set",
            }
            .into());
        }
        if self.recorder_cap == 0 {
            return Err(InvalidObsKnob {
                knob: "recorder_cap",
                value: 0.0,
                detail: "each worker must retain at least one trace span",
            }
            .into());
        }
        Ok(())
    }
}

/// Typed rejection of a zero/nonsense observability knob, caught by
/// [`ServeConfig::validate_obs`] before any thread starts. Carried as
/// an `anyhow` payload so callers can `downcast_ref` it apart from
/// other startup failures — the same recoverable-typed-error pattern as
/// [`super::scheduler::InvalidBatchPolicy`] and
/// [`crate::store::CorruptState`].
#[derive(Clone, Debug, PartialEq)]
pub struct InvalidObsKnob {
    /// The offending field, in config-struct spelling (the CLI flag is
    /// the kebab-case form, e.g. `--slo-error-budget`).
    pub knob: &'static str,
    pub value: f64,
    pub detail: &'static str,
}

impl fmt::Display for InvalidObsKnob {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "invalid observability knob {} = {}: {}",
            self.knob, self.value, self.detail
        )
    }
}

impl std::error::Error for InvalidObsKnob {}

// --------------------------------------------------------------- metrics ---

/// One tenant's live telemetry: a latency histogram plus SLO counters.
/// All atomics — recording never takes a lock (see [`Metrics`]).
#[derive(Debug, Default)]
struct TenantObs {
    hist: Hist,
    requests: AtomicU64,
    slo_violations: AtomicU64,
}

/// The session's handles on the process-wide [`MetricsRegistry`]: the
/// request ledger (`serve_requests_*_total`), the latency histogram
/// (`serve_latency_ns`) and the batch-size histogram
/// (`serve_batch_size`) — all [`Class::Stable`]: in fifo mode they are
/// pure functions of the seeded stream. These *mirror* the session-
/// private fields in [`Metrics`] rather than replacing them: shards
/// handed the same registry `Arc` share these handles, so the exported
/// values are fleet totals while each shard's `serve_summary` line
/// keeps reporting its own session exactly as before.
struct ServeObs {
    submitted: Arc<Counter>,
    completed: Arc<Counter>,
    failed: Arc<Counter>,
    latency_ns: Arc<Hist>,
    batch_size: Arc<Hist>,
}

impl ServeObs {
    fn register(reg: &MetricsRegistry) -> ServeObs {
        ServeObs {
            submitted: reg.counter("serve_requests_submitted_total", &[],
                                   Class::Stable),
            completed: reg.counter("serve_requests_completed_total", &[],
                                   Class::Stable),
            failed: reg.counter("serve_requests_failed_total", &[],
                                Class::Stable),
            latency_ns: reg.hist("serve_latency_ns", &[], Class::Stable),
            batch_size: reg.hist("serve_batch_size", &[], Class::Stable),
        }
    }
}

struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Outstanding requests (submitted, not yet responded) — the queue
    /// depth gauge; covers batcher buffers, the service queue, and
    /// requests on a worker.
    outstanding: AtomicUsize,
    max_outstanding: AtomicUsize,
    shared_client_workers: AtomicUsize,
    /// Session-wide latency histogram: one relaxed `fetch_add` per
    /// request, shared by all workers. O(buckets) memory for the whole
    /// session — quantiles are readable mid-run (the `serve_interval`
    /// snapshots) without sorting anything.
    lat_hist: Hist,
    /// Registry mirrors of the ledger above (see [`ServeObs`]).
    obs: ServeObs,
    /// Per-tenant telemetry. The RwLock only guards the map shape:
    /// recording goes through the `Arc<TenantObs>` atomics, so the
    /// write lock is taken once per tenant per session (first request).
    /// O(tenants · buckets) memory, replacing the per-tenant `Vec<u64>`
    /// that grew with every request.
    tenants: RwLock<BTreeMap<String, Arc<TenantObs>>>,
    batch_sizes: Mutex<BTreeMap<usize, u64>>,
    /// One flight recorder per worker (indexed by worker id), so pushes
    /// never contend across workers.
    recorders: Vec<Mutex<FlightRecorder>>,
    slo: SloPolicy,
    /// Next completed-count mark at which `tick` emits a
    /// `serve_interval` snapshot (fifo mode; claimed by CAS).
    next_mark: AtomicU64,
    interval_seq: AtomicU64,
    /// `--trace-dir` JSONL dump failures this session. The first one
    /// also emits a `serve_trace_error` EventLog line; the rest only
    /// count (a full disk would otherwise spam one line per dump).
    trace_errors: AtomicU64,
}

impl Metrics {
    fn new(cfg: &ServeConfig, reg: &MetricsRegistry) -> Metrics {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            outstanding: AtomicUsize::new(0),
            max_outstanding: AtomicUsize::new(0),
            shared_client_workers: AtomicUsize::new(0),
            lat_hist: Hist::new(),
            obs: ServeObs::register(reg),
            tenants: RwLock::new(BTreeMap::new()),
            batch_sizes: Mutex::new(BTreeMap::new()),
            recorders: (0..cfg.workers.max(1))
                .map(|_| Mutex::new(FlightRecorder::new(cfg.recorder_cap)))
                .collect(),
            slo: SloPolicy {
                p99_target_us: cfg.slo_p99_us,
                error_budget: cfg.slo_error_budget,
            },
            next_mark: AtomicU64::new(cfg.metrics_interval.max(1)),
            interval_seq: AtomicU64::new(0),
            trace_errors: AtomicU64::new(0),
        }
    }

    fn note_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.obs.submitted.inc();
        let depth = self.outstanding.fetch_add(1, Ordering::Relaxed) + 1;
        self.max_outstanding.fetch_max(depth, Ordering::Relaxed);
    }

    fn note_batch(&self, size: usize) {
        *lock_or_recover(&self.batch_sizes).entry(size).or_insert(0) += 1;
        self.obs.batch_size.record(size as u64);
    }

    /// The tenant's telemetry cell, created on first use. Fast path is
    /// a read lock + Arc clone; the write lock is taken only for a
    /// tenant's first-ever batch.
    fn tenant_obs(&self, tenant: &str) -> Arc<TenantObs> {
        if let Some(t) = read_or_recover(&self.tenants).get(tenant) {
            return t.clone();
        }
        write_or_recover(&self.tenants)
            .entry(tenant.to_string())
            .or_default()
            .clone()
    }

    /// Per-request completion accounting: atomics only (counter bumps
    /// and histogram increments), never a lock.
    fn note_complete(&self, t: &TenantObs, latency_ns: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.obs.completed.inc();
        self.outstanding.fetch_sub(1, Ordering::Relaxed);
        self.lat_hist.record(latency_ns);
        self.obs.latency_ns.record(latency_ns);
        t.hist.record(latency_ns);
        t.requests.fetch_add(1, Ordering::Relaxed);
        // SLO violations are judged against the exact latency here, not
        // reconstructed from buckets — quantization can't hide a breach
        if self.slo.violated(latency_ns) {
            t.slo_violations.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn note_failed(&self, n: usize) {
        self.failed.fetch_add(n as u64, Ordering::Relaxed);
        self.obs.failed.add(n as u64);
        self.outstanding.fetch_sub(n, Ordering::Relaxed);
    }

    fn record_trace(&self, worker: usize, rec: TraceRecord) {
        if let Some(r) = self.recorders.get(worker) {
            lock_or_recover(r).push(rec);
        }
    }

    fn summarize(&self, workers: usize, wall_s: f64, cache: CacheStats,
                 admission: AdmissionStats) -> ServeSummary {
        let completed = self.completed.load(Ordering::Relaxed);
        let tenants_map = read_or_recover(&self.tenants);
        let tenants = tenants_map.iter()
            .map(|(name, t)| TenantSummary {
                tenant: name.clone(),
                requests: t.requests.load(Ordering::Relaxed),
                p50_us: t.hist.quantile_us(50.0).ok(),
                p95_us: t.hist.quantile_us(95.0).ok(),
                p99_us: t.hist.quantile_us(99.0).ok(),
            })
            .collect();
        let slo = if self.slo.enabled() {
            Some(SloSummary {
                p99_target_us: self.slo.p99_target_us,
                error_budget: self.slo.error_budget,
                per_tenant: tenants_map.iter()
                    .map(|(name, t)| TenantSloStatus {
                        tenant: name.clone(),
                        requests: t.requests.load(Ordering::Relaxed),
                        violations: t.slo_violations.load(Ordering::Relaxed),
                    })
                    .collect(),
            })
        } else {
            None
        };
        drop(tenants_map);
        ServeSummary {
            workers,
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            wall_s,
            rps: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
            p50_us: self.lat_hist.quantile_us(50.0).ok(),
            p95_us: self.lat_hist.quantile_us(95.0).ok(),
            p99_us: self.lat_hist.quantile_us(99.0).ok(),
            max_queue_depth: self.max_outstanding.load(Ordering::Relaxed),
            shared_client_workers: self.shared_client_workers.load(Ordering::Relaxed),
            batch_hist: lock_or_recover(&self.batch_sizes).iter()
                .map(|(&s, &c)| (s, c)).collect(),
            cache,
            admission,
            tenants,
            slo,
            trace_errors: self.trace_errors.load(Ordering::Relaxed),
        }
    }
}

/// Nearest-rank percentile over a sorted nanosecond vector, in µs: the
/// value at the smallest rank whose cumulative share reaches `p`%
/// (`idx = ceil(p/100 · len) - 1`), so the result is always an observed
/// sample. len = 1 returns that sample at every p; len = 2 returns the
/// lower sample up to p50 and the upper one after.
///
/// The live metrics path reports quantiles from the log₂-bucket
/// [`Hist`] instead (O(buckets) memory); this exact-but-O(n) form stays
/// as the test oracle the histogram tolerance is pinned against.
pub fn percentile_us(sorted_ns: &[u64], p: f64) -> f64 {
    if sorted_ns.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted_ns.len() as f64).ceil() as usize;
    let idx = rank.clamp(1, sorted_ns.len()) - 1;
    sorted_ns[idx] as f64 / 1_000.0
}

#[derive(Clone, Debug)]
pub struct TenantSummary {
    pub tenant: String,
    pub requests: u64,
    /// `None` when the tenant completed no requests
    /// ([`EmptyHist`](crate::obs::EmptyHist) upstream) — rendered as
    /// `-`, never as a fake 0µs.
    pub p50_us: Option<f64>,
    pub p95_us: Option<f64>,
    pub p99_us: Option<f64>,
}

/// Session SLO accounting: the policy plus each tenant's violation
/// counts (only present when `--slo-p99-us` enabled tracking).
#[derive(Clone, Debug)]
pub struct SloSummary {
    pub p99_target_us: f64,
    pub error_budget: f64,
    pub per_tenant: Vec<TenantSloStatus>,
}

impl SloSummary {
    /// Tenants whose violations exceed their error-budget allowance.
    pub fn breached(&self) -> usize {
        self.per_tenant.iter()
            .filter(|t| !t.compliant(self.error_budget))
            .count()
    }
}

/// End-of-session metrics: global and per-tenant latency percentiles,
/// throughput, queue depth, batch-size histogram, cache counters.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub workers: usize,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub wall_s: f64,
    pub rps: f64,
    /// `None` when the session completed no requests
    /// ([`EmptyHist`](crate::obs::EmptyHist) upstream): JSON `null`,
    /// `-` in the rendered report.
    pub p50_us: Option<f64>,
    pub p95_us: Option<f64>,
    pub p99_us: Option<f64>,
    pub max_queue_depth: usize,
    pub shared_client_workers: usize,
    /// (batch size, batches dispatched at that size), ascending.
    pub batch_hist: Vec<(usize, u64)>,
    pub cache: CacheStats,
    /// Admission counters (admitted / rejected per reason, per tenant).
    pub admission: AdmissionStats,
    pub tenants: Vec<TenantSummary>,
    /// SLO compliance (None unless SLO tracking was enabled).
    pub slo: Option<SloSummary>,
    /// `--trace-dir` JSONL dumps that failed to write this session
    /// (0 when tracing to files was off or every dump landed).
    pub trace_errors: u64,
}

/// `Json::Null` for an absent (empty-histogram) percentile.
pub(crate) fn q_json(v: Option<f64>) -> Json {
    v.map_or(Json::Null, Json::Num)
}

/// `-` for an absent percentile in a rendered report, `{v:.1}µs` text
/// otherwise.
pub(crate) fn q_us(v: Option<f64>) -> String {
    v.map_or_else(|| "-".to_string(), |v| format!("{v:.1}µs"))
}

impl ServeSummary {
    /// Export through the event log: one `serve_summary` line (schema
    /// version 2: histogram-backed percentiles plus the `schema` field),
    /// one `serve_tenant` line per tenant, admission lines when the
    /// controller is enabled, and one `serve_slo` line per tenant when
    /// SLO tracking is on.
    pub fn emit(&self, log: &EventLog) {
        let hist = Json::Arr(self.batch_hist.iter()
            .map(|&(s, c)| Json::Arr(vec![s.into(), Json::Num(c as f64)]))
            .collect());
        log.emit("serve_summary", vec![
            ("schema", Json::Num(2.0)),
            ("workers", self.workers.into()),
            ("submitted", Json::Num(self.submitted as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("rps", Json::Num(self.rps)),
            ("p50_us", q_json(self.p50_us)),
            ("p95_us", q_json(self.p95_us)),
            ("p99_us", q_json(self.p99_us)),
            ("max_queue_depth", self.max_queue_depth.into()),
            ("shared_client_workers", self.shared_client_workers.into()),
            ("batch_hist", hist),
            ("cache_hits", Json::Num(self.cache.hits as f64)),
            ("cache_misses", Json::Num(self.cache.misses as f64)),
            ("cache_evictions", Json::Num(self.cache.evictions as f64)),
            ("cache_quota_rejections",
             Json::Num(self.cache.quota_rejections as f64)),
            ("cache_bytes", self.cache.bytes.into()),
            ("cache_capacity_bytes", self.cache.capacity_bytes.into()),
            ("cache_tenant_quota_bytes",
             self.cache.per_tenant_quota_bytes.into()),
            ("trace_errors", Json::Num(self.trace_errors as f64)),
        ]);
        for t in &self.tenants {
            log.emit("serve_tenant", vec![
                ("tenant", t.tenant.as_str().into()),
                ("requests", Json::Num(t.requests as f64)),
                ("p50_us", q_json(t.p50_us)),
                ("p95_us", q_json(t.p95_us)),
                ("p99_us", q_json(t.p99_us)),
            ]);
        }
        if self.admission.enabled {
            let a = &self.admission;
            log.emit("serve_admission", vec![
                ("rate_rps", Json::Num(a.rate_rps)),
                ("max_queue", a.max_queue.into()),
                ("admitted", Json::Num(a.admitted as f64)),
                ("rejected_rate_limited", Json::Num(a.rejected_rate_limited as f64)),
                ("rejected_queue_full", Json::Num(a.rejected_queue_full as f64)),
                ("rejected_total", Json::Num(a.rejected_total() as f64)),
                ("reloads", Json::Num(a.reloads as f64)),
            ]);
            for t in &a.per_tenant {
                log.emit("serve_admission_tenant", vec![
                    ("tenant", t.tenant.as_str().into()),
                    ("admitted", Json::Num(t.admitted as f64)),
                    ("rejected_rate_limited",
                     Json::Num(t.rejected_rate_limited as f64)),
                    ("rejected_queue_full", Json::Num(t.rejected_queue_full as f64)),
                ]);
            }
        }
        if let Some(slo) = &self.slo {
            for t in &slo.per_tenant {
                log.emit("serve_slo", vec![
                    ("tenant", t.tenant.as_str().into()),
                    ("p99_target_us", Json::Num(slo.p99_target_us)),
                    ("error_budget", Json::Num(slo.error_budget)),
                    ("requests", Json::Num(t.requests as f64)),
                    ("violations", Json::Num(t.violations as f64)),
                    ("burn", Json::Num(t.burn(slo.error_budget))),
                    ("compliant", Json::Bool(t.compliant(slo.error_budget))),
                ]);
            }
        }
    }

    /// Human-readable one-screen report for the CLI.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "served {} requests in {:.3}s with {} worker(s): {:.0} req/s \
             ({} failed)",
            self.completed, self.wall_s, self.workers, self.rps, self.failed);
        let _ = writeln!(
            s,
            "latency p50 {}  p95 {}  p99 {}  max queue depth {}",
            q_us(self.p50_us), q_us(self.p95_us), q_us(self.p99_us),
            self.max_queue_depth);
        let hist: Vec<String> = self.batch_hist.iter()
            .map(|&(sz, c)| format!("{sz}x{c}"))
            .collect();
        let _ = writeln!(s, "batch sizes [{}]", hist.join(" "));
        let _ = writeln!(
            s,
            "mat cache: {} hits / {} misses / {} evictions, {} / {} bytes \
             ({} entries)",
            self.cache.hits, self.cache.misses, self.cache.evictions,
            self.cache.bytes, self.cache.capacity_bytes, self.cache.entries);
        // the quota counters print unconditionally, matching the JSON
        // summary (which always carries cache_quota_rejections)
        let quota = if self.cache.per_tenant_quota_bytes > 0 {
            format!("{} bytes each", self.cache.per_tenant_quota_bytes)
        } else {
            "unlimited".to_string()
        };
        let _ = writeln!(
            s,
            "tenant quota: {quota}, {} quota rejection(s)",
            self.cache.quota_rejections);
        if self.trace_errors > 0 {
            let _ = writeln!(
                s,
                "WARNING: {} trace dump(s) failed to write (see the \
                 serve_trace_error event line)",
                self.trace_errors);
        }
        if self.admission.enabled {
            let a = &self.admission;
            let attempts = a.admitted + a.rejected_total();
            let shed = if attempts > 0 {
                100.0 * a.rejected_total() as f64 / attempts as f64
            } else {
                0.0
            };
            let _ = writeln!(
                s,
                "admission: {} admitted / {} rejected ({} rate-limited, \
                 {} queue-full) — {shed:.1}% shed",
                a.admitted, a.rejected_total(), a.rejected_rate_limited,
                a.rejected_queue_full);
            for t in &a.per_tenant {
                let _ = writeln!(
                    s,
                    "  {}: {} admitted, {} rate-limited, {} queue-full",
                    t.tenant, t.admitted, t.rejected_rate_limited,
                    t.rejected_queue_full);
            }
        }
        if let Some(slo) = &self.slo {
            let _ = writeln!(
                s,
                "slo: p99 target {:.1}µs, error budget {:.3} per tenant",
                slo.p99_target_us, slo.error_budget);
            for t in &slo.per_tenant {
                let ok = t.compliant(slo.error_budget);
                let _ = writeln!(
                    s,
                    "  {}: {}/{} over target, burn {:.2} [{}]",
                    t.tenant, t.violations, t.requests,
                    t.burn(slo.error_budget),
                    if ok { "ok" } else { "BREACHED" });
            }
            let n = slo.per_tenant.len();
            let _ = writeln!(
                s,
                "slo compliance: {}/{n} tenant(s) within budget",
                n - slo.breached());
        }
        s
    }
}

// ---------------------------------------------------------------- server ---

/// The submission surface a load driver needs, abstracted over *what*
/// serves: a single scoped session ([`ServerHandle`]) or the sharded
/// tier ([`super::shard::ShardRouter`]). The loadgen drivers are generic
/// over this, so the fifo determinism oracle runs unchanged against
/// either backend.
pub trait SubmitTarget {
    /// Admit one request (typed [`super::admission::Rejected`] on shed).
    fn submit(&self, tenant: &str, meta: u64, input: Vec<f32>)
              -> Result<ResponseHandle>;
    /// Dispatch all partial batches now.
    fn flush(&self);
    /// Advance the logical admission + span clocks (fifo mode).
    fn advance_clock(&self, dt_s: f64);
    /// Whether batching runs in deterministic fifo mode.
    fn is_fifo(&self) -> bool;
    /// Give the target a chance to emit due `serve_interval` snapshots
    /// (fifo mode; drivers call this at wave/collection boundaries,
    /// where completion counts are deterministic). Default: no-op.
    fn tick(&self) {}
}

impl SubmitTarget for ServerHandle<'_> {
    fn submit(&self, tenant: &str, meta: u64, input: Vec<f32>)
              -> Result<ResponseHandle> {
        ServerHandle::submit(self, tenant, meta, input)
    }

    fn flush(&self) {
        ServerHandle::flush(self)
    }

    fn advance_clock(&self, dt_s: f64) {
        ServerHandle::advance_clock(self, dt_s)
    }

    fn is_fifo(&self) -> bool {
        ServerHandle::is_fifo(self)
    }

    fn tick(&self) {
        ServerHandle::tick(self)
    }
}

/// What `body` gets: the submission side of a live serve session.
pub struct ServerHandle<'a> {
    registry: &'a Registry,
    service: &'a Service<Batch>,
    metrics: &'a Metrics,
    admission: &'a AdmissionController,
    batcher: Mutex<Batcher>,
    /// Contention handles for the batcher mutex (`site=serve_batcher`).
    batcher_obs: LockObs,
    fifo: bool,
    clock: &'a SpanClock,
    log: &'a EventLog,
    metrics_interval: u64,
}

impl ServerHandle<'_> {
    /// Admit one request. Validates tenant and input dimension up front,
    /// then runs admission control — a rejected request fails fast with
    /// the typed [`super::admission::Rejected`] error and is **never**
    /// enqueued. The returned handle resolves when a worker serves the
    /// batch this request lands in.
    pub fn submit(&self, tenant: &str, meta: u64, input: Vec<f32>)
                  -> Result<ResponseHandle> {
        let snap = self.registry.snapshot(tenant)?;
        if input.len() != snap.spec.dim() {
            bail!("tenant {tenant:?}: input has {} elements, adapter dim is {}",
                  input.len(), snap.spec.dim());
        }
        // the trace context is born here: id from (tenant, meta) — a
        // pure function of the seeded stream — and timestamps from the
        // session span clock (logical in fifo mode)
        let mut trace = TraceCtx::new(tenant, meta, self.clock.now_ns());
        let guard = {
            let _sp = Span::enter(self.clock, &mut trace.phase_ns[PH_ADMISSION]);
            // pin the tenant BEFORE consuming an admission token: begin()
            // can still fail (tenant evicted between snapshot and here,
            // e.g. by the spool watcher), and failing after try_admit
            // would leak an admitted++ / a rate token for a request that
            // never existed, breaking the admitted == completed + failed
            // ledger. A rejected request drops the guard immediately, so
            // the transient pin cannot block eviction.
            let guard = self.registry.begin(tenant)?;
            // queue-depth gauge for the cap: fifo mode reads the buffered
            // backlog (driven only by the submission sequence, so
            // admission stays byte-deterministic at any worker count);
            // timed mode reads real outstanding requests for true
            // backpressure. Skipped entirely when admission is off — no
            // extra batcher lock on the hot path.
            let depth = if !self.admission.enabled() {
                0
            } else if self.fifo {
                lock_observed(&self.batcher_obs, &self.batcher).pending()
            } else {
                self.metrics.outstanding.load(Ordering::Relaxed)
            };
            self.admission.try_admit(tenant, depth)?;
            guard
        };
        let (mut req, handle) = PendingRequest::new(meta, input, guard);
        req.trace = trace;
        self.metrics.note_submit();
        let full = lock_observed(&self.batcher_obs, &self.batcher)
            .push(tenant, req);
        if let Some(batch) = full {
            self.dispatch(batch);
        }
        if !self.fifo {
            self.flush_expired();
        }
        Ok(handle)
    }

    /// Advance the logical clocks (fifo mode): the open-loop loadgen
    /// declares its seeded interarrival gaps here instead of sleeping,
    /// which is what keeps rate-limited overload runs deterministic.
    /// Moves both the admission token-bucket clock and the span clock
    /// (so fifo latencies and trace timestamps are logical too). No-op
    /// in timed mode.
    pub fn advance_clock(&self, dt_s: f64) {
        self.admission.advance(dt_s);
        self.clock.advance_ns((dt_s.max(0.0) * 1e9) as u64);
    }

    /// Whether this session batches in deterministic fifo mode.
    pub fn is_fifo(&self) -> bool {
        self.fifo
    }

    /// Emit any due `serve_interval` snapshots (fifo mode). The interval
    /// unit is completed requests; each mark is claimed with a CAS so
    /// exactly one caller emits each snapshot, and drivers call this at
    /// wave boundaries where completion counts are deterministic — the
    /// snapshot lines join the fifo byte-identity guarantee. Timed
    /// sessions emit on a millisecond cadence from the flusher thread
    /// instead, so this is a no-op there.
    pub fn tick(&self) {
        if self.metrics_interval == 0 || !self.fifo {
            return;
        }
        loop {
            let completed = self.metrics.completed.load(Ordering::Relaxed);
            let mark = self.metrics.next_mark.load(Ordering::Relaxed);
            if completed < mark {
                return;
            }
            if self.metrics.next_mark
                .compare_exchange(mark, mark + self.metrics_interval,
                                  Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                self.emit_interval();
            }
        }
    }

    /// One live `serve_interval` snapshot: counters, histogram
    /// quantiles, queue depth, cache hit rate, per-tenant rejects.
    fn emit_interval(&self) {
        let m = self.metrics;
        let seq = m.interval_seq.fetch_add(1, Ordering::Relaxed);
        let elapsed_s = self.clock.elapsed_s();
        let completed = m.completed.load(Ordering::Relaxed);
        let cache = self.registry.cache_stats();
        let lookups = cache.hits + cache.misses;
        let hit_rate = if lookups > 0 {
            cache.hits as f64 / lookups as f64
        } else {
            0.0
        };
        let a = self.admission.stats();
        let rejects = Json::Arr(a.per_tenant.iter()
            .map(|t| Json::Arr(vec![
                t.tenant.as_str().into(),
                Json::Num((t.rejected_rate_limited
                           + t.rejected_queue_full) as f64),
            ]))
            .collect());
        self.log.emit("serve_interval", vec![
            ("seq", Json::Num(seq as f64)),
            ("completed", Json::Num(completed as f64)),
            ("submitted", Json::Num(m.submitted.load(Ordering::Relaxed) as f64)),
            ("failed", Json::Num(m.failed.load(Ordering::Relaxed) as f64)),
            ("rps", Json::Num(if elapsed_s > 0.0 {
                completed as f64 / elapsed_s
            } else {
                0.0
            })),
            ("p50_us", q_json(m.lat_hist.quantile_us(50.0).ok())),
            ("p95_us", q_json(m.lat_hist.quantile_us(95.0).ok())),
            ("p99_us", q_json(m.lat_hist.quantile_us(99.0).ok())),
            ("queue_depth", m.outstanding.load(Ordering::Relaxed).into()),
            ("cache_hits", Json::Num(cache.hits as f64)),
            ("cache_misses", Json::Num(cache.misses as f64)),
            ("cache_hit_rate", Json::Num(hit_rate)),
            ("rejected", Json::Num(a.rejected_total() as f64)),
            ("tenant_rejects", rejects),
        ]);
    }

    /// Dump the flight recorders now: merged, `(trace_id, meta)`-sorted
    /// `serve_trace` lines for every retained span. The session-end dump
    /// runs regardless; this is the on-demand variant for mid-session
    /// post-mortems.
    pub fn dump_traces(&self) {
        dump_traces(self.metrics, self.log, None);
    }

    /// Dispatch every buffer that has outwaited the policy (timed mode).
    pub fn flush_expired(&self) {
        // analyze: allow(determinism, obs-discipline) timed-mode expiry only; fifo never calls this
        let now = Instant::now();
        let expired =
            lock_observed(&self.batcher_obs, &self.batcher).take_expired(now);
        for batch in expired {
            self.dispatch(batch);
        }
    }

    /// Dispatch all partial batches now (the closed-loop driver calls
    /// this at each wave boundary; `serve` calls it after `body`).
    pub fn flush(&self) {
        let drained = lock_observed(&self.batcher_obs, &self.batcher).drain();
        for batch in drained {
            self.dispatch(batch);
        }
    }

    /// Outstanding requests: buffered + queued + on a worker.
    pub fn queue_depth(&self) -> usize {
        self.metrics.outstanding.load(Ordering::Relaxed)
    }

    pub fn registry(&self) -> &Registry {
        self.registry
    }

    fn dispatch(&self, mut batch: Batch) {
        // coalesce span: submit -> leaving the batcher (buffer time)
        let now = self.clock.now_ns();
        for req in &mut batch.requests {
            req.trace.phase_ns[PH_COALESCE] =
                now.saturating_sub(req.trace.submitted_ns);
        }
        self.metrics.note_batch(batch.requests.len());
        self.service.push(batch);
    }
}

struct WorkerState<'a> {
    /// Held for the session: on real PJRT bindings this is where batch
    /// execution compiles/loads artifacts, exactly-once per process via
    /// the shared exe_cache. The pure-Rust Q_P path needs no compiles.
    _wrt: crate::runtime::WorkerRuntime<'a>,
    log: EventLog,
    /// This worker's index — selects its flight recorder in
    /// [`Metrics::recorders`].
    worker: usize,
}

/// out = x @ Q_P for one request row (Q_P row-major [n, n]).
fn apply_row(input: &[f32], qp: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0f32; n];
    for (k, &xv) in input.iter().enumerate() {
        let row = &qp[k * n..(k + 1) * n];
        for (o, &w) in out.iter_mut().zip(row) {
            *o += xv * w;
        }
    }
    out
}

/// How a worker applies one adapter to request rows (resolved per batch).
enum ApplyPath {
    /// Cached dense Q_P, one row-multiply per request (small q).
    Dense(Arc<Vec<f32>>),
    /// Structured gate application straight from the thetas — no dense
    /// materialization, no LRU traffic (q >= [`STRUCTURED_APPLY_MIN_Q`]).
    Structured(pauli::PauliCircuit),
}

fn process_batch(registry: &Registry, metrics: &Metrics, clock: &SpanClock,
                 state: &mut WorkerState<'_>, ctx: TaskCtx, mut batch: Batch) {
    // queue span: submit -> a worker picked the batch up. Phase values
    // measured from here down are batch-level: every request in the
    // batch reports the shared cache_lookup / materialize durations.
    let picked_ns = clock.now_ns();
    for req in &mut batch.requests {
        req.trace.dispatched_ns = picked_ns;
        req.trace.phase_ns[PH_QUEUE] =
            picked_ns.saturating_sub(req.trace.submitted_ns);
    }
    // resolve the adapter at service time: an immutable snapshot, so a
    // concurrent hot-swap can never tear version/params mid-batch
    let mut lookup_ns = 0u64;
    let snap = {
        let _sp = Span::enter(clock, &mut lookup_ns);
        registry.snapshot(&batch.tenant)
    };
    let snap = match snap {
        Ok(s) => s,
        Err(e) => {
            return fail_batch(metrics, clock, state, ctx, batch, &e.to_string())
        }
    };
    let mut mat_ns = 0u64;
    let path = {
        let _sp = Span::enter(clock, &mut mat_ns);
        if snap.spec.q >= STRUCTURED_APPLY_MIN_Q {
            Ok(ApplyPath::Structured(pauli::build(
                snap.spec.q as usize, snap.spec.n_layers as usize)))
        } else {
            registry.materialized(&snap).map(ApplyPath::Dense)
        }
    };
    let path = match path {
        Ok(p) => p,
        Err(e) => {
            return fail_batch(metrics, clock, state, ctx, batch, &e.to_string())
        }
    };
    let n = snap.spec.dim();
    let tenant_obs = metrics.tenant_obs(&batch.tenant);
    let batch_size = batch.requests.len();
    let Batch { tenant, requests } = batch;
    for mut req in requests {
        let mut trace = std::mem::take(&mut req.trace);
        trace.phase_ns[PH_CACHE_LOOKUP] = lookup_ns;
        trace.phase_ns[PH_MATERIALIZE] = mat_ns;
        if req.input.len() != n {
            let msg = format!(
                "tenant {:?}: input has {} elements but the live adapter \
                 (version {}) has dim {n}",
                tenant, req.input.len(), snap.version);
            metrics.note_failed(1);
            metrics.record_trace(state.worker, TraceRecord {
                tenant: tenant.clone(),
                meta: req.meta,
                batch: batch_size,
                ok: false,
                completed_ns: clock.now_ns(),
                ctx: trace,
            });
            req.fail(msg);
            continue;
        }
        let output = {
            let _sp = Span::enter(clock, &mut trace.phase_ns[PH_APPLY]);
            match &path {
                ApplyPath::Dense(qp) => apply_row(&req.input, qp, n),
                ApplyPath::Structured(circuit) => {
                    let mut row = std::mem::take(&mut req.input);
                    circuit.apply(&mut row, 1, &snap.thetas);
                    row
                }
            }
        };
        // latency through the span clock: logical (and exactly
        // reproducible) in fifo mode, wall time in timed mode — no
        // unchecked u128 -> u64 narrowing anywhere on the path
        let completed_ns = clock.now_ns();
        let latency_ns = completed_ns.saturating_sub(trace.submitted_ns);
        metrics.note_complete(&tenant_obs, latency_ns);
        trace.phase_ns[PH_RESPOND] =
            clock.now_ns().saturating_sub(completed_ns);
        let meta = req.meta;
        metrics.record_trace(state.worker, TraceRecord {
            tenant: tenant.clone(),
            meta,
            batch: batch_size,
            ok: true,
            completed_ns,
            ctx: trace,
        });
        req.complete(Response {
            meta,
            tenant: tenant.clone(),
            version: snap.version,
            checksum: snap.checksum,
            output,
            latency_us: latency_ns as f64 / 1_000.0,
        });
    }
}

fn fail_batch(metrics: &Metrics, clock: &SpanClock,
              state: &mut WorkerState<'_>, ctx: TaskCtx, batch: Batch,
              msg: &str) {
    state.log.emit("serve_error", vec![
        ("tenant", batch.tenant.as_str().into()),
        ("batch_index", ctx.index.into()),
        ("requests", batch.requests.len().into()),
        ("error", msg.into()),
    ]);
    metrics.note_failed(batch.requests.len());
    let completed_ns = clock.now_ns();
    let batch_size = batch.requests.len();
    let Batch { tenant, requests } = batch;
    for mut req in requests {
        // failed requests keep their spans: the flight recorder is most
        // useful exactly when something went wrong
        let trace = std::mem::take(&mut req.trace);
        metrics.record_trace(state.worker, TraceRecord {
            tenant: tenant.clone(),
            meta: req.meta,
            batch: batch_size,
            ok: false,
            completed_ns,
            ctx: trace,
        });
        req.fail(msg.to_string());
    }
}

// ----------------------------------------------------------- trace dumps ---

fn trace_fields(r: &TraceRecord) -> Vec<(&'static str, Json)> {
    vec![
        ("trace", r.ctx.trace_hex().into()),
        ("tenant", r.tenant.as_str().into()),
        ("meta", Json::Num(r.meta as f64)),
        ("batch", r.batch.into()),
        ("ok", Json::Bool(r.ok)),
        ("submitted_ns", Json::Num(r.ctx.submitted_ns as f64)),
        ("completed_ns", Json::Num(r.completed_ns as f64)),
        ("latency_us", Json::Num(r.latency_ns() as f64 / 1_000.0)),
        ("phases", Json::Arr(
            PHASES.iter().zip(r.ctx.phase_ns.iter())
                .map(|(name, &ns)| Json::Arr(vec![
                    (*name).into(), Json::Num(ns as f64),
                ]))
                .collect())),
    ]
}

/// Merge every worker's flight recorder, sort by `(trace_id, meta)` —
/// a deterministic order however batches landed on workers — and emit
/// one `serve_trace` line per retained span, plus a JSONL file when
/// `trace_dir` is set.
fn dump_traces(metrics: &Metrics, log: &EventLog, trace_dir: Option<&Path>) {
    let mut recs: Vec<TraceRecord> = Vec::new();
    for r in &metrics.recorders {
        recs.extend(lock_or_recover(r).records());
    }
    if recs.is_empty() {
        return;
    }
    recs.sort_by_key(|r| (r.ctx.trace_id, r.meta));
    for r in &recs {
        log.emit("serve_trace", trace_fields(r));
    }
    if let Some(dir) = trace_dir {
        if let Err(e) = write_trace_file(dir, &recs) {
            // first failure logs, the rest only count: trace files are
            // best-effort, but the session summary must say they were lost
            if metrics.trace_errors.fetch_add(1, Ordering::Relaxed) == 0 {
                log.emit("serve_trace_error", vec![
                    ("dir", dir.display().to_string().into()),
                    ("error", format!("{e:#}").into()),
                ]);
            }
        }
    }
}

/// One JSONL file per dump: `trace-<pid>-<seq>.jsonl`, the process-wide
/// sequence keeping concurrent sessions (e.g. shards) from clobbering
/// each other.
fn write_trace_file(dir: &Path, recs: &[TraceRecord]) -> Result<()> {
    use std::io::Write as _;
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create trace dir {}", dir.display()))?;
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let path = dir.join(format!("trace-{}-{seq}.jsonl", std::process::id()));
    let mut out = String::new();
    for r in recs {
        out.push_str(&crate::util::json::obj(trace_fields(r)).dump());
        out.push('\n');
    }
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(out.as_bytes())
        .with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

/// A completed serve session: whatever `body` returned, plus the metrics.
pub struct ServeOutcome<R> {
    pub body: R,
    pub summary: ServeSummary,
}

/// Run a scoped serve session (see the module docs). The summary is
/// emitted through `log` before returning; retained trace spans are
/// dumped (as `serve_trace` lines) just before it.
pub fn serve<R, F>(rt: &Runtime, registry: &Registry, cfg: &ServeConfig,
                   log: &EventLog, body: F) -> Result<ServeOutcome<R>>
where
    F: FnOnce(&ServerHandle<'_>) -> Result<R>,
{
    // fail fast on an unusable policy (e.g. max_batch == 0, which would
    // buffer forever): a typed InvalidBatchPolicy before any thread or
    // watcher starts, instead of a silent rewrite at push time
    cfg.policy.validate()?;
    // same fail-fast for observability knobs: a typed InvalidObsKnob
    // (covers the old untyped slo_error_budget bail)
    cfg.validate_obs()?;
    // the process-wide registry this session's serve_* handles live on;
    // a session without one gets a private registry matching its mode
    let mreg = cfg
        .metrics
        .clone()
        .unwrap_or_else(|| MetricsRegistry::new(cfg.fifo));
    let metrics = Metrics::new(cfg, &mreg);
    // the session span clock: logical in fifo mode (driver-advanced, so
    // every latency/timestamp is a pure function of the submission
    // sequence), wall otherwise — the single sanctioned wall-clock
    // source on the serving path
    let clock = SpanClock::new(cfg.fifo);
    // logical clock in fifo mode: admission decisions depend only on the
    // submission sequence (plus explicit advance_clock calls), never on
    // wall time — the fifo byte-identity guarantee extends to rejections
    let admission = Arc::new(AdmissionController::new(cfg.admission, cfg.fifo));
    // admission hot-reload: a stability-window watcher applies config
    // file changes live for the whole session; joined when this guard
    // drops at the end of serve()
    let _reload_watcher = match &cfg.admission_reload {
        Some(spec) => {
            let mut reload =
                AdmissionReload::new(spec.clone(), admission.clone(), log.clone());
            Some(
                pool::Background::spawn(
                    "admission-reload",
                    Duration::from_millis(20),
                    move || {
                        reload.poll();
                    },
                )
                .context("spawn admission-reload watcher")?,
            )
        }
        None => None,
    };
    // analyze: allow(determinism, obs-discipline) wall-clock throughput only; never an emitted line
    let t0 = Instant::now();
    let (body_result, init_errors): (Result<R>, Vec<String>) = pool::run_service(
        cfg.workers,
        |w| {
            let wrt = rt.for_worker(w)?;
            if wrt.is_shared() {
                metrics.shared_client_workers.fetch_add(1, Ordering::Relaxed);
            }
            Ok(WorkerState {
                _wrt: wrt,
                log: log.for_worker(w),
                worker: w,
            })
        },
        |state, ctx, batch: Batch| {
            process_batch(registry, &metrics, &clock, state, ctx, batch)
        },
        |service| {
            let handle = ServerHandle {
                registry,
                service,
                metrics: &metrics,
                admission: admission.as_ref(),
                batcher: Mutex::new(Batcher::new(cfg.policy)),
                batcher_obs: LockObs::register(&mreg, "serve_batcher"),
                fifo: cfg.fifo,
                clock: &clock,
                log,
                metrics_interval: cfg.metrics_interval,
            };
            let r = if cfg.fifo {
                body(&handle)
            } else {
                // timed mode's max-wait bound must hold even when no
                // further submit arrives to piggyback a flush on: a
                // flusher thread sweeps expired buffers on a half-wait
                // cadence for the whole session — and carries the
                // millisecond-cadence serve_interval snapshots
                let stop = AtomicBool::new(false);
                let tick = Duration::from_micros(
                    (cfg.policy.max_wait_us / 2).max(50));
                let interval_ns =
                    cfg.metrics_interval.saturating_mul(1_000_000);
                std::thread::scope(|s| {
                    s.spawn(|| {
                        let mut last_emit = clock.now_ns();
                        while !stop.load(Ordering::Acquire) {
                            handle.flush_expired();
                            if interval_ns > 0 {
                                let now = clock.now_ns();
                                if now.saturating_sub(last_emit) >= interval_ns {
                                    last_emit = now;
                                    handle.emit_interval();
                                }
                            }
                            std::thread::sleep(tick);
                        }
                    });
                    let r = catch_unwind(AssertUnwindSafe(|| body(&handle)));
                    stop.store(true, Ordering::Release);
                    match r {
                        Ok(r) => r,
                        Err(p) => resume_unwind(p),
                    }
                })
            };
            handle.flush();
            r
        },
    );
    let wall_s = t0.elapsed().as_secs_f64();
    // worker-init failures are the root cause behind any "request
    // dropped unserved" errors the body saw — log them and attach them
    // to the body's error instead of discarding the diagnosis
    for e in &init_errors {
        log.emit("serve_error", vec![("error", e.as_str().into())]);
    }
    let body_value = match body_result {
        Ok(v) => v,
        Err(e) if !init_errors.is_empty() => {
            return Err(e.context(format!(
                "serve worker(s) failed to start: [{}]",
                init_errors.join("; "))));
        }
        Err(e) => return Err(e),
    };
    // session-end flight-recorder dump: serve_trace lines land before
    // the summary (and killing a shard ends its session, so a killed
    // shard's spans are dumped through this same path)
    dump_traces(&metrics, log, cfg.trace_dir.as_deref());
    let summary = metrics.summarize(cfg.workers, wall_s, registry.cache_stats(),
                                    admission.stats());
    summary.emit(log);
    Ok(ServeOutcome { body: body_value, summary })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantum::pauli;
    use crate::serve::registry::PauliSpec;

    fn test_registry() -> Registry {
        let reg = Registry::new(1 << 22);
        let spec = PauliSpec { q: 3, n_layers: 1 };
        let thetas: Vec<f32> = (0..spec.num_params())
            .map(|i| (i as f32 * 0.31).sin())
            .collect();
        reg.register("t0", spec, thetas).unwrap();
        reg
    }

    #[test]
    fn serve_round_trip_matches_direct_apply() {
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig { workers: 2, ..ServeConfig::default() };
        let input: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).cos()).collect();
        let outcome = serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            let r = h.submit("t0", 7, input.clone())?;
            h.flush();
            r.wait()
        }).unwrap();
        let resp = outcome.body;
        assert_eq!(resp.meta, 7);
        assert_eq!(resp.version, 1);
        // the served output is exactly x @ Q_P for the registered thetas
        let snap = reg.snapshot("t0").unwrap();
        let c = pauli::build(3, 1);
        let mut expect = input.clone();
        c.apply(&mut expect, 1, &snap.thetas);
        for (a, b) in resp.output.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert_eq!(outcome.summary.completed, 1);
        assert_eq!(outcome.summary.failed, 0);
        assert_eq!(outcome.summary.max_queue_depth, 1);
        // fifo latencies are logical: the driver never advanced the
        // clock, so the recorded latency is exactly zero
        assert_eq!(resp.latency_us, 0.0);
        // SLO tracking is off by default
        assert!(outcome.summary.slo.is_none());
    }

    #[test]
    fn unknown_tenant_and_bad_dim_fail_at_submit() {
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig::default();
        serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            assert!(h.submit("nope", 0, vec![0.0; 8]).is_err());
            assert!(h.submit("t0", 0, vec![0.0; 7]).is_err());
            Ok(())
        }).unwrap();
    }

    #[test]
    fn unwaited_requests_resolve_on_session_end() {
        // submit without flush: serve()'s end-of-body flush dispatches
        // the partial batch; the handle resolves after the session
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig::default();
        let outcome = serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            h.submit("t0", 3, vec![0.5; 8])
        }).unwrap();
        let resp = outcome.body.wait().unwrap();
        assert_eq!(resp.meta, 3);
        assert_eq!(outcome.summary.submitted, 1);
    }

    #[test]
    fn trace_dir_failure_is_logged_once_and_counted() {
        // point --trace-dir at a path occupied by a *file*: the dump's
        // create_dir_all fails, and the session must say so instead of
        // silently dropping the traces
        let dir = std::env::temp_dir()
            .join(format!("qp_trace_err_{}", std::process::id()));
        let events = std::env::temp_dir()
            .join(format!("qp_trace_err_events_{}.jsonl", std::process::id()));
        std::fs::write(&dir, b"not a directory").unwrap();
        let _ = std::fs::remove_file(&events);
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig {
            trace_dir: Some(dir.clone()),
            ..ServeConfig::default()
        };
        let log = EventLog::new(Some(events.clone()), false).unwrap();
        let outcome = serve(&rt, &reg, &cfg, &log, |h| {
            let r = h.submit("t0", 1, vec![0.2; 8])?;
            h.flush();
            r.wait()
        }).unwrap();
        drop(log);
        assert_eq!(outcome.summary.trace_errors, 1);
        let text = std::fs::read_to_string(&events).unwrap();
        let err_lines = text.lines()
            .filter(|l| l.contains("\"serve_trace_error\""))
            .count();
        assert_eq!(err_lines, 1, "{text}");
        let _ = std::fs::remove_file(&dir);
        let _ = std::fs::remove_file(&events);
    }

    #[test]
    fn percentiles_are_sane() {
        let ns: Vec<u64> = (1..=100).map(|i| i * 1000).collect();
        assert!((percentile_us(&ns, 50.0) - 51.0).abs() < 2.0);
        assert!((percentile_us(&ns, 99.0) - 99.0).abs() < 2.0);
        assert_eq!(percentile_us(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_is_nearest_rank_at_tiny_lengths() {
        // len = 1: every percentile is that one observation
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile_us(&[5_000], p), 5.0, "p={p}");
        }
        // len = 2: nearest-rank takes the lower sample up to p50
        // (ceil(0.5 * 2) = 1) and the upper one strictly after
        assert_eq!(percentile_us(&[1_000, 9_000], 0.0), 1.0);
        assert_eq!(percentile_us(&[1_000, 9_000], 50.0), 1.0);
        assert_eq!(percentile_us(&[1_000, 9_000], 51.0), 9.0);
        assert_eq!(percentile_us(&[1_000, 9_000], 99.0), 9.0);
        assert_eq!(percentile_us(&[1_000, 9_000], 100.0), 9.0);
        // the returned value is always an observed sample, never an
        // interpolation
        let ns = [1_000u64, 2_000, 4_000];
        for p in [10.0, 33.4, 66.7, 90.0] {
            let v = (percentile_us(&ns, p) * 1_000.0) as u64;
            assert!(ns.contains(&v), "p={p} gave {v}");
        }
    }

    #[test]
    fn structured_apply_path_matches_dense_and_skips_the_cache() {
        // q = 6 sits exactly at STRUCTURED_APPLY_MIN_Q: output must equal
        // the dense x @ Q_P while the materialization cache stays untouched
        let reg = Registry::new(1 << 26);
        let spec = PauliSpec { q: 6, n_layers: 2 };
        let thetas: Vec<f32> = (0..spec.num_params())
            .map(|i| (i as f32 * 0.23).sin())
            .collect();
        reg.register("big", spec, thetas.clone()).unwrap();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig { workers: 2, ..ServeConfig::default() };
        let input: Vec<f32> = (0..64).map(|i| (i as f32 * 0.11).cos()).collect();
        let outcome = serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            let r = h.submit("big", 1, input.clone())?;
            h.flush();
            r.wait()
        })
        .unwrap();
        // dense reference computed directly from the same snapshot
        let circuit = pauli::build(6, 2);
        let dense = circuit.materialize(&thetas);
        let expect = apply_row(&input, &dense, 64);
        for (a, b) in outcome.body.output.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        let s = reg.cache_stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0),
                   "structured path touched the dense LRU: {s:?}");
    }

    #[test]
    fn fifo_queue_cap_rejects_deterministically_and_counts_per_tenant() {
        use crate::serve::admission::{AdmissionConfig, RejectReason, Rejected};
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig {
            workers: 2,
            // max_batch larger than the cap so nothing auto-dispatches:
            // the buffered backlog is exactly the admit count
            policy: BatchPolicy { max_batch: 100, max_wait_us: 0 },
            fifo: true,
            admission: AdmissionConfig { rate_rps: 0.0, burst: 1.0, max_queue: 10 },
            ..ServeConfig::default()
        };
        let outcome = serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            let mut handles = Vec::new();
            let mut rejected = 0u64;
            for i in 0..50u64 {
                match h.submit("t0", i, vec![0.5; 8]) {
                    Ok(hd) => handles.push(hd),
                    Err(e) => {
                        let r = e.downcast_ref::<Rejected>().expect("typed");
                        assert_eq!(r.reason, RejectReason::QueueFull);
                        assert_eq!(r.tenant, "t0");
                        rejected += 1;
                    }
                }
            }
            // exactly the first 10 fit under the cap, rest shed
            assert_eq!(handles.len(), 10);
            assert_eq!(rejected, 40);
            h.flush();
            for hd in handles {
                hd.wait()?;
            }
            // backlog drained: the cap admits again
            assert!(h.submit("t0", 99, vec![0.5; 8]).is_ok());
            Ok(())
        })
        .unwrap();
        let a = &outcome.summary.admission;
        assert!(a.enabled);
        assert_eq!(a.admitted, 11);
        assert_eq!(a.rejected_queue_full, 40);
        assert_eq!(a.rejected_rate_limited, 0);
        assert_eq!(a.per_tenant.len(), 1);
        assert_eq!(a.per_tenant[0].tenant, "t0");
        assert_eq!(a.per_tenant[0].rejected_queue_full, 40);
        assert_eq!(outcome.summary.completed, 11);
    }

    #[test]
    fn timed_queue_cap_bounds_real_outstanding_depth() {
        use crate::serve::admission::{AdmissionConfig, Rejected};
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig {
            workers: 1,
            policy: BatchPolicy { max_batch: 1, max_wait_us: 50 },
            fifo: false,
            admission: AdmissionConfig { rate_rps: 0.0, burst: 1.0, max_queue: 4 },
            ..ServeConfig::default()
        };
        let attempts = 64u64;
        let outcome = serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            let mut handles = Vec::new();
            let mut rejected = 0u64;
            for i in 0..attempts {
                match h.submit("t0", i, vec![0.5; 8]) {
                    Ok(hd) => handles.push(hd),
                    Err(e) => {
                        assert!(e.downcast_ref::<Rejected>().is_some(), "{e}");
                        rejected += 1;
                    }
                }
            }
            for hd in handles {
                hd.wait()?;
            }
            Ok(rejected)
        })
        .unwrap();
        let a = &outcome.summary.admission;
        // accounting closes: every attempt either completed or rejected
        assert_eq!(a.admitted + a.rejected_queue_full, attempts);
        assert_eq!(outcome.summary.completed, a.admitted);
        assert_eq!(outcome.body, a.rejected_queue_full);
        // the cap held: with the gauge read before each admit, the
        // outstanding gauge can never exceed max_queue
        assert!(outcome.summary.max_queue_depth <= 4,
                "depth {} breached the cap", outcome.summary.max_queue_depth);
    }

    #[test]
    fn slo_violations_are_counted_against_logical_latency() {
        // fifo + an advanced clock between submit and completion: the
        // logical latency exceeds the target, so the violation is
        // counted and the summary carries the SLO section
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig {
            workers: 1,
            slo_p99_us: 100.0,
            slo_error_budget: 0.5,
            ..ServeConfig::default()
        };
        let outcome = serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            // request 0: completes with the clock still at submit time
            let a = h.submit("t0", 0, vec![0.5; 8])?;
            h.flush();
            a.wait()?;
            // request 1: the driver declares 1ms of logical time while
            // it is in flight (before the flush that serves it)
            let b = h.submit("t0", 1, vec![0.5; 8])?;
            h.advance_clock(1e-3);
            h.flush();
            let r = b.wait()?;
            assert!((r.latency_us - 1000.0).abs() < 1e-9, "{}", r.latency_us);
            Ok(())
        }).unwrap();
        let slo = outcome.summary.slo.as_ref().expect("slo enabled");
        assert_eq!(slo.per_tenant.len(), 1);
        let t = &slo.per_tenant[0];
        assert_eq!((t.requests, t.violations), (2, 1));
        // budget 0.5 over 2 requests allows exactly 1 violation
        assert!(t.compliant(slo.error_budget));
        assert_eq!(slo.breached(), 0);
        assert!((t.burn(slo.error_budget) - 1.0).abs() < 1e-12);
        // the session histogram caught the same two samples
        assert_eq!(outcome.summary.completed, 2);
        assert!(outcome.summary.p99_us.unwrap() > 0.0);
    }

    #[test]
    fn invalid_slo_budget_fails_fast() {
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig {
            slo_p99_us: 50.0,
            slo_error_budget: 0.0,
            ..ServeConfig::default()
        };
        let e = serve(&rt, &reg, &cfg, &EventLog::null(), |_h| Ok(()))
            .unwrap_err();
        let knob = e
            .downcast_ref::<InvalidObsKnob>()
            .expect("typed observability knob error lost");
        assert_eq!(knob.knob, "slo_error_budget");
        assert!(e.to_string().contains("slo_error_budget"), "{e}");
    }

    #[test]
    fn validate_obs_rejects_every_nonsense_knob() {
        // each bad knob is caught by the shared validator with the
        // offending field named; the default config passes
        ServeConfig::default().validate_obs().unwrap();
        let cases: Vec<(ServeConfig, &str)> = vec![
            (
                ServeConfig { slo_p99_us: -1.0, ..ServeConfig::default() },
                "slo_p99_us",
            ),
            (
                ServeConfig {
                    slo_p99_us: 50.0,
                    slo_error_budget: -0.25,
                    ..ServeConfig::default()
                },
                "slo_error_budget",
            ),
            (
                ServeConfig { recorder_cap: 0, ..ServeConfig::default() },
                "recorder_cap",
            ),
        ];
        for (cfg, expect) in cases {
            let e = cfg.validate_obs().unwrap_err();
            let knob = e
                .downcast_ref::<InvalidObsKnob>()
                .unwrap_or_else(|| panic!("untyped error for {expect}: {e}"));
            assert_eq!(knob.knob, expect);
        }
        // an SLO target of exactly 0 means "tracking off" and is fine
        // even with a zero budget (the budget is never consulted)
        ServeConfig { slo_error_budget: 0.0, ..ServeConfig::default() }
            .validate_obs()
            .unwrap();
    }

    #[test]
    fn serve_sessions_sharing_a_registry_sum_into_fleet_totals() {
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let mreg = MetricsRegistry::new(true);
        let cfg = ServeConfig {
            metrics: Some(mreg.clone()),
            ..ServeConfig::default()
        };
        for round in 0..2u64 {
            let outcome = serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
                let r = h.submit("t0", round, vec![0.5; 8])?;
                h.flush();
                r.wait()
            })
            .unwrap();
            // each session's summary stays session-local...
            assert_eq!(outcome.summary.completed, 1);
        }
        // ...while the shared registry accumulates across sessions
        let snap = mreg.snapshot();
        let completed = snap
            .iter()
            .find(|v| v.name == "serve_requests_completed_total")
            .expect("serve counter registered");
        assert!(
            matches!(completed.reading,
                     crate::obs::metrics::Reading::Counter(2)),
            "{completed:?}"
        );
        // the batcher lock site reported its acquires
        let locks = LockObs::register(&mreg, "serve_batcher");
        assert!(locks.acquires() >= 2, "{}", locks.acquires());
    }
}
